"""End-to-end driver: train a ~100M-param LM for a few hundred steps on the
synthetic pipeline, with checkpointing, and show the loss dropping.

Default is a width-reduced gemma (CPU-sized ~ a few M params) so the example
finishes in minutes; pass --hundred-m for the ~100M-parameter variant
(mamba2-130m full config) if you have the cycles.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--hundred-m", action="store_true",
                    help="train the full mamba2-130m config (slow on CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    argv = ["--arch", "mamba2-130m" if args.hundred_m else "gemma-2b",
            "--steps", str(args.steps), "--batch", "8", "--seq", "256",
            "--lr", "1e-3", "--ckpt-dir", args.ckpt_dir,
            "--ckpt-every", "100", "--log-every", "20"]
    if not args.hundred_m:
        argv.append("--smoke")
    final_loss = train_main(argv)
    print(f"[example] final loss {final_loss:.4f}")


if __name__ == "__main__":
    main()
