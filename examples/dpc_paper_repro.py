"""Paper reproduction driver: re-creates the paper's §6 experiment suite at
container scale and prints each table (see benchmarks/ for the harnesses).

    PYTHONPATH=src python examples/dpc_paper_repro.py [--full]
"""
import argparse

from benchmarks import accuracy, eps_sweep, scaling_dcut, scaling_n


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    n = 40_000 if args.full else 10_000

    print("== Tables 2-4: accuracy (Rand index vs Ex-DPC) ==")
    accuracy.main(n=n)
    print("\n== Table 5: S-Approx-DPC eps trade-off ==")
    eps_sweep.main(n=n)
    print("\n== Fig 7: cardinality scaling (fitted exponents) ==")
    exps = scaling_n.main(n_max=max(n, 16_000))
    print("\n== Fig 8: d_cut sensitivity ==")
    scaling_dcut.main(n=n // 2)

    print("\nPaper-claim checks:")
    print(f"  scan slope ~2 (quadratic):      {exps.get('scan', float('nan')):.2f}")
    print(f"  exdpc slope < scan:             {exps['exdpc']:.2f}")
    print(f"  sapproxdpc slope ~1 (linear):   {exps['sapproxdpc']:.2f}")


if __name__ == "__main__":
    main()
