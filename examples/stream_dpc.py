"""Streaming DPC under drift: sliding-window clustering with stable ids.

A ``drifting_batches`` stream (random-walk cluster centers that keep moving
each tick) feeds ``StreamDPC``: the window fills, steady-state incremental
ingest takes over, and the per-tick output shows cluster *continuity* —
stable center ids surviving drift, fresh ids for clusters that wander into
the window, and the full-rebuild fallback firing when the walk leaves the
indexed box.

    PYTHONPATH=src python examples/stream_dpc.py
"""
import numpy as np

from repro.data.points import drifting_batches
from repro.stream import StreamDPC, StreamDPCConfig


def main():
    cap, batch, k = 4096, 256, 6
    cfg = StreamDPCConfig(d_cut=3500.0, capacity=cap, batch_cap=batch,
                          rho_min=8.0, extent_margin=2)
    s = StreamDPC(cfg)
    stream = drifting_batches(batch=batch, ticks=cap // batch + 24, k=k,
                              d=2, seed=1, sigma=0.012, drift=0.03)

    prev_ids: set[int] = set()
    print(f"window={cap} batch={batch} d_cut={cfg.d_cut:.0f} "
          f"(drifting {k}-cluster walk)")
    for t, (pts, _, centers) in enumerate(stream):
        tick = s.ingest(pts)
        if not s.window.full:
            continue
        ids = set(int(x) for x in tick.stable_ids)
        born, died = sorted(ids - prev_ids), sorted(prev_ids - ids)
        prev_ids = ids
        noise = int((tick.labels < 0).sum())
        flags = "".join(["R" if tick.rebuilt else "",
                         "F" if tick.full_recompute else ""])
        print(f"tick {t:3d}  clusters={tick.num_clusters:2d} "
              f"ids={sorted(ids)} born={born or '-'} died={died or '-'} "
              f"noise={noise:4d} {flags}")
    st = s.stats()
    print(f"\n{st['ticks']} ticks, {st['rebuilds']} grid rebuilds, "
          f"{st['full_recomputes']} full recomputes, "
          f"{st['live_cells']} live cells "
          f"(budget {st['maxima_cap']})")
    print("stable ids persisted across drift; fresh ids only when a "
          "cluster entered/left the window")


if __name__ == "__main__":
    main()
