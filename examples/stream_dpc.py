"""Streaming DPC under drift: sliding-window clustering with stable ids,
driven through the unified ``DPCEngine.partial_fit``.

A ``drifting_batches`` stream (random-walk cluster centers that keep moving
each tick) feeds the engine: the window fills, steady-state incremental
ingest takes over, and the per-tick output shows cluster *continuity* —
stable center ids surviving drift, fresh ids for clusters that wander into
the window, and the full-rebuild fallback firing when the walk leaves the
indexed box.  ``predict`` labels probe points read-only between ticks.

    PYTHONPATH=src python examples/stream_dpc.py [--ticks 40] [--exec jnp:dense]

CI runs this script as an executable smoke doc with a small ``--ticks``.
"""
import argparse

from repro.data.points import drifting_batches
from repro.engine import DPCEngine, ExecSpec


def main(extra_ticks=24, exec_spec=None):
    cap, batch, k = 4096, 256, 6
    spec = exec_spec or ExecSpec()
    eng = DPCEngine(d_cut=3500.0, rho_min=8.0, window_capacity=cap,
                    batch_cap=batch, exec_spec=spec,
                    stream_options={"extent_margin": 2})
    stream = drifting_batches(batch=batch, ticks=cap // batch + extra_ticks,
                              k=k, d=2, seed=1, sigma=0.012, drift=0.03)

    prev_ids: set[int] = set()
    print(f"window={cap} batch={batch} d_cut={eng.d_cut:.0f} "
          f"exec={spec.describe()} (drifting {k}-cluster walk)")
    for t, (pts, _, centers) in enumerate(stream):
        tick = eng.partial_fit(pts)
        if not eng.stream.window.full:
            continue
        ids = set(int(x) for x in tick.stable_ids)
        born, died = sorted(ids - prev_ids), sorted(prev_ids - ids)
        prev_ids = ids
        noise = int((tick.labels < 0).sum())
        flags = "".join(["R" if tick.rebuilt else "",
                         "F" if tick.full_recompute else ""])
        print(f"tick {t:3d}  clusters={tick.num_clusters:2d} "
              f"ids={sorted(ids)} born={born or '-'} died={died or '-'} "
              f"noise={noise:4d} {flags}")
    st = eng.stream.stats()
    q = eng.predict(pts)                 # read-only: label the last batch
    print(f"\n{st['ticks']} ticks, {st['rebuilds']} grid rebuilds, "
          f"{st['full_recomputes']} full recomputes, "
          f"{st['live_cells']} live cells "
          f"(budget {st['maxima_cap']})")
    print(f"predict on the last batch: {int((q.status == 0).sum())}"
          f"/{len(q.labels)} HIT")
    print("stable ids persisted across drift; fresh ids only when a "
          "cluster entered/left the window")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ticks", type=int, default=24,
                    help="steady-state ticks after the window fills")
    ap.add_argument("--exec", dest="exec_spec", default=None,
                    help="backend:layout:precision (ExecSpec.parse)")
    a = ap.parse_args()
    main(extra_ticks=a.ticks, exec_spec=ExecSpec.parse(a.exec_spec)
         if a.exec_spec else None)
