"""Quickstart: cluster a 2-D Gaussian mixture with every DPC algorithm via
the unified DPCEngine and print the decision graph peaks (paper Fig. 1) +
Rand agreement.

    PYTHONPATH=src python examples/quickstart.py [--n 8000] [--exec jnp:dense]

``--exec backend:layout:precision`` is the uniform execution flag
(repro.engine.ExecSpec.parse): e.g. ``--exec jnp:block-sparse`` runs every
algorithm through the grid-pruned worklist engine.  CI runs this script as
an executable smoke doc with a small ``--n``.
"""
import argparse

import numpy as np

from repro.core import rand_index
from repro.data.points import gaussian_mixture
from repro.engine import DPCEngine, ExecSpec


def main(n=8000, exec_spec=None):
    k = 15
    pts, true_labels = gaussian_mixture(n, k=k, d=2, overlap=0.015, seed=0)
    # d_cut: ~1.5% distance quantile (the paper's rule of thumb)
    from repro.core.tuning import pick_dcut
    d_cut = pick_dcut(pts, target_rho=max(min(40, n // 200), 5))
    spec = exec_spec or ExecSpec()
    print(f"n={n}, k={k}, d_cut={d_cut:.1f}, exec={spec.describe()}")

    ref_labels = ref_eng = None
    for algo in ("exdpc", "approxdpc", "sapproxdpc", "scan", "lsh_ddp"):
        eng = DPCEngine(d_cut=d_cut, rho_min=8, algorithm=algo,
                        exec_spec=spec).fit(pts)
        labels = eng.labels_
        if ref_labels is None:          # exdpc = reference
            ref_labels, ref_eng = labels, eng
            dg = np.asarray(eng.decision_graph())
            gamma = dg[:, 0] * np.where(np.isfinite(dg[:, 1]), dg[:, 1],
                                        dg[np.isfinite(dg[:, 1]), 1].max())
            top = np.sort(gamma)[-k - 3:]
            print(f"  decision-graph gap: top-{k} gamma >= {top[3]:.3g}, "
                  f"next {top[2]:.3g} (clear gap = easy center selection)")
        ri = rand_index(ref_labels, labels)
        vs_true = rand_index(true_labels, labels)
        print(f"  {algo:12s} clusters={int(eng.clustering.num_clusters):3d} "
              f"rand_vs_exdpc={ri:.4f} rand_vs_truth={vs_true:.4f}")

    # the engine's serve-side read path: label unseen points without refit
    # (on the exact reference engine, not whichever baseline ran last)
    probe, _ = gaussian_mixture(64, k=k, d=2, overlap=0.015, seed=1)
    q = ref_eng.predict(probe)
    hits = int((q.status == 0).sum())
    print(f"  predict: {hits}/{len(probe)} probes HIT within d_cut "
          f"(rest fall back to the nearest center)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--exec", dest="exec_spec", default=None,
                    help="backend:layout:precision (ExecSpec.parse)")
    a = ap.parse_args()
    main(n=a.n, exec_spec=ExecSpec.parse(a.exec_spec)
         if a.exec_spec else None)
