"""Quickstart: cluster a 2-D Gaussian mixture with every DPC algorithm and
print the decision graph peaks (paper Fig. 1) + Rand agreement.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import DPCConfig, cluster, decision_graph, rand_index
from repro.data.points import gaussian_mixture

def main():
    n, k = 8000, 15
    pts, true_labels = gaussian_mixture(n, k=k, d=2, overlap=0.015, seed=0)
    # d_cut: ~1.5% distance quantile (the paper's rule of thumb)
    from repro.core.tuning import pick_dcut
    d_cut = pick_dcut(pts, target_rho=40)
    print(f"n={n}, k={k}, d_cut={d_cut:.1f}")

    ref_labels = None
    for algo in ("exdpc", "approxdpc", "sapproxdpc", "scan", "lsh_ddp"):
        out, res = cluster(pts, DPCConfig(d_cut=d_cut, rho_min=8,
                                          algorithm=algo))
        labels = np.asarray(out.labels)
        if ref_labels is None:          # exdpc = reference
            ref_labels = labels
            dg = np.asarray(decision_graph(res))
            gamma = dg[:, 0] * np.where(np.isfinite(dg[:, 1]), dg[:, 1],
                                        dg[np.isfinite(dg[:, 1]), 1].max())
            top = np.sort(gamma)[-k - 3:]
            print(f"  decision-graph gap: top-{k} gamma >= {top[3]:.3g}, "
                  f"next {top[2]:.3g} (clear gap = easy center selection)")
        ri = rand_index(ref_labels, labels)
        vs_true = rand_index(true_labels, labels)
        print(f"  {algo:12s} clusters={int(out.num_clusters):3d} "
              f"rand_vs_exdpc={ri:.4f} rand_vs_truth={vs_true:.4f}")

if __name__ == "__main__":
    main()
