"""HuBERT-style unit discovery with DPC instead of k-means.

HuBERT's pseudo-labels come from clustering frame features; k-means is
noise-sensitive and needs k fixed a priori — exactly the weaknesses the DPC
paper targets (§1, §2.2).  This example embeds synthetic frames with the
(reduced) hubert-xlarge backbone, clusters the hidden states with
Approx-DPC, and reports cluster quality vs k-means against the underlying
phone-like modes.

    PYTHONPATH=src python examples/hubert_units.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduce_config
from repro.core import DPCConfig, cluster, rand_index
from repro.core.cfsfdp_a import kmeans_pivots
from repro.models import build_model
from repro.models import transformer as tfm
from repro.core.tuning import pick_dcut


def main():
    cfg = reduce_config(ARCHS["hubert-xlarge"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # synthetic "audio": frames drawn around `units` phone modes
    rng = np.random.default_rng(0)
    units, B, L = 10, 4, 256
    modes = rng.normal(0, 1.0, (units, cfg.frontend_dim)).astype(np.float32)
    assign = rng.integers(0, units, (B, L))
    feats = modes[assign] + rng.normal(0, 0.25, (B, L, cfg.frontend_dim))

    # embed with the encoder backbone, project to 2-3 dims for DPC (the
    # paper's low-dim regime; §2.1 prescribes dimensionality reduction)
    x = jnp.einsum("blf,fd->bld", jnp.asarray(feats, jnp.float32)
                   .astype(cfg.dtype), params["frontend"])
    h = tfm.forward(params, x, cfg, jnp.arange(L, dtype=jnp.int32))
    hidden = np.asarray(h.astype(jnp.float32)).reshape(B * L, -1)
    hidden = hidden - hidden.mean(0)
    u, s, vt = np.linalg.svd(hidden, full_matrices=False)
    proj = (u[:, :3] * s[:3]).astype(np.float32)
    truth = assign.reshape(-1)

    d_cut = pick_dcut(proj, target_rho=30)
    out, _ = cluster(proj, DPCConfig(d_cut=d_cut, rho_min=5,
                                     algorithm="approxdpc"))
    ri_dpc = rand_index(truth, np.asarray(out.labels))

    _, km_assign = kmeans_pivots(jnp.asarray(proj), k=units, iters=20)
    ri_km = rand_index(truth, np.asarray(km_assign))

    print(f"[hubert-units] frames={B * L}, true units={units}")
    print(f"  DPC     units={int(out.num_clusters)}  rand={ri_dpc:.4f} "
          f"(k discovered from the decision graph)")
    print(f"  k-means units={units} (given!)  rand={ri_km:.4f}")


if __name__ == "__main__":
    main()
