"""Serve a small LM with batched requests + DPC-KV cache compression.

Runs the batched engine (prefill -> decode) on a reduced gemma config, then
compresses the prompt KV cache with density-peaks clustering and compares
the next-token distribution against the full cache — the paper's clustering
as a serving feature (DESIGN.md §5).

    PYTHONPATH=src python examples/serve_dpc_kv.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduce_config
from repro.models import build_model
from repro.serve import DPCKVConfig, ServeConfig, ServeEngine, compress_kv
from repro.serve.dpc_kv import attend_compressed


def main():
    cfg = reduce_config(ARCHS["gemma-2b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    engine = ServeEngine(model, params, ServeConfig(
        batch=4, max_prompt=96, max_new_tokens=16, temperature=0.0))
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab, rng.integers(20, 90)))
               for _ in range(4)]
    out = engine.generate(prompts)
    print(f"[serve] generated {out.shape[1]} tokens x {out.shape[0]} requests")
    print(f"[serve] first request: {out[0][:12].tolist()} ...")

    # --- DPC-KV: compress the final cache and compare one decode step
    cache = engine.cache
    k, v = cache.k[0], cache.v[0]          # layer 0: (B, S, K, hd)
    B, S, K, hd = k.shape
    budget = max(16, S // 8)
    kc, vc, cnt = compress_kv(k.astype(jnp.float32), v.astype(jnp.float32),
                              jnp.int32(S), DPCKVConfig(budget=budget))
    q = jax.random.normal(jax.random.PRNGKey(1), (B, cfg.n_heads, hd),
                          jnp.float32)
    full = attend_compressed(q, k.astype(jnp.float32), v.astype(jnp.float32),
                             jnp.ones((B, S, K)))
    comp = attend_compressed(q, kc, vc, cnt)
    err = float(jnp.linalg.norm(comp - full) / jnp.linalg.norm(full))
    print(f"[dpc-kv] cache {S} -> {budget} centers "
          f"({S / budget:.0f}x smaller), attention output rel-err {err:.3f}")


if __name__ == "__main__":
    main()
