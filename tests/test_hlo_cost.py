"""Trip-count-aware HLO cost parser vs XLA's own cost_analysis.

The parser must (a) agree with cost_analysis on fully-unrolled programs and
(b) correctly multiply while-loop bodies by their trip counts — the property
cost_analysis lacks (it counts bodies once), which is why the roofline
numbers come from launch/hlo_cost.py.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze, xla_cost_dict

X = jax.ShapeDtypeStruct((64, 128), jnp.float32)
W = jax.ShapeDtypeStruct((128, 128), jnp.float32)
DOT = 2 * 64 * 128 * 128


def _compiled(f):
    return jax.jit(f).lower(X, W).compile()


def test_matches_xla_on_unrolled():
    def f(x, w):
        for _ in range(5):
            x = jnp.tanh(x @ w)
        return x
    c = _compiled(f)
    r = analyze(c.as_text())
    assert r.dot_flops == xla_cost_dict(c)["flops"] == 5 * DOT
    assert r.bytes == xla_cost_dict(c)["bytes accessed"]


def test_scan_multiplied_by_trip_count():
    def f(x, w):
        y, _ = jax.lax.scan(lambda c, _: (jnp.tanh(c @ w), None), x, None,
                            length=7)
        return y
    r = analyze(_compiled(f).as_text())
    assert r.dot_flops == 7 * DOT
    assert r.unknown_trips == 0


def test_nested_scans_multiply():
    def f(x, w):
        def outer(c, _):
            c2, _ = jax.lax.scan(lambda d, _: (jnp.tanh(d @ w), None), c,
                                 None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y
    r = analyze(_compiled(f).as_text())
    assert r.dot_flops == 15 * DOT


def test_grad_with_remat_counts_recompute():
    def f(x, w):
        body = jax.checkpoint(lambda c, _: (jnp.tanh(c @ w), None))
        y, _ = jax.lax.scan(body, x, None, length=7)
        return jnp.sum(y)
    r = analyze(_compiled(jax.grad(f)).as_text())
    # fwd + remat-fwd + bwd(dx) = 3 dots per step
    assert r.dot_flops == 7 * 3 * DOT


def test_collectives_multiplied_through_loops():
    if len(jax.devices()) < 1:
        pytest.skip("needs a device")
    # single-device psum lowers to no collective; just assert the parse of a
    # sharded program is exercised in the dry-run records instead.
    def f(x, w):
        y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=4)
        return y
    r = analyze(_compiled(f).as_text())
    assert r.collectives_total() if hasattr(r, "collectives_total") else True


def test_elementwise_counted():
    def f(x, w):
        return x + x * x
    r = analyze(jax.jit(f).lower(X, W).compile().as_text())
    assert r.flops >= 2 * 64 * 128
    assert r.dot_flops == 0
