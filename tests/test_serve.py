"""Serving engine: fixed shapes, determinism, prompt handling."""
import numpy as np
import jax

from repro.configs import ARCHS, reduce_config
from repro.models import build_model
from repro.serve import ServeConfig, ServeEngine
from repro.serve.dpc_kv import DPCKVConfig


def _engine(arch="gemma-2b", **kw):
    cfg = reduce_config(ARCHS[arch])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return ServeEngine(model, params, ServeConfig(**kw)), cfg


class TestServeEngine:
    def test_greedy_is_deterministic(self):
        eng, cfg = _engine(batch=2, max_prompt=32, max_new_tokens=8)
        rng = np.random.default_rng(0)
        prompts = [list(rng.integers(0, cfg.vocab, 20)) for _ in range(2)]
        out1 = eng.generate(prompts)
        eng2, _ = _engine(batch=2, max_prompt=32, max_new_tokens=8)
        out2 = eng2.generate(prompts)
        np.testing.assert_array_equal(out1, out2)
        assert out1.shape == (2, 8)

    def test_ragged_prompts_padded(self):
        eng, cfg = _engine(batch=3, max_prompt=16, max_new_tokens=4)
        prompts = [[1, 2, 3], list(range(30)), [5]]   # short / too-long / tiny
        out = eng.generate(prompts)
        assert out.shape == (3, 4)

    def test_compress_prompt_cache(self):
        """DPC-KV compresses the prefilled prompt cache through the kernel
        backend: fixed output shapes, mass <= prompt positions."""
        kv = DPCKVConfig(budget=8, backend="jnp")
        eng, cfg = _engine(batch=2, max_prompt=32, max_new_tokens=4,
                           dpc_kv=kv)
        rng = np.random.default_rng(2)
        eng.generate([list(rng.integers(0, cfg.vocab, 20)) for _ in range(2)])
        k_c, v_c, counts = eng.compress_prompt_cache()
        L = eng.cache.k.shape[0]
        K, hd = eng.cache.k.shape[3], eng.cache.k.shape[4]
        assert k_c.shape == (L, 2, 8, K, hd)
        assert v_c.shape == (L, 2, 8, K, hd)
        assert counts.shape == (L, 2, 8, K)
        assert float(np.asarray(counts).max()) <= 32  # <= prompt positions
        assert float(np.asarray(counts).sum()) > 0

    def test_ssm_engine_decodes(self):
        eng, cfg = _engine("mamba2-130m", batch=2, max_prompt=32,
                           max_new_tokens=4)
        rng = np.random.default_rng(1)
        out = eng.generate([list(rng.integers(0, cfg.vocab, 16))
                            for _ in range(2)])
        assert out.shape == (2, 4)
        assert (out >= 0).all() and (out < cfg.vocab).all()
