"""Property tests on model-layer invariants (hypothesis)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import ARCHS, reduce_config
from repro.models.attention import attn_mask
from repro.models import moe as moe_mod


class TestAttnMaskProperties:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 48), st.integers(0, 1),
           st.one_of(st.none(), st.integers(1, 16)))
    def test_causal_and_window(self, L, causal, window):
        pos = jnp.arange(L, dtype=jnp.int32)[None, :]
        m = np.asarray(attn_mask(pos, pos, causal=bool(causal),
                                 window=window, prefix_len=None))[0]
        i, j = np.nonzero(m)
        if causal:
            assert (j <= i).all()
        if window is not None:
            assert (j > i - window).all()
        # every query attends somewhere (its own position at minimum)
        assert m.diagonal().all()

    @settings(max_examples=10, deadline=None)
    @given(st.integers(4, 32), st.integers(1, 8))
    def test_prefix_lm_bidirectional_over_prefix(self, L, P):
        P = min(P, L - 1)
        pos = jnp.arange(L, dtype=jnp.int32)[None, :]
        m = np.asarray(attn_mask(pos, pos, causal=True, window=None,
                                 prefix_len=P))[0]
        # all positions see the whole prefix; suffix stays causal
        assert m[:, :P].all()
        i, j = np.nonzero(~m)
        assert (j >= P).all() and (j > i).all()


class TestMoEDispatchProperties:
    def _setup(self, T=64, seed=0, dtype=None):
        cfg = reduce_config(ARCHS["qwen3-moe-30b-a3b"])
        if dtype is not None:
            cfg = cfg.replace(dtype=dtype)
        key = jax.random.PRNGKey(seed)
        lp = moe_mod.init_layer_params(cfg, key)
        x = jax.random.normal(key, (2, T // 2, cfg.d_model),
                              jnp.float32).astype(cfg.dtype)
        return cfg, lp, x

    @settings(max_examples=5, deadline=None)
    @given(st.integers(0, 100))
    def test_scatter_gather_equivalent_f32(self, seed):
        """In f32 both dispatch formulations agree tightly (they are the
        same math; only the data movement differs)."""
        cfg, lp, x = self._setup(seed=seed, dtype=jnp.float32)
        with moe_mod.dispatch_mode("scatter"):
            y1, a1 = moe_mod.moe_ffn(x, lp, cfg, None)
        with moe_mod.dispatch_mode("gather"):
            y2, a2 = moe_mod.moe_ffn(x, lp, cfg, None)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-5)
        assert float(a1) == float(a2)

    def test_scatter_gather_equivalent_bf16(self):
        """bf16 agreement within accumulation-order noise (gather combines
        in f32, scatter adds in bf16 — cancellation amplifies the diff)."""
        cfg, lp, x = self._setup()
        with moe_mod.dispatch_mode("scatter"):
            y1, _ = moe_mod.moe_ffn(x, lp, cfg, None)
        with moe_mod.dispatch_mode("gather"):
            y2, _ = moe_mod.moe_ffn(x, lp, cfg, None)
        a, b = np.asarray(y1, np.float32), np.asarray(y2, np.float32)
        denom = max(np.linalg.norm(b), 1e-9)
        assert np.linalg.norm(a - b) / denom < 2e-2

    def test_capacity_respected(self):
        """No expert bucket receives more than C tokens: route everything
        to one expert and check outputs stay finite + bounded."""
        cfg, lp, x = self._setup()
        # bias the router hard toward expert 0
        lp = dict(lp)
        router = np.zeros(lp["router"].shape, np.float32)
        router[..., 0] = 100.0
        lp["router"] = jnp.asarray(router)
        y, aux = moe_mod.moe_ffn(x, lp, cfg, None)
        assert np.isfinite(np.asarray(y, np.float32)).all()
        # aux loss spikes under collapse (the signal it exists to provide)
        assert float(aux) > 1.0

    def test_expert_padding_changes_only_layout(self):
        cfg, lp, x = self._setup()
        cfg_p = cfg.replace(n_experts_padded=8)
        kp = jax.random.PRNGKey(0)
        lp_p = moe_mod.init_layer_params(cfg_p, kp)
        # padded experts exist in weights but router never selects them
        assert lp_p["w_gate"].shape[0] == 8
        assert lp_p["router"].shape[-1] == cfg.n_experts
        y, _ = moe_mod.moe_ffn(x, lp_p, cfg_p, None)
        assert np.isfinite(np.asarray(y, np.float32)).all()
