"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Threshold contract: the kernels compute squared distances in the MXU expanded
form (|x|^2+|y|^2-2xy) while the oracle uses the direct difference; pairs
lying within f32 rounding of the d_cut boundary can be counted differently.
Tests therefore draw data away from the boundary (``_safe_points``) for exact
count equality, and use tolerances for distances.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import dependent_masked, dependent_prefix, local_density
from repro.kernels.ref import (masked_min_dist_ref, prefix_min_dist_ref,
                               range_count_ref)


def _safe_points(n, d, d_cut, seed, dtype=np.float32):
    """Points with no pairwise distance within 1e-3*d_cut of the threshold."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 50 * d_cut, size=(n, d)).astype(dtype)
    d2 = ((pts[:, None, :].astype(np.float64) - pts[None, :, :]) ** 2).sum(-1)
    dist = np.sqrt(d2)
    bad = np.abs(dist - d_cut) < 1e-3 * d_cut
    np.fill_diagonal(bad, False)
    keep = ~bad.any(1)
    return pts[keep]


class TestRangeCount:
    @pytest.mark.parametrize("n,d", [(100, 2), (300, 3), (257, 4), (64, 8)])
    def test_shapes(self, n, d):
        d_cut = 1.0
        pts = _safe_points(n, d, d_cut, seed=n + d)
        got = local_density(jnp.asarray(pts), d_cut, block_n=64, block_m=128,
                            interpret=True)
        want = range_count_ref(jnp.asarray(pts), jnp.asarray(pts), d_cut)
        assert got.shape == (len(pts),)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("dtype", [np.float32, np.float64])
    def test_dtypes(self, dtype):
        d_cut = 2.0
        pts = _safe_points(120, 3, d_cut, seed=7, dtype=dtype)
        got = local_density(jnp.asarray(pts), d_cut, block_n=64, block_m=64,
                            interpret=True)
        want = range_count_ref(jnp.asarray(pts, jnp.float32),
                               jnp.asarray(pts, jnp.float32), d_cut)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(max_examples=6, deadline=None)
    @given(st.integers(16, 200), st.integers(2, 4), st.integers(0, 99))
    def test_property_matches_oracle(self, n, d, seed):
        d_cut = 1.5
        pts = _safe_points(n, d, d_cut, seed=seed)
        if len(pts) < 4:
            return
        got = local_density(jnp.asarray(pts), d_cut, block_n=32, block_m=64,
                            interpret=True)
        want = range_count_ref(jnp.asarray(pts), jnp.asarray(pts), d_cut)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_nonsquare_blocks_and_padding(self):
        d_cut = 1.0
        pts = _safe_points(190, 2, d_cut, seed=3)   # forces ragged padding
        got = local_density(jnp.asarray(pts), d_cut, block_n=64, block_m=256,
                            interpret=True)
        want = range_count_ref(jnp.asarray(pts), jnp.asarray(pts), d_cut)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestPrefixMinDist:
    @pytest.mark.parametrize("n,d,block", [(100, 2, 32), (256, 3, 64),
                                           (500, 4, 128), (64, 8, 32)])
    def test_matches_oracle(self, n, d, block):
        rng = np.random.default_rng(n + d)
        pts = rng.uniform(0, 100, size=(n, d)).astype(np.float32)
        got_d, got_p = dependent_prefix(jnp.asarray(pts), block=block,
                                        interpret=True)
        want_d, want_p = prefix_min_dist_ref(jnp.asarray(pts))
        np.testing.assert_allclose(np.asarray(got_d)[1:], np.asarray(want_d)[1:],
                                   rtol=2e-4, atol=1e-4)
        # argmins may differ only where distances tie within tolerance
        diff = np.asarray(got_p) != np.asarray(want_p)
        if diff.any():
            gd = np.asarray(got_d)[diff]
            wd = np.asarray(want_d)[diff]
            np.testing.assert_allclose(gd, wd, rtol=2e-4, atol=1e-4)

    def test_first_row_has_no_prefix(self):
        pts = np.random.default_rng(0).uniform(0, 10, (64, 2)).astype(np.float32)
        got_d, got_p = dependent_prefix(jnp.asarray(pts), block=32, interpret=True)
        assert np.isinf(np.asarray(got_d)[0])
        assert int(got_p[0]) == -1


class TestMaskedMinDist:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(8, 100), st.integers(50, 300), st.integers(0, 99))
    def test_property_matches_oracle(self, m, n, seed):
        rng = np.random.default_rng(seed)
        x = rng.uniform(0, 100, (m, 3)).astype(np.float32)
        y = rng.uniform(0, 100, (n, 3)).astype(np.float32)
        xk = rng.permutation(m).astype(np.float32)
        yk = rng.uniform(0, m, n).astype(np.float32)
        got_d, got_p = dependent_masked(jnp.asarray(x), jnp.asarray(xk),
                                        jnp.asarray(y), jnp.asarray(yk),
                                        block_n=32, block_m=64, interpret=True)
        want_d, want_p = masked_min_dist_ref(jnp.asarray(x), jnp.asarray(xk),
                                             jnp.asarray(y), jnp.asarray(yk))
        fin = np.isfinite(np.asarray(want_d))
        np.testing.assert_allclose(np.asarray(got_d)[fin], np.asarray(want_d)[fin],
                                   rtol=2e-4, atol=1e-4)
        np.testing.assert_array_equal(np.isfinite(np.asarray(got_d)), fin)


class TestKernelStructure:
    """The kernels must trace through pallas_call (the CPU backend can only
    *interpret* Pallas, so TPU Mosaic lowering itself is exercised on real
    hardware; here we pin the call structure and the static grid math)."""

    def test_range_count_traces_as_pallas(self):
        x = jax.ShapeDtypeStruct((512, 4), jnp.float32)
        from repro.kernels.density import range_count
        jaxpr = jax.make_jaxpr(
            lambda a: range_count(a, a, 1.0, block_n=256, block_m=256,
                                  interpret=True))(x)
        assert "pallas_call" in str(jaxpr)

    def test_prefix_traces_as_pallas(self):
        from repro.kernels.dependent import prefix_min_dist
        x = jax.ShapeDtypeStruct((512, 4), jnp.float32)
        jaxpr = jax.make_jaxpr(
            lambda a: prefix_min_dist(a, block=256, interpret=True))(x)
        assert "pallas_call" in str(jaxpr)

    def test_block_shape_divisibility_enforced(self):
        from repro.kernels.density import range_count
        x = jnp.zeros((100, 2), jnp.float32)   # not a multiple of block
        with pytest.raises(AssertionError):
            range_count(x, x, 1.0, block_n=64, block_m=64, interpret=True)
