import os

# Smoke tests and benchmarks must see ONE device; only launch/dryrun.py sets
# the 512-device override (and only in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import repro  # noqa: E402,F401  (enables x64 before any test builds arrays)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-process / large-n tests (minutes, not ms)")
