"""repro.analysis: the jaxpr-level static analyzer (rules R1-R9 + audits).

The R1 positive control reconstructs the PR 4 distributed block-sparse
miscompile shape — a sort-derived order gather inside a multi-partition
shard_map body — which needs >1 device, so it runs in a subprocess with 4
fake host devices (the test_distributed_dpc.py pattern).  Everything else
(R2 source scans, R3/R4 hand-built traces, R5 cross-checks, the audit
registry, the plan-time gate) runs in-process.
"""
import json
import os
import subprocess
import sys

import pytest

import jax
import jax.numpy as jnp

from repro.analysis import AnalysisError, all_audits, audit_check_rep, audit_of
from repro.analysis import r2_check_rep, r3_precision, r4_pallas, \
    r5_coverage
from repro.analysis.rules import Finding, analyze_jaxpr
from repro.engine import ExecSpec
from repro.engine import planner

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------- audits
class TestAuditRegistry:
    def test_decorator_attaches_and_registers(self):
        @audit_check_rep("outputs are psum-reduced, identical per member",
                         collectives=("psum",))
        def body(x):
            return x

        rec = audit_of(body)
        assert rec is not None
        assert rec.collectives == ("psum",)
        assert "psum-reduced" in rec.reason
        assert body(3) == 3, "decorator must return the function unchanged"
        assert rec.key in all_audits()

    def test_empty_reason_rejected(self):
        with pytest.raises(ValueError, match="reason"):
            audit_check_rep("")
        with pytest.raises(ValueError, match="reason"):
            audit_check_rep("   ")

    def test_production_bodies_are_audited(self):
        """R2 on the real tree: every check_rep=False shard_map body in
        src/repro resolves to a def carrying @audit_check_rep."""
        findings = r2_check_rep.CheckRepAuditRule().check_project(_REPO_ROOT)
        assert findings == [], [f.to_dict() for f in findings]


# ------------------------------------------------------------------- R2
_R2_BAD = """\
from jax.experimental.shard_map import shard_map

def build(mesh, spec):
    def body(x):
        return x
    return shard_map(body, mesh=mesh, in_specs=spec, out_specs=spec,
                     check_rep=False)
"""

_R2_GOOD = """\
from jax.experimental.shard_map import shard_map
from repro.analysis.audit import audit_check_rep

def build(mesh, spec):
    @audit_check_rep("P(axis)-local rows only; no replicated outputs")
    def body(x):
        return x
    return shard_map(body, mesh=mesh, in_specs=spec, out_specs=spec,
                     check_rep=False)
"""

_R2_FACTORY = """\
from jax.experimental.shard_map import shard_map

def _make_body(scale):
    def body(x):
        return x * scale
    return body

def build(mesh, spec):
    body = _make_body(2.0)
    return shard_map(body, mesh=mesh, in_specs=spec, out_specs=spec,
                     check_rep=False)
"""

_R2_LAMBDA = """\
from jax.experimental.shard_map import shard_map

def build(mesh, spec):
    return shard_map(lambda x: x, mesh=mesh, in_specs=spec, out_specs=spec,
                     check_rep=False)
"""


class TestR2CheckRepAudit:
    def _scan(self, tmp_path, src):
        p = tmp_path / "mod.py"
        p.write_text(src)
        return r2_check_rep.scan_module(str(p), "mod.py")

    def test_unaudited_body_flagged(self, tmp_path):
        findings = self._scan(tmp_path, _R2_BAD)
        assert len(findings) == 1
        assert "no @audit_check_rep" in findings[0].message
        assert findings[0].severity == "error"

    def test_audited_body_clean(self, tmp_path):
        assert self._scan(tmp_path, _R2_GOOD) == []

    def test_factory_returned_body_resolved(self, tmp_path):
        """The distributed/dpc.py idiom: body = _make_xyz(...) resolves
        through the factory's returned inner def."""
        findings = self._scan(tmp_path, _R2_FACTORY)
        assert len(findings) == 1
        assert "`body`" in findings[0].message

    def test_unresolvable_body_flagged(self, tmp_path):
        findings = self._scan(tmp_path, _R2_LAMBDA)
        assert len(findings) == 1
        assert "cannot" in findings[0].message

    def test_default_check_rep_ignored(self, tmp_path):
        src = _R2_BAD.replace(",\n                     check_rep=False", "")
        assert self._scan(tmp_path, src) == []


# ------------------------------------------------------------------- R3
def _bf16_expanded_argmin(x, y):
    """The mixed-precision sweep shape: expanded-form d2 with a bf16 dot."""
    g = jnp.dot(x.astype(jnp.bfloat16),
                y.astype(jnp.bfloat16).T).astype(jnp.float32)
    d2 = (x * x).sum(-1)[:, None] + (y * y).sum(-1)[None, :] - 2.0 * g
    return jnp.argmin(d2, axis=1)


def _r3_findings(fn):
    x = jnp.zeros((8, 2), jnp.float32)
    y = jnp.zeros((5, 2), jnp.float32)
    closed = jax.make_jaxpr(fn)(x, y)
    return [f for f in analyze_jaxpr("r3-control", closed)
            if f.rule == r3_precision.RULE_NAME]


class TestR3PrecisionFlow:
    def test_bf16_dot_without_refinement_fires(self):
        assert len(_r3_findings(_bf16_expanded_argmin)) == 1

    def test_refinement_epilogue_passes(self):
        def refined(x, y):
            idx = _bf16_expanded_argmin(x, y)
            y_sel = y[idx]
            # the refine_topk_d2 / _fused_resolve contract: direct-diff
            # square-sum in full precision over the kept winners
            return jnp.sum((x - y_sel) ** 2, axis=-1)

        assert _r3_findings(refined) == []

    def test_pure_f32_never_fires(self):
        def f32_only(x, y):
            d2 = ((x[:, None, :] - y[None, :, :]) ** 2).sum(-1)
            return jnp.argmin(d2, axis=1)

        assert _r3_findings(f32_only) == []


# ------------------------------------------------------------------- R4
def _pallas_identity(block_rows):
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] + jnp.float32(1.0)

    n = 96
    grid = -(-n // block_rows)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((block_rows, 2), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 2), jnp.float32),
        interpret=True)


def _r4_findings(block_rows):
    x = jnp.zeros((96, 2), jnp.float32)
    closed = jax.make_jaxpr(_pallas_identity(block_rows))(x)
    return [f for f in analyze_jaxpr("r4-control", closed)
            if f.rule == r4_pallas.RULE_NAME]


class TestR4PallasLegality:
    def test_nondivisible_block_fires(self):
        findings = _r4_findings(40)          # 96 % 40 != 0
        assert findings, "96-row array with 40-row blocks must be flagged"
        assert all(f.severity == "error" for f in findings)

    def test_divisible_block_passes(self):
        assert _r4_findings(32) == []        # 96 % 32 == 0


# ------------------------------------------------------------------- R5
class TestR5SpecCoverage:
    def test_clean_on_tree(self):
        findings = r5_coverage.SpecCoverageRule().check_project(_REPO_ROOT)
        assert findings == [], [f.to_dict() for f in findings]

    def test_snapshot_drift_detected(self, monkeypatch):
        monkeypatch.setattr(r5_coverage, "KNOWN_BACKENDS", ("jnp", "pallas"))
        findings = r5_coverage.SpecCoverageRule().check_project(_REPO_ROOT)
        assert any("backends changed" in f.message for f in findings)

    @pytest.mark.parametrize("backend", ["jnp", "pallas", "pallas-interpret"])
    @pytest.mark.parametrize("layout", ["dense", "block-sparse"])
    @pytest.mark.parametrize("precision", ["f32", "bf16"])
    def test_axis_product_matches_validity_table(self, backend, layout,
                                                 precision):
        """Every axis value, by literal name (R5's corpus check counts on
        exactly this parametrization): ExecSpec accepts/rejects the full
        cross product where the documented table says."""
        if r5_coverage._expected_spec_valid(backend, layout, precision):
            spec = ExecSpec(backend=backend, layout=layout,
                            precision=precision)
            assert spec.describe() == f"{backend}:{layout}:{precision}"
        else:
            with pytest.raises(ValueError):
                ExecSpec(backend=backend, layout=layout, precision=precision)


# ------------------------------------------------ plan-time gate (planner)
class TestPlanTimeGate:
    def test_error_findings_fail_plan(self, monkeypatch):
        from repro import analysis

        bad = Finding(rule="X-test", severity="error", target="t",
                      message="injected failure")
        monkeypatch.setattr(analysis, "analyze_plan", lambda pl: [bad])
        spec = ExecSpec(backend="jnp", block=137)   # unique -> memo miss
        monkeypatch.delenv("REPRO_ANALYSIS", raising=False)
        planner._ANALYZED.pop(spec, None)
        planner._PLANS.pop((None, spec), None)
        try:
            with pytest.raises(AnalysisError, match="REPRO_ANALYSIS=0"):
                planner.plan(None, spec)
            # the documented escape hatch bypasses without re-analyzing
            monkeypatch.setenv("REPRO_ANALYSIS", "0")
            assert planner.plan(None, spec) is not None
        finally:
            planner._ANALYZED.pop(spec, None)
            planner._PLANS.pop((None, spec), None)

    def test_warnings_do_not_fail_plan(self, monkeypatch):
        from repro import analysis

        warn = Finding(rule="X-test", severity="warn", target="t",
                       message="advisory only")
        monkeypatch.setattr(analysis, "analyze_plan", lambda pl: [warn])
        spec = ExecSpec(backend="jnp", block=139)
        monkeypatch.delenv("REPRO_ANALYSIS", raising=False)
        planner._ANALYZED.pop(spec, None)
        planner._PLANS.pop((None, spec), None)
        try:
            assert planner.plan(None, spec) is not None
        finally:
            planner._ANALYZED.pop(spec, None)
            planner._PLANS.pop((None, spec), None)

    def test_real_plans_analyze_clean(self):
        """The canonical plan-time targets of the shipping specs carry no
        findings at all (error or warn) on this tree."""
        from repro.analysis import analyze_plan

        for spec in (ExecSpec(),
                     ExecSpec(backend="jnp", layout="block-sparse"),
                     ExecSpec(backend="pallas-interpret",
                              layout="block-sparse")):
            pl = planner.plan(None, spec)
            assert list(analyze_plan(pl)) == []


# ----------------------------------------------- R1 + the distributed gate
def test_single_device_blocksparse_layout():
    """shard_blocksparse_layout: single-partition meshes never hit the
    miscompile (no SPMD partitioning), so traceable-worklist plans keep
    block-sparse; dense plans and host-worklist backends never do."""
    from repro.distributed import dpc as ddpc

    mesh = jax.make_mesh((1,), ("data",))
    bs = planner.plan(None, ExecSpec(backend="jnp", layout="block-sparse"))
    assert ddpc.shard_blocksparse_layout(bs, mesh) == "block-sparse"
    dense = planner.plan(None, ExecSpec(backend="jnp"))
    assert ddpc.shard_blocksparse_layout(dense, mesh) is None
    host = planner.plan(None, ExecSpec(backend="pallas-interpret",
                                       layout="block-sparse"))
    assert ddpc.shard_blocksparse_layout(host, mesh) is None


_R1_SCRIPT = r"""
import warnings, json, os
warnings.filterwarnings("ignore")
os.environ["REPRO_ANALYSIS"] = "suspend"   # probe plans, not production fits
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.analysis import spmd_gather_safe, r1_spmd_gather
from repro.analysis.rules import analyze_jaxpr
from repro.analysis.targets import distributed_targets, stream_targets
from repro.distributed import dpc as ddpc
from repro.engine import ExecSpec
from repro.engine.planner import plan
from repro.kernels.backend import get_backend

mesh = jax.make_mesh((4,), ("data",))
be = get_backend("jnp")
pts = jnp.zeros((32, 2), jnp.float32)
rk = jnp.zeros((32,), jnp.float32)

# (a) positive control: a frozen copy of the pre-one-hot order-gather ring
# walk (argsort visit order, tile id read from the sorted permutation
# inside the walk, feeding a dynamic_slice) -- the exact shape the pinned
# XLA CPU SPMD pipeline miscompiles.  Deleted from production by the
# one-hot rewrite; kept here so R1's detection of the pattern stays pinned.
BM = 8
def frozen_order_gather_walk(x_my, y):
    nbc = y.shape[0] // BM
    lo = jnp.min(y.reshape(nbc, BM, -1), axis=1)
    lb = jnp.sum((jnp.mean(x_my, axis=0)[None, :] - lo) ** 2, axis=1)
    order = jnp.argsort(lb).astype(jnp.int32)     # sort-derived visit order
    lbs = jnp.take_along_axis(lb, order, axis=0)  # the old order-gather

    def cond(c):
        p, _ = c
        return (p < nbc) & (lbs[jnp.minimum(p, nbc - 1)] < jnp.inf)

    def body(c):
        p, acc = c
        j = order[p]                              # tainted tile id ...
        tile = jax.lax.dynamic_slice_in_dim(y, j * BM, BM, 0)  # ... -> R1
        d2 = jnp.sum((x_my[:, None, :] - tile[None, :, :]) ** 2, -1)
        return p + 1, acc + jnp.sum(d2 < 1.0, axis=1).astype(jnp.float32)

    _, acc = jax.lax.while_loop(
        cond, body, (jnp.int32(0), jnp.zeros(x_my.shape[0], jnp.float32)))
    return acc

sm_old = shard_map(frozen_order_gather_walk, mesh=mesh,
                   in_specs=(P("data"), P(None)), out_specs=P("data"),
                   check_rep=False)
safe_old = spmd_gather_safe(sm_old, pts, pts)
closed = jax.make_jaxpr(sm_old)(pts, pts)
r1 = [f for f in analyze_jaxpr("frozen-order-gather", closed)
      if f.rule == r1_spmd_gather.RULE_NAME]

# (b) the production one-hot walk: both block-sparse shard phases trace
# clean over 4 partitions, so the guard keeps block-sparse on this mesh
rho_fn = ddpc._make_rho_dense("data", 1.0, 256, be, layout="block-sparse")
delta_fn = ddpc._make_delta_dense("data", 256, be, layout="block-sparse")
sm_rho = shard_map(rho_fn, mesh=mesh, in_specs=(P("data"), P("data")),
                   out_specs=P("data"), check_rep=False)
sm_delta = shard_map(delta_fn, mesh=mesh, in_specs=(P("data"),) * 4,
                     out_specs=(P("data"),) * 3, check_rep=False)
safe_rho = spmd_gather_safe(sm_rho, pts, pts)
safe_delta = spmd_gather_safe(sm_delta, pts, rk, pts, rk)
pl_bs = plan(None, ExecSpec(backend="jnp", layout="block-sparse"))
pl_dense = plan(None, ExecSpec(backend="jnp"))
lay_bs = ddpc.shard_blocksparse_layout(pl_bs, mesh)
lay_dense = ddpc.shard_blocksparse_layout(pl_dense, mesh)

# (c) the clean tree: every distributed/stream target these plans run
# today -- now including the block-sparse shard phases and the sharded
# stream tail (NN re-query, label propagation, center distances) --
# analyzes with zero error findings
errors = []
for pl in (pl_bs, pl_dense):
    tgts = list(distributed_targets(pl)[0]) + list(stream_targets(pl)[0])
    for name, thunk in tgts:
        for f in analyze_jaxpr(name, thunk()):
            if f.severity == "error":
                errors.append([name, f.rule])

out = {"safe_old": bool(safe_old), "n_r1": len(r1),
       "messages": [f.message for f in r1],
       "safe_rho": bool(safe_rho), "safe_delta": bool(safe_delta),
       "layout_bs": lay_bs, "layout_dense": lay_dense,
       "clean_errors": errors}
print("RESULT" + json.dumps(out))
"""


def _run_subprocess(script):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(_REPO_ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_r1_positive_control_and_production_tree_is_clean():
    """All three R1 halves in one 4-device subprocess: the frozen copy of
    the old order-gather walk is still flagged (the rule's detection of
    the miscompile pattern stays pinned after the production rewrite),
    both production block-sparse shard phases trace clean so the probe
    keeps block-sparse on a multi-partition mesh (ISSUE 8 acceptance),
    and every shipping distributed/stream trace analyzes clean."""
    out = _run_subprocess(_R1_SCRIPT)
    assert out["safe_old"] is False
    assert out["n_r1"] >= 1
    assert any("sort-derived" in m for m in out["messages"])
    assert out["safe_rho"] is True and out["safe_delta"] is True, \
        "production one-hot shard phases must pass spmd_gather_safe"
    assert out["layout_bs"] == "block-sparse", \
        "the probe must re-enable multi-partition block-sparse"
    assert out["layout_dense"] is None
    assert out["clean_errors"] == []


# ------------------------------------------------------ R6 pallas-race
class TestR6PallasRace:
    """The race detector over real kernel traces: the shipping merges are
    proved associative-or-guarded, and the seeded lost-update mutation
    (kept-k merge -> passthrough overwrite) fires."""

    def _trace(self, spec):
        from repro.kernels import sweep as S

        x = jnp.zeros((128, 2), jnp.float32)
        return jax.make_jaxpr(
            lambda a, b: S.tile_sweep(spec, a, b, 0.35, interpret=True))(x, x)

    def _findings(self, closed):
        from repro.analysis.r6_pallas_race import PallasRaceRule

        return PallasRaceRule().check_jaxpr("t", closed)

    def test_shipping_topk_merge_clean(self):
        from repro.kernels import sweep as S

        spec = S.SweepSpec(block_n=64, block_m=128, count=True,
                           nn="topk", k=4)
        assert self._findings(self._trace(spec)) == []

    def test_shipping_best1_merge_clean(self):
        from repro.kernels import sweep as S

        spec = S.SweepSpec(block_n=64, block_m=128, nn="best1")
        assert self._findings(self._trace(spec)) == []

    def test_overwrite_mutation_fires(self, monkeypatch):
        """Positive control: _merge_topk mutated into last-tile-wins.  A
        unique SweepSpec forces a fresh trace (the jit cache would
        otherwise replay the unmutated kernel)."""
        from repro.kernels import sweep as S

        monkeypatch.setattr(S, "_merge_topk",
                            lambda ov, oi, nv, ni, k: (nv, ni))
        spec = S.SweepSpec(block_n=64, block_m=128, count=True,
                           nn="topk", k=3)
        findings = self._findings(self._trace(spec))
        assert len(findings) == 2, findings       # topv and topi outputs
        assert all(f.severity == "error" for f in findings)
        assert all("overwrite" in f.message for f in findings)
        assert all("revisited" in f.message for f in findings)


# ------------------------------------------------- R7 transfer / retrace
class TestR7TransferRetrace:
    def test_callback_in_trace_fires(self):
        from repro.analysis.r7_transfer_retrace import TransferRule

        def f(x):
            y = jax.pure_callback(
                lambda v: v, jax.ShapeDtypeStruct(x.shape, x.dtype), x)
            return y * 2.0

        closed = jax.make_jaxpr(f)(jnp.ones((4,), jnp.float32))
        fs = TransferRule().check_jaxpr("t", closed)
        assert [f.severity for f in fs] == ["error"]
        assert "round trip" in fs[0].message

    def test_clean_trace_passes(self):
        from repro.analysis.r7_transfer_retrace import TransferRule

        closed = jax.make_jaxpr(lambda x: x * 2.0)(jnp.ones((4,)))
        assert TransferRule().check_jaxpr("t", closed) == []

    def test_raw_jit_spellings_diverge_and_wrapper_normalizes(self):
        """The detection mechanism end-to-end: the un-normalized jit
        boundary shows weak-vs-strong aval drift across d_cut spellings;
        the public tile_sweep wrapper erases it."""
        import numpy as np

        from repro.analysis.r7_transfer_retrace import _jit_signature
        from repro.kernels import sweep as S

        x = jnp.zeros((128, 2), jnp.float32)
        spec = S.SweepSpec(block_n=64, block_m=128, count=True)

        def sig(fn, d):
            return _jit_signature(jax.make_jaxpr(
                lambda a, b: fn(spec, a, b, d, interpret=True))(x, x))

        assert sig(S._tile_sweep_jit, 0.35) != \
            sig(S._tile_sweep_jit, np.float32(0.35))
        assert sig(S.tile_sweep, 0.35) == sig(S.tile_sweep,
                                              np.float32(0.35))

    def test_plan_probe_clean_on_shipping_specs(self):
        from repro.analysis.r7_transfer_retrace import RetraceChurnRule

        for spec in (ExecSpec(backend="jnp"),
                     ExecSpec(backend="pallas-interpret",
                              layout="block-sparse")):
            pl = planner.plan(None, spec)
            assert RetraceChurnRule().check_plan(pl) == []

    def test_plan_probe_fires_on_unnormalized_plan(self):
        """Positive control: a plan whose rho_delta forwards d_cut raw
        into a jit boundary produces one trace-cache entry per spelling —
        the probe must call that out."""
        from repro.analysis.r7_transfer_retrace import RetraceChurnRule

        inner = jax.jit(lambda a, b, d: (a * d).sum() + b.sum())

        class _BE:
            fused_traceable = True

        class _FakePlan:
            backend = _BE()
            backend_name = "fake"
            layout = "dense"
            precision = "f32"
            spec = ("fake-spec",)
            sparse = False
            block = None

            def rho_delta(self, a, b, d):
                return inner(a, b, d)       # no normalization: the defect

        fs = RetraceChurnRule().check_plan(_FakePlan())
        assert any(f.severity == "error" and "retrace churn" in f.message
                   for f in fs), fs


# ------------------------------------------------------ R8 determinism
class TestR8Determinism:
    def _mesh(self):
        import numpy as np

        from jax.sharding import Mesh

        return Mesh(np.array(jax.devices()), ("i",))

    def _psum_trace(self, body, out_spec):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        sm = shard_map(body, mesh=self._mesh(), in_specs=(P("i"),),
                       out_specs=out_spec)
        return jax.make_jaxpr(sm)(jnp.ones((8,), jnp.float32))

    def _findings(self, closed):
        from repro.analysis.r8_determinism import DeterminismRule

        return DeterminismRule().check_jaxpr("t", closed)

    @pytest.mark.skipif(len(jax.devices()) < 2,
                        reason="needs a multi-partition mesh")
    def test_unannotated_float_psum_feeding_outputs_is_error(self):
        from jax.sharding import PartitionSpec as P

        def body(x):
            return jax.lax.psum(jnp.sum(x * 1.5), "i")

        fs = self._findings(self._psum_trace(body, P(None)))
        assert [f.severity for f in fs] == ["error"]
        assert "audit_determinism" in fs[0].message

    @pytest.mark.skipif(len(jax.devices()) < 2,
                        reason="needs a multi-partition mesh")
    def test_internal_only_psum_is_warn(self):
        from jax.sharding import PartitionSpec as P

        def body(x):
            _ = jax.lax.psum(jnp.sum(x * 1.5), "i")
            return jnp.ones_like(x)

        fs = self._findings(self._psum_trace(body, P("i")))
        assert [f.severity for f in fs] == ["warn"]

    @pytest.mark.skipif(len(jax.devices()) < 2,
                        reason="needs a multi-partition mesh")
    def test_blessed_psum_is_clean(self):
        from jax.sharding import PartitionSpec as P

        from repro.analysis import audit_determinism

        @audit_determinism("test blessing: values are integer-exact",
                           ops=("psum",))
        def body(x):
            return jax.lax.psum(jnp.sum(x * 1.5), "i")

        assert self._findings(self._psum_trace(body, P(None))) == []

    def test_duplicate_index_scatter_add_fires(self):
        def scat(x, idx):
            return jnp.zeros((4,), jnp.float32).at[idx].add(x)

        closed = jax.make_jaxpr(scat)(jnp.ones((8,), jnp.float32),
                                      jnp.zeros((8,), jnp.int32))
        fs = self._findings(closed)
        assert [f.severity for f in fs] == ["error"]
        assert "scatter-add" in fs[0].message

    def test_unique_index_scatter_add_clean(self):
        def scat(x):
            idx = jnp.arange(8)
            return jnp.zeros((8,), jnp.float32).at[idx].add(
                x, unique_indices=True)

        closed = jax.make_jaxpr(scat)(jnp.ones((8,), jnp.float32))
        assert self._findings(closed) == []

    def test_integer_scatter_add_clean(self):
        def scat(x, idx):
            return jnp.zeros((4,), jnp.int32).at[idx].add(x)

        closed = jax.make_jaxpr(scat)(jnp.ones((8,), jnp.int32),
                                      jnp.zeros((8,), jnp.int32))
        assert self._findings(closed) == []

    def test_production_blessings_registered(self):
        """The two shipping non-associative sites carry their audits.
        ``_compress_head``'s registers at import; the sharded repair's
        rides its factory (decorators on the inner def run per build)."""
        import repro.serve.dpc_kv                  # noqa: F401
        from repro.analysis import all_determinism_audits
        from repro.kernels.backend import get_backend
        from repro.stream.incremental import make_sharded_repair

        make_sharded_repair(jax.make_mesh((1,), ("i",)), "i",
                            get_backend("jnp"), 0.35)
        keys = set(all_determinism_audits())
        assert "repro.serve.dpc_kv._compress_head" in keys
        assert any(k.startswith("repro.stream.incremental."
                                "make_sharded_repair") for k in keys)


# --------------------------------------------------- R9 memory budget
class TestR9MemoryBudget:
    def _trace(self):
        from repro.kernels import sweep as S

        x = jnp.zeros((128, 2), jnp.float32)
        spec = S.SweepSpec(block_n=64, block_m=128, count=True)
        return jax.make_jaxpr(
            lambda a, b: S.tile_sweep(spec, a, b, 0.35, interpret=True))(x, x)

    def test_default_budget_passes(self):
        from repro.analysis.r9_memory_budget import MemoryBudgetRule

        assert MemoryBudgetRule().check_jaxpr("t", self._trace()) == []

    def test_tiny_vmem_budget_fires(self, monkeypatch):
        from repro.analysis.r9_memory_budget import MemoryBudgetRule

        monkeypatch.setenv("REPRO_LIMIT_VMEM_BYTES", "1024")
        fs = MemoryBudgetRule().check_jaxpr("t", self._trace())
        assert fs and all(f.severity == "error" for f in fs)
        assert any("VMEM" in f.message for f in fs)

    def test_live_buffer_gate_arms_only_with_env(self, monkeypatch):
        from repro.analysis.r9_memory_budget import MemoryBudgetRule

        closed = jax.make_jaxpr(
            lambda x: (x @ x.T).sum())(jnp.ones((64, 64), jnp.float32))
        assert MemoryBudgetRule().check_jaxpr("t", closed) == []
        monkeypatch.setenv("REPRO_LIMIT_LIVE_BYTES", "64")
        fs = MemoryBudgetRule().check_jaxpr("t", closed)
        assert [f.severity for f in fs] == ["error"]
        assert "live-buffer" in fs[0].message

    def test_limits_table_and_env_override(self, monkeypatch):
        from repro.analysis import limits

        base = limits.limits_for_platform(None)
        assert base.platform == "tpu"
        assert base.smem_bytes == 4 * (1 << 20)    # the R4-era contract
        monkeypatch.setenv("REPRO_LIMIT_SMEM_BYTES", "17")
        assert limits.limits_for_platform("tpu").smem_bytes == 17
        assert limits.limits_for_platform("tpu").vmem_bytes == \
            base.vmem_bytes

    def test_plan_telemetry_reports_memory(self):
        pl = planner.plan(None, ExecSpec(backend="pallas-interpret"))
        mem = pl.telemetry()["memory"]
        assert mem["kernels"], "pallas plan must report kernel estimates"
        for k in mem["kernels"]:
            assert k["vmem_bytes"] > 0
            assert k["vmem_bytes"] <= mem["limits"]["vmem_bytes"]
        assert mem["live_peak_bytes"] > 0
        assert mem["limits"]["platform"] == "tpu"
        # memoized: second call returns the same object, no re-trace
        assert pl.telemetry()["memory"] is mem


# ------------------------------------------- escape hatch + obs counter
class TestEscapeHatch:
    def test_bypass_records_findings_and_warns_once(self, monkeypatch,
                                                    caplog):
        import logging

        from repro import analysis

        bad = Finding(rule="X-hatch", severity="error", target="t",
                      message="injected failure")
        monkeypatch.setattr(analysis, "analyze_plan", lambda pl: [bad])
        monkeypatch.setenv("REPRO_ANALYSIS", "0")
        monkeypatch.setattr(planner, "_BYPASS_WARNED", False)
        spec = ExecSpec(backend="jnp", block=141)   # unique -> memo miss
        planner._ANALYZED.pop(spec, None)
        planner._PLANS.pop((None, spec), None)
        planner._M_FINDINGS._reset()
        try:
            with caplog.at_level(logging.WARNING, logger="repro.analysis"):
                assert planner.plan(None, spec) is not None
            assert any("bypassing" in r.message for r in caplog.records)
            vals = planner._M_FINDINGS._vals
            assert vals.get("level=error,rule=X-hatch") == 1, vals
            # second bypassed plan: counted via memo? new spec -> counted,
            # but the warning stays once-per-process
            caplog.clear()
            spec2 = ExecSpec(backend="jnp", block=143)
            planner._ANALYZED.pop(spec2, None)
            planner._PLANS.pop((None, spec2), None)
            with caplog.at_level(logging.WARNING, logger="repro.analysis"):
                assert planner.plan(None, spec2) is not None
            assert not any("bypassing" in r.message
                           for r in caplog.records)
        finally:
            for s in (spec, ExecSpec(backend="jnp", block=143)):
                planner._ANALYZED.pop(s, None)
                planner._PLANS.pop((None, s), None)
            planner._M_FINDINGS._reset()
            planner._BYPASS_WARNED = False

    def test_suspend_skips_entirely(self, monkeypatch):
        from repro import analysis

        calls = []
        monkeypatch.setattr(analysis, "analyze_plan",
                            lambda pl: calls.append(pl) or [])
        monkeypatch.setenv("REPRO_ANALYSIS", "suspend")
        spec = ExecSpec(backend="jnp", block=145)
        planner._ANALYZED.pop(spec, None)
        planner._PLANS.pop((None, spec), None)
        try:
            assert planner.plan(None, spec) is not None
            assert calls == []
        finally:
            planner._ANALYZED.pop(spec, None)
            planner._PLANS.pop((None, spec), None)


# --------------------------------------------------- SARIF + baseline
class TestSarifAndBaseline:
    def _report(self, findings):
        return {"ok": not any(f["severity"] == "error" for f in findings),
                "findings": findings, "targets": ["a"], "skipped": [],
                "rules": {"R6-pallas-race":
                          {"kind": "jaxpr", "description": "races"}}}

    def test_sarif_levels_and_locations(self):
        from repro.analysis.sarif import to_sarif

        findings = [
            {"rule": "R6-pallas-race", "severity": "error", "target": "t",
             "message": "m", "where": "pjit.jaxpr/pallas_call"},
            {"rule": "R2-check-rep-audit", "severity": "warn",
             "target": "t2", "message": "m2",
             "where": "src/repro/stream/incremental.py:271"},
        ]
        doc = to_sarif(self._report(findings))
        assert doc["version"] == "2.1.0"
        run = doc["runs"][0]
        ids = [r["id"] for r in run["tool"]["driver"]["rules"]]
        assert "R6-pallas-race" in ids and "baseline" in ids
        res = run["results"]
        assert res[0]["level"] == "error"
        fq = res[0]["locations"][0]["logicalLocations"][0]
        assert fq["fullyQualifiedName"] == "t::pjit.jaxpr/pallas_call"
        assert res[1]["level"] == "warning"
        phys = res[1]["locations"][0]["physicalLocation"]
        assert phys["artifactLocation"]["uri"].endswith("incremental.py")
        assert phys["region"]["startLine"] == 271

    def test_sarif_suppressed_findings_carry_justification(self):
        from repro.analysis.sarif import to_sarif

        findings = [{"rule": "R6-pallas-race", "severity": "suppressed",
                     "target": "t", "message": "m", "where": "w",
                     "suppressed_reason": "leased until fix lands",
                     "suppressed_until": "2099-01-01"}]
        res = to_sarif(self._report(findings))["runs"][0]["results"][0]
        assert res["suppressions"][0]["justification"] == \
            "leased until fix lands"

    def test_baseline_downgrades_matching_errors(self):
        import datetime

        from repro.analysis import report as R

        f = Finding(rule="R6-pallas-race", severity="error",
                    target="plan[x]:fused", message="m", where="p/q")
        entries = [{"rule": "R6-*", "target": "plan*", "reason": "leased",
                    "expires": "2099-01-01"}]
        out = R.apply_baseline([f], entries,
                               today=datetime.date(2026, 1, 1))
        assert out[0]["severity"] == "suppressed"
        assert out[0]["suppressed_reason"] == "leased"

    def test_expired_baseline_entry_fails(self):
        import datetime

        from repro.analysis import report as R

        today = datetime.date(2026, 8, 7)
        entries = [{"rule": "R6-*", "reason": "old lease",
                    "expires": "2025-01-01"}]
        errs = R._baseline_findings(entries, "analysis-baseline.json",
                                    today)
        assert [e.severity for e in errs] == ["error"]
        assert "expired" in errs[0].message
        # and an expired entry no longer suppresses anything
        f = Finding(rule="R6-pallas-race", severity="error", target="t",
                    message="m", where="w")
        out = R.apply_baseline([f], entries, today=today)
        assert out[0]["severity"] == "error"

    def test_entry_without_reason_or_date_fails(self):
        import datetime

        from repro.analysis import report as R

        errs = R._baseline_findings([{"rule": "*"}], "b.json",
                                    datetime.date(2026, 8, 7))
        kinds = " | ".join(e.message for e in errs)
        assert "no reason" in kinds and "expires" in kinds

    def test_checked_in_baseline_is_well_formed(self):
        from repro.analysis import report as R

        path = os.path.join(_REPO_ROOT, R.BASELINE_FILE)
        entries = R.load_baseline(path)
        assert R._baseline_findings(
            entries, path, __import__("datetime").date.today()) == []
