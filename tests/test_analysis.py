"""repro.analysis: the jaxpr-level static analyzer (rules R1-R5 + audits).

The R1 positive control reconstructs the PR 4 distributed block-sparse
miscompile shape — a sort-derived order gather inside a multi-partition
shard_map body — which needs >1 device, so it runs in a subprocess with 4
fake host devices (the test_distributed_dpc.py pattern).  Everything else
(R2 source scans, R3/R4 hand-built traces, R5 cross-checks, the audit
registry, the plan-time gate) runs in-process.
"""
import json
import os
import subprocess
import sys

import pytest

import jax
import jax.numpy as jnp

from repro.analysis import AnalysisError, all_audits, audit_check_rep, audit_of
from repro.analysis import r2_check_rep, r3_precision, r4_pallas, \
    r5_coverage
from repro.analysis.rules import Finding, analyze_jaxpr
from repro.engine import ExecSpec
from repro.engine import planner

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------- audits
class TestAuditRegistry:
    def test_decorator_attaches_and_registers(self):
        @audit_check_rep("outputs are psum-reduced, identical per member",
                         collectives=("psum",))
        def body(x):
            return x

        rec = audit_of(body)
        assert rec is not None
        assert rec.collectives == ("psum",)
        assert "psum-reduced" in rec.reason
        assert body(3) == 3, "decorator must return the function unchanged"
        assert rec.key in all_audits()

    def test_empty_reason_rejected(self):
        with pytest.raises(ValueError, match="reason"):
            audit_check_rep("")
        with pytest.raises(ValueError, match="reason"):
            audit_check_rep("   ")

    def test_production_bodies_are_audited(self):
        """R2 on the real tree: every check_rep=False shard_map body in
        src/repro resolves to a def carrying @audit_check_rep."""
        findings = r2_check_rep.CheckRepAuditRule().check_project(_REPO_ROOT)
        assert findings == [], [f.to_dict() for f in findings]


# ------------------------------------------------------------------- R2
_R2_BAD = """\
from jax.experimental.shard_map import shard_map

def build(mesh, spec):
    def body(x):
        return x
    return shard_map(body, mesh=mesh, in_specs=spec, out_specs=spec,
                     check_rep=False)
"""

_R2_GOOD = """\
from jax.experimental.shard_map import shard_map
from repro.analysis.audit import audit_check_rep

def build(mesh, spec):
    @audit_check_rep("P(axis)-local rows only; no replicated outputs")
    def body(x):
        return x
    return shard_map(body, mesh=mesh, in_specs=spec, out_specs=spec,
                     check_rep=False)
"""

_R2_FACTORY = """\
from jax.experimental.shard_map import shard_map

def _make_body(scale):
    def body(x):
        return x * scale
    return body

def build(mesh, spec):
    body = _make_body(2.0)
    return shard_map(body, mesh=mesh, in_specs=spec, out_specs=spec,
                     check_rep=False)
"""

_R2_LAMBDA = """\
from jax.experimental.shard_map import shard_map

def build(mesh, spec):
    return shard_map(lambda x: x, mesh=mesh, in_specs=spec, out_specs=spec,
                     check_rep=False)
"""


class TestR2CheckRepAudit:
    def _scan(self, tmp_path, src):
        p = tmp_path / "mod.py"
        p.write_text(src)
        return r2_check_rep.scan_module(str(p), "mod.py")

    def test_unaudited_body_flagged(self, tmp_path):
        findings = self._scan(tmp_path, _R2_BAD)
        assert len(findings) == 1
        assert "no @audit_check_rep" in findings[0].message
        assert findings[0].severity == "error"

    def test_audited_body_clean(self, tmp_path):
        assert self._scan(tmp_path, _R2_GOOD) == []

    def test_factory_returned_body_resolved(self, tmp_path):
        """The distributed/dpc.py idiom: body = _make_xyz(...) resolves
        through the factory's returned inner def."""
        findings = self._scan(tmp_path, _R2_FACTORY)
        assert len(findings) == 1
        assert "`body`" in findings[0].message

    def test_unresolvable_body_flagged(self, tmp_path):
        findings = self._scan(tmp_path, _R2_LAMBDA)
        assert len(findings) == 1
        assert "cannot" in findings[0].message

    def test_default_check_rep_ignored(self, tmp_path):
        src = _R2_BAD.replace(",\n                     check_rep=False", "")
        assert self._scan(tmp_path, src) == []


# ------------------------------------------------------------------- R3
def _bf16_expanded_argmin(x, y):
    """The mixed-precision sweep shape: expanded-form d2 with a bf16 dot."""
    g = jnp.dot(x.astype(jnp.bfloat16),
                y.astype(jnp.bfloat16).T).astype(jnp.float32)
    d2 = (x * x).sum(-1)[:, None] + (y * y).sum(-1)[None, :] - 2.0 * g
    return jnp.argmin(d2, axis=1)


def _r3_findings(fn):
    x = jnp.zeros((8, 2), jnp.float32)
    y = jnp.zeros((5, 2), jnp.float32)
    closed = jax.make_jaxpr(fn)(x, y)
    return [f for f in analyze_jaxpr("r3-control", closed)
            if f.rule == r3_precision.RULE_NAME]


class TestR3PrecisionFlow:
    def test_bf16_dot_without_refinement_fires(self):
        assert len(_r3_findings(_bf16_expanded_argmin)) == 1

    def test_refinement_epilogue_passes(self):
        def refined(x, y):
            idx = _bf16_expanded_argmin(x, y)
            y_sel = y[idx]
            # the refine_topk_d2 / _fused_resolve contract: direct-diff
            # square-sum in full precision over the kept winners
            return jnp.sum((x - y_sel) ** 2, axis=-1)

        assert _r3_findings(refined) == []

    def test_pure_f32_never_fires(self):
        def f32_only(x, y):
            d2 = ((x[:, None, :] - y[None, :, :]) ** 2).sum(-1)
            return jnp.argmin(d2, axis=1)

        assert _r3_findings(f32_only) == []


# ------------------------------------------------------------------- R4
def _pallas_identity(block_rows):
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] + jnp.float32(1.0)

    n = 96
    grid = -(-n // block_rows)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((block_rows, 2), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 2), jnp.float32),
        interpret=True)


def _r4_findings(block_rows):
    x = jnp.zeros((96, 2), jnp.float32)
    closed = jax.make_jaxpr(_pallas_identity(block_rows))(x)
    return [f for f in analyze_jaxpr("r4-control", closed)
            if f.rule == r4_pallas.RULE_NAME]


class TestR4PallasLegality:
    def test_nondivisible_block_fires(self):
        findings = _r4_findings(40)          # 96 % 40 != 0
        assert findings, "96-row array with 40-row blocks must be flagged"
        assert all(f.severity == "error" for f in findings)

    def test_divisible_block_passes(self):
        assert _r4_findings(32) == []        # 96 % 32 == 0


# ------------------------------------------------------------------- R5
class TestR5SpecCoverage:
    def test_clean_on_tree(self):
        findings = r5_coverage.SpecCoverageRule().check_project(_REPO_ROOT)
        assert findings == [], [f.to_dict() for f in findings]

    def test_snapshot_drift_detected(self, monkeypatch):
        monkeypatch.setattr(r5_coverage, "KNOWN_BACKENDS", ("jnp", "pallas"))
        findings = r5_coverage.SpecCoverageRule().check_project(_REPO_ROOT)
        assert any("backends changed" in f.message for f in findings)

    @pytest.mark.parametrize("backend", ["jnp", "pallas", "pallas-interpret"])
    @pytest.mark.parametrize("layout", ["dense", "block-sparse"])
    @pytest.mark.parametrize("precision", ["f32", "bf16"])
    def test_axis_product_matches_validity_table(self, backend, layout,
                                                 precision):
        """Every axis value, by literal name (R5's corpus check counts on
        exactly this parametrization): ExecSpec accepts/rejects the full
        cross product where the documented table says."""
        if r5_coverage._expected_spec_valid(backend, layout, precision):
            spec = ExecSpec(backend=backend, layout=layout,
                            precision=precision)
            assert spec.describe() == f"{backend}:{layout}:{precision}"
        else:
            with pytest.raises(ValueError):
                ExecSpec(backend=backend, layout=layout, precision=precision)


# ------------------------------------------------ plan-time gate (planner)
class TestPlanTimeGate:
    def test_error_findings_fail_plan(self, monkeypatch):
        from repro import analysis

        bad = Finding(rule="X-test", severity="error", target="t",
                      message="injected failure")
        monkeypatch.setattr(analysis, "analyze_plan", lambda pl: [bad])
        spec = ExecSpec(backend="jnp", block=137)   # unique -> memo miss
        monkeypatch.delenv("REPRO_ANALYSIS", raising=False)
        planner._ANALYZED.pop(spec, None)
        planner._PLANS.pop((None, spec), None)
        try:
            with pytest.raises(AnalysisError, match="REPRO_ANALYSIS=0"):
                planner.plan(None, spec)
            # the documented escape hatch bypasses without re-analyzing
            monkeypatch.setenv("REPRO_ANALYSIS", "0")
            assert planner.plan(None, spec) is not None
        finally:
            planner._ANALYZED.pop(spec, None)
            planner._PLANS.pop((None, spec), None)

    def test_warnings_do_not_fail_plan(self, monkeypatch):
        from repro import analysis

        warn = Finding(rule="X-test", severity="warn", target="t",
                       message="advisory only")
        monkeypatch.setattr(analysis, "analyze_plan", lambda pl: [warn])
        spec = ExecSpec(backend="jnp", block=139)
        monkeypatch.delenv("REPRO_ANALYSIS", raising=False)
        planner._ANALYZED.pop(spec, None)
        planner._PLANS.pop((None, spec), None)
        try:
            assert planner.plan(None, spec) is not None
        finally:
            planner._ANALYZED.pop(spec, None)
            planner._PLANS.pop((None, spec), None)

    def test_real_plans_analyze_clean(self):
        """The canonical plan-time targets of the shipping specs carry no
        findings at all (error or warn) on this tree."""
        from repro.analysis import analyze_plan

        for spec in (ExecSpec(),
                     ExecSpec(backend="jnp", layout="block-sparse"),
                     ExecSpec(backend="pallas-interpret",
                              layout="block-sparse")):
            pl = planner.plan(None, spec)
            assert list(analyze_plan(pl)) == []


# ----------------------------------------------- R1 + the distributed gate
def test_single_device_blocksparse_layout():
    """shard_blocksparse_layout: single-partition meshes never hit the
    miscompile (no SPMD partitioning), so traceable-worklist plans keep
    block-sparse; dense plans and host-worklist backends never do."""
    from repro.distributed import dpc as ddpc

    mesh = jax.make_mesh((1,), ("data",))
    bs = planner.plan(None, ExecSpec(backend="jnp", layout="block-sparse"))
    assert ddpc.shard_blocksparse_layout(bs, mesh) == "block-sparse"
    dense = planner.plan(None, ExecSpec(backend="jnp"))
    assert ddpc.shard_blocksparse_layout(dense, mesh) is None
    host = planner.plan(None, ExecSpec(backend="pallas-interpret",
                                       layout="block-sparse"))
    assert ddpc.shard_blocksparse_layout(host, mesh) is None


_R1_SCRIPT = r"""
import warnings, json, os
warnings.filterwarnings("ignore")
os.environ["REPRO_ANALYSIS"] = "0"     # probe plans, not production fits
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.analysis import spmd_gather_safe, r1_spmd_gather
from repro.analysis.rules import analyze_jaxpr
from repro.analysis.targets import distributed_targets, stream_targets
from repro.distributed import dpc as ddpc
from repro.engine import ExecSpec
from repro.engine.planner import plan
from repro.kernels.backend import get_backend

mesh = jax.make_mesh((4,), ("data",))
be = get_backend("jnp")
pts = jnp.zeros((32, 2), jnp.float32)
rk = jnp.zeros((32,), jnp.float32)

# (a) positive control: a frozen copy of the pre-one-hot order-gather ring
# walk (argsort visit order, tile id read from the sorted permutation
# inside the walk, feeding a dynamic_slice) -- the exact shape the pinned
# XLA CPU SPMD pipeline miscompiles.  Deleted from production by the
# one-hot rewrite; kept here so R1's detection of the pattern stays pinned.
BM = 8
def frozen_order_gather_walk(x_my, y):
    nbc = y.shape[0] // BM
    lo = jnp.min(y.reshape(nbc, BM, -1), axis=1)
    lb = jnp.sum((jnp.mean(x_my, axis=0)[None, :] - lo) ** 2, axis=1)
    order = jnp.argsort(lb).astype(jnp.int32)     # sort-derived visit order
    lbs = jnp.take_along_axis(lb, order, axis=0)  # the old order-gather

    def cond(c):
        p, _ = c
        return (p < nbc) & (lbs[jnp.minimum(p, nbc - 1)] < jnp.inf)

    def body(c):
        p, acc = c
        j = order[p]                              # tainted tile id ...
        tile = jax.lax.dynamic_slice_in_dim(y, j * BM, BM, 0)  # ... -> R1
        d2 = jnp.sum((x_my[:, None, :] - tile[None, :, :]) ** 2, -1)
        return p + 1, acc + jnp.sum(d2 < 1.0, axis=1).astype(jnp.float32)

    _, acc = jax.lax.while_loop(
        cond, body, (jnp.int32(0), jnp.zeros(x_my.shape[0], jnp.float32)))
    return acc

sm_old = shard_map(frozen_order_gather_walk, mesh=mesh,
                   in_specs=(P("data"), P(None)), out_specs=P("data"),
                   check_rep=False)
safe_old = spmd_gather_safe(sm_old, pts, pts)
closed = jax.make_jaxpr(sm_old)(pts, pts)
r1 = [f for f in analyze_jaxpr("frozen-order-gather", closed)
      if f.rule == r1_spmd_gather.RULE_NAME]

# (b) the production one-hot walk: both block-sparse shard phases trace
# clean over 4 partitions, so the guard keeps block-sparse on this mesh
rho_fn = ddpc._make_rho_dense("data", 1.0, 256, be, layout="block-sparse")
delta_fn = ddpc._make_delta_dense("data", 256, be, layout="block-sparse")
sm_rho = shard_map(rho_fn, mesh=mesh, in_specs=(P("data"), P("data")),
                   out_specs=P("data"), check_rep=False)
sm_delta = shard_map(delta_fn, mesh=mesh, in_specs=(P("data"),) * 4,
                     out_specs=(P("data"),) * 3, check_rep=False)
safe_rho = spmd_gather_safe(sm_rho, pts, pts)
safe_delta = spmd_gather_safe(sm_delta, pts, rk, pts, rk)
pl_bs = plan(None, ExecSpec(backend="jnp", layout="block-sparse"))
pl_dense = plan(None, ExecSpec(backend="jnp"))
lay_bs = ddpc.shard_blocksparse_layout(pl_bs, mesh)
lay_dense = ddpc.shard_blocksparse_layout(pl_dense, mesh)

# (c) the clean tree: every distributed/stream target these plans run
# today -- now including the block-sparse shard phases and the sharded
# stream tail (NN re-query, label propagation, center distances) --
# analyzes with zero error findings
errors = []
for pl in (pl_bs, pl_dense):
    tgts = list(distributed_targets(pl)[0]) + list(stream_targets(pl)[0])
    for name, thunk in tgts:
        for f in analyze_jaxpr(name, thunk()):
            if f.severity == "error":
                errors.append([name, f.rule])

out = {"safe_old": bool(safe_old), "n_r1": len(r1),
       "messages": [f.message for f in r1],
       "safe_rho": bool(safe_rho), "safe_delta": bool(safe_delta),
       "layout_bs": lay_bs, "layout_dense": lay_dense,
       "clean_errors": errors}
print("RESULT" + json.dumps(out))
"""


def _run_subprocess(script):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(_REPO_ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_r1_positive_control_and_production_tree_is_clean():
    """All three R1 halves in one 4-device subprocess: the frozen copy of
    the old order-gather walk is still flagged (the rule's detection of
    the miscompile pattern stays pinned after the production rewrite),
    both production block-sparse shard phases trace clean so the probe
    keeps block-sparse on a multi-partition mesh (ISSUE 8 acceptance),
    and every shipping distributed/stream trace analyzes clean."""
    out = _run_subprocess(_R1_SCRIPT)
    assert out["safe_old"] is False
    assert out["n_r1"] >= 1
    assert any("sort-derived" in m for m in out["messages"])
    assert out["safe_rho"] is True and out["safe_delta"] is True, \
        "production one-hot shard phases must pass spmd_gather_safe"
    assert out["layout_bs"] == "block-sparse", \
        "the probe must re-enable multi-partition block-sparse"
    assert out["layout_dense"] is None
    assert out["clean_errors"] == []
