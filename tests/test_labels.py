"""Label propagation (pointer jumping) vs a sequential DFS reference."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.labels import assign_labels, decision_graph
from repro.core.exdpc import run_exdpc
from repro.data.points import gaussian_mixture


def _sequential_labels(parent, centers, noise):
    """Reference: follow parents iteratively (the paper's DFS, inverted)."""
    n = len(parent)
    labels = np.full(n, -2)
    center_ids = {}
    for i in np.nonzero(centers)[0]:
        center_ids[i] = len(center_ids)
    for i in range(n):
        if noise[i]:
            labels[i] = -1
            continue
        j = i
        seen = 0
        while True:
            if centers[j]:
                labels[i] = center_ids[j]
                break
            nxt = parent[j]
            if nxt < 0 or noise[j]:
                labels[i] = -1
                break
            j = nxt
            seen += 1
            assert seen <= n, "cycle in dependency forest"
        if labels[i] == -2:
            labels[i] = -1
    return labels


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 1000))
def test_pointer_jumping_matches_dfs(seed):
    pts, _ = gaussian_mixture(600, k=5, seed=seed)
    res = run_exdpc(pts, 3000.0)
    out = assign_labels(res, rho_min=5.0, delta_min=6500.0)
    centers = np.asarray(out.centers)
    noise = np.asarray(res.rho) < 5.0
    ref = _sequential_labels(np.asarray(res.parent), centers, noise)
    got = np.asarray(out.labels)
    # label ids may differ by permutation; compare as partitions
    from repro.core import rand_index
    assert rand_index(got, ref) == 1.0


def test_chains_ascend_density():
    pts, _ = gaussian_mixture(800, k=5, seed=17)
    res = run_exdpc(pts, 3000.0)
    parent = np.asarray(res.parent)
    rk = np.asarray(res.rho_key)
    has = parent >= 0
    assert np.all(rk[parent[has]] > rk[has])


def test_decision_graph_shows_k_peaks():
    """Fig. 1: the decision graph separates exactly k cluster centers."""
    k = 8
    pts, _ = gaussian_mixture(3000, k=k, overlap=0.012, seed=18)
    res = run_exdpc(pts, 2000.0)
    dg = np.asarray(decision_graph(res))
    rho, delta = dg[:, 0], dg[:, 1]
    candidates = (rho >= 10.0)
    finite = np.where(np.isfinite(delta), delta, 1e9)
    top = np.sort(finite[candidates])[::-1]
    # gap between k-th and (k+1)-th dependent distance is large
    assert top[k - 1] > 3.0 * top[k]


def test_num_clusters_matches_centers():
    pts, _ = gaussian_mixture(1000, k=6, seed=19)
    res = run_exdpc(pts, 2500.0)
    out = assign_labels(res, 5.0, 6000.0)
    assert int(out.num_clusters) == int(np.asarray(out.centers).sum())
    k = int(out.num_clusters)
    labs = np.asarray(out.labels)
    assert set(np.unique(labs)) <= set(range(-1, k))
