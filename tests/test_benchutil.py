"""benchmarks.util timing: compile time must never leak into measurements.

``timeit_stats`` syncs every warmup result (``jax.block_until_ready`` over
the full output tree) *before* t0 of the first measured repeat and syncs
each repeat inside its own timing window.  The deliberately slow-to-compile
function below (a long unrolled chain of matmul+tanh on a tiny operand —
trivial to run, expensive for XLA to build) makes the difference
observable: warmup_s dwarfs every steady-state repeat.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from benchmarks.util import timeit, timeit_stats


@jax.jit
def _slow_compile(x):
    # ~60 fused matmul+tanh stages: milliseconds to execute on a 16x16
    # operand, but a deep graph for XLA to optimize — compile-heavy by
    # construction
    for _ in range(60):
        x = jnp.tanh(x @ x + x)
    return x


def test_warmup_absorbs_compile_time():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(16, 16)),
                    jnp.float32)
    stats = timeit_stats(_slow_compile, x, repeats=3, warmup=1)
    assert len(stats["times_s"]) == 3
    assert stats["median_s"] == float(np.median(stats["times_s"]))
    assert stats["min_s"] == min(stats["times_s"])
    # the compile happened inside the synced warmup, not the repeats
    assert stats["warmup_s"] > 5 * max(stats["times_s"])


def test_timeit_returns_median_seconds():
    x = jnp.ones((8, 8), jnp.float32)
    t = timeit(lambda v: v + 1.0, x, repeats=3, warmup=1)
    assert isinstance(t, float) and t >= 0.0


def test_repeats_are_device_synced():
    # every repeat window fences the whole output tree, so per-repeat times
    # are strictly positive even for tuple-of-array outputs
    x = jnp.asarray(np.random.default_rng(1).normal(size=(256, 256)),
                    jnp.float32)
    fn = jax.jit(lambda v: (v @ v, jnp.tanh(v)))
    synced = timeit_stats(fn, x, repeats=3, warmup=1)
    assert all(t > 0.0 for t in synced["times_s"])


@pytest.mark.parametrize("warmup", [0, 2])
def test_warmup_count_respected(warmup):
    calls = []

    def fn():
        calls.append(1)
        return jnp.zeros(())

    stats = timeit_stats(fn, repeats=2, warmup=warmup)
    assert len(calls) == warmup + 2
    assert stats["warmup_s"] >= 0.0
