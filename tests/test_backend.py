"""Kernel-backend parity: the pallas kernels (interpret mode on CPU) must
reproduce the jnp reference through every algorithm driver.

Threshold contract (kernels/backend.py): the pallas backends compute d2 in
the MXU expanded form, so data is drawn away from the d_cut boundary and
with NN distances comparable to the domain scale (uniform), where the
expanded form is exact to the same f32 ulps as the direct difference —
equality is then *bit*-equality, not a tolerance.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import DPCConfig, cluster, compute_dpc
from repro.core.scan import run_scan
from repro.kernels import available_backends, get_backend
from repro.kernels.backend import JnpBackend, KernelBackend, PallasBackend
from repro.kernels.ref import masked_min_dist_ref, range_count_ref

D_CUT = 900.0


def _safe_points(n, d, d_cut, seed):
    """Uniform points with no pairwise distance near the d_cut threshold."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 50 * d_cut, size=(n, d)).astype(np.float32)
    d2 = ((pts[:, None, :].astype(np.float64) - pts[None, :, :]) ** 2).sum(-1)
    bad = np.abs(np.sqrt(d2) - d_cut) < 1e-3 * d_cut
    np.fill_diagonal(bad, False)
    return pts[~bad.any(1)]


def _assert_equal_results(a, b):
    assert bool(jnp.all(a.rho == b.rho)), "rho mismatch"
    assert bool(jnp.all(a.parent == b.parent)), "parent mismatch"
    both_inf = jnp.isinf(a.delta) & jnp.isinf(b.delta)
    assert bool(jnp.all((a.delta == b.delta) | both_inf)), "delta mismatch"


class TestRegistry:
    def test_all_backends_registered(self):
        assert {"jnp", "pallas", "pallas-interpret"} <= set(
            available_backends())

    def test_cpu_default_is_jnp(self):
        # conftest pins JAX_PLATFORMS=cpu, so auto-detection must pick the
        # reference (interpret mode is a CI opt-in, not a default)
        assert isinstance(get_backend(None), JnpBackend)
        assert get_backend("auto").name == get_backend(None).name

    def test_instance_passthrough_and_flags(self):
        be = get_backend("pallas-interpret")
        assert get_backend(be) is be
        assert isinstance(be, PallasBackend) and be.mxu_dense
        assert not get_backend("jnp").mxu_dense

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError, match="unknown kernel backend"):
            get_backend("cuda")

    def test_custom_registration(self):
        from repro.kernels.backend import register_backend, _REGISTRY

        class _Probe(KernelBackend):
            name = "probe"

        register_backend("probe", _Probe)
        try:
            assert isinstance(get_backend("probe"), _Probe)
        finally:
            _REGISTRY.pop("probe", None)


class TestPrimitiveParity:
    """Both backends against the dense jnp oracles, rectangular shapes."""

    @pytest.mark.parametrize("name", ["jnp", "pallas-interpret"])
    def test_range_count(self, name):
        be = get_backend(name)
        x = jnp.asarray(_safe_points(300, 3, D_CUT, 0))
        y = jnp.asarray(_safe_points(500, 3, D_CUT, 1))
        got = be.range_count(x, y, D_CUT)
        ref = range_count_ref(x, y, D_CUT).astype(jnp.float32)
        assert bool(jnp.all(got == ref))

    @pytest.mark.parametrize("name", ["jnp", "pallas-interpret"])
    def test_denser_nn(self, name):
        be = get_backend(name)
        rng = np.random.default_rng(2)
        x = jnp.asarray(_safe_points(300, 3, D_CUT, 3))
        y = jnp.asarray(_safe_points(500, 3, D_CUT, 4))
        xk = jnp.asarray(rng.uniform(0, 10, x.shape[0]), jnp.float32)
        yk = jnp.asarray(rng.uniform(0, 10, y.shape[0]), jnp.float32)
        dd, pp = be.denser_nn(x, xk, y, yk)
        rd, rp = masked_min_dist_ref(x, xk, y, yk)
        assert bool(jnp.all(pp == rp))
        both_inf = jnp.isinf(dd) & jnp.isinf(rd)
        assert bool(jnp.allclose(jnp.where(both_inf, 0, dd),
                                 jnp.where(both_inf, 0, rd),
                                 rtol=1e-6, atol=1e-4))

    def test_prefix_nn_matches_denser_nn_semantics(self):
        # prefix NN == denser NN keyed by descending position
        pts = jnp.asarray(_safe_points(300, 2, D_CUT, 5))
        for name in ("jnp", "pallas-interpret"):
            be = get_backend(name)
            dd, pp = be.prefix_nn(pts)
            n = pts.shape[0]
            key = -jnp.arange(n, dtype=jnp.float32)
            rd, rp = masked_min_dist_ref(pts, key, pts, key)
            assert bool(jnp.all(pp == rp)), name
            assert bool(jnp.all(jnp.isinf(dd) == jnp.isinf(rd))), name


class TestStreamingPrimitives:
    """The two streaming batched primitives (repro.stream) per backend."""

    @pytest.mark.parametrize("name", ["jnp", "pallas-interpret"])
    def test_range_count_delta(self, name):
        be = get_backend(name)
        rng = np.random.default_rng(5)
        x = jnp.asarray(_safe_points(400, 3, D_CUT, 6))
        batch = jnp.asarray(_safe_points(96, 3, D_CUT, 7))
        signs = jnp.asarray(rng.choice([-1.0, 0.0, 1.0], batch.shape[0]),
                            jnp.float32)
        got = be.range_count_delta(x, batch, signs, D_CUT)
        d2 = ((np.asarray(x)[:, None, :].astype(np.float64)
               - np.asarray(batch)[None]) ** 2).sum(-1)
        ref = ((d2 < D_CUT ** 2) * np.asarray(signs)[None, :]).sum(1)
        assert np.array_equal(np.asarray(got), ref.astype(np.float32))

    @pytest.mark.parametrize("name", ["jnp", "pallas-interpret"])
    def test_delta_of_counts_composes(self, name):
        """rho(after) == rho(before) + delta(batch): the exact-integer
        repair identity the sliding window relies on."""
        be = get_backend(name)
        pts = _safe_points(500, 2, D_CUT, 8)
        survivors, ins = pts[:400], pts[400:432]
        evi = survivors[:32]          # pretend these leave the window
        after = np.concatenate([survivors[32:], ins])
        batch = jnp.asarray(np.concatenate([ins, evi]))
        signs = jnp.asarray(np.concatenate([np.ones(len(ins)),
                                            -np.ones(len(evi))]), jnp.float32)
        q = jnp.asarray(survivors[32:])
        before = be.range_count(q, jnp.asarray(survivors), D_CUT)
        repaired = before + be.range_count_delta(q, batch, signs, D_CUT)
        fresh = be.range_count(q, jnp.asarray(after), D_CUT)
        assert bool(jnp.all(repaired == fresh))

    @pytest.mark.parametrize("name", ["jnp", "pallas-interpret"])
    def test_denser_nn_update_subset(self, name):
        be = get_backend(name)
        rng = np.random.default_rng(9)
        pts = jnp.asarray(_safe_points(400, 3, D_CUT, 10))
        n = pts.shape[0]
        rk = jnp.asarray(rng.permutation(n).astype(np.float32))
        rows = np.sort(rng.choice(n, 48, replace=False))
        q_slots = jnp.asarray(np.concatenate([rows, [n, n + 3]]))  # + padding
        dd, pp = be.denser_nn_update(pts, rk, q_slots)
        rd, rp = be.denser_nn(pts[jnp.asarray(rows)],
                              rk[jnp.asarray(rows)], pts, rk)
        assert bool(jnp.all(pp[:48] == rp))
        both_inf = jnp.isinf(dd[:48]) & jnp.isinf(rd)
        assert bool(jnp.all((dd[:48] == rd) | both_inf))
        assert bool(jnp.all(jnp.isinf(dd[48:])))     # padding rows inert
        assert bool(jnp.all(pp[48:] == -1))


class TestArgminRefinement:
    """ROADMAP item: expanded-form d2 can flip near-tie argmins when NN
    distances << domain scale; the kernels re-rank the top-k candidates in
    direct-diff form so the winner survives ill conditioning."""

    @staticmethod
    def _adversarial(offset=5e4, seed=0):
        """Query at a large offset with a planted near-tie: true NN at
        r=30, decoy at r=30.07 — a gap far below the expanded form's
        absolute error (~eps * |x|^2 ~ 1e2 at this offset), with fillers
        far enough to stay out of every top-k."""
        rng = np.random.default_rng(seed)
        q = np.array([offset, offset], np.float32)
        nn = q + np.array([30.0, 0.0], np.float32)
        decoy = q + np.array([0.0, 30.07], np.float32)
        fillers = q + (rng.uniform(300.0, 2000.0, (61, 2)).astype(np.float32)
                       * rng.choice([-1, 1], (61, 2)))
        y = np.concatenate([[nn], [decoy], fillers]).astype(np.float32)
        return (jnp.asarray(q[None]), jnp.zeros(1, jnp.float32),
                jnp.asarray(y), jnp.ones(len(y), jnp.float32))

    def test_topk_rerank_recovers_true_nn(self):
        from repro.kernels import ops

        x, xk, y, yk = self._adversarial()
        ref_d, ref_p = get_backend("jnp").denser_nn(x, xk, y, yk)
        assert int(ref_p[0]) == 0                    # direct diff: true NN
        got_d, got_p = ops.dependent_masked(x, xk, y, yk, interpret=True)
        assert int(got_p[0]) == int(ref_p[0])
        assert float(got_d[0]) == float(ref_d[0])    # winner value direct-diff

    def test_k1_reproduces_the_bug(self):
        """refine_k=1 is the historical refine-the-winner-only behavior;
        the adversarial data must flip it (guards the test's potency)."""
        from repro.kernels.dependent import masked_min_dist
        from repro.kernels.ops import pad_points, pad_vec

        x, xk, y, yk = self._adversarial()
        xp, xkp = pad_points(x, 128), pad_vec(xk, 128, jnp.inf)
        yp, ykp = pad_points(y, 256), pad_vec(yk, 256, -jnp.inf)
        _, p1 = masked_min_dist(xp, xkp, yp, ykp, interpret=True, refine_k=1)
        assert int(p1[0]) == 1, "expanded-form flip no longer reproduces"

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_scaled_dataset_parent_parity(self, seed):
        """Whole-dataset regression: a blob at a 50x offset (NN distances
        << coordinate scale; expanded-form noise spans several near-ties,
        flipping refine_k=1 on every seed) keeps exact parent parity
        between the jnp reference and the re-ranking kernels."""
        rng = np.random.default_rng(seed)
        pts = (rng.normal(0, 200.0, (384, 2)) + 1e4).astype(np.float32)
        x = jnp.asarray(pts)
        rk = jnp.asarray(rng.permutation(len(pts)).astype(np.float32))
        rd, rp = get_backend("jnp").denser_nn(x, rk, x, rk)
        gd, gp = get_backend("pallas-interpret").denser_nn(x, rk, x, rk)
        assert bool(jnp.all(rp == gp))
        both_inf = jnp.isinf(rd) & jnp.isinf(gd)
        assert bool(jnp.all((rd == gd) | both_inf))


class TestAlgorithmParity:
    """Acceptance: compute_dpc(..., backend="pallas-interpret") equals the
    jnp backend (and, for the exact algorithms, the run_scan oracle)."""

    @pytest.fixture(scope="class")
    def pts(self):
        return _safe_points(800, 3, D_CUT, 0)

    @pytest.mark.parametrize("alg", ["scan", "exdpc", "approxdpc",
                                     "sapproxdpc"])
    def test_matches_jnp_backend(self, pts, alg):
        rj = compute_dpc(pts, DPCConfig(d_cut=D_CUT, algorithm=alg,
                                        backend="jnp"))
        rp = compute_dpc(pts, DPCConfig(d_cut=D_CUT, algorithm=alg,
                                        backend="pallas-interpret"))
        _assert_equal_results(rj, rp)

    @pytest.mark.parametrize("alg", ["scan", "exdpc"])
    def test_exact_algorithms_match_scan_oracle(self, pts, alg):
        oracle = run_scan(jnp.asarray(pts), D_CUT)   # jnp reference oracle
        rp = compute_dpc(pts, DPCConfig(d_cut=D_CUT, algorithm=alg,
                                        backend="pallas-interpret"))
        _assert_equal_results(oracle, rp)

    def test_approxdpc_centers_equal(self, pts):
        cfg = dict(d_cut=D_CUT, algorithm="approxdpc", rho_min=3.0)
        cj, _ = cluster(pts, DPCConfig(backend="jnp", **cfg))
        cp, _ = cluster(pts, DPCConfig(backend="pallas-interpret", **cfg))
        assert bool(jnp.all(cj.centers == cp.centers))
        assert bool(jnp.all(cj.labels == cp.labels))

    def test_dense_path_engages(self, pts):
        # the pallas run must actually take the dense branch (guard against
        # silently falling back to the stencil)
        assert get_backend("pallas-interpret").mxu_dense
