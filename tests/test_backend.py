"""Kernel-backend parity: the pallas kernels (interpret mode on CPU) must
reproduce the jnp reference through every algorithm driver.

Threshold contract (kernels/backend.py): the pallas backends compute d2 in
the MXU expanded form, so data is drawn away from the d_cut boundary and
with NN distances comparable to the domain scale (uniform), where the
expanded form is exact to the same f32 ulps as the direct difference —
equality is then *bit*-equality, not a tolerance.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import DPCConfig, cluster, compute_dpc
from repro.core.scan import run_scan
from repro.kernels import available_backends, get_backend
from repro.kernels.backend import JnpBackend, KernelBackend, PallasBackend
from repro.kernels.ref import masked_min_dist_ref, range_count_ref

D_CUT = 900.0


def _safe_points(n, d, d_cut, seed):
    """Uniform points with no pairwise distance near the d_cut threshold."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 50 * d_cut, size=(n, d)).astype(np.float32)
    d2 = ((pts[:, None, :].astype(np.float64) - pts[None, :, :]) ** 2).sum(-1)
    bad = np.abs(np.sqrt(d2) - d_cut) < 1e-3 * d_cut
    np.fill_diagonal(bad, False)
    return pts[~bad.any(1)]


def _assert_equal_results(a, b):
    assert bool(jnp.all(a.rho == b.rho)), "rho mismatch"
    assert bool(jnp.all(a.parent == b.parent)), "parent mismatch"
    both_inf = jnp.isinf(a.delta) & jnp.isinf(b.delta)
    assert bool(jnp.all((a.delta == b.delta) | both_inf)), "delta mismatch"


class TestRegistry:
    def test_all_backends_registered(self):
        assert {"jnp", "pallas", "pallas-interpret"} <= set(
            available_backends())

    def test_cpu_default_is_jnp(self):
        # conftest pins JAX_PLATFORMS=cpu, so auto-detection must pick the
        # reference (interpret mode is a CI opt-in, not a default)
        assert isinstance(get_backend(None), JnpBackend)
        assert get_backend("auto").name == get_backend(None).name

    def test_instance_passthrough_and_flags(self):
        be = get_backend("pallas-interpret")
        assert get_backend(be) is be
        assert isinstance(be, PallasBackend) and be.mxu_dense
        assert not get_backend("jnp").mxu_dense

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError, match="unknown kernel backend"):
            get_backend("cuda")

    def test_custom_registration(self):
        from repro.kernels.backend import register_backend, _REGISTRY

        class _Probe(KernelBackend):
            name = "probe"

        register_backend("probe", _Probe)
        try:
            assert isinstance(get_backend("probe"), _Probe)
        finally:
            _REGISTRY.pop("probe", None)


class TestPrimitiveParity:
    """Both backends against the dense jnp oracles, rectangular shapes."""

    @pytest.mark.parametrize("name", ["jnp", "pallas-interpret"])
    def test_range_count(self, name):
        be = get_backend(name)
        x = jnp.asarray(_safe_points(300, 3, D_CUT, 0))
        y = jnp.asarray(_safe_points(500, 3, D_CUT, 1))
        got = be.range_count(x, y, D_CUT)
        ref = range_count_ref(x, y, D_CUT).astype(jnp.float32)
        assert bool(jnp.all(got == ref))

    @pytest.mark.parametrize("name", ["jnp", "pallas-interpret"])
    def test_denser_nn(self, name):
        be = get_backend(name)
        rng = np.random.default_rng(2)
        x = jnp.asarray(_safe_points(300, 3, D_CUT, 3))
        y = jnp.asarray(_safe_points(500, 3, D_CUT, 4))
        xk = jnp.asarray(rng.uniform(0, 10, x.shape[0]), jnp.float32)
        yk = jnp.asarray(rng.uniform(0, 10, y.shape[0]), jnp.float32)
        dd, pp = be.denser_nn(x, xk, y, yk)
        rd, rp = masked_min_dist_ref(x, xk, y, yk)
        assert bool(jnp.all(pp == rp))
        both_inf = jnp.isinf(dd) & jnp.isinf(rd)
        assert bool(jnp.allclose(jnp.where(both_inf, 0, dd),
                                 jnp.where(both_inf, 0, rd),
                                 rtol=1e-6, atol=1e-4))

    def test_prefix_nn_matches_denser_nn_semantics(self):
        # prefix NN == denser NN keyed by descending position
        pts = jnp.asarray(_safe_points(300, 2, D_CUT, 5))
        for name in ("jnp", "pallas-interpret"):
            be = get_backend(name)
            dd, pp = be.prefix_nn(pts)
            n = pts.shape[0]
            key = -jnp.arange(n, dtype=jnp.float32)
            rd, rp = masked_min_dist_ref(pts, key, pts, key)
            assert bool(jnp.all(pp == rp)), name
            assert bool(jnp.all(jnp.isinf(dd) == jnp.isinf(rd))), name


class TestAlgorithmParity:
    """Acceptance: compute_dpc(..., backend="pallas-interpret") equals the
    jnp backend (and, for the exact algorithms, the run_scan oracle)."""

    @pytest.fixture(scope="class")
    def pts(self):
        return _safe_points(800, 3, D_CUT, 0)

    @pytest.mark.parametrize("alg", ["scan", "exdpc", "approxdpc",
                                     "sapproxdpc"])
    def test_matches_jnp_backend(self, pts, alg):
        rj = compute_dpc(pts, DPCConfig(d_cut=D_CUT, algorithm=alg,
                                        backend="jnp"))
        rp = compute_dpc(pts, DPCConfig(d_cut=D_CUT, algorithm=alg,
                                        backend="pallas-interpret"))
        _assert_equal_results(rj, rp)

    @pytest.mark.parametrize("alg", ["scan", "exdpc"])
    def test_exact_algorithms_match_scan_oracle(self, pts, alg):
        oracle = run_scan(jnp.asarray(pts), D_CUT)   # jnp reference oracle
        rp = compute_dpc(pts, DPCConfig(d_cut=D_CUT, algorithm=alg,
                                        backend="pallas-interpret"))
        _assert_equal_results(oracle, rp)

    def test_approxdpc_centers_equal(self, pts):
        cfg = dict(d_cut=D_CUT, algorithm="approxdpc", rho_min=3.0)
        cj, _ = cluster(pts, DPCConfig(backend="jnp", **cfg))
        cp, _ = cluster(pts, DPCConfig(backend="pallas-interpret", **cfg))
        assert bool(jnp.all(cj.centers == cp.centers))
        assert bool(jnp.all(cj.labels == cp.labels))

    def test_dense_path_engages(self, pts):
        # the pallas run must actually take the dense branch (guard against
        # silently falling back to the stencil)
        assert get_backend("pallas-interpret").mxu_dense
