"""Property tests for the abstract-interpretation layer (repro.analysis.absint)
and the walker taint engine.

Two tiers, same properties:

* **seeded-random sweeps** — always run, no extra deps: a fixed
  ``numpy`` RNG drives a few hundred random affine index maps / traced
  programs per property, so local runs exercise the domain even where
  hypothesis is absent;
* **hypothesis** — the same properties under minimizing search, guarded
  with the repo's ``requirements-dev`` convention (degrade to skips when
  hypothesis is not installed; CI installs it).

The core soundness property: for any affine index map over a concrete
grid small enough to enumerate, :func:`absint.visit_verdict` must agree
*exactly* with brute-force enumeration — ``"once"`` iff no two grid
points produce the same output block tuple.  Above the enumeration cap
the check is one-sided (a ``"once"`` claim must still be true; the
analyzer may say ``"unknown"``).
"""
import itertools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import absint
from repro.analysis.absint import Affine

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


# ------------------------------------------------------------ ground truth
def brute_force_verdict(dims, grid):
    """Exact uniqueness of the output tuples over the concrete grid."""
    seen = set()
    for point in itertools.product(*[range(s) for s in grid]):
        key = tuple(d.eval(point) for d in dims)
        if key in seen:
            return "revisit"
        seen.add(key)
    return "once"


def random_case(rng):
    """One random (dims, grid): <= 3 grid axes of size 1..6, <= 3 output
    dims, coefficients in [-3, 3], constants in [-4, 4]."""
    n_axes = int(rng.integers(1, 4))
    grid = tuple(int(rng.integers(1, 7)) for _ in range(n_axes))
    n_dims = int(rng.integers(1, 4))
    dims = []
    for _ in range(n_dims):
        coeffs = tuple(
            (a, int(c)) for a in range(n_axes)
            if (c := rng.integers(-3, 4)) != 0)
        dims.append(Affine(int(rng.integers(-4, 5)), coeffs))
    return dims, grid


def check_exact_agreement(dims, grid):
    verdict = absint.visit_verdict(dims, grid)
    truth = brute_force_verdict(dims, grid)
    vol = 1
    for s in grid:
        vol *= s
    if vol <= absint.ENUM_CAP:
        assert verdict == truth, (dims, grid, verdict, truth)
    elif verdict == "once":                           # pragma: no cover
        assert truth == "once", (dims, grid)


# ------------------------------------------------- seeded-random fallback
class TestAffineDomainSeeded:
    def test_visit_verdict_matches_enumeration(self):
        rng = np.random.default_rng(0)
        for _ in range(300):
            dims, grid = random_case(rng)
            check_exact_agreement(dims, grid)

    def test_eval_index_map_matches_python_semantics(self):
        """Random affine lambdas traced with make_jaxpr: the abstract
        evaluation of the index-map jaxpr reproduces the concrete map at
        every grid point."""
        rng = np.random.default_rng(1)
        for _ in range(60):
            c0, c1, k = (int(rng.integers(-3, 4)) for _ in range(3))

            def f(i, j, c0=c0, c1=c1, k=k):
                return c0 * i + k, c1 * j - k, i + j

            closed = jax.make_jaxpr(f)(jnp.int32(0), jnp.int32(0))
            dims = absint.eval_index_map(closed, n_grid=2)
            assert all(isinstance(d, Affine) for d in dims), dims
            for point in itertools.product(range(4), range(4)):
                concrete = f(*point)
                assert tuple(d.eval(point) for d in dims) == concrete

    def test_unit_ownership_once_claims_are_sound_above_cap(self):
        """Big grids (enumeration impossible) only get "once" through the
        unit-coefficient ownership condition — spot-check its claims
        against sampled collisions."""
        grid = (512, 512)                  # vol > ENUM_CAP
        dims = [Affine(0, ((0, 1),)), Affine(3, ((1, 1),))]
        assert absint.visit_verdict(dims, grid) == "once"
        rng = np.random.default_rng(2)
        seen = {}
        for _ in range(5000):
            p = (int(rng.integers(512)), int(rng.integers(512)))
            key = tuple(d.eval(p) for d in dims)
            assert seen.setdefault(key, p) == p
        # and a genuinely colliding big-grid map must not claim "once"
        dims_bad = [Affine(0, ((0, 1),))]  # axis 1 unused -> revisit
        assert absint.visit_verdict(dims_bad, grid) == "revisit"

    def test_data_and_top_degrade(self):
        assert absint.visit_verdict([absint.DATA], (4,)) == "data"
        assert absint.visit_verdict([absint.TOP], (4,)) == "unknown"
        assert absint.visit_verdict([Affine(0, ((0, 1),))], (0.5,)) \
            == "unknown"


# ----------------------------------------------------- hypothesis mirror
if HAVE_HYPOTHESIS:
    coeff = st.integers(min_value=-3, max_value=3)

    @st.composite
    def affine_case(draw):
        n_axes = draw(st.integers(1, 3))
        grid = tuple(draw(st.lists(st.integers(1, 6), min_size=n_axes,
                                   max_size=n_axes)))
        n_dims = draw(st.integers(1, 3))
        dims = []
        for _ in range(n_dims):
            coeffs = tuple((a, c) for a in range(n_axes)
                           if (c := draw(coeff)) != 0)
            dims.append(Affine(draw(st.integers(-4, 4)), coeffs))
        return dims, grid

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis absent")
    class TestAffineDomainHypothesis:
        @settings(max_examples=200, deadline=None)
        @given(case=affine_case())
        def test_visit_verdict_matches_enumeration(self, case):
            dims, grid = case
            check_exact_agreement(dims, grid)

        @settings(max_examples=100, deadline=None)
        @given(axis_sizes=st.lists(st.integers(1, 5), min_size=1,
                                   max_size=3),
               consts=st.lists(st.integers(-4, 4), min_size=1,
                               max_size=3))
        def test_identity_maps_visit_once(self, axis_sizes, consts):
            """Each live axis owning its own unit-coefficient dim is the
            BlockSpec common case — always "once", any grid size."""
            grid = tuple(axis_sizes)
            dims = [Affine(consts[min(a, len(consts) - 1)], ((a, 1),))
                    for a in range(len(grid))]
            assert absint.visit_verdict(dims, grid) == "once"


# ------------------------------------------------- walker taint properties
def _taint_hits(fn, *args, require_multi_partition=False):
    from repro.analysis.walker import spmd_sort_tainted_slices

    closed = jax.make_jaxpr(fn)(*args)
    return spmd_sort_tainted_slices(
        closed, require_multi_partition=require_multi_partition)


def _in_shard_map(body):
    """Wrap body in a 1-device shard_map (single-partition: only visible
    with require_multi_partition=False)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.array(jax.devices()[:1]), ("i",))
    return shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                     check_rep=False)


class TestWalkerTaintProperties:
    def test_sort_derived_gather_is_hit(self):
        def body(x):
            order = jnp.argsort(x)
            return x[order]

        hits = _taint_hits(_in_shard_map(body), jnp.arange(8.0))
        assert hits and all(h.primitive in ("gather", "dynamic_slice")
                            for h in hits)

    def test_span_derived_gather_is_clean(self):
        """Indices computed arithmetically (no sort ancestry) never hit —
        the property that keeps the stencil paths out of R1."""
        def body(x):
            idx = (jnp.arange(8) * 3 + 1) % 8
            return x[idx]

        assert _taint_hits(_in_shard_map(body), jnp.arange(8.0)) == []

    def test_taint_survives_while_carry_fixpoint(self):
        def body(x):
            order = jnp.argsort(x)

            def cond(state):
                i, _ = state
                return i < 2

            def step(state):
                i, o = state
                return i + 1, o[o]          # keeps sort ancestry

            _, o = jax.lax.while_loop(cond, step, (0, order))
            return x[o]

        hits = _taint_hits(_in_shard_map(body), jnp.arange(8.0))
        assert hits, "carry fixpoint must preserve sort taint"

    def test_outside_shard_map_never_hits(self):
        def body(x):
            return x[jnp.argsort(x)]

        assert _taint_hits(body, jnp.arange(8.0)) == []

    def test_default_requires_multi_partition(self):
        def body(x):
            return x[jnp.argsort(x)]

        assert _taint_hits(_in_shard_map(body), jnp.arange(8.0),
                           require_multi_partition=True) == []

    def test_random_index_chains_agree_with_ancestry(self):
        """Seeded sweep: random chains of index ops either include a sort
        ancestor or not; hits mirror that exactly."""
        rng = np.random.default_rng(3)
        ops_pool = ("add", "mul", "mod")
        for _ in range(40):
            use_sort = bool(rng.integers(2))
            chain = [ops_pool[int(rng.integers(len(ops_pool)))]
                     for _ in range(int(rng.integers(1, 4)))]

            def body(x, use_sort=use_sort, chain=tuple(chain)):
                idx = jnp.argsort(x) if use_sort \
                    else jnp.arange(x.shape[0])
                for op in chain:
                    if op == "add":
                        idx = idx + 1
                    elif op == "mul":
                        idx = idx * 2
                    idx = idx % x.shape[0]
                return x[idx]

            hits = _taint_hits(_in_shard_map(body), jnp.arange(8.0))
            assert bool(hits) == use_sort, (use_sort, chain, hits)


# ----------------------------------------------------- memory estimators
class TestMemoryEstimators:
    def test_pallas_memory_counts_blocks_and_prefetch(self):
        from repro.analysis.walker import iter_sites
        from repro.kernels import sweep as S

        x = jnp.zeros((128, 2), jnp.float32)
        spec = S.SweepSpec(block_n=64, block_m=128, count=True)
        closed = jax.make_jaxpr(
            lambda a, b: S.tile_sweep(spec, a, b, 0.35,
                                      interpret=True))(x, x)
        eqns = [s.eqn for s in iter_sites(closed)
                if s.eqn.primitive.name == "pallas_call"]
        assert eqns
        est = absint.pallas_memory(eqns[0])
        assert est["vmem_bytes"] > 0
        assert est["smem_bytes"] > 0          # worklist meta prefetch
        assert list(est["grid"]) == [2]       # 2 row-blocks x 1 col-block

    def test_live_buffer_peak_scales_with_intermediates(self):
        small = jax.make_jaxpr(
            lambda x: (x * 2).sum())(jnp.ones((8, 8), jnp.float32))
        big = jax.make_jaxpr(
            lambda x: (x[:, None, :] - x[None, :, :]).sum())(
                jnp.ones((64, 8), jnp.float32))
        assert absint.live_buffer_peak(big) > \
            absint.live_buffer_peak(small) > 0
