"""Distributed DPC (shard_map) equals the single-device exact algorithms.

Multi-device CPU requires XLA_FLAGS set before jax initializes, so the
actual comparison runs in a subprocess with 4 fake host devices.
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import warnings, json
warnings.filterwarnings("ignore")
import numpy as np, jax, jax.numpy as jnp
from repro.distributed import distributed_dpc, DistDPCConfig
from repro.core.exdpc import run_exdpc
from repro.core.scan import run_scan
from repro.data.points import gaussian_mixture, with_noise

out = {}
for seed, d, k in ((0, 2, 6), (1, 3, 4)):
    pts, labels = gaussian_mixture(1200, k=k, d=d, overlap=0.03, seed=seed)
    pts, labels = with_noise(pts, labels, 0.05, seed=seed)
    d_cut = 3000.0
    # jax 0.4.x has no sharding.AxisType / axis_types kwarg; the default
    # (auto) axis behavior is what shard_map needs anyway.
    mesh = jax.make_mesh((4,), ("data",))
    res_d = distributed_dpc(pts, DistDPCConfig(d_cut=d_cut), mesh)
    res_e = run_exdpc(pts, d_cut)
    res_s = run_scan(pts, d_cut)
    key = f"{seed}_{d}"
    out[key] = {
        "rho_eq_ex": bool(jnp.all(res_d.rho == res_e.rho)),
        "rho_eq_scan": bool(jnp.all(res_d.rho == res_s.rho)),
        "delta_close": bool(jnp.allclose(res_d.delta, res_e.delta,
                                         rtol=1e-5, atol=1e-4)),
        "parent_eq": float((np.asarray(res_d.parent)
                            == np.asarray(res_e.parent)).mean()),
    }

# pallas backend parity: the per-shard dense MXU phases (interpret mode on
# CPU) must reproduce the single-device exact result.  Uniform data keeps
# the expanded-form d2 well conditioned, so equality is exact.
rng = np.random.default_rng(5)
d_cut = 900.0
pts = rng.uniform(0, 30 * d_cut, size=(1200, 3)).astype(np.float32)
res_p = distributed_dpc(pts, DistDPCConfig(d_cut=d_cut,
                                           backend="pallas-interpret"), mesh)
res_r = run_exdpc(pts, d_cut)
res_o = run_scan(jnp.asarray(pts), d_cut)
both_inf = jnp.isinf(res_p.delta) & jnp.isinf(res_r.delta)
out["pallas"] = {
    "rho_eq_ex": bool(jnp.all(res_p.rho == res_r.rho)),
    "rho_eq_scan": bool(jnp.all(res_p.rho == res_o.rho)),
    "delta_close": bool(jnp.all((res_p.delta == res_r.delta) | both_inf)),
    "parent_eq": float((np.asarray(res_p.parent)
                        == np.asarray(res_r.parent)).mean()),
}
print("RESULT" + json.dumps(out))
"""

# Regression pin (runs in CI — deliberately NOT slow-marked): block-sparse
# exec on a MULTI-device mesh must stay exact AND stay *enabled*.  With
# the one-hot ring walk, shard_blocksparse_layout's R1 probe passes on
# multi-partition meshes, so the shard phases run block-sparse worklists —
# this check fails both if the probe silently degrades again (layout flips
# to None) and if the enabled phases ever stop bit-matching run_exdpc
# (which is how the pinned jax-0.4.37 XLA SPMD miscompile manifested).
_BS_GUARD_SCRIPT = r"""
import warnings, json
warnings.filterwarnings("ignore")
import numpy as np, jax, jax.numpy as jnp
from repro.distributed import distributed_dpc
from repro.distributed.dpc import shard_blocksparse_layout
from repro.core.exdpc import run_exdpc
from repro.data.points import gaussian_mixture
from repro.engine import ExecSpec
from repro.engine.planner import plan

mesh = jax.make_mesh((4,), ("data",))
pl = plan(None, ExecSpec(backend="jnp", layout="block-sparse"))
layout = shard_blocksparse_layout(pl, mesh)
pts, _ = gaussian_mixture(1024, k=5, d=2, overlap=0.03, seed=3)
res = distributed_dpc(pts, mesh=mesh, d_cut=2500.0,
                      exec_spec=ExecSpec(backend="jnp",
                                         layout="block-sparse"))
ref = run_exdpc(pts, 2500.0, exec_spec=ExecSpec(backend="jnp"))
binf = jnp.isinf(res.delta) & jnp.isinf(ref.delta)
out = {"bs_multidev": {
    "layout": layout,
    "rho_eq_ex": bool(jnp.all(res.rho == ref.rho)),
    "rho_eq_scan": True,
    "delta_close": bool(jnp.all((res.delta == ref.delta) | binf)),
    "parent_eq": float((np.asarray(res.parent)
                        == np.asarray(ref.parent)).mean()),
}}
print("RESULT" + json.dumps(out))
"""


def _run_subprocess(script: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


@pytest.mark.slow
def test_distributed_matches_exact():
    out = _run_subprocess(_SCRIPT)
    for key, r in out.items():
        assert r["rho_eq_ex"], (key, r)
        assert r["rho_eq_scan"], (key, r)
        assert r["delta_close"], (key, r)
        assert r["parent_eq"] == 1.0, (key, r)


def test_multidev_block_sparse_enabled_and_exact():
    """ISSUE 8 acceptance: per-shard block-sparse on a 4-device mesh is
    *enabled* (the R1 probe passes on the one-hot ring walk, so
    shard_blocksparse_layout returns "block-sparse") and bit-matches
    run_exdpc.  Not slow-marked on purpose: CI must catch both a silent
    probe degrade and a miscompile-shaped divergence."""
    out = _run_subprocess(_BS_GUARD_SCRIPT)
    r = out["bs_multidev"]
    assert r["layout"] == "block-sparse", r
    assert r["rho_eq_ex"] and r["delta_close"] and r["parent_eq"] == 1.0, r


_HALO_SCRIPT = r"""
import warnings, json
warnings.filterwarnings("ignore")
import numpy as np, jax, jax.numpy as jnp
from repro.distributed import distributed_dpc, DistDPCConfig
from repro.core.exdpc import run_exdpc
from repro.kernels import get_backend

rng = np.random.default_rng(5)
d_cut = 900.0
pts = rng.uniform(0, 10 * d_cut, size=(800, 3)).astype(np.float32)
mesh = jax.make_mesh((4,), ("data",))
res_e = run_exdpc(pts, d_cut)
out = {}

# --- pallas-interpret halo: the optimized path must exercise the kernel
#     backend — count the halo-primitive invocations to prove there is no
#     silent jnp fallback ---
be = get_backend("pallas-interpret")
calls = {"rho": 0, "nn": 0}
orig_rc, orig_nn = be.range_count_halo, be.denser_nn_halo
def _rc(*a, **k):
    calls["rho"] += 1
    return orig_rc(*a, **k)
def _nn(*a, **k):
    calls["nn"] += 1
    return orig_nn(*a, **k)
be.range_count_halo, be.denser_nn_halo = _rc, _nn
try:
    res_h = distributed_dpc(pts, DistDPCConfig(
        d_cut=d_cut, strategy="halo", backend="pallas-interpret"), mesh)
finally:
    be.range_count_halo, be.denser_nn_halo = orig_rc, orig_nn
both_inf = jnp.isinf(res_h.delta) & jnp.isinf(res_e.delta)
out["pallas_halo"] = {
    "rho_calls": calls["rho"], "nn_calls": calls["nn"],
    "rho_eq": bool(jnp.all(res_h.rho == res_e.rho)),
    "delta_eq": bool(jnp.all((res_h.delta == res_e.delta) | both_inf)),
    "parent_eq": float((np.asarray(res_h.parent)
                        == np.asarray(res_e.parent)).mean()),
}

# --- jnp halo (the gather-form backend primitives) stays exact too ---
res_j = distributed_dpc(pts, DistDPCConfig(d_cut=d_cut, strategy="halo"),
                        mesh)
both_inf = jnp.isinf(res_j.delta) & jnp.isinf(res_e.delta)
out["jnp_halo"] = {
    "rho_calls": 1, "nn_calls": 1,
    "rho_eq": bool(jnp.all(res_j.rho == res_e.rho)),
    "delta_eq": bool(jnp.all((res_j.delta == res_e.delta) | both_inf)),
    "parent_eq": float((np.asarray(res_j.parent)
                        == np.asarray(res_e.parent)).mean()),
}
print("RESULT" + json.dumps(out))
"""


def test_halo_strategy_runs_kernel_backend():
    """ISSUE 3 acceptance: the halo phases route through the pallas(-interpret)
    backend — kernel primitives actually invoked, results exact vs Ex-DPC."""
    out = _run_subprocess(_HALO_SCRIPT)
    for key, r in out.items():
        assert r["rho_calls"] >= 1 and r["nn_calls"] >= 1, (key, r)
        assert r["rho_eq"], (key, r)
        assert r["delta_eq"], (key, r)
        assert r["parent_eq"] == 1.0, (key, r)
