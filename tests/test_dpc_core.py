"""Correctness of the core DPC algorithms against the O(n^2) Scan oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import DPCConfig, assign_labels, cluster, compute_dpc, rand_index
from repro.core.approxdpc import run_approxdpc
from repro.core.exdpc import run_exdpc
from repro.core.sapproxdpc import run_sapproxdpc
from repro.core.scan import run_scan
from repro.data.points import gaussian_mixture, with_noise


def _dataset(n=1200, k=6, d=2, overlap=0.02, seed=0):
    return gaussian_mixture(n, k=k, d=d, overlap=overlap, seed=seed)


class TestExDPCExactness:
    """Ex-DPC must be bit-identical to the straightforward algorithm."""

    @pytest.mark.parametrize("d,seed", [(2, 0), (3, 1), (4, 2)])
    def test_matches_scan(self, d, seed):
        pts, _ = _dataset(n=900, k=5, d=d, seed=seed)
        d_cut = 4000.0
        sc = run_scan(jnp.asarray(pts), d_cut)
        ex = run_exdpc(pts, d_cut)
        assert bool(jnp.all(sc.rho == ex.rho))
        both_inf = jnp.isinf(sc.delta) & jnp.isinf(ex.delta)
        assert bool(jnp.all((sc.delta == ex.delta) | both_inf))
        assert bool(jnp.all(sc.parent == ex.parent))

    def test_global_peak_has_inf_delta(self):
        pts, _ = _dataset(n=500, seed=3)
        ex = run_exdpc(pts, 3000.0)
        peak = int(jnp.argmax(ex.rho_key))
        assert bool(jnp.isinf(ex.delta[peak]))
        assert int(ex.parent[peak]) == -1
        # exactly one point has no dependent
        assert int(jnp.sum(ex.parent < 0)) == 1

    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 10_000), st.sampled_from([1500.0, 3000.0, 6000.0]),
           st.integers(2, 3))
    def test_property_exactness(self, seed, d_cut, d):
        """Hypothesis sweep: exactness holds across seeds, radii, dims."""
        pts, _ = _dataset(n=400, k=4, d=d, seed=seed)
        sc = run_scan(jnp.asarray(pts), d_cut)
        ex = run_exdpc(pts, d_cut)
        assert bool(jnp.all(sc.rho == ex.rho))
        both_inf = jnp.isinf(sc.delta) & jnp.isinf(ex.delta)
        assert bool(jnp.all((sc.delta == ex.delta) | both_inf))


class TestApproxDPC:
    """Theorem 4: Approx-DPC yields identical cluster centers to Ex-DPC."""

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_exact_rho(self, seed):
        pts, _ = _dataset(seed=seed)
        sc = run_scan(jnp.asarray(pts), 3000.0)
        ap = run_approxdpc(pts, 3000.0)
        assert bool(jnp.all(sc.rho == ap.rho))

    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 10_000), st.sampled_from([2000.0, 4000.0]))
    def test_center_guarantee(self, seed, d_cut):
        pts, _ = _dataset(n=800, seed=seed)
        ex = run_exdpc(pts, d_cut)
        ap = run_approxdpc(pts, d_cut)
        for delta_min in (1.5 * d_cut, 2.5 * d_cut):
            le = assign_labels(ex, 5.0, delta_min)
            la = assign_labels(ap, 5.0, delta_min)
            assert bool(jnp.all(le.centers == la.centers))

    def test_approx_delta_never_exceeds_dcut_unless_exact(self):
        """Resolved points report d_cut; only stem roots exceed it (exactly)."""
        pts, _ = _dataset(seed=4)
        d_cut = 3000.0
        ap = run_approxdpc(pts, d_cut)
        ex = run_exdpc(pts, d_cut)
        over = np.asarray(ap.delta) > d_cut
        # every over-d_cut delta is the exact one
        ex_d = np.asarray(ex.delta)
        ap_d = np.asarray(ap.delta)
        assert np.allclose(ap_d[over], ex_d[over], rtol=1e-6, atol=1e-6)

    def test_high_accuracy_vs_exact(self):
        pts, _ = _dataset(n=2000, k=8, seed=5)
        d_cut = 2500.0
        ex = run_exdpc(pts, d_cut)
        ap = run_approxdpc(pts, d_cut)
        le = assign_labels(ex, 5.0, 5000.0)
        la = assign_labels(ap, 5.0, 5000.0)
        assert rand_index(np.asarray(la.labels), np.asarray(le.labels)) > 0.95


class TestSApproxDPC:
    @pytest.mark.parametrize("eps", [0.2, 0.5, 1.0])
    def test_reasonable_accuracy(self, eps):
        pts, _ = _dataset(n=2000, k=8, seed=6)
        d_cut = 2500.0
        ex = run_exdpc(pts, d_cut)
        sa = run_sapproxdpc(pts, d_cut, eps=eps)
        le = assign_labels(ex, 5.0, 5000.0)
        ls = assign_labels(sa, 5.0, 5000.0)
        assert rand_index(np.asarray(ls.labels), np.asarray(le.labels)) > 0.9

    def test_smaller_eps_more_accurate_or_equal(self):
        pts, _ = _dataset(n=2000, k=8, seed=7)
        d_cut = 2500.0
        ex = run_exdpc(pts, d_cut)
        le = assign_labels(ex, 5.0, 5000.0)
        ris = []
        for eps in (0.2, 1.0):
            sa = run_sapproxdpc(pts, d_cut, eps=eps)
            ls = assign_labels(sa, 5.0, 5000.0)
            ris.append(rand_index(np.asarray(ls.labels), np.asarray(le.labels)))
        assert ris[0] >= ris[1] - 0.02  # paper Table 5 trend (with slack)

    def test_members_never_centers(self):
        pts, _ = _dataset(n=1500, seed=8)
        sa = run_sapproxdpc(pts, 2500.0, eps=1.0)
        ls = assign_labels(sa, 5.0, 5000.0)
        # centers must be representatives: their delta came from phases 1/2
        centers = np.asarray(ls.centers)
        deltas = np.asarray(sa.delta)
        assert np.all(deltas[centers] >= 5000.0)


class TestAPI:
    def test_cluster_end_to_end(self):
        pts, gt = _dataset(n=1500, k=6, seed=9)
        cfg = DPCConfig(d_cut=2500.0, rho_min=5.0, delta_min=6000.0,
                        algorithm="approxdpc")
        out, res = cluster(pts, cfg)
        assert out.labels.shape == (1500,)
        assert int(out.num_clusters) >= 4
        assert rand_index(np.asarray(out.labels), gt) > 0.9

    def test_delta_min_validation(self):
        with pytest.raises(ValueError):
            DPCConfig(d_cut=100.0, delta_min=50.0).resolved_delta_min()

    @pytest.mark.parametrize("algo", ["scan", "exdpc", "approxdpc",
                                      "sapproxdpc", "lsh_ddp", "cfsfdp_a"])
    def test_all_algorithms_run(self, algo):
        pts, _ = _dataset(n=600, k=4, seed=10)
        cfg = DPCConfig(d_cut=3000.0, algorithm=algo)
        res = compute_dpc(pts, cfg)
        assert res.rho.shape == (600,)
        assert bool(jnp.all(res.rho >= 1))  # self-count
        assert not bool(jnp.any(jnp.isnan(res.delta)))


class TestNoiseRobustness:
    """Table 2: accuracy stays high under increasing noise rate."""

    def test_noise_sweep(self):
        base, gt = _dataset(n=1500, k=6, overlap=0.012, seed=11)
        for rate in (0.02, 0.08):
            pts, labels = with_noise(base, gt, rate, seed=12)
            d_cut = 2500.0
            ex = run_exdpc(pts, d_cut)
            ap = run_approxdpc(pts, d_cut)
            le = assign_labels(ex, 10.0, 5000.0)
            la = assign_labels(ap, 10.0, 5000.0)
            assert rand_index(np.asarray(la.labels), np.asarray(le.labels)) > 0.93
