"""Training substrate: optimizer, schedule, microbatching, checkpointing."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.train import (AdamWConfig, TrainStepConfig, adamw_init,
                         adamw_update, make_train_step, warmup_cosine)
from repro.train import checkpoint as ckpt


def toy_params(key=0):
    k = jax.random.PRNGKey(key)
    k1, k2 = jax.random.split(k)
    return {"w": jax.random.normal(k1, (8, 4), jnp.float32),
            "b": jnp.zeros((4,), jnp.bfloat16)}


def toy_loss(params, batch, rules=None):
    x, y = batch["x"], batch["y"]
    pred = x @ params["w"] + params["b"].astype(jnp.float32)
    return jnp.mean((pred - y) ** 2)


def toy_batch(n=16, key=1):
    k = jax.random.PRNGKey(key)
    kx, ky = jax.random.split(k)
    return {"x": jax.random.normal(kx, (n, 8), jnp.float32),
            "y": jax.random.normal(ky, (n, 4), jnp.float32)}


class TestOptimizer:
    def test_masters_are_f32(self):
        state = adamw_init(toy_params())
        assert state["master"]["b"].dtype == jnp.float32

    def test_update_descends(self):
        params = toy_params()
        state = adamw_init(params)
        batch = toy_batch()
        cfg = AdamWConfig(weight_decay=0.0)
        for _ in range(20):
            loss, grads = jax.value_and_grad(toy_loss)(params, batch)
            params, state, gnorm = adamw_update(grads, state, params,
                                                1e-2, cfg)
        assert float(toy_loss(params, batch)) < float(
            toy_loss(toy_params(), batch))

    def test_grad_clip_bounds_update(self):
        params = toy_params()
        state = adamw_init(params)
        huge = jax.tree.map(lambda p: jnp.full_like(p, 1e9), params)
        cfg = AdamWConfig(grad_clip=1.0, weight_decay=0.0)
        new, state, gnorm = adamw_update(huge, state, params, 1e-3, cfg)
        assert float(gnorm) > 1e8
        delta = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), new, params)
        assert max(jax.tree.leaves(delta)) < 1.0   # lr-scale steps only

    def test_param_dtype_preserved(self):
        params = toy_params()
        state = adamw_init(params)
        loss, grads = jax.value_and_grad(toy_loss)(params, toy_batch())
        new, _, _ = adamw_update(grads, state, params, 1e-3, AdamWConfig())
        assert new["b"].dtype == jnp.bfloat16


class TestSchedule:
    def test_warmup_then_decay(self):
        lr0 = float(warmup_cosine(0, peak_lr=1.0, warmup_steps=10,
                                  total_steps=100))
        lr_peak = float(warmup_cosine(10, peak_lr=1.0, warmup_steps=10,
                                      total_steps=100))
        lr_end = float(warmup_cosine(100, peak_lr=1.0, warmup_steps=10,
                                     total_steps=100))
        assert lr0 == 0.0
        assert lr_peak == pytest.approx(1.0)
        assert lr_end == pytest.approx(0.1, rel=1e-3)


class TestMicrobatching:
    def test_equivalent_to_full_batch(self):
        """Grad accumulation must match the single-shot gradient."""
        params = toy_params()
        batch = toy_batch(n=16)
        outs = {}
        for mb in (1, 4):
            step = make_train_step(toy_loss, TrainStepConfig(
                peak_lr=1e-2, warmup_steps=0, total_steps=10,
                microbatches=mb))
            p, s, m = step(params, adamw_init(params), batch, jnp.int32(1))
            outs[mb] = (jax.tree.leaves(p), float(m["loss"]))
        for a, b in zip(outs[1][0], outs[4][0]):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-5, atol=1e-6)
        assert outs[1][1] == pytest.approx(outs[4][1], rel=1e-5)


class TestCheckpoint:
    def test_roundtrip_atomic(self, tmp_path):
        tree = (toy_params(), adamw_init(toy_params()))
        d = str(tmp_path / "ck")
        ckpt.save(d, 3, tree, extras={"step": 3, "cursor": 17})
        assert ckpt.latest_step(d) == 3
        like = jax.eval_shape(lambda: tree)
        restored, extras = ckpt.restore(d, 3, like)
        assert extras["cursor"] == 17
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32))
            assert a.dtype == b.dtype   # bf16 survives the npy round-trip

    def test_tmp_dirs_ignored_and_gced(self, tmp_path):
        d = str(tmp_path / "ck")
        os.makedirs(os.path.join(d, "step_9.tmp"))
        assert ckpt.latest_step(d) is None
        ckpt.save(d, 1, {"w": jnp.ones((2,))})
        assert not any(n.endswith(".tmp") for n in os.listdir(d))

    def test_latest_of_many(self, tmp_path):
        d = str(tmp_path / "ck")
        for s in (1, 5, 3):
            ckpt.save(d, s, {"w": jnp.ones((2,)) * s})
        assert ckpt.latest_step(d) == 5

    def test_restore_rejects_wrong_shape(self, tmp_path):
        d = str(tmp_path / "ck")
        ckpt.save(d, 0, {"w": jnp.ones((4,))})
        with pytest.raises(AssertionError):
            ckpt.restore(d, 0, {"w": jax.ShapeDtypeStruct((8,),
                                                          jnp.float32)})


class TestPipeline:
    def test_deterministic_and_restorable(self):
        from repro.configs import ARCHS, reduce_config
        from repro.data.tokens import TokenPipeline
        cfg = reduce_config(ARCHS["gemma-2b"])
        p1 = TokenPipeline(cfg, batch=2, seq_len=32, seed=7)
        b0 = next(p1)
        b1 = next(p1)
        p2 = TokenPipeline(cfg, batch=2, seq_len=32, seed=7)
        p2.load_state_dict({"seed": 7, "cursor": 1})
        b1_replay = next(p2)
        np.testing.assert_array_equal(b1["tokens"], b1_replay["tokens"])
        assert not np.array_equal(b0["tokens"], b1["tokens"])

    def test_fixed_shapes(self):
        from repro.configs import ARCHS, reduce_config
        from repro.data.tokens import TokenPipeline
        for arch in ("hubert-xlarge", "paligemma-3b", "qwen3-moe-30b-a3b"):
            cfg = reduce_config(ARCHS[arch])
            p = TokenPipeline(cfg, batch=2, seq_len=32)
            shapes = [jax.tree.map(lambda a: a.shape, next(p))
                      for _ in range(3)]
            assert shapes[0] == shapes[1] == shapes[2]
