"""repro.obs acceptance: tracing, metrics and kernel telemetry.

The ISSUE 7 contract:

* **Off is free** — at the default level, ``obs.span()`` returns one shared
  null singleton (no allocation, no recording) and ``sync`` is the
  identity.
* **Spans nest** — paths/parents/depths follow the runtime call tree; a
  traced ``DPCEngine.fit`` emits the engine/driver/labeling phase tree
  with fenced device times; traces round-trip through the JSONL file and
  the ``python -m repro.obs report`` CLI.
* **Metrics migrate** — the planner plan-cache, blocksparse worklist,
  stream tick and serve query-status counters live on the registry while
  the legacy read surfaces (``plan_cache_info``, ``worklist_build_count``,
  ``StreamDPC.stats``) keep their exact semantics.
* **Plan telemetry** — ``DPCPlan.telemetry()`` reports the resolved axes,
  pad waste and worklist cache; ``include_cost=True`` adds the hlo_cost
  flop/byte estimate.
"""
import json

import numpy as np
import pytest
import jax.numpy as jnp

from repro import obs
from repro.engine import DPCEngine, ExecSpec, as_plan
from repro.kernels import blocksparse
from repro.obs import report as obs_report
from repro.obs.__main__ import main as obs_main
from repro.stream import QueryStatus


@pytest.fixture(autouse=True)
def _clean_obs():
    obs.configure(level="off", trace_path=None)
    obs.reset_spans()
    yield
    obs.configure(level="off", trace_path=None)
    obs.reset_spans()


def _blobs(n=256, d=2, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0, 6000.0, (4, d))
    pts = (centers[rng.integers(0, 4, n)]
           + rng.normal(0, 150.0, (n, d))).astype(np.float32)
    return pts


# --------------------------------------------------------------- tracer
class TestTracer:
    def test_off_returns_null_singleton(self):
        s1 = obs.span("a", n=3)
        s2 = obs.span("b")
        assert s1 is obs.NULL_SPAN and s2 is obs.NULL_SPAN
        x = object()
        with s1 as sp:
            assert sp.sync(x) is x
            sp.set(ignored=1)
        assert obs.spans() == []

    def test_metrics_level_host_time_only(self):
        obs.configure(level="metrics")
        with obs.span("phase", n=7):
            pass
        (rec,) = obs.spans()
        assert rec["name"] == "phase" and rec["path"] == "phase"
        assert rec["host_s"] >= 0.0
        assert rec["device_s"] is None
        assert rec["attrs"] == {"n": 7}

    def test_trace_level_fences_device_time(self):
        obs.configure(level="trace")
        with obs.span("compute") as sp:
            out = sp.sync(jnp.arange(1024.0).sum())
        assert float(out) == 1024.0 * 1023.0 / 2.0
        (rec,) = obs.spans()
        assert rec["device_s"] is not None and rec["device_s"] >= 0.0
        assert rec["host_s"] >= rec["device_s"]

    def test_nesting_paths_and_parents(self):
        obs.configure(level="metrics")
        with obs.span("outer"):
            with obs.span("mid"):
                with obs.span("inner"):
                    pass
        recs = {r["name"]: r for r in obs.spans()}
        assert recs["outer"]["path"] == "outer"
        assert recs["mid"]["path"] == "outer/mid"
        assert recs["inner"]["path"] == "outer/mid/inner"
        assert recs["inner"]["depth"] == 2
        assert recs["mid"]["parent"] == recs["outer"]["id"]

    def test_exception_closes_span(self):
        obs.configure(level="metrics")
        with pytest.raises(RuntimeError):
            with obs.span("boom"):
                raise RuntimeError("x")
        (rec,) = obs.spans()
        assert rec["error"] == "RuntimeError"
        # the stack unwound: a fresh span is a root again
        with obs.span("after"):
            pass
        assert obs.spans()[-1]["path"] == "after"

    def test_jsonl_roundtrip(self, tmp_path):
        path = str(tmp_path / "trace.jsonl")
        obs.configure(level="trace", trace_path=path)
        with obs.span("a", n=1):
            with obs.span("b"):
                pass
        obs.flush()
        obs.configure(trace_path=None)
        recs = obs_report.load_trace(path)
        assert [r["path"] for r in recs] == ["a/b", "a"]
        assert all({"id", "host_s", "t0", "depth"} <= set(r) for r in recs)

    def test_configure_rejects_bad_level(self):
        with pytest.raises(ValueError, match="level"):
            obs.configure(level="verbose")


# -------------------------------------------------------------- metrics
class TestMetrics:
    def test_counter_labels_and_total(self):
        c = obs.counter("t_obs_counter")
        c._reset()
        c.inc()
        c.inc(3, kind="x")
        c.inc(2, kind="x")
        assert c.value() == 1
        assert c.value(kind="x") == 5
        assert c.total() == 6
        assert c.series() == {"": 1, "kind=x": 5}

    def test_gauge_and_histogram(self):
        g = obs.gauge("t_obs_gauge")
        g.set(0.25)
        g.set(0.5)
        assert g.value() == 0.5
        h = obs.histogram("t_obs_hist")
        h._reset()
        for v in (1.0, 3.0, 2.0):
            h.observe(v)
        assert h.stats() == {"count": 3, "sum": 6.0, "min": 1.0, "max": 3.0}
        assert h.stats(missing="yes") is None

    def test_registry_get_or_register(self):
        a = obs.counter("t_obs_same")
        b = obs.counter("t_obs_same")
        assert a is b
        with pytest.raises(TypeError, match="already registered"):
            obs.gauge("t_obs_same")
        assert obs.get_metric("t_obs_same") is a

    def test_snapshot_and_reset(self):
        c = obs.counter("t_obs_snap")
        c._reset()
        c.inc(4)
        snap = obs.metrics_snapshot()
        assert snap["t_obs_snap"]["kind"] == "counter"
        assert snap["t_obs_snap"]["values"] == {"": 4}
        c._reset()
        assert obs.metrics_snapshot()["t_obs_snap"]["values"] == {}

    def test_suspend_counters_restores_worklist_metrics(self):
        builds = obs.get_metric("worklist_builds")
        before = builds.value()
        with blocksparse.suspend_counters():
            builds.inc(17)
            assert builds.value() == before + 17
        assert builds.value() == before
        assert blocksparse.worklist_build_count() == int(before)


# ------------------------------------------------------ engine tracing
class TestEngineTracing:
    def test_fit_emits_phase_tree_with_device_times(self):
        pts = _blobs(256)
        eng = DPCEngine(d_cut=300.0, algorithm="approxdpc",
                        exec_spec=ExecSpec(backend="jnp",
                                           layout="block-sparse"))
        obs.configure(level="trace")
        eng.fit(pts)
        paths = {r["path"] for r in obs.spans()}
        assert {"engine.fit", "engine.fit/approxdpc.grid",
                "engine.fit/approxdpc.rho_delta",
                "engine.fit/approxdpc.rules",
                "engine.fit/labels.assign"} <= paths
        phases = obs_report.aggregate(obs.spans())
        assert phases["engine.fit/approxdpc.rho_delta"]["device_s"] is not None
        root = phases["engine.fit"]
        child = sum(r["host_s"] for p, r in phases.items()
                    if p.startswith("engine.fit/"))
        assert child <= root["host_s"] + 1e-6

    def test_fit_off_emits_nothing(self):
        pts = _blobs(128)
        DPCEngine(d_cut=300.0).fit(pts)
        assert obs.spans() == []

    def test_predict_spans_and_serve_status_counters(self):
        pts = _blobs(256)
        eng = DPCEngine(d_cut=300.0).fit(pts)
        calls = obs.get_metric("serve_query_calls")
        points = obs.get_metric("serve_query_points")
        c0, p0 = calls.value(), points.total()
        obs.configure(level="metrics")
        out = eng.predict(pts[:17])
        assert {"engine.predict", "engine.predict/serve.query"} <= {
            r["path"] for r in obs.spans()}
        assert calls.value() == c0 + 1
        assert points.total() == p0 + 17
        # fitted points queried back are coverage hits
        assert points.value(status=QueryStatus.HIT.name) > 0
        assert (out.status == int(QueryStatus.HIT)).all()

    def test_stream_metrics_dual_write(self):
        from repro.stream import StreamDPC, StreamDPCConfig

        ticks = obs.get_metric("stream_ticks")
        t0 = ticks.value()
        s = StreamDPC(StreamDPCConfig(d_cut=300.0, capacity=64,
                                      batch_cap=32))
        s.ingest(_blobs(64, seed=1))     # fills the window
        s.ingest(_blobs(32, seed=2))     # steady-state tick
        assert ticks.value() >= t0 + 3
        st = s.stats()
        assert st["ticks"] == 3          # legacy dict unchanged
        assert st["nn_queries"] <= st["nn_maxima_total"]


# -------------------------------------------------------- plan telemetry
class TestPlanTelemetry:
    def test_static_axes_and_pad(self):
        pts = _blobs(200)
        pl = as_plan(ExecSpec(backend="jnp", layout="block-sparse"),
                     jnp.asarray(pts))
        t = pl.telemetry()
        assert t["backend"] == "jnp"
        assert t["layout"] == "block-sparse"
        assert t["worklist_strategy"] == "traced"
        assert t["shape"] == {"n": 200, "d": 2}
        pad = t["pad"]
        assert pad["row_block"] == blocksparse.BS_BLOCK_N
        assert pad["padded_n"] % pad["row_block"] == 0
        assert 0.0 <= pad["pad_waste_frac"] < 1.0
        assert t["worklists"]["strategy"] == "traced"
        assert "hlo_cost" not in t

    def test_cost_estimate_cached(self):
        pts = _blobs(128)
        pl = as_plan(ExecSpec(backend="jnp"), jnp.asarray(pts))
        builds0 = blocksparse.worklist_build_count()
        cost = pl.telemetry(include_cost=True)["hlo_cost"]
        assert cost["formulation"] == "dense"
        assert cost.get("flops", 0) > 0
        # compiled once, cached after
        assert pl.telemetry(include_cost=True)["hlo_cost"] is cost or \
            pl.telemetry(include_cost=True)["hlo_cost"] == cost
        # probe compilation left the worklist counters untouched
        assert blocksparse.worklist_build_count() == builds0


# --------------------------------------------------------------- report
class TestReport:
    def _recs(self):
        return [
            {"name": "fit", "path": "fit", "id": 1, "parent": None,
             "depth": 0, "t0": 0.0, "host_s": 1.0, "device_s": 0.6},
            {"name": "rho", "path": "fit/rho", "id": 2, "parent": 1,
             "depth": 1, "t0": 0.1, "host_s": 0.7, "device_s": 0.5},
        ]

    def test_aggregate_self_time(self):
        phases = obs_report.aggregate(self._recs())
        assert phases["fit"]["self_s"] == pytest.approx(0.3)
        assert phases["fit/rho"]["host_s"] == pytest.approx(0.7)
        assert phases["fit/rho"]["device_s"] == pytest.approx(0.5)

    def test_render_table_and_metrics(self):
        table = obs_report.render_table(obs_report.aggregate(self._recs()))
        assert "fit" in table and "rho" in table and "%run" in table
        assert obs_report.render_table({}) == "(no spans recorded)"
        text = obs_report.render_metrics(
            {"c": {"kind": "counter", "help": "", "values": {"": 3}}})
        assert "c = 3" in text

    def test_snapshot_schema(self):
        snap = obs_report.build_snapshot(self._recs(), {})
        assert snap["schema"] == "repro.obs/1"
        assert "fit/rho" in snap["phases"]

    def test_cli_report(self, tmp_path, capsys):
        trace = tmp_path / "t.jsonl"
        trace.write_text("".join(json.dumps(r) + "\n" for r in self._recs()))
        mpath = tmp_path / "m.json"
        mpath.write_text(json.dumps(
            {"plan_cache_hits": {"kind": "counter", "help": "",
                                 "values": {"": 2}}}))
        out = tmp_path / "snap.json"
        rc = obs_main(["report", "--trace", str(trace), "--metrics",
                       str(mpath), "--json", str(out)])
        assert rc == 0
        printed = capsys.readouterr().out
        assert "rho" in printed and "plan_cache_hits = 2" in printed
        snap = json.loads(out.read_text())
        assert snap["schema"] == "repro.obs/1"
        assert snap["metrics"]["plan_cache_hits"]["values"][""] == 2
