"""DPC-KV cache compression: shapes, mass preservation, and accuracy vs a
random-eviction baseline on clustered keys (where density peaks matter)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.serve.dpc_kv import (DPCKVConfig, attend_compressed, compress_kv)


def clustered_cache(B=2, S=512, K=2, hd=32, modes=6, seed=0):
    """Keys drawn around a few attention modes + matching values."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 1, (modes, hd)).astype(np.float32) * 3
    assign = rng.integers(0, modes, (B, S, K))
    k = centers[assign] + rng.normal(0, 0.15, (B, S, K, hd))
    v = centers[assign] * 0.5 + rng.normal(0, 0.05, (B, S, K, hd))
    return (jnp.asarray(k, jnp.float32), jnp.asarray(v, jnp.float32),
            jnp.asarray(centers))


def full_attention(q, k, v):
    B, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd)
    logits = jnp.einsum("bkgh,bskh->bkgs", qg, k) * hd ** -0.5
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, v)
    return out.reshape(B, H, hd)


class TestCompressKV:
    def test_shapes_and_counts(self):
        k, v, _ = clustered_cache()
        cfg = DPCKVConfig(budget=32)
        kc, vc, counts = compress_kv(k, v, jnp.int32(512), cfg)
        assert kc.shape == (2, 32, 2, 32)
        assert vc.shape == (2, 32, 2, 32)
        assert counts.shape == (2, 32, 2)
        # every valid position lands in at most one cluster
        assert float(counts.sum()) <= 2 * 512 * 2

    def test_respects_valid_length(self):
        k, v, _ = clustered_cache()
        cfg = DPCKVConfig(budget=16)
        _, _, c_full = compress_kv(k, v, jnp.int32(512), cfg)
        _, _, c_half = compress_kv(k, v, jnp.int32(256), cfg)
        assert float(c_half.sum()) <= float(c_full.sum())
        assert float(c_half.sum()) <= 2 * 256 * 2

    def test_better_than_random_eviction(self):
        """On clustered keys, DPC-KV must beat random keeping at equal
        budget for attention-output fidelity."""
        k, v, _ = clustered_cache(seed=3)
        B, S, K, hd = k.shape
        q = jnp.asarray(np.random.default_rng(1).normal(0, 1, (B, 4, hd)),
                        jnp.float32)
        ref = full_attention(q, k, v)

        cfg = DPCKVConfig(budget=48)
        kc, vc, counts = compress_kv(k, v, jnp.int32(S), cfg)
        got = attend_compressed(q, kc, vc, counts)
        err_dpc = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))

        rng = np.random.default_rng(0)
        keep = rng.choice(S, 48, replace=False)
        kr, vr = k[:, keep], v[:, keep]
        cnt_r = jnp.ones((B, 48, K))
        got_r = attend_compressed(q, kr, vr, cnt_r)
        err_rand = float(jnp.linalg.norm(got_r - ref) / jnp.linalg.norm(ref))
        assert err_dpc < err_rand, (err_dpc, err_rand)
        assert err_dpc < 0.25, err_dpc
