"""Streaming DPC: incremental sliding-window parity, rebuilds, continuity.

Acceptance contract (ISSUE 2): after any sequence of ingest/evict batches,
``StreamDPC`` rho/delta/parent and the derived centers/labels equal a
from-scratch ``run_approxdpc`` + ``assign_labels`` on the current window
contents — per backend, including ``pallas-interpret``.  Data follows the
repo's threshold convention (drawn away from d_cut boundaries by being
generically positioned; fixed seeds keep runs deterministic).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.approxdpc import run_approxdpc
from repro.core.labels import assign_labels
from repro.data.points import drifting_batches, gaussian_mixture
from repro.engine import ExecSpec
from repro.stream import (StreamDPC, StreamDPCConfig, StreamServeConfig,
                          StreamService)
from repro.stream.window import SlidingWindow

CAP, B, D_CUT, RHO_MIN = 512, 64, 8000.0, 3.0


def _cfg(backend="jnp", **kw):
    base = dict(d_cut=D_CUT, capacity=CAP, batch_cap=B, rho_min=RHO_MIN,
                exec_spec=ExecSpec(backend=backend))
    base.update(kw)
    return StreamDPCConfig(**base)


def _assert_parity(s: StreamDPC, backend):
    w = jnp.asarray(s.window_points())
    fresh = run_approxdpc(w, s.cfg.d_cut,
                          exec_spec=ExecSpec(backend=backend))
    res = s.result
    assert bool(jnp.all(fresh.rho == res.rho)), "rho diverged"
    assert bool(jnp.all(fresh.parent == res.parent)), "parent diverged"
    both_inf = jnp.isinf(fresh.delta) & jnp.isinf(res.delta)
    assert bool(jnp.all((fresh.delta == res.delta) | both_inf)), "delta"
    cl = assign_labels(fresh, s.cfg.rho_min, s.cfg.resolved_delta_min())
    assert bool(jnp.all(cl.centers == s.clustering.centers)), "centers"
    assert bool(jnp.all(cl.labels == s.clustering.labels)), "labels"


class TestIncrementalParity:
    """The headline acceptance: stream == from-scratch, every tick."""

    @pytest.mark.parametrize("backend", ["jnp", "pallas-interpret"])
    def test_matches_fresh_approxdpc(self, backend):
        ticks = 3 if backend == "pallas-interpret" else 6
        pts, _ = gaussian_mixture(CAP + ticks * B, k=5, d=2, overlap=0.05,
                                  seed=3)
        s = StreamDPC(_cfg(backend))
        s.initialize(pts[:CAP])
        for t in range(ticks):
            s.ingest(pts[CAP + t * B: CAP + (t + 1) * B])
            _assert_parity(s, backend)

    def test_partial_and_oversize_batches(self):
        """Variable request sizes: padding discipline keeps repairs exact."""
        pts, _ = gaussian_mixture(CAP + 200, k=4, d=2, overlap=0.05, seed=5)
        s = StreamDPC(_cfg())
        s.initialize(pts[:CAP])
        s.ingest(pts[CAP: CAP + 17])          # r << batch_cap
        _assert_parity(s, "jnp")
        s.ingest(pts[CAP + 17: CAP + 200])    # r > batch_cap -> chunks
        _assert_parity(s, "jnp")

    def test_warmup_then_steady(self):
        """Fill through ingest only (no bulk initialize): full recomputes
        during warm-up, incremental repairs once at capacity."""
        pts, _ = gaussian_mixture(CAP + 2 * B, k=4, d=2, overlap=0.05, seed=6)
        s = StreamDPC(_cfg())
        for i in range(0, CAP + 2 * B, B):
            s.ingest(pts[i: i + B])
        assert s.window.full
        assert s.stats()["full_recomputes"] == CAP // B
        _assert_parity(s, "jnp")

    def test_rho_never_drifts_over_many_ticks(self):
        """Counts are exact integers in f32: long runs cannot accumulate
        float error in the repaired densities."""
        pts, _ = gaussian_mixture(CAP + 12 * B, k=5, d=2, overlap=0.04,
                                  seed=9)
        s = StreamDPC(_cfg())
        s.initialize(pts[:CAP])
        for t in range(12):
            s.ingest(pts[CAP + t * B: CAP + (t + 1) * B])
        _assert_parity(s, "jnp")


class TestRebuildFallback:
    """Measured-capacity overflow -> full grid rebuild, parity preserved."""

    def test_drift_triggers_rebuild(self):
        rng = np.random.default_rng(0)
        pts, _ = gaussian_mixture(CAP, k=4, d=2, overlap=0.05, seed=1)
        s = StreamDPC(_cfg(extent_margin=1, cell_slack=1.0))
        s.initialize(pts)
        rebuilt = 0
        for t in range(8):
            center = np.array([9e4, 9e4]) + t * 3000.0
            batch = rng.normal(center, 2000.0, (B, 2)).astype(np.float32)
            tick = s.ingest(batch)
            rebuilt += tick.rebuilt
            _assert_parity(s, "jnp")
        assert rebuilt >= 1, "drift never overflowed the measured box"
        assert s.stats()["rebuilds"] == rebuilt

    def test_density_collapse_triggers_cell_overflow(self):
        """Scatter into many new cells -> live cells exceed the measured
        budget (tight slack) -> rebuild instead of a wrong answer."""
        rng = np.random.default_rng(2)
        pts = rng.normal(5e4, 1500.0, (CAP, 2)).astype(np.float32)
        s = StreamDPC(_cfg(cell_slack=1.0, extent_margin=32))
        s.initialize(pts)
        rebuilt = 0
        for _ in range(3):
            spread = rng.uniform(1e4, 9e4, (B, 2)).astype(np.float32)
            rebuilt += s.ingest(spread).rebuilt
            _assert_parity(s, "jnp")
        assert rebuilt >= 1, "cell spawning never overflowed the budget"


class TestContinuity:
    """Stable center ids persist while the underlying clusters persist."""

    def test_stable_ids_survive_mild_drift(self):
        pts, _ = gaussian_mixture(CAP + 6 * B, k=3, d=2, overlap=0.02, seed=4)
        s = StreamDPC(_cfg())
        s.initialize(pts[:CAP])
        first = set(int(x) for x in s._last.stable_ids)
        for t in range(6):
            tick = s.ingest(pts[CAP + t * B: CAP + (t + 1) * B])
            ids = set(int(x) for x in tick.stable_ids)
            # same population refreshing -> same clusters -> ids carry over
            assert ids == first

    def test_new_cluster_gets_fresh_id(self):
        rng = np.random.default_rng(8)
        pts, _ = gaussian_mixture(CAP, k=2, d=2, overlap=0.01, seed=7)
        s = StreamDPC(_cfg(rho_min=3.0))
        s.initialize(pts)
        before = set(int(x) for x in s._last.stable_ids)
        # inject a brand-new dense blob far from existing clusters
        blob = rng.normal([1000.0, 1000.0], 500.0, (2 * B, 2)) \
            .astype(np.float32)
        tick = s.ingest(blob)
        after = set(int(x) for x in tick.stable_ids)
        assert after - before, "new cluster did not receive a fresh id"


_SHARDED_SCRIPT = r"""
import warnings, json
warnings.filterwarnings("ignore")
import numpy as np, jax, jax.numpy as jnp
from repro.core.approxdpc import run_approxdpc
from repro.core.labels import assign_labels
from repro.data.points import gaussian_mixture
from repro.engine import ExecSpec
from repro.stream import StreamDPC, StreamDPCConfig

assert jax.device_count() == 4
cap, B, d_cut = 512, 64, 8000.0
mesh = jax.make_mesh((2, 2), ("data", "model"))   # flattens to 4 shards
out = {}
for layout in (None, "block-sparse"):
    def mk(m):
        return StreamDPC(StreamDPCConfig(
            d_cut=d_cut, capacity=cap, batch_cap=B, rho_min=3.0,
            exec_spec=ExecSpec(backend="jnp", layout=layout)), mesh=m)
    pts, _ = gaussian_mixture(cap + 3 * B, k=4, d=2, overlap=0.05, seed=2)
    s = mk(mesh)        # every repair-tail stage sharded over 4 devices
    r = mk(None)        # the replicated predecessor of each stage
    s.initialize(pts[:cap]); r.initialize(pts[:cap])
    ok = True
    for t in range(3):
        ts = s.ingest(pts[cap + t * B: cap + (t + 1) * B])
        tr = r.ingest(pts[cap + t * B: cap + (t + 1) * B])
        fresh = run_approxdpc(jnp.asarray(s.window_points()), d_cut,
                              exec_spec=ExecSpec(backend="jnp"))
        ok &= bool(jnp.all(fresh.rho == s.result.rho))
        # sharded maxima-NN re-query == replicated denser_nn_update
        ok &= bool(jnp.all(fresh.parent == s.result.parent))
        both = jnp.isinf(fresh.delta) & jnp.isinf(s.result.delta)
        ok &= bool(jnp.all((fresh.delta == s.result.delta) | both))
        # sharded one-hot label propagation == replicated pointer jumping
        cl = assign_labels(fresh, 3.0, 2 * d_cut)
        ok &= bool(jnp.all(cl.labels == s.clustering.labels))
        ok &= bool(jnp.all(cl.centers == s.clustering.centers))
        # sharded center-distance matrix == numpy greedy-matching input
        ok &= bool(np.array_equal(ts.labels, tr.labels))
        ok &= bool(np.array_equal(ts.stable_ids, tr.stable_ids))
    stages = (s._sharded is not None and s._sharded_nn is not None
              and s._sharded_labels is not None
              and s._sharded_cdist is not None)
    out[layout or "dense"] = {"parity": ok, "stages_built": stages}
print("RESULT" + json.dumps(out))
"""


class TestShardedIngest:
    """Window partitioned over the mesh (flatten_mesh), bit-equal repair."""

    def test_sharded_single_device_path(self):
        """In-process coverage of the shard_map code path (1-device mesh);
        the real 4-shard run is the subprocess test below."""
        mesh = jax.make_mesh((1,), ("data",))
        pts, _ = gaussian_mixture(CAP + 2 * B, k=4, d=2, overlap=0.05, seed=2)
        s = StreamDPC(_cfg(), mesh=mesh)
        s.initialize(pts[:CAP])
        for t in range(2):
            s.ingest(pts[CAP + t * B: CAP + (t + 1) * B])
        _assert_parity(s, "jnp")

    @pytest.mark.slow
    def test_sharded_multi_device(self):
        """4 fake host devices (subprocess: XLA_FLAGS must precede jax
        init): the whole repair tail — rho repair, maxima NN re-query,
        label propagation, center matching — runs sharded, bit-equal to
        both the replicated stream and a from-scratch run_approxdpc +
        assign_labels, on dense and block-sparse layouts."""
        import json as _json
        import os
        import subprocess
        import sys

        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                         "src")
        env["JAX_PLATFORMS"] = "cpu"
        proc = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                              env=env, capture_output=True, text=True,
                              timeout=900)
        assert proc.returncode == 0, proc.stderr[-3000:]
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("RESULT")][0]
        out = _json.loads(line[len("RESULT"):])
        for layout, r in out.items():
            assert r["stages_built"], (layout, r)
            assert r["parity"], (layout, r)


class TestWindow:
    def test_ring_eviction_order(self):
        w = SlidingWindow(8, 2)
        b = np.arange(16, dtype=np.float32).reshape(8, 2)
        slots, _, ev = w.push(b, 8)
        assert w.full and not ev.any()
        nxt = np.full((4, 2), 99.0, np.float32)
        slots, evicted, ev = w.push(nxt, 4)
        assert list(slots) == [0, 1, 2, 3]        # oldest slots first
        assert ev.all()
        np.testing.assert_array_equal(evicted, b[:4])
        np.testing.assert_array_equal(w.host[:4], nxt)

    def test_warmup_prefix_and_padding(self):
        w = SlidingWindow(8, 2)
        batch = np.full((4, 2), 7.0, np.float32)
        slots, _, ev = w.push(batch, 3)
        assert w.count == 3 and not ev.any()
        assert list(slots) == [0, 1, 2, 8]        # padding row drops
        assert w.contents().shape == (3, 2)


class TestService:
    def _service(self, backend="jnp"):
        return StreamService(StreamServeConfig(stream=_cfg(backend)))

    def test_micro_batch_accumulation(self):
        pts, _ = gaussian_mixture(CAP + 3 * B, k=4, d=2, overlap=0.05, seed=0)
        svc = self._service()
        svc.engine.initialize(pts[:CAP])
        ticks = svc.submit(pts[CAP: CAP + B // 2])
        assert ticks == [] and svc.stats()["buffered"] == B // 2
        ticks = svc.submit(pts[CAP + B // 2: CAP + 2 * B + 10])
        assert len(ticks) == 2 and svc.stats()["buffered"] == 10
        tick = svc.flush()
        assert tick is not None and svc.stats()["buffered"] == 0
        _assert_parity(svc.engine, "jnp")

    def test_query_labels_match_window(self):
        from repro.stream import QueryStatus

        pts, _ = gaussian_mixture(CAP + B, k=3, d=2, overlap=0.02, seed=11)
        svc = self._service()
        svc.engine.initialize(pts[:CAP])
        svc.submit(pts[CAP: CAP + B])
        last = svc.engine._last
        # querying window points themselves returns their own stable labels
        probe = np.nonzero(last.labels >= 0)[0][:16]
        res = svc.query(svc.engine.window.host[probe])
        np.testing.assert_array_equal(res.labels, last.labels[probe])
        assert (res.status == QueryStatus.HIT).all()

    def test_query_miss_falls_back_to_nearest_center(self):
        from repro.stream import QueryStatus

        pts, _ = gaussian_mixture(CAP + B, k=3, d=2, overlap=0.02, seed=11)
        svc = self._service()
        svc.engine.initialize(pts[:CAP])
        svc.submit(pts[CAP: CAP + B])
        ids, pos = svc.engine.center_positions()
        assert len(ids) > 0
        # a probe far outside coverage adopts the nearest center's stable id
        # with an explicit MISS_FALLBACK flag (not a bare -1)
        probe = np.array([[9e8, 9e8]], np.float32)
        res = svc.query(probe)
        assert res.status[0] == QueryStatus.MISS_FALLBACK
        d2 = ((probe[0] - pos) ** 2).sum(-1)
        assert res.labels[0] == ids[np.argmin(d2)]
        # mixed request: in-coverage rows stay HIT with their window label
        mixed = np.concatenate([svc.engine.window.host[:1], probe])
        res = svc.query(mixed)
        assert res.status[0] == QueryStatus.HIT
        assert res.status[1] == QueryStatus.MISS_FALLBACK

    def test_query_no_centers_is_miss(self):
        from repro.stream import QueryStatus

        rng = np.random.default_rng(4)
        # all-noise window (uniform scatter, rho never reaches rho_min)
        pts = rng.uniform(0, 5e6, (CAP, 2)).astype(np.float32)
        svc = self._service()
        svc.engine.initialize(pts)
        if svc.engine.clustering.num_clusters == 0:
            res = svc.query(np.array([[9e8, 9e8]], np.float32))
            assert res.labels[0] == -1
            assert res.status[0] == QueryStatus.MISS


class TestDriftingGenerator:
    def test_shapes_and_motion(self):
        gen = drifting_batches(batch=32, ticks=5, k=3, d=2, seed=0,
                               drift=0.02)
        frames = list(gen)
        assert len(frames) == 5
        for pts, labels, centers in frames:
            assert pts.shape == (32, 2) and labels.shape == (32,)
            assert centers.shape == (3, 2)
        # centers actually move between ticks
        assert not np.allclose(frames[0][2], frames[-1][2])
