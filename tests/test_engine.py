"""repro.engine acceptance: one ExecSpec, one engine, one plan.

The ISSUE 5 contract, in four parts:

* **Parity** — the legacy-kwarg config shims (``DPCConfig(backend=...)``,
  ``DistDPCConfig``, ``StreamDPCConfig``, ``DPCKVConfig``) and the unified
  ``ExecSpec`` / ``DPCEngine`` paths produce bit-identical results per
  backend (including ``pallas-interpret``) and per layout.
* **Plan reuse** — a re-``fit`` on a same-shaped input returns the *same*
  plan object, adds no new jit trace entries, and (block-sparse pallas)
  skips the host worklist rebuild entirely.
* **Deprecation** — constructing any of the four shims through its legacy
  exec kwargs emits a ``DeprecationWarning`` pointing at ``repro.engine``.
* **Fail-fast validation** — unknown backend/layout/precision names and
  impossible combos raise ``ValueError`` at construction / plan time, not
  inside the kernel layer.
"""
import warnings

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import DPCConfig, compute_dpc
from repro.core.approxdpc import run_approxdpc
from repro.core.sapproxdpc import run_sapproxdpc
from repro.engine import DPCEngine, ExecSpec, PointsSpec, as_plan, plan
from repro.kernels import blocksparse
from repro.stream import QueryStatus, StreamDPC, StreamDPCConfig

BACKENDS = ["jnp", "pallas-interpret"]


def _mix(n=384, d=2, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0, 6000.0, (4, d))
    pts = (centers[rng.integers(0, 4, n)]
           + rng.normal(0, 150.0, (n, d))).astype(np.float32)
    return pts


def _eq(a, b):
    a, b = np.asarray(a), np.asarray(b)
    if a.dtype.kind == "f":
        return bool(np.all((a == b) | (np.isinf(a) & np.isinf(b))))
    return bool(np.all(a == b))


def _assert_same_result(a, b):
    assert _eq(a.rho, b.rho), "rho diverged"
    assert _eq(a.rho_key, b.rho_key), "rho_key diverged"
    assert _eq(a.delta, b.delta), "delta diverged"
    assert _eq(a.parent, b.parent), "parent diverged"


class TestLegacyShimParity:
    """Legacy-kwarg configs == ExecSpec/DPCEngine, bit for bit."""

    @pytest.mark.parametrize("algo", ["scan", "exdpc", "approxdpc",
                                      "sapproxdpc"])
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_batch_backend_parity(self, backend, algo):
        pts = _mix(256 if backend == "jnp" else 160)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            legacy = compute_dpc(pts, DPCConfig(d_cut=900.0, algorithm=algo,
                                                backend=backend))
        spec = ExecSpec(backend=backend)
        unified = compute_dpc(pts, DPCConfig(d_cut=900.0, algorithm=algo,
                                             exec_spec=spec))
        engine = DPCEngine(d_cut=900.0, algorithm=algo,
                           exec_spec=spec).fit(pts).result
        _assert_same_result(legacy, unified)
        _assert_same_result(unified, engine)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_batch_layout_parity(self, backend):
        pts = _mix(256 if backend == "jnp" else 160, seed=3)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            legacy = compute_dpc(pts, DPCConfig(
                d_cut=900.0, backend=backend, layout="block-sparse"))
        spec = ExecSpec(backend=backend, layout="block-sparse")
        engine = DPCEngine(d_cut=900.0, exec_spec=spec).fit(pts).result
        _assert_same_result(legacy, engine)

    def test_block_kwarg_parity(self):
        """The resolved-block satellite: an explicit legacy block and the
        plan's native default give identical bits (block is a throughput
        knob only), and the shim threads it to the same place ExecSpec
        does."""
        pts = _mix(300, seed=5)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            legacy = compute_dpc(pts, DPCConfig(d_cut=900.0,
                                                algorithm="scan", block=96))
        via_spec = compute_dpc(pts, DPCConfig(
            d_cut=900.0, algorithm="scan",
            exec_spec=ExecSpec(block=96)))
        native = compute_dpc(pts, DPCConfig(d_cut=900.0, algorithm="scan"))
        _assert_same_result(legacy, via_spec)
        _assert_same_result(legacy, native)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_stream_parity(self, backend):
        pts = _mix(320, seed=7)
        cap, B = 256, 32

        def drive(cfg):
            s = StreamDPC(cfg)
            s.initialize(pts[:cap])
            for i in range(cap, len(pts), B):
                tick = s.ingest(pts[i: i + B])
            return s, tick

        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            s1, t1 = drive(StreamDPCConfig(d_cut=900.0, capacity=cap,
                                           batch_cap=B, rho_min=3.0,
                                           backend=backend))
        s2, t2 = drive(StreamDPCConfig(
            d_cut=900.0, capacity=cap, batch_cap=B, rho_min=3.0,
            exec_spec=ExecSpec(backend=backend)))
        _assert_same_result(s1.result, s2.result)
        assert np.array_equal(t1.labels, t2.labels)
        # and the engine facade drives the same stream
        eng = DPCEngine(d_cut=900.0, rho_min=3.0, window_capacity=cap,
                        batch_cap=B, exec_spec=ExecSpec(backend=backend))
        eng.partial_fit(pts[:cap])
        for i in range(cap, len(pts), B):
            eng.partial_fit(pts[i: i + B])
        _assert_same_result(eng.result, s2.result)

    def test_distributed_parity(self):
        from repro.distributed import DistDPCConfig, distributed_dpc

        pts = _mix(256, seed=9)
        mesh = jax.make_mesh((1,), ("data",))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            legacy = distributed_dpc(pts, DistDPCConfig(d_cut=900.0,
                                                        backend="jnp"),
                                     mesh)
        unified = distributed_dpc(pts, mesh=mesh, d_cut=900.0,
                                  exec_spec=ExecSpec(backend="jnp"))
        engine = DPCEngine(d_cut=900.0, algorithm="exdpc", mesh=mesh,
                           exec_spec=ExecSpec(backend="jnp")).fit(pts)
        _assert_same_result(legacy, unified)
        _assert_same_result(unified, engine.result)

    def test_dpc_kv_parity(self):
        from repro.serve.dpc_kv import DPCKVConfig, compress_kv

        rng = np.random.default_rng(2)
        k = jnp.asarray(rng.normal(size=(2, 96, 2, 32)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(2, 96, 2, 32)), jnp.float32)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            legacy = compress_kv(k, v, 80, DPCKVConfig(budget=16,
                                                       backend="jnp"))
        unified = compress_kv(k, v, 80, DPCKVConfig(
            budget=16, exec_spec=ExecSpec(backend="jnp")))
        for a, b in zip(legacy, unified):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_dpc_kv_block_sparse_traceable(self):
        """Newly-reachable capability: jnp jit-built worklists let DPC-KV
        run block-sparse under its jit+vmap, bit-equal to dense."""
        from repro.serve.dpc_kv import DPCKVConfig, compress_kv

        rng = np.random.default_rng(4)
        k = jnp.asarray(rng.normal(size=(1, 96, 2, 32)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 96, 2, 32)), jnp.float32)
        dense = compress_kv(k, v, 90, DPCKVConfig(
            budget=12, exec_spec=ExecSpec(backend="jnp")))
        sparse = compress_kv(k, v, 90, DPCKVConfig(
            budget=12, exec_spec=ExecSpec(backend="jnp",
                                          layout="block-sparse")))
        for a, b in zip(dense, sparse):
            assert np.array_equal(np.asarray(a), np.asarray(b))


class TestPlanReuse:
    """Re-fit on same-shaped input: cached plan, no retrace, no rebuild."""

    def test_plan_object_identity_and_no_retrace(self):
        from repro.kernels.backend import _rho_delta_jnp

        pts = _mix(288, seed=11)
        eng = DPCEngine(d_cut=900.0, algorithm="scan",
                        exec_spec=ExecSpec(backend="jnp"))
        eng.fit(pts)
        first_plan = eng.plan
        traces_after_first = _rho_delta_jnp._cache_size()
        eng.fit(pts)                                # same shape, same data
        eng.fit(_mix(288, seed=12))                 # same shape, new data
        assert eng.plan is first_plan, "same-shaped re-fit built a new plan"
        assert _rho_delta_jnp._cache_size() == traces_after_first, \
            "same-shaped re-fit re-traced the fused sweep"
        # a different shape re-plans (and re-traces) as it must
        eng.fit(_mix(290, seed=12))
        assert eng.plan is not first_plan

    def test_plan_cache_function(self):
        ps = PointsSpec(n=128, d=3)
        spec = ExecSpec(backend="jnp", layout="block-sparse")
        assert plan(ps, spec) is plan(ps, spec)
        assert plan(ps, spec) is not plan(PointsSpec(n=129, d=3), spec)
        assert as_plan(spec).spec == spec

    def test_host_worklist_reuse(self):
        """pallas block-sparse: the second same-data fit serves every host
        worklist from the plan's content-addressed cache."""
        pts = _mix(160, seed=13)
        eng = DPCEngine(d_cut=900.0, algorithm="scan",
                        exec_spec=ExecSpec(backend="pallas-interpret",
                                           layout="block-sparse"))
        eng.fit(pts)
        builds_after_first = blocksparse.worklist_build_count()
        assert builds_after_first > 0
        eng.fit(pts)                                # same data
        assert blocksparse.worklist_build_count() == builds_after_first, \
            "same-data re-fit rebuilt a host worklist"
        # different data with the same shape must rebuild (fingerprinted)
        eng.fit(_mix(160, seed=14))
        assert blocksparse.worklist_build_count() > builds_after_first

    def test_worklist_fingerprint_source_dtype_miss(self):
        """Cache identity is the caller's data, not its f32 shadow: the
        same coordinates handed in at a different source dtype must MISS
        (the sweep kernels consume the original arrays; only the worklist
        builder canonicalizes to f32, so the converted bytes collide)."""
        pts32 = np.asarray(_mix(96, seed=16), np.float32)
        pts64 = pts32.astype(np.float64)
        kw = dict(block_n=64, block_m=64)
        with blocksparse.worklist_cache({}):
            before = blocksparse.worklist_build_count()
            hits0 = blocksparse.worklist_cache_hits()
            blocksparse.build_flat_worklist(pts32, pts32, 500.0, **kw)
            assert blocksparse.worklist_build_count() == before + 1
            blocksparse.build_flat_worklist(pts32, pts32, 500.0, **kw)
            assert blocksparse.worklist_build_count() == before + 1, \
                "identical call must be served from the cache"
            assert blocksparse.worklist_cache_hits() == hits0 + 1
            blocksparse.build_flat_worklist(pts64, pts64, 500.0, **kw)
            assert blocksparse.worklist_build_count() == before + 2, \
                "same coords at f64 hit the f32-coord fingerprint"
            blocksparse.build_flat_worklist(pts64, pts32, 500.0, **kw)
            assert blocksparse.worklist_build_count() == before + 3, \
                "per-argument dtype tags: (f64, f32) != (f64, f64)"

    def test_worklist_fingerprint_perturbation_miss(self):
        """One moved point is a different identity — content-addressed
        keys, not shape-addressed."""
        pts = np.asarray(_mix(96, seed=17), np.float32)
        bumped = pts.copy()
        bumped[17, 0] += 1.0
        kw = dict(block_n=64, block_m=64)
        with blocksparse.worklist_cache({}):
            before = blocksparse.worklist_build_count()
            blocksparse.build_flat_worklist(pts, pts, 500.0, **kw)
            blocksparse.build_flat_worklist(bumped, bumped, 500.0, **kw)
            assert blocksparse.worklist_build_count() == before + 2

    def test_direct_backend_calls_never_cache(self):
        """Without an active plan context the builder is stateless."""
        pts, = (np.asarray(_mix(96, seed=15)),)
        before = blocksparse.worklist_build_count()
        blocksparse.build_flat_worklist(pts, pts, 500.0, block_n=64,
                                        block_m=64)
        blocksparse.build_flat_worklist(pts, pts, 500.0, block_n=64,
                                        block_m=64)
        assert blocksparse.worklist_build_count() == before + 2


class TestDeprecationShims:
    def test_dpc_config_warns(self):
        with pytest.warns(DeprecationWarning, match="repro.engine"):
            DPCConfig(d_cut=100.0, backend="jnp")
        with pytest.warns(DeprecationWarning, match="repro.engine"):
            DPCConfig(d_cut=100.0, layout="block-sparse")
        with pytest.warns(DeprecationWarning, match="repro.engine"):
            DPCConfig(d_cut=100.0, block=128)

    def test_dist_config_warns(self):
        from repro.distributed import DistDPCConfig
        with pytest.warns(DeprecationWarning, match="repro.engine"):
            DistDPCConfig(d_cut=100.0, backend="jnp")

    def test_stream_config_warns(self):
        with pytest.warns(DeprecationWarning, match="repro.engine"):
            StreamDPCConfig(d_cut=100.0, layout="block-sparse")

    def test_data_axis_legacy_kwarg(self):
        from repro.distributed import DistDPCConfig
        with pytest.warns(DeprecationWarning, match="data_axis"):
            cfg = DistDPCConfig(d_cut=100.0, data_axis="dp")
        assert cfg.resolved_exec().data_axis == "dp"
        with pytest.raises(ValueError, match="legacy"):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                DistDPCConfig(d_cut=100.0, data_axis="dp",
                              exec_spec=ExecSpec(data_axis="mp"))
        with pytest.warns(DeprecationWarning, match="data_axis"):
            scfg = StreamDPCConfig(d_cut=100.0, data_axis="dp")
        assert scfg.resolved_exec().data_axis == "dp"

    def test_kv_config_warns(self):
        from repro.serve.dpc_kv import DPCKVConfig
        with pytest.warns(DeprecationWarning, match="repro.engine"):
            DPCKVConfig(budget=8, backend="jnp")

    def test_no_warning_on_unified_path(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            DPCConfig(d_cut=100.0, exec_spec=ExecSpec(backend="jnp"))
            StreamDPCConfig(d_cut=100.0)

    def test_conflicting_legacy_and_spec_raise(self):
        with pytest.raises(ValueError, match="legacy"):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                DPCConfig(d_cut=100.0, backend="jnp",
                          exec_spec=ExecSpec(backend="pallas-interpret"))


class TestFailFastValidation:
    def test_unknown_backend(self):
        with pytest.raises(ValueError, match="unknown kernel backend"):
            ExecSpec(backend="cuda")

    def test_unknown_layout(self):
        with pytest.raises(ValueError, match="unknown layout"):
            ExecSpec(layout="sparse")

    def test_unknown_precision(self):
        with pytest.raises(ValueError, match="unknown precision"):
            ExecSpec(precision="fp8")

    def test_bf16_on_jnp(self):
        with pytest.raises(ValueError, match="bf16"):
            ExecSpec(backend="jnp", precision="bf16")

    def test_bf16_auto_resolving_to_jnp(self):
        # on a CPU container auto-detection lands on jnp: plan() must
        # reject bf16 with a clear message, not fail inside the kernels
        spec = ExecSpec(precision="bf16")
        with pytest.raises(ValueError, match="pallas"):
            as_plan(spec, np.zeros((8, 2), np.float32))

    def test_bad_block(self):
        with pytest.raises(ValueError, match="block"):
            ExecSpec(block=0)

    def test_bad_eps_sapprox(self):
        with pytest.raises(ValueError, match="eps > 0"):
            DPCConfig(d_cut=10.0, algorithm="sapproxdpc", eps=0.0)
        with pytest.raises(ValueError, match="eps > 0"):
            run_sapproxdpc(np.zeros((4, 2), np.float32), 1.0, eps=-1.0)
        with pytest.raises(ValueError, match="eps > 0"):
            DPCEngine(d_cut=10.0, algorithm="sapproxdpc", eps=0.0)

    def test_bad_dcut(self):
        with pytest.raises(ValueError, match="d_cut"):
            DPCConfig(d_cut=0.0)
        with pytest.raises(ValueError, match="d_cut"):
            StreamDPCConfig(d_cut=-1.0)

    def test_unknown_algorithm(self):
        with pytest.raises(ValueError, match="algorithm"):
            DPCConfig(d_cut=10.0, algorithm="dbscan")
        with pytest.raises(ValueError, match="algorithm"):
            DPCEngine(d_cut=10.0, algorithm="dbscan")

    def test_pallas_block_sparse_under_jit_config(self):
        from repro.serve.dpc_kv import DPCKVConfig
        with pytest.raises(ValueError, match="jit"):
            DPCKVConfig(budget=8, exec_spec=ExecSpec(
                backend="pallas", layout="block-sparse"))

    def test_legacy_kwargs_rejected_on_runners(self):
        with pytest.raises(TypeError):
            run_approxdpc(np.zeros((4, 2), np.float32), 1.0, backend="jnp")

    def test_runners_accept_array_likes(self):
        """Plain lists keep working on the public run_* API (the planner
        reads shapes only after jnp.asarray coercion)."""
        from repro.core.scan import run_scan
        res = run_scan([[0.0, 0.0], [1.0, 1.0], [2.0, 0.0]], 1.5)
        assert res.rho.shape == (3,)

    def test_distributed_cfg_kwarg_conflict(self):
        from repro.distributed import DistDPCConfig, distributed_dpc
        mesh = jax.make_mesh((1,), ("data",))
        pts = np.zeros((8, 2), np.float32)
        with pytest.raises(ValueError, match="not both"):
            distributed_dpc(pts, DistDPCConfig(d_cut=1.0), mesh,
                            strategy="halo")
        with pytest.raises(ValueError, match="not both"):
            distributed_dpc(pts, DistDPCConfig(d_cut=1.0), mesh, d_cut=2.0)

    def test_exec_parse(self):
        assert ExecSpec.parse("jnp:block-sparse") == \
            ExecSpec(backend="jnp", layout="block-sparse")
        assert ExecSpec.parse("::") == ExecSpec()
        assert ExecSpec.parse("pallas::bf16").precision == "bf16"
        with pytest.raises(ValueError):
            ExecSpec.parse("a:b:c:d")


class TestExecParseErrors:
    """Each malformed --exec form fails with the offending segment named
    and that axis's valid values enumerated (ISSUE 6 satellite)."""

    def test_too_many_segments(self):
        with pytest.raises(ValueError) as ei:
            ExecSpec.parse("jnp:dense:f32:extra")
        msg = str(ei.value)
        assert "at most 3" in msg and "got 4" in msg
        # the recovery path: every axis's valid values are in the message
        for value in ("jnp", "pallas", "pallas-interpret", "dense",
                      "block-sparse", "f32", "bf16"):
            assert value in msg

    def test_unknown_backend_segment(self):
        with pytest.raises(ValueError) as ei:
            ExecSpec.parse("cuda:dense")
        msg = str(ei.value)
        assert "segment 1 (backend)" in msg and "'cuda'" in msg
        assert "jnp" in msg and "pallas-interpret" in msg
        assert "empty/'-'/'auto'" in msg

    def test_unknown_layout_segment(self):
        with pytest.raises(ValueError) as ei:
            ExecSpec.parse("jnp:sparse")
        msg = str(ei.value)
        assert "segment 2 (layout)" in msg and "'sparse'" in msg
        assert "dense" in msg and "block-sparse" in msg

    def test_unknown_precision_segment(self):
        with pytest.raises(ValueError) as ei:
            ExecSpec.parse("jnp:dense:fp8")
        msg = str(ei.value)
        assert "segment 3 (precision)" in msg and "'fp8'" in msg
        assert "f32" in msg and "bf16" in msg

    def test_misordered_value_hint(self):
        # a precision in the layout slot: the error says which axis the
        # value actually belongs to and restates the segment order
        with pytest.raises(ValueError) as ei:
            ExecSpec.parse("jnp:bf16")
        msg = str(ei.value)
        assert "segment 2 (layout)" in msg
        assert "'bf16' is a precision" in msg
        assert "backend:layout:precision" in msg

    def test_backend_in_precision_slot_hint(self):
        with pytest.raises(ValueError) as ei:
            ExecSpec.parse("::jnp")
        assert "'jnp' is a backend" in str(ei.value)

    def test_valid_combos_still_parse(self):
        assert ExecSpec.parse("") == ExecSpec()
        assert ExecSpec.parse("-:block-sparse:-").layout == "block-sparse"
        assert ExecSpec.parse("auto:dense").layout == "dense"
        # combo validation still happens (in the constructor, post-parse)
        with pytest.raises(ValueError, match="bf16"):
            ExecSpec.parse("jnp::bf16")


class TestEnginePredict:
    """predict == the serve layer's query semantics, on batch state."""

    def test_hit_and_fallback(self):
        pts = _mix(256, seed=21)
        eng = DPCEngine(d_cut=900.0, rho_min=3.0,
                        exec_spec=ExecSpec(backend="jnp")).fit(pts)
        q = eng.predict(pts[:16])
        assert (q.status == int(QueryStatus.HIT)).all()
        assert np.array_equal(q.labels, eng.labels_[:16])
        far = eng.predict(np.full((1, 2), 1e7, np.float32))
        assert far.status[0] == int(QueryStatus.MISS_FALLBACK)
        assert far.labels[0] in set(eng.labels_[eng.labels_ >= 0])

    def test_stream_predict_matches_service_query(self):
        from repro.stream import StreamServeConfig, StreamService

        pts = _mix(320, seed=22)
        cap, B = 256, 32
        spec = ExecSpec(backend="jnp")
        eng = DPCEngine(d_cut=900.0, rho_min=3.0, window_capacity=cap,
                        batch_cap=B, exec_spec=spec)
        svc = StreamService(StreamServeConfig(stream=StreamDPCConfig(
            d_cut=900.0, capacity=cap, batch_cap=B, rho_min=3.0,
            exec_spec=spec)))
        # drive both through the same warm-up ticks so the stable-id
        # assignment order (and with it the label values) matches
        eng.partial_fit(pts[:cap])
        svc.engine.ingest(pts[:cap])
        for i in range(cap, len(pts), B):
            eng.partial_fit(pts[i: i + B])
            svc.engine.ingest(pts[i: i + B])
        qe = eng.predict(pts[:40])
        qs = svc.query(pts[:40])
        assert np.array_equal(qe.labels, qs.labels)
        assert np.array_equal(qe.status, qs.status)

    def test_unfitted_raises(self):
        with pytest.raises(ValueError, match="unfitted"):
            DPCEngine(d_cut=10.0).predict(np.zeros((1, 2), np.float32))

    def test_refit_resets_stream(self):
        """A fit after streaming replaces the window: the next partial_fit
        seeds from the newly fitted points, not the stale stream."""
        a = _mix(128, seed=30)
        c = _mix(128, seed=31) + 50000.0      # disjoint data
        eng = DPCEngine(d_cut=900.0, rho_min=3.0, window_capacity=128,
                        batch_cap=32, exec_spec=ExecSpec(backend="jnp"))
        eng.partial_fit(a)
        eng.fit(c)
        assert eng.stream is None
        eng.partial_fit(c[:32])               # re-seeds from c, ingests
        w = eng.stream.window_points()
        assert np.abs(w).min() >= 40000.0, "stale pre-fit window survived"

    def test_engine_ctor_validation(self):
        with pytest.raises(ValueError, match="strategy"):
            DPCEngine(d_cut=10.0, strategy="ring")
        with pytest.raises(ValueError, match="batch_cap"):
            DPCEngine(d_cut=10.0, window_capacity=64, batch_cap=128)
