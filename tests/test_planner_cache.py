"""Planner plan-cache behavior: LRU eviction, counter accuracy, clear.

The cache memoizes ``plan(PointsSpec, ExecSpec)`` in an OrderedDict capped
at ``_PLAN_CACHE_MAX``; its traffic counters (hits / misses / evictions)
live on the repro.obs registry with ``plan_cache_info()`` as the stable
read surface.  These tests pin the exact counting semantics so the shims
stay honest.
"""
import pytest

from repro.engine import ExecSpec, PointsSpec, as_plan, plan
from repro.engine.planner import _PLAN_CACHE_MAX, _PLANS
from repro.engine import plan_cache_clear, plan_cache_info


@pytest.fixture(autouse=True)
def _fresh_cache():
    plan_cache_clear()
    yield
    plan_cache_clear()


SPEC = ExecSpec(backend="jnp")


class TestCounters:
    def test_miss_then_hit(self):
        p1 = plan((64, 2), SPEC)
        assert plan_cache_info() == {"hits": 0, "misses": 1,
                                     "evictions": 0, "entries": 1}
        p2 = plan((64, 2), SPEC)
        assert p2 is p1
        assert plan_cache_info() == {"hits": 1, "misses": 1,
                                     "evictions": 0, "entries": 1}

    def test_as_plan_same_shape_is_free(self):
        import numpy as np

        pts = np.zeros((64, 2), np.float32)
        p1 = as_plan(SPEC, pts)
        info = plan_cache_info()
        # handing the resolved plan back with a same-shaped input returns
        # it without touching the cache at all
        assert as_plan(p1, pts) is p1
        assert plan_cache_info() == info

    def test_as_plan_replans_on_shape_mismatch(self):
        import numpy as np

        p1 = as_plan(SPEC, np.zeros((64, 2), np.float32))
        info = plan_cache_info()
        p2 = as_plan(p1, np.zeros((96, 2), np.float32))
        assert p2 is not p1
        assert p2.spec == p1.spec
        assert p2.pspec == PointsSpec(96, 2)
        assert plan_cache_info()["misses"] == info["misses"] + 1
        # the mismatched re-plan is itself cached: doing it again is a hit
        assert as_plan(p1, np.zeros((96, 2), np.float32)) is p2
        assert plan_cache_info()["hits"] == info["hits"] + 1


class TestLRU:
    def test_eviction_at_capacity(self):
        extra = 5
        for n in range(extra + _PLAN_CACHE_MAX):
            plan((64 + n, 2), SPEC)
        info = plan_cache_info()
        assert info["entries"] == _PLAN_CACHE_MAX
        assert len(_PLANS) == _PLAN_CACHE_MAX
        assert info["misses"] == _PLAN_CACHE_MAX + extra
        assert info["evictions"] == extra
        # the oldest shapes fell out, the newest survived
        assert plan((64 + extra + _PLAN_CACHE_MAX - 1, 2), SPEC)
        assert plan_cache_info()["hits"] == 1
        plan((64, 2), SPEC)     # evicted -> miss again
        assert plan_cache_info()["misses"] == _PLAN_CACHE_MAX + extra + 1

    def test_hit_refreshes_recency(self):
        plan((64, 2), SPEC)
        for n in range(1, _PLAN_CACHE_MAX):
            plan((64 + n, 2), SPEC)
        assert plan_cache_info()["entries"] == _PLAN_CACHE_MAX
        p_old = plan((64, 2), SPEC)          # hit: moves to MRU
        plan((4096, 2), SPEC)                # evicts the LRU entry...
        assert plan((64, 2), SPEC) is p_old  # ...which is no longer (64, 2)
        assert plan_cache_info()["evictions"] == 1


class TestClear:
    def test_clear_resets_entries_and_counters(self):
        plan((64, 2), SPEC)
        plan((64, 2), SPEC)
        assert plan_cache_info()["hits"] == 1
        plan_cache_clear()
        assert plan_cache_info() == {"hits": 0, "misses": 0,
                                     "evictions": 0, "entries": 0}
        # a post-clear plan is a rebuild, not the old object by identity
        p = plan((64, 2), SPEC)
        assert plan_cache_info() == {"hits": 0, "misses": 1,
                                     "evictions": 0, "entries": 1}
        assert plan((64, 2), SPEC) is p
