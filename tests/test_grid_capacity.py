"""Grid capacity handling: build-time measurement under skew, canonical
partitions, and the overflow -> rebuild path the streaming subsystem
relies on."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.grid import (build_grid, canonical_group_coords,
                             cell_span_bounds, point_span_bounds)
from repro.data.points import gaussian_mixture, real_proxy
from repro.stream.incremental import CellOverflow, IncrementalGrid

D_CUT = 5000.0


class TestBuildTimeCapacities:
    """Measured capacities are exact data statistics, even under skew."""

    @pytest.mark.parametrize("name", ["airline", "household"])
    def test_cell_cap_is_max_occupancy(self, name):
        pts, _ = real_proxy(name, 1500, seed=0)     # pareto-skewed densities
        grid = build_grid(jnp.asarray(pts), D_CUT)
        counts = np.asarray(grid.cell_count)[: grid.num_cells]
        assert counts.sum() == len(pts)
        assert counts.max() == grid.cell_cap        # measured, not padded
        assert counts.min() >= 1                    # only occupied cells

    def test_span_cap_bounds_every_span(self):
        pts, _ = real_proxy("pamap2", 1200, seed=1)
        grid = build_grid(jnp.asarray(pts), D_CUT)
        starts, ends = point_span_bounds(grid)
        widths = np.asarray(ends - starts)
        assert widths.max() == grid.span_cap        # tight measurement
        cs, ce = cell_span_bounds(grid)
        assert int(jnp.max(ce - cs)) <= grid.span_cap

    def test_stencil_covers_dcut_ball(self):
        """Every point within d_cut of p lies inside p's candidate spans —
        the invariant that makes stencil rho/delta exact."""
        pts, _ = gaussian_mixture(600, k=4, d=3, overlap=0.06, seed=2)
        grid = build_grid(jnp.asarray(pts), D_CUT)
        sorted_pts = np.asarray(grid.points)
        starts, ends = map(np.asarray, point_span_bounds(grid))
        d2 = ((sorted_pts[:, None, :].astype(np.float64)
               - sorted_pts[None]) ** 2).sum(-1)
        for i in range(0, len(pts), 37):
            nbrs = set(np.nonzero(d2[i] < D_CUT ** 2)[0])
            covered = set()
            for s, e in zip(starts[i], ends[i]):
                covered.update(range(s, e))
            assert nbrs <= covered


class TestCanonicalPartition:
    """floor(p/side) quantization: the partition is origin-independent."""

    def test_shared_points_group_identically(self):
        pts, _ = gaussian_mixture(400, k=3, d=2, overlap=0.05, seed=3)
        extra = np.array([[1.0, 1.0]], np.float32)   # shifts the data min
        a = canonical_group_coords(jnp.asarray(pts), D_CUT)
        b = canonical_group_coords(jnp.asarray(np.concatenate([extra, pts])),
                                   D_CUT)[1:]
        assert bool(jnp.all(a == b))
        # and through build_grid: same pairs share grouping cells
        ga = build_grid(jnp.asarray(pts), D_CUT)
        gb = build_grid(jnp.asarray(np.concatenate([extra, pts])), D_CUT)
        key_a = np.asarray(ga.group_key)[np.asarray(ga.inv_order)]
        key_b = np.asarray(gb.group_key)[np.asarray(gb.inv_order)][1:]
        same_a = key_a[:, None] == key_a[None, :]
        same_b = key_b[:, None] == key_b[None, :]
        assert (same_a == same_b).all()


class TestIncrementalOverflow:
    """The streaming grid's measured budgets: overflow raises, rebuild
    restores an exact partition."""

    def _grid(self, pts, **kw):
        g = IncrementalGrid(D_CUT, capacity=len(pts), dim=pts.shape[1], **kw)
        g.rebuild(pts, len(pts))
        return g

    def test_rebuild_matches_canonical_coords(self):
        pts, _ = gaussian_mixture(300, k=3, d=2, overlap=0.05, seed=4)
        g = self._grid(pts)
        coords = np.asarray(canonical_group_coords(jnp.asarray(pts), D_CUT))
        keys = g._pack(coords)
        seg = np.asarray(g.seg_dev)[: len(pts)]
        # same packed key <-> same segment id
        for k in np.unique(keys):
            ids = np.unique(seg[keys == k])
            assert len(ids) == 1
        assert g.live_cells == len(np.unique(keys))

    def test_out_of_box_raises(self):
        pts = np.random.default_rng(0).normal(5e4, 800.0, (64, 2)) \
            .astype(np.float32)
        g = self._grid(pts, extent_margin=1)
        far = np.array([[9.9e4, 9.9e4]], np.float32)
        with pytest.raises(CellOverflow):
            g.apply(np.array([0], np.int32), far, pts[:1], 1)

    def test_live_cell_budget_raises(self):
        rng = np.random.default_rng(1)
        pts = rng.normal(5e4, 500.0, (128, 2)).astype(np.float32)
        g = self._grid(pts, cell_slack=1.0, extent_margin=16)
        budget = g.maxima_cap
        side = D_CUT / np.sqrt(2.0)
        # one new singleton cell per insert, marching along a grid row
        with pytest.raises(CellOverflow):
            for i in range(budget + 1):
                p = np.array([[3e4 + (2 * i + 1) * side, 2e4]], np.float32)
                g.apply(np.array([i % 64], np.int32), p, pts[i % 64: i % 64 + 1],
                        1)
                pts[i % 64] = p[0]

    def test_eviction_recycles_cell_ids(self):
        pts = np.array([[0., 0.], [1e4, 1e4], [2e4, 2e4], [3e4, 3e4]],
                       np.float32)
        g = IncrementalGrid(100.0, capacity=4, dim=2, extent_margin=500)
        g.rebuild(pts, 4)
        assert g.live_cells == 4
        # replace a singleton with a point in an existing cell: id freed
        g.apply(np.array([3], np.int32), pts[:1].copy(), pts[3:4], 1)
        assert g.live_cells == 3 and len(g.free_ids) == 1
        # replacing a singleton with a new singleton: the evicted cell's id
        # frees and the new cell reuses a recycled id — ids stay < capacity
        old_seg2 = int(g.seg_np[2])
        g.apply(np.array([2], np.int32), np.array([[4e4, 4e4]], np.float32),
                pts[2:3], 1)
        assert g.live_cells == 3 and len(g.free_ids) == 1
        assert int(g.seg_np[2]) in (old_seg2, 3)   # recycled, never a new id
        assert g.next_id == 4
