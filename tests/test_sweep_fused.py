"""Fused rho_delta parity and the halo / mixed-precision engine paths.

The acceptance contract of the unified tile-sweep engine (ISSUE 3):

* fused ``rho_delta`` == the sequential ``range_count`` + ``denser_nn``
  two-pass formulation, per backend (``jnp``, ``pallas-interpret``) and
  dtype (f32, bf16+refine) — property-tested on integer-lattice data where
  every distance and inner product is exact in all three arithmetics, so
  equality is *bit* equality (including duplicate points, i.e. exact
  distance ties exercising the lexicographic tie-break).  The property runs
  under hypothesis when available (CI) and over a fixed seed matrix always;
* adversarially scaled near-tie data: the fused path's kept-k candidates are
  re-ranked in direct-difference form, so expanded-form rounding cannot flip
  the dependent point (extending the ``refine_topk_d2`` contract);
* the halo primitives (span-masked tiles) agree between the jnp gather form
  and the pallas dense form, and with an unrestricted reference when the
  spans cover the whole window.
"""
import numpy as np
import pytest
import jax.numpy as jnp

try:  # dev-only dep (requirements-dev.txt); fixed-seed tests run without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.dpc_types import density_jitter
from repro.kernels import get_backend, rho_delta_sequential
from repro.kernels.backend import JnpBackend


def _assert_fused_equals_sequential(be, pts, d_cut, precision=None,
                                    seq_be=None):
    n = pts.shape[0]
    jit_ = density_jitter(n)
    seq = rho_delta_sequential(seq_be or be, pts, pts, d_cut, jitter=jit_)
    fus = be.rho_delta(pts, pts, d_cut, jitter=jit_, precision=precision)
    rho_s, rk_s, dd_s, pp_s = seq
    rho_f, rk_f, dd_f, pp_f = fus
    assert bool(jnp.all(rho_f == rho_s)), "fused rho != sequential rho"
    assert bool(jnp.all(rk_f == rk_s)), "fused rho_key != sequential"
    assert bool(jnp.all(pp_f == pp_s)), (
        f"{int(jnp.sum(pp_f != pp_s))} fused parents differ")
    both_inf = jnp.isinf(dd_f) & jnp.isinf(dd_s)
    assert bool(jnp.all((dd_f == dd_s) | both_inf)), "fused delta differs"


def _lattice(n, d, sexp, seed):
    """Integer lattice x power-of-two scale: coordinates, squared distances
    and expanded-form inner products are exact integers well inside the
    bf16-product / f32-sum exact range, so jnp (direct-diff f32), pallas
    (expanded f32) and pallas-bf16 agree bit-for-bit and the interesting
    behavior left is masking and tie-breaking.  Small coords make duplicate
    points (exact distance ties) frequent.  d2cut = (k + .5)*scale^2 never
    ties an integer squared distance."""
    rng = np.random.default_rng(seed)
    pts = rng.integers(0, 13, (n, d)).astype(np.float32) * (2.0 ** sexp)
    d2cut = (float(rng.integers(1, 3 * 13 ** 2)) + 0.5) * (2.0 ** (2 * sexp))
    return jnp.asarray(pts), float(np.sqrt(d2cut))


def _check_lattice_parity(backend, n, d, sexp, seed, precision=None):
    pts, d_cut = _lattice(n, d, sexp, seed)
    seq_be = get_backend("jnp") if precision == "bf16" else None
    _assert_fused_equals_sequential(get_backend(backend), pts, d_cut,
                                    precision=precision, seq_be=seq_be)


SEED_MATRIX = [(17, 2, 0, 0), (96, 3, 3, 1), (64, 4, 6, 2), (2, 2, 0, 3),
               (33, 2, 1, 4)]


class TestFusedParity:
    """fused rho_delta == sequential two-pass, property-tested."""

    @pytest.mark.parametrize("n,d,sexp,seed", SEED_MATRIX)
    @pytest.mark.parametrize("backend", ["jnp", "pallas-interpret"])
    def test_fixed_seeds_f32(self, backend, n, d, sexp, seed):
        _check_lattice_parity(backend, n, d, sexp, seed)

    @pytest.mark.parametrize("n,d,sexp,seed", SEED_MATRIX[:3])
    def test_fixed_seeds_bf16(self, n, d, sexp, seed):
        """bf16 accumulation + f32 refine == the f32 jnp sequential oracle
        on exactly-representable data: mixed precision loses nothing."""
        _check_lattice_parity("pallas-interpret", n, d, sexp, seed,
                              precision="bf16")

    if HAVE_HYPOTHESIS:
        @settings(max_examples=40, deadline=None)
        @given(n=st.integers(2, 96), d=st.integers(2, 4),
               sexp=st.integers(0, 6), seed=st.integers(0, 2 ** 31))
        def test_hypothesis_jnp(self, n, d, sexp, seed):
            _check_lattice_parity("jnp", n, d, sexp, seed)

        @settings(max_examples=12, deadline=None)
        @given(n=st.integers(2, 96), d=st.integers(2, 4),
               sexp=st.integers(0, 6), seed=st.integers(0, 2 ** 31))
        def test_hypothesis_pallas_interpret(self, n, d, sexp, seed):
            _check_lattice_parity("pallas-interpret", n, d, sexp, seed)

        @settings(max_examples=8, deadline=None)
        @given(n=st.integers(2, 96), d=st.integers(2, 4),
               sexp=st.integers(0, 6), seed=st.integers(0, 2 ** 31))
        def test_hypothesis_bf16(self, n, d, sexp, seed):
            _check_lattice_parity("pallas-interpret", n, d, sexp, seed,
                                  precision="bf16")

    @pytest.mark.parametrize("backend", ["jnp", "pallas-interpret"])
    def test_rep_subset_selection(self, backend):
        """y_sel_slots: the NN candidate set restricted to a row subset
        (S-Approx representatives) matches the sequential -inf-key mask."""
        rng = np.random.default_rng(7)
        n, m = 60, 200
        y = jnp.asarray(rng.integers(0, 13, (m, 3)).astype(np.float32) * 8)
        slots = jnp.asarray(np.sort(rng.choice(m, n, replace=False)))
        x = y[slots]
        d_cut = float(np.sqrt(100.5)) * 8
        be = get_backend(backend)
        jit_ = density_jitter(n)
        seq = rho_delta_sequential(be, x, y, d_cut, jitter=jit_,
                                   y_sel_slots=slots)
        fus = be.rho_delta(x, y, d_cut, jitter=jit_, y_sel_slots=slots)
        for a, b, name in zip(seq, fus, ("rho", "rho_key", "delta",
                                         "parent")):
            both_inf = (jnp.isinf(a) & jnp.isinf(b)
                        if a.dtype.kind == "f" else jnp.zeros(a.shape, bool))
            assert bool(jnp.all((a == b) | both_inf)), name

    def test_jnp_backend_rejects_bf16(self):
        pts = jnp.zeros((8, 2), jnp.float32)
        with pytest.raises(ValueError, match="f32"):
            get_backend("jnp").rho_delta(pts, pts, 1.0, precision="bf16")


class TestFusedAdversarial:
    """Scaled near-tie data: expanded-form noise spans several candidate
    orderings, and the fused path must still return the direct-diff winner
    (kept-k + epilogue re-rank extends the refine_topk_d2 contract)."""

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_scaled_blob_parity(self, seed):
        """Same-backend parity on ill-conditioned data: counts near the
        threshold follow the backend's expanded-form contract, so the oracle
        is the *pallas* sequential formulation; what must survive the scale
        is the fused path's winner selection (kept-k + direct-diff refine)."""
        rng = np.random.default_rng(seed)
        pts = (rng.normal(0, 200.0, (384, 2)) + 1e4).astype(np.float32)
        d_cut = 150.0
        _assert_fused_equals_sequential(get_backend("pallas-interpret"),
                                        jnp.asarray(pts), d_cut)

    def test_planted_near_tie(self):
        """True NN at r=30, decoy at r=30.07, offset 5e4: expanded-form
        error (~1e2) dwarfs the gap; the epilogue re-rank must recover the
        true dependent point with its direct-diff distance."""
        rng = np.random.default_rng(0)
        off = np.array([5e4, 5e4], np.float32)
        q = off + np.array([0.0, 0.0], np.float32)
        nn = off + np.array([30.0, 0.0], np.float32)
        decoy = off + np.array([0.0, 30.07], np.float32)
        fillers = off + (rng.uniform(300.0, 2000.0, (61, 2)).astype(np.float32)
                         * rng.choice([-1, 1], (61, 2)))
        pts = jnp.asarray(np.concatenate([[q], [nn], [decoy], fillers]))
        # jitter making q the least dense: its NN search sees all candidates
        n = pts.shape[0]
        jit_ = jnp.arange(n, dtype=jnp.float32) / n
        d_cut = 5000.0
        seq = rho_delta_sequential(get_backend("jnp"), pts, pts, d_cut,
                                   jitter=jit_)
        fus = get_backend("pallas-interpret").rho_delta(pts, pts, d_cut,
                                                        jitter=jit_)
        assert int(fus[3][0]) == int(seq[3][0]) == 1
        assert float(fus[2][0]) == float(seq[2][0])  # direct-diff value


class TestHaloPrimitives:
    """Span-masked engine tiles == the jnp gather form, and both == an
    unrestricted reference when the spans cover the whole window."""

    @staticmethod
    def _spans(rng, m, W, S):
        # per-row *disjoint* spans (the grid's candidate-cell spans are)
        cuts = np.sort(rng.integers(0, W, (m, 2 * S)), axis=1)
        st_ = cuts[:, 0::2].astype(np.int32)
        en = cuts[:, 1::2].astype(np.int32)
        st_[:3] = en[:3] = 0          # empty spans
        st_[3] = en[3] = -9           # negative (padding semantics)
        return st_, en

    @pytest.mark.parametrize("seed", [0, 1])
    def test_count_jnp_vs_pallas(self, seed):
        rng = np.random.default_rng(seed)
        W, m, S, d = 256, 300, 3, 3
        d_cut = 900.0
        window = jnp.asarray(rng.uniform(0, 6 * d_cut, (W, d)), jnp.float32)
        x = jnp.asarray(rng.uniform(0, 6 * d_cut, (m, d)), jnp.float32)
        st_, en = self._spans(rng, m, W, S)
        cap = max(int((en - st_).max()), 1)
        cj = get_backend("jnp").range_count_halo(
            x, window, jnp.asarray(st_), jnp.asarray(en), d_cut, span_cap=cap)
        cp = get_backend("pallas-interpret").range_count_halo(
            x, window, jnp.asarray(st_), jnp.asarray(en), d_cut, span_cap=cap)
        assert bool(jnp.all(cj == cp))

    @pytest.mark.parametrize("seed", [0, 1])
    def test_nn_jnp_vs_pallas(self, seed):
        rng = np.random.default_rng(seed + 10)
        W, m, S, d = 256, 300, 3, 3
        d_cut = 2500.0
        window = jnp.asarray(rng.uniform(0, 6 * d_cut, (W, d)), jnp.float32)
        wk = jnp.asarray(rng.permutation(W).astype(np.float32))
        x = jnp.asarray(rng.uniform(0, 6 * d_cut, (m, d)), jnp.float32)
        xk = jnp.asarray(rng.uniform(0, W, m).astype(np.float32))
        st_, en = self._spans(rng, m, W, S)
        cap = max(int((en - st_).max()), 1)
        args = (x, xk, window, wk, jnp.asarray(st_), jnp.asarray(en), d_cut)
        dj, pj, fj = get_backend("jnp").denser_nn_halo(*args, span_cap=cap)
        dp, pp, fp = get_backend("pallas-interpret").denser_nn_halo(
            *args, span_cap=cap)
        assert bool(jnp.all(fj == fp))
        assert bool(jnp.all(pj == pp))
        both_inf = jnp.isinf(dj) & jnp.isinf(dp)
        assert bool(jnp.all((dj == dp) | both_inf))

    @pytest.mark.parametrize("backend", ["jnp", "pallas-interpret"])
    def test_full_window_spans_match_unrestricted(self, backend):
        """One [0, W) span per row == plain range count / within-d_cut NN."""
        rng = np.random.default_rng(3)
        W, m, d = 192, 128, 2
        d_cut = 2000.0
        window = jnp.asarray(rng.uniform(0, 5 * d_cut, (W, d)), jnp.float32)
        wk = jnp.asarray(rng.permutation(W).astype(np.float32))
        x = window[:m]
        xk = wk[:m]
        st_ = jnp.zeros((m, 1), jnp.int32)
        en = jnp.full((m, 1), W, jnp.int32)
        be = get_backend(backend)
        cnt = be.range_count_halo(x, window, st_, en, d_cut, span_cap=W)
        ref = be.range_count(x, window, d_cut)
        assert bool(jnp.all(cnt == ref))
        dd, pp, ff = be.denser_nn_halo(x, xk, window, wk, st_, en, d_cut,
                                       span_cap=W)
        rd, rp = be.denser_nn(x, xk, window, wk)
        within = jnp.isfinite(rd) & (rd < d_cut)
        # the halo NN only answers within d_cut; beyond it reports unfound
        assert bool(jnp.all(ff == within))
        assert bool(jnp.all(jnp.where(within, pp == rp, pp == -1)))


class TestEngineRegistryFlags:
    def test_fused_traceable_flags(self):
        assert get_backend("jnp").fused_traceable
        assert not get_backend("pallas-interpret").fused_traceable
        assert isinstance(get_backend("jnp"), JnpBackend)
