"""Elastic restart: a checkpoint written on one mesh restores onto another.

Save params+opt on a 4-device (2x2) mesh, restore onto a 2-device (2x1)
mesh and onto a single device, and verify bit-identical values — the
fault-tolerance contract of train/checkpoint.py (checkpoints store logical
global arrays; any mesh whose axes divide the shapes can load them).
"""
import os
import subprocess
import sys

import pytest

_WRITER = r"""
import warnings; warnings.filterwarnings("ignore")
import numpy as np, jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import ARCHS, reduce_config
from repro.models import build_model
from repro.models.common import MeshRules
from repro.train.optimizer import adamw_init, opt_state_specs
from repro.train import checkpoint as ckpt

cfg = reduce_config(ARCHS["gemma-2b"])
model = build_model(cfg)
mesh = jax.make_mesh((2, 2), ("data", "model"))
if hasattr(jax, "set_mesh"):       # jax >= 0.6; shardings below are explicit
    jax.set_mesh(mesh)
rules = MeshRules(data_axes=("data",), model_axis="model",
                  axis_sizes={"data": 2, "model": 2})
psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                   model.param_specs(rules))
params = jax.jit(model.init, out_shardings=psh)(jax.random.PRNGKey(7))
opt = adamw_init(params)
ckpt.save("@DIR@", 5, (params, opt), extras={"step": 5})
tot = float(sum(np.abs(np.asarray(l, np.float32)).sum()
                for l in jax.tree.leaves(params)))
print("SUM", repr(tot))
"""

_READER = r"""
import warnings; warnings.filterwarnings("ignore")
import numpy as np, jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import ARCHS, reduce_config
from repro.models import build_model
from repro.models.common import MeshRules
from repro.train.optimizer import adamw_init
from repro.train import checkpoint as ckpt

cfg = reduce_config(ARCHS["gemma-2b"])
model = build_model(cfg)
n = @NDEV@
params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
opt_shape = jax.eval_shape(adamw_init, params_shape)
shardings = None
if n > 1:
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    if hasattr(jax, "set_mesh"):
        jax.set_mesh(mesh)
    rules = MeshRules(data_axes=("data",), model_axis="model",
                      axis_sizes={"data": n, "model": 1})
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                       model.param_specs(rules))
    rep = NamedSharding(mesh, P())
    shardings = (psh, jax.tree.map(lambda _: rep, opt_shape))
(params, opt), extras = ckpt.restore("@DIR@", 5, (params_shape, opt_shape),
                                     shardings)
assert extras["step"] == 5
tot = float(sum(np.abs(np.asarray(l, np.float32)).sum()
                for l in jax.tree.leaves(params)))
print("SUM", repr(tot))
"""


def _run(code, ndev):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    return float([l for l in proc.stdout.splitlines()
                  if l.startswith("SUM")][0].split(" ", 1)[1])


@pytest.mark.slow
def test_restore_across_mesh_sizes(tmp_path):
    d = str(tmp_path / "ck")
    ref = _run(_WRITER.replace("@DIR@", d), 4)
    got2 = _run(_READER.replace("@DIR@", d).replace("@NDEV@", "2"), 2)
    got1 = _run(_READER.replace("@DIR@", d).replace("@NDEV@", "1"), 1)
    assert got2 == pytest.approx(ref, rel=1e-6)
    assert got1 == pytest.approx(ref, rel=1e-6)
