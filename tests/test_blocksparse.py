"""Block-sparse tile-sweep parity: grid-pruned == dense, bit for bit.

The acceptance contract of the block-sparse execution mode (ISSUE 4): for
every accumulator x mask x backend x precision combination the worklist-
driven sweep must reproduce the dense sweep of the same backend exactly.
Lattice data (integer coords x power-of-two scale) makes every distance
exact in f32 *and* makes duplicate points — exact distance ties — frequent,
so the pruning bounds' conservative slack and the explicit lexicographic NN
tie-breaks are both exercised where they can actually flip answers.

Also here: the adversarial all-in-one-cell case (nothing prunes — the
worklist degenerates to the dense pair set and must still be correct), the
worklist statistics sanity checks, driver/distributed-level layout parity
on tie-free data, and the streaming dirty-tracking contract (queries
actually skipped, parity preserved).
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import DPCConfig, compute_dpc
from repro.core.dpc_types import density_jitter
from repro.core.grid import build_grid
from repro.kernels import get_backend
from repro.kernels.blocksparse import build_flat_worklist, worklist_stats

BACKENDS = ["jnp", "pallas-interpret"]
SEED_MATRIX = [(17, 2, 0, 0), (96, 3, 3, 1), (64, 4, 6, 2), (2, 2, 0, 3),
               (33, 2, 1, 4), (300, 3, 2, 7)]


def _lattice(n, d, sexp, seed):
    """Integer-lattice data (see tests/test_sweep_fused.py): distances are
    exact in every arithmetic, ties are frequent, and the grid sort gives
    the worklist real structure to prune."""
    rng = np.random.default_rng(seed)
    pts = rng.integers(0, 13, (n, d)).astype(np.float32) * (2.0 ** sexp)
    d2cut = (float(rng.integers(1, 3 * 13 ** 2)) + 0.5) * (2.0 ** (2 * sexp))
    d_cut = float(np.sqrt(d2cut))
    grid = build_grid(jnp.asarray(pts), d_cut)
    return grid.points, d_cut


def _eq(a, b):
    a, b = np.asarray(a), np.asarray(b)
    if a.dtype.kind == "f":
        return bool(np.all((a == b) | (np.isinf(a) & np.isinf(b))))
    return bool(np.all(a == b))


class TestEngineParity:
    """backend primitive x layout: block-sparse == dense, bit for bit."""

    @pytest.mark.parametrize("n,d,sexp,seed", SEED_MATRIX)
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_range_count(self, backend, n, d, sexp, seed):
        pts, d_cut = _lattice(n, d, sexp, seed)
        be = get_backend(backend)
        dense = be.range_count(pts, pts, d_cut)
        bs = be.range_count(pts, pts, d_cut, layout="block-sparse")
        assert _eq(dense, bs)

    @pytest.mark.parametrize("n,d,sexp,seed", SEED_MATRIX[:4])
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_range_count_delta_signed(self, backend, n, d, sexp, seed):
        pts, d_cut = _lattice(n, d, sexp, seed)
        rng = np.random.default_rng(seed)
        signs = jnp.asarray(rng.choice([-1.0, 0.0, 1.0], n)
                            .astype(np.float32))
        be = get_backend(backend)
        dense = be.range_count_delta(pts, pts, signs, d_cut)
        bs = be.range_count_delta(pts, pts, signs, d_cut,
                                  layout="block-sparse")
        assert _eq(dense, bs)

    @pytest.mark.parametrize("n,d,sexp,seed", SEED_MATRIX)
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_denser_nn(self, backend, n, d, sexp, seed):
        """best-1 + strictly-denser key mask, runtime ring pruning; the
        lattice duplicates force the lexicographic (d2, col) tie-break."""
        pts, d_cut = _lattice(n, d, sexp, seed)
        rng = np.random.default_rng(seed + 1)
        rk = jnp.asarray(rng.permutation(n).astype(np.float32))
        be = get_backend(backend)
        dd, dp = be.denser_nn(pts, rk, pts, rk)
        sd, sp = be.denser_nn(pts, rk, pts, rk, layout="block-sparse")
        assert _eq(dp, sp)
        assert _eq(dd, sd)

    @pytest.mark.parametrize("n,d,sexp,seed", SEED_MATRIX)
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_rho_delta_fused(self, backend, n, d, sexp, seed):
        pts, d_cut = _lattice(n, d, sexp, seed)
        be = get_backend(backend)
        jit_ = density_jitter(n)
        dense = be.rho_delta(pts, pts, d_cut, jitter=jit_)
        bs = be.rho_delta(pts, pts, d_cut, jitter=jit_,
                          layout="block-sparse")
        for a, b, name in zip(dense, bs, ("rho", "rho_key", "delta",
                                          "parent")):
            assert _eq(a, b), f"fused {name} differs under block-sparse"

    @pytest.mark.parametrize("n,d,sexp,seed", SEED_MATRIX[:3])
    def test_rho_delta_fused_bf16(self, n, d, sexp, seed):
        """precision axis: the bf16 inner-product path prunes identically
        (bounds compare against f32 values; winners are f32-refined)."""
        pts, d_cut = _lattice(n, d, sexp, seed)
        be = get_backend("pallas-interpret")
        jit_ = density_jitter(n)
        dense = be.rho_delta(pts, pts, d_cut, jitter=jit_, precision="bf16")
        bs = be.rho_delta(pts, pts, d_cut, jitter=jit_, precision="bf16",
                          layout="block-sparse")
        for a, b in zip(dense, bs):
            assert _eq(a, b)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_rho_delta_rep_subset(self, backend):
        """nn_sel mask (S-Approx representatives): the static kept-k ring
        must count only admissible columns, or it would over-prune."""
        rng = np.random.default_rng(7)
        n, m = 60, 200
        y_np = rng.integers(0, 13, (m, 3)).astype(np.float32) * 8
        d_cut = float(np.sqrt(100.5)) * 8
        y = build_grid(jnp.asarray(y_np), d_cut).points
        slots = jnp.asarray(np.sort(rng.choice(m, n, replace=False)))
        x = y[slots]
        be = get_backend(backend)
        jit_ = density_jitter(n)
        dense = be.rho_delta(x, y, d_cut, jitter=jit_, y_sel_slots=slots)
        bs = be.rho_delta(x, y, d_cut, jitter=jit_, y_sel_slots=slots,
                          layout="block-sparse")
        for a, b in zip(dense, bs):
            assert _eq(a, b)


class TestHaloParity:
    """span-masked primitives: worklist pruning by span reach AND d_cut."""

    @staticmethod
    def _case(seed, W=256, m=300, S=3, d=3):
        rng = np.random.default_rng(seed)
        window = jnp.asarray(rng.integers(0, 50, (W, d))
                             .astype(np.float32) * 64)
        x = jnp.asarray(rng.integers(0, 50, (m, d)).astype(np.float32) * 64)
        xk = jnp.asarray(rng.uniform(0, W, m).astype(np.float32))
        wk = jnp.asarray(rng.permutation(W).astype(np.float32))
        cuts = np.sort(rng.integers(0, W, (m, 2 * S)), axis=1)
        st = cuts[:, 0::2].astype(np.int32)
        en = cuts[:, 1::2].astype(np.int32)
        st[:3] = en[:3] = 0
        st[3] = en[3] = -9
        return (window, x, xk, wk, jnp.asarray(st), jnp.asarray(en),
                int(max((en - st).max(), 1)))

    @pytest.mark.parametrize("seed", [0, 1])
    def test_halo_count(self, seed):
        window, x, _, _, st, en, cap = self._case(seed)
        be = get_backend("pallas-interpret")
        d_cut = 900.0
        dense = be.range_count_halo(x, window, st, en, d_cut, span_cap=cap)
        bs = be.range_count_halo(x, window, st, en, d_cut, span_cap=cap,
                                 layout="block-sparse")
        assert _eq(dense, bs)

    @pytest.mark.parametrize("seed", [0, 1])
    def test_halo_nn(self, seed):
        window, x, xk, wk, st, en, cap = self._case(seed + 10)
        be = get_backend("pallas-interpret")
        d_cut = 900.0
        dense = be.denser_nn_halo(x, xk, window, wk, st, en, d_cut,
                                  span_cap=cap)
        bs = be.denser_nn_halo(x, xk, window, wk, st, en, d_cut,
                               span_cap=cap, layout="block-sparse")
        for a, b in zip(dense, bs):
            assert _eq(a, b)


class TestDegenerateWorklists:
    """All points in one grouping cell: nothing prunes, the worklist is the
    dense pair set, and the engine must behave exactly as worklist=None."""

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_single_cell_parity(self, backend):
        rng = np.random.default_rng(2)
        n = 400
        # spread << cell side: one grouping cell, every tile pair kept
        pts_np = rng.integers(0, 4, (n, 3)).astype(np.float32)
        d_cut = float(np.sqrt(3 * 16 + 0.5)) * 4
        grid = build_grid(jnp.asarray(pts_np), d_cut)
        assert grid.num_cells == 1
        pts = grid.points
        be = get_backend(backend)
        jit_ = density_jitter(n)
        dense = be.rho_delta(pts, pts, d_cut, jitter=jit_)
        bs = be.rho_delta(pts, pts, d_cut, jitter=jit_,
                          layout="block-sparse")
        for a, b in zip(dense, bs):
            assert _eq(a, b)

    def test_single_cell_worklist_is_dense(self):
        rng = np.random.default_rng(2)
        pts = rng.integers(0, 4, (400, 3)).astype(np.float32)
        wl = build_flat_worklist(pts, pts, 1e6, block_n=128, block_m=128,
                                 count=True)
        assert wl.n_kept == wl.n_total
        assert wl.pruned_frac == 0.0

    def test_separated_clusters_prune(self):
        """Far-apart clusters with a small d_cut: the count worklist must
        actually drop cross-cluster tile pairs."""
        rng = np.random.default_rng(3)
        a = rng.normal(0, 1, (512, 3)).astype(np.float32)
        b = rng.normal(0, 1, (512, 3)).astype(np.float32) + 1000.0
        pts = np.concatenate([a, b])
        stats = worklist_stats(pts, pts, 5.0, block_n=128, block_m=128)
        assert stats["pruned_tile_frac"] >= 0.4
        # and the pruned sweep still counts correctly
        be = get_backend("jnp")
        dense = be.range_count(jnp.asarray(pts), jnp.asarray(pts), 5.0)
        bs = be.range_count(jnp.asarray(pts), jnp.asarray(pts), 5.0,
                            layout="block-sparse")
        assert _eq(dense, bs)

    def test_worklist_always_initializes_rows(self):
        """Row tiles with nothing in range still appear once (their output
        blocks must initialize): counts are exact zeros, not garbage."""
        pts = np.zeros((300, 2), np.float32)
        pts[200:] = 1e6                 # far tile: nothing within d_cut
        be = get_backend("pallas-interpret")
        dense = be.range_count(jnp.asarray(pts), jnp.asarray(pts[:200]), 1.0)
        bs = be.range_count(jnp.asarray(pts), jnp.asarray(pts[:200]), 1.0,
                            layout="block-sparse")
        assert _eq(dense, bs)


class TestTraceability:
    def test_jnp_worklists_are_jit_safe(self):
        import jax
        be = get_backend("jnp")
        assert be.worklist_traceable
        pts = jnp.asarray(np.random.default_rng(0)
                          .uniform(0, 100, (200, 3)).astype(np.float32))
        f = jax.jit(lambda p: be.range_count(p, p, 10.0,
                                             layout="block-sparse"))
        assert _eq(f(pts), be.range_count(pts, pts, 10.0))

    def test_pallas_worklists_require_host(self):
        import jax
        be = get_backend("pallas-interpret")
        assert not be.worklist_traceable
        pts = jnp.zeros((64, 2), jnp.float32)
        with pytest.raises(ValueError, match="host"):
            jax.jit(lambda p: be.range_count(p, p, 1.0,
                                             layout="block-sparse"))(pts)

    def test_unknown_layout_rejected(self):
        pts = jnp.zeros((8, 2), jnp.float32)
        with pytest.raises(ValueError, match="layout"):
            get_backend("jnp").range_count(pts, pts, 1.0, layout="sparse")


class TestDriverParity:
    """Driver-level layout parity on tie-free data (random floats: parents
    are unique, so original-order vs sorted-order tie-breaks coincide)."""

    @pytest.mark.parametrize("algo", ["scan", "exdpc", "approxdpc",
                                      "sapproxdpc"])
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_algorithms(self, algo, backend):
        rng = np.random.default_rng(3)
        pts = rng.uniform(0, 50 * 900.0, (500, 3)).astype(np.float32)
        base = DPCConfig(d_cut=4000.0, algorithm=algo, backend=backend)
        a = compute_dpc(pts, base)
        b = compute_dpc(pts, DPCConfig(d_cut=4000.0, algorithm=algo,
                                       backend=backend,
                                       layout="block-sparse"))
        assert _eq(a.rho, b.rho)
        assert _eq(a.parent, b.parent)
        assert _eq(a.delta, b.delta)

    def test_distributed(self):
        from jax.sharding import Mesh
        import jax
        from repro.distributed.dpc import DistDPCConfig, distributed_dpc
        rng = np.random.default_rng(5)
        pts = rng.uniform(0, 20000.0, (400, 3)).astype(np.float32)
        mesh = Mesh(np.array(jax.devices()), ("data",))
        a = distributed_dpc(pts, DistDPCConfig(d_cut=2500.0, backend="jnp"),
                            mesh)
        b = distributed_dpc(pts, DistDPCConfig(d_cut=2500.0, backend="jnp",
                                               layout="block-sparse"), mesh)
        assert _eq(a.rho, b.rho)
        assert _eq(a.parent, b.parent)
        assert _eq(a.delta, b.delta)


class TestStreamDirtyTracking:
    """Per-cell dirty tracking: clean-cell maxima reuse cached NN answers —
    parity must hold AND queries must actually be skipped."""

    @staticmethod
    def _drive(dirty_tracking, rng):
        from repro.stream import StreamDPC, StreamDPCConfig
        cfg = StreamDPCConfig(d_cut=2.0, capacity=512, batch_cap=16,
                              dirty_tracking=dirty_tracking)
        s = StreamDPC(cfg)
        centers = rng.uniform(0, 120, (12, 2))
        pts = (centers[rng.integers(0, 12, 512)]
               + rng.normal(0, 0.5, (512, 2))).astype(np.float32)
        s.initialize(pts)
        for t in range(8):
            c = centers[t % 12]
            batch = (c + rng.normal(0, 0.5, (16, 2))).astype(np.float32)
            s.ingest(batch)
        return s

    def test_parity_and_savings(self):
        rng = np.random.default_rng(0)
        s = self._drive(True, rng)
        # parity vs a from-scratch solve of the final window
        from repro.core.approxdpc import run_approxdpc
        ref = run_approxdpc(jnp.asarray(s.window_points()), s.cfg.d_cut,
                            exec_spec=s.plan.spec)
        assert _eq(s.result.rho, ref.rho)
        assert _eq(s.result.parent, ref.parent)
        assert _eq(s.result.delta, ref.delta)
        st = s.stats()
        assert st["nn_queries"] < st["nn_maxima_total"], \
            "dirty tracking never skipped a maxima query"

    def test_matches_undirtied_stream(self):
        """Tick-for-tick label equality with tracking off."""
        a = self._drive(True, np.random.default_rng(1))
        b = self._drive(False, np.random.default_rng(1))
        assert np.array_equal(a._last.labels, b._last.labels)
        assert _eq(a.result.delta, b.result.delta)
        assert _eq(a.result.parent, b.result.parent)
