"""repro.resilience: checkpoints, transactional ticks, quarantine, degrade.

Acceptance contract (ISSUE 9): for every fault-injection site, a mid-tick
kill + restore yields bit-identical rho/delta/labels/center-ids versus the
uninterrupted run; and a poisoned (NaN/Inf) batch under each quarantine
policy never changes the labels of already-windowed points.
"""
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro import obs
from repro.data.points import gaussian_mixture
from repro.engine import ExecSpec
from repro.engine.dpc_engine import DPCEngine
from repro.engine.planner import plan, plan_cache_clear
from repro.resilience import checkpoint, degrade, faultinject
from repro.resilience.sanitize import (AdmissionConfig, PoisonedInputError,
                                       admit, finite_or)
from repro.stream import (QueryStatus, StreamDPC, StreamDPCConfig,
                          StreamServeConfig, StreamService)

CAP, B, D_CUT, RHO_MIN = 512, 64, 8000.0, 3.0


def _cfg(backend="jnp", **kw):
    base = dict(d_cut=D_CUT, capacity=CAP, batch_cap=B, rho_min=RHO_MIN,
                exec_spec=ExecSpec(backend=backend))
    base.update(kw)
    return StreamDPCConfig(**base)


def _data(ticks=3, seed=2):
    pts, _ = gaussian_mixture(CAP + ticks * B, k=4, d=2, overlap=0.05,
                              seed=seed)
    return pts


def _stream(backend="jnp", ticks=2, seed=2, **kw):
    pts = _data(ticks=ticks, seed=seed)
    s = StreamDPC(_cfg(backend, **kw))
    s.initialize(pts[:CAP])
    for t in range(ticks):
        s.ingest(pts[CAP + t * B: CAP + (t + 1) * B])
    return s, pts


@pytest.fixture(autouse=True)
def _no_leftover_faults():
    """Every test starts and ends with no armed fault plan."""
    faultinject.deactivate()
    yield
    faultinject.deactivate()


# --------------------------------------------------------------- sanitize
class TestSanitize:
    def test_clean_points_pass_untouched(self):
        pts = np.array([[1.0, 2.0], [9e8, -9e8]], np.float32)
        for policy in ("reject", "drop", "clamp"):
            out = admit(pts, AdmissionConfig(policy=policy))
            assert np.array_equal(out.points, pts)
            assert out.keep.all() and out.quarantined == 0

    def test_reject_raises_and_names_the_row(self):
        pts = np.array([[1.0, 2.0], [np.nan, 0.0]], np.float32)
        with pytest.raises(PoisonedInputError, match="row 1"):
            admit(pts, AdmissionConfig())

    def test_drop_keeps_alignment_mask(self):
        pts = np.array([[1.0, 1.0], [np.inf, 0.0], [2.0, 2.0], [2e9, 0.0]],
                       np.float32)
        out = admit(pts, AdmissionConfig(policy="drop"))
        assert out.keep.tolist() == [True, False, True, False]
        assert np.array_equal(out.points, pts[[0, 2]])
        assert out.quarantined == 2

    def test_clamp_repairs_in_place(self):
        pts = np.array([[np.nan, np.inf], [-np.inf, 3.0], [2e9, -2e9]],
                       np.float32)
        out = admit(pts, AdmissionConfig(policy="clamp"))
        assert out.keep.all() and out.quarantined == 3
        assert np.isfinite(out.points).all()
        assert (np.abs(out.points) < 1e9).all()
        assert out.points[0, 0] == 0.0          # NaN -> 0
        assert out.points[1, 1] == 3.0          # finite coords untouched

    def test_bad_dtype_rejected_under_every_policy(self):
        for policy in ("reject", "drop", "clamp"):
            with pytest.raises(PoisonedInputError, match="dtype"):
                admit(np.array([["a", "b"]]), AdmissionConfig(policy=policy))

    def test_out_of_range_bound_is_open_at_pad_coord(self):
        # 1e9 == PAD_COORD must quarantine; just below it must pass (the
        # serve miss-fallback tests probe with 9e8 coordinates)
        with pytest.raises(PoisonedInputError):
            admit(np.array([[1e9, 0.0]]), AdmissionConfig())
        out = admit(np.array([[9e8, 0.0]]), AdmissionConfig())
        assert out.quarantined == 0

    def test_policy_validation(self):
        with pytest.raises(ValueError, match="policy"):
            AdmissionConfig(policy="ignore")
        with pytest.raises(ValueError, match="max_abs"):
            AdmissionConfig(max_abs=0.0)

    def test_quarantine_counter_increments(self):
        m = obs.counter("resilience_quarantined_points", "")
        before = m.value(reason="non_finite", policy="drop",
                         where="unit") or 0
        admit(np.array([[np.nan, 0.0]]), AdmissionConfig(policy="drop"),
              where="unit")
        after = m.value(reason="non_finite", policy="drop", where="unit")
        assert after == before + 1

    def test_finite_or_under_jit(self):
        import jax
        f = jax.jit(lambda x: finite_or(x, 7.0))
        x = jnp.array([1.0, jnp.inf, -jnp.inf, jnp.nan])
        assert np.array_equal(np.asarray(f(x)), [1.0, 7.0, 7.0, 7.0])


# ------------------------------------------------------------ faultinject
class TestFaultInject:
    def test_fires_on_nth_hit(self):
        faultinject.activate("tick.finish", trigger=3)
        faultinject.fire("tick.finish")
        faultinject.fire("tick.finish")
        with pytest.raises(faultinject.FaultError):
            faultinject.fire("tick.finish")
        # one-shot: hit 4 does not re-fire
        faultinject.fire("tick.finish")

    def test_trigger_zero_fires_every_hit(self):
        faultinject.activate("kernel.dispatch", trigger=0)
        for _ in range(3):
            with pytest.raises(faultinject.FaultError):
                faultinject.fire("kernel.dispatch")

    def test_other_sites_unaffected(self):
        faultinject.activate("tick.rho_repair", trigger=1)
        faultinject.fire("tick.finish")
        faultinject.fire("checkpoint.write")

    def test_seed_trigger_is_deterministic(self):
        t1 = faultinject.activate("tick.finish", seed=7).trigger
        t2 = faultinject.activate("tick.finish", seed=7).trigger
        t3 = faultinject.activate("tick.finish", seed=8).trigger
        assert t1 == t2 and t1 >= 1 and t3 >= 1

    def test_unknown_site_or_mode_fails_loudly(self):
        with pytest.raises(ValueError, match="unknown fault site"):
            faultinject.activate("tick.typo")
        with pytest.raises(ValueError, match="unknown fault mode"):
            faultinject.activate("tick.finish", mode="explode")

    def test_corrupt_mode_never_raises_at_fire(self):
        faultinject.activate("checkpoint.write", mode="corrupt", trigger=1)
        faultinject.fire("checkpoint.write")
        assert faultinject.should_corrupt("checkpoint.write")
        assert not faultinject.should_corrupt("checkpoint.serialize")


# ---------------------------------------------------- transactional ingest
class TestTransactionalIngest:
    @pytest.mark.parametrize("site", ["tick.grid_apply", "tick.rho_repair",
                                      "tick.nn_update", "tick.finish"])
    def test_failed_tick_rolls_back_and_replays_bit_identical(self, site):
        pts = _data(ticks=2)
        control = StreamDPC(_cfg())
        control.initialize(pts[:CAP])
        control.ingest(pts[CAP: CAP + B])
        t_ref = control.ingest(pts[CAP + B: CAP + 2 * B])

        s = StreamDPC(_cfg())
        s.initialize(pts[:CAP])
        s.ingest(pts[CAP: CAP + B])
        pre_host = s.window.host.copy()
        pre_rho = np.asarray(s._rho).copy()
        pre_stats = s.stats()
        faultinject.activate(site, trigger=1)
        with pytest.raises(faultinject.FaultError):
            s.ingest(pts[CAP + B: CAP + 2 * B])
        faultinject.deactivate()
        # rollback: window/grid/rho/counters exactly pre-tick
        assert np.array_equal(s.window.host, pre_host)
        assert np.array_equal(np.asarray(s._rho), pre_rho)
        assert s.stats() == pre_stats
        # replaying the failed batch matches the never-faulted control
        t = s.ingest(pts[CAP + B: CAP + 2 * B])
        assert np.array_equal(t.labels, t_ref.labels)
        assert np.array_equal(t.stable_ids, t_ref.stable_ids)
        assert np.array_equal(np.asarray(s._rho), np.asarray(control._rho))
        assert np.array_equal(np.asarray(s.result.delta),
                              np.asarray(control.result.delta))

    def test_transactional_off_skips_snapshots(self):
        s, _ = _stream(ticks=1, transactional=False)
        pts = _data(ticks=2)
        faultinject.activate("tick.finish", trigger=1)
        with pytest.raises(faultinject.FaultError):
            s.ingest(pts[CAP + B: CAP + 2 * B])


# ------------------------------------------------------------ edge inputs
class TestEdgeInputs:
    def test_empty_ingest_is_a_noop(self):
        s, _ = _stream(ticks=1)
        last = s._last
        ticks = s._ticks
        assert s.ingest(np.zeros((0, 2), np.float32)) is last
        assert s._ticks == ticks

    def test_initialize_overfill_raises(self):
        s = StreamDPC(_cfg())
        with pytest.raises(ValueError, match="capacity"):
            s.initialize(np.zeros((CAP + 1, 2), np.float32))

    def test_dim_mismatch_raises(self):
        s, _ = _stream(ticks=1)
        with pytest.raises(ValueError, match="dimensionality"):
            s.ingest(np.zeros((4, 3), np.float32))

    def test_empty_submit_and_flush(self):
        svc = StreamService(StreamServeConfig(stream=_cfg()))
        assert svc.submit(np.zeros((0, 2), np.float32)) == []
        assert svc.flush() is None
        assert svc.stats()["buffered"] == 0


# ------------------------------------------------------- admission control
class TestAdmission:
    def _service(self, policy):
        pts = _data(ticks=1)
        svc = StreamService(StreamServeConfig(
            stream=_cfg(), admission=AdmissionConfig(policy=policy)))
        svc.engine.initialize(pts[:CAP])
        return svc, pts

    def test_reject_poisoned_submit_leaves_state_untouched(self):
        svc, pts = self._service("reject")
        before = svc.engine._last
        bad = pts[CAP: CAP + B].copy()
        bad[3, 0] = np.nan
        with pytest.raises(PoisonedInputError):
            svc.submit(bad)
        assert svc.engine._last is before
        assert svc.stats()["buffered"] == 0

    def test_drop_all_poisoned_batch_is_a_noop(self):
        svc, _ = self._service("drop")
        before = svc.engine._last
        bad = np.full((B, 2), np.nan, np.float32)
        assert svc.submit(bad) == []
        assert svc.engine._last is before
        assert svc.stats()["buffered"] == 0

    def test_drop_mixed_batch_equals_clean_only_ingest(self):
        svc, pts = self._service("drop")
        batch = pts[CAP: CAP + B].copy()
        batch[5, 1] = np.inf
        batch[17, 0] = np.nan
        svc.submit(batch)
        clean = np.delete(pts[CAP: CAP + B], [5, 17], axis=0)
        ref = StreamDPC(_cfg())
        ref.initialize(pts[:CAP])
        ref.ingest(clean)        # partial tick buffered in svc: flush first
        tick = svc.flush()
        assert np.array_equal(tick.labels, ref._last.labels)
        assert np.array_equal(tick.stable_ids, ref._last.stable_ids)

    def test_clamp_equals_presanitized_ingest(self):
        svc, pts = self._service("clamp")
        batch = pts[CAP: CAP + B].copy()
        batch[0, 0] = np.nan
        batch[1, 1] = np.inf
        ticks = svc.submit(batch)
        assert len(ticks) == 1
        fixed = admit(batch, AdmissionConfig(policy="clamp")).points
        ref = StreamDPC(_cfg())
        ref.initialize(pts[:CAP])
        t_ref = ref.ingest(fixed)
        assert np.array_equal(ticks[0].labels, t_ref.labels)
        assert np.array_equal(ticks[0].stable_ids, t_ref.stable_ids)

    def test_admission_disabled_passes_through(self):
        svc = StreamService(StreamServeConfig(stream=_cfg(), admission=None))
        svc.submit(np.full((4, 2), 42.0, np.float32))
        assert svc.stats()["buffered"] == 4

    @pytest.mark.parametrize("backend", ["jnp", "pallas-interpret"])
    def test_query_quarantines_non_finite_rows(self, backend):
        pts = _data(ticks=1)
        svc = StreamService(StreamServeConfig(stream=_cfg(backend)))
        svc.engine.initialize(pts[:CAP])
        q = np.array([pts[0], [np.nan, 1.0], [np.inf, -np.inf]], np.float32)
        out = svc.query(q)
        assert out.status[0] == int(QueryStatus.HIT)
        assert (out.status[1:] == int(QueryStatus.QUARANTINED)).all()
        assert (out.labels[1:] == -1).all()

    def test_engine_fit_rejects_poison(self):
        pts = _data(ticks=0)[:256].copy()
        pts[7, 0] = np.nan
        eng = DPCEngine(d_cut=D_CUT, rho_min=RHO_MIN,
                        exec_spec=ExecSpec(backend="jnp"))
        with pytest.raises(PoisonedInputError):
            eng.fit(pts)

    def test_engine_predict_drop_expands_quarantined_rows(self):
        pts = _data(ticks=0)
        eng = DPCEngine(d_cut=D_CUT, rho_min=RHO_MIN,
                        exec_spec=ExecSpec(backend="jnp"),
                        admission=AdmissionConfig(policy="drop"))
        eng.fit(pts[:256])
        q = np.array([pts[0], [np.nan, 0.0], pts[1]], np.float32)
        out = eng.predict(q)
        assert len(out.labels) == 3
        assert out.status[1] == int(QueryStatus.QUARANTINED)
        assert out.labels[1] == -1
        clean = eng.predict(np.array([pts[0], pts[1]], np.float32))
        assert np.array_equal(out.labels[[0, 2]], clean.labels)

    def test_engine_partial_fit_quarantined_batch_is_noop(self):
        pts = _data(ticks=1)
        eng = DPCEngine(d_cut=D_CUT, rho_min=RHO_MIN, window_capacity=CAP,
                        batch_cap=B, exec_spec=ExecSpec(backend="jnp"),
                        admission=AdmissionConfig(policy="drop"))
        eng.partial_fit(pts[:CAP])
        last = eng.stream._last
        out = eng.partial_fit(np.full((8, 2), np.inf, np.float32))
        assert out is last


# ------------------------------------------------------------- checkpoints
class TestCheckpoint:
    @pytest.mark.parametrize("backend", ["jnp", "pallas-interpret"])
    def test_restore_ticks_bit_identical(self, backend, tmp_path):
        ticks = 3
        pts = _data(ticks=ticks)
        ref = StreamDPC(_cfg(backend))
        ref.initialize(pts[:CAP])
        for t in range(ticks):
            t_ref = ref.ingest(pts[CAP + t * B: CAP + (t + 1) * B])

        s = StreamDPC(_cfg(backend))
        s.initialize(pts[:CAP])
        s.ingest(pts[CAP: CAP + B])
        p = str(tmp_path / "ckpt.npz")
        s.save(p)
        r = StreamDPC.restore(p)
        assert r.stats() == s.stats()
        for t in range(1, ticks):
            tick = r.ingest(pts[CAP + t * B: CAP + (t + 1) * B])
        assert np.array_equal(tick.labels, t_ref.labels)
        assert np.array_equal(tick.stable_ids, t_ref.stable_ids)
        assert np.array_equal(np.asarray(r._rho), np.asarray(ref._rho))
        assert np.array_equal(np.asarray(r.result.delta),
                              np.asarray(ref.result.delta))
        assert np.array_equal(np.asarray(r.result.parent),
                              np.asarray(ref.result.parent))

    def test_warmup_state_round_trips(self, tmp_path):
        pts = _data(ticks=0)
        s = StreamDPC(_cfg())
        s.initialize(pts[: CAP // 2])       # below capacity: grid unbuilt
        p = str(tmp_path / "warm.npz")
        s.save(p)
        r = StreamDPC.restore(p)
        t1 = r.ingest(pts[CAP // 2: CAP // 2 + B])
        t2 = s.ingest(pts[CAP // 2: CAP // 2 + B])
        assert np.array_equal(t1.labels, t2.labels)

    def test_save_before_data_raises(self, tmp_path):
        with pytest.raises(ValueError, match="window state"):
            StreamDPC(_cfg()).save(str(tmp_path / "x.npz"))

    def test_atomic_write_keeps_previous_checkpoint(self, tmp_path):
        s, pts = _stream(ticks=2)
        p = str(tmp_path / "ckpt.npz")
        s.save(p)
        ticks_saved = s._ticks
        s.ingest(pts[CAP + B: CAP + 2 * B])
        faultinject.activate("checkpoint.write", trigger=1)
        with pytest.raises(faultinject.FaultError):
            s.save(p)
        faultinject.deactivate()
        r = StreamDPC.restore(p)        # previous file intact + readable
        assert r._ticks == ticks_saved

    def test_corrupted_file_raises_checkpoint_error(self, tmp_path):
        s, _ = _stream(ticks=1)
        p = str(tmp_path / "ckpt.npz")
        faultinject.activate("checkpoint.write", mode="corrupt", trigger=1)
        s.save(p)
        faultinject.deactivate()
        with pytest.raises(checkpoint.CheckpointError):
            StreamDPC.restore(p)

    def test_garbage_file_raises_checkpoint_error(self, tmp_path):
        p = tmp_path / "junk.npz"
        p.write_bytes(b"not a checkpoint")
        with pytest.raises(checkpoint.CheckpointError):
            StreamDPC.restore(str(p))

    def test_future_version_raises_checkpoint_error(self, tmp_path):
        import json
        meta = {"format": checkpoint.FORMAT, "version": checkpoint.VERSION + 1}
        p = str(tmp_path / "future.npz")
        np.savez(p, meta=np.frombuffer(json.dumps(meta).encode(), np.uint8))
        with pytest.raises(checkpoint.CheckpointError, match="version"):
            StreamDPC.restore(p)


# -------------------------------------------------------------- degradation
class TestDegrade:
    def test_pallas_degrades_to_interpret_on_cpu(self, monkeypatch):
        # natural degradation: no TPU, Mosaic cannot compile
        monkeypatch.setenv("REPRO_ANALYSIS", "0")
        assert degrade.resolve_backend("pallas") == "pallas-interpret"
        pl = plan(None, ExecSpec(backend="pallas"))
        assert pl.backend_name == "pallas-interpret"
        m = obs.counter("resilience_degrade_total", "")
        assert any("src=pallas" in k for k in m._vals), \
            "degrade counter never incremented"

    def test_forced_full_chain_lands_on_jnp(self):
        faultinject.activate("degrade.probe", trigger=0)
        degrade.reset()
        try:
            assert degrade.resolve_backend("pallas") == "jnp"
        finally:
            faultinject.deactivate()
            degrade.reset()

    def test_bf16_never_degrades_to_jnp(self):
        faultinject.activate("degrade.probe", trigger=0)
        degrade.reset()
        try:
            with pytest.raises(RuntimeError, match="bf16"):
                degrade.resolve_backend("pallas", precision="bf16")
        finally:
            faultinject.deactivate()
            degrade.reset()

    def test_degrade_disabled_returns_request_unprobed(self, monkeypatch):
        monkeypatch.setenv("REPRO_DEGRADE", "0")
        assert degrade.resolve_backend("pallas") == "pallas"

    def test_jnp_and_auto_never_probe(self):
        assert degrade.resolve_backend("jnp") == "jnp"
        # auto on CPU resolves to jnp before the chain is consulted
        assert degrade.resolve_backend(None) == "jnp"


# ------------------------------------------------------------- chaos suite
# A subprocess runs the stream with checkpoints after every tick and an
# env-armed kill fault; the parent restores from the last checkpoint and
# proves the resumed run is bit-identical to an uninterrupted one.
_CHAOS_SCRIPT = r"""
import sys, warnings
warnings.filterwarnings("ignore")
import numpy as np
from repro.data.points import gaussian_mixture
from repro.engine import ExecSpec
from repro.stream import StreamDPC, StreamDPCConfig

ckpt, backend = sys.argv[1], sys.argv[2]
CAP, B, TICKS = 512, 64, 4
pts, _ = gaussian_mixture(CAP + TICKS * B, k=4, d=2, overlap=0.05, seed=2)
s = StreamDPC(StreamDPCConfig(d_cut=8000.0, capacity=CAP, batch_cap=B,
                              rho_min=3.0,
                              exec_spec=ExecSpec(backend=backend)))
s.initialize(pts[:CAP])
s.save(ckpt)
for t in range(TICKS):
    s.ingest(pts[CAP + t * B: CAP + (t + 1) * B])   # env fault kills here
    s.save(ckpt)
print("SURVIVED")   # only reached when no fault is armed
"""

_SHARDED_CKPT_SCRIPT = r"""
import json, warnings
warnings.filterwarnings("ignore")
import numpy as np, jax
from repro.data.points import gaussian_mixture
from repro.engine import ExecSpec
from repro.stream import StreamDPC, StreamDPCConfig
import sys

ckpt = sys.argv[1]
assert jax.device_count() == 4
CAP, B = 512, 64
mesh = jax.make_mesh((2, 2), ("data", "model"))
pts, _ = gaussian_mixture(CAP + 3 * B, k=4, d=2, overlap=0.05, seed=2)
s = StreamDPC(StreamDPCConfig(d_cut=8000.0, capacity=CAP, batch_cap=B,
                              rho_min=3.0,
                              exec_spec=ExecSpec(backend="jnp")), mesh=mesh)
s.initialize(pts[:CAP])
for t in range(2):
    s.ingest(pts[CAP + t * B: CAP + (t + 1) * B])
s.save(ckpt)                    # checkpoint of a 4-device sharded stream
tick = s.ingest(pts[CAP + 2 * B: CAP + 3 * B])
out = {"labels": tick.labels.tolist(),
       "stable": tick.stable_ids.tolist(),
       "rho": np.asarray(s._rho).tolist(),
       "delta": np.asarray(s.result.delta).tolist()}
print("RESULT" + json.dumps(out))
"""


def _run_chaos(tmp_path, site, trigger, backend="jnp"):
    import subprocess
    import sys

    ckpt = str(tmp_path / f"chaos-{site.replace('.', '-')}.npz")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    env["REPRO_FAULT_SITE"] = site
    env["REPRO_FAULT_MODE"] = "kill"
    env["REPRO_FAULT_TRIGGER"] = str(trigger)
    proc = subprocess.run([sys.executable, "-c", _CHAOS_SCRIPT, ckpt,
                           backend], env=env, capture_output=True,
                          text=True, timeout=900)
    return proc, ckpt


class TestChaosCrashRestore:
    @pytest.mark.slow
    @pytest.mark.parametrize("site,trigger", [
        ("tick.grid_apply", 2), ("tick.rho_repair", 2),
        ("tick.nn_update", 2),
        # initialize's full tick hits tick.finish once already
        ("tick.finish", 3),
        # between the temp write and the rename: the old file must survive
        ("checkpoint.write", 3),
    ])
    def test_kill_restore_parity(self, site, trigger, tmp_path):
        """Kill the stream mid-tick at every injection site, restore from
        the last checkpoint, replay — bit-identical to uninterrupted."""
        CAP_, B_, TICKS = 512, 64, 4
        pts = _data(ticks=TICKS)
        ref = StreamDPC(_cfg())
        ref.initialize(pts[:CAP_])
        for t in range(TICKS):
            t_ref = ref.ingest(pts[CAP_ + t * B_: CAP_ + (t + 1) * B_])

        proc, ckpt = _run_chaos(tmp_path, site, trigger)
        assert proc.returncode == faultinject.KILL_EXIT_CODE, \
            (proc.returncode, proc.stderr[-2000:])
        assert "SURVIVED" not in proc.stdout
        r = StreamDPC.restore(ckpt)
        done = r.stats()["ticks"] - 1      # initialize counts one tick
        assert 0 <= done < TICKS
        for t in range(done, TICKS):
            tick = r.ingest(pts[CAP_ + t * B_: CAP_ + (t + 1) * B_])
        assert np.array_equal(tick.labels, t_ref.labels)
        assert np.array_equal(tick.stable_ids, t_ref.stable_ids)
        assert np.array_equal(np.asarray(r._rho), np.asarray(ref._rho))
        assert np.array_equal(np.asarray(r.result.delta),
                              np.asarray(ref.result.delta))
        assert np.array_equal(np.asarray(r.result.parent),
                              np.asarray(ref.result.parent))

    @pytest.mark.slow
    def test_sharded_checkpoint_restores_onto_one_device(self, tmp_path):
        """A 4-device sharded stream's checkpoint restores onto a single
        device and the next tick is bit-identical — the restore-across-
        device-count contract riding the sharded-parity guarantee."""
        import json
        import subprocess
        import sys

        ckpt = str(tmp_path / "sharded.npz")
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                         "src")
        env["JAX_PLATFORMS"] = "cpu"
        proc = subprocess.run([sys.executable, "-c", _SHARDED_CKPT_SCRIPT,
                               ckpt], env=env, capture_output=True,
                              text=True, timeout=900)
        assert proc.returncode == 0, proc.stderr[-3000:]
        line = [ln for ln in proc.stdout.splitlines()
                if ln.startswith("RESULT")][0]
        out = json.loads(line[len("RESULT"):])

        pts = _data(ticks=3)
        r = StreamDPC.restore(ckpt)             # mesh=None: one device
        tick = r.ingest(pts[CAP + 2 * B: CAP + 3 * B])
        assert np.array_equal(tick.labels, np.array(out["labels"]))
        assert np.array_equal(tick.stable_ids, np.array(out["stable"]))
        assert np.array_equal(np.asarray(r._rho),
                              np.array(out["rho"], np.float32))
        assert np.array_equal(np.asarray(r.result.delta),
                              np.array(out["delta"], np.float32))
