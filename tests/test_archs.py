"""Per-architecture smoke tests: reduced same-family configs, one forward/
train step on CPU, shape + finiteness checks, spec/param tree congruence.

The FULL configs are exercised only by the dry-run (launch/dryrun.py).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, input_specs, reduce_config, SHAPES
from repro.models import build_model
from repro.models.common import MeshRules

ARCH_IDS = list(ARCHS)


def tiny_batch(cfg, B=2, L=32, seed=0):
    k = jax.random.PRNGKey(seed)
    if cfg.family == "encoder":
        return {
            "features": jax.random.normal(k, (B, L, cfg.frontend_dim),
                                          jnp.float32).astype(jnp.bfloat16),
            "labels": jax.random.randint(k, (B, L), 0, cfg.vocab,
                                         jnp.int32),
        }
    if cfg.family == "vlm":
        return {
            "patches": jax.random.normal(
                k, (B, cfg.num_patches, cfg.frontend_dim),
                jnp.float32).astype(jnp.bfloat16),
            "tokens": jax.random.randint(k, (B, L - cfg.num_patches), 0,
                                         cfg.vocab, jnp.int32),
        }
    return {"tokens": jax.random.randint(k, (B, L), 0, cfg.vocab, jnp.int32)}


@pytest.fixture(scope="module")
def built():
    """Build each reduced model + params once per test session."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduce_config(ARCHS[arch])
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_loss_finite(arch, built):
    cfg, model, params = built(arch)
    batch = tiny_batch(cfg)
    loss = jax.jit(lambda p, b: model.loss_fn(p, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch} loss not finite"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_reduces_loss(arch, built):
    """A few SGD-ish steps on one repeated batch must reduce the loss."""
    from repro.train import TrainStepConfig, make_train_step
    cfg, model, params = built(arch)
    from repro.train.optimizer import adamw_init
    batch = tiny_batch(cfg)
    step = jax.jit(make_train_step(
        model.loss_fn, TrainStepConfig(peak_lr=3e-3, warmup_steps=1,
                                       total_steps=100, microbatches=1)))
    opt = adamw_init(params)
    p = params
    losses = []
    for i in range(5):
        p, opt, metrics = step(p, opt, batch, jnp.int32(i))
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses)), f"{arch}: {losses}"
    assert losses[-1] < losses[0], f"{arch} loss did not drop: {losses}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_family(arch, built):
    cfg, model, params = built(arch)
    if not model.is_decoder:
        assert cfg.family == "encoder"
        return
    B, L = 2, 32
    batch = tiny_batch(cfg, B=B, L=L)
    cache = model.init_cache(B, L + 8)
    logits, cache = jax.jit(
        lambda p, b, c: model.prefill(p, b, c))(params, batch, cache)
    assert logits.shape == (B, cfg.vocab)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    logits2, cache = jax.jit(
        lambda p, c, t: model.decode_step(p, c, t, jnp.int32(L)))(
        params, cache, tok)
    assert logits2.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_congruent(arch, built):
    """Spec tree must match the param tree structure with rank-matching
    PartitionSpecs — this is what the 512-device dry-run relies on."""
    cfg, model, params = built(arch)
    rules = MeshRules(data_axes=("data",), model_axis="model",
                      axis_sizes={"data": 16, "model": 16})
    specs = model.param_specs(rules)
    jax.tree.map(lambda *_: None, params, specs)   # raises on mismatch

    flat_p = jax.tree_util.tree_leaves_with_path(params)
    flat_s = jax.tree_util.tree_leaves_with_path(specs)
    for (pp, leaf), (sp, spec) in zip(flat_p, flat_s):
        assert len(spec) <= leaf.ndim, (
            f"{arch} {jax.tree_util.keystr(pp)}: spec {spec} rank > "
            f"leaf rank {leaf.shape}")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_input_specs(arch):
    """Full-size input specs are well-formed for every non-skipped cell."""
    from repro.configs import skip_reason
    cfg = ARCHS[arch]
    for shape_name, spec in SHAPES.items():
        if skip_reason(arch, shape_name):
            continue
        tree = input_specs(cfg, spec)
        for leaf in jax.tree.leaves(tree):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
            assert all(d > 0 for d in leaf.shape)
        if spec.kind != "decode" and cfg.family not in ("encoder",):
            total = (tree["tokens"].shape[1] +
                     (cfg.num_patches if cfg.family == "vlm" else 0))
            assert total == spec.seq_len


def test_sliding_window_cache_is_bounded():
    cfg = reduce_config(ARCHS["h2o-danube-1.8b"])
    model = build_model(cfg)
    cache = model.init_cache(2, 10_000)
    assert cache.k.shape[2] == cfg.sliding_window  # ring buffer, not 10k


def test_ssm_cache_constant_in_seq_len():
    cfg = reduce_config(ARCHS["mamba2-130m"])
    model = build_model(cfg)
    c1 = model.init_cache(2, 1000)
    c2 = model.init_cache(2, 100_000)
    assert all(a.shape == b.shape for a, b in
               zip(jax.tree.leaves(c1), jax.tree.leaves(c2)))
