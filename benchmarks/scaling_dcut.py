"""Fig. 8: running time vs d_cut.

Paper claims: Scan is insensitive to d_cut; the grid algorithms degrade as
d_cut grows (rho_avg enters their complexity); S-Approx-DPC is least
sensitive (|G'| shrinks as d_cut grows).

Each row also records the block-sparse engine's runtime *and* its
pruned-tile fraction at that d_cut, so the sensitivity plot shows **why**
the speedup changes: the worklist keeps the tile pairs within d_cut of each
other's AABBs, and that kept fraction grows with the cut — the engine's
advantage decays exactly as fast as the pruning does.
"""
from __future__ import annotations

import argparse

import numpy as np
import jax.numpy as jnp

from repro.core.approxdpc import run_approxdpc
from repro.core.exdpc import run_exdpc
from repro.core.grid import build_grid
from repro.core.sapproxdpc import run_sapproxdpc
from repro.core.scan import run_scan
from repro.data.points import real_proxy
from repro.engine import ExecSpec
from repro.kernels.blocksparse import worklist_stats
from .util import CSV, pick_dcut, timeit


def main(n=10_000, dataset="household"):
    csv = CSV("fig8_dcut")
    csv.header(f"time vs d_cut ({dataset}, n={n})")
    pts, _ = real_proxy(dataset, n, seed=7)
    base = pick_dcut(pts, target_rho=min(20.0, n / 200))
    for mult in (0.5, 1.0, 2.0, 4.0):
        d_cut = base * mult
        grid = build_grid(jnp.asarray(pts), float(d_cut))
        stats = worklist_stats(np.asarray(grid.points),
                               np.asarray(grid.points), float(d_cut))
        csv.add(dcut_mult=mult, d_cut=d_cut,
                scan_s=timeit(run_scan, pts, d_cut, repeats=2),
                bs_scan_s=timeit(run_scan, pts, d_cut, repeats=2,
                                 exec_spec=ExecSpec(layout="block-sparse")),
                exdpc_s=timeit(run_exdpc, pts, d_cut, repeats=2),
                approxdpc_s=timeit(run_approxdpc, pts, d_cut, repeats=2),
                sapproxdpc_s=timeit(run_sapproxdpc, pts, d_cut, repeats=2),
                pruned_tile_frac=stats["pruned_tile_frac"],
                tiles_kept=stats["tiles_kept"],
                tiles_total=stats["tiles_total"])
    return csv


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10_000)
    main(ap.parse_args().n)
