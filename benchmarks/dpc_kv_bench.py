"""Beyond-paper benchmark: DPC-KV cache compression quality/size trade.

Measures attention-output relative error of the DPC-compressed cache vs
(a) random eviction and (b) strided keeping, across compression budgets —
the serving-side application of the paper's technique (DESIGN.md §5).
"""
from __future__ import annotations

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.serve.dpc_kv import DPCKVConfig, attend_compressed, compress_kv
from .util import CSV


def _cache(B, S, K, hd, modes, seed):
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 1, (modes, hd)).astype(np.float32) * 3
    assign = rng.integers(0, modes, (B, S, K))
    k = centers[assign] + rng.normal(0, 0.2, (B, S, K, hd))
    v = centers[assign] * 0.5 + rng.normal(0, 0.05, (B, S, K, hd))
    return jnp.asarray(k, jnp.float32), jnp.asarray(v, jnp.float32)


def _full(q, k, v):
    B, H, hd = q.shape
    K = k.shape[2]
    qg = q.reshape(B, K, H // K, hd)
    logits = jnp.einsum("bkgh,bskh->bkgs", qg, k) * hd ** -0.5
    p = jax.nn.softmax(logits, -1)
    return jnp.einsum("bkgs,bskh->bkgh", p, v).reshape(B, H, hd)


def _err(a, b):
    return float(jnp.linalg.norm(a - b) / jnp.linalg.norm(b))


def main(S=1024, modes=12):
    csv = CSV("dpc_kv")
    csv.header(f"attention error vs budget (S={S}, {modes} key modes)")
    B, K, hd = 2, 2, 64
    k, v = _cache(B, S, K, hd, modes, seed=0)
    q = jnp.asarray(np.random.default_rng(1).normal(0, 1, (B, 8, hd)),
                    jnp.float32)
    ref = _full(q, k, v)
    rng = np.random.default_rng(2)
    for budget in (32, 64, 128, 256):
        kc, vc, cnt = compress_kv(k, v, jnp.int32(S),
                                  DPCKVConfig(budget=budget))
        e_dpc = _err(attend_compressed(q, kc, vc, cnt), ref)
        keep = rng.choice(S, budget, replace=False)
        e_rand = _err(attend_compressed(q, k[:, keep], v[:, keep],
                                        jnp.ones((B, budget, K))), ref)
        stride = S // budget
        e_stride = _err(attend_compressed(q, k[:, ::stride][:, :budget],
                                          v[:, ::stride][:, :budget],
                                          jnp.ones((B, budget, K))), ref)
        csv.add(budget=budget, compress_ratio=S / budget, err_dpc=e_dpc,
                err_random=e_rand, err_strided=e_stride)
    return csv


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--S", type=int, default=1024)
    main(ap.parse_args().S)
