"""Per-primitive kernel-backend throughput: jnp vs pallas.

The perf baseline for the backend layer (repro.kernels.backend): times the
two DPC primitives (+ the triangular prefix variant) on each backend and
writes a JSON record, so future kernel PRs diff against today's numbers.

On CPU containers the pallas backend runs in *interpret* mode — a
correctness path, orders of magnitude slower than both compiled paths —
so each record carries an ``interpret`` flag and the jnp row is the
meaningful CPU number.  On TPU the ``pallas`` rows are the headline.

    PYTHONPATH=src python -m benchmarks.backend_compare [--n 8192]
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.backend import get_backend

from .util import CSV, timeit

PRIMITIVES = ("range_count", "denser_nn", "prefix_nn")


def default_backends() -> list[str]:
    if jax.default_backend() == "tpu":
        return ["jnp", "pallas"]
    return ["jnp", "pallas-interpret"]


def bench_backend(name: str, pts, rho_key, d_cut: float, repeats: int):
    be = get_backend(name)
    runs = {
        "range_count": lambda: be.range_count(pts, pts, d_cut),
        "denser_nn": lambda: be.denser_nn(pts, rho_key, pts, rho_key),
        "prefix_nn": lambda: be.prefix_nn(pts),
    }
    out = {}
    n = pts.shape[0]
    for prim, fn in runs.items():
        secs = timeit(fn, repeats=repeats)
        out[prim] = {
            "seconds": secs,
            "pairs_per_s": float(n) * n / secs,
            "interpret": name == "pallas-interpret",
        }
    return out


def main(n: int = 4096, d: int = 3, repeats: int = 3,
         backends: list[str] | None = None,
         out: str = "experiments/backends"):
    backends = backends or default_backends()
    rng = np.random.default_rng(0)
    d_cut = 900.0
    pts = jnp.asarray(rng.uniform(0, 30 * d_cut, (n, d)), jnp.float32)
    rho_key = jnp.asarray(rng.permutation(n).astype(np.float32))

    csv = CSV("backend_compare")
    csv.header(f"n={n} d={d}")
    rec = {"n": n, "d": d, "d_cut": d_cut, "platform": jax.default_backend(),
           "primitives": {p: {} for p in PRIMITIVES}}
    for name in backends:
        res = bench_backend(name, pts, rho_key, d_cut, repeats)
        for prim, r in res.items():
            rec["primitives"][prim][name] = r
            csv.add(primitive=prim, backend=name, seconds=r["seconds"],
                    pairs_per_s=r["pairs_per_s"])

    os.makedirs(out, exist_ok=True)
    path = os.path.join(out, "backend_compare.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"[backend_compare] wrote {path}", flush=True)
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--d", type=int, default=3)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--backends", default=None,
                    help="comma-separated (default: platform pair)")
    ap.add_argument("--out", default="experiments/backends")
    a = ap.parse_args()
    main(n=a.n, d=a.d, repeats=a.repeats,
         backends=a.backends.split(",") if a.backends else None, out=a.out)
