"""Per-primitive kernel-backend throughput: jnp vs pallas.

The perf baseline for the backend layer (repro.kernels.backend): times every
engine primitive — the two classic sweeps (+ the triangular prefix variant),
the fused ``rho_delta`` against its two-pass formulation, the mixed-precision
fused path, and the halo span-masked primitives — on each backend and writes
a JSON record, so future kernel PRs diff against today's numbers
(``BENCH_core.json`` at the repo root is the committed copy).

On CPU containers the pallas backend runs in *interpret* mode — a
correctness path, orders of magnitude slower than both compiled paths —
so each record carries an ``interpret`` flag and the jnp rows are the
meaningful CPU numbers.  On TPU the ``pallas`` rows are the headline.

    PYTHONPATH=src python -m benchmarks.backend_compare [--n 4096]

``--smoke`` is the CI gate: a quick jnp-gated run plus a small
pallas-interpret exercise pass, failing (exit 1) when

* the fused ``rho_delta`` is less than FUSED_MIN_SPEEDUP x the two-pass
  dense sweep on the jnp CPU baseline (the ISSUE 3 acceptance bar), or
* the block-sparse fused path's speedup over the dense fused path (same
  grid-sorted data, paper-style d_cut) regressed more than SMOKE_TOLERANCE
  relative to the committed ratio (the ISSUE 4 pruning bar), or
* the multi-device distributed row's paired ratio (block-sparse vs dense
  shard phases on a host-device-count mesh, run in a 4-virtual-device
  subprocess) regressed more than SMOKE_TOLERANCE relative to the
  committed ratio, or the shard-layout probe silently degraded (the
  ISSUE 8 bar), or
* any jnp primitive regressed more than SMOKE_TOLERANCE in *relative*
  pairs/s against the committed BENCH_core.json (throughputs are normalized
  by the currently measured jnp range_count rate first, so the gate tracks
  algorithmic regressions rather than CI-machine speed), or
* a stream checkpoint restore breaks tick parity (the ISSUE 9 resilience
  bar: one post-restore ingest must be bit-identical to the uninterrupted
  stream's; the save/restore latencies printed alongside are
  informational, never gated).

``--refresh-baseline`` rewrites BENCH_core.json: the standard-shape record
plus the ISSUE-4 acceptance measurement (block-sparse vs dense fused
``rho_delta`` wall clock at n=64k, d=3, paper-style d_cut, jnp CPU), the
ISSUE-8 distributed rows (dense vs block-sparse shard phases at the
same acceptance shape, plus a smaller smoke shape the CI gate re-measures)
and the ISSUE-9 ``stream_checkpoint`` latency/parity row.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.dpc_types import density_jitter
from repro.core.grid import build_grid
from repro.core.tuning import pick_dcut
from repro.engine import ExecSpec, as_plan
from repro.kernels.backend import get_backend

from .util import CSV

PRIMITIVES = ("range_count", "denser_nn", "prefix_nn", "rho_delta_two_pass",
              "rho_delta_fused", "range_count_halo", "denser_nn_halo",
              "rho_delta_fused_dense_gs", "rho_delta_fused_bs")

FUSED_MIN_SPEEDUP = 1.3     # fused vs two-pass, jnp CPU (ISSUE 3 acceptance)
SMOKE_TOLERANCE = 0.30      # relative pairs/s regression tripping the gate
ACCEPT_N = 65536            # ISSUE 4 acceptance shape (n, d, min speedup)
ACCEPT_D = 3
ACCEPT_MIN_SPEEDUP = 3.0
DIST_SMOKE_N = 16384        # distributed smoke shape (gate re-measures it)
DIST_DEVICES = 4            # virtual host devices for the distributed rows


def default_backends() -> list[str]:
    if jax.default_backend() == "tpu":
        return ["jnp", "pallas"]
    return ["jnp", "pallas-interpret"]


def _bench_data(n: int, d: int, seed: int = 0):
    """Clustered-density data: domain 6*d_cut keeps rho ~ tens, so the fused
    path's resolution statistics resemble a real clustering workload."""
    rng = np.random.default_rng(seed)
    d_cut = 900.0
    pts = jnp.asarray(rng.uniform(0, 6 * d_cut, (n, d)), jnp.float32)
    rho_key = jnp.asarray(rng.permutation(n).astype(np.float32))
    # halo layout: each sorted row sees one contiguous window span around it
    width = min(n, 128)
    st = np.clip(np.arange(n) - width // 2, 0, max(n - width, 0))
    starts = jnp.asarray(st[:, None].astype(np.int32))
    ends = jnp.asarray((st + width)[:, None].astype(np.int32))
    return pts, rho_key, d_cut, starts, ends, width


def _bench_data_sparse(n: int, d: int, seed: int = 0):
    """Block-sparse layout rows: same uniform domain, but a *paper-style*
    d_cut (average rho in the tens — the assumption the grid pruning pays
    under) and the points grid-sorted, exactly as the drivers lay them out.
    Returns (pts_sorted, d_cut)."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 6 * 900.0, (n, d)).astype(np.float32)
    d_cut = float(pick_dcut(pts, target_rho=min(30.0, n / 200)))
    grid = build_grid(jnp.asarray(pts), d_cut)
    return grid.points, d_cut


def bench_backend(name: str, n: int, d: int, repeats: int,
                  precision_rows: bool = True):
    be = get_backend(name)
    pts, rho_key, d_cut, starts, ends, width = _bench_data(n, d)
    jitter = density_jitter(n)

    def two_pass():
        rho = be.range_count(pts, pts, d_cut)
        rk = rho + jitter
        return be.denser_nn(pts, rk, pts, rk)

    runs = {
        "range_count": (lambda: be.range_count(pts, pts, d_cut), n * n),
        "denser_nn": (lambda: be.denser_nn(pts, rho_key, pts, rho_key),
                      n * n),
        "prefix_nn": (lambda: be.prefix_nn(pts), n * n),
        "rho_delta_two_pass": (two_pass, 2 * n * n),
        "rho_delta_fused": (
            lambda: be.rho_delta(pts, pts, d_cut, jitter=jitter), 2 * n * n),
        "range_count_halo": (
            lambda: be.range_count_halo(pts, pts, starts, ends, d_cut,
                                        span_cap=width), n * width),
        "denser_nn_halo": (
            lambda: be.denser_nn_halo(pts, rho_key, pts, rho_key, starts,
                                      ends, d_cut, span_cap=width),
            n * width),
    }
    if precision_rows and be.mxu_dense:
        runs["rho_delta_fused_bf16"] = (
            lambda: be.rho_delta(pts, pts, d_cut, jitter=jitter,
                                 precision="bf16"), 2 * n * n)

    # block-sparse layout rows: dense vs grid-pruned fused rho_delta on the
    # same grid-sorted data at paper-style d_cut.  Both rows use the dense
    # 2*n^2 pair count, so pairs/s is *wall-clock-equivalent* — the sparse
    # row's higher rate IS the pruning win.
    pts_gs, dcut_gs = _bench_data_sparse(n, d)
    runs["rho_delta_fused_dense_gs"] = (
        lambda: be.rho_delta(pts_gs, pts_gs, dcut_gs, jitter=jitter),
        2 * n * n)
    runs["rho_delta_fused_bs"] = (
        lambda: be.rho_delta(pts_gs, pts_gs, dcut_gs, jitter=jitter,
                             layout="block-sparse"), 2 * n * n)

    # Interleaved timing: one pass over the whole primitive set per repeat,
    # so slow machine-load drift hits every primitive equally and the
    # *relative* throughputs (what the smoke gate and the fused-speedup
    # acceptance compare) stay stable on noisy shared CPUs.
    import time as _time

    for fn, _ in runs.values():                    # warmup / compile
        jax.block_until_ready(fn())
    samples = {prim: [] for prim in runs}
    for _ in range(repeats):
        for prim, (fn, _) in runs.items():
            t0 = _time.perf_counter()
            jax.block_until_ready(fn())
            samples[prim].append(_time.perf_counter() - t0)

    out = {}
    for prim, (fn, pairs) in runs.items():
        # best-of-repeats: the minimum is the reproducible statistic on a
        # shared/bursty CPU (load only ever adds time, never subtracts)
        secs = float(np.min(samples[prim]))
        out[prim] = {
            "seconds": secs,
            "pairs_per_s": float(pairs) / secs,
            "interpret": name == "pallas-interpret",
        }
    # fused speedup from *paired* per-repeat ratios: the two formulations
    # run back-to-back inside each repeat, so machine-load drift divides out
    ratios = [t / f for t, f in zip(samples["rho_delta_two_pass"],
                                    samples["rho_delta_fused"])]
    out["_fused_speedup"] = float(np.median(ratios))
    sratios = [t / f for t, f in zip(samples["rho_delta_fused_dense_gs"],
                                     samples["rho_delta_fused_bs"])]
    out["_sparse_speedup"] = float(np.median(sratios))
    return out


def run(n: int, d: int, repeats: int, backends: list[str]):
    csv = CSV("backend_compare")
    csv.header(f"n={n} d={d}")
    rec = {"n": n, "d": d, "d_cut": 900.0,
           "platform": jax.default_backend(),
           "primitives": {}, "fused_speedup": {}, "sparse_speedup": {}}
    for name in backends:
        res = bench_backend(name, n, d, repeats)
        rec["fused_speedup"][name] = res.pop("_fused_speedup")
        rec["sparse_speedup"][name] = res.pop("_sparse_speedup")
        for prim, r in res.items():
            rec["primitives"].setdefault(prim, {})[name] = r
            csv.add(primitive=prim, backend=name, seconds=r["seconds"],
                    pairs_per_s=r["pairs_per_s"])
    return rec


def measure_acceptance(repeats: int = 3) -> dict:
    """The ISSUE 4 acceptance record: block-sparse vs dense fused rho_delta
    wall clock at n=64k, d=3, paper-style d_cut, jnp CPU (grid-sorted)."""
    import time as _time

    be = get_backend("jnp")
    pts, d_cut = _bench_data_sparse(ACCEPT_N, ACCEPT_D)
    jitter = density_jitter(ACCEPT_N)
    forms = {
        "dense": lambda: be.rho_delta(pts, pts, d_cut, jitter=jitter),
        "block_sparse": lambda: be.rho_delta(pts, pts, d_cut, jitter=jitter,
                                             layout="block-sparse"),
    }
    secs = {}
    for name, fn in forms.items():
        jax.block_until_ready(fn())
        ts = []
        for _ in range(repeats):
            t0 = _time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(_time.perf_counter() - t0)
        secs[name] = float(np.min(ts))
    speedup = secs["dense"] / secs["block_sparse"]
    print(f"[backend_compare] acceptance n={ACCEPT_N}: dense "
          f"{secs['dense']:.2f}s, block-sparse {secs['block_sparse']:.2f}s "
          f"-> {speedup:.2f}x (bar {ACCEPT_MIN_SPEEDUP}x)", flush=True)
    return {"n": ACCEPT_N, "d": ACCEPT_D, "d_cut": float(d_cut),
            "backend": "jnp",
            "dense_seconds": secs["dense"],
            "block_sparse_seconds": secs["block_sparse"],
            "pairs_per_s_equiv_dense": 2 * ACCEPT_N ** 2 / secs["dense"],
            "pairs_per_s_equiv_bs": 2 * ACCEPT_N ** 2 / secs["block_sparse"],
            "speedup": speedup, "min_required": ACCEPT_MIN_SPEEDUP}


# Multi-device shard phases (ISSUE 8): dense vs block-sparse worklists on a
# host-device-count mesh.  XLA's virtual host devices must be configured
# before jax initializes, so the measurement runs in a subprocess; both
# variants run the same _make_rho_dense/_make_delta_dense shard bodies on
# the same grid-sorted padded table, differing only in layout — the paired
# per-repeat ratio is the pruning win and is machine-speed independent.
_DIST_SCRIPT = r"""
import json, sys, time, warnings, os
warnings.filterwarnings("ignore")
os.environ["REPRO_ANALYSIS"] = "suspend"   # bench plans, not production fits
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core.grid import build_grid
from repro.core.tuning import pick_dcut
from repro.distributed import dpc as ddpc
from repro.engine import ExecSpec
from repro.engine.planner import plan
from repro.kernels.backend import get_backend

n, d, repeats = int(sys.argv[1]), int(sys.argv[2]), int(sys.argv[3])
S = jax.device_count()
mesh = jax.make_mesh((S,), ("data",))
be = get_backend("jnp")

rng = np.random.default_rng(0)
pts = rng.uniform(0, 6 * 900.0, (n, d)).astype(np.float32)
d_cut = float(pick_dcut(pts, target_rho=min(30.0, n / 200)))
grid = build_grid(jnp.asarray(pts), d_cut)
n0 = grid.points.shape[0]
m = -(-n0 // S) * S
pts_s = jnp.pad(grid.points, ((0, m - n0), (0, 0)), constant_values=1e9)
key = rng.permutation(n0).astype(np.float32)   # all-distinct density keys
rk_tab = jnp.asarray(np.concatenate(
    [key, np.full(m - n0, -np.inf, np.float32)]))
rk_q = jnp.asarray(np.concatenate(
    [key, np.full(m - n0, np.inf, np.float32)]))

def phases(layout):
    rho_fn = ddpc._make_rho_dense("data", d_cut, 256, be, layout=layout)
    delta_fn = ddpc._make_delta_dense("data", 256, be, layout=layout)
    sm_rho = jax.jit(shard_map(
        rho_fn, mesh=mesh, in_specs=(P("data"), P("data")),
        out_specs=P("data"), check_rep=False))
    sm_delta = jax.jit(shard_map(
        delta_fn, mesh=mesh, in_specs=(P("data"),) * 4,
        out_specs=(P("data"),) * 3, check_rep=False))
    def run():
        out = (sm_rho(pts_s, pts_s), sm_delta(pts_s, rk_q, pts_s, rk_tab))
        return jax.block_until_ready(out)
    return run

dense_run, bs_run = phases(None), phases("block-sparse")
lay = ddpc.shard_blocksparse_layout(
    plan(None, ExecSpec(backend="jnp", layout="block-sparse")), mesh)
dense_run(); bs_run()                          # warmup / compile
dts, bts = [], []
for _ in range(repeats):
    t0 = time.perf_counter(); dense_run(); dts.append(time.perf_counter() - t0)
    t0 = time.perf_counter(); bs_run(); bts.append(time.perf_counter() - t0)
print("RESULT" + json.dumps({
    "n": n, "d": d, "d_cut": d_cut, "devices": S, "backend": "jnp",
    "layout_probe": lay,
    "dense_seconds": float(np.min(dts)), "bs_seconds": float(np.min(bts)),
    "pairs_per_s_equiv_dense": 2 * n * n / float(np.min(dts)),
    "pairs_per_s_equiv_bs": 2 * n * n / float(np.min(bts)),
    "speedup": float(np.median([a / b for a, b in zip(dts, bts)]))}))
"""


def measure_distributed(n: int, d: int, repeats: int = 3,
                        devices: int = DIST_DEVICES) -> dict:
    """The ISSUE 8 distributed row: dense vs block-sparse shard phases on
    a ``devices``-device mesh (subprocess; see ``_DIST_SCRIPT``)."""
    import subprocess

    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env.setdefault("PYTHONPATH", "src")
    proc = subprocess.run([sys.executable, "-c", _DIST_SCRIPT,
                           str(n), str(d), str(repeats)],
                          env=env, capture_output=True, text=True,
                          timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError("distributed bench subprocess failed:\n"
                           + proc.stderr[-3000:])
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    rec = json.loads(line[len("RESULT"):])
    print(f"[backend_compare] distributed n={rec['n']} "
          f"S={rec['devices']}: dense shard phases "
          f"{rec['dense_seconds']:.2f}s, block-sparse "
          f"{rec['bs_seconds']:.2f}s -> {rec['speedup']:.2f}x "
          f"(probe: {rec['layout_probe']})", flush=True)
    return rec


def measure_checkpoint(repeats: int = 5, capacity: int = 4096,
                       batch: int = 256, d: int = 3) -> dict:
    """The ISSUE 9 resilience row: crash-safe stream-checkpoint latency
    (save / restore wall clock and file size at the engine's default
    window shape, jnp backend, steady-state ring) plus the restore
    contract itself — one post-restore ingest must be bit-identical to
    the uninterrupted stream's.  Latency is informational (min over
    ``repeats``); only a parity break gates."""
    import tempfile
    import time

    from repro.stream.stream_dpc import StreamDPC, StreamDPCConfig

    rng = np.random.default_rng(7)
    d_cut = 900.0
    pts = rng.uniform(0, 6 * d_cut,
                      (capacity + 3 * batch, d)).astype(np.float32)
    cfg = StreamDPCConfig(d_cut=d_cut, capacity=capacity, batch_cap=batch,
                          rho_min=3.0, exec_spec=ExecSpec(backend="jnp"))
    s = StreamDPC(cfg)
    s.initialize(pts[:capacity])
    s.ingest(pts[capacity:capacity + 2 * batch])   # steady state: ring wraps
    saves, restores = [], []
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "stream.ckpt")
        for _ in range(repeats):
            t0 = time.perf_counter()
            s.save(path)
            saves.append(time.perf_counter() - t0)
        size = os.path.getsize(path)
        for _ in range(repeats):
            t0 = time.perf_counter()
            r = StreamDPC.restore(path)
            restores.append(time.perf_counter() - t0)
        tail = pts[capacity + 2 * batch:]
        a = s.ingest(tail)
        b = r.ingest(tail)
    parity = bool(np.array_equal(a.labels, b.labels)
                  and np.array_equal(a.stable_ids, b.stable_ids))
    rec = {"capacity": capacity, "batch_cap": batch, "d": d,
           "backend": "jnp",
           "save_ms": float(np.min(saves) * 1e3),
           "restore_ms": float(np.min(restores) * 1e3),
           "bytes": int(size),
           "post_restore_parity": parity}
    print(f"[backend_compare] stream checkpoint (capacity={capacity}, "
          f"d={d}): save {rec['save_ms']:.1f} ms, restore "
          f"{rec['restore_ms']:.1f} ms, {size / 1e6:.2f} MB, "
          f"post-restore parity={'OK' if parity else 'BROKEN'}",
          flush=True)
    return rec


def dist_gate(committed, repeats: int,
              tolerance: float = SMOKE_TOLERANCE) -> list[str]:
    """Smoke check of the multi-device row: the probe must keep
    block-sparse enabled, and the paired dense/block-sparse shard-phase
    ratio must hold within ``tolerance`` of the committed record."""
    ref = committed.get("distributed_multidev", {}).get("smoke")
    if ref is None:
        return ["committed baseline lacks the distributed multi-device "
                "smoke row (refresh BENCH_core.json)"]
    now = measure_distributed(ref["n"], ref["d"], repeats=repeats,
                              devices=ref["devices"])
    failures = []
    if now["layout_probe"] != "block-sparse":
        failures.append(f"shard_blocksparse_layout degraded on the "
                        f"{now['devices']}-device mesh: "
                        f"{now['layout_probe']!r}")
    if now["speedup"] < (1.0 - tolerance) * ref["speedup"]:
        failures.append(
            f"distributed block-sparse vs dense shard phases "
            f"{now['speedup']:.2f}x < (1-{tolerance})x committed "
            f"{ref['speedup']:.2f}x")
    return failures


def smoke_gate(rec, committed, tolerance: float = SMOKE_TOLERANCE):
    """Relative-throughput regression check vs the committed baseline."""
    failures = []
    sp = rec["fused_speedup"].get("jnp", 0.0)
    if sp < FUSED_MIN_SPEEDUP:
        failures.append(f"jnp fused rho_delta speedup {sp:.2f}x "
                        f"< required {FUSED_MIN_SPEEDUP}x")
    ssp = rec.get("sparse_speedup", {}).get("jnp", 0.0)
    ssp_ref = committed.get("sparse_speedup", {}).get("jnp")
    if ssp_ref is None:
        failures.append("committed baseline lacks the jnp sparse_speedup "
                        "ratio (refresh BENCH_core.json)")
    elif ssp < (1.0 - tolerance) * ssp_ref:
        failures.append(f"jnp block-sparse speedup {ssp:.2f}x < "
                        f"(1-{tolerance})x committed {ssp_ref:.2f}x")
    try:
        base_now = rec["primitives"]["range_count"]["jnp"]["pairs_per_s"]
        base_ref = committed["primitives"]["range_count"]["jnp"]["pairs_per_s"]
    except KeyError:
        return failures + ["committed baseline lacks jnp range_count row"]
    for prim, rows in committed["primitives"].items():
        for name, ref in rows.items():
            if ref.get("interpret"):
                continue        # interpret timings are not performance
            now = rec["primitives"].get(prim, {}).get(name)
            if now is None:
                failures.append(f"{prim}/{name}: row missing from this run")
                continue
            rel_now = now["pairs_per_s"] / base_now
            rel_ref = ref["pairs_per_s"] / base_ref
            if rel_now < (1.0 - tolerance) * rel_ref:
                failures.append(
                    f"{prim}/{name}: relative pairs/s {rel_now:.3f} < "
                    f"(1-{tolerance})x committed {rel_ref:.3f}")
    return failures


def _export_obs(path: str | None):
    """Write the repro.obs metrics/trace snapshot accumulated by this run
    (worklist builds/cache hits, plan-cache traffic, any spans) so CI can
    archive and diff it alongside the throughput record."""
    if not path:
        return
    from repro.obs import report as obs_report
    obs_report.export_snapshot(path)
    print(f"[backend_compare] wrote obs snapshot to {path}", flush=True)


def main(n: int = 4096, d: int = 3, repeats: int = 3,
         backends: list[str] | None = None,
         out: str = "experiments/backends", smoke: bool = False,
         baseline: str = "BENCH_core.json",
         refresh_baseline: bool = False, obs_snapshot: str | None = None):
    if smoke:
        # gated jnp pass at the committed shape + a small kernel exercise
        committed = json.load(open(baseline))
        rec = run(n=committed.get("n", 2048), d=committed.get("d", 3),
                  repeats=max(repeats, 5), backends=["jnp"])
        exercise = run(n=512, d=d, repeats=1,
                       backends=["pallas-interpret"]
                       if jax.default_backend() != "tpu" else ["pallas"])
        del exercise  # correctness/coverage only; never gated
        failures = smoke_gate(rec, committed)
        failures += dist_gate(committed, repeats=max(repeats, 3))
        ck = measure_checkpoint(repeats=max(repeats, 3))
        rec["stream_checkpoint"] = ck
        if not ck["post_restore_parity"]:
            failures.append("stream checkpoint restore broke tick parity "
                            "(post-restore ingest != uninterrupted stream)")
        _export_obs(obs_snapshot)
        if failures:
            print("[backend_compare --smoke] FAIL", flush=True)
            for f in failures:
                print("  -", f, flush=True)
            sys.exit(1)
        print(f"[backend_compare --smoke] OK (jnp fused speedup "
              f"{rec['fused_speedup']['jnp']:.2f}x, block-sparse "
              f"{rec['sparse_speedup']['jnp']:.2f}x)", flush=True)
        return rec

    rec = run(n=n, d=d, repeats=repeats,
              backends=backends or default_backends())
    if refresh_baseline:
        rec["acceptance_64k"] = measure_acceptance(repeats=repeats)
        rec["distributed_multidev"] = {
            "acceptance": measure_distributed(ACCEPT_N, ACCEPT_D,
                                              repeats=repeats),
            "smoke": measure_distributed(DIST_SMOKE_N, ACCEPT_D,
                                         repeats=repeats),
        }
        rec["stream_checkpoint"] = measure_checkpoint(repeats=repeats)
        with open(baseline, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"[backend_compare] refreshed {baseline}", flush=True)
    else:
        os.makedirs(out, exist_ok=True)
        path = os.path.join(out, "backend_compare.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"[backend_compare] wrote {path}", flush=True)
    for name, sp in rec["fused_speedup"].items():
        print(f"[backend_compare] {name}: fused rho_delta {sp:.2f}x over "
              f"two-pass; block-sparse {rec['sparse_speedup'][name]:.2f}x "
              f"over dense fused", flush=True)
    _export_obs(obs_snapshot)
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--d", type=int, default=3)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--exec", dest="exec_spec", default=None,
                    help="uniform execution flag backend:layout:precision "
                         "(repro.engine.ExecSpec.parse): bench that one "
                         "backend — layout/precision are validated against "
                         "it (every run still records the dense AND "
                         "block-sparse fused rows; that pairing IS the "
                         "layout comparison)")
    ap.add_argument("--backends", default=None,
                    help="comma-separated (legacy; prefer --exec)")
    ap.add_argument("--out", default="experiments/backends")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate vs the committed BENCH_core.json")
    ap.add_argument("--baseline", default="BENCH_core.json")
    ap.add_argument("--refresh-baseline", action="store_true",
                    help="rewrite the committed baseline, including the "
                         "n=64k block-sparse acceptance record")
    ap.add_argument("--obs-snapshot", default=None,
                    help="write the repro.obs metrics snapshot here "
                         "(CI archives it next to the throughput record)")
    a = ap.parse_args()
    backends = a.backends.split(",") if a.backends else None
    if a.exec_spec:
        if backends:
            ap.error("--exec and --backends are mutually exclusive")
        # plan once: resolves the backend name and fail-fasts on bad
        # names / impossible combos before any timing runs
        backends = [as_plan(ExecSpec.parse(a.exec_spec)).backend_name]
    main(n=a.n, d=a.d, repeats=a.repeats,
         backends=backends, out=a.out,
         smoke=a.smoke, baseline=a.baseline,
         refresh_baseline=a.refresh_baseline,
         obs_snapshot=a.obs_snapshot)
