"""Fig. 7: end-to-end running time vs cardinality (sampling rate).

Validates the paper's scaling claims: Scan is O(n^2); Ex-DPC/Approx-DPC are
sub-quadratic; S-Approx-DPC is ~linear for fixed parameters.  The fitted
log-log slope per algorithm is printed alongside the raw times.

``layout_scaling`` (also ``--layouts`` on the CLI) is the block-sparse
engine's scaling record: dense vs grid-pruned fused ``rho_delta`` at fixed
d_cut as n grows.  Dense pairs/s is ~flat (every tile pair visited); the
block-sparse pairs/s-equivalent must grow super-linearly in n, because at
fixed d_cut the kept-tile fraction shrinks as the data outgrows the cut —
the sub-quadratic claim made measurable.
"""
from __future__ import annotations

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.approxdpc import run_approxdpc
from repro.core.dpc_types import density_jitter
from repro.core.exdpc import run_exdpc
from repro.core.grid import build_grid
from repro.core.lsh_ddp import run_lsh_ddp
from repro.core.sapproxdpc import run_sapproxdpc
from repro.core.scan import run_scan
from repro.data.points import real_proxy
from repro.engine import ExecSpec, as_plan
from repro.kernels.blocksparse import worklist_stats
from .util import CSV, pick_dcut, timeit

# the four engine-driven algorithms accept the unified exec_spec; the
# LSH-DDP baseline always runs its own reference math
_ENGINE_ALGOS = ("exdpc", "approxdpc", "sapproxdpc", "scan")


def main(n_max=32_000, dataset="household", include_scan=True,
         exec_spec: ExecSpec | None = None):
    spec = exec_spec or ExecSpec()
    csv = CSV("fig7_scaling_n")
    csv.header(f"time vs n ({dataset}, n_max={n_max}, "
               f"exec={spec.describe()})")
    ns = [n_max // 8, n_max // 4, n_max // 2, n_max]
    pts_full, _ = real_proxy(dataset, n_max, seed=6)
    d_cut = pick_dcut(pts_full, target_rho=min(30.0, n_max / 200))
    algos = {
        "exdpc": run_exdpc,
        "approxdpc": run_approxdpc,
        "sapproxdpc": run_sapproxdpc,
        "lsh_ddp": run_lsh_ddp,
    }
    if include_scan:
        algos["scan"] = run_scan
    times = {a: [] for a in algos}
    for n in ns:
        pts = pts_full[:n]
        row = {"n": n}
        for algo, fn in algos.items():
            kw = {"exec_spec": spec} if algo in _ENGINE_ALGOS else {}
            t = timeit(fn, pts, d_cut, repeats=2, **kw)
            times[algo].append(t)
            row[f"{algo}_s"] = t
        csv.add(**row)
    # fitted scaling exponents
    logn = np.log(np.array(ns, float))
    exps = {a: float(np.polyfit(logn, np.log(np.maximum(ts, 1e-9)), 1)[0])
            for a, ts in times.items()}
    csv.add(**{f"slope_{a}": e for a, e in exps.items()})
    return exps


def layout_scaling(n_max=32_000, d=3, exec_spec: ExecSpec | None = None,
                   seed=11):
    """Dense vs block-sparse fused rho_delta pairs/s at fixed d_cut vs n."""
    pl = as_plan(exec_spec)
    csv = CSV("fig7b_layout")
    csv.header(f"dense vs block-sparse engine (backend={pl.backend_name}, "
               f"n_max={n_max})")
    rng = np.random.default_rng(seed)
    pts_full = rng.uniform(0, 6 * 900.0, (n_max, d)).astype(np.float32)
    # paper-style d_cut picked at n_max, then held FIXED across n: the
    # pruning (and with it pairs/s) must strengthen as n grows
    d_cut = float(pick_dcut(pts_full, target_rho=min(30.0, n_max / 200)))
    be = pl.backend
    ns = [n_max // 8, n_max // 4, n_max // 2, n_max]
    rates = {"dense": [], "bs": []}
    for n in ns:
        grid = build_grid(jnp.asarray(pts_full[:n]), d_cut)
        pts = grid.points
        jit_ = density_jitter(n)
        t_d = timeit(lambda: jax.block_until_ready(
            be.rho_delta(pts, pts, d_cut, jitter=jit_)), repeats=2)
        t_s = timeit(lambda: jax.block_until_ready(
            be.rho_delta(pts, pts, d_cut, jitter=jit_,
                         layout="block-sparse")), repeats=2)
        stats = worklist_stats(np.asarray(pts), np.asarray(pts), d_cut)
        pairs = 2.0 * n * n
        rates["dense"].append(pairs / t_d)
        rates["bs"].append(pairs / t_s)
        csv.add(n=n, d_cut=d_cut, dense_s=t_d, bs_s=t_s,
                dense_pairs_per_s=pairs / t_d, bs_pairs_per_s=pairs / t_s,
                speedup=t_d / t_s,
                pruned_tile_frac=stats["pruned_tile_frac"])
    logn = np.log(np.array(ns, float))
    slopes = {k: float(np.polyfit(logn, np.log(np.array(v)), 1)[0])
              for k, v in rates.items()}
    # slope of log(pairs/s) vs log(n): > 0 means super-linear growth of the
    # effective rate — the block-sparse engine's sub-quadratic signature
    csv.add(slope_pairs_per_s_dense=slopes["dense"],
            slope_pairs_per_s_bs=slopes["bs"])
    return slopes


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-max", type=int, default=32_000)
    ap.add_argument("--exec", dest="exec_spec", default=None,
                    help="uniform execution flag backend:layout:precision "
                         "(repro.engine.ExecSpec.parse) applied to every "
                         "engine-driven algorithm")
    ap.add_argument("--layouts", action="store_true",
                    help="run the dense vs block-sparse engine scaling")
    a = ap.parse_args()
    spec = ExecSpec.parse(a.exec_spec) if a.exec_spec else None
    if a.layouts:
        layout_scaling(a.n_max, exec_spec=spec)
    else:
        main(a.n_max, exec_spec=spec)
