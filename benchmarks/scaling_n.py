"""Fig. 7: end-to-end running time vs cardinality (sampling rate).

Validates the paper's scaling claims: Scan is O(n^2); Ex-DPC/Approx-DPC are
sub-quadratic; S-Approx-DPC is ~linear for fixed parameters.  The fitted
log-log slope per algorithm is printed alongside the raw times.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.approxdpc import run_approxdpc
from repro.core.exdpc import run_exdpc
from repro.core.lsh_ddp import run_lsh_ddp
from repro.core.sapproxdpc import run_sapproxdpc
from repro.core.scan import run_scan
from repro.data.points import real_proxy
from .util import CSV, pick_dcut, timeit


def main(n_max=32_000, dataset="household", include_scan=True):
    csv = CSV("fig7_scaling_n")
    csv.header(f"time vs n ({dataset}, n_max={n_max})")
    ns = [n_max // 8, n_max // 4, n_max // 2, n_max]
    pts_full, _ = real_proxy(dataset, n_max, seed=6)
    d_cut = pick_dcut(pts_full, target_rho=min(30.0, n_max / 200))
    algos = {
        "exdpc": run_exdpc,
        "approxdpc": run_approxdpc,
        "sapproxdpc": run_sapproxdpc,
        "lsh_ddp": run_lsh_ddp,
    }
    if include_scan:
        algos["scan"] = run_scan
    times = {a: [] for a in algos}
    for n in ns:
        pts = pts_full[:n]
        row = {"n": n}
        for algo, fn in algos.items():
            t = timeit(fn, pts, d_cut, repeats=2)
            times[algo].append(t)
            row[f"{algo}_s"] = t
        csv.add(**row)
    # fitted scaling exponents
    logn = np.log(np.array(ns, float))
    exps = {a: float(np.polyfit(logn, np.log(np.maximum(ts, 1e-9)), 1)[0])
            for a, ts in times.items()}
    csv.add(**{f"slope_{a}": e for a, e in exps.items()})
    return exps


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-max", type=int, default=32_000)
    main(ap.parse_args().n_max)
