"""Roofline analysis over the dry-run records (single-pod mesh).

Terms per (arch x shape), all per-device (the partitioned module IS the
per-device program):

    T_comp = HLO_flops / 197e12           (bf16 MXU peak, TPU v5e-like)
    T_mem  = HLO_bytes / 819e9            (HBM bandwidth)
    T_coll = sum_k mult_k * bytes_k / 50e9  (ICI link bandwidth)

Link-traffic multipliers: ring all-reduce moves ~2x its payload per device;
all-gather payload is already counted as the gathered output (~1x traffic);
reduce-scatter / all-to-all / collective-permute ~1x.  These are the
standard ring-collective estimates; EXPERIMENTS.md documents them.

MODEL_FLOPS = 6 * N_matmul * D (train) or 2 * N_matmul * D (serve forward),
with N_matmul = matmul-visible parameters (embedding *gathers* excluded,
the unembedding matmul included, MoE experts counted at top_k/E activity).
The MODEL/HLO ratio exposes remat + sharding redundancy.

Usage:  PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

COLL_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
             "all-to-all": 1.0, "collective-permute": 1.0}


def model_flops(arch: str, shape: dict, devices: int) -> float:
    """Analytic MODEL_FLOPS per device for the cell."""
    import jax
    from repro.configs import ARCHS, SHAPES
    from repro.models import build_model

    cfg = ARCHS[arch]
    spec = SHAPES[shape["shape"]]
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_leaves_with_path(params)
    total = sum(int(_np_prod(l.shape)) for _, l in flat)
    embed = sum(int(_np_prod(l.shape)) for p, l in flat
                if "embed" in jax.tree_util.keystr(p))
    n_matmul = total if cfg.tie_embeddings else total - embed
    if cfg.family == "moe":
        expert = sum(int(_np_prod(l.shape)) for p, l in flat
                     if any(w in jax.tree_util.keystr(p)
                            for w in ("w_gate", "w_up", "w_down")))
        # active share over the (possibly padded) expert count: padded
        # experts receive no tokens, so k/Ep x padded_total = k x (d x ff x 3)
        e_p = max(cfg.n_experts_padded, cfg.n_experts)
        n_matmul -= expert * (1.0 - cfg.top_k / e_p)
    if spec.kind == "train":
        tokens = spec.global_batch * spec.seq_len
        return 6.0 * n_matmul * tokens / devices
    if spec.kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
        return 2.0 * n_matmul * tokens / devices
    tokens = spec.global_batch            # one new token per sequence
    return 2.0 * n_matmul * tokens / devices


def _np_prod(shape):
    out = 1
    for s in shape:
        out *= s
    return out


def cell_terms(rec: dict) -> dict:
    c = rec["cost"]
    t_comp = c["flops"] / PEAK_FLOPS
    t_mem = c["bytes"] / HBM_BW
    t_coll = sum(COLL_MULT.get(k, 1.0) * v / LINK_BW
                 for k, v in c["collectives"]["bytes"].items())
    dom = max(("compute", t_comp), ("memory", t_mem),
              ("collective", t_coll), key=lambda kv: kv[1])
    mf = model_flops(rec["arch"], rec, rec["devices"])
    bound = max(t_comp, t_mem, t_coll)
    return {
        "t_comp_s": t_comp, "t_mem_s": t_mem, "t_coll_s": t_coll,
        "dominant": dom[0],
        "model_flops_per_device": mf,
        "useful_ratio": mf / max(c["flops"], 1.0),
        # fraction of the bound spent on *useful* model math at MXU peak:
        # the roofline score for the cell
        "roofline_fraction": (mf / PEAK_FLOPS) / max(bound, 1e-30),
        "step_time_bound_s": bound,
    }


_NOTES = {
    "compute": ("compute-bound: raise useful_ratio — cut remat recompute or "
                "reshard so both mesh axes contribute to the dominant "
                "matmuls"),
    "memory": ("memory-bound: shrink materialized intermediates (fuse f32 "
               "chains, narrower activations dtype, bigger effective "
               "microbatch) or shard the traffic-heavy tensor"),
    "collective": ("collective-bound: reduce per-step traffic — accumulate "
                   "grads locally and all-reduce once, overlap the ring "
                   "with compute, or reshard to kill the biggest gather"),
}


def load_records(d: str, mesh: str = "pod16x16"):
    recs = []
    for f in sorted(glob.glob(os.path.join(d, f"*__{mesh}.json"))):
        r = json.load(open(f))
        if "cost" in r:
            recs.append(r)
        elif "skipped" in r:
            recs.append(r)
    return recs


def table(d: str, mesh: str = "pod16x16"):
    rows = []
    for r in load_records(d, mesh):
        if "skipped" in r:
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "skipped": r["skipped"]})
            continue
        t = cell_terms(r)
        biggest_coll = max(r["cost"]["collectives"]["bytes"].items(),
                           key=lambda kv: kv[1], default=("-", 0))
        rows.append({
            "arch": r["arch"], "shape": r["shape"], **t,
            "biggest_coll": biggest_coll[0],
            "note": _NOTES[t["dominant"]],
        })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--md-out", default="experiments/roofline.md")
    args = ap.parse_args()

    rows = table(args.dir, args.mesh)
    hdr = (f"| arch | shape | T_comp s | T_mem s | T_coll s | dominant | "
           f"MODEL/HLO | roofline frac | top coll |")
    sep = "|" + "---|" * 9
    lines = [f"Roofline over {args.mesh} "
             f"(197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)", "", hdr, sep]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip: {r['skipped']} | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_comp_s']:.3g} | "
            f"{r['t_mem_s']:.3g} | {r['t_coll_s']:.3g} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.2%} | "
            f"{r['biggest_coll']} |")
    out = "\n".join(lines)
    print(out)
    if args.md_out:
        os.makedirs(os.path.dirname(args.md_out), exist_ok=True)
        with open(args.md_out, "w") as f:
            f.write(out + "\n")
        print(f"\n[roofline] table -> {args.md_out}")


if __name__ == "__main__":
    main()
