"""CI smoke test for the repro.obs observability layer.

Three checks, exit 1 when any fails:

1. **Span tree** — a traced block-sparse ``DPCEngine.fit`` must emit the
   expected phase tree (``engine.fit`` root with the approxdpc driver and
   labeling children) with fenced device times on the compute phases, and
   the children's host time must account for most of the root's (the
   fence-inside-span design: per-phase times sum to ~wall time).
2. **Disabled overhead** — with obs off, ``span()`` must return the shared
   null singleton at sub-microsecond cost, and an end-to-end ``fit`` must
   not be measurably slower than the same fit at ``level="metrics"``
   (generous noise bound; the off path adds one dict lookup per phase).
3. **Snapshot** — ``--out`` writes the run's metrics/trace snapshot
   (``repro.obs/1`` schema) for CI artifact diffing.

    PYTHONPATH=src python -m benchmarks.obs_smoke [--n 4096] [--out obs-metrics.json]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro import obs
from repro.core.tuning import pick_dcut
from repro.engine import DPCEngine, ExecSpec
from repro.obs import report as obs_report

from .util import timeit_stats

EXPECTED_PATHS = (
    "engine.fit",
    "engine.fit/approxdpc.grid",
    "engine.fit/approxdpc.rho_delta",
    "engine.fit/approxdpc.rules",
    "engine.fit/labels.assign",
)
# children must cover this fraction of the root's host time (the fences run
# inside the phase spans, so orchestration self-time is all that's left out)
MIN_CHILD_COVERAGE = 0.5
# null-span path budget per obs.span() call with obs off (one dict lookup)
MAX_NULL_SPAN_US = 5.0
# off-vs-metrics fit time: off may not exceed metrics by more than this
# factor (both should be ~identical; this is a noise-tolerant upper bound)
MAX_OFF_OVERHEAD = 1.5


def _data(n: int, d: int = 3, seed: int = 0):
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0.0, 5400.0, (n, d)).astype(np.float32)
    d_cut = float(pick_dcut(pts, target_rho=min(30.0, n / 200)))
    return pts, d_cut


def _fresh_engine(d_cut: float) -> DPCEngine:
    return DPCEngine(d_cut=d_cut, algorithm="approxdpc",
                     exec_spec=ExecSpec(backend="jnp", layout="block-sparse"))


def check_span_tree(n: int) -> list[str]:
    failures = []
    pts, d_cut = _data(n)
    obs.reset_spans()
    obs.configure(level="trace")
    try:
        _fresh_engine(d_cut).fit(pts)
    finally:
        obs.configure(level="off")
    recs = obs.spans()
    paths = {r["path"] for r in recs}
    for want in EXPECTED_PATHS:
        if want not in paths:
            failures.append(f"span tree: missing phase {want!r} "
                            f"(got {sorted(paths)})")
    phases = obs_report.aggregate(recs)
    root = phases.get("engine.fit")
    if root is None:
        return failures
    fenced = [p for p, r in phases.items()
              if p != "engine.fit" and r["device_s"] is not None]
    if not fenced:
        failures.append("span tree: no child phase fenced device time at "
                        "level='trace'")
    child_host = sum(r["host_s"] for p, r in phases.items()
                     if p.startswith("engine.fit/"))
    if root["host_s"] > 0 and child_host < MIN_CHILD_COVERAGE * root["host_s"]:
        failures.append(
            f"span tree: children cover {child_host / root['host_s']:.0%} "
            f"of engine.fit host time < {MIN_CHILD_COVERAGE:.0%} floor")
    print(f"[obs_smoke] span tree OK: {len(recs)} spans, engine.fit "
          f"{root['host_s'] * 1e3:.1f}ms, children "
          f"{child_host * 1e3:.1f}ms", flush=True)
    return failures


def check_disabled_overhead(n: int) -> list[str]:
    failures = []
    obs.configure(level="off")
    # (a) the off-path span() must be the shared null singleton, cheap
    if obs.span("x") is not obs.NULL_SPAN:
        failures.append("off path: span() did not return NULL_SPAN")
    reps = 100_000
    t0 = time.perf_counter()
    for _ in range(reps):
        with obs.span("x") as sp:
            sp.sync(None)
    per_us = (time.perf_counter() - t0) / reps * 1e6
    if per_us > MAX_NULL_SPAN_US:
        failures.append(f"off path: {per_us:.2f}us per span() call "
                        f"> {MAX_NULL_SPAN_US}us budget")
    # (b) end-to-end: off fit must not be slower than metrics fit (bound is
    # generous — the point is catching an accidentally always-on fence)
    pts, d_cut = _data(n)

    def fit_off():
        return _fresh_engine(d_cut).fit(pts).result.rho

    def fit_metrics():
        obs.configure(level="metrics")
        try:
            return _fresh_engine(d_cut).fit(pts).result.rho
        finally:
            obs.configure(level="off")

    off = timeit_stats(fit_off, repeats=3, warmup=1)
    met = timeit_stats(fit_metrics, repeats=3, warmup=1)
    if off["min_s"] > MAX_OFF_OVERHEAD * met["min_s"]:
        failures.append(
            f"off path: fit {off['min_s'] * 1e3:.1f}ms > "
            f"{MAX_OFF_OVERHEAD}x metrics-level fit "
            f"{met['min_s'] * 1e3:.1f}ms")
    print(f"[obs_smoke] disabled overhead OK: {per_us:.2f}us/span, fit "
          f"off {off['min_s'] * 1e3:.1f}ms vs metrics "
          f"{met['min_s'] * 1e3:.1f}ms", flush=True)
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--out", default=None,
                    help="write the repro.obs run snapshot here")
    a = ap.parse_args(argv)

    failures = check_span_tree(a.n) + check_disabled_overhead(a.n)
    if a.out:
        obs_report.export_snapshot(a.out)
        print(f"[obs_smoke] wrote snapshot to {a.out}", flush=True)
    if failures:
        print("[obs_smoke] FAIL", flush=True)
        for f in failures:
            print("  -", f, flush=True)
        return 1
    print("[obs_smoke] OK", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
