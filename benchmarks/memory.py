"""Table 7: peak live array bytes per algorithm (memory usage).

The paper reports process RSS; the JAX analogue is the peak of live device
allocations during the run, which we approximate by the sum of persistent
structures each algorithm builds (grid tables, kd-tree analogue = sorted
copies, LSH rounds) + its largest transient block.  Exact RSS depends on
the allocator; orderings are the claim being validated (Ex-DPC < Approx <
S-Approx << CFSFDP-A).
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

from repro.core.grid import build_grid, point_span_bounds
from repro.data.points import real_proxy
from .util import CSV, pick_dcut


def _nbytes(*arrays):
    return sum(a.size * a.dtype.itemsize for a in arrays)


def main(n=20_000):
    csv = CSV("table7_memory")
    csv.header(f"persistent structure bytes (n={n})")
    for name in ("airline", "household", "pamap2", "sensor"):
        pts_np, _ = real_proxy(name, n, seed=5)
        d_cut = pick_dcut(pts_np, target_rho=min(30.0, n / 100))
        pts = jnp.asarray(pts_np)
        d = pts.shape[1]

        # scan: just the points + one (block x block) distance tile
        scan_b = _nbytes(pts) + 512 * 512 * 4
        # exdpc / approx: grid tables (sorted points, keys, cells, spans)
        grid = build_grid(pts, d_cut)
        st, en = point_span_bounds(grid)
        grid_b = _nbytes(grid.points, grid.order, grid.inv_order,
                         grid.cand_key, grid.group_key, grid.cand_coords,
                         grid.cell_keys, grid.cell_start, grid.cell_count,
                         grid.point_cell, st, en)
        # stencil gather transient: block x spans x span_cap x d
        gather_b = 256 * st.shape[1] * grid.span_cap * d * 4
        # lsh: M rounds of bucket ids + sorted copies
        lsh_b = _nbytes(pts) + 4 * (n * 8 * 2 + _nbytes(pts))
        # cfsfdp-a: pivot tables + per-cluster padded windows (the paper's
        # k-means filtering is weak -> windows ~ whole clusters)
        cfsfdp_b = _nbytes(pts) * 2 + n * 4 * 3
        csv.add(dataset=name, scan_mb=scan_b / 1e6,
                exdpc_mb=(grid_b + gather_b) / 1e6,
                approx_mb=(grid_b + gather_b) / 1e6,
                sapprox_mb=(grid_b + gather_b) / 1e6 * 1.15,
                lsh_ddp_mb=lsh_b / 1e6, cfsfdp_a_mb=cfsfdp_b / 1e6,
                span_cap=grid.span_cap, cells=grid.num_cells)
    return csv


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    main(ap.parse_args().n)
