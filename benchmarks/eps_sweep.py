"""Table 5: S-Approx-DPC time vs accuracy across its eps parameter."""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import DPCConfig, cluster, rand_index
from repro.core.sapproxdpc import run_sapproxdpc

from repro.data.points import real_proxy

from .util import CSV, pick_dcut, timeit


def main(n=20_000):
    csv = CSV("table5_eps")
    csv.header(f"S-Approx-DPC eps sweep (n={n})")
    for dataset in ("airline", "household"):
        pts, _ = real_proxy(dataset, n, seed=3)
        d_cut = pick_dcut(pts, target_rho=min(40.0, n / 100))
        ref, _ = cluster(pts, DPCConfig(d_cut=d_cut, rho_min=8,
                                        algorithm="exdpc"))
        ref_labels = np.asarray(ref.labels)
        for eps in (0.2, 0.4, 0.6, 0.8, 1.0):
            t = timeit(run_sapproxdpc, pts, d_cut, eps, repeats=2)
            out, _ = cluster(pts, DPCConfig(d_cut=d_cut, rho_min=8,
                                            algorithm="sapproxdpc", eps=eps))
            ri = rand_index(ref_labels, np.asarray(out.labels))
            csv.add(dataset=dataset, eps=eps, time_s=t, rand_index=ri)
    return csv


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    main(ap.parse_args().n)
