"""Shared benchmark utilities: timing, d_cut selection, CSV emission.

Scale note: the paper's machine is a 24-core Xeon running C++ on datasets of
2-6M points; this container is a single-core CPU interpreting JAX, so the
default sizes are scaled down (n ~ 2e4) and every table records its n.  The
paper's *claims* that we validate — accuracy ordering, scaling exponents,
algorithm speed ordering — are size-robust; absolute seconds are not
comparable and are not the deliverable (the roofline/dry-run is).
"""
from __future__ import annotations

import time

import numpy as np
import jax


def timeit_stats(fn, *args, repeats: int = 3, warmup: int = 1, **kw) -> dict:
    """Device-synced timing of ``fn(*args, **kw)``.

    Every warmup result is fully synced (``jax.block_until_ready`` over the
    whole output tree) *before* t0 of the first measured repeat, so compile
    time can never leak into the measurements.  Each measured repeat is
    likewise synced inside its own window, so ``times_s`` are true
    device-complete wall times, not async-dispatch times.

    Returns ``{"times_s": [per-repeat seconds], "median_s", "min_s",
    "warmup_s" (total seconds spent in the synced warmup runs)}``.
    """
    w0 = time.perf_counter()
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    warmup_s = time.perf_counter() - w0
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return {"times_s": ts, "median_s": float(np.median(ts)),
            "min_s": float(np.min(ts)), "warmup_s": warmup_s}


def timeit(fn, *args, repeats: int = 3, warmup: int = 1, **kw) -> float:
    """Median device-synced wall seconds of fn(*args); see timeit_stats."""
    return timeit_stats(fn, *args, repeats=repeats, warmup=warmup,
                        **kw)["median_s"]


from repro.core.tuning import pick_dcut  # noqa: F401  (re-export)


class CSV:
    """Collects rows and prints a section of `name,key=val,...` lines."""

    def __init__(self, name: str):
        self.name = name
        self.rows = []

    def add(self, **kv):
        self.rows.append(kv)
        print(f"[{self.name}] " + ",".join(f"{k}={_fmt(v)}"
                                           for k, v in kv.items()),
              flush=True)

    def header(self, note: str = ""):
        print(f"\n=== {self.name} {note}", flush=True)


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)
