"""Shared benchmark utilities: timing, d_cut selection, CSV emission.

Scale note: the paper's machine is a 24-core Xeon running C++ on datasets of
2-6M points; this container is a single-core CPU interpreting JAX, so the
default sizes are scaled down (n ~ 2e4) and every table records its n.  The
paper's *claims* that we validate — accuracy ordering, scaling exponents,
algorithm speed ordering — are size-robust; absolute seconds are not
comparable and are not the deliverable (the roofline/dry-run is).
"""
from __future__ import annotations

import time

import numpy as np
import jax


def timeit(fn, *args, repeats: int = 3, warmup: int = 1, **kw):
    """Median wall seconds of fn(*args); blocks on all jax outputs."""
    for _ in range(warmup):
        _block(fn(*args, **kw))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        _block(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _block(out):
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return out


from repro.core.tuning import pick_dcut  # noqa: F401  (re-export)


class CSV:
    """Collects rows and prints a section of `name,key=val,...` lines."""

    def __init__(self, name: str):
        self.name = name
        self.rows = []

    def add(self, **kv):
        self.rows.append(kv)
        print(f"[{self.name}] " + ",".join(f"{k}={_fmt(v)}"
                                           for k, v in kv.items()),
              flush=True)

    def header(self, note: str = ""):
        print(f"\n=== {self.name} {note}", flush=True)


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)
