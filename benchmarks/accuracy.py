"""Clustering-quality benchmarks: paper Tables 2, 3, 4 (+ Fig. 6 counts).

Rand index of each approximation algorithm against Ex-DPC's clustering
(Ex-DPC = ground truth, exactly the paper's §6.1 protocol).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import DPCConfig, cluster, rand_index
from repro.data.points import gaussian_mixture, random_walk, real_proxy, with_noise

from .util import CSV, pick_dcut


def _cluster_labels(points, d_cut, algorithm, rho_min, eps=1.0):
    out, _ = cluster(points, DPCConfig(d_cut=d_cut, rho_min=rho_min,
                                       algorithm=algorithm, eps=eps))
    return np.asarray(out.labels), int(out.num_clusters)


ALGOS = ("approxdpc", "sapproxdpc", "lsh_ddp")


def noise_sweep(n=20_000, seed=0):
    """Table 2: Rand index vs noise rate on Syn (random-walk, 13 peaks)."""
    csv = CSV("table2_noise")
    csv.header(f"Rand index vs noise rate (Syn-like, n={n})")
    for rate in (0.01, 0.02, 0.04, 0.08, 0.16):
        base, labels = random_walk(int(n / (1 + rate)), k=13, seed=seed)
        pts, _ = with_noise(base, labels, rate, seed=seed)
        d_cut = pick_dcut(pts, target_rho=min(40.0, n / 100))
        ref, k_ref = _cluster_labels(pts, d_cut, "exdpc", rho_min=8)
        row = {"noise_rate": rate, "clusters_exdpc": k_ref}
        for algo in ALGOS:
            got, _ = _cluster_labels(pts, d_cut, algo, rho_min=8)
            row[f"rand_{algo}"] = rand_index(ref, got)
        csv.add(**row)
    return csv


def overlap_sweep(n=20_000, seed=1):
    """Table 3: Rand index vs cluster overlap (S1..S4 analogues)."""
    csv = CSV("table3_overlap")
    csv.header(f"Rand index vs overlap degree (15 Gaussians, n={n})")
    for name, overlap in (("S1", 0.010), ("S2", 0.016), ("S3", 0.022),
                          ("S4", 0.028)):
        pts, _ = gaussian_mixture(n, k=15, d=2, overlap=overlap, seed=seed)
        d_cut = pick_dcut(pts, target_rho=min(40.0, n / 100))
        ref, k_ref = _cluster_labels(pts, d_cut, "exdpc", rho_min=8)
        row = {"dataset": name, "clusters_exdpc": k_ref}
        for algo in ALGOS:
            got, _ = _cluster_labels(pts, d_cut, algo, rho_min=8)
            row[f"rand_{algo}"] = rand_index(ref, got)
        csv.add(**row)
    return csv


def realistic(n=20_000, seed=2):
    """Table 4: Rand index on real-dataset proxies (Airline/Household/
    PAMAP2/Sensor dims + skewed densities)."""
    csv = CSV("table4_real")
    csv.header(f"Rand index on real-like datasets (n={n})")
    for name in ("airline", "household", "pamap2", "sensor"):
        pts, _ = real_proxy(name, n, seed=seed)
        d_cut = pick_dcut(pts, target_rho=min(40.0, n / 100))
        ref, k_ref = _cluster_labels(pts, d_cut, "exdpc", rho_min=8)
        row = {"dataset": name, "clusters_exdpc": k_ref}
        for algo in ALGOS:
            got, _ = _cluster_labels(pts, d_cut, algo, rho_min=8)
            row[f"rand_{algo}"] = rand_index(ref, got)
        csv.add(**row)
    return csv


def main(n=20_000):
    noise_sweep(n)
    overlap_sweep(n)
    realistic(n)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    main(ap.parse_args().n)
