"""Fig. 9: scaling with worker count (threads -> SPMD shards).

The paper varies OpenMP threads on a 24-core Xeon; the TPU-native analogue
is the shard count of the distributed DPC runtime.  Each shard count runs
in a subprocess (XLA fixes the host device count at init).  On this 1-core
container the wall-time is serialized, so the reported metric is the
per-shard WORK (max shard's touched candidate volume) — the load-balance
property the paper's Fig. 9 is actually about — plus wall seconds for
reference.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from .util import CSV

_WORKER = r"""
import warnings, json, time
warnings.filterwarnings("ignore")
import numpy as np, jax
from repro.distributed import distributed_dpc, DistDPCConfig
from repro.data.points import real_proxy
from benchmarks.util import pick_dcut

n, shards, dataset = @N@, @SHARDS@, "@DATASET@"
pts, _ = real_proxy(dataset, n, seed=8)
d_cut = pick_dcut(pts, target_rho=min(30.0, n / 200))
mesh = jax.make_mesh((shards,), ("data",))
t0 = time.time()
res = distributed_dpc(pts, DistDPCConfig(d_cut=d_cut), mesh)
res.rho.block_until_ready()
t1 = time.time()
# load balance: per-shard candidate work = sum of span widths of its rows
from repro.core.grid import build_grid, point_span_bounds
import jax.numpy as jnp
grid = build_grid(jnp.asarray(pts, jnp.float32), d_cut)
st, en = point_span_bounds(grid)
work = np.asarray((en - st).sum(axis=1))
m = -(-len(work) // shards) * shards
work = np.pad(work, (0, m - len(work)))
per = work.reshape(shards, -1).sum(axis=1)
print("RESULT" + json.dumps({
    "wall_s": t1 - t0,
    "work_max": float(per.max()), "work_mean": float(per.mean()),
    "imbalance": float(per.max() / max(per.mean(), 1.0)),
}))
"""


def main(n=16_000, dataset="household", shard_counts=(1, 2, 4, 8)):
    csv = CSV("fig9_shards")
    csv.header(f"distributed DPC vs shard count ({dataset}, n={n})")
    for s in shard_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={s}"
        env.setdefault("PYTHONPATH", "src")
        code = (_WORKER.replace("@N@", str(n))
                .replace("@SHARDS@", str(s))
                .replace("@DATASET@", dataset))
        proc = subprocess.run([sys.executable, "-c", code], env=env,
                              capture_output=True, text=True, timeout=1800)
        if proc.returncode != 0:
            csv.add(shards=s, error=proc.stderr.strip()[-200:])
            continue
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("RESULT")][0]
        r = json.loads(line[len("RESULT"):])
        csv.add(shards=s, wall_s=r["wall_s"], work_per_shard_max=r["work_max"],
                imbalance=r["imbalance"])
    return csv


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16_000)
    main(ap.parse_args().n)
