"""Table 6: decomposed rho-computation vs delta-computation time per
algorithm, on the real-dataset proxies."""
from __future__ import annotations

import argparse

import jax.numpy as jnp

from repro.core.cfsfdp_a import run_cfsfdp_a
from repro.core.grid import build_grid
from repro.core.lsh_ddp import run_lsh_ddp
from repro.core.sapproxdpc import run_sapproxdpc
from repro.core.scan import dependent_scan, local_density_scan
from repro.core.stencil import (dependent_stencil, density_per_cell,
                                density_per_point)
from repro.core.dpc_types import with_jitter

from repro.data.points import real_proxy
from .util import CSV, pick_dcut, timeit


def main(n=10_000, datasets=("airline", "household", "pamap2", "sensor")):
    csv = CSV("table6_decomposed")
    csv.header(f"rho/delta decomposed seconds (n={n})")
    for name in datasets:
        pts_np, _ = real_proxy(name, n, seed=4)
        d_cut = pick_dcut(pts_np, target_rho=min(30.0, n / 100))
        pts = jnp.asarray(pts_np)
        grid = build_grid(pts, d_cut)

        rho = local_density_scan(pts, d_cut)
        rk = with_jitter(rho)
        rk_sorted = rk[grid.order]

        rows = {
            # Scan: blocked O(n^2) rho + O(n^2) masked-NN delta
            "scan": (
                timeit(local_density_scan, pts, d_cut, repeats=2),
                timeit(dependent_scan, pts, rk, repeats=2)),
            # Ex-DPC: per-point stencil rho + stencil-delta (+ fallback cost
            # excluded: host-orchestrated, measured by scaling_n end-to-end)
            "exdpc": (
                timeit(density_per_point, grid, repeats=2),
                timeit(dependent_stencil, grid, rk_sorted, repeats=2)),
            # Approx-DPC: joint per-cell rho; delta is O(1) segment ops +
            # the same stencil pass
            "approxdpc": (
                timeit(density_per_cell, grid, repeats=2),
                timeit(dependent_stencil, grid, rk_sorted, repeats=2)),
        }
        for algo, (t_rho, t_delta) in rows.items():
            csv.add(dataset=name, algo=algo, rho_s=t_rho, delta_s=t_delta)
        # end-to-end for the approximate/baseline algorithms (their phases
        # interleave, so report total)
        for algo, fn in (("sapproxdpc", lambda: run_sapproxdpc(pts, d_cut)),
                         ("lsh_ddp", lambda: run_lsh_ddp(pts, d_cut)),
                         ("cfsfdp_a", lambda: run_cfsfdp_a(pts, d_cut))):
            csv.add(dataset=name, algo=algo, total_s=timeit(fn, repeats=2))
    return csv


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10_000)
    main(ap.parse_args().n)
