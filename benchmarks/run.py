"""Benchmark driver: one section per paper table/figure + the roofline and
beyond-paper benches.  ``--quick`` (default) uses CPU-container sizes; pass
--full for larger n.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only table2,...]
"""
from __future__ import annotations

import argparse
import time
import traceback


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated section names")
    args = ap.parse_args()
    big = args.full

    from . import (accuracy, backend_compare, decomposed, dpc_kv_bench,
                   eps_sweep, memory, scaling_dcut, scaling_n, scaling_shards)

    sections = {
        "table2_3_4_accuracy": lambda: accuracy.main(
            n=40_000 if big else 12_000),
        "table5_eps": lambda: eps_sweep.main(n=40_000 if big else 12_000),
        "table6_decomposed": lambda: decomposed.main(
            n=20_000 if big else 8_000),
        "table7_memory": lambda: memory.main(n=40_000 if big else 16_000),
        "fig7_scaling_n": lambda: scaling_n.main(
            n_max=64_000 if big else 16_000),
        "fig8_dcut": lambda: scaling_dcut.main(n=20_000 if big else 8_000),
        "fig9_shards": lambda: scaling_shards.main(
            n=32_000 if big else 10_000),
        "dpc_kv": lambda: dpc_kv_bench.main(S=2048 if big else 768),
        "backend_compare": lambda: backend_compare.main(
            n=8192 if big else 2048),
        "roofline": _roofline,
    }
    only = set(args.only.split(",")) if args.only else None
    failures = 0
    for name, fn in sections.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            fn()
            print(f"[run] {name} done in {time.time() - t0:.1f}s",
                  flush=True)
        except Exception:
            failures += 1
            print(f"[run] {name} FAILED:\n{traceback.format_exc()}",
                  flush=True)
    print(f"[run] complete, {failures} failed sections", flush=True)
    raise SystemExit(1 if failures else 0)


def _roofline():
    import os
    import sys
    from .roofline import main as roofline_main
    if not os.path.isdir("experiments/dryrun"):
        print("[roofline] no dry-run records; run "
              "PYTHONPATH=src python -m repro.launch.dryrun first")
        return
    argv = sys.argv
    sys.argv = [argv[0]]
    try:
        roofline_main()
    finally:
        sys.argv = argv


if __name__ == "__main__":
    main()
