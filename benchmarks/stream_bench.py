"""Streaming DPC throughput: incremental ingest vs full recompute.

The bench behind ``BENCH_stream.json``: loads an n-point sliding window,
then times (a) steady-state incremental ingest of batch_cap-point
micro-batches (``StreamDPC.ingest``: rho repair + maxima-only dependent
updates + labels) against (b) a from-scratch ``run_approxdpc`` +
``assign_labels`` of the same window.  Parity between the two is asserted
before timing — the speedup is for the *identical* answer.

Acceptance (ISSUE 2): B=256 into n=64k must beat full recompute by >= 5x
on CPU with the jnp backend.

    PYTHONPATH=src python -m benchmarks.stream_bench [--n 65536 --batch 256]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.approxdpc import run_approxdpc
from repro.core.labels import assign_labels
from repro.data.points import gaussian_mixture
from repro.engine import ExecSpec
from repro.stream import StreamDPC, StreamDPCConfig

from .util import CSV


def _block(x):
    jax.block_until_ready(x)
    return x


def main(n: int = 65536, batch: int = 256, d: int = 2, d_cut: float = 2000.0,
         ticks: int = 4, rho_min: float = 20.0, backend: str = "jnp",
         out: str = "experiments/stream"):
    csv = CSV("stream_bench")
    csv.header(f"n={n} batch={batch} backend={backend}")
    pts, _ = gaussian_mixture(n + (ticks + 1) * batch, k=15, d=d, seed=0)
    cfg = StreamDPCConfig(d_cut=d_cut, capacity=n, batch_cap=batch,
                          rho_min=rho_min,
                          exec_spec=ExecSpec(backend=backend))
    s = StreamDPC(cfg)

    t0 = time.perf_counter()
    s.initialize(pts[:n])
    init_s = time.perf_counter() - t0
    csv.add(phase="initialize", seconds=init_s)

    # warm the incremental path (compiles the repair/segment/NN programs)
    s.ingest(pts[n: n + batch])

    tick_s = []
    for t in range(1, ticks + 1):
        t0 = time.perf_counter()
        _block(s.ingest(pts[n + t * batch: n + (t + 1) * batch]).labels)
        tick_s.append(time.perf_counter() - t0)
        csv.add(phase="ingest", tick=t, seconds=tick_s[-1])
    inc_s = float(np.mean(tick_s))

    # full-recompute reference on the same window (warm timing)
    w = jnp.asarray(s.window_points())

    def full():
        res = run_approxdpc(w, d_cut, exec_spec=ExecSpec(backend=backend))
        return assign_labels(res, rho_min, cfg.resolved_delta_min())

    fresh = _block(full())
    assert bool(jnp.all(fresh.labels == s.clustering.labels)), \
        "bench aborted: stream diverged from the from-scratch reference"
    t0 = time.perf_counter()
    _block(full())
    full_s = time.perf_counter() - t0
    csv.add(phase="full_recompute", seconds=full_s)

    speedup = full_s / inc_s
    csv.add(phase="summary", incremental_s=inc_s, full_s=full_s,
            speedup=speedup)
    rec = {
        "n": n, "batch": batch, "d": d, "d_cut": d_cut, "ticks": ticks,
        "backend": backend, "platform": jax.default_backend(),
        "initialize_seconds": init_s,
        "incremental_seconds_per_tick": inc_s,
        "incremental_points_per_s": batch / inc_s,
        "full_recompute_seconds": full_s,
        "speedup": speedup,
        "parity_checked": True,
        "stats": s.stats(),
    }
    os.makedirs(out, exist_ok=True)
    path = os.path.join(out, "stream_bench.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"[stream_bench] wrote {path} (speedup {speedup:.1f}x)", flush=True)
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=65536)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--d", type=int, default=2)
    ap.add_argument("--d-cut", type=float, default=2000.0)
    ap.add_argument("--ticks", type=int, default=4)
    ap.add_argument("--backend", default="jnp")
    ap.add_argument("--out", default="experiments/stream")
    a = ap.parse_args()
    main(n=a.n, batch=a.batch, d=a.d, d_cut=a.d_cut, ticks=a.ticks,
         backend=a.backend, out=a.out)
