"""GQA/MQA attention with RoPE, sliding windows, prefix-LM masks and KV caches.

Memory discipline: training/prefill attention is chunked over the query axis
(lax.scan) so the live score tensor is (B, H, q_chunk, Lk) — a 4k x 4k f32
score matrix per layer would otherwise dominate HBM at the assigned shapes.
Softmax/logit arithmetic is f32; inputs/outputs bf16.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import ArchConfig, apply_rope, rope_angles, softcap, mscan


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, S, n_kv, head_dim) bf16
    v: jnp.ndarray  # (B, S, n_kv, head_dim) bf16
    # number of valid positions is tracked by the serving engine


def attn_mask(q_pos, k_pos, *, causal: bool, window: int | None,
              prefix_len: int | None, k_valid=None):
    """Boolean mask (..., Lq, Lk). True = attend."""
    m = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), bool)
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if causal:
        c = kp <= qp
        if prefix_len is not None:
            c = c | (kp < prefix_len)
        m = m & c
    if window is not None:
        m = m & (kp > qp - window)
    if k_valid is not None:
        m = m & k_valid[..., None, :]
    return m


def _sdpa(q, k, v, mask, cfg: ArchConfig):
    """q: (B, Lq, K, G, hd); k/v: (B, Lk, K, hd); mask: (B or 1, Lq, Lk)."""
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, cfg.attn_logit_softcap)
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out


def attention(q, k, v, q_positions, k_positions, cfg: ArchConfig, *,
              causal=True, window=None, prefix_len=None, k_valid=None,
              q_chunk: int = 512):
    """q: (B, Lq, H, hd); k/v: (B, Lk, K, hd).  Chunked over Lq.

    q_positions/k_positions: (Lq,)/(Lk,) absolute positions (RoPE applied by
    the caller).  Returns (B, Lq, H, hd).
    """
    B, Lq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Lq, K, G, hd)

    if Lq <= q_chunk:
        mask = attn_mask(jnp.broadcast_to(q_positions, (B, Lq)),
                         jnp.broadcast_to(k_positions, (B, k.shape[1])),
                         causal=causal, window=window, prefix_len=prefix_len,
                         k_valid=k_valid)
        out = _sdpa(qg, k, v, mask, cfg)
        return out.reshape(B, Lq, H, hd)

    assert Lq % q_chunk == 0, "query length must be divisible by q_chunk"
    nq = Lq // q_chunk
    qg = qg.reshape(B, nq, q_chunk, K, G, hd)
    qp = q_positions.reshape(nq, q_chunk)

    def body(_, inp):
        q_i, qp_i = inp
        mask = attn_mask(jnp.broadcast_to(qp_i, (B, q_chunk)),
                         jnp.broadcast_to(k_positions, (B, k.shape[1])),
                         causal=causal, window=window, prefix_len=prefix_len,
                         k_valid=k_valid)
        return None, _sdpa(q_i, k, v, mask, cfg)

    _, out = mscan(body, None,
                          (jnp.moveaxis(qg, 1, 0), qp))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Lq, K, G, hd)
    return out.reshape(B, Lq, H, hd)


def qkv_project(x, wq, wk, wv, cfg: ArchConfig, positions):
    """x: (B, L, d) -> RoPE'd q (B,L,H,hd), k/v (B,L,K,hd)."""
    q = jnp.einsum("bld,dnh->blnh", x, wq)
    k = jnp.einsum("bld,dnh->blnh", x, wk)
    v = jnp.einsum("bld,dnh->blnh", x, wv)
    cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def out_project(o, wo):
    """o: (B, L, H, hd) x wo (H, hd, d) -> (B, L, d)."""
    return jnp.einsum("blnh,nhd->bld", o, wo)


def seq_update(arr, new, slot):
    """dynamic_update_slice at sequence position ``slot`` (axis 1) for a
    (B, S, heads, head_dim) buffer; index dtypes are unified (x64-safe)."""
    slot = jnp.asarray(slot)
    z = jnp.zeros((), slot.dtype)
    return jax.lax.dynamic_update_slice(arr, new.astype(arr.dtype),
                                        (z, slot, z, z))


def update_cache(cache: KVCache, k_new, v_new, pos) -> KVCache:
    """Write k/v at [pos : pos+Lnew) (decode Lnew=1; prefill writes a prompt)."""
    return KVCache(k=seq_update(cache.k, k_new, pos),
                   v=seq_update(cache.v, v_new, pos))
