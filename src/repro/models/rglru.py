"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention
in a repeating (rec, rec, attn) pattern — the recurrentgemma-9b architecture.

The RG-LRU recurrence  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
with  a_t = exp(-c * r_t * softplus(L)) is a diagonal linear recurrence, so
training/prefill use jax.lax.associative_scan over time (O(log L) depth);
decode is the O(1) step.  Gates are block-diagonal (n_heads blocks), as in
Griffin.  Layers that do not divide the pattern length form an explicit
recurrent tail (38 = 12 x (rec,rec,attn) + 2 rec).

Each layer = temporal block + GeGLU MLP, both pre-norm residual.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import transformer as tfm
from .attention import attention, out_project, qkv_project, seq_update
from .common import (ArchConfig, MeshRules, constrain, dense_init, glu_ffn,
                     logical_to_spec, rms_norm, mscan)

_C = 8.0  # Griffin's fixed gate sharpness


def _counts(cfg: ArchConfig):
    n_super = cfg.n_layers // 3
    n_tail = cfg.n_layers - 3 * n_super        # trailing rec layers
    return n_super, n_tail


# ------------------------------------------------------------------- params
def _mlp_params(cfg: ArchConfig, key):
    k1, k2 = jax.random.split(key)
    return {"ln2": jnp.zeros((cfg.d_model,), cfg.dtype),
            "w_in": dense_init(k1, (cfg.d_model, 2, cfg.d_ff), cfg.dtype),
            "w_out": dense_init(k2, (cfg.d_ff, cfg.d_model), cfg.dtype)}


def _rec_params(cfg: ArchConfig, key):
    d, w, nb = cfg.d_model, cfg.rnn_width, cfg.n_heads
    bs = w // nb
    ks = jax.random.split(key, 8)
    dt = cfg.dtype
    # init Lambda so that a^c is in ~[0.9, 0.999] at r = 1
    u = jax.random.uniform(ks[7], (w,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2.0 * _C)))
    return {
        "ln1": jnp.zeros((d,), dt),
        "wg": dense_init(ks[0], (d, w), dt),
        "wx": dense_init(ks[1], (d, w), dt),
        "conv_w": dense_init(ks[2], (cfg.ssm_conv, w), dt),
        "conv_b": jnp.zeros((w,), dt),
        "wa": dense_init(ks[3], (nb, bs, bs), dt, in_axis=1),
        "ba": jnp.zeros((w,), jnp.float32),
        "wi": dense_init(ks[4], (nb, bs, bs), dt, in_axis=1),
        "bi": jnp.zeros((w,), jnp.float32),
        "lam": lam,
        "wo": dense_init(ks[6], (w, d), dt),
        **_mlp_params(cfg, ks[5]),
    }


def _attn_params(cfg: ArchConfig, key):
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 5)
    dt = cfg.dtype
    return {
        "ln1": jnp.zeros((d,), dt),
        "wq": dense_init(ks[0], (d, H, hd), dt),
        "wk": dense_init(ks[1], (d, K, hd), dt),
        "wv": dense_init(ks[2], (d, K, hd), dt),
        "wo": dense_init(ks[3], (H, hd, d), dt, in_axis=0),
        **_mlp_params(cfg, ks[4]),
    }


def init_params(cfg: ArchConfig, key) -> dict:
    n_super, n_tail = _counts(cfg)
    kE, kS, kT = jax.random.split(key, 3)

    def super_params(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"rec1": _rec_params(cfg, k1), "rec2": _rec_params(cfg, k2),
                "attn": _attn_params(cfg, k3)}

    params = {
        "embed": tfm.embed_init(kE, (cfg.vocab, cfg.d_model), cfg.dtype),
        "supers": jax.vmap(super_params)(jax.random.split(kS, n_super)),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    if n_tail:
        params["tail"] = jax.vmap(lambda k: _rec_params(cfg, k))(
            jax.random.split(kT, n_tail))
    return params


def _rec_specs(cfg: ArchConfig, rules: MeshRules, L: int):
    d, w, nb, ff = cfg.d_model, cfg.rnn_width, cfg.n_heads, cfg.d_ff

    def spec(*ax):
        return logical_to_spec(rules, *ax)

    return {
        "ln1": P(None, None),
        "wg": spec((None, L), (None, d), ("model", w)),
        "wx": spec((None, L), (None, d), ("model", w)),
        "conv_w": spec((None, L), (None, 0), ("model", w)),
        "conv_b": spec((None, L), ("model", w)),
        "wa": spec((None, L), ("model", nb), (None, 0), (None, 0)),
        "ba": spec((None, L), ("model", w)),
        "wi": spec((None, L), ("model", nb), (None, 0), (None, 0)),
        "bi": spec((None, L), ("model", w)),
        "lam": spec((None, L), ("model", w)),
        "wo": spec((None, L), ("model", w), (None, d)),
        "ln2": P(None, None),
        "w_in": spec((None, L), (None, d), (None, 2), ("model", ff)),
        "w_out": spec((None, L), ("model", ff), (None, d)),
    }


def _attn_specs(cfg: ArchConfig, rules: MeshRules, L: int):
    d, H, K, hd, ff = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                       cfg.d_ff)

    def spec(*ax):
        return logical_to_spec(rules, *ax)

    return {
        "ln1": P(None, None),
        "wq": spec((None, L), (None, d), ("model", H), (None, hd)),
        "wk": spec((None, L), (None, d), ("model", K), (None, hd)),
        "wv": spec((None, L), (None, d), ("model", K), (None, hd)),
        "wo": spec((None, L), ("model", H), (None, hd), (None, d)),
        "ln2": P(None, None),
        "w_in": spec((None, L), (None, d), (None, 2), ("model", ff)),
        "w_out": spec((None, L), ("model", ff), (None, d)),
    }


def param_specs(cfg: ArchConfig, rules: MeshRules) -> dict:
    n_super, n_tail = _counts(cfg)
    specs = {
        "embed": logical_to_spec(rules, ("model", cfg.vocab),
                                 (None, cfg.d_model)),
        "supers": {"rec1": _rec_specs(cfg, rules, n_super),
                   "rec2": _rec_specs(cfg, rules, n_super),
                   "attn": _attn_specs(cfg, rules, n_super)},
        "final_norm": P(None),
    }
    if n_tail:
        specs["tail"] = _rec_specs(cfg, rules, n_tail)
    return specs


# ------------------------------------------------------------------ blocks
def _blockdiag(x, w, b):
    """x: (..., width) -> block-diagonal linear; w: (nb, bs, bs)."""
    nb, bs, _ = w.shape
    xh = x.reshape(x.shape[:-1] + (nb, bs))
    y = jnp.einsum("...nb,nbc->...nc", xh, w)
    return y.reshape(x.shape) + b.astype(x.dtype)


def _rglru_scan(x, r, i, lam):
    """x/r/i: (B, L, w); lam: (w,).  Full-sequence linear recurrence (f32)."""
    log_a = -_C * r * jax.nn.softplus(lam.astype(jnp.float32))[None, None, :]
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * x)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h


def _rec_block(x, lp, cfg: ArchConfig, rules):
    """x: (B, L, d) -> temporal-mix output (B, L, d)."""
    w = cfg.rnn_width
    gate = jax.nn.gelu(jnp.einsum("bld,dw->blw", x, lp["wg"])
                       .astype(jnp.float32))
    u = jnp.einsum("bld,dw->blw", x, lp["wx"])
    # causal temporal conv (width ssm_conv)
    K = lp["conv_w"].shape[0]
    up = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    conv = sum(up[:, k:k + u.shape[1], :] * lp["conv_w"][k][None, None, :]
               for k in range(K)) + lp["conv_b"][None, None, :]
    r = jax.nn.sigmoid(_blockdiag(conv, lp["wa"], lp["ba"])
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(_blockdiag(conv, lp["wi"], lp["bi"])
                       .astype(jnp.float32))
    h = _rglru_scan(conv.astype(jnp.float32), r, i, lp["lam"])
    y = (h * gate).astype(x.dtype)
    if rules is not None:
        y = constrain(y, P(rules.data, None, rules.model(w)))
    return jnp.einsum("blw,wd->bld", y, lp["wo"])


def _layer(x, lp, cfg: ArchConfig, kind: str, positions, rules,
           q_chunk: int = 512):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if kind == "rec":
        x = x + _rec_block(h, lp, cfg, rules)
    else:
        q, k, v = qkv_project(h, lp["wq"], lp["wk"], lp["wv"], cfg, positions)
        o = attention(q, k, v, positions, positions, cfg, causal=True,
                      window=cfg.local_window, q_chunk=q_chunk)
        x = x + out_project(o, lp["wo"])
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + glu_ffn(h, lp["w_in"], lp["w_out"], cfg.activation)
    if rules is not None:
        x = constrain(x, P(rules.data, None, None))
    return x


# ----------------------------------------------------------------- forward
def forward(params, x, cfg: ArchConfig, positions, rules=None,
            remat: bool = True, q_chunk: int = 512):
    def body(h, sp):
        h = _layer(h, sp["rec1"], cfg, "rec", positions, rules, q_chunk)
        h = _layer(h, sp["rec2"], cfg, "rec", positions, rules, q_chunk)
        h = _layer(h, sp["attn"], cfg, "attn", positions, rules, q_chunk)
        return h, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = mscan(body, x, params["supers"])
    if "tail" in params:
        def tail_body(h, lp):
            return _layer(h, lp, cfg, "rec", positions, rules, q_chunk), None
        if remat:
            tail_body = jax.checkpoint(tail_body, prevent_cse=False)
        x, _ = mscan(tail_body, x, params["tail"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def loss_fn(params, batch, cfg: ArchConfig, rules=None, q_chunk: int = 512):
    tokens = batch["tokens"]
    x = tfm.embed_tokens(params, tokens, cfg)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    h = forward(params, x, cfg, positions, rules, q_chunk=q_chunk)
    labels, lmask = tfm.shifted_labels(tokens)
    if "mask" in batch:
        lmask = lmask & batch["mask"]
    return tfm.chunked_ce_loss(params, h, labels, cfg, mask=lmask,
                               rules=rules)


# ---------------------------------------------------------------- serving
def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    n_super, n_tail = _counts(cfg)
    w, K = cfg.rnn_width, cfg.ssm_conv
    S = min(max_len, cfg.local_window)
    cache = {
        "conv1": jnp.zeros((n_super, batch, K - 1, w), cfg.dtype),
        "h1": jnp.zeros((n_super, batch, w), jnp.float32),
        "conv2": jnp.zeros((n_super, batch, K - 1, w), cfg.dtype),
        "h2": jnp.zeros((n_super, batch, w), jnp.float32),
        "k": jnp.zeros((n_super, batch, S, cfg.n_kv_heads, cfg.head_dim),
                       cfg.dtype),
        "v": jnp.zeros((n_super, batch, S, cfg.n_kv_heads, cfg.head_dim),
                       cfg.dtype),
    }
    if n_tail:
        cache["tconv"] = jnp.zeros((n_tail, batch, K - 1, w), cfg.dtype)
        cache["th"] = jnp.zeros((n_tail, batch, w), jnp.float32)
    return cache


def cache_specs(cfg: ArchConfig, rules: MeshRules):
    n_super, n_tail = _counts(cfg)
    w = cfg.rnn_width

    def spec(*ax):
        return logical_to_spec(rules, *ax)

    conv = spec((None, 0), ("data", 0), (None, 0), ("model", w))
    hsp = spec((None, 0), ("data", 0), ("model", w))
    kv = spec((None, 0), ("data", 0), (None, 0),
              ("model", cfg.n_kv_heads), (None, 0))
    out = {"conv1": conv, "h1": hsp, "conv2": conv, "h2": hsp,
           "k": kv, "v": kv}
    if n_tail:
        out["tconv"] = conv
        out["th"] = hsp
    return out


def _rec_step(x1, conv_st, h_st, lp, cfg: ArchConfig):
    """One-token RG-LRU step. x1: (B, d)."""
    gate = jax.nn.gelu((x1 @ lp["wg"]).astype(jnp.float32))
    u = x1 @ lp["wx"]                                          # (B, w)
    window = jnp.concatenate([conv_st, u[:, None, :]], axis=1)  # (B,K,w)
    conv = jnp.einsum("bkw,kw->bw", window, lp["conv_w"]) + lp["conv_b"]
    r = jax.nn.sigmoid(_blockdiag(conv, lp["wa"], lp["ba"])
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(_blockdiag(conv, lp["wi"], lp["bi"])
                       .astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(lp["lam"].astype(jnp.float32))[None, :]
    a = jnp.exp(log_a)
    h_st = a * h_st + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * conv.astype(jnp.float32))
    y = (h_st * gate).astype(x1.dtype)
    return y @ lp["wo"], window[:, 1:, :], h_st


def _layer_step(h, lp, cfg, kind, state, pos, B):
    hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
    if kind == "rec":
        conv_st, h_st = state
        y, conv_st, h_st = _rec_step(hn[:, 0, :], conv_st, h_st, lp, cfg)
        h = h + y[:, None, :]
        new_state = (conv_st, h_st)
    else:
        kc, vc = state
        S = kc.shape[1]
        slot = pos % S
        q_pos = jnp.full((1,), pos, jnp.int32)
        idx = jnp.arange(S, dtype=jnp.int32)
        k_pos = jnp.where(idx <= slot, pos - slot + idx, pos - slot - S + idx)
        k_valid = (k_pos >= 0) & (k_pos <= pos)
        q, k_new, v_new = qkv_project(hn, lp["wq"], lp["wk"], lp["wv"], cfg,
                                      q_pos)
        kc = seq_update(kc, k_new, slot)
        vc = seq_update(vc, v_new, slot)
        o = attention(q, kc, vc, q_pos, k_pos, cfg, causal=True,
                      window=cfg.local_window,
                      k_valid=jnp.broadcast_to(k_valid, (B, S)))
        h = h + out_project(o, lp["wo"])
        new_state = (kc, vc)
    hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
    h = h + glu_ffn(hn, lp["w_in"], lp["w_out"], cfg.activation)
    return h, new_state


def decode_step(params, cache, tokens, pos, cfg: ArchConfig, rules=None):
    B = tokens.shape[0]
    x = tfm.embed_tokens(params, tokens, cfg)                  # (B, 1, d)

    def body(h, layer):
        sp, c1, h1, c2, h2, kc, vc = layer
        h, (c1, h1) = _layer_step(h, sp["rec1"], cfg, "rec", (c1, h1), pos, B)
        h, (c2, h2) = _layer_step(h, sp["rec2"], cfg, "rec", (c2, h2), pos, B)
        h, (kc, vc) = _layer_step(h, sp["attn"], cfg, "attn", (kc, vc), pos, B)
        return h, (c1, h1, c2, h2, kc, vc)

    h, (c1, h1, c2, h2, kc, vc) = mscan(
        body, x, (params["supers"], cache["conv1"], cache["h1"],
                  cache["conv2"], cache["h2"], cache["k"], cache["v"]))
    new_cache = dict(cache, conv1=c1, h1=h1, conv2=c2, h2=h2, k=kc, v=vc)
    if "tail" in params:
        def tail_body(h, layer):
            lp, ct, ht = layer
            h, (ct, ht) = _layer_step(h, lp, cfg, "rec", (ct, ht), pos, B)
            return h, (ct, ht)
        h, (ct, ht) = mscan(tail_body, h,
                                   (params["tail"], cache["tconv"],
                                    cache["th"]))
        new_cache["tconv"] = ct
        new_cache["th"] = ht
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = tfm.logits_at(params, h[:, -1, :], cfg)
    return logits, new_cache


def prefill(params, tokens, cfg: ArchConfig, cache, rules=None,
            q_chunk: int = 512):
    """Prompt pass.  Recurrent states via associative scan; the attention
    cache keeps the trailing local window.  Full hidden states are computed
    by the training forward; states are then re-derived per layer (the extra
    pass is the standard price of scan-stacked heterogeneous layers)."""
    B, L = tokens.shape
    x = tfm.embed_tokens(params, tokens, cfg)
    positions = jnp.arange(L, dtype=jnp.int32)
    S = cache["k"].shape[2]

    def rec_with_state(h, lp):
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        w = cfg.rnn_width
        gate = jax.nn.gelu(jnp.einsum("bld,dw->blw", hn, lp["wg"])
                           .astype(jnp.float32))
        u = jnp.einsum("bld,dw->blw", hn, lp["wx"])
        K = lp["conv_w"].shape[0]
        conv_tail = u[:, -(K - 1):, :].astype(cache["conv1"].dtype)
        up = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
        conv = sum(up[:, k:k + L, :] * lp["conv_w"][k][None, None, :]
                   for k in range(K)) + lp["conv_b"][None, None, :]
        r = jax.nn.sigmoid(_blockdiag(conv, lp["wa"], lp["ba"])
                           .astype(jnp.float32))
        i = jax.nn.sigmoid(_blockdiag(conv, lp["wi"], lp["bi"])
                           .astype(jnp.float32))
        hs = _rglru_scan(conv.astype(jnp.float32), r, i, lp["lam"])
        y = (hs * gate).astype(h.dtype)
        h = h + jnp.einsum("blw,wd->bld", y, lp["wo"])
        hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
        h = h + glu_ffn(hn, lp["w_in"], lp["w_out"], cfg.activation)
        return h, conv_tail, hs[:, -1, :]

    def attn_with_cache(h, lp):
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k_new, v_new = qkv_project(hn, lp["wq"], lp["wk"], lp["wv"], cfg,
                                      positions)
        o = attention(q, k_new, v_new, positions, positions, cfg, causal=True,
                      window=cfg.local_window, q_chunk=q_chunk)
        h = h + out_project(o, lp["wo"])
        hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
        h = h + glu_ffn(hn, lp["w_in"], lp["w_out"], cfg.activation)
        # ring-buffer layout: slot of position p is p % S
        tail_k = k_new[:, -S:, :, :]
        tail_v = v_new[:, -S:, :, :]
        shift = L % S
        kc = jnp.roll(tail_k, shift, axis=1).astype(cache["k"].dtype)
        vc = jnp.roll(tail_v, shift, axis=1).astype(cache["v"].dtype)
        return h, kc, vc

    def body(h, sp):
        h, c1, h1 = rec_with_state(h, sp["rec1"])
        h, c2, h2 = rec_with_state(h, sp["rec2"])
        h, kc, vc = attn_with_cache(h, sp["attn"])
        return h, (c1, h1, c2, h2, kc, vc)

    body = jax.checkpoint(body, prevent_cse=False)
    h, (c1, h1, c2, h2, kc, vc) = mscan(body, x, params["supers"])
    new_cache = dict(cache, conv1=c1, h1=h1, conv2=c2, h2=h2, k=kc, v=vc)
    if "tail" in params:
        def tail_body(h, lp):
            h, ct, ht = rec_with_state(h, lp)
            return h, (ct, ht)
        tail_body = jax.checkpoint(tail_body, prevent_cse=False)
        h, (ct, ht) = mscan(tail_body, h, params["tail"])
        new_cache["tconv"] = ct
        new_cache["th"] = ht
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = tfm.logits_at(params, h[:, -1, :], cfg)
    return logits, new_cache
