"""Model zoo: the 10 assigned architectures as composable JAX modules."""
from .common import ArchConfig
from .model_api import build_model, Model

__all__ = ["ArchConfig", "build_model", "Model"]
