"""Shared model config, layers, init and sharding helpers.

All parameters are stored in bf16 (training keeps f32 masters in the
optimizer state — see repro.train.optimizer); all norms/softmax/losses
accumulate in f32.  Parameter pytrees are plain nested dicts; a parallel
pytree of PartitionSpecs is produced by ``*_specs`` functions using logical
sharding rules resolved against the mesh axis sizes (a kv-head axis smaller
than the model axis falls back to replication, e.g. MQA).
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# --------------------------------------------------------------- scan shim
# XLA's cost_analysis counts a while-loop body ONCE, not x trip-count, so the
# dry-run's roofline pass lowers a separate fully-unrolled "cost program"
# (launch/dryrun.py).  All model scans go through ``mscan`` so that pass can
# flip them to unroll without touching call sites.
_UNROLL_SCANS = False


@contextlib.contextmanager
def unroll_scans(enable: bool = True):
    global _UNROLL_SCANS
    old = _UNROLL_SCANS
    _UNROLL_SCANS = enable
    try:
        yield
    finally:
        _UNROLL_SCANS = old


def mscan(body, init, xs, length=None):
    n = length
    if n is None:
        n = jax.tree.leaves(xs)[0].shape[0]
    return jax.lax.scan(body, init, xs, length=length,
                        unroll=n if _UNROLL_SCANS else 1)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    activation: str = "swiglu"   # swiglu | geglu
    rope_theta: float = 10000.0
    sliding_window: int | None = None
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    embed_scale: bool = False    # gemma-style sqrt(d) embedding scaling
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    n_experts_padded: int = 0   # pad expert count to a shardable multiple
    # ssm (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # hybrid (recurrentgemma): block pattern, local-attn window, rnn width
    pattern: tuple = ()
    local_window: int = 0
    rnn_width: int = 0
    # modality frontends (STUBS: inputs are precomputed embeddings)
    frontend_dim: int = 0        # audio frame / vision patch feature dim
    num_patches: int = 0         # vlm image tokens per example
    is_causal: bool = True
    dtype: Any = jnp.bfloat16

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)


# ---------------------------------------------------------------- sharding
@dataclass(frozen=True)
class MeshRules:
    """Logical-axis -> mesh-axis rules, resolved against axis sizes."""
    data_axes: tuple = ("data",)      # ('pod','data') on the multi-pod mesh
    model_axis: str = "model"
    axis_sizes: dict | None = None    # name -> size (for divisibility checks)

    def model(self, dim_size: int):
        """Shard over the model axis if divisible, else replicate.

        model_axis=None disables tensor parallelism entirely (small archs
        fold the model axis into data parallelism instead — §Perf)."""
        if self.model_axis is None:
            return None
        if self.axis_sizes is not None:
            m = self.axis_sizes.get(self.model_axis, 1)
            if dim_size % m != 0 or dim_size < m:
                return None
        return self.model_axis

    @property
    def data(self):
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

    def data_if(self, dim_size: int):
        """Shard over the data axes if divisible, else replicate."""
        if self.axis_sizes is not None:
            total = 1
            for a in self.data_axes:
                total *= self.axis_sizes.get(a, 1)
            if dim_size % total != 0 or dim_size < total:
                return None
        return self.data


def logical_to_spec(rules: MeshRules, *axes_and_sizes):
    """Build a PartitionSpec from (logical_axis, dim_size) pairs.

    Logical axes: 'model' (tensor-parallel), 'data' (batch), None (replicated).
    """
    parts = []
    for logical, size in axes_and_sizes:
        if logical == "model":
            parts.append(rules.model(size))
        elif logical == "data":
            parts.append(rules.data)
        else:
            parts.append(None)
    return P(*parts)


def constrain(x, spec: P):
    """with_sharding_constraint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


# ----------------------------------------------------------------- layers
def rms_norm(x, gamma, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def rope_angles(positions, head_dim: int, theta: float):
    """positions: (...,) int32 -> cos/sin (..., head_dim/2) in f32."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(jnp.float32)
    s = sin[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s],
                           axis=-1).astype(x.dtype)


def softcap(x, cap: float | None):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def glu_ffn(x, w_in, w_out, activation: str):
    """SwiGLU/GeGLU: w_in (d, 2, ff) fused gate+up, w_out (ff, d)."""
    h = jnp.einsum("...d,dcf->...cf", x, w_in)
    gate, up = h[..., 0, :], h[..., 1, :]
    act = jax.nn.silu if activation == "swiglu" else (
        lambda g: jax.nn.gelu(g, approximate=True))
    hidden = act(gate.astype(jnp.float32)).astype(x.dtype) * up
    return jnp.einsum("...f,fd->...d", hidden, w_out)


def cross_entropy(logits, labels, mask=None):
    """Token cross-entropy in f32; mask selects contributing positions."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# -------------------------------------------------------------------- init
def dense_init(key, shape, dtype, in_axis: int = 0):
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)).astype(dtype)


def split_tree(key, tree_def_dict):
    """Split a PRNG key into a dict matching tree_def_dict's keys."""
    keys = jax.random.split(key, len(tree_def_dict))
    return dict(zip(tree_def_dict, keys))
