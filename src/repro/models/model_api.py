"""Uniform Model facade over the four family implementations.

Every family exposes: init / loss_fn / param_specs, and (for decoder archs)
init_cache / cache_specs / prefill / decode_step.  ``build_model(cfg)``
dispatches on cfg.family so the trainer, server, dry-run and benchmarks are
architecture-agnostic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

from . import moe, rglru, ssm, transformer as tfm
from .common import ArchConfig


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[..., Any]            # (key) -> params
    loss_fn: Callable[..., Any]         # (params, batch, rules=None) -> loss
    param_specs: Callable[..., Any]     # (rules) -> PartitionSpec pytree
    init_cache: Callable[..., Any] | None = None   # (batch, max_len) -> cache
    cache_specs: Callable[..., Any] | None = None  # (rules) -> spec pytree
    prefill: Callable[..., Any] | None = None      # (params, batch, cache, rules)
    decode_step: Callable[..., Any] | None = None  # (params, cache, tok, pos, rules)

    @property
    def is_decoder(self) -> bool:
        return self.decode_step is not None


def _tfm_prefill(params, batch, cfg, cache, rules=None, q_chunk: int = 512):
    if cfg.family == "vlm" and "patches" in batch:
        return tfm.vlm_prefill(params, batch, cfg, cache, rules=rules,
                               q_chunk=q_chunk)
    return tfm.prefill(params, batch["tokens"], cfg, cache, rules=rules,
                       q_chunk=q_chunk)


def _moe_prefill(params, batch, cfg, cache, rules=None, q_chunk: int = 512):
    return moe.prefill(params, batch["tokens"], cfg, cache, rules=rules,
                       q_chunk=q_chunk)


def _ssm_prefill(params, batch, cfg, cache, rules=None, q_chunk: int = 512):
    return ssm.prefill(params, batch["tokens"], cfg, cache, rules=rules,
                       q_chunk=q_chunk)


def _rglru_prefill(params, batch, cfg, cache, rules=None, q_chunk: int = 512):
    return rglru.prefill(params, batch["tokens"], cfg, cache, rules=rules,
                         q_chunk=q_chunk)


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family in ("dense", "encoder", "vlm"):
        decoder = cfg.family != "encoder"
        return Model(
            cfg=cfg,
            init=lambda key: tfm.init_params(cfg, key),
            loss_fn=lambda p, b, rules=None, **kw: tfm.loss_fn(
                p, b, cfg, rules=rules, **kw),
            param_specs=lambda rules: tfm.param_specs(cfg, rules),
            init_cache=(lambda b, s: tfm.init_cache(cfg, b, s)) if decoder else None,
            cache_specs=(lambda rules: tfm.cache_specs(cfg, rules)) if decoder else None,
            prefill=(lambda p, b, c, rules=None, **kw: _tfm_prefill(
                p, b, cfg, c, rules=rules, **kw)) if decoder else None,
            decode_step=(lambda p, c, t, pos, rules=None: tfm.decode_step(
                p, c, t, pos, cfg, rules=rules)) if decoder else None,
        )
    if cfg.family == "moe":
        return Model(
            cfg=cfg,
            init=lambda key: moe.init_params(cfg, key),
            loss_fn=lambda p, b, rules=None, **kw: moe.loss_fn(
                p, b, cfg, rules=rules, **kw),
            param_specs=lambda rules: moe.param_specs(cfg, rules),
            init_cache=lambda b, s: tfm.init_cache(cfg, b, s),
            cache_specs=lambda rules: tfm.cache_specs(cfg, rules),
            prefill=lambda p, b, c, rules=None, **kw: _moe_prefill(
                p, b, cfg, c, rules=rules, **kw),
            decode_step=lambda p, c, t, pos, rules=None: moe.decode_step(
                p, c, t, pos, cfg, rules=rules),
        )
    if cfg.family == "ssm":
        return Model(
            cfg=cfg,
            init=lambda key: ssm.init_params(cfg, key),
            loss_fn=lambda p, b, rules=None, **kw: ssm.loss_fn(
                p, b, cfg, rules=rules, **kw),
            param_specs=lambda rules: ssm.param_specs(cfg, rules),
            init_cache=lambda b, s: ssm.init_cache(cfg, b, s),
            cache_specs=lambda rules: ssm.cache_specs(cfg, rules),
            prefill=lambda p, b, c, rules=None, **kw: _ssm_prefill(
                p, b, cfg, c, rules=rules, **kw),
            decode_step=lambda p, c, t, pos, rules=None: ssm.decode_step(
                p, c, t, pos, cfg, rules=rules),
        )
    if cfg.family == "hybrid":
        return Model(
            cfg=cfg,
            init=lambda key: rglru.init_params(cfg, key),
            loss_fn=lambda p, b, rules=None, **kw: rglru.loss_fn(
                p, b, cfg, rules=rules, **kw),
            param_specs=lambda rules: rglru.param_specs(cfg, rules),
            init_cache=lambda b, s: rglru.init_cache(cfg, b, s),
            cache_specs=lambda rules: rglru.cache_specs(cfg, rules),
            prefill=lambda p, b, c, rules=None, **kw: _rglru_prefill(
                p, b, cfg, c, rules=rules, **kw),
            decode_step=lambda p, c, t, pos, rules=None: rglru.decode_step(
                p, c, t, pos, cfg, rules=rules),
        )
    raise ValueError(f"unknown family: {cfg.family}")
