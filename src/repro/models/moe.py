"""Mixture-of-Experts family: granite-moe-3b-a800m (40e top-8... per the
assignment card: 32->40 experts top-8) and qwen3-moe-30b-a3b (128e top-8).

Dispatch is sort-based (MegaBlocks/MaxText style): token->expert assignments
are sorted by expert id, ranked within expert, dropped beyond capacity, and
gathered into an (E, C, d) buffer that feeds one batched einsum per FFN
matrix.  Under pjit the buffer is sharding-constrained to the model axis
(expert parallelism); XLA inserts the token all-to-alls.  A Switch-style
load-balancing aux loss is returned alongside.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import transformer as tfm
from .attention import attention, out_project, qkv_project, seq_update
from .common import (ArchConfig, MeshRules, constrain, dense_init,
                     logical_to_spec, rms_norm, mscan)


def _padded_experts(cfg: ArchConfig) -> int:
    return max(cfg.n_experts_padded, cfg.n_experts)


def init_layer_params(cfg: ArchConfig, key) -> dict:
    ks = jax.random.split(key, 8)
    d, H, K, hd, ff = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                       cfg.head_dim, cfg.d_ff)
    Ep = _padded_experts(cfg)       # expert weights padded (shardable)
    dt = cfg.dtype
    return {
        "ln1": jnp.zeros((d,), dt),
        "wq": dense_init(ks[0], (d, H, hd), dt),
        "wk": dense_init(ks[1], (d, K, hd), dt),
        "wv": dense_init(ks[2], (d, K, hd), dt),
        "wo": dense_init(ks[3], (H, hd, d), dt),
        "ln2": jnp.zeros((d,), dt),
        "router": dense_init(ks[4], (d, cfg.n_experts), jnp.float32),
        "w_gate": dense_init(ks[5], (Ep, d, ff), dt),
        "w_up": dense_init(ks[6], (Ep, d, ff), dt),
        "w_down": dense_init(ks[7], (Ep, ff, d), dt),
    }


def init_params(cfg: ArchConfig, key) -> dict:
    kE, kL, kU = jax.random.split(key, 3)
    params = {
        "embed": tfm.embed_init(kE, (cfg.vocab, cfg.d_model), cfg.dtype),
        "layers": jax.vmap(lambda k: init_layer_params(cfg, k))(
            jax.random.split(kL, cfg.n_layers)),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(kU, (cfg.d_model, cfg.vocab), cfg.dtype)
    return params


def param_specs(cfg: ArchConfig, rules: MeshRules) -> dict:
    base = tfm.param_specs(cfg.replace(family="dense"), rules)
    d, ff, E, L = (cfg.d_model, cfg.d_ff, _padded_experts(cfg),
                   cfg.n_layers)

    def spec(*ax):
        return logical_to_spec(rules, *ax)

    moe = {
        "router": P(None, None, None),
        "w_gate": spec((None, L), ("model", E), (None, d), (None, ff)),
        "w_up": spec((None, L), ("model", E), (None, d), (None, ff)),
        "w_down": spec((None, L), ("model", E), (None, ff), (None, d)),
    }
    layers = dict(base["layers"])
    for k in ("w_in", "w_out"):
        layers.pop(k, None)
    layers.update(moe)
    base["layers"] = layers
    return base


# Dispatch/combine formulation: 'scatter' builds the (E, C, d) buffer with
# scatter-writes and combines with scatter-add — GSPMD lowers both as
# replicated-compute + all-reduce.  'gather' scatters only int32 slot maps
# (tiny) and moves activations with gathers, which GSPMD reshards with
# all-gather/all-to-all instead — the §Perf collective-term iteration.
import contextlib

# Production default is the measured-better 'gather' mode (EXPERIMENTS.md
# §Perf cell 1: 10.2x less collective traffic, 7.7x less HBM traffic on
# qwen3-moe train_4k); 'scatter' reproduces the paper-faithful baseline
# records (launch/dryrun.py --moe-scatter).
DISPATCH_MODE = "gather"


@contextlib.contextmanager
def dispatch_mode(mode: str):
    global DISPATCH_MODE
    old = DISPATCH_MODE
    DISPATCH_MODE = mode
    try:
        yield
    finally:
        DISPATCH_MODE = old


def moe_ffn(x, lp, cfg: ArchConfig, rules: MeshRules | None):
    """x: (B, L, d) -> (y, aux_loss). Sort-based top-k dispatch."""
    B, L, d = x.shape
    T = B * L
    E, k = cfg.n_experts, cfg.top_k
    Ep = _padded_experts(cfg)       # buffer/einsum expert count (shardable)
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ lp["router"])          # (T, E) f32
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                      # (T, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    # Switch aux loss: E * sum_e f_e * P_e
    dispatch_frac = jnp.mean(
        jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0)
    router_frac = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(dispatch_frac * router_frac)

    C = int(max(8, -(-T * k // E) * cfg.capacity_factor))
    C = min(C, T)  # no point exceeding token count
    C = -(-C // 32) * 32   # keep the capacity axis shardable over data
    eflat = topi.reshape(-1)                                  # (T*k,)
    sort_idx = jnp.argsort(eflat, stable=True)
    sorted_e = eflat[sort_idx]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(T * k) - first
    keep = rank < C
    dest = jnp.where(keep, sorted_e * C + rank, Ep * C)       # drop slot Ep*C
    token_of = sort_idx // k

    if DISPATCH_MODE == "gather":
        # scatter only the int32 inverse map; activation movement = gather
        slot_token = jnp.full((Ep * C + 1,), T, jnp.int32).at[dest].set(
            token_of.astype(jnp.int32), mode="drop")[:Ep * C]
        xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), x.dtype)], axis=0)
        buf = xt_pad[jnp.minimum(slot_token, T)]
    else:
        buf = jnp.zeros((Ep * C, d), x.dtype).at[dest].set(xt[token_of],
                                                           mode="drop")
    buf = buf.reshape(Ep, C, d)
    if rules is not None:
        # expert parallelism over `model` AND capacity over `data`: the
        # token all-to-all moves rows from the (data-sharded tokens) layout
        # into the (E/model, C/data) buffer; both mesh axes do expert FLOPs
        buf = constrain(buf, P(rules.model(Ep), rules.data_if(C), None))

    gate = jnp.einsum("ecd,edf->ecf", buf, lp["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", buf, lp["w_up"])
    hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    out = jnp.einsum("ecf,efd->ecd", hidden, lp["w_down"])
    if rules is not None:
        out = constrain(out, P(rules.model(Ep), rules.data_if(C), None))

    out_flat = out.reshape(Ep * C, d)
    if DISPATCH_MODE == "gather":
        # per-token gather of its k expert outputs (no scatter-add): the
        # inverse of sort_idx maps (token, choice) -> sorted position
        inv_sort = jnp.argsort(sort_idx)                  # (T*k,)
        dest_tc = dest[inv_sort].reshape(T, k)            # slot per choice
        keep_tc = keep[inv_sort].reshape(T, k)
        got = out_flat[jnp.minimum(dest_tc, Ep * C - 1)]  # (T, k, d)
        got = jnp.where(keep_tc[..., None], got, 0)
        y = jnp.einsum("tkd,tk->td", got.astype(jnp.float32),
                       topw).astype(x.dtype)
        return y.reshape(B, L, d), aux
    gathered = jnp.where(keep[:, None],
                         out_flat[jnp.minimum(dest, Ep * C - 1)], 0)
    w_flat = topw.reshape(-1)[sort_idx]
    contrib = gathered * w_flat[:, None].astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[token_of].add(contrib)
    return y.reshape(B, L, d), aux


def _block(x, lp, cfg: ArchConfig, positions, rules, q_chunk=512):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q, kk, vv = qkv_project(h, lp["wq"], lp["wk"], lp["wv"], cfg, positions)
    o = attention(q, kk, vv, positions, positions, cfg, causal=True,
                  window=cfg.sliding_window, q_chunk=q_chunk)
    x = x + out_project(o, lp["wo"])
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    y, aux = moe_ffn(h, lp, cfg, rules)
    x = x + y
    if rules is not None:
        x = constrain(x, P(rules.data, None, None))
    return x, aux


def forward(params, x, cfg: ArchConfig, positions, rules=None, remat=True,
            q_chunk: int = 512):
    def body(carry, lp):
        h, aux = carry
        h, a = _block(h, lp, cfg, positions, rules, q_chunk)
        return (h, aux + a), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = mscan(body, (x, jnp.float32(0.0)), params["layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def loss_fn(params, batch, cfg: ArchConfig, rules=None, aux_weight=0.01,
            q_chunk: int = 512):
    tokens = batch["tokens"]
    x = tfm.embed_tokens(params, tokens, cfg)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    h, aux = forward(params, x, cfg, positions, rules, q_chunk=q_chunk)
    labels, lmask = tfm.shifted_labels(tokens)
    ce = tfm.chunked_ce_loss(params, h, labels, cfg, mask=lmask, rules=rules)
    return ce + aux_weight * aux / cfg.n_layers


def decode_step(params, cache, tokens, pos, cfg: ArchConfig, rules=None):
    B = tokens.shape[0]
    x = tfm.embed_tokens(params, tokens, cfg)
    S = cache.k.shape[2]
    q_pos = jnp.full((1,), pos, jnp.int32)
    k_pos = jnp.arange(S, dtype=jnp.int32)
    k_valid = k_pos <= pos

    def body(h, layer):
        lp, kc, vc = layer
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k_new, v_new = qkv_project(hn, lp["wq"], lp["wk"], lp["wv"], cfg,
                                      q_pos)
        kc = seq_update(kc, k_new, pos)
        vc = seq_update(vc, v_new, pos)
        o = attention(q, kc, vc, q_pos, k_pos, cfg, causal=True,
                      k_valid=jnp.broadcast_to(k_valid, (B, S)))
        h = h + out_project(o, lp["wo"])
        hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
        y, _ = moe_ffn(hn, lp, cfg, rules)
        h = h + y
        return h, (kc, vc)

    h, (k_all, v_all) = mscan(body, x, (params["layers"], cache.k,
                                               cache.v))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = tfm.logits_at(params, h[:, -1, :], cfg)
    return logits, tfm.KVCache(k=k_all, v=v_all)


def prefill(params, tokens, cfg: ArchConfig, cache, rules=None,
            q_chunk: int = 512):
    B, L = tokens.shape
    x = tfm.embed_tokens(params, tokens, cfg)
    positions = jnp.arange(L, dtype=jnp.int32)
    S = cache.k.shape[2]

    def body(h, layer):
        lp, kc, vc = layer
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k_new, v_new = qkv_project(hn, lp["wq"], lp["wk"], lp["wv"], cfg,
                                      positions)
        o = attention(q, k_new, v_new, positions, positions, cfg, causal=True,
                      q_chunk=q_chunk)
        h = h + out_project(o, lp["wo"])
        hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
        y, _ = moe_ffn(hn, lp, cfg, rules)
        h = h + y
        kc = jax.lax.dynamic_update_slice(
            kc, k_new[:, -S:, :, :].astype(kc.dtype), (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            vc, v_new[:, -S:, :, :].astype(vc.dtype), (0, 0, 0, 0))
        return h, (kc, vc)

    body = jax.checkpoint(body, prevent_cse=False)
    h, (k_all, v_all) = mscan(body, x, (params["layers"], cache.k,
                                               cache.v))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = tfm.logits_at(params, h[:, -1, :], cfg)
    return logits, tfm.KVCache(k=k_all, v=v_all)
