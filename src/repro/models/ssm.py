"""Mamba-2 (SSD, state-space duality) — the mamba2-130m assigned architecture.

The SSD recurrence  h_t = a_t * h_{t-1} + dt_t * B_t x_t^T,  y_t = C_t . h_t
is evaluated in the paper's chunked dual form: within a chunk of length Q the
output is an attention-like masked matmul (MXU work), across chunks a small
(H, P, N) state is carried by lax.scan.  Decode is the O(1) recurrent form.

Shapes follow the mamba2 reference: d_inner = expand * d_model, H heads of
head_dim P = d_inner / H, shared-BC groups G = 1, state N = ssm_state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import transformer as tfm
from .common import (ArchConfig, MeshRules, constrain, dense_init,
                     logical_to_spec, rms_norm, mscan)


def _dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    N = cfg.ssm_state
    G = 1                                   # mamba2 default: one BC group
    conv_dim = d_inner + 2 * G * N
    return d_inner, H, cfg.ssm_head_dim, N, G, conv_dim


def init_layer_params(cfg: ArchConfig, key) -> dict:
    d = cfg.d_model
    d_inner, H, Phd, N, G, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 6)
    dt = cfg.dtype
    return {
        "ln": jnp.zeros((d,), dt),
        "wz": dense_init(ks[0], (d, d_inner), dt),
        "wxbc": dense_init(ks[1], (d, conv_dim), dt),
        "wdt": dense_init(ks[2], (d, H), dt),
        "conv_w": dense_init(ks[3], (cfg.ssm_conv, conv_dim), dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        # A in (-exp range); init log A uniformly in [log 1, log 16] (mamba2)
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01))).astype(jnp.float32),
        "gnorm": jnp.zeros((d_inner,), dt),
        "wo": dense_init(ks[4], (d_inner, d), dt),
    }


def init_params(cfg: ArchConfig, key) -> dict:
    kE, kL = jax.random.split(key)
    return {
        "embed": tfm.embed_init(kE, (cfg.vocab, cfg.d_model), cfg.dtype),
        "layers": jax.vmap(lambda k: init_layer_params(cfg, k))(
            jax.random.split(kL, cfg.n_layers)),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
    }


def param_specs(cfg: ArchConfig, rules: MeshRules) -> dict:
    d = cfg.d_model
    d_inner, H, Phd, N, G, conv_dim = _dims(cfg)
    L = cfg.n_layers

    def spec(*ax):
        return logical_to_spec(rules, *ax)

    return {
        "embed": spec(("model", cfg.vocab), (None, d)),
        "layers": {
            "ln": P(None, None),
            "wz": spec((None, L), (None, d), ("model", d_inner)),
            "wxbc": P(None, None, None),   # conv_dim mixes x/B/C: replicate
            "wdt": spec((None, L), (None, d), ("model", H)),
            "conv_w": P(None, None, None),
            "conv_b": P(None, None),
            "A_log": spec((None, L), ("model", H)),
            "D": spec((None, L), ("model", H)),
            "dt_bias": spec((None, L), ("model", H)),
            "gnorm": spec((None, L), ("model", d_inner)),
            "wo": spec((None, L), ("model", d_inner), (None, d)),
        },
        "final_norm": P(None),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv1d. x: (B, L, C); w: (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for k in range(K):      # K = 4: unrolled taps beat a conv_general here
        out = out + xp[:, k:k + x.shape[1], :] * w[k][None, None, :]
    return out + b[None, None, :]


def _ssd_chunked(xh, dtv, Bm, Cm, A_log, Q: int):
    """Chunked SSD scan.

    xh: (B, L, H, P) inputs; dtv: (B, L, H) discretization (post-softplus);
    Bm/Cm: (B, L, G, N); A_log: (H,).  Returns y: (B, L, H, P) in f32.
    """
    Bsz, L, H, Phd = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert L % Q == 0
    nc = L // Q
    hpg = H // G

    xf = xh.astype(jnp.float32).reshape(Bsz, nc, Q, H, Phd)
    dtf = dtv.astype(jnp.float32).reshape(Bsz, nc, Q, H)
    Bf = Bm.astype(jnp.float32).reshape(Bsz, nc, Q, G, N)
    Cf = Cm.astype(jnp.float32).reshape(Bsz, nc, Q, G, N)
    neg_A = -jnp.exp(A_log.astype(jnp.float32))                 # (H,)

    def chunk(state, inp):
        x_c, dt_c, B_c, C_c = inp            # (B,Q,H,P) (B,Q,H) (B,Q,G,N) x2
        la = dt_c * neg_A[None, None, :]     # log a_t  (B,Q,H)
        cum = jnp.cumsum(la, axis=1)         # (B,Q,H)
        # intra-chunk: decay matrix L[i,j] = exp(cum_i - cum_j), j <= i
        diff = cum[:, :, None, :] - cum[:, None, :, :]          # (B,Q,Q,H)
        iota = jnp.arange(Q)
        causal = (iota[:, None] >= iota[None, :])[None, :, :, None]
        decay = jnp.where(causal, jnp.exp(diff), 0.0)           # (B,Q,Q,H)
        CB = jnp.einsum("bign,bjgn->bijg", C_c, B_c)            # (B,Q,Q,G)
        CB = jnp.repeat(CB, hpg, axis=-1)                       # (B,Q,Q,H)
        att = decay * CB * dt_c[:, None, :, :]                  # (B,Q,Q,H)
        y = jnp.einsum("bijh,bjhp->bihp", att, x_c)
        # inter-chunk: contribution of the carried state (C_c broadcasts over
        # the hpg heads of its group; G == 1 in all assigned configs)
        Ch = jnp.repeat(C_c, hpg, axis=2)                       # (B,Q,H,N)
        y = y + jnp.exp(cum)[..., None] * jnp.einsum(
            "bihn,bhpn->bihp", Ch, state)
        # state update: S <- exp(cum_Q) S + sum_j exp(cum_Q - cum_j) dt_j B_j x_j^T
        tail = jnp.exp(cum[:, -1:, :] - cum) * dt_c             # (B,Q,H)
        Bh = jnp.repeat(B_c, hpg, axis=2)                       # (B,Q,H,N)
        new_state = (jnp.exp(cum[:, -1, :])[..., None, None] * state
                     + jnp.einsum("bjh,bjhn,bjhp->bhpn", tail, Bh, x_c))
        return new_state, y

    state0 = jnp.zeros((Bsz, H, Phd, N), jnp.float32)
    _, ys = mscan(chunk, state0,
                         (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
                          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).reshape(Bsz, L, H, Phd)


def _mix(x, lp, cfg: ArchConfig, rules: MeshRules | None):
    """One mamba2 mixing block (pre-norm residual applied by caller)."""
    B, L, d = x.shape
    d_inner, H, Phd, N, G, conv_dim = _dims(cfg)
    z = jnp.einsum("bld,di->bli", x, lp["wz"])
    xbc = jnp.einsum("bld,dc->blc", x, lp["wxbc"])
    dt_raw = jnp.einsum("bld,dh->blh", x, lp["wdt"])
    xbc = _causal_conv(xbc, lp["conv_w"], lp["conv_b"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xs = xbc[..., :d_inner].reshape(B, L, H, Phd)
    Bm = xbc[..., d_inner:d_inner + G * N].reshape(B, L, G, N)
    Cm = xbc[..., d_inner + G * N:].reshape(B, L, G, N)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32)
                          + lp["dt_bias"][None, None, :])
    if rules is not None:
        xs = constrain(xs, P(rules.data, None, rules.model(H), None))
    y = _ssd_chunked(xs, dtv, Bm, Cm, lp["A_log"], cfg.ssm_chunk)
    y = y + lp["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, L, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, lp["gnorm"], cfg.norm_eps)
    return jnp.einsum("bli,id->bld", y, lp["wo"])


def forward(params, x, cfg: ArchConfig, rules=None, remat: bool = True):
    def body(h, lp):
        hn = rms_norm(h, lp["ln"], cfg.norm_eps)
        h = h + _mix(hn, lp, cfg, rules)
        if rules is not None:
            h = constrain(h, P(rules.data, None, None))
        return h, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = mscan(body, x, params["layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def loss_fn(params, batch, cfg: ArchConfig, rules=None, q_chunk: int = 512):
    tokens = batch["tokens"]
    x = tfm.embed_tokens(params, tokens, cfg)
    h = forward(params, x, cfg, rules)
    labels, lmask = tfm.shifted_labels(tokens)
    if "mask" in batch:
        lmask = lmask & batch["mask"]
    return tfm.chunked_ce_loss(params, h, labels, cfg, mask=lmask,
                               rules=rules)


# ---------------------------------------------------------------- serving
class SSMCache(dict):
    """Pytree: {'conv': (Lyr,B,K-1,conv_dim), 'state': (Lyr,B,H,P,N)}."""


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    d_inner, H, Phd, N, G, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_dim),
                          cfg.dtype),
        "state": jnp.zeros((cfg.n_layers, batch, H, Phd, N), jnp.float32),
    }


def cache_specs(cfg: ArchConfig, rules: MeshRules):
    d_inner, H, Phd, N, G, conv_dim = _dims(cfg)
    return {
        "conv": logical_to_spec(rules, (None, cfg.n_layers), ("data", 0),
                                (None, 0), (None, 0)),
        "state": logical_to_spec(rules, (None, cfg.n_layers), ("data", 0),
                                 ("model", H), (None, 0), (None, 0)),
    }


def _mix_step(x1, conv_st, state, lp, cfg: ArchConfig):
    """One-token recurrent step. x1: (B, d). Returns (y1, conv_st, state)."""
    B, d = x1.shape
    d_inner, H, Phd, N, G, conv_dim = _dims(cfg)
    z = x1 @ lp["wz"]
    xbc = x1 @ lp["wxbc"]                                       # (B, conv_dim)
    dt_raw = x1 @ lp["wdt"]
    window = jnp.concatenate([conv_st, xbc[:, None, :]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window, lp["conv_w"]) + lp["conv_b"]
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x1.dtype)
    xs = conv_out[:, :d_inner].reshape(B, H, Phd).astype(jnp.float32)
    Bm = conv_out[:, d_inner:d_inner + G * N].reshape(B, G, N).astype(jnp.float32)
    Cm = conv_out[:, d_inner + G * N:].reshape(B, G, N).astype(jnp.float32)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"][None, :])
    a = jnp.exp(-jnp.exp(lp["A_log"].astype(jnp.float32))[None, :] * dtv)
    hpg = H // G
    Bh = jnp.repeat(Bm, hpg, axis=1)                            # (B,H,N)
    Ch = jnp.repeat(Cm, hpg, axis=1)
    state = a[..., None, None] * state + (dtv[..., None, None]
                                          * Bh[:, :, None, :] * xs[..., None])
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    y = y + lp["D"][None, :, None] * xs
    y = y.reshape(B, d_inner).astype(x1.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x1.dtype)
    y = rms_norm(y, lp["gnorm"], cfg.norm_eps)
    return y @ lp["wo"], window[:, 1:, :], state


def decode_step(params, cache, tokens, pos, cfg: ArchConfig, rules=None):
    """tokens: (B, 1).  pos is unused (state is position-free)."""
    x = tfm.embed_tokens(params, tokens, cfg)[:, 0, :]          # (B, d)

    def body(h, layer):
        lp, conv_st, state = layer
        hn = rms_norm(h, lp["ln"], cfg.norm_eps)
        y, conv_st, state = _mix_step(hn, conv_st, state, lp, cfg)
        return h + y, (conv_st, state)

    h, (conv_all, state_all) = mscan(
        body, x, (params["layers"], cache["conv"], cache["state"]))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = tfm.logits_at(params, h[:, None, :], cfg)[:, 0, :]
    return logits, {"conv": conv_all, "state": state_all}


def prefill(params, tokens, cfg: ArchConfig, cache, rules=None,
            q_chunk: int = 512):
    """Prompt pass via the chunked-SSD path; final state written to cache."""
    B, L = tokens.shape
    x = tfm.embed_tokens(params, tokens, cfg)
    d_inner, H, Phd, N, G, conv_dim = _dims(cfg)

    def body(carry, layer):
        h = carry
        lp, conv0, st0 = layer
        hn = rms_norm(h, lp["ln"], cfg.norm_eps)
        # run the train-path mix but also emit the trailing conv/ssm state
        z = jnp.einsum("bld,di->bli", hn, lp["wz"])
        xbc = jnp.einsum("bld,dc->blc", hn, lp["wxbc"])
        dt_raw = jnp.einsum("bld,dh->blh", hn, lp["wdt"])
        conv_tail = xbc[:, -(cfg.ssm_conv - 1):, :].astype(conv0.dtype)
        xbc = _causal_conv(xbc, lp["conv_w"], lp["conv_b"])
        xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(h.dtype)
        xs = xbc[..., :d_inner].reshape(B, L, H, Phd)
        Bm = xbc[..., d_inner:d_inner + G * N].reshape(B, L, G, N)
        Cm = xbc[..., d_inner + G * N:].reshape(B, L, G, N)
        dtv = jax.nn.softplus(dt_raw.astype(jnp.float32)
                              + lp["dt_bias"][None, None, :])
        y = _ssd_chunked(xs, dtv, Bm, Cm, lp["A_log"], cfg.ssm_chunk)
        # recompute the final state with a one-chunk pass over the tail
        # (cheap: state is the fixed point of the last chunk's recursion);
        # exact: rerun the scan keeping only the carry.
        la = dtv * (-jnp.exp(lp["A_log"].astype(jnp.float32)))[None, None, :]
        cum = jnp.cumsum(la, axis=1)
        tailw = jnp.exp(cum[:, -1:, :] - cum) * dtv
        Bh = jnp.repeat(Bm.astype(jnp.float32), H // G, axis=2)
        st = jnp.einsum("bjh,bjhn,bjhp->bhpn", tailw, Bh,
                        xs.astype(jnp.float32))
        y = y + lp["D"][None, None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(B, L, d_inner).astype(h.dtype)
        y = y * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype)
        y = rms_norm(y, lp["gnorm"], cfg.norm_eps)
        h = h + jnp.einsum("bli,id->bld", y, lp["wo"])
        return h, (conv_tail, st)

    body = jax.checkpoint(body, prevent_cse=False)
    h, (conv_all, state_all) = mscan(
        body, x, (params["layers"], cache["conv"], cache["state"]))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = tfm.logits_at(params, h[:, -1, :], cfg)
    return logits, {"conv": conv_all, "state": state_all}
