"""Dense transformer family: gemma-2b, granite-8b, phi3-mini, h2o-danube
(causal LMs), hubert-xlarge (bidirectional encoder), paligemma-3b (prefix-LM
VLM backbone).  One implementation, configured by ArchConfig.

Layers are stacked on a leading axis and executed with lax.scan (+ remat),
which keeps the HLO size O(1) in depth — required for 48-layer dry-run
compiles — and matches how production JAX LMs are written.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as attn_mod
from .attention import KVCache, attention, out_project, qkv_project
from .common import (ArchConfig, MeshRules, constrain,
                     dense_init, embed_init, glu_ffn, logical_to_spec,
                     rms_norm, softcap, mscan)


# ------------------------------------------------------------------- params
def init_layer_params(cfg: ArchConfig, key) -> dict:
    ks = jax.random.split(key, 6)
    d, H, K, hd, ff = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                       cfg.head_dim, cfg.d_ff)
    dt = cfg.dtype
    return {
        "ln1": jnp.zeros((d,), dt),
        "wq": dense_init(ks[0], (d, H, hd), dt),
        "wk": dense_init(ks[1], (d, K, hd), dt),
        "wv": dense_init(ks[2], (d, K, hd), dt),
        "wo": dense_init(ks[3], (H, hd, d), dt, in_axis=0),
        "ln2": jnp.zeros((d,), dt),
        "w_in": dense_init(ks[4], (d, 2, ff), dt),
        "w_out": dense_init(ks[5], (ff, d), dt),
    }


def init_params(cfg: ArchConfig, key) -> dict:
    kE, kL, kU, kF = jax.random.split(key, 4)
    params = {
        "embed": embed_init(kE, (cfg.vocab, cfg.d_model), cfg.dtype),
        "layers": jax.vmap(lambda k: init_layer_params(cfg, k))(
            jax.random.split(kL, cfg.n_layers)),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(kU, (cfg.d_model, cfg.vocab), cfg.dtype)
    if cfg.frontend_dim:
        params["frontend"] = dense_init(kF, (cfg.frontend_dim, cfg.d_model),
                                        cfg.dtype)
    return params


def layer_specs(cfg: ArchConfig, rules: MeshRules) -> dict:
    d, H, K, hd, ff = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                       cfg.head_dim, cfg.d_ff)
    L = ("layers",)  # leading scan axis is never sharded

    def spec(*ax):
        return logical_to_spec(rules, *ax)

    return {
        "ln1": P(None, None),
        "wq": spec((None, cfg.n_layers), (None, d), ("model", H), (None, hd)),
        "wk": spec((None, cfg.n_layers), (None, d), ("model", K), (None, hd)),
        "wv": spec((None, cfg.n_layers), (None, d), ("model", K), (None, hd)),
        "wo": spec((None, cfg.n_layers), ("model", H), (None, hd), (None, d)),
        "ln2": P(None, None),
        "w_in": spec((None, cfg.n_layers), (None, d), (None, 2), ("model", ff)),
        "w_out": spec((None, cfg.n_layers), ("model", ff), (None, d)),
    }


def param_specs(cfg: ArchConfig, rules: MeshRules) -> dict:
    specs = {
        "embed": logical_to_spec(rules, ("model", cfg.vocab),
                                 (None, cfg.d_model)),
        "layers": layer_specs(cfg, rules),
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = logical_to_spec(rules, (None, cfg.d_model),
                                           ("model", cfg.vocab))
    if cfg.frontend_dim:
        specs["frontend"] = P(None, None)
    return specs


# ------------------------------------------------------------------ forward
def _block(x, lp, cfg: ArchConfig, positions, rules: MeshRules | None,
           prefix_len=None, q_chunk: int = 512):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = qkv_project(h, lp["wq"], lp["wk"], lp["wv"], cfg, positions)
    if rules is not None:
        q = constrain(q, P(rules.data, None, rules.model(cfg.n_heads), None))
    o = attention(q, k, v, positions, positions, cfg, causal=cfg.is_causal,
                  window=cfg.sliding_window, prefix_len=prefix_len,
                  q_chunk=q_chunk)
    x = x + out_project(o, lp["wo"])
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + glu_ffn(h, lp["w_in"], lp["w_out"], cfg.activation)
    if rules is not None:
        x = constrain(x, P(rules.data, None, None))
    return x


def forward(params, x, cfg: ArchConfig, positions, rules=None,
            prefix_len=None, remat: bool = True, q_chunk: int = 512):
    """x: (B, L, d) embedded input -> final hidden states (B, L, d)."""

    def body(h, lp):
        return _block(h, lp, cfg, positions, rules, prefix_len, q_chunk), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = mscan(body, x, params["layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def embed_tokens(params, tokens, cfg: ArchConfig):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
    return x


def _unembed_matrix(params, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return params["embed"].T          # (d, V)
    return params["unembed"]


def logits_at(params, h, cfg: ArchConfig):
    w = _unembed_matrix(params, cfg)
    logits = jnp.einsum("...d,dv->...v", h, w)
    return softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)


def shifted_labels(tokens):
    """Next-token labels at full length: position L-1 is masked out (no
    target), so callers never slice the hidden states to L-1."""
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.concatenate(
        [jnp.ones_like(tokens[:, 1:], bool),
         jnp.zeros_like(tokens[:, :1], bool)], axis=1)
    return labels, mask


def chunked_ce_loss(params, h, labels, cfg: ArchConfig, mask=None,
                    rules: MeshRules | None = None, chunk: int = 512):
    """Cross-entropy with logits materialized one sequence-chunk at a time.

    Full (B, L, V) f32 logits would dominate HBM (B=16, L=4k, V=256k is
    17 GB/device); chunking bounds it at (B, chunk, V/model_parallel).
    Sequences that do not divide ``chunk`` are padded with masked positions.
    """
    B, L, d = h.shape
    chunk = min(chunk, L)
    if L % chunk:
        pad = chunk - L % chunk
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask if mask is not None
                       else jnp.ones((B, L), bool), ((0, 0), (0, pad)))
        L = L + pad
    nc = L // chunk
    hc = h.reshape(B, nc, chunk, d)
    lc = labels.reshape(B, nc, chunk)
    mc = (mask.reshape(B, nc, chunk) if mask is not None
          else jnp.ones((B, nc, chunk), bool))

    def body(acc, inp):
        h_i, l_i, m_i = inp
        logits = logits_at(params, h_i, cfg)
        if rules is not None:
            logits = constrain(logits, P(rules.data, None,
                                         rules.model(cfg.vocab)))
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_i[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * m_i.astype(jnp.float32)
        return (acc[0] + jnp.sum(nll), acc[1] + jnp.sum(m_i)), None

    (tot, cnt), _ = mscan(
        body, (jnp.float32(0.0), jnp.float32(0.0)),
        (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(lc, 1, 0), jnp.moveaxis(mc, 1, 0)))
    return tot / jnp.maximum(cnt, 1.0)


# ----------------------------------------------------------------- training
def loss_fn(params, batch, cfg: ArchConfig, rules=None, q_chunk: int = 512):
    """Causal-LM loss; encoder (hubert) and VLM variants handled by family."""
    if cfg.family == "encoder":
        feats = batch["features"].astype(cfg.dtype)     # (B, L, frontend_dim)
        x = jnp.einsum("blf,fd->bld", feats, params["frontend"])
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        h = forward(params, x, cfg, positions, rules, q_chunk=q_chunk)
        return chunked_ce_loss(params, h, batch["labels"], cfg,
                               mask=batch.get("mask"), rules=rules)
    if cfg.family == "vlm":
        patches = batch["patches"].astype(cfg.dtype)    # (B, Np, frontend_dim)
        img = jnp.einsum("bpf,fd->bpd", patches, params["frontend"])
        tok = embed_tokens(params, batch["tokens"], cfg)
        x = jnp.concatenate([img, tok], axis=1)
        L = x.shape[1]
        positions = jnp.arange(L, dtype=jnp.int32)
        h = forward(params, x, cfg, positions, rules,
                    prefix_len=cfg.num_patches, q_chunk=q_chunk)
        h_txt = h[:, cfg.num_patches:, :]
        # next-token prediction over the text suffix
        labels, lmask = shifted_labels(batch["tokens"])
        return chunked_ce_loss(params, h_txt, labels, cfg, mask=lmask,
                               rules=rules)
    tokens = batch["tokens"]
    x = embed_tokens(params, tokens, cfg)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    h = forward(params, x, cfg, positions, rules, q_chunk=q_chunk)
    labels, lmask = shifted_labels(tokens)
    if "mask" in batch:
        lmask = lmask & batch["mask"]
    return chunked_ce_loss(params, h, labels, cfg, mask=lmask, rules=rules)


# ---------------------------------------------------------------- serving
def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> KVCache:
    S = max_len if cfg.sliding_window is None else min(max_len,
                                                       cfg.sliding_window)
    shape = (cfg.n_layers, batch, S, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, cfg.dtype), v=jnp.zeros(shape, cfg.dtype))


def cache_specs(cfg: ArchConfig, rules: MeshRules) -> KVCache:
    s = logical_to_spec(rules, (None, cfg.n_layers), ("data", 0),
                        (None, 0), ("model", cfg.n_kv_heads), (None, 0))
    return KVCache(k=s, v=s)


def decode_step(params, cache: KVCache, tokens, pos, cfg: ArchConfig,
                rules=None):
    """One decode step: tokens (B, 1) at absolute position ``pos``.

    With a sliding window the cache is a ring buffer of size window and the
    write slot is pos % window.
    """
    B = tokens.shape[0]
    x = embed_tokens(params, tokens, cfg)
    S = cache.k.shape[2]
    slot = pos if cfg.sliding_window is None else pos % S
    q_pos = jnp.full((1,), pos, jnp.int32)
    if cfg.sliding_window is None:
        k_pos = jnp.arange(S, dtype=jnp.int32)
    else:
        # ring buffer: absolute position of slot s given write head at `slot`
        idx = jnp.arange(S, dtype=jnp.int32)
        k_pos = jnp.where(idx <= slot, pos - slot + idx, pos - slot - S + idx)
    k_valid = (k_pos >= 0) & (k_pos <= pos)

    def body(h, layer):
        lp, kc, vc = layer
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k_new, v_new = qkv_project(hn, lp["wq"], lp["wk"], lp["wv"], cfg,
                                      q_pos)
        kc = attn_mod.seq_update(kc, k_new, slot)
        vc = attn_mod.seq_update(vc, v_new, slot)
        o = attention(q, kc, vc, q_pos, k_pos, cfg, causal=True,
                      window=cfg.sliding_window,
                      k_valid=jnp.broadcast_to(k_valid, (B, S)))
        h = h + out_project(o, lp["wo"])
        hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
        h = h + glu_ffn(hn, lp["w_in"], lp["w_out"], cfg.activation)
        if rules is not None:
            h = constrain(h, P(rules.data, None, None))
        return h, (kc, vc)

    h, (k_all, v_all) = mscan(body, x, (params["layers"], cache.k,
                                               cache.v))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_at(params, h[:, -1, :], cfg)
    return logits, KVCache(k=k_all, v=v_all)


def prefill_embedded(params, x, cfg: ArchConfig, cache: KVCache, rules=None,
                     prefix_len=None, q_chunk: int = 512):
    """Prompt pass over pre-embedded inputs x (B, L, d): returns
    last-position logits + the filled cache.

    Full-sequence logits are never materialized (a 32k x 256k logit tensor
    would be ~34 GB/device) — serving only needs the last position.
    """
    B, L = x.shape[:2]
    positions = jnp.arange(L, dtype=jnp.int32)
    S = cache.k.shape[2]

    def body(h, layer):
        lp, kc, vc = layer
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k_new, v_new = qkv_project(hn, lp["wq"], lp["wk"], lp["wv"], cfg,
                                      positions)
        o = attention(q, k_new, v_new, positions, positions, cfg, causal=True,
                      window=cfg.sliding_window, prefix_len=prefix_len,
                      q_chunk=q_chunk)
        h = h + out_project(o, lp["wo"])
        hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
        h = h + glu_ffn(hn, lp["w_in"], lp["w_out"], cfg.activation)
        if rules is not None:
            h = constrain(h, P(rules.data, None, None))
        kc = jax.lax.dynamic_update_slice(
            kc, k_new[:, -S:, :, :].astype(kc.dtype), (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            vc, v_new[:, -S:, :, :].astype(vc.dtype), (0, 0, 0, 0))
        return h, (kc, vc)

    body = jax.checkpoint(body, prevent_cse=False)
    h, (k_all, v_all) = mscan(body, x, (params["layers"], cache.k,
                                               cache.v))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_at(params, h[:, -1, :], cfg)
    return logits, KVCache(k=k_all, v=v_all)


def prefill(params, tokens, cfg: ArchConfig, cache: KVCache, rules=None,
            q_chunk: int = 512):
    """Token-prompt prefill (dense LMs)."""
    x = embed_tokens(params, tokens, cfg)
    return prefill_embedded(params, x, cfg, cache, rules=rules,
                            q_chunk=q_chunk)


def vlm_prefill(params, batch, cfg: ArchConfig, cache: KVCache, rules=None,
                q_chunk: int = 512):
    """VLM prompt pass: image patches (stub frontend) + text tokens."""
    patches = batch["patches"].astype(cfg.dtype)
    img = jnp.einsum("bpf,fd->bpd", patches, params["frontend"])
    tok = embed_tokens(params, batch["tokens"], cfg)
    x = jnp.concatenate([img, tok], axis=1)
    return prefill_embedded(params, x, cfg, cache, rules=rules,
                            prefix_len=cfg.num_patches, q_chunk=q_chunk)


def encode_step(params, batch, cfg: ArchConfig, rules=None,
                q_chunk: int = 512):
    """Encoder serving (hubert): frame features -> per-frame unit logits."""
    feats = batch["features"].astype(cfg.dtype)
    x = jnp.einsum("blf,fd->bld", feats, params["frontend"])
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    h = forward(params, x, cfg, positions, rules, q_chunk=q_chunk)
    return logits_at(params, h, cfg)
