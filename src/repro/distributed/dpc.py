"""Multi-chip DPC: the paper's multicore parallelization as shard_map SPMD.

Mapping (DESIGN.md §2/§4):

* OpenMP ``schedule(dynamic)``  ->  *space-sorted equal-count partitioning*:
  points are globally sorted by grid cell id (the build_grid sort), then
  split into equal contiguous chunks over the ``data`` mesh axis.  Sorting
  groups dense cells together, so equal point counts imply similar candidate
  volumes — the paper's cost model (cost ∝ |P(c)|) baked into the layout.
* Shared-memory reads of P  ->  an explicit ``all_gather`` of the sorted
  point table (baseline) or a ring of ``ppermute`` block exchanges
  (optimized; see benchmarks/roofline notes).  DPC datasets are O(1e6-1e7)
  rows of 2-8 f32s, so a replicated table is ~100 MB — the standard
  time/space trade at pod scale.
* Ex-DPC's sequential kd-tree delta  ->  the stencil + masked-NN fallback
  (exact; parallel over rows), as in core/exdpc.py.
* Label propagation (DFS)  ->  pointer jumping: replicated parents
  (core/labels.py) for batch callers, or the sharded one-hot-matmul
  formulation (stream/sharded.py) when a mesh is in play.

Phases (each a shard_map over the ``data`` axis; fixed shapes throughout):

1. rho:    my rows x gathered table, grid-stencil range count.
2. delta:  my rows x gathered table, stencil NN among denser rows
           (resolves the paper's alpha fraction exactly).
3. fallback: stencil-unresolved rows (padded to a static cap) x gathered
           table, dense masked NN — the (1-alpha) remainder.

Everything is exact: output equals core.run_exdpc / run_scan (tested).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import obs
from repro.analysis.audit import audit_check_rep
from repro.core.dpc_types import DPCResult, with_jitter
from repro.core.grid import build_grid, point_span_bounds
from repro.engine.planner import as_plan
from repro.engine.spec import ExecSpec, merge_legacy
from repro.launch.mesh import flatten_mesh

_STRATEGIES = ("gather", "halo")


@dataclass(frozen=True)
class DistDPCConfig:
    """Distributed-phase parameters.

    Execution (backend / layout / precision / block / mesh axis) is one
    :class:`repro.engine.ExecSpec` on ``exec_spec``; the ``backend`` /
    ``layout`` / ``block`` / ``data_axis`` fields are the legacy spellings
    and fold into it with a ``DeprecationWarning`` (see ``repro.engine``).

    Execution-axis semantics here:

    * backend — per-shard kernel backend.  With a pallas backend +
      'gather', the rho/delta phases run the dense MXU kernels per shard
      (my rows x gathered table) and the delta phase is already globally
      exact, so the fallback phase is skipped.  With 'halo', both phases
      run the backend's span-masked halo primitives.
    * layout 'block-sparse' — grid-pruned worklists for the per-shard
      gather-strategy phases: each shard owns a contiguous chunk of the
      space-sorted table, so its row tiles have compact AABBs against the
      gathered table and most tile pairs prune away.  Requires a backend
      whose worklists are jit-built (``worklist_traceable`` — the jnp
      backend): pallas worklists are host-built and cannot be constructed
      inside shard_map, so pallas shards keep the dense MXU tiles.
      Honored on any mesh that passes the R1 probe
      (:func:`shard_blocksparse_layout`) — with the one-hot ring walk no
      sort-derived index reaches a gather inside the shard body, so
      multi-partition meshes run block-sparse shard phases too.  (Before
      the one-hot rewrite the order-gather walk tripped the pinned
      jax-0.4.37 XLA CPU SPMD miscompile and multi-device meshes degraded
      to dense per-shard tiles.)
    """

    d_cut: float
    fallback_cap_factor: float = 0.05   # static cap: fraction of n (padded)
    # 'gather': replicate the sorted table per shard (baseline; traffic =
    #   n*d per device).  'halo': ring-ppermute only the blocks that
    #   intersect each shard's stencil window (traffic = (W+m)*d — the
    #   space-sorted layout makes candidate windows narrow; §Perf).
    strategy: str = "gather"
    exec_spec: ExecSpec | None = None
    block: int | None = None            # deprecated -> ExecSpec.block
    data_axis: str = "data"             # deprecated -> ExecSpec.data_axis
    backend: str | None = None          # deprecated -> ExecSpec.backend
    layout: str | None = None           # deprecated -> ExecSpec.layout

    def __post_init__(self):
        if not self.d_cut > 0.0:
            raise ValueError(f"d_cut must be positive, got {self.d_cut!r}")
        if self.strategy not in _STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; "
                             f"expected one of {_STRATEGIES}")
        ex = merge_legacy(self.exec_spec, owner="DistDPCConfig",
                          backend=self.backend, layout=self.layout,
                          block=self.block, data_axis=self.data_axis)
        object.__setattr__(self, "exec_spec", ex)

    def resolved_exec(self) -> ExecSpec:
        return self.exec_spec


def _pad_rows(x, m, value):
    pad = [(0, m - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad, constant_values=value)


def _blocked(n, block):
    return -(-n // block)


def _halo_window(tbl_my, lo_my, axis, n_shards: int, W: int,
                 hops_fwd: int, hops_bwd: int):
    """Assemble each shard's candidate window [lo, lo+W) via ppermute rings.

    tbl_my: (m, ...) my block of the sorted table; lo_my: (1,) my window
    start.  Two chains: pass-left delivers blocks AFTER mine (hop h sees
    block s+h), pass-right delivers blocks BEFORE mine (hop h sees s-h);
    rows whose global index falls inside my window are copied in.  Traffic
    per shard = (hops_fwd + hops_bwd) * m * rowbytes, vs n * rowbytes for
    the all-gather baseline — the space-sorted layout keeps windows narrow.
    """
    m = tbl_my.shape[0]
    my_id = jax.lax.axis_index(axis)
    lo = lo_my[0]
    wrow = lo + jnp.arange(W)                        # global row of window w
    wblock = wrow // m                               # owning block
    wpos = wrow % m
    left = [(i, (i - 1) % n_shards) for i in range(n_shards)]
    right = [(i, (i + 1) % n_shards) for i in range(n_shards)]

    def take_into(window, visiting, vid):
        take = wblock == vid
        rows = visiting[jnp.minimum(wpos, m - 1)]
        return jnp.where(take.reshape((W,) + (1,) * (tbl_my.ndim - 1)),
                         rows, window)

    window = jnp.zeros((W,) + tbl_my.shape[1:], tbl_my.dtype)
    visiting = tbl_my
    for h in range(hops_fwd + 1):                    # h=0: my own block
        window = take_into(window, visiting, (my_id + h) % n_shards)
        if h < hops_fwd:
            visiting = jax.lax.ppermute(visiting, axis, left)
    visiting = tbl_my
    for h in range(1, hops_bwd + 1):
        visiting = jax.lax.ppermute(visiting, axis, right)
        window = take_into(window, visiting, (my_id - h) % n_shards)
    return window


def _make_rho_halo(axis, d_cut, block, span_w, n_shards, W, hf, hb, be):
    @audit_check_rep(
        "window rows arrive via the ppermute ring and axis_index-gated "
        "selects; every output row is P(axis)-local (my rows' counts), "
        "nothing is claimed replicated",
        collectives=("ppermute", "axis_index"))
    def rho(my_pts, my_starts, my_ends, tbl_my, lo_my):
        """Halo rho phase: ring-assemble the window, then the backend's
        span-masked range-count primitive (pallas tiles when the backend is
        dense — the optimized distributed path exercises the Mosaic kernels,
        not the jnp reference)."""
        window = _halo_window(tbl_my, lo_my, axis, n_shards, W, hf, hb)
        lo = lo_my[0]
        return be.range_count_halo(my_pts, window, my_starts - lo,
                                   my_ends - lo, d_cut, span_cap=span_w,
                                   block=block)

    return rho


def _make_delta_halo(axis, d_cut, block, span_w, n_shards, W, hf, hb, be):
    @audit_check_rep(
        "same ppermute-ring window assembly as the rho phase; outputs "
        "(delta, parent, found) are all P(axis)-local per-row results",
        collectives=("ppermute", "axis_index"))
    def delta(my_pts, my_rk, my_starts, my_ends, tbl_my, rk_my, lo_my):
        """Halo delta phase: strictly-denser NN within d_cut over the halo
        window, through the backend's span-masked NN primitive."""
        both = jnp.concatenate([tbl_my, rk_my[:, None]], axis=1)
        wboth = _halo_window(both, lo_my, axis, n_shards, W, hf, hb)
        window, wrk = wboth[:, :-1], wboth[:, -1]
        lo = lo_my[0]
        dd, pp, ok = be.denser_nn_halo(my_pts, my_rk, window, wrk,
                                       my_starts - lo, my_ends - lo, d_cut,
                                       span_cap=span_w, block=block)
        # local window idx -> global sorted slot
        pp = jnp.where(ok, (pp + lo).astype(jnp.int32), -1)
        return dd, pp, ok

    return delta


def _make_rho(axis, d_cut, block, span_w):
    d2cut = jnp.float32(d_cut) ** 2

    def rho(my_pts, my_starts, my_ends, tbl_my):
        tbl = jax.lax.all_gather(tbl_my, axis, axis=0, tiled=True)
        n = tbl.shape[0]
        m = my_pts.shape[0]
        nb = _blocked(m, block)
        mp = nb * block
        pts_p = _pad_rows(my_pts, mp, 0.0)
        st_p = _pad_rows(my_starts, mp, 0)
        en_p = _pad_rows(my_ends, mp, 0)

        def chunk(i0):
            rows = jax.lax.dynamic_slice_in_dim(pts_p, i0, block, 0)
            st = jax.lax.dynamic_slice_in_dim(st_p, i0, block, 0)
            en = jax.lax.dynamic_slice_in_dim(en_p, i0, block, 0)
            idx = st[..., None] + jnp.arange(span_w, dtype=st.dtype)
            valid = idx < en[..., None]
            cand = tbl[jnp.minimum(idx, n - 1)]
            d2 = jnp.sum((rows[:, None, None, :] - cand) ** 2, axis=-1)
            return jnp.sum((d2 < d2cut) & valid, axis=(1, 2))

        cnt = jax.lax.map(chunk, jnp.arange(nb) * block).reshape(-1)[:m]
        return cnt.astype(jnp.float32)

    return rho


def _make_delta(axis, d_cut, block, span_w):
    d2cut = jnp.float32(d_cut) ** 2

    def delta(my_pts, my_rk, my_starts, my_ends, tbl_my, rk_my):
        tbl = jax.lax.all_gather(tbl_my, axis, axis=0, tiled=True)
        rk_all = jax.lax.all_gather(rk_my, axis, axis=0, tiled=True)
        n = tbl.shape[0]
        m = my_pts.shape[0]
        nb = _blocked(m, block)
        mp = nb * block
        pts_p = _pad_rows(my_pts, mp, 0.0)
        rk_p = _pad_rows(my_rk, mp, jnp.inf)
        st_p = _pad_rows(my_starts, mp, 0)
        en_p = _pad_rows(my_ends, mp, 0)

        def chunk(i0):
            rows = jax.lax.dynamic_slice_in_dim(pts_p, i0, block, 0)
            rk = jax.lax.dynamic_slice_in_dim(rk_p, i0, block, 0)
            st = jax.lax.dynamic_slice_in_dim(st_p, i0, block, 0)
            en = jax.lax.dynamic_slice_in_dim(en_p, i0, block, 0)
            idx = st[..., None] + jnp.arange(span_w, dtype=st.dtype)
            valid = idx < en[..., None]
            idx_c = jnp.minimum(idx, n - 1)
            cand = tbl[idx_c]
            cand_rk = rk_all[idx_c]
            d2 = jnp.sum((rows[:, None, None, :] - cand) ** 2, axis=-1)
            mask = valid & (cand_rk > rk[:, None, None]) & (d2 < d2cut)
            d2m = jnp.where(mask, d2, jnp.inf).reshape(block, -1)
            j = jnp.argmin(d2m, axis=1)
            best = d2m[jnp.arange(block), j]
            pidx = idx_c.reshape(block, -1)[jnp.arange(block), j]
            ok = jnp.isfinite(best)
            return (jnp.sqrt(best),
                    jnp.where(ok, pidx, -1).astype(jnp.int32), ok)

        dd, pp, ff = jax.lax.map(chunk, jnp.arange(nb) * block)
        return (dd.reshape(-1)[:m], pp.reshape(-1)[:m], ff.reshape(-1)[:m])

    return delta


def _make_fallback(axis, block, be, layout=None):
    @audit_check_rep(
        "the table and its keys are made identical on every member by "
        "all_gather(tiled) before use; outputs are P(axis)-local query "
        "rows", collectives=("all_gather",))
    def fallback(q_pts, q_rk, tbl_my, rk_my):
        """Dense denser-NN for unresolved rows (padded, rk=+inf rows inert):
        the backend's Def.-2 primitive over my queries x gathered table."""
        tbl = jax.lax.all_gather(tbl_my, axis, axis=0, tiled=True)
        rk_all = jax.lax.all_gather(rk_my, axis, axis=0, tiled=True)
        return be.denser_nn(q_pts, q_rk, tbl, rk_all, block=block,
                            layout=layout)

    return fallback


def _make_rho_dense(axis, d_cut, block, be, layout=None):
    @audit_check_rep(
        "the gathered table is replicated by all_gather(tiled); the range "
        "count reads it and writes P(axis)-local per-row counts only",
        collectives=("all_gather",))
    def rho(my_pts, tbl_my):
        """Engine tiles: my rows x gathered table (kernel range count;
        grid-pruned worklist when layout='block-sparse' — the shard rows
        are a contiguous chunk of the space-sorted table, so the jit-built
        AABB worklist prunes most of the gathered table's tiles)."""
        tbl = jax.lax.all_gather(tbl_my, axis, axis=0, tiled=True)
        return be.range_count(my_pts, tbl, d_cut, block=block, layout=layout)

    return rho


def _make_delta_dense(axis, block, be, layout=None):
    @audit_check_rep(
        "table and keys replicated by all_gather(tiled) before the NN "
        "kernel; outputs are P(axis)-local per-row (delta, parent, ok)",
        collectives=("all_gather",))
    def delta(my_pts, my_rk, tbl_my, rk_my):
        """Engine denser-NN kernel: globally exact, no fallback needed."""
        tbl = jax.lax.all_gather(tbl_my, axis, axis=0, tiled=True)
        rk_all = jax.lax.all_gather(rk_my, axis, axis=0, tiled=True)
        dd, pp = be.denser_nn(my_pts, my_rk, tbl, rk_all, block=block,
                              layout=layout)
        # the only infinite delta is the global peak (already final)
        return dd, pp, jnp.ones(dd.shape, bool)

    return delta


_BS_SAFE_CACHE: dict = {}


def _bs_shards_safe(flat_mesh, axis: str, be) -> bool:
    """R1 probe: trace the block-sparse shard phases this mesh would run
    and ask :func:`repro.analysis.spmd_gather_safe` whether any sort-
    derived value feeds a gather/dynamic-slice index inside the
    multi-partition body — the exact pattern the pinned jax-0.4.37 XLA CPU
    SPMD pipeline miscompiles (``ord_i[p]`` degrades to ``p``, silently
    skipping kept tiles).  Memoized per (shard count, axis, backend):
    the verdict depends only on the traced program, not on data."""
    S = int(flat_mesh.devices.size)
    key = (S, axis, be.name)
    hit = _BS_SAFE_CACHE.get(key)
    if hit is not None:
        return hit
    from repro.analysis import spmd_gather_safe

    rho_fn = _make_rho_dense(axis, 1.0, 256, be, layout="block-sparse")
    delta_fn = _make_delta_dense(axis, 256, be, layout="block-sparse")
    sm_rho = shard_map(rho_fn, mesh=flat_mesh,
                       in_specs=(P(axis), P(axis)), out_specs=P(axis),
                       check_rep=False)
    sm_delta = shard_map(delta_fn, mesh=flat_mesh, in_specs=(P(axis),) * 4,
                         out_specs=(P(axis), P(axis), P(axis)),
                         check_rep=False)
    pts = jnp.zeros((S * 8, 2), jnp.float32)
    rk = jnp.zeros((S * 8,), jnp.float32)
    ok = bool(spmd_gather_safe(sm_rho, pts, pts)
              and spmd_gather_safe(sm_delta, pts, rk, pts, rk))
    _BS_SAFE_CACHE[key] = ok
    return ok


# Shard-phase layout decisions, visible in ``python -m repro.obs report``:
# a future probe regression shows up as a dist_bs_degrade_total increment
# with reason=r1-probe-failed instead of only in timings.
_M_BS_ENABLED = obs.counter(
    "dist_bs_enabled",
    "shard-phase layout decisions that kept block-sparse worklists")
_M_BS_DEGRADE = obs.counter(
    "dist_bs_degrade_total",
    "shard-phase layout decisions that degraded block-sparse to dense "
    "per-shard tiles, by reason")
_G_BS_LAYOUT = obs.gauge(
    "dist_bs_layout",
    "last shard-phase layout decision (1 = block-sparse, 0 = dense "
    "degrade), by reason")


def shard_blocksparse_layout(pl, mesh) -> str | None:
    """The layout the per-shard gather-strategy phases run with:
    ``"block-sparse"`` when the plan asks for it AND the shards can honor
    it, else ``None`` (dense degrade — correct results always beat pruned
    tile counts).

    Per-shard block-sparse needs jit-built worklists (inside shard_map),
    so only ``worklist_traceable`` backends qualify.  On multi-partition
    meshes the phases must additionally pass the R1 probe
    (:func:`_bs_shards_safe`) against the pinned jax-0.4.37 XLA CPU SPMD
    miscompile.  The one-hot ring walk keeps every sort-derived value out
    of gather/dynamic-slice index position, so the probe passes and
    multi-device meshes run block-sparse shard phases
    (tests/test_distributed_dpc.py pins both the probe verdict and
    bit-parity with ``run_exdpc`` in a 4-device subprocess).

    Every decision on a sparse plan is recorded on the obs registry
    (``dist_bs_enabled`` / ``dist_bs_degrade_total`` with a reason label,
    plus the ``dist_bs_layout`` gauge) so a silent future degrade is
    visible in ``python -m repro.obs report``."""
    be = pl.backend
    if not pl.sparse:
        return None                     # dense plan: nothing to decide

    def decide(layout, reason):
        (_M_BS_DEGRADE if layout is None else _M_BS_ENABLED).inc(
            reason=reason)
        _G_BS_LAYOUT.set(0.0 if layout is None else 1.0, reason=reason)
        return layout

    if not be.worklist_traceable:
        return decide(None, "host-worklist-backend")
    flat_mesh = flatten_mesh(mesh, pl.data_axis)
    if flat_mesh.devices.size == 1:
        return decide("block-sparse", "single-partition")
    if _bs_shards_safe(flat_mesh, pl.data_axis, be):
        return decide("block-sparse", "r1-probe-passed")
    return decide(None, "r1-probe-failed")


def distributed_dpc(points, cfg: DistDPCConfig | None = None,
                    mesh: Mesh | None = None, *, d_cut: float | None = None,
                    exec_spec=None, strategy: str | None = None,
                    fallback_cap_factor: float | None = None) -> DPCResult:
    """Exact DPC (Ex-DPC semantics) on a device mesh.  Host-orchestrated
    phases, each an SPMD shard_map over the exec spec's data axis.

    Two spellings — mutually exclusive, never silently merged: the legacy
    ``distributed_dpc(points, cfg, mesh)`` with a :class:`DistDPCConfig`,
    or the unified-engine form ``distributed_dpc(points, mesh=mesh,
    d_cut=..., exec_spec=ExecSpec(...), strategy=...)``.
    """
    if cfg is None:
        if d_cut is None:
            raise ValueError("distributed_dpc needs a DistDPCConfig or an "
                             "explicit d_cut=")
        cfg = DistDPCConfig(d_cut=d_cut,
                            strategy=strategy or "gather",
                            fallback_cap_factor=0.05
                            if fallback_cap_factor is None
                            else fallback_cap_factor,
                            exec_spec=as_plan(exec_spec).spec
                            if exec_spec is not None else None)
    else:
        clashes = [n for n, v in (("d_cut", d_cut), ("exec_spec", exec_spec),
                                  ("strategy", strategy),
                                  ("fallback_cap_factor",
                                   fallback_cap_factor)) if v is not None]
        if clashes:
            raise ValueError(f"pass {clashes} either on the DistDPCConfig "
                             f"or as kwargs, not both")
    if mesh is None:
        raise ValueError("distributed_dpc needs a mesh")
    points = jnp.asarray(points, jnp.float32)
    pl = as_plan(cfg.resolved_exec(), points)
    be = pl.backend
    # one resolved row-block for every distributed phase (legacy default
    # 256 — the per-shard chunk loops and halo tiles were tuned to it)
    block = pl.block if pl.block is not None else 256
    n_orig, d = points.shape
    axis = pl.data_axis
    # flatten every mesh axis into the data dimension for DPC: a dedicated
    # 1-axis view keeps specs simple (launch.mesh.flatten_mesh).
    flat_mesh = flatten_mesh(mesh, axis)
    S_data = flat_mesh.devices.size

    with obs.span("dist.grid", n=n_orig) as sp:
        grid = sp.sync(build_grid(points, cfg.d_cut))
    n = grid.points.shape[0]
    # pad rows to a multiple of the shard count; padded rows are inert
    m = -(-n // S_data) * S_data
    pts_s = _pad_rows(grid.points, m, 1e9)

    halo = cfg.strategy == "halo"
    shard_layout = shard_blocksparse_layout(pl, flat_mesh)
    dense = (be.mxu_dense or shard_layout == "block-sparse") and not halo
    if halo or not dense:   # the dense kernel tiles never read the spans
        starts, ends = point_span_bounds(grid)      # (n, S_spans)
        span_w = grid.span_cap
        starts_p = _pad_rows(starts, m, 0).astype(jnp.int32)
        ends_p = _pad_rows(ends, m, 0).astype(jnp.int32)
    if halo:
        # per-shard window bounds from the span table (host: data statistic)
        rows_per = m // S_data
        st_np = np.asarray(starts_p).reshape(S_data, rows_per, -1)
        en_np = np.asarray(ends_p).reshape(S_data, rows_per, -1)
        nonempty = en_np > st_np
        lo_s = np.where(nonempty, st_np, np.iinfo(np.int64).max) \
                 .reshape(S_data, -1).min(axis=1)
        hi_s = en_np.reshape(S_data, -1).max(axis=1)
        starts_block = np.arange(S_data) * rows_per
        lo_s = np.minimum(lo_s, starts_block)
        hi_s = np.maximum(hi_s, starts_block + rows_per)
        W = int((hi_s - lo_s).max())
        # ring reach in blocks, forward and backward of each shard's own
        hf = int(min(S_data - 1,
                     -(-max(int((hi_s - starts_block - rows_per).max()), 0)
                       // rows_per)))
        hb = int(min(S_data - 1,
                     -(-max(int((starts_block - lo_s).max()), 0)
                       // rows_per)))
        lo_arr = jnp.asarray(lo_s[:, None].astype(np.int64))  # (S, 1)

        rho_fn = _make_rho_halo(axis, cfg.d_cut, block, span_w,
                                S_data, W, hf, hb, be)
        sm_rho = shard_map(rho_fn, mesh=flat_mesh,
                           in_specs=(P(axis),) * 5, out_specs=P(axis),
                           check_rep=not be.mxu_dense)  # pallas: no rep rule
        with obs.span("dist.rho", n=n, shards=S_data,
                      strategy=cfg.strategy) as sp:
            rho_sorted = sp.sync(jax.jit(sm_rho)(
                pts_s, starts_p, ends_p, pts_s, lo_arr)[:n])
    elif dense:
        rho_fn = _make_rho_dense(axis, cfg.d_cut, block, be,
                                 layout=shard_layout)
        sm_rho = shard_map(rho_fn, mesh=flat_mesh,
                           in_specs=(P(axis), P(axis)), out_specs=P(axis),
                           check_rep=False)   # pallas_call lacks a rep rule
        with obs.span("dist.rho", n=n, shards=S_data,
                      strategy=cfg.strategy) as sp:
            rho_sorted = sp.sync(jax.jit(sm_rho)(pts_s, pts_s)[:n])
    else:
        rho_fn = _make_rho(axis, cfg.d_cut, block, span_w)
        sm_rho = shard_map(rho_fn, mesh=flat_mesh,
                           in_specs=(P(axis), P(axis), P(axis), P(axis)),
                           out_specs=P(axis))
        with obs.span("dist.rho", n=n, shards=S_data,
                      strategy=cfg.strategy) as sp:
            rho_sorted = sp.sync(jax.jit(sm_rho)(
                pts_s, starts_p, ends_p, pts_s)[:n])

    rho = rho_sorted[grid.inv_order]
    rho_key = with_jitter(rho)
    rk_sorted_full = _pad_rows(rho_key[grid.order], m, -jnp.inf)
    # queries must carry +inf keys on padded rows so they never match
    rk_query = _pad_rows(rho_key[grid.order], m, jnp.inf)
    if halo:
        delta_fn = _make_delta_halo(axis, cfg.d_cut, block, span_w,
                                    S_data, W, hf, hb, be)
        sm_delta = shard_map(delta_fn, mesh=flat_mesh,
                             in_specs=(P(axis),) * 7,
                             out_specs=(P(axis), P(axis), P(axis)),
                             check_rep=not be.mxu_dense)  # pallas: no rep rule
        with obs.span("dist.delta", n=n, shards=S_data) as sp:
            dlt_s, par_s, ok_s = sp.sync(jax.jit(sm_delta)(
                pts_s, rk_query, starts_p, ends_p, pts_s, rk_sorted_full,
                lo_arr))
    elif dense:
        delta_fn = _make_delta_dense(axis, block, be,
                                     layout=shard_layout)
        sm_delta = shard_map(delta_fn, mesh=flat_mesh,
                             in_specs=(P(axis),) * 4,
                             out_specs=(P(axis), P(axis), P(axis)),
                             check_rep=False)  # pallas_call lacks a rep rule
        with obs.span("dist.delta", n=n, shards=S_data) as sp:
            dlt_s, par_s, ok_s = sp.sync(jax.jit(sm_delta)(
                pts_s, rk_query, pts_s, rk_sorted_full))
    else:
        delta_fn = _make_delta(axis, cfg.d_cut, block, span_w)
        sm_delta = shard_map(delta_fn, mesh=flat_mesh,
                             in_specs=(P(axis),) * 6,
                             out_specs=(P(axis), P(axis), P(axis)))
        with obs.span("dist.delta", n=n, shards=S_data) as sp:
            dlt_s, par_s, ok_s = sp.sync(jax.jit(sm_delta)(
                pts_s, rk_query, starts_p, ends_p, pts_s, rk_sorted_full))
    dlt_s, par_s, ok_s = dlt_s[:n], par_s[:n], ok_s[:n]

    # ---- fallback for stencil-unresolved rows (exact, the 1-alpha tail)
    unresolved = np.nonzero(~np.asarray(ok_s))[0]
    if unresolved.size:
        cap = max(S_data, int(-(-unresolved.size // S_data) * S_data))
        q_idx = np.pad(unresolved, (0, cap - unresolved.size),
                       constant_values=0)
        q_pts = grid.points[jnp.asarray(q_idx)]
        q_rk = jnp.asarray(np.where(
            np.arange(cap) < unresolved.size,
            np.asarray(rho_key[grid.order])[q_idx], np.inf))
        # the halo phases route through the configured backend's span-masked
        # kernels (winners direct-diff refined), so the fallback uses the
        # same backend — no silent jnp detour on the optimized path
        fb_be = be
        fb_fn = _make_fallback(axis, max(block, 1024), fb_be,
                               layout=shard_layout)
        sm_fb = shard_map(fb_fn, mesh=flat_mesh,
                          in_specs=(P(axis), P(axis), P(axis), P(axis)),
                          out_specs=(P(axis), P(axis)),
                          check_rep=not fb_be.mxu_dense)
        with obs.span("dist.fallback", unresolved=int(unresolved.size),
                      shards=S_data) as sp:
            fd, fp = sp.sync(jax.jit(sm_fb)(q_pts, q_rk, pts_s,
                                            rk_sorted_full))
        fd = np.asarray(fd)[: unresolved.size]
        fp = np.asarray(fp)[: unresolved.size]
        dlt = np.asarray(dlt_s).copy()
        par = np.asarray(par_s).copy()
        dlt[unresolved] = np.where(np.isfinite(fd), fd, np.inf)
        par[unresolved] = fp
        dlt_s, par_s = jnp.asarray(dlt), jnp.asarray(par)

    delta = dlt_s[grid.inv_order]
    parent_sorted = par_s[grid.inv_order]
    parent = jnp.where(parent_sorted >= 0, grid.order[parent_sorted],
                       -1).astype(jnp.int32)
    return DPCResult(rho=rho, rho_key=rho_key, delta=delta, parent=parent)
