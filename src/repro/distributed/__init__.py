"""Distributed DPC runtime (shard_map) + sharding utilities."""
from .dpc import DistDPCConfig, distributed_dpc

__all__ = ["DistDPCConfig", "distributed_dpc"]
