"""Step-atomic, elastic checkpointing.

Layout (one directory per step):

    <dir>/step_<N>.tmp/           -- written first
        meta.json                 -- treedef, shapes, dtypes, step, extras
        arr_<k>.npy               -- one file per leaf (host-gathered)
    <dir>/step_<N>/               -- atomic rename after fsync

* **Atomicity**: the rename is the commit point; a crash mid-write leaves
  only a ``.tmp`` directory, which ``latest_step`` ignores and ``save``
  garbage-collects.
* **Elasticity**: leaves are stored as *global* arrays with their logical
  shapes; ``restore`` re-shards onto whatever mesh/sharding the new run
  provides (any axis sizes that divide the global shapes).  A 16-device
  checkpoint restores onto 4 or 32 devices unchanged.
* **Determinism**: the data-pipeline cursor and RNG key ride along in
  ``extras`` so a restarted run replays the exact stream.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import ml_dtypes
import numpy as np

# dtypes numpy cannot round-trip through .npy: stored as raw integer views
_RAW_VIEW = {
    "bfloat16": (np.uint16, ml_dtypes.bfloat16),
    "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
    "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2),
}


def _leaves_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return flat


def save(directory: str, step: int, tree, extras: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    # GC any stale partial writes
    for name in os.listdir(directory):
        if name.endswith(".tmp"):
            shutil.rmtree(os.path.join(directory, name), ignore_errors=True)
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)

    flat = _leaves_with_paths(tree)
    meta = {"step": int(step), "extras": extras or {}, "leaves": []}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = arr.dtype.name
        if dtype_name in _RAW_VIEW:
            arr = arr.view(_RAW_VIEW[dtype_name][0])
        np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
        meta["leaves"].append({
            "path": jax.tree_util.keystr(path),
            "shape": list(arr.shape),
            "dtype": dtype_name,
        })
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final) if not os.path.isdir(final) else None
    if os.path.isdir(tmp):          # os.replace cannot overwrite a dir
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(n.split("_", 1)[1]) for n in os.listdir(directory)
             if n.startswith("step_") and not n.endswith(".tmp")]
    return max(steps) if steps else None


def restore(directory: str, step: int, tree_like, shardings=None):
    """Restore into the structure of ``tree_like``.

    ``tree_like`` supplies the pytree structure (e.g. from jax.eval_shape);
    ``shardings`` (same structure, optional) re-shards each leaf on load —
    this is the elastic-restart path: the saved global arrays are placed
    onto the *current* mesh regardless of the mesh that wrote them.
    Returns (tree, extras).
    """
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    flat_like = _leaves_with_paths(tree_like)
    assert len(flat_like) == len(meta["leaves"]), (
        f"checkpoint has {len(meta['leaves'])} leaves, "
        f"target tree has {len(flat_like)}")
    arrays = []
    for i, ((kpath, like), desc) in enumerate(zip(flat_like, meta["leaves"])):
        arr = np.load(os.path.join(path, f"arr_{i}.npy"))
        if desc["dtype"] in _RAW_VIEW:
            arr = arr.view(_RAW_VIEW[desc["dtype"]][1])
        want_shape = tuple(like.shape)
        assert tuple(arr.shape) == want_shape, (
            f"leaf {desc['path']}: saved {arr.shape} != target {want_shape}")
        arrays.append(arr)
    treedef = jax.tree_util.tree_structure(tree_like)
    out = jax.tree_util.tree_unflatten(treedef, arrays)
    if shardings is not None:
        out = jax.tree.map(
            lambda a, s, l: jax.device_put(np.asarray(a, l.dtype), s),
            out, shardings, tree_like)
    else:
        out = jax.tree.map(lambda a, l: jax.numpy.asarray(a, l.dtype),
                           out, tree_like)
    return out, meta["extras"]
