"""Training substrate: optimizer, LR schedule, train-step factory,
fault-tolerant checkpointing."""
from .optimizer import AdamWConfig, adamw_init, adamw_update, opt_state_specs
from .schedule import warmup_cosine
from .step import TrainStepConfig, make_train_step

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "opt_state_specs",
           "warmup_cosine", "TrainStepConfig", "make_train_step"]
