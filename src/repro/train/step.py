"""Train-step factory: loss -> grad -> clip -> AdamW, with optional
microbatch gradient accumulation (lax.scan) so the per-device live batch
stays bounded at large global batches.

The returned step is a pure function
    (params, opt_state, batch, step_idx) -> (params, opt_state, metrics)
suitable for jax.jit with in/out shardings (launch/train.py, launch/dryrun.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from .optimizer import AdamWConfig, adamw_update
from .schedule import warmup_cosine


@dataclass(frozen=True)
class TrainStepConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    microbatches: int = 1          # grad-accumulation factor
    # 'grad': scan of value_and_grad, accumulating gradient trees — GSPMD
    #   emits the data-axis grad all-reduce INSIDE the loop (x microbatches
    #   collective traffic).
    # 'loss': microbatch scan inside the loss; one jax.grad outside — the
    #   parameter cotangent accumulates as the backward-scan carry and is
    #   reduced ONCE per step (the §Perf collective-term optimization).
    accumulation: str = "grad"
    opt: AdamWConfig = AdamWConfig()


def _split_batch(batch: dict, k: int):
    """Reshape every batch leaf (B, ...) -> (k, B//k, ...)."""
    def f(x):
        B = x.shape[0]
        assert B % k == 0, f"batch {B} not divisible by {k} microbatches"
        return x.reshape((k, B // k) + x.shape[1:])
    return jax.tree.map(f, batch)


def make_train_step(loss_fn: Callable, cfg: TrainStepConfig,
                    rules=None) -> Callable:
    """loss_fn: (params, batch, rules=None) -> scalar."""

    def grads_of(params, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, rules=rules))(params)
        return loss, grads

    def scanned_loss(params, batch):
        """Mean loss with the microbatch loop INSIDE (see accumulation)."""
        mb = _split_batch(batch, cfg.microbatches)

        def body(acc, b):
            return acc + loss_fn(params, b, rules=rules), None

        body = jax.checkpoint(body, prevent_cse=False)
        total, _ = jax.lax.scan(body, jnp.float32(0.0), mb)
        return total / cfg.microbatches

    def train_step(params, opt_state, batch, step_idx):
        if cfg.microbatches > 1 and cfg.accumulation == "loss":
            loss, grads = jax.value_and_grad(scanned_loss)(params, batch)
        elif cfg.microbatches > 1:
            mb = _split_batch(batch, cfg.microbatches)

            def body(acc, b):
                loss_acc, g_acc = acc
                loss, g = grads_of(params, b)
                return (loss_acc + loss,
                        jax.tree.map(jnp.add, g_acc, g)), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (loss_sum, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), g0),
                                                mb)
            inv = 1.0 / cfg.microbatches
            loss = loss_sum * inv
            grads = jax.tree.map(lambda g: g * inv, grads)
        else:
            loss, grads = grads_of(params, batch)

        lr = warmup_cosine(step_idx, peak_lr=cfg.peak_lr,
                           warmup_steps=cfg.warmup_steps,
                           total_steps=cfg.total_steps)
        params, opt_state, gnorm = adamw_update(grads, opt_state, params, lr,
                                                cfg.opt)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step
