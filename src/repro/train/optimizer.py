"""AdamW with f32 master weights for bf16 parameter trees.

Model parameters live in bf16 (the compute dtype); the optimizer carries the
f32 master copy plus f32 first/second moments.  ``adamw_update`` consumes
bf16 grads, updates the masters, and re-casts to the param dtype — the
standard mixed-precision training recipe.

State sharding: every per-parameter state tensor inherits the parameter's
PartitionSpec (``opt_state_specs``), i.e. optimizer state is sharded exactly
like the model (ZeRO-1 comes from the data-axis sharding of the specs where
params are model-sharded only; see distributed/sharding.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    f32 = lambda p: p.astype(jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(f32, params),
        "mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        "nu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
    }


def opt_state_specs(param_specs):
    from jax.sharding import PartitionSpec as P
    return {
        "step": P(),
        "master": param_specs,
        "mu": param_specs,
        "nu": param_specs,
    }


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(grads, state, params, lr, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        w = w - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                      + cfg.weight_decay * w)
        return m, v, w

    out = jax.tree.map(upd, grads, state["mu"], state["nu"], state["master"])
    mu = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master, params)
    new_state = {"step": step, "master": master, "mu": mu, "nu": nu}
    return new_params, new_state, gnorm
