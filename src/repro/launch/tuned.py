"""Per-(architecture, step-kind) tuned launch settings (EXPERIMENTS.md §Perf).

The dry-run/launcher applies these with ``--tuned``; without the flag every
arch runs the uniform paper-faithful baseline layout (DP=16 x TP=16,
microbatches=8, scatter MoE dispatch) so the baseline records stay
reproducible.

Settings are keyed by step kind because the optimum depends on the batch
geometry: mamba2's data-only mesh needs global_batch >= 256 (train_4k), and
actively hurts prefill_32k (batch 32 cannot shard 256 ways — measured 10x
flops regression when applied blindly; see §Perf cell 2 notes).
"""

TUNED: dict[str, dict[str, dict]] = {
    # model dims (H=24, d_model=768) cannot use 16-way tensor parallelism:
    # fold the model axis into data parallelism for TRAINING; per-device
    # batch of one sequence needs no gradient accumulation.
    # (flops/dev /8.3, coll /31 — EXPERIMENTS.md §Perf cell 2)
    "mamba2-130m": {"train": {"data_only": True, "microbatches": 1}},
}


def launch_kwargs(arch: str, kind: str, tuned: bool) -> dict:
    if not tuned:
        return {}
    return dict(TUNED.get(arch, {}).get(kind, {}))
