import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any jax-importing module: jax locks the
# device count at first init, and the production meshes below need 512
# placeholder host devices (16x16 single-pod, 2x16x16 multi-pod).
import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp                      # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import (ARCHS, SHAPES, input_specs, reduce_config,  # noqa: E402
                           skip_reason)
from repro.launch.hlo_stats import collective_bytes          # noqa: E402
from repro.launch.mesh import (activate_mesh, batch_sharding,   # noqa: E402
                               batch_spec, make_production_mesh, rules_for,
                               specs_to_shardings)
from repro.models import build_model                         # noqa: E402
from repro.models import transformer as tfm                  # noqa: E402
from repro.launch.hlo_cost import analyze_compiled, xla_cost_dict  # noqa: E402
from repro.train import TrainStepConfig, make_train_step     # noqa: E402
from repro.train.optimizer import adamw_init, opt_state_specs  # noqa: E402


def _fix_batch_dim(spec_tree, rules, B):
    """Replace data-axis entries in cache specs with the batch-size-aware
    sharding (long_500k has global_batch=1, which cannot shard 16 ways)."""
    bs = batch_spec(rules, B)
    repl = bs[0] if len(bs) else None
    data_entries = {rules.data, tuple(rules.data_axes), *rules.data_axes}

    def fix(p):
        parts = []
        for e in p:
            key = tuple(e) if isinstance(e, (tuple, list)) else e
            parts.append(repl if key in data_entries else e)
        return P(*parts)

    return jax.tree.map(fix, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _spec_step(cfg, shape, rules, microbatches: int,
               accumulation: str = "grad"):
    """Build (fn, arg_shapes, in_shardings, out_shardings) for one cell."""
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(model.init, key)
    pspecs = model.param_specs(rules)
    batch = input_specs(cfg, shape)
    bspecs = batch_sharding(rules, batch)

    if shape.kind == "train":
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        ospecs = opt_state_specs(pspecs)
        step = make_train_step(
            model.loss_fn,
            TrainStepConfig(microbatches=microbatches,
                            accumulation=accumulation),
            rules=rules)
        args = (params_shape, opt_shape, batch,
                jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (pspecs, ospecs, bspecs, None)
        out_sh = (pspecs, ospecs, None)
        return step, args, in_sh, out_sh, (0, 1)   # donate params + opt

    if shape.kind == "prefill":
        if cfg.family == "encoder":
            def enc(params, batch):
                return tfm.encode_step(params, batch, cfg, rules=rules)
            return enc, (params_shape, batch), (pspecs, bspecs), None, ()
        B = shape.global_batch
        cache_shape = jax.eval_shape(lambda: model.init_cache(B, shape.seq_len))
        cspecs = _fix_batch_dim(model.cache_specs(rules), rules, B)

        def pf(params, batch, cache):
            return model.prefill(params, batch, cache, rules=rules)
        return (pf, (params_shape, batch, cache_shape),
                (pspecs, bspecs, cspecs), (None, cspecs), (2,))  # donate cache

    # decode
    B = shape.global_batch
    cache_shape = jax.eval_shape(lambda: model.init_cache(B, shape.seq_len))
    cspecs = _fix_batch_dim(model.cache_specs(rules), rules, B)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tspec = P(*(tuple(batch_spec(rules, B)) + (None,)))
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def dec(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos, rules=rules)
    return (dec, (params_shape, cache_shape, tok, pos),
            (pspecs, cspecs, tspec, None), (None, cspecs), (1,))  # donate cache


def _analyze(fn, args, in_sh, out_sh, save_hlo=None, donate=()):
    """jit + lower + compile + trip-count-aware HLO cost extraction.

    ``donate``: argnums whose buffers the step owns (params/opt for train,
    the KV cache for serve) — production steps always donate these, and
    without it XLA materializes a full copy of every functionally-updated
    state tensor (the decode cache copy alone is ~90x the attention reads).
    """
    t0 = time.time()
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate)
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    xla_cost = xla_cost_dict(compiled)
    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    rec = {
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        # trip-count-aware per-device costs (launch/hlo_cost.py); XLA's own
        # cost_analysis counts while-loop bodies once, so it is recorded only
        # for reference as "xla_*"
        "cost": analyze_compiled(compiled),
        "xla_flops_body_once": float(xla_cost.get("flops", 0.0)),
        "xla_bytes_body_once": float(xla_cost.get("bytes accessed", 0.0)),
        "collectives_body_once": collective_bytes(hlo),
    }
    rec["flops_per_device"] = rec["cost"]["flops"]
    rec["bytes_per_device"] = rec["cost"]["bytes"]
    rec["collectives"] = rec["cost"]["collectives"]
    return rec


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             microbatches: int = 8, save_hlo: str | None = None,
             arch_override=None, accumulation: str = "grad",
             data_only: bool = False) -> dict:
    """Lower + compile one (arch x shape x mesh) cell; return its record."""
    cfg = arch_override if arch_override is not None else ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind}
    reason = skip_reason(arch, shape_name)
    if reason:
        rec["skipped"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(mesh, data_only=data_only)
    rec["devices"] = mesh.devices.size
    rec["variant"] = {"accumulation": accumulation, "data_only": data_only}

    with activate_mesh(mesh):
        fn, args, in_sh, out_sh, donate = _spec_step(cfg, shape, rules,
                                                     microbatches,
                                                     accumulation)
        if not hasattr(jax, "set_mesh"):   # 0.4.x: jit wants Shardings
            in_sh = specs_to_shardings(mesh, in_sh)
            out_sh = specs_to_shardings(mesh, out_sh)
        rec.update(_analyze(fn, args, in_sh, out_sh, save_hlo=save_hlo,
                            donate=donate))
    rec["microbatches"] = microbatches if shape.kind == "train" else 1
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES),
                    help="one shape (default: all)")
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--smoke-config", action="store_true",
                    help="use the reduced config (debugging the harness)")
    ap.add_argument("--accumulation", default="grad",
                    choices=["grad", "loss"],
                    help="microbatch gradient accumulation mode (Perf)")
    ap.add_argument("--data-only", action="store_true",
                    help="fold the model axis into data parallelism (Perf)")
    ap.add_argument("--suffix", default="",
                    help="output filename suffix for perf variants")
    ap.add_argument("--moe-gather", action="store_true",
                    help="gather-based MoE dispatch/combine (now the "
                         "default; flag kept for provenance)")
    ap.add_argument("--moe-scatter", action="store_true",
                    help="scatter-based MoE dispatch (paper-faithful "
                         "baseline records)")
    ap.add_argument("--tuned", action="store_true",
                    help="apply per-arch tuned launch settings "
                         "(launch/tuned.py; EXPERIMENTS.md §Perf)")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                tag = f"{arch}__{shape}__{mesh_name}{args.suffix}"
                hlo_path = (os.path.join(args.out, tag + ".hlo.txt")
                            if args.save_hlo else None)
                override = (reduce_config(ARCHS[arch])
                            if args.smoke_config else None)
                try:
                    from repro.launch.tuned import launch_kwargs
                    from repro.models import moe as moe_mod
                    tk = launch_kwargs(arch, SHAPES[shape].kind, args.tuned)
                    mode = ("scatter" if args.moe_scatter else "gather")
                    with moe_mod.dispatch_mode(mode):
                        rec = run_cell(
                            arch, shape, mp,
                            microbatches=tk.get("microbatches",
                                                args.microbatches),
                            save_hlo=hlo_path,
                            arch_override=override,
                            accumulation=args.accumulation,
                            data_only=tk.get("data_only", args.data_only))
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures += 1
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=2)
                if "skipped" in rec:
                    status = "SKIP " + rec["skipped"]
                elif "error" in rec:
                    status = "FAIL " + rec["error"][:120]
                else:
                    status = (f"ok lower={rec['lower_s']}s "
                              f"compile={rec['compile_s']}s "
                              f"flops/dev={rec['flops_per_device']:.3g} "
                              f"coll={rec['collectives']['total_bytes']:.3g}B")
                print(f"[dryrun] {tag}: {status}", flush=True)
    print(f"[dryrun] done, {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
