"""Collective-traffic extraction from compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` reports FLOPs and bytes for the *per-device*
partitioned module but not collective traffic; this parser sums the result
byte-sizes of every collective instruction in ``compiled.as_text()``:

    all-gather       -> bytes = gathered (output) size: what crosses links
    all-reduce       -> bytes = tensor size (ring: 2x(N-1)/N ~ 2x, see note)
    reduce-scatter   -> bytes = input size / N (output shard per device)
    all-to-all       -> bytes = tensor size
    collective-permute -> bytes = tensor size

The per-op link-traffic multipliers (ring all-reduce moves ~2x its payload)
are applied by the roofline layer, not here — this module reports raw
per-device payload bytes per collective kind so the model is explicit.
Async pairs (``-start``/``-done``) are counted once (at ``-start``).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"([\w\-]+)\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind payload bytes + op counts from partitioned HLO."""
    out = defaultdict(int)
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        type_str, opname = m.groups()
        if opname.endswith("-done"):
            continue
        base = opname.removesuffix("-start")
        for kind in _COLLECTIVES:
            if base == kind or base.startswith(kind + "."):
                out[kind] += _shape_bytes(type_str)
                counts[kind] += 1
                break
    return {"bytes": dict(out), "counts": dict(counts),
            "total_bytes": sum(out.values())}
