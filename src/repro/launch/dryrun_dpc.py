import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# 512 placeholder devices, set before any jax import (same contract as
# launch/dryrun.py).  This dry-run lowers the DISTRIBUTED DPC phases — the
# paper's parallel algorithm itself — on the production mesh and extracts
# roofline terms, baseline (all-gather) vs optimized (halo ring).
import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp                      # noqa: E402
from jax.sharding import Mesh, PartitionSpec as P   # noqa: E402
from jax.experimental.shard_map import shard_map    # noqa: E402

from repro.distributed.dpc import (_make_delta, _make_delta_halo,  # noqa: E402
                                   _make_rho, _make_rho_halo)
from repro.launch.hlo_cost import analyze_compiled   # noqa: E402
from repro.launch.mesh import make_production_mesh   # noqa: E402


def lower_phase(fn, arg_shapes, flat_mesh, axis, n_in, out_specs):
    sm = shard_map(fn, mesh=flat_mesh, in_specs=(P(axis),) * n_in,
                   out_specs=out_specs)
    t0 = time.time()
    compiled = jax.jit(sm).lower(*arg_shapes).compile()
    return compiled, time.time() - t0


def run(n: int, d: int, span_w: int, window_blocks: int, multi_pod: bool,
        out_dir: str):
    mesh = make_production_mesh(multi_pod=multi_pod)
    S = mesh.devices.size
    flat_mesh = Mesh(mesh.devices.reshape(-1), ("data",))
    if hasattr(jax, "set_mesh"):   # jax >= 0.6; shard_map gets mesh= below
        jax.set_mesh(flat_mesh)
    m = n // S                       # rows per shard
    n_spans = 9                      # 3^(g-1), g=3 leading grid dims
    f32 = jnp.float32
    i32 = jnp.int32

    pts = jax.ShapeDtypeStruct((n, d), f32)
    st = jax.ShapeDtypeStruct((n, n_spans), i32)
    rk = jax.ShapeDtypeStruct((n,), f32)
    lo = jax.ShapeDtypeStruct((S, 1), jnp.int64)

    # halo statics: window = `window_blocks` blocks (space-sorted layout —
    # a uniform-ish distribution needs the two neighbour blocks; skew is
    # absorbed by the host-measured W at runtime)
    W = window_blocks * m
    hf = hb = max(1, (window_blocks - 1) // 2)

    recs = {}
    one = P("data")
    three = (P("data"), P("data"), P("data"))
    phases = {
        "rho_gather": (_make_rho("data", 1.0, 256, span_w),
                       (pts, st, st, pts), 4, one),
        "rho_halo": (_make_rho_halo("data", 1.0, 256, span_w, S, W, hf, hb),
                     (pts, st, st, pts, lo), 5, one),
        "delta_gather": (_make_delta("data", 1.0, 256, span_w),
                         (pts, rk, st, st, pts, rk), 6, three),
        "delta_halo": (_make_delta_halo("data", 1.0, 256, span_w, S, W,
                                        hf, hb),
                       (pts, rk, st, st, pts, rk, lo), 7, three),
    }
    for name, (fn, shapes, n_in, out_specs) in phases.items():
        compiled, dt = lower_phase(fn, shapes, flat_mesh, "data", n_in,
                                   out_specs)
        cost = analyze_compiled(compiled)
        mem = compiled.memory_analysis()
        recs[name] = {
            "compile_s": round(dt, 2),
            "flops": cost["flops"], "dot_flops": cost["dot_flops"],
            "bytes": cost["bytes"],
            "collectives": cost["collectives"],
            "temp_bytes": mem.temp_size_in_bytes,
        }
        print(f"[dpc-dryrun] {name}: flops/dev={cost['flops']:.3g} "
              f"bytes={cost['bytes']:.3g} "
              f"coll={cost['collectives']['total_bytes']:.3g}B "
              f"temp={mem.temp_size_in_bytes:.3g}B", flush=True)

    rec = {"n": n, "d": d, "span_w": span_w, "devices": S,
           "window_blocks": window_blocks, "phases": recs}
    os.makedirs(out_dir, exist_ok=True)
    tag = "pod2x16x16" if multi_pod else "pod16x16"
    with open(os.path.join(out_dir, f"dpc__n{n}__{tag}.json"), "w") as f:
        json.dump(rec, f, indent=2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 24)   # 16.7M points
    ap.add_argument("--d", type=int, default=3)
    ap.add_argument("--span-w", type=int, default=64)
    ap.add_argument("--window-blocks", type=int, default=3)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    run(args.n, args.d, args.span_w, args.window_blocks, args.multipod,
        args.out)


if __name__ == "__main__":
    main()
