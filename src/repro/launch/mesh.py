"""Production mesh construction + logical sharding rule resolution.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run process sets
XLA_FLAGS for 512 host devices before calling it, every other process sees
the real (single-CPU) topology.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.models.common import MeshRules


def activate_mesh(mesh):
    """Make ``mesh`` ambient for PartitionSpec-based in/out shardings.

    Returns a context manager that deactivates on exit on every jax
    version: ``jax.sharding.use_mesh`` where it exists (>= 0.5), else the
    Mesh context manager (0.4.x, this container).
    """
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh


def specs_to_shardings(mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree for jit in/out_shardings.

    jax 0.4.x jit accepts only Sharding objects (no ambient-mesh
    PartitionSpecs); None leaves/subtrees stay None (= unspecified).
    """
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        spec_tree, is_leaf=lambda x: isinstance(x, P))


def flatten_mesh(mesh, axis_name: str = "data"):
    """Collapse every mesh axis into one ``axis_name`` axis.

    DPC is data-parallel only (the paper's algorithm has no model axis), so
    both the batch path (``distributed.dpc``) and the streaming window
    (``repro.stream``) shard over the flattened device list: the model axis
    is reused as more data workers."""
    from jax.sharding import Mesh

    return Mesh(mesh.devices.reshape(-1), (axis_name,))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def rules_for(mesh, *, data_only: bool = False) -> MeshRules:
    """Logical rules for a mesh.  ``data_only`` folds the model axis into
    the data axes (pure DP) — the right layout for small archs whose dims
    cannot use 16-way tensor parallelism (mamba2-130m; §Perf)."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_axes = tuple(a for a in ("pod", "data") if a in axis_sizes)
    if data_only:
        return MeshRules(data_axes=data_axes + ("model",), model_axis=None,
                         axis_sizes=axis_sizes)
    return MeshRules(data_axes=data_axes, model_axis="model",
                     axis_sizes=axis_sizes)


def batch_spec(rules: MeshRules, global_batch: int) -> P:
    """Batch-dim sharding over the data axes, falling back to replication
    when the batch does not divide (long_500k has global_batch=1)."""
    total = 1
    for a in rules.data_axes:
        total *= rules.axis_sizes.get(a, 1)
    if global_batch % total == 0:
        return P(rules.data)
    # try the trailing data axis alone before giving up
    last = rules.data_axes[-1]
    if global_batch % rules.axis_sizes.get(last, 1) == 0:
        return P(last)
    return P(None)


def batch_sharding(rules: MeshRules, batch_tree):
    """Per-leaf input sharding: batch dim over data, rest replicated."""
    import jax.tree_util as jtu

    def leaf(spec: jax.ShapeDtypeStruct):
        bs = batch_spec(rules, spec.shape[0])
        return P(*(tuple(bs) + (None,) * (len(spec.shape) - 1)))

    return jtu.tree_map(leaf, batch_tree)
