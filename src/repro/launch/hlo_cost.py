"""Trip-count-aware cost analysis of compiled (SPMD-partitioned) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a while-loop body ONCE,
not multiplied by its trip count, so any scan-based program (layer scans,
microbatch accumulation, chunked attention/CE — i.e. every real LM program)
is undercounted by orders of magnitude.  XLA annotates each ``while`` with
``backend_config={"known_trip_count":{"n":...}}``, so the fix is a recursive
walk of the computation graph that multiplies child-computation costs by
their trip counts.

Per instruction:
* flops:  dot = 2 * prod(batch) * M * N * K (from the dot dnums in the text);
          listed elementwise/reduce ops = result (or input) element count —
          the same convention as XLA's HloCostAnalysis.
* bytes:  operands + results of every top-level instruction except free ops
          (parameter/tuple/get-tuple-element/constant/bitcast).  Fusions are
          counted at the call boundary only — exactly the HBM-traffic view,
          since fused internals never round-trip to memory.
* collectives: payload bytes per kind (all-gather counts its gathered
          output; others their tensor size), multiplied through loops.

The result is a per-device cost (the partitioned module is the per-device
program).  Used by launch/dryrun.py and benchmarks/roofline.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "s4": 1, "u4": 1, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops whose cost is ~1 flop per output element (XLA convention)
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "sqrt", "rsqrt", "power",
    "floor", "ceil", "sign", "compare", "select", "and", "or", "not", "xor",
    "atan2", "expm1", "log1p", "logistic", "cbrt", "erf", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "clamp", "round-nearest-afz", "round-nearest-even", "cosine", "sine",
    "tan",
}
_FREE = {"parameter", "tuple", "get-tuple-element", "constant", "bitcast",
         "after-all", "partition-id", "replica-id", "opt-barrier"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
    r"([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_CALLS_RE = re.compile(r"calls=(%[\w.\-]+)")
_WHILE_RE = re.compile(r"condition=(%[\w.\-]+),\s*body=(%[\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"(%[\w.\-]+)")
_DOT_DIMS = {
    "lhs_contracting_dims": re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}"),
    "rhs_contracting_dims": re.compile(r"rhs_contracting_dims=\{([0-9,]*)\}"),
    "lhs_batch_dims": re.compile(r"lhs_batch_dims=\{([0-9,]*)\}"),
    "rhs_batch_dims": re.compile(r"rhs_batch_dims=\{([0-9,]*)\}"),
}


def _shape_elems_bytes(type_str: str):
    elems, total = 0, 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dtype]
    return elems, total


def _first_shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Cost:
    flops: float = 0.0
    dot_flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=dict)
    unknown_trips: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.dot_flops += mult * other.dot_flops
        self.bytes += mult * other.bytes
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + mult * v
        self.unknown_trips += other.unknown_trips


@dataclass
class _Instr:
    name: str
    type_str: str
    opcode: str
    rest: str          # everything after the '('
    is_root: bool = False


def _parse_computations(text: str):
    comps: dict[str, list[_Instr]] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        hdr = (_COMP_HDR_RE.match(line)
               if "{" in line and not line.startswith(" ") else None)
        if hdr:
            cur = hdr.group(2)
            comps[cur] = []
            if hdr.group(1):
                entry = cur
            continue
        if line.startswith("}") or line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[cur].append(_Instr(m.group(1), m.group(2), m.group(3),
                                     m.group(4),
                                     is_root="ROOT" in line[:12]))
    return comps, entry


def _dot_flops(instr: _Instr, types: dict) -> float:
    ops = _OPERAND_RE.findall(instr.rest.split("),")[0] + ")")
    if len(ops) < 2:
        return 0.0
    lhs_t = types.get(ops[0], "")
    lhs = _first_shape_dims(lhs_t)
    dims = {}
    for k, rx in _DOT_DIMS.items():
        m = rx.search(instr.rest)
        dims[k] = ([int(x) for x in m.group(1).split(",") if x] if m else [])
    out = _first_shape_dims(instr.type_str)
    contract = 1
    for i in dims["lhs_contracting_dims"]:
        if i < len(lhs):
            contract *= lhs[i]
    out_elems = 1
    for d in out:
        out_elems *= d
    return 2.0 * out_elems * contract


def _root_opcode(instrs) -> str | None:
    for ins in instrs:
        if ins.is_root:
            return ins.opcode
    return instrs[-1].opcode if instrs else None


def _operands(ins: _Instr):
    return _OPERAND_RE.findall(ins.rest.split("),")[0] + ")")


_SPARSE_READS = ("dynamic-slice", "slice", "gather")


def _fusion_bytes(inner_instrs, opnd_names, outer_types, result_type) -> float:
    """HBM traffic of one fusion, XLA-HloCostAnalysis style.

    Reads: each fusion operand is charged at full size UNLESS every internal
    use is a slice/gather (charged at sliced size) or the buffer operand of
    a dynamic-update-slice (in-place: no read).  Writes: the result, except
    a root DUS writes only its update region.  Internal intermediates stay
    in registers/VMEM and are free.
    """
    # DUS-emulation fusions: XLA CPU lowers a bf16 dynamic-update-slice as
    # convert(f32) -> DUS -> convert(bf16) over the WHOLE buffer.  On TPU
    # this is a native in-place row write, so charge only the update region
    # (2x: read update + write region).
    passthrough = {"convert", "copy", "bitcast", "reshape", "transpose",
                   "parameter", "constant"}
    nonfree = [i for i in inner_instrs if i.opcode not in passthrough]
    if (len(nonfree) == 1
            and nonfree[0].opcode == "dynamic-update-slice"):
        inner_types = {i.name: i.type_str for i in inner_instrs}
        ops_d = _operands(nonfree[0])
        if len(ops_d) >= 2:
            upd = _shape_elems_bytes(inner_types.get(ops_d[1], ""))[1]
            if upd:
                return 2.0 * upd

    params_by_idx = {}
    for ii in inner_instrs:
        if ii.opcode == "parameter":
            try:
                idx = int(ii.rest.split(")")[0])
            except ValueError:
                continue
            params_by_idx[idx] = ii.name

    read = 0.0
    for idx, opn in enumerate(opnd_names):
        full = _shape_elems_bytes(outer_types.get(opn, ""))[1]
        pname = params_by_idx.get(idx)
        if pname is None:
            read += full
            continue
        uses = [u for u in inner_instrs if pname in _operands(u)]
        sliced = bool(uses)
        part = 0.0
        for u in uses:
            ops_u = _operands(u)
            if u.opcode in _SPARSE_READS and ops_u and ops_u[0] == pname:
                part += _shape_elems_bytes(u.type_str)[1]
            elif (u.opcode == "dynamic-update-slice" and ops_u
                  and ops_u[0] == pname):
                part += 0.0          # in-place buffer: no read
            elif u.opcode in ("bitcast", "copy", "reshape", "transpose"):
                sliced = False       # full pass-through -> full read
                break
            else:
                sliced = False
                break
        read += part if sliced else full

    root = next((i for i in inner_instrs if i.is_root),
                inner_instrs[-1] if inner_instrs else None)
    write = _shape_elems_bytes(result_type)[1]
    if root is not None and root.opcode == "dynamic-update-slice":
        ops_r = _operands(root)
        if len(ops_r) >= 2:
            inner_types = {i.name: i.type_str for i in inner_instrs}
            write = _shape_elems_bytes(inner_types.get(ops_r[1], ""))[1]
    return read + write


def analyze(text: str) -> Cost:
    comps, entry = _parse_computations(text)
    if entry is None:
        # fall back: biggest computation named main
        entry = next((n for n in comps if "main" in n), None)
        if entry is None:
            return Cost()
    memo: dict[str, Cost] = {}

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()          # guard against cycles
        total = Cost()
        instrs = comps.get(name, [])
        types = {i.name: i.type_str for i in instrs}
        for ins in instrs:
            op = ins.opcode
            if op in _FREE:
                continue
            elems, byts = _shape_elems_bytes(ins.type_str)
            # operand bytes
            opnd_names = _OPERAND_RE.findall(ins.rest.split("),")[0] + ")")
            opnd_bytes = sum(_shape_elems_bytes(types.get(o, ""))[1]
                             for o in opnd_names)
            base = op.removesuffix("-start")
            is_coll = next((k for k in _COLLECTIVES
                            if base == k or base.startswith(k + ".")), None)
            if op.endswith("-done"):
                continue
            if is_coll:
                total.coll[is_coll] = total.coll.get(is_coll, 0.0) + byts
                total.bytes += byts + opnd_bytes
                continue
            if op == "while":
                m = _WHILE_RE.search(ins.rest)
                trip = None
                tm = _TRIP_RE.search(ins.rest)
                if tm:
                    trip = int(tm.group(1))
                if m:
                    body, cond = m.group(2), m.group(1)
                    if trip is None:
                        trip = 1
                        total.unknown_trips += 1
                    total.add(comp_cost(body), trip)
                    total.add(comp_cost(cond), trip + 1)
                continue
            if op == "fusion":
                cm = _CALLS_RE.search(ins.rest)
                if cm:
                    inner = comp_cost(cm.group(1))
                    total.flops += inner.flops
                    total.dot_flops += inner.dot_flops
                    for k, v in inner.coll.items():
                        total.coll[k] = total.coll.get(k, 0.0) + v
                    total.bytes += _fusion_bytes(
                        comps.get(cm.group(1), []), opnd_names, types,
                        ins.type_str)
                    continue
                # fusion bytes = call-boundary traffic only
                total.bytes += byts + opnd_bytes
                continue
            if op in ("call", "conditional", "sort", "map", "reduce",
                      "reduce-window", "scatter", "select-and-scatter"):
                for cm in re.finditer(
                        r"(?:to_apply|calls)=(%[\w.\-]+)", ins.rest):
                    # applied computations are per-element; charge once per
                    # output element for reduce-likes via the elementwise rule
                    pass
                if op == "conditional":
                    branches = re.search(
                        r"branch_computations=\{([^}]*)\}", ins.rest)
                    if branches:
                        subs = [comp_cost(b.strip()) for b in
                                branches.group(1).split(",")]
                        if subs:
                            big = max(subs, key=lambda c: c.flops + c.bytes)
                            total.add(big)
                if op == "call":
                    cm = re.search(r"to_apply=(%[\w.\-]+)", ins.rest)
                    if cm:
                        total.add(comp_cost(cm.group(1)))
                total.bytes += byts + opnd_bytes
                total.flops += elems
                continue
            if op == "dot" or op == "convolution":
                f = _dot_flops(ins, types)
                total.flops += f
                total.dot_flops += f
                total.bytes += byts + opnd_bytes
                continue
            if op in _ELEMENTWISE:
                total.flops += elems
                total.bytes += byts + opnd_bytes
                continue
            if op in ("dynamic-slice", "slice", "gather"):
                # sparse reads: only the produced elements are touched
                # (+ indices, negligible) — NOT the whole operand
                total.bytes += 2.0 * byts
                continue
            if op in ("dynamic-update-slice", "scatter"):
                # sparse writes: only the update region is read + written
                op_sizes = [_shape_elems_bytes(types.get(o, ""))[1]
                            for o in opnd_names]
                small = sum(op_sizes) - (max(op_sizes) if op_sizes else 0)
                total.bytes += 2.0 * small
                continue
            if op == "custom-call":
                # CPU oneDNN matmul rewrites etc.: charge bytes; flops only
                # if it looks like a matmul (documented limitation)
                total.bytes += byts + opnd_bytes
                continue
            # everything else (copy, broadcast, reshape, slice, dus, iota,
            # gather, concatenate, pad, reduce, transpose, rng, convert...)
            total.bytes += byts + opnd_bytes
        memo[name] = total
        return total

    return comp_cost(entry)


def xla_cost_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized to one flat dict.

    Depending on the jax version this returns a dict or a one-element list
    of dicts (one per partitioned module); normalize so callers can index
    by property name either way.
    """
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def analyze_compiled(compiled) -> dict:
    c = analyze(compiled.as_text())
    return {
        "flops": c.flops,
        "dot_flops": c.dot_flops,
        "bytes": c.bytes,
        "collectives": {"bytes": dict(c.coll),
                        "total_bytes": float(sum(c.coll.values()))},
        "unknown_trips": c.unknown_trips,
    }
