"""End-to-end training driver: data pipeline -> sharded train loop ->
step-atomic checkpoints -> restart/elastic restore.

Run locally (CPU, reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault-tolerance wiring:
* checkpoints carry params + optimizer + data cursor + RNG seed; a killed
  run resumes bit-identically (tests/test_checkpoint.py);
* fixed-shape batches: a restarted host can never change the collective
  schedule (straggler discipline);
* ``--mesh-shape`` reshards any checkpoint onto the current mesh (elastic
  restart: axis sizes only need to divide the global shapes).
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import ARCHS, reduce_config
from repro.data.tokens import TokenPipeline
from repro.launch.mesh import batch_sharding, rules_for
from repro.models import build_model
from repro.train import TrainStepConfig, make_train_step
from repro.train import checkpoint as ckpt
from repro.train.optimizer import adamw_init, opt_state_specs


def build_mesh(shape, names):
    n = int(np.prod(shape))
    if len(jax.devices()) < n:
        raise SystemExit(
            f"need {n} devices, have {len(jax.devices())}; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    return jax.make_mesh(tuple(shape), tuple(names))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh-shape", type=int, nargs="+", default=[1, 1])
    ap.add_argument("--mesh-names", nargs="+", default=["data", "model"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = reduce_config(cfg)
    model = build_model(cfg)

    mesh = build_mesh(args.mesh_shape, args.mesh_names)
    if hasattr(jax, "set_mesh"):   # jax >= 0.6; shardings below are explicit
        jax.set_mesh(mesh)
    rules = rules_for(mesh)

    pipeline = TokenPipeline(cfg, args.batch, args.seq, seed=args.seed)
    tcfg = TrainStepConfig(peak_lr=args.lr, warmup_steps=min(20, args.steps),
                           total_steps=args.steps,
                           microbatches=args.microbatches)
    step_fn = make_train_step(model.loss_fn, tcfg, rules=rules)

    pspecs = model.param_specs(rules)
    ospecs = opt_state_specs(pspecs)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    osh = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs)

    start_step = 0
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(args.seed))
    opt_shape = jax.eval_shape(adamw_init, params_shape)
    latest = ckpt.latest_step(args.ckpt_dir) if args.ckpt_dir else None
    if latest is not None:
        (params, opt_state), extras = ckpt.restore(
            args.ckpt_dir, latest, (params_shape, opt_shape), (psh, osh))
        pipeline.load_state_dict(extras["pipeline"])
        start_step = int(extras["step"]) + 1
        print(f"[train] restored step {latest} "
              f"(cursor={pipeline.cursor})", flush=True)
    else:
        params = jax.jit(model.init, out_shardings=psh)(
            jax.random.PRNGKey(args.seed))
        opt_state = jax.jit(adamw_init, out_shardings=osh)(params)

    batch_sh = None
    jit_step = jax.jit(step_fn, in_shardings=(psh, osh, None, None),
                       out_shardings=(psh, osh, None),
                       donate_argnums=(0, 1))

    t0 = time.time()
    tokens_seen = 0
    for step in range(start_step, args.steps):
        np_batch = next(pipeline)
        if batch_sh is None:
            bspecs = batch_sharding(rules, jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), np_batch))
            batch_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), bspecs)
        batch = jax.tree.map(jax.device_put, np_batch, batch_sh)
        params, opt_state, metrics = jit_step(params, opt_state, batch,
                                              jnp.int32(step))
        tokens_seen += args.batch * args.seq
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"[train] step {step:5d} loss {float(metrics['loss']):.4f}"
                  f" gnorm {float(metrics['grad_norm']):.3f}"
                  f" lr {float(metrics['lr']):.2e}"
                  f" tok/s {tokens_seen / max(dt, 1e-9):.0f}", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            path = ckpt.save(args.ckpt_dir, step, (params, opt_state),
                             extras={"step": step,
                                     "pipeline": pipeline.state_dict(),
                                     "arch": cfg.name})
            print(f"[train] checkpoint -> {path}", flush=True)
    print(f"[train] done: {args.steps - start_step} steps in "
          f"{time.time() - t0:.1f}s", flush=True)
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
