"""DPCEngine: one facade over batch, distributed, streaming and serving DPC.

The subsystems share one lifecycle:

* ``fit(points)`` — batch clustering with the configured algorithm
  (``scan`` / ``exdpc`` / ``approxdpc`` / ``sapproxdpc`` / baselines), or
  the distributed shard_map phases when the engine holds a mesh.
* ``partial_fit(batch)`` — incremental sliding-window clustering
  (delegates to :class:`repro.stream.StreamDPC`; bit-identical to a
  from-scratch ``fit`` of the window contents, per the stream parity
  contract).  A batch ``fit`` of at most ``window_capacity`` points seeds
  the window.
* ``predict(points)`` — read-only nearest-label queries with the serve
  layer's semantics (``StreamService.query``): a query within ``d_cut`` of
  a fitted point adopts its label (``HIT``); out-of-coverage queries fall
  back to the nearest cluster center (``MISS_FALLBACK``); ``MISS`` only
  when no centers exist.
* ``decision_graph()`` — the paper's Fig. 1 (rho, delta) pairs for the
  current state.

Execution is one :class:`ExecSpec`, resolved once per input shape by the
planner and reused: repeated ``fit`` calls on same-shaped inputs get the
same :class:`DPCPlan` object back (same jit traces; host-built pallas
worklists re-served from the plan's content-addressed cache).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro import obs
from repro.resilience.sanitize import AdmissionConfig, admit

from .planner import DPCPlan, as_plan
from .spec import ExecSpec

__all__ = ["DPCEngine"]

# the canonical algorithm list lives with the dispatch table in dpc_api
from repro.core.dpc_api import _ALGORITHMS as _BATCH_ALGORITHMS

_DISTRIBUTED_OK = ("exdpc", "scan")     # distributed_dpc is exact DPC


class DPCEngine:
    """One engine, one plan: ``fit`` / ``partial_fit`` / ``predict`` /
    ``decision_graph`` over a single :class:`ExecSpec`.

    Domain parameters mirror :class:`repro.core.DPCConfig` (``d_cut``,
    ``algorithm``, ``rho_min`` / ``delta_min``, ``eps``, ``grid_dims``)
    plus the streaming window shape (``window_capacity`` / ``batch_cap``;
    extra :class:`repro.stream.StreamDPCConfig` fields ride in
    ``stream_options``) and an optional device ``mesh`` (distributed
    ``fit`` phases, sharded streaming rho repair).  Validation is
    fail-fast at construction (``stream_options`` contents are checked by
    ``StreamDPCConfig`` when the first ``partial_fit`` builds it).
    """

    def __init__(self, d_cut: float, *, algorithm: str = "approxdpc",
                 rho_min: float = 10.0, delta_min: float | None = None,
                 eps: float = 0.8, grid_dims: int | None = None,
                 exec_spec: ExecSpec | None = None, mesh=None,
                 strategy: str = "gather",
                 window_capacity: int = 4096, batch_cap: int = 256,
                 stream_options: dict | None = None,
                 admission: AdmissionConfig | None = AdmissionConfig()):
        if not d_cut > 0.0:
            raise ValueError(f"d_cut must be positive, got {d_cut!r}")
        if algorithm not in _BATCH_ALGORITHMS:
            raise ValueError(f"unknown algorithm {algorithm!r}; expected "
                             f"one of {_BATCH_ALGORITHMS}")
        if algorithm == "sapproxdpc" and eps <= 0.0:
            raise ValueError(f"S-Approx-DPC needs eps > 0, got {eps!r}")
        if delta_min is not None and delta_min <= d_cut:
            raise ValueError("delta_min must exceed d_cut (Def. 5)")
        if exec_spec is not None and not isinstance(exec_spec, ExecSpec):
            raise TypeError(f"exec_spec must be an ExecSpec, got "
                            f"{type(exec_spec).__name__}")
        if strategy not in ("gather", "halo"):
            raise ValueError(f"unknown strategy {strategy!r}; expected "
                             f"'gather' or 'halo'")
        if batch_cap > window_capacity:
            raise ValueError(f"batch_cap ({batch_cap}) cannot exceed "
                             f"window_capacity ({window_capacity})")
        if admission is not None and not isinstance(admission,
                                                    AdmissionConfig):
            raise TypeError(f"admission must be an AdmissionConfig or None, "
                            f"got {type(admission).__name__}")
        self.admission = admission
        self.d_cut = float(d_cut)
        self.algorithm = algorithm
        self.rho_min = float(rho_min)
        self.delta_min = delta_min
        self.eps = float(eps)
        self.grid_dims = grid_dims
        self.exec_spec = exec_spec if exec_spec is not None else ExecSpec()
        self.mesh = mesh
        self.strategy = strategy
        self.window_capacity = int(window_capacity)
        self.batch_cap = int(batch_cap)
        self.stream_options = dict(stream_options or {})
        self._plan: DPCPlan | None = None
        self._points = None             # fitted table (batch mode)
        self._result = None
        self._clustering = None
        self._stream = None             # StreamDPC (stream mode)
        self._mode: str | None = None

    # -------------------------------------------------------------- state
    @property
    def plan(self) -> DPCPlan | None:
        """The resolved plan of the most recent ``fit`` (or the stream's)."""
        return self._plan

    @property
    def result(self):
        """The current :class:`~repro.core.dpc_types.DPCResult`."""
        self._require_fitted()
        return self._result

    @property
    def clustering(self):
        self._require_fitted()
        return self._clustering

    @property
    def labels_(self) -> np.ndarray:
        """Current labels: cluster ids after ``fit``, the latest tick's
        *stable* ids after ``partial_fit``."""
        self._require_fitted()
        if self._mode == "stream":
            return np.asarray(self._stream._last.labels)
        return np.asarray(self._clustering.labels)

    def _require_fitted(self):
        if self._mode is None:
            raise ValueError("engine is unfitted: call fit() or "
                             "partial_fit() first")

    def resolved_delta_min(self) -> float:
        return 2.0 * self.d_cut if self.delta_min is None else self.delta_min

    # ---------------------------------------------------------------- fit
    def fit(self, points) -> "DPCEngine":
        """Batch (or distributed, when the engine holds a mesh) clustering
        of ``points``; re-fitting on a same-shaped input reuses the plan.
        A ``fit`` replaces any streaming state: the next ``partial_fit``
        starts a fresh window seeded from these points (when they fit)."""
        from repro.core.labels import assign_labels

        if self.admission is not None:
            admitted = admit(points, self.admission, where="engine.fit")
            if admitted.points.size == 0:
                raise ValueError(
                    "fit: no points survived admission control "
                    f"({admitted.quarantined} quarantined)")
            points = admitted.points
        points = jnp.asarray(points, jnp.float32)
        self._plan = as_plan(self.exec_spec, points)
        with obs.span("engine.fit", n=int(points.shape[0]),
                      algorithm=self.algorithm,
                      plan=self._plan.describe()) as sp:
            if self.mesh is not None:
                if self.algorithm not in _DISTRIBUTED_OK:
                    raise ValueError(
                        f"distributed fit implements exact DPC "
                        f"({'/'.join(_DISTRIBUTED_OK)}); algorithm="
                        f"{self.algorithm!r} is not distributed — drop the "
                        f"mesh or pick an exact algorithm")
                from repro.distributed.dpc import distributed_dpc
                res = distributed_dpc(points, mesh=self.mesh,
                                      d_cut=self.d_cut,
                                      exec_spec=self._plan,
                                      strategy=self.strategy)
                cl = assign_labels(res, self.rho_min,
                                   self.resolved_delta_min())
            else:
                # one dispatch table: the engine IS dpc_api.cluster over the
                # resolved plan's spec (the driver re-resolves it through the
                # plan cache, so self._plan stays the object used)
                from repro.core.dpc_api import DPCConfig, cluster
                cl, res = cluster(points, DPCConfig(
                    d_cut=self.d_cut, rho_min=self.rho_min,
                    delta_min=self.delta_min, algorithm=self.algorithm,
                    eps=self.eps, grid_dims=self.grid_dims,
                    exec_spec=self._plan.spec))
            sp.sync((res.rho, res.delta, cl.labels))
        self._result = res
        self._clustering = cl
        self._points = points
        self._mode = "batch"
        self._stream = None     # fitted data supersedes any old window
        return self

    # -------------------------------------------------------- partial_fit
    def partial_fit(self, batch):
        """Sliding-window streaming ingest (micro-batched); returns the
        :class:`repro.stream.StreamTick`.  The first call builds the
        stream driver — seeded with the batch-fitted points when ``fit``
        ran first and they fit the window."""
        if self.algorithm != "approxdpc":
            raise ValueError(
                f"partial_fit maintains Approx-DPC state (the stream "
                f"parity contract); algorithm={self.algorithm!r} does not "
                f"stream")
        if self.admission is not None:
            batch = admit(batch, self.admission,
                          where="engine.partial_fit").points
        if np.asarray(batch).size == 0:
            # empty or fully-quarantined batch: a no-op, never a ghost tick
            return self._stream._last if self._stream is not None else None
        tick = None
        with obs.span("engine.partial_fit") as sp:
            if self._stream is None:
                from repro.stream.stream_dpc import StreamDPC, StreamDPCConfig
                cfg = StreamDPCConfig(
                    d_cut=self.d_cut, capacity=self.window_capacity,
                    batch_cap=self.batch_cap, rho_min=self.rho_min,
                    delta_min=self.delta_min, exec_spec=self.exec_spec,
                    **self.stream_options)
                self._stream = StreamDPC(cfg, mesh=self.mesh)
                self._plan = self._stream.plan
                if self._mode == "batch" \
                        and self._points.shape[0] <= self.window_capacity:
                    tick = self._stream.initialize(np.asarray(self._points))
            tick = self._stream.ingest(batch)
            sp.sync(tick.labels)
        self._result = self._stream.result
        self._clustering = self._stream.clustering
        self._mode = "stream"
        return tick

    @property
    def stream(self):
        """The underlying :class:`repro.stream.StreamDPC` (or None)."""
        return self._stream

    # ------------------------------------------------------------ predict
    def predict(self, points):
        """Read-only nearest-label queries over the fitted state, with
        ``StreamService.query`` semantics: returns a
        :class:`repro.stream.QueryResult` of (labels, status) — ``HIT``
        within d_cut of a fitted point, ``MISS_FALLBACK`` to the nearest
        center otherwise, ``MISS`` (-1) only with no centers at all."""
        self._require_fitted()
        from repro.stream.service import (QueryResult, QueryStatus,
                                          nearest_label_query)

        keep = None
        if self.admission is not None:
            admitted = admit(points, self.admission, where="engine.predict")
            points = admitted.points
            if admitted.quarantined:
                keep = admitted.keep
        with obs.span("engine.predict", mode=self._mode) as sp:
            if self._mode == "stream":
                s = self._stream
                ids, pos = s.center_positions()
                out = nearest_label_query(
                    s.be, points, self.d_cut, s.window.device,
                    s._last.labels, ids, pos, pad_multiple=self.batch_cap)
            else:
                labels = np.asarray(self._clustering.labels)
                centers = np.asarray(self._clustering.centers)
                pts_np = np.asarray(self._points)
                c_rows = np.nonzero(centers)[0]
                out = nearest_label_query(
                    self._plan.backend, points, self.d_cut, self._points,
                    labels, labels[c_rows].astype(np.int64), pts_np[c_rows],
                    pad_multiple=self.batch_cap)
            sp.sync(out.labels)
        if keep is not None:
            # re-expand to the caller's row alignment: dropped rows answer
            # (-1, QUARANTINED) instead of silently shifting every result
            labels = np.full(len(keep), -1, np.int64)
            status = np.full(len(keep), int(QueryStatus.QUARANTINED),
                             np.int8)
            labels[keep] = out.labels
            status[keep] = out.status
            out = QueryResult(labels=labels, status=status)
        return out

    # ----------------------------------------------------- decision graph
    def decision_graph(self):
        """(rho_i, delta_i) pairs of the current state (paper Fig. 1)."""
        from repro.core.labels import decision_graph as _dg
        return _dg(self.result)
