"""The planner: resolve an ExecSpec once, reuse it for every call.

``plan(points_spec, exec_spec) -> DPCPlan`` resolves the execution axes a
single time — the :class:`~repro.kernels.backend.KernelBackend` instance,
the layout (and with it the worklist strategy: none for dense, jit-built
for the jnp ring worklists, host-built scalar-prefetch tables for pallas),
the grid-sort requirement, the precision, and the sweep block size — and
hands back a plan object whose primitive wrappers inject all of that into
every kernel call.  Drivers stop re-threading ``backend=/layout=/block=``
kwargs; they take a plan (or an ExecSpec, via :func:`as_plan`) and call
``plan.rho_delta(...)``.

Two caches make repeated ``fit`` / ``partial_fit`` calls cheap:

* the **plan cache**: ``plan()`` memoizes on ``(PointsSpec, ExecSpec)``
  (both frozen/hashable), so a re-fit on same-shaped input gets the *same*
  plan object back — and with it every jit trace keyed off the plan's
  resolved static arguments (no re-trace; asserted in
  tests/test_engine.py).
* the **worklist cache**: each plan owns a small LRU of host-built pallas
  worklists (``kernels.blocksparse.FlatWorklist``), keyed by a content
  fingerprint of the inputs.  A re-fit on the same data skips the host
  worklist rebuild entirely (the jnp worklists are jit-built and already
  ride the jax trace cache).

Block-size resolution (the one documented default): ``spec.block`` when
set; otherwise each backend's native tile default (jnp: 512, pallas: the
Mosaic tile constants in ``kernels.ops``).  This replaces the old silent
per-call-site defaults (``run_scan``'s 512 vs ``dpc_api``'s
``max(block, 256)``); results are block-independent on every backend, so
the resolution is a throughput knob only.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

from repro.kernels import blocksparse
from repro.kernels.backend import KernelBackend, get_backend
from repro.obs import metrics as _obsm
from repro.resilience import faultinject
from repro.resilience.degrade import resolve_backend

from .spec import ExecSpec

__all__ = ["PointsSpec", "DPCPlan", "plan", "as_plan", "plan_cache_info",
           "plan_cache_clear"]

_PLAN = object()          # sentinel: "use the plan's resolved value"
_WL_CACHE_MAX = 8         # host worklists kept per plan (LRU)
_PLAN_CACHE_MAX = 64


@dataclass(frozen=True)
class PointsSpec:
    """Static shape of a point table: what the planner needs to size pads."""

    n: int
    d: int

    @classmethod
    def of(cls, points) -> "PointsSpec":
        return cls(n=int(points.shape[0]), d=int(points.shape[1]))


class DPCPlan:
    """A resolved execution plan: backend + layout + precision + block,
    with primitive wrappers that inject them (and the worklist cache) into
    every kernel call.

    ``worklist_strategy``: ``"dense"`` (no worklists), ``"traced"``
    (jit-built jnp ring worklists — legal inside jit/shard_map), or
    ``"host"`` (host-built pallas scalar-prefetch tables, cached per plan).
    ``grid_sort`` tells drivers the points must be laid out grid-sorted
    (block-sparse pruning quality depends on it).  ``resolved_block`` is
    the count-sweep row-tile size the wrappers actually pass.
    """

    def __init__(self, pspec: PointsSpec | None, spec: ExecSpec):
        self.spec = spec
        self.pspec = pspec
        # plan-time compile probe + graceful degradation chain
        # (pallas -> pallas-interpret -> jnp; see resilience.degrade)
        self.backend: KernelBackend = get_backend(resolve_backend(
            spec.backend, precision=spec.resolved_precision))
        self.backend_name: str = self.backend.name
        self.layout: str = spec.resolved_layout
        self.sparse: bool = spec.sparse
        self.precision: str = spec.resolved_precision
        self.data_axis: str = spec.data_axis
        if self.precision == "bf16" and not self.backend.mxu_dense:
            raise ValueError(
                f"precision='bf16' needs a pallas backend; resolved "
                f"backend is {self.backend_name!r} (the f32 reference)")
        self.block: int | None = spec.block
        # THE resolved sweep row-block (the satellite's one documented
        # default): spec.block when set, else the backend's native
        # count-sweep tile (jnp 512, pallas DENSITY_BLOCK_N).  The
        # count-sweep wrappers below pass exactly this value; the NN /
        # halo wrappers keep per-primitive native defaults when spec.block
        # is unset (their tiles are tuned separately).
        self.resolved_block: int = spec.block if spec.block is not None \
            else self._native_block()
        # drivers consult this to lay points out grid-sorted before the
        # sweep (block-sparse pruning quality depends on the layout)
        self.grid_sort: bool = self.sparse
        if not self.sparse:
            self.worklist_strategy = "dense"
        elif self.backend.worklist_traceable:
            self.worklist_strategy = "traced"
        else:
            self.worklist_strategy = "host"
        self._wl: OrderedDict = OrderedDict()   # host-worklist LRU
        self._cost: dict | None = None          # hlo_cost estimate (lazy)
        self._memory: dict | None = None        # R9 memory block (lazy)

    def _native_block(self) -> int:
        if self.backend.mxu_dense:
            from repro.kernels import ops
            return ops.DENSITY_BLOCK_N
        return 512

    # ------------------------------------------------------- introspection
    def describe(self) -> str:
        shape = "" if self.pspec is None \
            else f" n={self.pspec.n} d={self.pspec.d}"
        return (f"DPCPlan[{self.backend_name}:{self.layout}:"
                f"{self.precision} block={self.block or 'native'} "
                f"worklists={self.worklist_strategy}{shape}]")

    __repr__ = describe

    def worklist_cache_info(self) -> dict:
        return {"entries": len(self._wl), "max": _WL_CACHE_MAX}

    # --------------------------------------------------- kernel telemetry
    def telemetry(self, include_cost: bool = False) -> dict:
        """What this plan resolved to and what its kernels will touch.

        Static fields (resolved axes, grid-sort, pad waste) are free.  The
        ``worklists`` block reflects the plan's live host-worklist cache —
        kept-pair counts and pruned-tile fractions for each cached build.
        ``include_cost=True`` adds a ``launch/hlo_cost`` flop/byte estimate
        from compiling the canonical fused sweep at the plan's shape; the
        estimate is computed once per plan and cached (compiles are not
        free), and host-worklist plans are costed on the dense formulation
        — an upper bound — because flat worklists cannot be built during an
        abstract trace.

        The ``memory`` block carries the R9 estimates the plan was gated
        against: per-``pallas_call`` VMEM/SMEM (block shapes
        double-buffered + scalar prefetch + scratch), the dense
        live-buffer peak over the canonical traces, and the platform
        budget table (``repro.analysis.limits``).  Computed once per plan
        and cached (it traces the canonical targets).
        """
        t: dict = {
            "backend": self.backend_name,
            "layout": self.layout,
            "precision": self.precision,
            "block": self.resolved_block,
            "worklist_strategy": self.worklist_strategy,
            "grid_sort": self.grid_sort,
            "data_axis": self.data_axis,
            "shape": None if self.pspec is None
            else {"n": self.pspec.n, "d": self.pspec.d},
            "pad": self._pad_telemetry(),
            "worklists": self._worklist_telemetry(),
            "memory": self._memory_estimate(),
        }
        if include_cost:
            t["hlo_cost"] = self._cost_estimate()
        return t

    def _pad_telemetry(self) -> dict | None:
        if self.pspec is None:
            return None
        n = self.pspec.n
        # the row tile the sweep actually pads to: block-sparse sweeps use
        # the ring-tile constants, dense sweeps the resolved block
        row_block = blocksparse.BS_BLOCK_N if self.sparse \
            else self.resolved_block
        padded = -(-n // row_block) * row_block
        return {"row_block": row_block, "n": n, "padded_n": padded,
                "pad_waste_frac": round(1.0 - n / padded, 6)}

    def _worklist_telemetry(self) -> dict:
        out: dict = {"strategy": self.worklist_strategy,
                     "cache_entries": len(self._wl),
                     "cache_max": _WL_CACHE_MAX}
        if self._wl:
            out["cached"] = [
                {"n_kept": w.n_kept, "n_total": w.n_total,
                 "pruned_frac": round(w.pruned_frac, 6)}
                for w in self._wl.values()]
        return out

    def _memory_estimate(self) -> dict:
        if self._memory is None:
            from repro.analysis.r9_memory_budget import plan_memory

            try:
                self._memory = plan_memory(self)
            except Exception as e:   # noqa: BLE001 — telemetry, not a gate
                self._memory = {"error": f"{type(e).__name__}: {e}"}
        return self._memory

    def _cost_estimate(self) -> dict:
        if self._cost is not None:
            return self._cost
        if self.pspec is None:
            return {"error": "plan has no bound shape"}
        import jax
        import jax.numpy as jnp

        from repro.launch import hlo_cost

        n, d = self.pspec.n, self.pspec.d
        layout = "block-sparse" if self.worklist_strategy == "traced" \
            else None
        formulation = ("block-sparse" if layout else
                       "dense-upper-bound" if self.sparse else "dense")

        def canonical(pts):
            return self.backend.rho_delta(
                pts, pts, 1.0, block=self.resolved_block,
                precision=self.precision, layout=layout)

        x = jax.ShapeDtypeStruct((n, d), jnp.float32)
        try:
            with blocksparse.suspend_counters():
                compiled = jax.jit(canonical).lower(x).compile()
            cost = dict(hlo_cost.analyze_compiled(compiled))
        except Exception as e:  # backend may not lower on this platform
            return {"error": f"{type(e).__name__}: {e}",
                    "formulation": formulation}
        cost["formulation"] = formulation
        self._cost = cost
        return cost

    # ------------------------------------------------------ value helpers
    def _layout(self, override):
        if override is _PLAN:
            return "block-sparse" if self.sparse else None
        return override

    def _block(self, override):
        return self.block if override is _PLAN else override

    def _ctx(self):
        """Activate this plan's host-worklist cache for the wrapped call."""
        if self.worklist_strategy == "host":
            return blocksparse.worklist_cache(self._wl, max_entries=_WL_CACHE_MAX)
        import contextlib
        return contextlib.nullcontext()

    # -------------------------------------------------- primitive wrappers
    # Thin forms of the two DRIVER-facing primitives with the plan's
    # resolved layout / precision / block injected (each overridable per
    # call for the few sites that intentionally diverge, e.g. dense
    # fallbacks).  Only the primitives the unified drivers actually route
    # through the plan live here; subsystems with bespoke orchestration —
    # the distributed halo phases, the stream repair primitives — consume
    # ``plan.backend`` directly with their own tuned parameters (their
    # call sites say so), rather than carrying dead wrapper surface.

    def _sweep_block(self, override):
        return self.resolved_block if override is _PLAN else override

    def denser_nn(self, x, x_key, y, y_key, *, block=_PLAN, layout=_PLAN):
        faultinject.fire("kernel.dispatch")
        with self._ctx():
            return self.backend.denser_nn(
                x, x_key, y, y_key, block=self._block(block),
                layout=self._layout(layout))

    def rho_delta(self, x, y, d_cut, *, jitter=None, y_sel_slots=None,
                  fallback_interest=None, block=_PLAN, layout=_PLAN,
                  precision=_PLAN):
        faultinject.fire("kernel.dispatch")
        if d_cut is not None:
            # strong-f32 before any jit boundary: a python float traces
            # weak-typed, a numpy scalar strong — one cache entry per
            # spelling otherwise (R7's retrace-churn finding)
            import jax.numpy as jnp

            d_cut = jnp.asarray(d_cut, jnp.float32)
        with self._ctx():
            return self.backend.rho_delta(
                x, y, d_cut, jitter=jitter, y_sel_slots=y_sel_slots,
                fallback_interest=fallback_interest,
                block=self._sweep_block(block),
                precision=self.precision if precision is _PLAN else precision,
                layout=self._layout(layout))


# ------------------------------------------------------------- plan cache
_PLANS: OrderedDict = OrderedDict()

# Cache traffic counts on the repro.obs registry; plan_cache_info() below
# stays the stable read surface.
_M_HITS = _obsm.counter("plan_cache_hits", "plan() memo hits")
_M_MISSES = _obsm.counter("plan_cache_misses", "plan() builds (memo misses)")
_M_EVICTIONS = _obsm.counter(
    "plan_cache_evictions", "plans LRU-evicted at _PLAN_CACHE_MAX")

# plan-time static analysis results, memoized per ExecSpec (the canonical
# traces depend only on the spec's resolved axes, not the point shape)
_ANALYZED: dict = {}

# every plan-time finding lands here, bypassed or not — the escape hatch
# silences the raise, never the telemetry
_M_FINDINGS = _obsm.counter(
    "analysis_findings_total",
    "plan-time static-analyzer findings, labeled by rule and level")

_BYPASS_WARNED = False


def _plan_check(pl: DPCPlan) -> None:
    """Run the static analyzer (``repro.analysis``) over the plan's
    canonical traces + the plan itself, once per spec; raise on
    error-severity findings so a spec that dispatches into a flagged
    kernel path fails at ``plan()``, before any data is touched.

    ``REPRO_ANALYSIS=0`` (also ``off``/``no``) is the debugging escape
    hatch: findings are still computed and recorded on the
    ``analysis_findings_total`` obs counter, and the first bypassed error
    logs one warning — the raise is suppressed, the evidence is not.  The
    internal value ``suspend`` (set by the analyzer's own sweep, which
    builds plans *in order to* analyze them) skips entirely."""
    import os

    global _BYPASS_WARNED

    mode = os.environ.get("REPRO_ANALYSIS", "1").lower()
    if mode == "suspend":
        return
    res = _ANALYZED.get(pl.spec)
    if res is None:
        from repro import analysis

        # tracing the canonical targets may host-build throwaway worklists;
        # suspend the worklist metrics so plan() stays neutral w.r.t. the
        # instrumentation tests assert on (worklist_build_count & co.)
        with blocksparse.suspend_counters():
            res = tuple(analysis.analyze_plan(pl))
        _ANALYZED[pl.spec] = res
        for f in res:
            _M_FINDINGS.inc(rule=f.rule, level=f.severity)
    errors = [f for f in res if f.severity == "error"]
    if not errors:
        return
    if mode in ("0", "off", "no"):
        if not _BYPASS_WARNED:
            import logging

            logging.getLogger("repro.analysis").warning(
                "REPRO_ANALYSIS=%s: bypassing %d error finding(s) for %s "
                "(recorded on analysis_findings_total; this warning is "
                "logged once per process)", mode, len(errors),
                pl.describe())
            _BYPASS_WARNED = True
        return
    from repro.analysis import AnalysisError

    raise AnalysisError(errors)


def plan(points_spec: PointsSpec | tuple | None,
         exec_spec: ExecSpec | None = None) -> DPCPlan:
    """Resolve (points_spec, exec_spec) -> DPCPlan, memoized.

    ``points_spec`` may be a PointsSpec, an ``(n, d)`` tuple, or ``None``
    for shape-independent plans (e.g. a stream driver before its window
    exists).  Same inputs return the *same object*, carrying its caches.
    """
    if isinstance(points_spec, tuple):
        points_spec = PointsSpec(*points_spec)
    spec = exec_spec if exec_spec is not None else ExecSpec()
    key = (points_spec, spec)
    hit = _PLANS.get(key)
    if hit is not None:
        _M_HITS.inc()
        _PLANS.move_to_end(key)
        return hit
    _M_MISSES.inc()
    pl = DPCPlan(points_spec, spec)
    _plan_check(pl)
    _PLANS[key] = pl
    while len(_PLANS) > _PLAN_CACHE_MAX:
        _PLANS.popitem(last=False)
        _M_EVICTIONS.inc()
    return pl


def as_plan(exec_spec, points=None) -> DPCPlan:
    """Coerce a driver's ``exec_spec`` argument (ExecSpec | DPCPlan | None)
    into a plan for ``points`` (re-planning a shape-mismatched plan's spec;
    the plan cache makes that free)."""
    pspec = None if points is None else PointsSpec.of(points)
    if isinstance(exec_spec, DPCPlan):
        if pspec is None or exec_spec.pspec == pspec:
            return exec_spec
        return plan(pspec, exec_spec.spec)
    if exec_spec is not None and not isinstance(exec_spec, ExecSpec):
        raise TypeError(
            f"exec_spec must be an ExecSpec, DPCPlan or None, got "
            f"{type(exec_spec).__name__} (legacy backend=/layout=/block= "
            f"kwargs moved onto repro.engine.ExecSpec)")
    return plan(pspec, exec_spec)


def plan_cache_info() -> dict:
    return {"hits": int(_M_HITS.value()),
            "misses": int(_M_MISSES.value()),
            "evictions": int(_M_EVICTIONS.value()),
            "entries": len(_PLANS)}


def plan_cache_clear() -> None:
    """Drop every cached plan and zero the cache counters (registry
    families included)."""
    _PLANS.clear()
    for m in (_M_HITS, _M_MISSES, _M_EVICTIONS):
        m._reset()
