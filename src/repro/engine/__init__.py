"""repro.engine: one execution plan, one engine.

The public surface of the unified execution model:

* :class:`ExecSpec` — the frozen ``backend x layout x precision x block x
  data_axis`` execution spec accepted by every subsystem entry point
  (batch ``run_*`` / ``compute_dpc``, ``distributed_dpc``, ``StreamDPC``,
  DPC-KV ``compress_kv``).
* :func:`plan` / :class:`DPCPlan` — the planner: resolve a spec once
  (backend instance, worklist strategy, grid sort, pad shapes) and reuse
  the plan — with its jit traces and host-built pallas worklists — across
  repeated calls.
* :class:`DPCEngine` — the facade: ``fit(points)`` (batch or distributed
  when given a mesh), ``partial_fit(batch)`` (sliding-window streaming),
  ``predict(points)`` (read-only nearest-label queries with the serve
  layer's HIT / MISS_FALLBACK semantics), ``decision_graph()``.

The four legacy configs (``DPCConfig``, ``DistDPCConfig``,
``StreamDPCConfig``, ``DPCKVConfig``) remain as thin shims whose old
``backend=`` / ``layout=`` / ``block=`` kwargs fold into one ExecSpec with
a DeprecationWarning.
"""
from .planner import (DPCPlan, PointsSpec, as_plan, plan, plan_cache_clear,
                      plan_cache_info)
from .spec import ExecSpec

__all__ = ["ExecSpec", "DPCPlan", "PointsSpec", "plan", "as_plan",
           "plan_cache_info", "plan_cache_clear", "DPCEngine"]


def __getattr__(name):
    # DPCEngine imports the subsystem drivers, which themselves import the
    # planner above — loading it lazily keeps `repro.engine` importable
    # from inside those drivers without a cycle.
    if name == "DPCEngine":
        from .dpc_engine import DPCEngine
        return DPCEngine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
