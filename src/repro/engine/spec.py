"""ExecSpec: one execution plan for every DPC subsystem.

After the kernel layer grew a backend axis (PR 1), a precision axis (PR 3)
and a layout axis (PR 4), each subsystem re-threaded those knobs through its
own config (``DPCConfig`` / ``DistDPCConfig`` / ``StreamDPCConfig`` /
``DPCKVConfig``) and its own ``run_*`` kwargs.  ``ExecSpec`` is the single
carrier for the *how-to-execute* axes —

    backend x layout x precision x block x data_axis

— validated eagerly at construction (unknown names and impossible combos
fail here, not deep inside the kernel layer), resolved **once** by
:func:`repro.engine.planner.plan` into a :class:`~repro.engine.planner.DPCPlan`
that every subsystem entry point accepts.  The four legacy configs survive
as thin shims that build one of these.
"""
from __future__ import annotations

from dataclasses import dataclass, fields

from repro.kernels.backend import available_backends

__all__ = ["ExecSpec", "LAYOUTS", "PRECISIONS"]

LAYOUTS = ("dense", "block-sparse")
PRECISIONS = ("f32", "bf16")


@dataclass(frozen=True)
class ExecSpec:
    """The execution axes shared by batch / distributed / stream / serve.

    * ``backend`` — kernel backend name (``repro.kernels.backend`` registry:
      ``"jnp"``, ``"pallas"``, ``"pallas-interpret"``); ``None``/``"auto"``
      selects by platform (pallas on TPU, jnp elsewhere).
    * ``layout`` — ``"dense"`` (all-pairs tile sweep, the default) or
      ``"block-sparse"`` (grid-pruned worklist mode).
    * ``precision`` — ``"f32"`` (default) or ``"bf16"`` (mixed-precision
      fused ``rho_delta``: bf16 inner product, f32 winner refinement;
      requires a pallas backend — validated here when the backend is
      explicit, at plan time when auto-detected).
    * ``block`` — row-tile size for the sweep primitives.  ``None`` (the
      default) resolves to each backend's native tile default at plan time
      (jnp: 512; pallas: the Mosaic tile constants) — ONE documented
      resolution, replacing the old per-call-site defaults (``run_scan``'s
      512 vs ``dpc_api``'s ``max(block, 256)``).  Results are independent
      of ``block`` on every backend (order-independent accumulators,
      lexicographic NN tie-breaks); only throughput changes.
    * ``data_axis`` — mesh axis name for the sharded paths (distributed
      phases, sharded stream ingest).

    Frozen and hashable, so a spec can ride inside jitted-static configs
    (DPC-KV) and key the plan cache.
    """

    backend: str | None = None
    layout: str | None = None
    precision: str | None = None
    block: int | None = None
    data_axis: str = "data"

    def __post_init__(self):
        if self.backend not in (None, "auto") \
                and self.backend not in available_backends():
            raise ValueError(
                f"unknown kernel backend {self.backend!r}; available: "
                f"{available_backends()} (or None/'auto' to detect)")
        if self.layout not in (None, *LAYOUTS):
            raise ValueError(f"unknown layout {self.layout!r}; "
                             f"expected one of {LAYOUTS}")
        if self.precision not in (None, *PRECISIONS):
            raise ValueError(f"unknown precision {self.precision!r}; "
                             f"expected one of {PRECISIONS}")
        if self.precision == "bf16" and self.backend == "jnp":
            raise ValueError(
                "precision='bf16' needs a pallas backend: the jnp backend "
                "is the f32 direct-difference reference")
        if self.block is not None and (not isinstance(self.block, int)
                                       or self.block < 1):
            raise ValueError(f"block must be a positive int or None, "
                             f"got {self.block!r}")
        if not self.data_axis or not isinstance(self.data_axis, str):
            raise ValueError(f"data_axis must be a non-empty mesh-axis "
                             f"name, got {self.data_axis!r}")

    # ------------------------------------------------------------ helpers
    @property
    def sparse(self) -> bool:
        return self.layout == "block-sparse"

    @property
    def resolved_layout(self) -> str:
        return self.layout or "dense"

    @property
    def resolved_precision(self) -> str:
        return self.precision or "f32"

    @classmethod
    def parse(cls, text: str, **overrides) -> "ExecSpec":
        """Build a spec from the uniform CLI form ``backend:layout:precision``
        (trailing segments optional; empty / ``-`` / ``auto`` segments mean
        default) — e.g. ``jnp:block-sparse``, ``pallas::bf16``, ``:dense``.

        Malformed forms fail here with the *offending segment* named and
        that axis's valid values enumerated (plus a segment-order hint when
        the value belongs to a different axis), rather than falling through
        to the generic constructor errors.
        """
        axes = ("backend", "layout", "precision")
        valids = {"backend": tuple(available_backends()),
                  "layout": LAYOUTS, "precision": PRECISIONS}
        parts = (text or "").split(":")
        if len(parts) > 3:
            detail = "; ".join(f"{a}: {', '.join(valids[a])}" for a in axes)
            raise ValueError(
                f"--exec takes at most 3 ':'-separated segments "
                f"(backend:layout:precision), got {len(parts)} in {text!r} "
                f"— valid values per segment: {detail}")
        parts += [""] * (3 - len(parts))
        norm = [None if p in ("", "-", "auto") else p for p in parts]
        for pos, (axis, value) in enumerate(zip(axes, norm), start=1):
            if value is None or value in valids[axis]:
                continue
            other = next((a for a in axes
                          if a != axis and value in valids[a]), None)
            hint = (f" ({value!r} is a {other} — segment order is "
                    f"backend:layout:precision)") if other else ""
            raise ValueError(
                f"--exec segment {pos} ({axis}) got {value!r}; valid "
                f"{axis} values: {', '.join(valids[axis])}, or "
                f"empty/'-'/'auto' for the default{hint}")
        return cls(backend=norm[0], layout=norm[1], precision=norm[2],
                   **overrides)

    def replace(self, **kw) -> "ExecSpec":
        from dataclasses import replace
        return replace(self, **kw)

    def describe(self) -> str:
        return (f"{self.backend or 'auto'}:{self.resolved_layout}:"
                f"{self.resolved_precision}")


# per-field "not set" sentinel for legacy kwargs: every exec axis is
# Optional except data_axis, whose unset spelling is its default name
_UNSET = {"data_axis": "data"}


def merge_legacy(exec_spec: ExecSpec | None, *, owner: str,
                 **legacy) -> ExecSpec:
    """Fold legacy per-config exec kwargs into one ExecSpec (shim support).

    ``legacy`` maps ExecSpec field names to the values a legacy config was
    constructed with (field-specific unset sentinel = not set: ``None``
    for most axes, ``"data"`` for ``data_axis``).  Passing both an
    ``exec_spec`` and a conflicting legacy kwarg is an error — fail fast
    rather than silently prefer one.  Emits a DeprecationWarning naming
    the owner config when any legacy kwarg is in use.
    """
    import warnings

    used = {k: v for k, v in legacy.items() if v != _UNSET.get(k)}
    if not used:
        return exec_spec if exec_spec is not None else ExecSpec()
    names = sorted(used)
    # stacklevel: warn -> merge_legacy -> __post_init__ -> generated
    # __init__ -> the user's construction site
    warnings.warn(
        f"{owner}({', '.join(names)}=...) is deprecated: build a "
        f"repro.engine.ExecSpec({', '.join(names)}=...) and pass it as "
        f"exec_spec= (see repro.engine)", DeprecationWarning, stacklevel=4)
    if exec_spec is not None:
        clash = [k for k, v in used.items()
                 if getattr(exec_spec, k) != _UNSET.get(k)
                 and getattr(exec_spec, k) != v]
        if clash:
            raise ValueError(f"{owner}: {clash} given both on exec_spec and "
                             f"as legacy kwargs with different values")
        return exec_spec.replace(**used)
    valid = {f.name for f in fields(ExecSpec)}
    assert set(used) <= valid, f"unknown legacy exec kwargs: {used}"
    return ExecSpec(**used)
