"""R4 pallas-legality: static checks on every ``pallas_call`` instantiation.

Three invariants every kernel launch in this tree is supposed to hold, all
checkable from the ``grid_mapping`` the eqn params carry (jax 0.4.37:
``GridMapping`` with ``grid``, ``block_mappings`` — each a ``BlockMapping``
with ``block_shape`` / ``array_shape_dtype`` / SMEM-typed
``index_map_avals`` — ``num_index_operands``, ``num_dynamic_grid_bounds``):

* **grid/block divisibility** — callers pad arrays to block multiples
  before launching (``_pad_inf`` / ``_pad_rows`` / the ops pads); a block
  mapping whose array extent is not a block multiple means a missed pad —
  out-of-bounds tile reads on TPU, silent zero-fill differences between
  interpret and compiled modes.
* **SMEM scalar-prefetch placement** — scalar-prefetch operands
  (``num_index_operands``: the worklist meta tables driving the 1-D sweep
  grid) must be SMEM references in the index-map avals, and small enough
  to live there; a worklist table accidentally routed through VMEM/HBM
  block mappings would compile on the interpreter and fail (or crawl) on
  Mosaic.
* **static grid** — host-built worklists size the launch grid
  (``grid = (n_kept,)``); ``num_dynamic_grid_bounds > 0`` means a traced
  value reached the grid, i.e. a worklist was constructed under a tracer
  (the ``_require_host`` contract, enforced here statically too).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from .rules import Finding, Rule, register_rule

RULE_NAME = "R4-pallas-legality"


def _check_pallas_eqn(target: str, site: Any) -> list[Finding]:
    eqn = site.eqn
    gm = eqn.params.get("grid_mapping")
    out: list[Finding] = []
    where = site.where + "/pallas_call"
    name_info = eqn.params.get("name_and_src_info")
    kernel = str(name_info) if name_info is not None else "<kernel>"

    def finding(msg: str) -> None:
        out.append(Finding(rule=RULE_NAME, severity="error", target=target,
                           message=f"{kernel}: {msg}", where=where))

    if gm is None:
        finding("pallas_call eqn carries no grid_mapping param (jax "
                "version drift? — re-probe the eqn layout)")
        return out

    if int(getattr(gm, "num_dynamic_grid_bounds", 0) or 0) > 0:
        finding("dynamic grid bounds: a traced value sizes the launch "
                "grid, i.e. a host-built worklist was constructed under "
                "a tracer (_require_host contract)")

    grid = tuple(getattr(gm, "grid", ()) or ())
    for g in grid:
        if isinstance(g, int) and g < 1:
            finding(f"degenerate grid {grid}: every launch dimension "
                    f"must be >= 1")
            break

    for bm in tuple(getattr(gm, "block_mappings", ()) or ()):
        shape = tuple(getattr(getattr(bm, "array_shape_dtype", None),
                              "shape", ()) or ())
        block = tuple(getattr(bm, "block_shape", ()) or ())
        origin = getattr(bm, "origin", "?")
        if len(shape) != len(block):
            continue                    # mapped/squeezed dims: skip
        for dim, (b, s) in enumerate(zip(block, shape)):
            if isinstance(b, int) and b > 0 and isinstance(s, int) \
                    and s % b != 0:
                finding(f"block mapping for {origin}: array extent "
                        f"{s} (dim {dim}) is not a multiple of block "
                        f"{b} — caller missed the pad-to-block-multiple "
                        f"contract")

    n_idx = int(getattr(gm, "num_index_operands", 0) or 0)
    if n_idx:
        from . import limits

        smem_budget = limits.limits_for_eqn(eqn).smem_bytes
        avals = tuple(getattr(gm, "index_map_avals", ()) or ())
        # index_map avals = grid indices followed by the prefetch refs
        prefetch = avals[len(avals) - n_idx:]
        for aval in prefetch:
            text = str(aval).lower()
            if "smem" not in text:
                finding(f"scalar-prefetch operand {aval} is not an SMEM "
                        f"reference — worklist meta tables must prefetch "
                        f"into SMEM, not ride the block mappings")
            inner = getattr(aval, "inner_aval", None)
            shape = tuple(getattr(aval, "shape", ()) or
                          getattr(inner, "shape", ()) or ())
            dtype = getattr(aval, "dtype", None) or \
                getattr(inner, "dtype", None)
            size = 1
            for s in shape:
                size *= int(s)
            nbytes = size * int(getattr(dtype, "itemsize", 4) or 4)
            if nbytes > smem_budget:
                finding(f"scalar-prefetch operand {aval} is {nbytes} "
                        f"bytes — over the "
                        f"{limits.limits_for_eqn(eqn).platform} SMEM "
                        f"budget of {smem_budget} bytes (shared table "
                        f"with R9; REPRO_LIMIT_SMEM_BYTES overrides)")
    return out


@dataclass(frozen=True)
class PallasLegalityRule(Rule):
    name: str = RULE_NAME
    description: str = ("pallas_call launches: block sizes divide padded "
                        "array extents, scalar-prefetch tables are SMEM "
                        "refs, grids are host-static")
    kind: str = "jaxpr"

    def check_jaxpr(self, target: str, closed_jaxpr: Any) -> list[Finding]:
        from .walker import iter_sites

        out: list[Finding] = []
        for site in iter_sites(closed_jaxpr):
            if site.eqn.primitive.name == "pallas_call":
                out.extend(_check_pallas_eqn(target, site))
        return out


register_rule(PallasLegalityRule())
