"""R5 spec-coverage: the ExecSpec axes, the planner dispatch and the test
parametrizations stay mutually exhaustive.

The failure mode this rule exists for: someone adds an axis value (a new
backend, a new precision) and it ships reachable-but-untested — the spec
validation accepts it, the planner dispatches it somewhere, and no parity
test ever parametrizes over it.  R5 cross-checks four things and fails if
any drift:

1. **pinned axis snapshot** — the live ``available_backends()`` /
   ``LAYOUTS`` / ``PRECISIONS`` must equal the snapshot reviewed into this
   rule.  Adding an axis value therefore *requires* touching this file,
   which is the review hook for the other three checks.
2. **validation-table consistency** — for the full explicit cross product,
   ``ExecSpec`` construction and ``plan()`` resolution must succeed/fail
   exactly where the documented validity table says (bf16 needs an
   ``mxu_dense`` backend; everything else is legal).
3. **planner dispatch totality** — every valid plan must land on exactly
   the documented ``worklist_strategy`` (dense / traced / host from the
   backend's ``worklist_traceable`` flag) and ``grid_sort`` contract.
4. **parity-test coverage** — every axis value literal must appear in
   ``tests/`` at least once (the parametrized parity suites), so a new
   value cannot ship without a test naming it.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Any

from .rules import Finding, Rule, register_rule

RULE_NAME = "R5-spec-coverage"

# The reviewed snapshot (check 1).  When an axis grows, update this tuple
# AND the validity logic below AND the parity-test parametrizations —
# that is the point.
KNOWN_BACKENDS = ("jnp", "pallas", "pallas-interpret")
KNOWN_LAYOUTS = ("dense", "block-sparse")
KNOWN_PRECISIONS = ("f32", "bf16")


def _expected_spec_valid(backend: str, layout: str,
                         precision: str) -> bool:
    """ExecSpec construction-time validity (backend-explicit combos)."""
    del layout
    return not (precision == "bf16" and backend == "jnp")


def _expected_plan_valid(be: Any, precision: str) -> bool:
    """plan()-time validity for a resolved backend instance."""
    return precision != "bf16" or be.mxu_dense


@dataclass(frozen=True)
class SpecCoverageRule(Rule):
    name: str = RULE_NAME
    description: str = ("ExecSpec axes, validation table, planner dispatch "
                        "and parity-test parametrizations cross-checked "
                        "for exhaustiveness")
    kind: str = "project"

    def check_project(self, repo_root: str) -> list[Finding]:
        from repro.engine.planner import plan
        from repro.engine.spec import ExecSpec, LAYOUTS, PRECISIONS
        from repro.kernels.backend import available_backends, get_backend

        out: list[Finding] = []

        def finding(msg: str, where: str = "") -> None:
            out.append(Finding(rule=RULE_NAME, severity="error",
                               target="spec-coverage", message=msg,
                               where=where))

        # 1. pinned snapshot
        for label, live, known in (
                ("backends", tuple(available_backends()), KNOWN_BACKENDS),
                ("layouts", tuple(LAYOUTS), KNOWN_LAYOUTS),
                ("precisions", tuple(PRECISIONS), KNOWN_PRECISIONS)):
            if set(live) != set(known):
                finding(f"{label} changed: live {sorted(live)} vs reviewed "
                        f"snapshot {sorted(known)} — update "
                        f"analysis/r5_coverage.py (validity table + "
                        f"snapshot) and the parity-test parametrizations "
                        f"together", where="r5_coverage.py")

        # 2 + 3. validation table and dispatch, over the explicit product.
        # Plan-time jaxpr analysis is suspended for these probe plans:
        # AnalysisError subclasses ValueError and would read as validity
        # drift here, and the sweep already analyzes every combo's traces.
        # ("suspend", not "0": the 0/off escape hatch now still computes
        # findings for telemetry — probe plans must skip entirely.)
        prev = os.environ.get("REPRO_ANALYSIS")
        os.environ["REPRO_ANALYSIS"] = "suspend"
        try:
            self._check_table(plan, ExecSpec, get_backend, finding)
        finally:
            if prev is None:
                os.environ.pop("REPRO_ANALYSIS", None)
            else:
                os.environ["REPRO_ANALYSIS"] = prev

        # 4. every axis value appears in the test suites
        tests_dir = os.path.join(repo_root, "tests")
        corpus = ""
        if os.path.isdir(tests_dir):
            for fname in sorted(os.listdir(tests_dir)):
                if fname.endswith(".py"):
                    with open(os.path.join(tests_dir, fname),
                              encoding="utf-8") as fh:
                        corpus += fh.read()
        for value in (*KNOWN_BACKENDS, *KNOWN_LAYOUTS, *KNOWN_PRECISIONS):
            if f'"{value}"' not in corpus and f"'{value}'" not in corpus:
                finding(f"axis value {value!r} appears in no test under "
                        f"tests/ — parametrize a parity test over it "
                        f"before shipping", where="tests/")
        return out

    @staticmethod
    def _check_table(plan: Any, ExecSpec: Any, get_backend: Any,
                     finding: Any) -> None:
        for backend in KNOWN_BACKENDS:
            for layout in KNOWN_LAYOUTS:
                for precision in KNOWN_PRECISIONS:
                    combo = f"{backend}:{layout}:{precision}"
                    try:
                        spec = ExecSpec(backend=backend, layout=layout,
                                        precision=precision)
                        spec_ok = True
                    except ValueError:
                        spec_ok = False
                    if spec_ok != _expected_spec_valid(backend, layout,
                                                       precision):
                        finding(f"ExecSpec validation drift for {combo}: "
                                f"construction "
                                f"{'succeeded' if spec_ok else 'failed'} "
                                f"but the documented table says otherwise",
                                where=combo)
                        continue
                    if not spec_ok:
                        continue
                    be = get_backend(backend)
                    try:
                        pl = plan(None, spec)
                        plan_ok = True
                    except ValueError:
                        plan_ok = False
                    if plan_ok != _expected_plan_valid(be, precision):
                        finding(f"plan() validity drift for {combo}: "
                                f"resolution "
                                f"{'succeeded' if plan_ok else 'failed'} "
                                f"but bf16-needs-mxu_dense says otherwise",
                                where=combo)
                        continue
                    if not plan_ok:
                        continue
                    want = "dense" if layout != "block-sparse" else (
                        "traced" if be.worklist_traceable else "host")
                    if pl.worklist_strategy != want:
                        finding(f"planner dispatch drift for {combo}: "
                                f"worklist_strategy="
                                f"{pl.worklist_strategy!r}, documented "
                                f"table says {want!r}", where=combo)
                    if pl.grid_sort != (layout == "block-sparse"):
                        finding(f"planner dispatch drift for {combo}: "
                                f"grid_sort={pl.grid_sort!r} but "
                                f"grid_sort contract is sparse-only",
                                where=combo)


register_rule(SpecCoverageRule())
