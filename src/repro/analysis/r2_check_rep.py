"""R2 check-rep-audit: every ``check_rep=False`` shard_map body carries an
explicit :func:`repro.analysis.audit.audit_check_rep` annotation.

``check_rep=False`` switches off the one JAX mechanism that would notice a
shard body producing non-replicated values where replication is claimed —
and this tree runs *every* kernel-backed shard body that way, because
``pallas_call`` has no replication rule.  The audit decorator records the
human argument for why that is safe (which collectives make the body's
outputs well-defined per member); R2 makes the annotation mandatory, so a
new ``check_rep=False`` site cannot ship with the argument still in the
author's head.

The check is a source scan (the jaxpr has no trace of where a body
function was defined): for each ``shard_map(...)`` call whose
``check_rep`` keyword is anything but a literal ``True`` (absent =
default True = fine), resolve the body argument to its ``def`` —

* a function defined in an enclosing scope, or
* the nearest preceding assignment ``body = _make_xyz(...)`` whose factory
  is a module-level function returning an inner ``def`` (the
  ``distributed/dpc.py`` phase-factory idiom)

— and require an ``audit_check_rep`` decorator on it.  Unresolvable bodies
are findings too (conservative: if the scanner cannot see the def, a
reviewer probably cannot either).

``src/repro/analysis`` itself is excluded: the analyzer builds throwaway
shard_map probes of *other* modules' bodies (the R1 gate, the sweep
targets); those are analysis inputs, not production shard bodies.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass
from typing import Any

from .rules import Finding, Rule, register_rule

RULE_NAME = "R2-check-rep-audit"
_DECORATOR = "audit_check_rep"


def _is_shard_map(call: ast.Call) -> bool:
    f = call.func
    return (isinstance(f, ast.Name) and f.id == "shard_map") or \
           (isinstance(f, ast.Attribute) and f.attr == "shard_map")


def _check_rep_maybe_false(call: ast.Call) -> bool:
    """True when the call's check_rep could evaluate to False at runtime."""
    for kw in call.keywords:
        if kw.arg == "check_rep":
            v = kw.value
            return not (isinstance(v, ast.Constant) and v.value is True)
    return False               # absent -> default True


def _has_audit_decorator(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        node = dec.func if isinstance(dec, ast.Call) else dec
        if isinstance(node, ast.Name) and node.id == _DECORATOR:
            return True
        if isinstance(node, ast.Attribute) and node.attr == _DECORATOR:
            return True
    return False


@dataclass
class _ScopeInfo:
    node: object                       # Module | FunctionDef
    defs: dict                         # name -> FunctionDef (direct children)
    assigns: list                      # (lineno, name, value-expr)


def _scope_infos(tree: ast.Module) -> dict:
    """Map every FunctionDef/Module to its direct child defs + assigns."""
    parents: dict = {}

    def visit(node: Any, owner: Any) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                parents.setdefault(owner, []).append(("def", child))
                visit(child, child)
            else:
                if isinstance(child, ast.Assign) and len(child.targets) == 1 \
                        and isinstance(child.targets[0], ast.Name):
                    parents.setdefault(owner, []).append(
                        ("assign", (child.lineno, child.targets[0].id,
                                    child.value)))
                visit(child, owner)

    visit(tree, tree)
    infos: dict = {}
    for owner, items in parents.items():
        defs: dict[str, ast.FunctionDef] = {}
        assigns: list[tuple[int, str, Any]] = []
        for kind, payload in items:
            if kind == "def":
                defs.setdefault(payload.name, payload)
            else:
                assigns.append(payload)
        infos[owner] = _ScopeInfo(node=owner, defs=defs, assigns=assigns)
    return infos


def _factory_inner_def(factory: ast.FunctionDef) -> ast.FunctionDef | None:
    """The inner def a factory returns (``def f(): ... ; return f``)."""
    inner = {n.name: n for n in factory.body
             if isinstance(n, ast.FunctionDef)}
    for node in ast.walk(factory):
        if isinstance(node, ast.Return) and isinstance(node.value, ast.Name):
            if node.value.id in inner:
                return inner[node.value.id]
    return None


def _resolve_body(arg: Any, scope_stack: list, infos: dict,
                  call_lineno: int) -> ast.FunctionDef | None:
    """Resolve a shard_map body expression to its FunctionDef, or None."""
    if not isinstance(arg, ast.Name):
        return None
    name = arg.id
    # 1. a def visible in an enclosing scope
    for scope in reversed(scope_stack):
        info = infos.get(scope)
        if info and name in info.defs:
            return info.defs[name]
    # 2. nearest preceding `name = factory(...)` in an enclosing scope,
    #    where factory is a resolvable def returning an inner def
    for scope in reversed(scope_stack):
        info = infos.get(scope)
        if not info:
            continue
        cands = [(ln, val) for ln, nm, val in info.assigns
                 if nm == name and ln <= call_lineno]
        if not cands:
            continue
        _, val = max(cands, key=lambda c: c[0])
        if isinstance(val, ast.Call) and isinstance(val.func, ast.Name):
            for fscope in reversed(scope_stack):
                finfo = infos.get(fscope)
                if finfo and val.func.id in finfo.defs:
                    return _factory_inner_def(finfo.defs[val.func.id])
        return None
    return None


def scan_module(path: str, rel: str) -> list[Finding]:
    with open(path, encoding="utf-8") as fh:
        tree = ast.parse(fh.read(), filename=path)
    infos = _scope_infos(tree)
    findings: list[Finding] = []

    def visit(node: Any, stack: list) -> None:
        for child in ast.iter_child_nodes(node):
            new_stack = stack + [child] \
                if isinstance(child, ast.FunctionDef) else stack
            if isinstance(child, ast.Call) and _is_shard_map(child) \
                    and _check_rep_maybe_false(child):
                where = f"{rel}:{child.lineno}"
                body = child.args[0] if child.args else None
                fn = _resolve_body(body, stack, infos, child.lineno)
                if fn is None:
                    findings.append(Finding(
                        rule=RULE_NAME, severity="error", target=rel,
                        message=("shard_map with check_rep that may be "
                                 "False has a body this scanner cannot "
                                 "resolve to a def — restructure so the "
                                 "body is a named local function (or a "
                                 "factory-returned one) and annotate it "
                                 "with @audit_check_rep"),
                        where=where))
                elif not _has_audit_decorator(fn):
                    findings.append(Finding(
                        rule=RULE_NAME, severity="error", target=rel,
                        message=(f"shard_map body `{fn.name}` runs with "
                                 f"check_rep=False but carries no "
                                 f"@audit_check_rep annotation — record "
                                 f"why the body is replication-safe "
                                 f"(see repro.analysis.audit)"),
                        where=where))
            visit(child, new_stack)

    visit(tree, [tree])
    return findings


@dataclass(frozen=True)
class CheckRepAuditRule(Rule):
    name: str = RULE_NAME
    description: str = ("every check_rep=False shard_map body must carry an "
                        "explicit @audit_check_rep replication-safety "
                        "annotation")
    kind: str = "project"

    def check_project(self, repo_root: str) -> list[Finding]:
        src = os.path.join(repo_root, "src", "repro")
        skip = os.path.join(src, "analysis")
        findings: list[Finding] = []
        for dirpath, _dirnames, filenames in os.walk(src):
            if dirpath.startswith(skip):
                continue
            for fname in sorted(filenames):
                if not fname.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fname)
                rel = os.path.relpath(path, repo_root)
                findings.extend(scan_module(path, rel))
        return findings


register_rule(CheckRepAuditRule())
