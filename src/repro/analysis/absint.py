"""Abstract interpretation over pallas grids and jaxpr buffers (R6/R9 core).

Three analyses, each grounded in how the pinned jax 0.4.37 actually lowers
this repo's kernels (probed, not guessed):

* an **affine domain over symbolic grid indices** —
  :func:`eval_index_map` evaluates a ``BlockSpec`` index-map jaxpr with the
  grid indices as symbolic unit affines and the scalar-prefetch operands as
  opaque table references; :func:`visit_verdict` then decides whether the
  output block coordinates are visited ``once`` over the whole grid,
  definitely ``revisit`` (some live grid axis never reaches any output
  coordinate — ``gather_nn``'s doubled column grid), are ``data``-dependent
  (the worklist sweep's ``mt[0, p]`` prefetch-table read), or ``unknown``.
* a **kernel-body write classifier** — :func:`classify_kernel_writes` runs
  a forward dataflow over the kernel jaxpr (reads are ``get`` eqns, writes
  are ``swap`` eqns; ``pl.when`` lowers to ``cond``) and classifies every
  write to an *output* ref: a read-modify-write through associative
  accumulate/merge ops only (``rmw-clean`` — safe on revisited blocks), an
  RMW whose old value crossed a non-whitelisted op (``rmw-dirty``), an
  overwrite under a grid/prefetch-pure guard (``overwrite-guarded`` — the
  first-visit init idiom), or a plain ``overwrite`` (lost-update on any
  revisited block: the R6 finding).
* a **live-buffer walker** — :func:`live_buffer_peak` bounds the
  simultaneously-live buffer bytes of a traced computation (last-use
  liveness over the eqn sequence, sub-jaxpr peaks stacked on the caller's
  live set), the dense-path half of R9's memory budget.

Everything here is pure jaxpr introspection: nothing executes, nothing
compiles, no device is touched.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Union

from jax._src import core as jcore

from .walker import _CALL_JAXPR_PARAMS, sub_jaxprs, unwrap

__all__ = [
    "Affine", "TOP", "DATA", "VInfo", "WriteSite",
    "eval_index_map", "visit_verdict", "classify_kernel_writes",
    "live_buffer_peak", "pallas_memory",
]

# enumeration ceiling for the exact small-grid visit check
ENUM_CAP = 1 << 16


# ------------------------------------------------------------ affine domain
@dataclasses.dataclass(frozen=True)
class Affine:
    """``const + sum(coeff_a * grid_index_a)`` — an affine map of the grid."""

    const: int
    coeffs: tuple[tuple[int, int], ...] = ()   # sorted (axis, coeff != 0)

    @property
    def axes(self) -> tuple[int, ...]:
        return tuple(a for a, _ in self.coeffs)

    def coeff(self, axis: int) -> int:
        for a, c in self.coeffs:
            if a == axis:
                return c
        return 0

    def eval(self, point: tuple[int, ...]) -> int:
        return self.const + sum(c * point[a] for a, c in self.coeffs)


# lattice companions of Affine: TOP = not affine (e.g. rem-folded column
# maps), DATA = derived from a scalar-prefetch table read, _REF = the
# table reference itself
TOP = "top"
DATA = "data"
_REF = "ref"

AbsVal = Union[Affine, str]


def _aff_add(a: Affine, b: Affine, sign: int = 1) -> Affine:
    coeffs = dict(a.coeffs)
    for ax, c in b.coeffs:
        coeffs[ax] = coeffs.get(ax, 0) + sign * c
    return Affine(a.const + sign * b.const,
                  tuple(sorted((ax, c) for ax, c in coeffs.items() if c)))


def _aff_scale(a: Affine, k: int) -> Affine:
    return Affine(a.const * k,
                  tuple(sorted((ax, c * k) for ax, c in a.coeffs if c * k)))


def _as_const(val: AbsVal) -> int | None:
    if isinstance(val, Affine) and not val.coeffs:
        return val.const
    return None


def _lit_val(v: Any) -> AbsVal:
    try:
        x = v.val
        if hasattr(x, "item"):
            x = x.item()
        if isinstance(x, bool):
            return TOP
        return Affine(int(x))
    except (TypeError, ValueError, AttributeError):
        return TOP


def _eval_jaxpr(jaxpr: Any, invals: list[AbsVal]) -> list[AbsVal]:
    jaxpr = unwrap(jaxpr)
    if len(jaxpr.invars) != len(invals):
        return [TOP] * len(jaxpr.outvars)
    env: dict[Any, AbsVal] = dict(zip(jaxpr.invars, invals))
    for v in jaxpr.constvars:
        env[v] = TOP

    def read(v: Any) -> AbsVal:
        if isinstance(v, jcore.Literal):
            return _lit_val(v)
        return env.get(v, TOP)

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        vals = [read(v) for v in eqn.invars]
        outs: list[AbsVal] | None = None
        if any(v is _REF for v in vals) or any(v is DATA for v in vals):
            # a scalar-prefetch table read (get on a ref), or anything
            # derived from one: the block coordinate is data-dependent
            out: AbsVal = DATA
        elif name in ("add", "sub") and len(vals) == 2 \
                and all(isinstance(v, Affine) for v in vals):
            out = _aff_add(vals[0], vals[1], 1 if name == "add" else -1)
        elif name == "mul" and len(vals) == 2 \
                and all(isinstance(v, Affine) for v in vals) \
                and (_as_const(vals[0]) is not None
                     or _as_const(vals[1]) is not None):
            k = _as_const(vals[0])
            out = _aff_scale(vals[1], k) if k is not None \
                else _aff_scale(vals[0], _as_const(vals[1]) or 0)
        elif name == "neg" and isinstance(vals[0], Affine):
            out = _aff_scale(vals[0], -1)
        elif name in ("convert_element_type", "copy", "squeeze",
                      "broadcast_in_dim", "reshape") and vals \
                and isinstance(vals[0], Affine):
            # scalar plumbing around an affine value keeps it affine
            out = vals[0]
        else:
            inner = next((eqn.params[k] for k in _CALL_JAXPR_PARAMS
                          if isinstance(eqn.params.get(k),
                                        (jcore.Jaxpr, jcore.ClosedJaxpr))),
                         None)
            if inner is not None:
                outs = _eval_jaxpr(inner, vals)
            else:
                out = TOP
        if outs is None:
            outs = [out] * len(eqn.outvars)
        for ov, o in zip(eqn.outvars, outs):
            env[ov] = o
    return [read(v) for v in jaxpr.outvars]


def eval_index_map(index_map_jaxpr: Any, n_grid: int) -> list[AbsVal]:
    """Per-output-dim abstract block coordinates of a BlockSpec index map.

    ``index_map_jaxpr`` invars are the grid indices followed by the
    scalar-prefetch refs (jax 0.4.37 ``BlockMapping.index_map_jaxpr``
    layout).  Returns one :data:`AbsVal` per output dimension.
    """
    jaxpr = unwrap(index_map_jaxpr)
    invals: list[AbsVal] = [
        Affine(0, ((i, 1),)) if i < n_grid else _REF
        for i in range(len(jaxpr.invars))]
    return _eval_jaxpr(jaxpr, invals)


def visit_verdict(dims: list[AbsVal], grid: tuple[Any, ...],
                  enum_cap: int = ENUM_CAP) -> str:
    """Is each output block coordinate produced at most once over ``grid``?

    Returns ``"once"`` (proved unique), ``"revisit"`` (proved repeated),
    ``"data"`` (worklist/prefetch-dependent — uniqueness is a runtime
    property of the table), or ``"unknown"``.
    """
    if not all(isinstance(s, int) for s in grid):
        return "unknown"                  # dynamic grid bounds: R4 territory
    if any(d is DATA or d is _REF for d in dims):
        return "data"
    if not all(isinstance(d, Affine) for d in dims):
        return "unknown"
    affs = [d for d in dims if isinstance(d, Affine)]
    live = [a for a, s in enumerate(grid) if int(s) > 1]
    if not live:
        return "once"
    used: set[int] = set()
    for d in affs:
        used.update(d.axes)
    if any(a not in used for a in live):
        # a >1-sized grid axis never reaches any output coordinate: the
        # same block tuple recurs across that whole axis
        return "revisit"
    vol = 1
    for s in grid:
        vol *= max(int(s), 1)
    if vol <= enum_cap:
        seen: set[tuple[int, ...]] = set()
        for point in itertools.product(*[range(int(s)) for s in grid]):
            key = tuple(d.eval(point) for d in affs)
            if key in seen:
                return "revisit"
            seen.add(key)
        return "once"
    # sufficient condition for big grids: every live axis owns a distinct
    # output dim with a unit coefficient and no live-axis co-tenant
    owner: dict[int, int] = {}
    for i, d in enumerate(affs):
        axs = [a for a in d.axes if a in live]
        if len(axs) == 1 and abs(d.coeff(axs[0])) == 1:
            owner.setdefault(axs[0], i)
    if all(a in owner for a in live) \
            and len(set(owner.values())) == len(owner):
        return "once"
    return "unknown"


# ------------------------------------------------- kernel write classifier
# ops through which an accumulator's old value may legally flow back into
# its ref: associative accumulates (+ / min / max), the select/merge family
# and pure data movement — the building blocks of the kept-k lexicographic
# merge and the best-1 min update
_ACCUM_OK = frozenset({
    "add", "add_any", "sub", "max", "min",
    "reduce_max", "reduce_min", "reduce_sum",
    "select_n", "concatenate", "broadcast_in_dim", "reshape", "expand_dims",
    "squeeze", "transpose", "slice", "pad", "rev",
    "convert_element_type", "copy", "stop_gradient",
})
# predicate-producing ops: their result is control information, not a
# merged value — taint is deliberately killed (a comparison against the old
# accumulator is how min/merge updates decide, not how values flow)
_PREDICATE = frozenset({
    "eq", "ne", "lt", "le", "gt", "ge", "and", "or", "xor", "not",
    "is_finite", "reduce_and", "reduce_or",
})
# sources that are pure functions of the grid position
_PURE_SOURCES = frozenset({"program_id", "num_programs", "iota"})


@dataclasses.dataclass(frozen=True)
class VInfo:
    """Abstract value state inside a kernel body.

    ``taint``: output-ref slots whose *stored value* flows into this value
    through accumulate-whitelisted ops; ``dirty``: some tainted operand
    crossed a non-whitelisted op on the way here; ``pure``: derived only
    from grid indices, scalar-prefetch reads and literals (guard purity).
    """

    taint: frozenset = frozenset()
    dirty: bool = False
    pure: bool = False


_PURE_V = VInfo(pure=True)
_OPAQUE_V = VInfo()


@dataclasses.dataclass(frozen=True)
class WriteSite:
    """One ``swap`` on an output ref, classified.

    ``slot`` is the output operand index (-1: a write inside an unmappable
    sub-jaxpr — conservatively matches every output).  ``kind`` is one of
    ``rmw-clean`` / ``rmw-dirty`` / ``overwrite-guarded`` / ``overwrite``.
    """

    slot: int
    kind: str
    path: str


def _join_v(infos: list[VInfo]) -> VInfo:
    if not infos:
        return _OPAQUE_V
    taint = frozenset().union(*[i.taint for i in infos])
    return VInfo(taint=taint, dirty=any(i.dirty for i in infos),
                 pure=all(i.pure for i in infos))


def classify_kernel_writes(body: Any, n_prefetch: int, n_inputs: int,
                           n_outputs: int
                           ) -> tuple[list[WriteSite], set[tuple[str, int]]]:
    """Classify every output-ref write in a pallas kernel body.

    ``body`` is the kernel jaxpr whose invars are, in order, the
    scalar-prefetch refs, the input refs, the output refs and the scratch
    refs (jax 0.4.37 ``pallas_call`` eqn ``jaxpr`` param layout).  Returns
    ``(writes, reads)`` where ``reads`` is the set of ref slots whose value
    is read anywhere (``("input", i)`` / ``("output", k)`` / ...).
    """
    jaxpr = unwrap(body)
    writes: list[WriteSite] = []
    reads: set[tuple[str, int]] = set()

    refs0: dict[Any, tuple[str, int]] = {}
    for i, v in enumerate(jaxpr.invars):
        if i < n_prefetch:
            refs0[v] = ("prefetch", i)
        elif i < n_prefetch + n_inputs:
            refs0[v] = ("input", i - n_prefetch)
        elif i < n_prefetch + n_inputs + n_outputs:
            refs0[v] = ("output", i - n_prefetch - n_inputs)
        else:
            refs0[v] = ("scratch", i - n_prefetch - n_inputs - n_outputs)

    def conservative_scan(jaxpr: Any, path: tuple[str, ...]) -> None:
        """A sub-jaxpr whose invars we could not map: any swap inside may
        target any output (slot -1, plain overwrite)."""
        for eqn in unwrap(jaxpr).eqns:
            if eqn.primitive.name == "swap":
                writes.append(WriteSite(slot=-1, kind="overwrite",
                                        path="/".join(path) or "<kernel>"))
            for key, sub in sub_jaxprs(eqn):
                conservative_scan(sub, path + (f"{eqn.primitive.name}.{key}",))

    def run(jaxpr: Any, refs: dict, invals: list[VInfo],
            guard_pure: bool, guarded: bool,
            path: tuple[str, ...]) -> list[VInfo]:
        jaxpr = unwrap(jaxpr)
        vals: dict[Any, VInfo] = dict(zip(jaxpr.invars, invals))
        for v in jaxpr.constvars:
            vals[v] = _OPAQUE_V

        def vinfo(v: Any) -> VInfo:
            if isinstance(v, jcore.Literal):
                return _PURE_V
            return vals.get(v, _OPAQUE_V)

        def refid(v: Any) -> tuple[str, int] | None:
            if isinstance(v, jcore.Literal):
                return None
            return refs.get(v)

        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            ins = [vinfo(v) for v in eqn.invars]
            outs: list[VInfo] | None = None
            out = _OPAQUE_V
            if name in ("get", "swap", "addupdate"):
                rid = refid(eqn.invars[0])
                if rid is not None:
                    reads.add(rid)
                idx_pure = all(vinfo(v).pure for v in eqn.invars[1:])
                if name in ("swap", "addupdate") and rid is not None \
                        and rid[0] == "output":
                    k = rid[1]
                    val = vinfo(eqn.invars[1])
                    if name == "addupdate" or k in val.taint:
                        kind = "rmw-dirty" if val.dirty else "rmw-clean"
                    elif guarded and guard_pure:
                        kind = "overwrite-guarded"
                    else:
                        kind = "overwrite"
                    writes.append(WriteSite(
                        slot=k, kind=kind,
                        path="/".join(path) or "<kernel>"))
                # the produced value is the ref's (old) stored value
                if rid is not None and rid[0] == "output":
                    out = VInfo(taint=frozenset({rid[1]}))
                elif rid is not None and rid[0] == "prefetch":
                    out = VInfo(pure=idx_pure)
                else:
                    out = _OPAQUE_V
            elif name == "cond":
                pred = ins[0]
                branches = tuple(eqn.params.get("branches", ()))
                op_vals = ins[1:]
                op_refs = {unwrap(br).invars[i]: refid(v)
                           for br in branches
                           for i, v in enumerate(eqn.invars[1:])
                           if len(unwrap(br).invars) == len(eqn.invars) - 1
                           and refid(v) is not None}
                per_branch: list[list[VInfo]] = []
                ok = True
                for bi, br in enumerate(branches):
                    sub = unwrap(br)
                    if len(sub.invars) != len(eqn.invars) - 1:
                        ok = False
                        break
                    sub_refs = {sv: refid(v) for sv, v in
                                zip(sub.invars, eqn.invars[1:])
                                if refid(v) is not None}
                    per_branch.append(run(
                        sub, sub_refs, op_vals,
                        guard_pure=guard_pure and pred.pure, guarded=True,
                        path=path + (f"cond[{bi}]",)))
                del op_refs
                if ok and per_branch:
                    outs = [_join_v(list(t)) for t in zip(*per_branch)]
                    if not outs:
                        outs = [_OPAQUE_V] * len(eqn.outvars)
                else:
                    for bi, br in enumerate(branches):
                        conservative_scan(br, path + (f"cond[{bi}]",))
                    outs = [_OPAQUE_V] * len(eqn.outvars)
            elif name in _PURE_SOURCES:
                out = _PURE_V
            elif name in _PREDICATE:
                out = VInfo(pure=all(i.pure for i in ins))
            elif name == "select_n":
                # the predicate selects; only the case operands' values flow
                cases = ins[1:]
                out = VInfo(
                    taint=frozenset().union(*[c.taint for c in cases])
                    if cases else frozenset(),
                    dirty=any(c.dirty for c in cases),
                    pure=all(i.pure for i in ins))
            elif name in _ACCUM_OK:
                out = _join_v(ins) if ins else _PURE_V
            else:
                inner = next((eqn.params[k] for k in _CALL_JAXPR_PARAMS
                              if isinstance(eqn.params.get(k),
                                            (jcore.Jaxpr, jcore.ClosedJaxpr))),
                             None)
                if inner is not None:
                    sub = unwrap(inner)
                    if len(sub.invars) == len(eqn.invars):
                        sub_refs = {sv: refid(v) for sv, v in
                                    zip(sub.invars, eqn.invars)
                                    if refid(v) is not None}
                        outs = run(sub, sub_refs, ins, guard_pure, guarded,
                                   path + (name,))
                        if len(outs) != len(eqn.outvars):
                            outs = [_join_v(ins)] * len(eqn.outvars)
                    else:
                        conservative_scan(sub, path + (name,))
                        outs = [_OPAQUE_V] * len(eqn.outvars)
                elif any(isinstance(val, (jcore.Jaxpr, jcore.ClosedJaxpr))
                         for val in eqn.params.values()) \
                        or any(isinstance(val, (tuple, list))
                               and any(isinstance(x, (jcore.Jaxpr,
                                                      jcore.ClosedJaxpr))
                                       for x in val)
                               for val in eqn.params.values()):
                    # while/scan/other sub-jaxpr carriers we do not model:
                    # conservative over every nested swap
                    for key, subj in sub_jaxprs(eqn):
                        conservative_scan(subj, path + (f"{name}.{key}",))
                    outs = [_OPAQUE_V] * len(eqn.outvars)
                else:
                    t = frozenset().union(*[i.taint for i in ins]) \
                        if ins else frozenset()
                    out = VInfo(taint=t,
                                dirty=any(i.dirty for i in ins) or bool(t),
                                pure=False)
            if outs is None:
                outs = [out] * len(eqn.outvars)
            for ov, o in zip(eqn.outvars, outs):
                vals[ov] = o
        return [vinfo(v) for v in jaxpr.outvars]

    run(jaxpr, refs0, [_OPAQUE_V] * len(jaxpr.invars),
        guard_pure=True, guarded=False, path=())
    return writes, reads


# ----------------------------------------------------- live-buffer walker
def _aval_bytes(v: Any) -> int:
    aval = getattr(v, "aval", None)
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    size = 1
    for s in shape:
        if not isinstance(s, int):
            return 0
        size *= s
    try:
        return int(size) * int(dtype.itemsize)
    except (TypeError, AttributeError):
        return 0


def live_buffer_peak(closed: Any) -> int:
    """Upper bound on simultaneously-live buffer bytes of a traced
    computation.

    Last-use liveness over each jaxpr's eqn sequence; a sub-jaxpr's peak is
    stacked on top of the caller's live set at its call point (boundary
    values are counted on both sides — this is an upper bound, which is the
    useful direction for a budget).  ``pallas_call`` bodies are excluded:
    their on-chip footprint is :func:`pallas_memory`'s job, not HBM's.
    """
    memo: dict[int, int] = {}

    def peak(jaxpr: Any) -> int:
        jaxpr = unwrap(jaxpr)
        key = id(jaxpr)
        if key in memo:
            return memo[key]
        memo[key] = 0                    # cycle/diamond guard
        last: dict[Any, int] = {}
        n = len(jaxpr.eqns)
        for i, eqn in enumerate(jaxpr.eqns):
            for v in eqn.invars:
                if isinstance(v, jcore.Var):
                    last[v] = i
        for v in jaxpr.outvars:
            if isinstance(v, jcore.Var):
                last[v] = n
        live = 0
        alive: set = set()

        def birth(v: Any) -> int:
            if isinstance(v, jcore.Var) and v in last and v not in alive:
                alive.add(v)
                return _aval_bytes(v)
            return 0

        for v in (*jaxpr.invars, *jaxpr.constvars):
            live += birth(v)
        best = live
        for i, eqn in enumerate(jaxpr.eqns):
            for v in eqn.outvars:
                live += birth(v)
            sub_peak = 0
            if eqn.primitive.name != "pallas_call":
                sub_peak = max((peak(sub) for _, sub in sub_jaxprs(eqn)),
                               default=0)
            best = max(best, live + sub_peak)
            for v in set(x for x in eqn.invars if isinstance(x, jcore.Var)) \
                    | set(eqn.outvars):
                if v in alive and last.get(v, -1) <= i:
                    alive.discard(v)
                    live -= _aval_bytes(v)
        memo[key] = best
        return best

    return peak(closed)


# -------------------------------------------------- pallas memory estimate
def _ref_shape_dtype(aval: Any) -> tuple[tuple[int, ...], Any]:
    inner = getattr(aval, "inner_aval", None)
    shape = getattr(aval, "shape", None) or getattr(inner, "shape", None) \
        or ()
    dtype = getattr(aval, "dtype", None) or getattr(inner, "dtype", None)
    return tuple(int(s) for s in shape if isinstance(s, int)), dtype


def _nbytes(shape: tuple[int, ...], dtype: Any) -> int:
    size = 1
    for s in shape:
        size *= max(int(s), 1)
    try:
        return size * int(dtype.itemsize)
    except (TypeError, AttributeError):
        return size * 4


def _is_smem(aval: Any) -> bool:
    return "smem" in str(aval).lower()


def pallas_memory(eqn: Any) -> dict:
    """Peak VMEM/SMEM bytes one ``pallas_call`` launch needs, from its
    ``grid_mapping``: non-SMEM block mappings double-buffered, scalar
    prefetch + SMEM blocks + SMEM scratch resident for the whole launch,
    VMEM scratch single-buffered."""
    gm = eqn.params.get("grid_mapping")
    body = eqn.params.get("jaxpr")
    name_info = eqn.params.get("name_and_src_info")
    out = {"kernel": str(name_info) if name_info is not None else "<kernel>",
           "grid": [], "vmem_bytes": 0, "smem_bytes": 0}
    if gm is None or body is None:
        return out
    out["grid"] = [int(g) if isinstance(g, int) else str(g)
                   for g in tuple(getattr(gm, "grid", ()) or ())]
    vmem = smem = 0
    for bm in tuple(getattr(gm, "block_mappings", ()) or ()):
        block = tuple(1 if b is None else int(b)
                      for b in tuple(getattr(bm, "block_shape", ()) or ()))
        dtype = getattr(getattr(bm, "array_shape_dtype", None), "dtype", None)
        nb = _nbytes(block, dtype)
        if _is_smem(getattr(bm, "block_aval", "")):
            smem += nb
        else:
            vmem += 2 * nb              # pipelined: double-buffered
    n_pf = int(getattr(gm, "num_index_operands", 0) or 0)
    n_in = int(getattr(gm, "num_inputs", 0) or 0)
    n_out = int(getattr(gm, "num_outputs", 0) or 0)
    invars = tuple(unwrap(body).invars)
    for v in invars[:n_pf]:
        shape, dtype = _ref_shape_dtype(v.aval)
        smem += _nbytes(shape, dtype)
    for v in invars[n_pf + n_in + n_out:]:
        shape, dtype = _ref_shape_dtype(v.aval)
        if _is_smem(v.aval):
            smem += _nbytes(shape, dtype)
        else:
            vmem += _nbytes(shape, dtype)
    out["vmem_bytes"] = int(vmem)
    out["smem_bytes"] = int(smem)
    return out
