"""R7 transfer-retrace: no hidden host hops, no avoidable retrace churn.

Two halves share the rule name:

* **jaxpr half** — traced hot paths must not smuggle host transfers: a
  ``pure_callback`` / ``io_callback`` / ``debug_callback`` (or raw
  ``infeed`` / ``outfeed``) inside a canonical trace is a device->host
  round trip *per call*, serialized against the XLA stream.  The tree's
  deliberate host work (worklist builds) happens *outside* traces by
  construction; anything host-shaped that shows up inside one is a defect.
* **plan half** — the planner's jit caches must be spelling-stable.  The
  same plan called with equivalent ``d_cut`` spellings (python ``float``,
  ``np.float32``, ``np.float64``) must produce identical jit-boundary
  avals: a python float traces as a *weak-typed* f32 and a numpy scalar as
  a strong one, so an un-normalized scalar argument silently doubles the
  trace cache (one entry per spelling the caller happens to use — retrace
  churn, measured in whole-kernel recompiles).  The probe traces the
  plan's density primitive under each spelling and compares every ``pjit``
  boundary's ``(dtype, shape, weak_type)`` signature.

The fix the probe enforces: ``DPCPlan.rho_delta`` and the ``tile_sweep``
host wrapper normalize ``d_cut`` to a strong ``f32`` before crossing any
jit boundary.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from .rules import Finding, Rule, register_rule

RULE_NAME = "R7-transfer-retrace"

# host-transfer primitives that must never appear inside a hot trace
_TRANSFER_PRIMS = ("pure_callback", "io_callback", "debug_callback",
                   "infeed", "outfeed")

_DESCRIPTION = ("hot traced paths carry no host callbacks/transfers; "
                "equivalent d_cut spellings hit one jit trace (stable "
                "weak-type/dtype avals at every pjit boundary)")


@dataclass(frozen=True)
class TransferRule(Rule):
    name: str = RULE_NAME
    description: str = _DESCRIPTION
    kind: str = "jaxpr"

    def check_jaxpr(self, target: str, closed_jaxpr: Any) -> list[Finding]:
        from .walker import iter_sites

        out: list[Finding] = []
        for site in iter_sites(closed_jaxpr):
            pname = site.eqn.primitive.name
            if pname in _TRANSFER_PRIMS:
                out.append(Finding(
                    rule=RULE_NAME, severity="error", target=target,
                    message=(f"{pname} inside a hot traced path: a "
                             f"device->host round trip per call, "
                             f"serialized against the XLA stream — hoist "
                             f"the host work out of the trace (worklist "
                             f"builds and callbacks belong on the host "
                             f"side of the dispatch seam)"),
                    where=site.where + f"/{pname}"))
        return out


# ----------------------------------------------------- retrace-churn probe
def _jit_signature(closed: Any) -> tuple:
    """Every ``pjit`` boundary's aval signature, outermost to innermost."""
    from .walker import iter_sites

    sig: list[Any] = []
    for site in iter_sites(closed):
        eqn = site.eqn
        if eqn.primitive.name != "pjit":
            continue
        avals = tuple(
            (str(v.aval.dtype), tuple(getattr(v.aval, "shape", ())),
             bool(getattr(v.aval, "weak_type", False)))
            for v in eqn.invars)
        sig.append((site.where, str(eqn.params.get("name", "")), avals))
    return tuple(sig)


def _spelling_probes(pl: Any) -> list[tuple[str, Any, Any]]:
    """(spelling label, d_cut value, trace thunk) triples for the plan's
    density primitive — the scalar argument every driver passes per call,
    in the spellings real call sites actually use."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from .targets import D_CUT, canonical_points

    x_np = canonical_points()
    x = jnp.asarray(x_np)
    spellings = (("float", float(D_CUT)),
                 ("np.float32", np.float32(D_CUT)),
                 ("np.float64", np.float64(D_CUT)))
    be = pl.backend

    if be.fused_traceable:
        def make(d: Any) -> Any:
            return jax.make_jaxpr(lambda a, b: pl.rho_delta(a, b, d))(x, x)
    else:
        from repro.kernels import blocksparse, ops

        interpret = bool(getattr(be, "interpret", False))
        bn = pl.block or ops.DENSITY_BLOCK_N
        wl = None
        if pl.sparse:
            wl = blocksparse.build_flat_worklist(
                x_np, x_np, D_CUT, block_n=bn, block_m=ops.DENSITY_BLOCK_M,
                count=True, nn="topk", k=ops.FUSED_TOPK)

        def make(d: Any) -> Any:
            return jax.make_jaxpr(
                lambda a, b: ops.fused_sweep(
                    a, b, d, precision=pl.precision, block_n=bn,
                    interpret=interpret, worklist=wl))(x, x)

    return [(label, val, lambda v=val: make(v)) for label, val in spellings]


@dataclass(frozen=True)
class RetraceChurnRule(Rule):
    name: str = RULE_NAME
    description: str = _DESCRIPTION
    kind: str = "plan"

    def check_plan(self, pl: Any) -> list[Finding]:
        from repro.kernels import blocksparse
        from repro.resilience import faultinject

        target = f"plan[{pl.backend_name}:{pl.layout}:{pl.precision}]"
        out: list[Finding] = []

        # the plan cache key itself must be stable/hashable
        try:
            hash(pl.spec)
        except TypeError as exc:
            out.append(Finding(
                rule=RULE_NAME, severity="error", target=target,
                message=f"ExecSpec is unhashable ({exc}): every plan() "
                        f"call becomes a cache miss", where="<plan-cache>"))
            return out

        sigs: dict[str, tuple] = {}
        with faultinject.suspended(), blocksparse.suspend_counters():
            for label, _val, thunk in _spelling_probes(pl):
                try:
                    sigs[label] = _jit_signature(thunk())
                except Exception as exc:   # noqa: BLE001 — report, don't die
                    out.append(Finding(
                        rule="trace", severity="warn", target=target,
                        message=f"retrace probe [{label}] could not trace: "
                                f"{type(exc).__name__}: {exc}",
                        where="<retrace-probe>"))
                    return out

        base_label, base = next(iter(sigs.items()))
        for label, sig in sigs.items():
            if sig == base:
                continue
            boundary = "<pjit count differs>"
            for a, b in zip(base, sig):
                if a != b:
                    boundary = f"{a[0]}/pjit:{a[1] or '<anon>'}"
                    break
            out.append(Finding(
                rule=RULE_NAME, severity="error", target=target,
                message=(f"d_cut spelled as {label} traces different "
                         f"jit-boundary avals than {base_label} at "
                         f"{boundary} — each spelling lands its own trace "
                         f"cache entry (retrace churn: normalize the "
                         f"scalar to a strong f32 before the jit "
                         f"boundary)"),
                where="<retrace-probe>"))
        return out


register_rule(TransferRule())
register_rule(RetraceChurnRule())
