"""The full analysis sweep: every ExecSpec combo x every subsystem target,
plus the project rules, folded into one JSON-able report.

``run_sweep`` is what ``python -m repro.analysis`` (and CI) runs.  Shape::

    {"ok": bool,                  # no error-severity findings
     "findings": [Finding.to_dict(), ...],
     "targets": ["<spec>:<target>", ...],   # every trace analyzed
     "skipped": ["<reason>", ...],          # impossible combos, with why
     "audits":  {key: {...}, ...}}          # registered check_rep audits

Plan-time analysis is suspended for the duration (``REPRO_ANALYSIS=0``):
the sweep runs the same jaxpr rules itself over a superset of the
plan-time targets, and a plan-time :class:`AnalysisError` mid-sweep would
surface as an untraceable-target warning instead of the real findings.
"""
from __future__ import annotations

import os

from .rules import project_rules


def _repo_root() -> str:
    # .../src/repro/analysis/report.py -> repo root
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def run_sweep(repo_root: str | None = None) -> dict:
    from repro.engine.planner import plan

    from .audit import all_audits
    from .rules import analyze_jaxpr
    from .targets import (analyze_plan, distributed_targets, serve_targets,
                          stream_targets, sweep_specs)

    root = repo_root or _repo_root()
    findings: list = []
    targets_run: list[str] = []
    skipped: list[str] = []

    prev = os.environ.get("REPRO_ANALYSIS")
    os.environ["REPRO_ANALYSIS"] = "0"
    try:
        for spec in sweep_specs():
            label = spec.describe()
            pl = plan(None, spec)

            plan_findings = analyze_plan(pl)
            findings.extend(plan_findings)
            targets_run.append(f"{label}:batch")

            for name, thunk in _collect(
                    (distributed_targets, pl), (stream_targets, pl),
                    skipped=skipped, label=label):
                target = f"{label}:{name}"
                targets_run.append(target)
                findings.extend(_analyze_one(target, thunk, analyze_jaxpr))

            serve_t, serve_skip = serve_targets(spec)
            skipped.extend(serve_skip)
            for name, thunk in serve_t:
                target = f"{label}:{name}"
                targets_run.append(target)
                findings.extend(_analyze_one(target, thunk, analyze_jaxpr))
    finally:
        if prev is None:
            os.environ.pop("REPRO_ANALYSIS", None)
        else:
            os.environ["REPRO_ANALYSIS"] = prev

    for rule in project_rules():
        findings.extend(rule.check_project(root))

    audits = {k: {"reason": a.reason, "collectives": list(a.collectives)}
              for k, a in sorted(all_audits().items())}
    errors = [f for f in findings if f.severity == "error"]
    return {"ok": not errors,
            "findings": [f.to_dict() for f in findings],
            "targets": sorted(set(targets_run)),
            "skipped": sorted(set(skipped)),
            "audits": audits}


def _collect(*sources, skipped: list, label: str):
    for fn, pl in sources:
        tgts, skip = fn(pl)
        skipped.extend(f"{label}:{s}" for s in skip)
        yield from tgts


def _analyze_one(target: str, thunk, analyze_jaxpr) -> list:
    from .targets import _trace_failure

    try:
        closed = thunk()
    except Exception as exc:                 # noqa: BLE001
        return [_trace_failure(target, exc)]
    return analyze_jaxpr(target, closed)
