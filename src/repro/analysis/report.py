"""The full analysis sweep: every ExecSpec combo x every subsystem target,
plus the project rules, folded into one JSON-able report.

``run_sweep`` is what ``python -m repro.analysis`` (and CI) runs.  Shape::

    {"ok": bool,                  # no *unsuppressed* error findings
     "findings": [Finding.to_dict(), ...],  # suppressed ones carry
                                            # severity "suppressed" + why
     "targets": ["<spec>:<target>", ...],   # every trace analyzed
     "skipped": ["<reason>", ...],          # impossible combos, with why
     "rules":   {name: {kind, description}, ...},
     "audits":  {key: {...}, ...},          # registered check_rep audits
     "determinism_audits": {key: {...}, ...}}

Plan-time analysis is suspended for the duration
(``REPRO_ANALYSIS=suspend`` — the internal value, not the ``0`` escape
hatch, which now still computes findings for telemetry): the sweep runs
the same rules itself over a superset of the plan-time targets, and a
plan-time :class:`AnalysisError` mid-sweep would surface as an
untraceable-target warning instead of the real findings.

**Baseline suppressions** (``analysis-baseline.json`` at the repo root,
or ``--baseline``): each entry matches findings by ``rule`` / ``target`` /
``where`` fnmatch globs and must carry a ``reason`` and an ``expires``
date (ISO ``YYYY-MM-DD``).  A matched error finding is downgraded to
severity ``"suppressed"`` (reported, not fatal); an entry past its expiry
is itself an error — suppressions are leases, not landfills.
"""
from __future__ import annotations

import datetime
import fnmatch
import json
import os
from typing import Any, Iterator

from .rules import Finding, project_rules


def _repo_root() -> str:
    # .../src/repro/analysis/report.py -> repo root
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


BASELINE_FILE = "analysis-baseline.json"


def load_baseline(path: str) -> list[dict]:
    """Suppression entries from a baseline file (missing file: none)."""
    if not os.path.exists(path):
        return []
    with open(path, encoding="utf-8") as fh:
        doc = json.load(fh)
    entries = doc.get("suppressions", []) if isinstance(doc, dict) else doc
    if not isinstance(entries, list):
        raise ValueError(f"{path}: 'suppressions' must be a list")
    return entries


def _baseline_findings(entries: list[dict], path: str,
                       today: datetime.date) -> list[Finding]:
    """Malformed / expired suppression entries, as error findings."""
    out: list[Finding] = []
    for i, e in enumerate(entries):
        where = f"{os.path.basename(path)}[{i}]"
        reason = str(e.get("reason", "")).strip()
        raw_exp = str(e.get("expires", "")).strip()
        if not reason:
            out.append(Finding(
                rule="baseline", severity="error", target=path,
                message="suppression entry carries no reason — a "
                        "suppression is an argued exception, not a mute "
                        "button", where=where))
        try:
            expires = datetime.date.fromisoformat(raw_exp)
        except ValueError:
            out.append(Finding(
                rule="baseline", severity="error", target=path,
                message=f"suppression entry has no parseable 'expires' "
                        f"date (got {raw_exp!r}; want YYYY-MM-DD) — "
                        f"suppressions are leases and leases end",
                where=where))
            continue
        if expires < today:
            out.append(Finding(
                rule="baseline", severity="error", target=path,
                message=f"suppression expired {expires.isoformat()} "
                        f"(rule={e.get('rule', '*')!r} "
                        f"target={e.get('target', '*')!r}): fix the "
                        f"finding or renew the lease with a fresh "
                        f"review", where=where))
    return out


def _matches(entry: dict, f: Finding) -> bool:
    return (fnmatch.fnmatch(f.rule, str(entry.get("rule", "*")))
            and fnmatch.fnmatch(f.target, str(entry.get("target", "*")))
            and fnmatch.fnmatch(f.where, str(entry.get("where", "*"))))


def apply_baseline(findings: list[Finding], entries: list[dict],
                   today: datetime.date | None = None) -> list[dict]:
    """Finding dicts with baseline-matched errors downgraded to
    ``"suppressed"`` (the suppression's reason attached).  Expired
    entries never match — their error finding keeps the pressure on."""
    today = today or datetime.date.today()

    def live(e: dict) -> bool:
        try:
            return datetime.date.fromisoformat(
                str(e.get("expires", ""))) >= today
        except ValueError:
            return False

    live_entries = [e for e in entries if live(e)]
    out: list[dict] = []
    for f in findings:
        d = f.to_dict()
        if f.severity == "error":
            hit = next((e for e in live_entries if _matches(e, f)), None)
            if hit is not None:
                d["severity"] = "suppressed"
                d["suppressed_reason"] = str(hit.get("reason", ""))
                d["suppressed_until"] = str(hit.get("expires", ""))
        out.append(d)
    return out


def run_sweep(repo_root: str | None = None,
              baseline_path: str | None = None) -> dict:
    from repro.engine.planner import plan

    from .audit import all_audits, all_determinism_audits
    from .rules import all_rules, analyze_jaxpr
    from .targets import (analyze_plan, distributed_targets, serve_targets,
                          stream_targets, sweep_specs)

    root = repo_root or _repo_root()
    baseline = baseline_path or os.path.join(root, BASELINE_FILE)
    findings: list[Finding] = []
    targets_run: list[str] = []
    skipped: list[str] = []

    prev = os.environ.get("REPRO_ANALYSIS")
    os.environ["REPRO_ANALYSIS"] = "suspend"
    try:
        for spec in sweep_specs():
            label = spec.describe()
            pl = plan(None, spec)

            plan_findings = analyze_plan(pl)
            findings.extend(plan_findings)
            targets_run.append(f"{label}:batch")

            for name, thunk in _collect(
                    (distributed_targets, pl), (stream_targets, pl),
                    skipped=skipped, label=label):
                target = f"{label}:{name}"
                targets_run.append(target)
                findings.extend(_analyze_one(target, thunk, analyze_jaxpr))

            serve_t, serve_skip = serve_targets(spec)
            skipped.extend(serve_skip)
            for name, thunk in serve_t:
                target = f"{label}:{name}"
                targets_run.append(target)
                findings.extend(_analyze_one(target, thunk, analyze_jaxpr))
    finally:
        if prev is None:
            os.environ.pop("REPRO_ANALYSIS", None)
        else:
            os.environ["REPRO_ANALYSIS"] = prev

    for rule in project_rules():
        findings.extend(rule.check_project(root))

    today = datetime.date.today()
    entries = load_baseline(baseline)
    findings.extend(_baseline_findings(entries, baseline, today))
    finding_dicts = apply_baseline(findings, entries, today)

    audits = {k: {"reason": a.reason, "collectives": list(a.collectives)}
              for k, a in sorted(all_audits().items())}
    det_audits = {k: {"reason": a.reason, "ops": list(a.ops),
                      "site": f"{a.file_name}:{a.function_name}"}
                  for k, a in sorted(all_determinism_audits().items())}
    rules_meta: dict[str, dict] = {}
    for r in all_rules():
        meta = rules_meta.setdefault(
            r.name, {"kind": r.kind, "description": r.description})
        if r.kind not in meta["kind"].split("+"):
            meta["kind"] += f"+{r.kind}"
    errors = [d for d in finding_dicts if d["severity"] == "error"]
    return {"ok": not errors,
            "findings": finding_dicts,
            "targets": sorted(set(targets_run)),
            "skipped": sorted(set(skipped)),
            "rules": rules_meta,
            "audits": audits,
            "determinism_audits": det_audits}


def _collect(*sources: tuple, skipped: list[str],
             label: str) -> Iterator[tuple[str, Any]]:
    for fn, pl in sources:
        tgts, skip = fn(pl)
        skipped.extend(f"{label}:{s}" for s in skip)
        yield from tgts


def _analyze_one(target: str, thunk: Any,
                 analyze_jaxpr: Any) -> list[Finding]:
    from .targets import _trace_failure

    try:
        closed = thunk()
    except Exception as exc:                 # noqa: BLE001
        return [_trace_failure(target, exc)]
    return analyze_jaxpr(target, closed)
