"""Rule base + registry for the jaxpr static analyzer.

Three rule kinds share one :class:`Finding` vocabulary:

* **jaxpr rules** (``kind = "jaxpr"``) check one traced computation at a
  time — they run at plan time (``repro.engine.planner.plan``) on each
  plan's canonical traces, and in the CLI sweep on every target the
  subsystems expose.
* **plan rules** (``kind = "plan"``) check a resolved :class:`DPCPlan`
  itself — properties that live *between* traces, like R7's retrace-churn
  probe (the same plan called with different but equivalent ``d_cut``
  spellings must produce identical jit-boundary avals).
* **project rules** (``kind = "project"``) check the source tree or the
  spec/dispatch tables once per sweep (R2's audit scan, R5's coverage
  cross-check); they have no single jaxpr to anchor to.

This module is deliberately jax-free: importing it (e.g. via
``repro.analysis.audit`` from a kernel module, or ``python -m
repro.analysis`` before XLA flags are finalized) must not initialize any
backend.  Rule implementations that need jaxpr machinery import
``repro.analysis.walker`` lazily.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Iterable


@dataclass(frozen=True)
class Finding:
    """One analyzer result: a rule violation at a location.

    ``severity`` is ``"error"`` (fails the sweep and plan-time checks) or
    ``"warn"`` (reported, never fatal — used for skipped/untraceable
    targets, not for rule violations).
    """

    rule: str                  # e.g. "R1-spmd-gather"
    severity: str              # "error" | "warn"
    target: str                # traced target or file being checked
    message: str
    where: str = ""            # jaxpr path or file:line

    def to_dict(self) -> dict:
        return {"rule": self.rule, "severity": self.severity,
                "target": self.target, "message": self.message,
                "where": self.where}


class AnalysisError(ValueError):
    """Raised by plan-time analysis when error-severity findings exist."""

    def __init__(self, findings: Iterable[Finding]) -> None:
        self.findings = tuple(findings)
        lines = [f"static analysis found {len(self.findings)} problem(s):"]
        lines += [f"  [{f.rule}] {f.target} @ {f.where}: {f.message}"
                  for f in self.findings]
        lines.append("  (set REPRO_ANALYSIS=0 to bypass while debugging — "
                     "findings still land on analysis_findings_total)")
        super().__init__("\n".join(lines))


@dataclass(frozen=True)
class Rule:
    """A registered analyzer rule.  Subclasses override one ``check_*``."""

    name: str = ""
    description: str = ""
    kind: str = "jaxpr"        # "jaxpr" | "plan" | "project"

    def check_jaxpr(self, target: str, closed_jaxpr: Any) -> list[Finding]:
        return []

    def check_plan(self, pl: Any) -> list[Finding]:
        return []

    def check_project(self, repo_root: str) -> list[Finding]:
        return []


_RULES: list[Rule] = []
_LOADED = False


def register_rule(rule: Rule) -> Rule:
    _RULES.append(rule)
    return rule


def _load() -> None:
    """Import the rule modules once (lazy: they pull in jax)."""
    global _LOADED
    if _LOADED:
        return
    from . import r1_spmd_gather, r2_check_rep, r3_precision  # noqa: F401
    from . import r4_pallas, r5_coverage                       # noqa: F401
    from . import r6_pallas_race, r7_transfer_retrace          # noqa: F401
    from . import r8_determinism, r9_memory_budget             # noqa: F401
    _LOADED = True


def all_rules() -> tuple[Rule, ...]:
    _load()
    return tuple(_RULES)


def jaxpr_rules() -> tuple[Rule, ...]:
    return tuple(r for r in all_rules() if r.kind == "jaxpr")


def plan_rules() -> tuple[Rule, ...]:
    return tuple(r for r in all_rules() if r.kind == "plan")


def project_rules() -> tuple[Rule, ...]:
    return tuple(r for r in all_rules() if r.kind == "project")


def analyze_jaxpr(target: str, closed_jaxpr: Any,
                  rules: tuple[Rule, ...] | None = None) -> list[Finding]:
    """Run every (or the given) jaxpr rule over one traced computation."""
    out: list[Finding] = []
    for rule in (jaxpr_rules() if rules is None else rules):
        out.extend(rule.check_jaxpr(target, closed_jaxpr))
    return out
