"""R1 spmd-gather: sort-derived indices must not feed sliced reads inside
multi-partition shard_map bodies.

The pinned jax-0.4.37 XLA CPU SPMD pipeline miscompiles exactly this
pattern: PR 4's distributed block-sparse path sorted tile lower bounds
inside each shard (``jnp.argsort`` in the ring-worklist build) and then
walked the order with ``ord_i[p]`` — on multi-device meshes the compiled
module silently degraded the order-gather to the loop counter, skipping
kept tiles with *identical wrong answers on every device* (no check_rep,
no test failure).  PR 5 found it by accident and degraded the distributed
block-sparse phases to dense tiles behind a blunt ``S_data == 1`` guard.

R1 is the precise replacement for that guard: flag every ``gather`` /
``dynamic_slice`` whose index operand is tainted by a ``sort`` computed in
traced code, inside a shard_map body mapped over an axis of size > 1.
Narrowing to *sort-derived* indices is load-bearing — the clean stencil
phases gather with traced span-table indices inside the very same
shard_maps and compile correctly, so "any traced index" would drown the
tree in false positives.

:func:`spmd_gather_safe` is the re-enablement gate (ROADMAP item 2):
``distributed_dpc`` traces its candidate block-sparse shard phases through
it and re-enables them the day the pattern no longer appears (an XLA
unpin, a worklist rewrite to one-hot matmuls, ...).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from .rules import Finding, Rule, register_rule
from .walker import spmd_sort_tainted_slices

RULE_NAME = "R1-spmd-gather"


@dataclass(frozen=True)
class SpmdGatherRule(Rule):
    name: str = RULE_NAME
    description: str = ("sort-derived index operands must not feed gather/"
                        "dynamic_slice inside a multi-partition shard_map "
                        "body (jax-0.4.37 XLA CPU SPMD miscompiles it)")
    kind: str = "jaxpr"

    def check_jaxpr(self, target: str, closed_jaxpr: Any) -> list[Finding]:
        out: list[Finding] = []
        for hit in spmd_sort_tainted_slices(closed_jaxpr):
            axes = ", ".join(f"{a}={s}" for a, s in hit.shard.axis_sizes)
            out.append(Finding(
                rule=self.name, severity="error", target=target,
                message=(f"`{hit.primitive}` reads with a sort-derived "
                         f"index inside a shard_map body over a multi-"
                         f"partition axis ({axes}); the pinned XLA CPU "
                         f"SPMD pipeline miscompiles this (the PR 4 "
                         f"block-sparse ring-walk bug)"),
                where=hit.where))
        return out


register_rule(SpmdGatherRule())


def spmd_gather_safe(fn: Any, *example_args: Any) -> bool:
    """True iff tracing ``fn(*example_args)`` shows no R1 pattern.

    The guard ``distributed_dpc`` consults before running block-sparse
    per-shard phases on a multi-partition mesh: trace the candidate
    shard_map'd phase on representative (small) shapes and admit it only
    when the sort-tainted-gather pattern is absent.
    """
    import jax

    closed = jax.make_jaxpr(fn)(*example_args)
    return not spmd_sort_tainted_slices(closed)
