"""R9 memory-budget: kernels fit their memory hierarchy, provably, at plan
time.

Two estimators from :mod:`repro.analysis.absint` feed one gate:

* per ``pallas_call``: peak VMEM from the block shapes (every non-SMEM
  block double-buffered by the Mosaic pipeline, so 2x per mapping, plus
  scratch) and SMEM from scalar-prefetch operands + SMEM-space blocks /
  scratch;
* per dense trace: a live-buffer upper bound over the jaxpr (last-use
  liveness; the XLA fusion floor, not a promise of what the compiler
  allocates — useful as a regression tripwire, not an exact number).

Budgets come from :mod:`repro.analysis.limits` — per-platform rows shared
with R4's scalar-prefetch check, overridable via ``REPRO_LIMIT_*``
environment knobs.  A kernel over budget is an **error** (it would OOM or
spill on the real device long after ``plan()`` succeeded); the live-buffer
gate only arms when ``REPRO_LIMIT_LIVE_BYTES`` is set (dense peaks scale
with the caller's ``n``, so a hard default would fail legitimate fits).

:func:`plan_memory` reuses the same estimators to build the ``memory``
block ``DPCPlan.telemetry()`` reports.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from .rules import Finding, Rule, register_rule

RULE_NAME = "R9-memory-budget"


def _fmt(n: int) -> str:
    return f"{n} B ({n / (1 << 20):.2f} MiB)"


@dataclass(frozen=True)
class MemoryBudgetRule(Rule):
    name: str = RULE_NAME
    description: str = ("per-pallas_call VMEM/SMEM estimates (block shapes "
                        "+ scalar prefetch, double-buffered) and dense "
                        "live-buffer peaks stay under the per-platform "
                        "budget table")
    kind: str = "jaxpr"

    def check_jaxpr(self, target: str, closed_jaxpr: Any) -> list[Finding]:
        from . import absint, limits
        from .walker import iter_sites

        out: list[Finding] = []
        for site in iter_sites(closed_jaxpr):
            eqn = site.eqn
            if eqn.primitive.name != "pallas_call":
                continue
            est = absint.pallas_memory(eqn)
            lims = limits.limits_for_eqn(eqn)
            where = site.where + "/pallas_call"
            for kind_key, budget in (("vmem_bytes", lims.vmem_bytes),
                                     ("smem_bytes", lims.smem_bytes)):
                used = int(est.get(kind_key, 0))
                if used <= budget:
                    continue
                space = kind_key.split("_", 1)[0].upper()
                out.append(Finding(
                    rule=RULE_NAME, severity="error", target=target,
                    message=(f"{est.get('kernel', '<kernel>')}: estimated "
                             f"{space} {_fmt(used)} exceeds the "
                             f"{lims.platform} budget {_fmt(budget)} "
                             f"(block shapes double-buffered + scratch; "
                             f"shrink the block spec or raise "
                             f"REPRO_LIMIT_{space}_BYTES deliberately)"),
                    where=where))

        live_budget = limits.live_budget_bytes()
        if live_budget is not None:
            from . import absint as _ai

            peak = int(_ai.live_buffer_peak(closed_jaxpr))
            if peak > live_budget:
                out.append(Finding(
                    rule=RULE_NAME, severity="error", target=target,
                    message=(f"dense live-buffer peak {_fmt(peak)} exceeds "
                             f"REPRO_LIMIT_LIVE_BYTES "
                             f"{_fmt(live_budget)}"),
                    where="<live-buffers>"))
        return out


def plan_memory(pl: Any) -> dict:
    """The ``memory`` telemetry block for one plan: per-kernel VMEM/SMEM
    estimates, the dense live-buffer peak across the plan's canonical
    traces, and the budgets they were gated against."""
    from repro.kernels import blocksparse
    from repro.resilience import faultinject

    from . import absint, limits
    from .targets import plan_targets
    from .walker import iter_sites

    kernels: list[dict] = []
    live_peak = 0
    platform = None
    with faultinject.suspended(), blocksparse.suspend_counters():
        for name, thunk in plan_targets(pl):
            try:
                closed = thunk()
            except Exception:   # noqa: BLE001 — telemetry is best-effort
                continue
            live_peak = max(live_peak, int(absint.live_buffer_peak(closed)))
            for site in iter_sites(closed):
                if site.eqn.primitive.name != "pallas_call":
                    continue
                est = absint.pallas_memory(site.eqn)
                lims = limits.limits_for_eqn(site.eqn)
                platform = platform or lims.platform
                kernels.append({"target": name, **est})
    lims = limits.limits_for_platform(platform)
    return {
        "kernels": kernels,
        "live_peak_bytes": live_peak,
        "live_budget_bytes": limits.live_budget_bytes(),
        "limits": lims.to_dict(),
    }


register_rule(MemoryBudgetRule())
