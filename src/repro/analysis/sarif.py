"""SARIF 2.1.0 emission for the analysis sweep.

``python -m repro.analysis --sarif out.sarif`` converts the sweep report
into one SARIF run so code hosts and IDE problem panes render the
findings natively.  Mapping:

* each registered rule becomes a ``tool.driver.rules`` entry (id = rule
  name, e.g. ``R6-pallas-race``); the trace/baseline pseudo-rules ride
  along so every result has a rule to anchor to;
* severity ``error`` -> SARIF ``error``, ``warn`` -> ``warning``;
  baseline-``suppressed`` findings keep level ``error`` but carry a
  ``suppressions`` entry (``kind: external``) with the lease's reason —
  exactly how SARIF models accepted findings, and how viewers know to
  fold them;
* a ``where`` of ``file:line`` shape becomes a ``physicalLocation``;
  jaxpr paths (``shard_map.jaxpr/psum2`` & co.) become
  ``logicalLocations`` with ``fullyQualifiedName = target::where`` — a
  trace path has no source file, and pretending otherwise would pin
  findings to wrong lines.

This module is jax-free and pure (dict in, dict out).
"""
from __future__ import annotations

import re

SARIF_VERSION = "2.1.0"
SARIF_SCHEMA = ("https://raw.githubusercontent.com/oasis-tcs/sarif-spec/"
                "master/Schemata/sarif-schema-2.1.0.json")

_FILE_LINE = re.compile(r"^(?P<file>[^\s:]+\.(?:py|json)):(?P<line>\d+)$")

_LEVELS = {"error": "error", "warn": "warning",
           "suppressed": "error", "info": "note"}


def _location(finding: dict) -> dict:
    where = str(finding.get("where", ""))
    m = _FILE_LINE.match(where)
    if m:
        return {"physicalLocation": {
            "artifactLocation": {"uri": m.group("file")},
            "region": {"startLine": int(m.group("line"))}}}
    fq = f"{finding.get('target', '<sweep>')}::{where or '<top>'}"
    return {"logicalLocations": [{"fullyQualifiedName": fq,
                                  "kind": "function"}]}


def to_sarif(report: dict) -> dict:
    """One SARIF 2.1.0 log for a ``run_sweep`` report."""
    rule_ids: dict[str, int] = {}
    rules: list[dict] = []

    def rule_index(rid: str, description: str = "") -> int:
        if rid not in rule_ids:
            rule_ids[rid] = len(rules)
            entry: dict = {"id": rid}
            if description:
                entry["shortDescription"] = {"text": description}
            rules.append(entry)
        return rule_ids[rid]

    for name, meta in sorted(report.get("rules", {}).items()):
        rule_index(name, meta.get("description", ""))
    rule_index("trace", "target could not be traced (reported, non-fatal)")
    rule_index("baseline", "suppression-file hygiene: entries carry a "
                           "reason and an unexpired lease")

    results: list[dict] = []
    for f in report.get("findings", []):
        sev = str(f.get("severity", "warn"))
        res: dict = {
            "ruleId": str(f.get("rule", "unknown")),
            "ruleIndex": rule_index(str(f.get("rule", "unknown"))),
            "level": _LEVELS.get(sev, "warning"),
            "message": {"text": str(f.get("message", ""))},
            "locations": [_location(f)],
            "properties": {"target": f.get("target", "")},
        }
        if sev == "suppressed":
            res["suppressions"] = [{
                "kind": "external",
                "justification": str(f.get("suppressed_reason", "")),
            }]
            res["properties"]["suppressedUntil"] = \
                f.get("suppressed_until", "")
        results.append(res)

    return {
        "$schema": SARIF_SCHEMA,
        "version": SARIF_VERSION,
        "runs": [{
            "tool": {"driver": {
                "name": "repro.analysis",
                "rules": rules,
            }},
            "results": results,
            "properties": {
                "ok": bool(report.get("ok", False)),
                "targets": len(report.get("targets", [])),
                "skipped": len(report.get("skipped", [])),
            },
        }],
    }
