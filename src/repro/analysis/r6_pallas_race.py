"""R6 pallas-race: output blocks are visited once or merged associatively.

The worklist-driven sweep *deliberately* revisits output row tiles — many
worklist entries share a row tile, and ``gather_nn``'s doubled column grid
revisits every output block ``2 * nbc`` times.  That is only sound because
every revisit-path write is either an associative accumulate/merge of the
block's old value (``+`` / min / max / the kept-k lexicographic merge) or a
first-visit init under a grid/prefetch-pure guard.  A plain overwrite on a
revisited block is a lost update: the last worklist entry wins and every
earlier tile's contribution silently disappears — exactly what mutating
``kernels/sweep.py``'s ``_merge_topk`` into a passthrough would ship.

Per ``pallas_call``:

* every *output* block mapping's index map is evaluated over the symbolic
  grid (``absint.eval_index_map`` + ``visit_verdict``); blocks proved to be
  visited ``once`` need no write discipline;
* for ``revisit`` / ``data`` / ``unknown`` outputs, every kernel-body write
  to that output ref must classify as ``rmw-clean`` (associative merge of
  the old value) or ``overwrite-guarded`` (init under a pure guard):
  ``rmw-dirty`` and plain ``overwrite`` are findings;
* ``input_output_aliases`` entries whose aliased input is read anywhere in
  the body are read-write aliasing findings (the read races the output
  pipeline's writes to the shared buffer).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from .rules import Finding, Rule, register_rule

RULE_NAME = "R6-pallas-race"

_UNSAFE_KINDS = ("rmw-dirty", "overwrite")


def _check_pallas_eqn(target: str, site: Any) -> list[Finding]:
    from . import absint

    eqn = site.eqn
    gm = eqn.params.get("grid_mapping")
    body = eqn.params.get("jaxpr")
    out: list[Finding] = []
    where = site.where + "/pallas_call"
    name_info = eqn.params.get("name_and_src_info")
    kernel = str(name_info) if name_info is not None else "<kernel>"

    def finding(msg: str) -> None:
        out.append(Finding(rule=RULE_NAME, severity="error", target=target,
                           message=f"{kernel}: {msg}", where=where))

    if gm is None or body is None:
        finding("pallas_call eqn carries no grid_mapping/jaxpr params "
                "(jax version drift? — re-probe the eqn layout)")
        return out

    grid = tuple(getattr(gm, "grid", ()) or ())
    n_pf = int(getattr(gm, "num_index_operands", 0) or 0)
    n_in = int(getattr(gm, "num_inputs", 0) or 0)
    n_out = int(getattr(gm, "num_outputs", 0) or 0)
    bms = tuple(getattr(gm, "block_mappings", ()) or ())
    out_bms = [bm for bm in bms
               if str(getattr(bm, "origin", "")).startswith("output")]
    if len(out_bms) != n_out:           # origin format drift: positional
        out_bms = list(bms[n_in:n_in + n_out])

    writes, reads = absint.classify_kernel_writes(body, n_pf, n_in, n_out)

    for k, bm in enumerate(out_bms):
        imj = getattr(bm, "index_map_jaxpr", None)
        if imj is None:
            finding(f"output {k}: block mapping carries no index_map_jaxpr")
            continue
        dims = absint.eval_index_map(imj, len(grid))
        verdict = absint.visit_verdict(dims, grid)
        if verdict == "once":
            continue
        bad = [w for w in writes
               if w.slot in (k, -1) and w.kind in _UNSAFE_KINDS]
        for w in bad:
            how = ("old value crosses non-associative ops before the "
                   "write-back" if w.kind == "rmw-dirty" else
                   "plain overwrite (no merge of the block's prior value, "
                   "no pure first-visit guard)")
            finding(f"output {k} blocks are revisited across the grid "
                    f"(visit verdict: {verdict}) but the write at "
                    f"{w.path} is a {w.kind}: {how} — a revisited block "
                    f"loses every earlier tile's contribution")

    aliases = tuple(eqn.params.get("input_output_aliases") or ())
    for pair in aliases:
        try:
            i_in, i_out = int(pair[0]), int(pair[1])
        except (TypeError, ValueError, IndexError):
            continue
        # alias indices count the call's flattened operands (scalar
        # prefetch included); probe both interpretations of the input slot
        cand = {("input", i_in), ("input", i_in - n_pf)}
        if cand & reads:
            finding(f"input {i_in} is aliased onto output {i_out} and read "
                    f"inside the kernel body — read-write aliasing: the "
                    f"read races the output pipeline's writes to the "
                    f"shared buffer")
    return out


@dataclass(frozen=True)
class PallasRaceRule(Rule):
    name: str = RULE_NAME
    description: str = ("pallas_call output blocks are visited once or only "
                        "updated through associative accumulates; aliased "
                        "inputs are never read")
    kind: str = "jaxpr"

    def check_jaxpr(self, target: str, closed_jaxpr: Any) -> list[Finding]:
        from .walker import iter_sites

        out: list[Finding] = []
        for site in iter_sites(closed_jaxpr):
            if site.eqn.primitive.name == "pallas_call":
                out.extend(_check_pallas_eqn(target, site))
        return out


register_rule(PallasRaceRule())
