"""R8 determinism: non-associative float reductions need a blessing.

Float addition is not associative; any reduction whose *operand order* is
not pinned by the program can move bits when the mesh, device count or
lowering changes.  Two shapes in this tree have that property:

* a float ``psum`` inside a shard_map mapped over a multi-partition axis —
  the all-reduce combines per-device partials in an order chosen by the
  runtime's reduction topology (ring vs tree, device count);
* a float ``scatter-add`` whose indices are not proven unique
  (``unique_indices=False``) — duplicate slots accumulate in an order the
  lowering picks, and XLA does not promise one.

Neither shape is a bug per se: counts summed in f32 are integer-exact
under any order, and some accumulations tolerate last-bit wobble by
design.  What *is* a bug is shipping one silently.  R8 therefore flags
every such site that is not lexically inside an
:func:`repro.analysis.audit.audit_determinism`-decorated function (matched
through the traced eqn's source frames, same mechanism as R2's check_rep
audits): **error** when the reduction's value flows to the trace's
outputs (user-visible labels / centers / densities), **warn** when it
stays internal.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from .rules import Finding, Rule, register_rule

RULE_NAME = "R8-determinism"


def _is_float(v: Any) -> bool:
    return "float" in str(getattr(getattr(v, "aval", None), "dtype", ""))


def _blessed(eqn: Any, index: dict) -> object | None:
    """The determinism audit covering this eqn's source site, if any."""
    from jax._src import source_info_util

    try:
        frames = list(source_info_util.user_frames(eqn.source_info))
    except Exception:       # noqa: BLE001 — source info is best-effort
        return None
    for fr in frames:
        rec = index.get((fr.file_name, fr.function_name))
        if rec is not None:
            return rec
    return None


def _src_of(eqn: Any) -> str:
    from jax._src import source_info_util

    try:
        fr = source_info_util.summarize(eqn.source_info)
        return str(fr)
    except Exception:       # noqa: BLE001
        return "<unknown source>"


def _feeds_outputs(jaxpr: Any, eqn: Any) -> bool:
    """Forward closure from ``eqn``'s outputs within its containing jaxpr:
    does the reduction's value reach the jaxpr's outvars?  Conservative —
    any consumer propagates (incl. opaque sub-jaxpr calls)."""
    from jax._src import core as jcore

    reached = set(map(id, eqn.outvars))
    seen = False
    for e in jaxpr.eqns:
        if not seen:
            seen = e is eqn
            continue
        if any(not isinstance(v, jcore.Literal) and id(v) in reached
               for v in e.invars):
            reached.update(map(id, e.outvars))
    return any(not isinstance(v, jcore.Literal) and id(v) in reached
               for v in jaxpr.outvars)


def _check(target: str, closed: Any) -> list[Finding]:
    from .audit import determinism_audit_index
    from .walker import shard_ctx_of, sub_jaxprs, unwrap

    index = determinism_audit_index()
    out: list[Finding] = []

    def visit(jaxpr: Any, path: tuple[str, ...], shard: Any) -> None:
        jaxpr = unwrap(jaxpr)
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            hit = None
            # jax 0.4.37 traces lax.psum as "psum2" inside shard_map
            # bodies and "psum" under pmap — match both spellings
            if (name in ("psum", "psum2") and shard is not None
                    and shard.multi_partition
                    and any(_is_float(v) for v in eqn.invars)):
                hit = ("float psum over a multi-partition axis: the "
                       "all-reduce combines per-device partials in a "
                       "runtime-chosen order (ring vs tree varies with "
                       "device count)")
            elif (name == "scatter-add"
                    and eqn.params.get("unique_indices") is False
                    and any(_is_float(v) for v in eqn.invars)):
                hit = ("float scatter-add with possibly-duplicate indices: "
                       "colliding slots accumulate in a lowering-chosen "
                       "order XLA does not pin")
            if hit is not None and _blessed(eqn, index) is None:
                feeds = _feeds_outputs(jaxpr, eqn)
                sev = "error" if feeds else "warn"
                flow = ("feeds the trace's outputs" if feeds
                        else "stays internal to the trace")
                out.append(Finding(
                    rule=RULE_NAME, severity=sev, target=target,
                    message=(f"{hit}; the value {flow} and the site at "
                             f"{_src_of(eqn)} carries no "
                             f"@audit_determinism blessing — state why "
                             f"the order cannot move the result (or that "
                             f"the wobble is accepted) on the containing "
                             f"function"),
                    where="/".join(path + (name,)) or name))
            sub_shard = shard_ctx_of(eqn) if name == "shard_map" else shard
            for key, sub in sub_jaxprs(eqn):
                visit(sub, path + (f"{name}.{key}",), sub_shard)

    visit(closed, (), None)
    return out


@dataclass(frozen=True)
class DeterminismRule(Rule):
    name: str = RULE_NAME
    description: str = ("non-associative float reductions (multi-device "
                        "psum, duplicate-index scatter-add) carry an "
                        "@audit_determinism blessing; unannotated sites "
                        "feeding user-visible outputs are errors")
    kind: str = "jaxpr"

    def check_jaxpr(self, target: str, closed_jaxpr: Any) -> list[Finding]:
        return _check(target, closed_jaxpr)


register_rule(DeterminismRule())
