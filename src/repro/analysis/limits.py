"""Per-platform kernel memory limits, shared by R4 and R9.

One table replaces R4's old hard-coded ``_SMEM_MAX_ELEMS = 1 << 20``
constant: budgets are looked up from the platform a ``pallas_call``
actually targets (its ``backend`` param when set; otherwise the kernels in
this tree are Mosaic TPU kernels — interpret mode runs them on CPU but
models the TPU memory hierarchy, so the TPU budgets apply there too).

Defaults are deliberately conservative fractions of real hardware (TPU v4
VMEM is 128 MiB; we budget 16 MiB so a kernel that fits here fits every
generation back to v2, double-buffering included).  The SMEM budget equals
the old R4 constant (2^20 four-byte scalars) so the R4 contract is
unchanged by the table refactor.

Environment overrides (operators raising/lowering the gate without a code
change)::

    REPRO_LIMIT_VMEM_BYTES      per-pallas_call VMEM budget
    REPRO_LIMIT_SMEM_BYTES      per-pallas_call SMEM budget
    REPRO_LIMIT_LIVE_BYTES      whole-trace live-buffer budget for dense
                                jnp paths (unset = report-only, no gate)

This module is jax-free (importable before backends initialize).
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Any

__all__ = ["KernelLimits", "limits_for_platform", "limits_for_eqn",
           "live_budget_bytes"]

_MIB = 1 << 20


@dataclass(frozen=True)
class KernelLimits:
    """Memory budgets for one target platform, in bytes."""

    platform: str
    vmem_bytes: int
    smem_bytes: int

    def to_dict(self) -> dict:
        return {"platform": self.platform,
                "vmem_bytes": self.vmem_bytes,
                "smem_bytes": self.smem_bytes}


# the R4-compatible SMEM budget: 2^20 four-byte scalars
_SMEM_DEFAULT = 4 * _MIB

_TABLE: dict[str, KernelLimits] = {
    "tpu": KernelLimits("tpu", vmem_bytes=16 * _MIB,
                        smem_bytes=_SMEM_DEFAULT),
    # Mosaic GPU shared memory is far smaller than TPU VMEM; nothing in
    # this tree targets it yet, so the budget is the Hopper 228 KiB smem
    # ceiling with VMEM modelling L1/register residency per block.
    "gpu": KernelLimits("gpu", vmem_bytes=228 * 1024,
                        smem_bytes=48 * 1024),
}


def _env_int(name: str) -> int | None:
    raw = os.environ.get(name, "").strip()
    if not raw:
        return None
    try:
        return int(raw)
    except ValueError:
        return None


def limits_for_platform(platform: str | None) -> KernelLimits:
    """Budget row for a resolved platform (unknown/None -> the TPU row:
    every pallas kernel in this tree is written against ``pltpu``)."""
    key = (platform or "tpu").lower()
    if key in ("cpu", "interpret", "mosaic", "mosaic_tpu", "tpu"):
        key = "tpu"
    elif key not in _TABLE:
        key = "tpu"
    base = _TABLE[key]
    vmem = _env_int("REPRO_LIMIT_VMEM_BYTES")
    smem = _env_int("REPRO_LIMIT_SMEM_BYTES")
    if vmem is None and smem is None:
        return base
    return KernelLimits(base.platform,
                        vmem_bytes=vmem if vmem is not None
                        else base.vmem_bytes,
                        smem_bytes=smem if smem is not None
                        else base.smem_bytes)


def limits_for_eqn(eqn: Any) -> KernelLimits:
    """Budget row for one ``pallas_call`` eqn: its ``backend`` param when
    the call pinned one, else the TPU row (Mosaic kernels under interpret
    mode still model the TPU memory hierarchy)."""
    backend = eqn.params.get("backend") if hasattr(eqn, "params") else None
    return limits_for_platform(str(backend) if backend else None)


def live_budget_bytes() -> int | None:
    """Whole-trace live-buffer budget for dense jnp paths, or None when the
    gate is report-only (the default: dense peaks scale with the caller's n,
    so a hard default would fail legitimate large fits)."""
    return _env_int("REPRO_LIMIT_LIVE_BYTES")
