"""Traced targets: the canonical computations the analyzer checks.

Two consumers share these traces:

* **plan time** — ``repro.engine.planner.plan`` calls :func:`analyze_plan`
  on every fresh plan (memoized per spec): the plan's two driver-facing
  primitives are traced on a small canonical input and every jaxpr rule
  runs over them, so an ExecSpec that would dispatch into a flagged
  kernel path fails at ``plan()`` — before any data is touched.
* **the CLI sweep** (``python -m repro.analysis``) — every valid ExecSpec
  combo x every subsystem entry point: the batch primitives (as at plan
  time), the distributed phase shard_maps exactly as ``distributed_dpc``
  would assemble them for that plan (halo / dense / stencil dispatch
  mirrored, including the block-sparse shard-layout guard), the sharded
  stream repair, and the DPC-KV per-head compression.

Every target is a *trace* (``jax.make_jaxpr``) — nothing executes, so the
pallas targets work on hosts with no TPU and the distributed targets only
need ``--xla_force_host_platform_device_count`` (the CLI sets it).

Targets that a plan cannot express return alongside a skip *reason*
(e.g. DPC-KV rejects host-worklist layouts at construction) rather than a
finding: an impossible combination is the validation table working, not a
defect — R5 checks that table separately.
"""
from __future__ import annotations

import numpy as np
from typing import Any

from .rules import Finding, analyze_jaxpr

# Canonical trace input: small (tracing cost rides every plan() miss),
# 2-D (the paper's regime), sized to cover multiple jnp row blocks and a
# non-trivial block-sparse grid.  Values are a fixed low-discrepancy-ish
# lattice + deterministic jitter — no RNG, identical across processes.
N_POINTS = 96
DIM = 2
D_CUT = 0.35


def canonical_points() -> np.ndarray:
    i = np.arange(N_POINTS, dtype=np.float32)
    pts = np.stack([(i * 0.6180339887) % 1.0, (i * 0.7548776662) % 1.0], 1)
    return np.ascontiguousarray(pts[:, :DIM], dtype=np.float32)


def _trace_failure(target: str, exc: Exception) -> Finding:
    return Finding(rule="trace", severity="warn", target=target,
                   message=f"could not trace: {type(exc).__name__}: {exc}",
                   where="<trace>")


# --------------------------------------------------------- batch (plan time)
def plan_targets(pl: Any) -> list:
    """``(name, thunk)`` pairs tracing the plan's driver-facing primitives.

    ``fused_traceable`` backends trace ``plan.rho_delta`` / ``plan.denser_nn``
    directly.  The pallas backends' fused path is host-orchestrated (the
    unresolved-tail fallback), so their targets are the traced *segments*
    the host stitches: the fused tile sweep + the f32 direct-diff resolve
    epilogue (the R3 subject), and the masked-NN kernel — with host-built
    worklists when the plan is block-sparse (built here, outside the trace,
    exactly as the backend does).
    """
    import jax
    import jax.numpy as jnp

    from repro.core.dpc_types import density_jitter

    x_np = canonical_points()
    x = jnp.asarray(x_np)
    jitter = density_jitter(N_POINTS)
    rk = jnp.arange(N_POINTS, dtype=jnp.float32)   # all-distinct NN keys
    be = pl.backend
    targets: list[tuple[str, Any]] = []

    if be.fused_traceable:
        targets.append((
            "rho_delta",
            lambda: jax.make_jaxpr(
                lambda a, b: pl.rho_delta(a, b, D_CUT))(x, x)))
        targets.append((
            "denser_nn",
            lambda: jax.make_jaxpr(
                lambda a, ak, b, bk: pl.denser_nn(a, ak, b, bk))(
                    x, rk, x, rk)))
        return targets

    from repro.kernels import blocksparse, ops
    from repro.kernels.backend import _fused_resolve

    interpret = bool(getattr(be, "interpret", False))
    bn = pl.block or ops.DENSITY_BLOCK_N
    nn_bn = min(pl.block or 128, 1024)
    wl = nn_wl = None
    if pl.sparse:
        wl = blocksparse.build_flat_worklist(
            x_np, x_np, D_CUT, block_n=bn, block_m=ops.DENSITY_BLOCK_M,
            count=True, nn="topk", k=ops.FUSED_TOPK)
        nn_wl = blocksparse.build_flat_worklist(
            x_np, x_np, None, block_n=nn_bn, block_m=256, count=False,
            nn="best1")

    def fused(a: Any, b: Any, jit_: Any) -> Any:
        cnt, topv, topi = ops.fused_sweep(
            a, b, D_CUT, precision=pl.precision, block_n=bn,
            interpret=interpret, worklist=wl)
        rho_key = cnt + jit_
        return _fused_resolve(a, b, rho_key, rho_key, topv, topi)

    def masked_nn(a: Any, ak: Any, b: Any, bk: Any) -> Any:
        return ops.dependent_masked(a, ak, b, bk, block_n=nn_bn,
                                    interpret=interpret, worklist=nn_wl)

    targets.append(("fused_sweep+resolve",
                    lambda: jax.make_jaxpr(fused)(x, x, jitter)))
    targets.append(("dependent_masked",
                    lambda: jax.make_jaxpr(masked_nn)(x, rk, x, rk)))
    return targets


def analyze_plan(pl: Any) -> list:
    """Run every jaxpr rule over the plan's canonical traces, then every
    plan rule over the plan itself.  Tracing is side-effect-neutral: an
    armed chaos fault neither fires in here nor has its hit budget spent
    by probe traffic (``faultinject.suspended``)."""
    from repro.resilience import faultinject

    from .rules import plan_rules

    label = f"plan[{pl.backend_name}:{pl.layout}:{pl.precision}]"
    findings: list[Finding] = []
    with faultinject.suspended():
        for name, thunk in plan_targets(pl):
            target = f"{label}:{name}"
            try:
                closed = thunk()
            except Exception as exc:      # noqa: BLE001 — report, don't die
                findings.append(_trace_failure(target, exc))
                continue
            findings.extend(analyze_jaxpr(target, closed))
        for rule in plan_rules():
            try:
                findings.extend(rule.check_plan(pl))
            except Exception as exc:      # noqa: BLE001 — report, don't die
                findings.append(_trace_failure(f"{label}:{rule.name}", exc))
    return findings


# ------------------------------------------------------- sweep-only targets
def distributed_targets(pl: Any) -> tuple[list, list]:
    """The distributed phase shard_maps this plan dispatches, traced on a
    flat mesh over every visible device.  Returns (targets, skip_reasons).

    Mirrors ``distributed_dpc``'s halo / dense / stencil branch selection —
    including the block-sparse shard-layout guard, so these traces show the
    phases that would actually run (and stay clean exactly when the guard
    lets a layout through).
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    devs = jax.devices()
    S = len(devs)
    if S < 2:
        return [], ["distributed: single-device runtime — shard phases "
                    "degenerate (the CLI sweep forces a 4-device host "
                    "platform; in-process callers see plan-time checks only)"]

    from repro.distributed import dpc as ddpc

    be = pl.backend
    axis = pl.data_axis
    mesh = Mesh(np.array(devs), (axis,))
    block = pl.block if pl.block is not None else 256
    rows = 8
    m = S * rows
    span_w = 4
    pts = jnp.zeros((m, DIM), jnp.float32)
    rk = jnp.zeros((m,), jnp.float32)
    starts = jnp.zeros((m, span_w), jnp.int32)
    ends = jnp.zeros((m, span_w), jnp.int32)
    lo_arr = jnp.zeros((S, 1), jnp.int64)

    shard_layout = ddpc.shard_blocksparse_layout(pl, mesh)
    dense = be.mxu_dense or shard_layout == "block-sparse"
    targets: list[tuple[str, Any]] = []

    def add(name: str, fn: Any, in_specs: Any, out_specs: Any,
            args: Any, check_rep: bool = True) -> None:
        sm = shard_map(fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=check_rep)
        targets.append((name, lambda sm=sm, args=args:
                        jax.make_jaxpr(sm)(*args)))

    # halo strategy: reachable for every backend via strategy="halo"
    rho_halo = ddpc._make_rho_halo(axis, D_CUT, block, span_w, S,
                                   2 * rows, 1, 1, be)
    add("halo:rho", rho_halo, (P(axis),) * 5, P(axis),
        (pts, starts, ends, pts, lo_arr), check_rep=not be.mxu_dense)
    delta_halo = ddpc._make_delta_halo(axis, D_CUT, block, span_w, S,
                                       2 * rows, 1, 1, be)
    add("halo:delta", delta_halo, (P(axis),) * 7,
        (P(axis), P(axis), P(axis)),
        (pts, rk, starts, ends, pts, rk, lo_arr),
        check_rep=not be.mxu_dense)

    # gather strategy: dense engine tiles or the grid stencil, per dispatch
    if dense:
        rho_fn = ddpc._make_rho_dense(axis, D_CUT, block, be,
                                      layout=shard_layout)
        add("dense:rho", rho_fn, (P(axis), P(axis)), P(axis),
            (pts, pts), check_rep=False)
        delta_fn = ddpc._make_delta_dense(axis, block, be,
                                          layout=shard_layout)
        add("dense:delta", delta_fn, (P(axis),) * 4,
            (P(axis), P(axis), P(axis)), (pts, rk, pts, rk),
            check_rep=False)
    else:
        rho_fn = ddpc._make_rho(axis, D_CUT, block, span_w)
        add("stencil:rho", rho_fn, (P(axis),) * 4, P(axis),
            (pts, starts, ends, pts))
        delta_fn = ddpc._make_delta(axis, D_CUT, block, span_w)
        add("stencil:delta", delta_fn, (P(axis),) * 6,
            (P(axis), P(axis), P(axis)), (pts, rk, starts, ends, pts, rk))
        fb_fn = ddpc._make_fallback(axis, max(block, 1024), be,
                                    layout=shard_layout)
        add("stencil:fallback", fb_fn, (P(axis),) * 4, (P(axis), P(axis)),
            (pts, rk, pts, rk), check_rep=not be.mxu_dense)
    return targets, []


def stream_targets(pl: Any) -> tuple[list, list]:
    """Every sharded stage of the stream repair tail, traced over every
    visible device: rho repair, dirty-maxima NN re-query (at the plan's
    probe-resolved layout), label propagation and the center-continuity
    distances."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    devs = jax.devices()
    if len(devs) < 2:
        return [], ["stream: single-device runtime — sharded repair "
                    "degenerates (the CLI sweep forces 4 devices)"]
    from repro.distributed.dpc import shard_blocksparse_layout
    from repro.stream.incremental import make_sharded_repair
    from repro.stream.sharded import make_sharded_center_dists, \
        make_sharded_labels, make_sharded_nn_update

    axis = pl.data_axis
    mesh = Mesh(np.array(devs), (axis,))
    S = len(devs)
    repair = make_sharded_repair(mesh, axis, pl.backend, D_CUT)
    m = S * 8
    window = jnp.zeros((m, DIM), jnp.float32)
    rho = jnp.zeros((m,), jnp.float32)
    batch = jnp.zeros((4, DIM), jnp.float32)
    signs = jnp.zeros((4,), jnp.float32)
    ins = jnp.zeros((4, DIM), jnp.float32)
    slots = jnp.zeros((4,), jnp.int32)
    targets = [("stream:sharded_repair",
                lambda: jax.make_jaxpr(repair)(window, rho, batch, signs,
                                               ins, slots))]

    # the post-repair tail: each factory exposes its shard_map body on
    # ``.inner`` (the host wrappers around them do numpy/obs work and are
    # not traceable); the NN stage traces at the plan's probe-resolved
    # layout, so a future R1 regression in the one-hot ring walk surfaces
    # here as well as in the probe
    lay = shard_blocksparse_layout(pl, mesh)
    nn = make_sharded_nn_update(mesh, axis, pl.backend, layout=lay)
    q = jnp.zeros((4, DIM), jnp.float32)
    qk = jnp.zeros((4,), jnp.float32)
    targets.append((f"stream:sharded_nn[{lay or 'dense'}]",
                    lambda: jax.make_jaxpr(nn.inner)(window, rho, q, qk)))

    labels = make_sharded_labels(mesh, axis, m)
    parent = jnp.zeros((m,), jnp.int32)
    targets.append(("stream:sharded_labels",
                    lambda: jax.make_jaxpr(labels.inner)(parent)))

    cdist = make_sharded_center_dists(mesh, axis)
    new_pos = jnp.zeros((S * 2, DIM), jnp.float32)
    prev = jnp.zeros((3, DIM), jnp.float32)
    targets.append(("stream:sharded_center_dists",
                    lambda: jax.make_jaxpr(cdist.inner)(new_pos, prev)))
    return targets, []


def serve_targets(spec: Any) -> tuple[list, list]:
    """DPC-KV per-head compression (fully traced serve path) for a spec."""
    import jax
    import jax.numpy as jnp

    from repro.serve.dpc_kv import DPCKVConfig, _compress_head

    try:
        cfg = DPCKVConfig(budget=8, exec_spec=spec)
    except ValueError as exc:
        return [], [f"serve: spec {spec.describe()} rejected at config "
                    f"time ({exc})"]
    k = jnp.zeros((32, 8), jnp.float32)
    v = jnp.zeros((32, 8), jnp.float32)
    valid = jnp.ones((32,), bool)
    return [("serve:compress_head",
             lambda: jax.make_jaxpr(
                 lambda kk, vv, va: _compress_head(kk, vv, va, cfg))(
                     k, v, valid))], []


# -------------------------------------------------------------- sweep specs
def sweep_specs() -> list:
    """Every ExecSpec combo the sweep analyzes: the default spec plus the
    explicit backend x layout x precision product, minus combos the spec /
    plan validation rejects (R5 checks that rejection table separately)."""
    from repro.engine.spec import ExecSpec, LAYOUTS, PRECISIONS
    from repro.kernels.backend import available_backends, get_backend

    specs = [ExecSpec()]
    for backend in available_backends():
        for layout in (None, *LAYOUTS):
            for precision in (None, *PRECISIONS):
                try:
                    spec = ExecSpec(backend=backend, layout=layout,
                                    precision=precision)
                except ValueError:
                    continue
                if spec.resolved_precision == "bf16" \
                        and not get_backend(backend).mxu_dense:
                    continue
                specs.append(spec)
    return specs
