"""Jaxpr walking + sort-taint propagation (the analyzer's engine).

Everything here is grounded in how jax 0.4.37 actually lowers the
repo's code (probed, not guessed):

* ``jnp.argsort`` lowers to a nested ``pjit`` eqn whose body holds ``iota``
  + ``sort`` — so taint sources hide one call level down and the engine
  must recurse through ``pjit`` bodies.
* scalar indexing ``order[p]`` inside a ``while_loop`` lowers to
  ``dynamic_slice`` (NOT ``gather``) with a traced start index; array
  indexing (``take_along_axis``, ``tbl[idx_array]``) lowers to ``gather``.
  The PR 4 miscompile class therefore covers *both* read primitives.
* ``while`` eqn invars are ``cond_consts + body_consts + carry`` and the
  body jaxpr's invars are ``body_consts + carry``; carry taint needs a
  fixpoint (monotone, so it terminates in <= len(carry) rounds).
* ``shard_map`` eqn params carry the raw body ``Jaxpr`` under ``jaxpr``,
  the ``mesh``, per-operand ``in_names``/``out_names`` dicts and
  ``check_rep``; body invars map 1:1 onto eqn invars.

The taint engine answers R1's question: *does any ``gather`` /
``dynamic_slice`` read use an index derived from a ``sort`` computed in
traced code, inside a shard_map body over a multi-partition axis?*  That
is exactly the shape of the jax-0.4.37 XLA CPU SPMD miscompile that broke
PR 4's distributed block-sparse path (the ring walk's order-gather), and
narrowing the taint source to ``sort`` outputs is what keeps the clean
stencil paths — which gather with *span-table*-derived indices inside the
very same shard_maps, correctly — out of the findings.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Iterator

from jax._src import core as jcore

Jaxpr = jcore.Jaxpr
ClosedJaxpr = jcore.ClosedJaxpr


def unwrap(j: Any) -> Any:
    """ClosedJaxpr | Jaxpr -> Jaxpr."""
    return j.jaxpr if isinstance(j, ClosedJaxpr) else j


def sub_jaxprs(eqn: Any) -> Iterator[tuple[str, Jaxpr]]:
    """Every sub-jaxpr a primitive's params carry (pjit/while/scan/cond
    bodies, shard_map bodies, pallas kernels), with its param name."""
    for key, val in eqn.params.items():
        if isinstance(val, (Jaxpr, ClosedJaxpr)):
            yield key, unwrap(val)
        elif isinstance(val, (tuple, list)):
            for i, item in enumerate(val):
                if isinstance(item, (Jaxpr, ClosedJaxpr)):
                    yield f"{key}[{i}]", unwrap(item)


@dataclass(frozen=True)
class ShardCtx:
    """The shard_map context an eqn sits inside."""

    axis_sizes: tuple[tuple[str, int], ...]   # mapped mesh axes and sizes
    check_rep: bool

    @property
    def multi_partition(self) -> bool:
        return any(s > 1 for _, s in self.axis_sizes)


def shard_ctx_of(eqn: Any) -> ShardCtx:
    """Build the ShardCtx for a shard_map eqn (defensive over param shape)."""
    mesh = eqn.params.get("mesh")
    names: set = set()
    for spec in tuple(eqn.params.get("in_names") or ()) + \
            tuple(eqn.params.get("out_names") or ()):
        if isinstance(spec, dict):
            for axes in spec.values():
                names.update(axes if isinstance(axes, (tuple, list))
                             else (axes,))
    sizes: list[tuple[str, int]] = []
    shape = getattr(mesh, "shape", None)
    if shape:
        for ax, sz in dict(shape).items():
            if not names or ax in names:
                sizes.append((str(ax), int(sz)))
    return ShardCtx(axis_sizes=tuple(sizes),
                    check_rep=bool(eqn.params.get("check_rep", True)))


@dataclass(frozen=True)
class Site:
    """One eqn with its nesting path and innermost shard_map context."""

    eqn: Any
    path: tuple[str, ...]
    shard: ShardCtx | None

    @property
    def where(self) -> str:
        return "/".join(self.path) or "<top>"


def iter_sites(jaxpr: Any, path: tuple[str, ...] = (),
               shard: ShardCtx | None = None) -> Iterator[Site]:
    """Recursively yield every eqn in the program as a :class:`Site`.

    Structural iteration only — no dataflow.  Used by the shape/dtype
    rules (R3, R4); R1 uses the taint engine below, which needs value
    tracking the Site stream cannot carry.
    """
    jaxpr = unwrap(jaxpr)
    for eqn in jaxpr.eqns:
        yield Site(eqn=eqn, path=path, shard=shard)
        name = eqn.primitive.name
        sub_shard = shard_ctx_of(eqn) if name == "shard_map" else shard
        for key, sub in sub_jaxprs(eqn):
            yield from iter_sites(sub, path + (f"{name}.{key}",), sub_shard)


# --------------------------------------------------------------- taint (R1)
# read primitives and their index/start operands: gather's indices are
# invars[1]; dynamic_slice's start indices are invars[1:]
_INDEX_OPERANDS = {
    "gather": lambda eqn: eqn.invars[1:2],
    "dynamic_slice": lambda eqn: eqn.invars[1:],
}

# call-like primitives whose single sub-jaxpr maps invars 1:1
_CALL_JAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr")


@dataclass(frozen=True)
class TaintHit:
    """A sliced read with a sort-tainted index inside a multi-partition
    shard_map body — the R1 pattern."""

    primitive: str
    path: tuple[str, ...]
    shard: ShardCtx

    @property
    def where(self) -> str:
        return "/".join(self.path) or "<top>"


def spmd_sort_tainted_slices(closed_jaxpr: Any, *,
                             require_multi_partition: bool = True
                             ) -> list[TaintHit]:
    """All R1 pattern instances in a traced computation.

    Taint = "derives from a ``sort`` output computed in traced code"
    (conservatively propagated: any tainted operand taints every output,
    carries reach a fixpoint through while/scan).  A hit is a ``gather`` /
    ``dynamic_slice`` whose *index* operands carry taint while inside a
    shard_map body mapped over an axis of size > 1.

    ``require_multi_partition=False`` reports hits inside *any* shard_map
    body regardless of mapped axis sizes — the property tests exercise the
    taint engine on single-device runtimes where no multi-partition mesh
    exists; R1 itself always uses the default.
    """
    hits: list[TaintHit] = []

    def sub_run(inner: Any, in_t: list[bool], path: tuple[str, ...],
                shard: ShardCtx | None, report: bool,
                eqn: Any) -> list[bool]:
        """Recurse into a call-like sub-jaxpr; conservative on mismatch."""
        j = unwrap(inner)
        if len(j.invars) != len(in_t):
            return [any(in_t)] * len(eqn.outvars)
        return run(j, in_t, path, shard, report)

    def run(jaxpr: Any, in_taint: list[bool], path: tuple[str, ...],
            shard: ShardCtx | None, report: bool) -> list[bool]:
        jaxpr = unwrap(jaxpr)
        env: dict[Any, bool] = {}

        def get(v: Any) -> bool:
            if isinstance(v, jcore.Literal):
                return False
            return env.get(v, False)

        for v, t in zip(jaxpr.invars, in_taint):
            env[v] = bool(t)

        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            in_t = [get(v) for v in eqn.invars]

            if report and shard is not None and \
                    (shard.multi_partition or not require_multi_partition):
                pick = _INDEX_OPERANDS.get(name)
                if pick is not None and any(get(v) for v in pick(eqn)):
                    hits.append(TaintHit(primitive=name, path=path,
                                         shard=shard))

            if name == "sort":
                out_t = [True] * len(eqn.outvars)
            elif name == "while":
                cn = eqn.params["cond_nconsts"]
                bn = eqn.params["body_nconsts"]
                body = eqn.params["body_jaxpr"]
                cond = eqn.params["cond_jaxpr"]
                consts_t = in_t[cn:cn + bn]
                carry_t = list(in_t[cn + bn:])
                for _ in range(len(carry_t) + 1):
                    out_c = sub_run(body, consts_t + carry_t, path, shard,
                                    False, eqn)
                    new = [a or b for a, b in zip(carry_t, out_c)]
                    if new == carry_t:
                        break
                    carry_t = new
                if report:
                    sub_run(body, consts_t + carry_t,
                            path + ("while.body",), shard, True, eqn)
                    sub_run(cond, list(in_t[:cn]) + carry_t,
                            path + ("while.cond",), shard, True, eqn)
                out_t = carry_t
            elif name == "scan":
                nc = eqn.params["num_consts"]
                ncar = eqn.params["num_carry"]
                body = eqn.params["jaxpr"]
                consts_t = in_t[:nc]
                carry_t = list(in_t[nc:nc + ncar])
                xs_t = in_t[nc + ncar:]
                ys_t: list = []
                for _ in range(len(carry_t) + 1):
                    outs = sub_run(body, consts_t + carry_t + xs_t, path,
                                   shard, False, eqn)
                    new = [a or b for a, b in zip(carry_t, outs[:ncar])]
                    ys_t = list(outs[ncar:])
                    if new == carry_t:
                        break
                    carry_t = new
                if report:
                    outs = sub_run(body, consts_t + carry_t + xs_t,
                                   path + ("scan.body",), shard, True, eqn)
                    ys_t = list(outs[ncar:])
                out_t = carry_t + ys_t
            elif name == "cond":
                branches = eqn.params["branches"]
                op_t = in_t[1:]
                branch_outs = [sub_run(br, op_t,
                                       path + (f"cond.branches[{i}]",),
                                       shard, report, eqn)
                               for i, br in enumerate(branches)]
                out_t = [any(ts) for ts in zip(*branch_outs)] \
                    if branch_outs else [any(in_t)] * len(eqn.outvars)
            elif name == "shard_map":
                sub_shard = shard_ctx_of(eqn)
                out_t = sub_run(eqn.params["jaxpr"], in_t,
                                path + ("shard_map",), sub_shard, report,
                                eqn)
            elif name == "pallas_call":
                # Mosaic kernels are outside the XLA SPMD partitioner (the
                # miscompile class R1 targets); propagate conservatively
                # without descending
                out_t = [any(in_t)] * len(eqn.outvars)
            else:
                inner = next((eqn.params[k] for k in _CALL_JAXPR_PARAMS
                              if isinstance(eqn.params.get(k),
                                            (Jaxpr, ClosedJaxpr))), None)
                if inner is not None:
                    out_t = sub_run(inner, in_t, path + (name,), shard,
                                    report, eqn)
                else:
                    out_t = [any(in_t)] * len(eqn.outvars)

            for v, t in zip(eqn.outvars, out_t):
                env[v] = bool(t)
        return [get(v) for v in jaxpr.outvars]

    j = unwrap(closed_jaxpr)
    run(j, [False] * len(j.invars), (), None, True)
    return hits
