"""Audit annotations: ``check_rep=False`` bodies and determinism blessings.

Two structured "the author thought about this" records, both attached to
functions with zero-wrapper decorators (one attribute set; decorated code
traces exactly as before):

* :func:`audit_check_rep` — ``shard_map(..., check_rep=False)`` switches
  off JAX's replication checking, the mechanism that would catch a body
  producing different values on different mesh members.  Every such body
  in this tree exists because a primitive inside it (``pallas_call``) has
  no replication rule, not because the body is replication-unsafe; the
  decorator records *why* it is safe and *which collectives* make it so.
  Rule R2 fails any unannotated ``check_rep=False`` body.
* :func:`audit_determinism` — a float ``psum`` whose operand order depends
  on the device count, or a float scatter-add with possibly-duplicate
  indices, is a non-associative reduction whose bit pattern can move when
  the mesh or lowering changes.  The decorator records why a specific site
  is nevertheless deterministic (integer-exact values, tolerated
  approximation, ...).  Rule R8 fails any unannotated site that feeds
  user-visible outputs; annotated sites are matched through the traced
  eqn's source frames (file + function name), so the blessing sits on the
  function that *contains* the reduction.

This module stays jax-free (kernel modules import it at definition time).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, TypeVar

_F = TypeVar("_F", bound=Callable[..., Any])


@dataclass(frozen=True)
class CheckRepAudit:
    """One audited ``check_rep=False`` body: the replication-safety argument."""

    qualname: str
    module: str
    reason: str
    collectives: tuple[str, ...] = field(default=())

    @property
    def key(self) -> str:
        return f"{self.module}.{self.qualname}"


_REGISTRY: dict[str, CheckRepAudit] = {}

_AUDIT_ATTR = "__check_rep_audit__"


def audit_check_rep(reason: str, *,
                    collectives: tuple[str, ...] | list[str] = ()
                    ) -> Callable[[_F], _F]:
    """Annotate a shard_map body as audited for ``check_rep=False``.

    ``reason`` states why the body is replication-safe; ``collectives``
    names the collective primitives (``all_gather``, ``psum``, ``ppermute``,
    ...) whose semantics the argument rests on.  The decorated function is
    returned unchanged.
    """
    if not reason or not reason.strip():
        raise ValueError("audit_check_rep needs a non-empty reason: the "
                         "annotation exists to record the safety argument")

    def deco(fn: _F) -> _F:
        rec = CheckRepAudit(qualname=fn.__qualname__, module=fn.__module__,
                            reason=" ".join(reason.split()),
                            collectives=tuple(collectives))
        setattr(fn, _AUDIT_ATTR, rec)
        _REGISTRY[rec.key] = rec
        return fn

    return deco


def audit_of(fn: Any) -> CheckRepAudit | None:
    """The audit record attached to ``fn``, or None."""
    return getattr(fn, _AUDIT_ATTR, None)


def all_audits() -> dict[str, CheckRepAudit]:
    """Every audit registered so far (importing a module registers its
    decorated bodies); keys are ``module.qualname``."""
    return dict(_REGISTRY)


# ------------------------------------------------------------- determinism
@dataclass(frozen=True)
class DeterminismAudit:
    """One blessed non-associative reduction site: the determinism argument.

    ``file_name`` / ``function_name`` are the match keys R8 compares
    against the traced eqn's ``source_info`` user frames — the blessing
    covers every flagged reduction *lexically inside* the decorated
    function, nothing else.
    """

    qualname: str
    module: str
    reason: str
    file_name: str
    function_name: str
    ops: tuple[str, ...] = field(default=())

    @property
    def key(self) -> str:
        return f"{self.module}.{self.qualname}"


_DET_REGISTRY: dict[str, DeterminismAudit] = {}

_DET_AUDIT_ATTR = "__determinism_audit__"


def audit_determinism(reason: str, *,
                      ops: tuple[str, ...] | list[str] = ()
                      ) -> Callable[[_F], _F]:
    """Annotate a function whose non-associative float reductions are
    deliberate and deterministic (or whose nondeterminism is accepted).

    ``reason`` states the argument — e.g. *counts are integer-exact in
    f32, so every summation order produces the same bits*; ``ops`` names
    the reduction primitives the argument covers (``psum``,
    ``scatter-add``, ...).  The decorated function is returned unchanged.
    """
    if not reason or not reason.strip():
        raise ValueError("audit_determinism needs a non-empty reason: the "
                         "annotation exists to record the determinism "
                         "argument")

    def deco(fn: _F) -> _F:
        code = fn.__code__
        rec = DeterminismAudit(qualname=fn.__qualname__,
                               module=fn.__module__,
                               reason=" ".join(reason.split()),
                               file_name=code.co_filename,
                               function_name=fn.__name__,
                               ops=tuple(ops))
        setattr(fn, _DET_AUDIT_ATTR, rec)
        _DET_REGISTRY[rec.key] = rec
        return fn

    return deco


def determinism_audit_of(fn: Any) -> DeterminismAudit | None:
    """The determinism audit attached to ``fn``, or None."""
    return getattr(fn, _DET_AUDIT_ATTR, None)


def all_determinism_audits() -> dict[str, DeterminismAudit]:
    """Every determinism audit registered so far, keyed
    ``module.qualname``."""
    return dict(_DET_REGISTRY)


def determinism_audit_index() -> dict[tuple[str, str], DeterminismAudit]:
    """The R8 match index: ``(file_name, function_name)`` -> audit."""
    return {(a.file_name, a.function_name): a
            for a in _DET_REGISTRY.values()}
