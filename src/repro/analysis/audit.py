"""Audit annotations for ``check_rep=False`` shard_map bodies.

``shard_map(..., check_rep=False)`` switches off JAX's replication checking
— the mechanism that would catch a body producing different values on
different mesh members.  Every such body in this tree exists because a
primitive inside it (``pallas_call``) has no replication rule, not because
the body is actually replication-unsafe; but that argument lives in the
author's head unless it is written down where a tool can see it.

:func:`audit_check_rep` is that writing-down: it attaches a structured
record — *why* the body is replication-safe and *which collectives* make it
so — to the body function and registers it in a process-wide table.  The
decorator returns the function unchanged (one attribute set, no wrapper),
so decorated bodies trace exactly as before.

Rule R2 (``repro.analysis.r2_check_rep``) fails any ``check_rep=False``
shard_map whose body does not carry one of these annotations.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class CheckRepAudit:
    """One audited ``check_rep=False`` body: the replication-safety argument."""

    qualname: str
    module: str
    reason: str
    collectives: tuple[str, ...] = field(default=())

    @property
    def key(self) -> str:
        return f"{self.module}.{self.qualname}"


_REGISTRY: dict[str, CheckRepAudit] = {}

_AUDIT_ATTR = "__check_rep_audit__"


def audit_check_rep(reason: str, *, collectives: tuple[str, ...] | list[str] = ()):
    """Annotate a shard_map body as audited for ``check_rep=False``.

    ``reason`` states why the body is replication-safe; ``collectives``
    names the collective primitives (``all_gather``, ``psum``, ``ppermute``,
    ...) whose semantics the argument rests on.  The decorated function is
    returned unchanged.
    """
    if not reason or not reason.strip():
        raise ValueError("audit_check_rep needs a non-empty reason: the "
                         "annotation exists to record the safety argument")

    def deco(fn):
        rec = CheckRepAudit(qualname=fn.__qualname__, module=fn.__module__,
                            reason=" ".join(reason.split()),
                            collectives=tuple(collectives))
        setattr(fn, _AUDIT_ATTR, rec)
        _REGISTRY[rec.key] = rec
        return fn

    return deco


def audit_of(fn) -> CheckRepAudit | None:
    """The audit record attached to ``fn``, or None."""
    return getattr(fn, _AUDIT_ATTR, None)


def all_audits() -> dict[str, CheckRepAudit]:
    """Every audit registered so far (importing a module registers its
    decorated bodies); keys are ``module.qualname``."""
    return dict(_REGISTRY)
