"""``python -m repro.analysis``: the full static-analysis sweep, as CI runs it.

Environment is pinned *before* any jax computation (jax initializes its
backend lazily, so setting these after ``import repro`` but before first
device use still works): CPU platform, 4 host devices — the distributed
and stream shard_map targets need a multi-partition mesh to mean anything.

Exit status 1 iff error-severity findings exist (warn-only reports pass).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _pin_environment() -> None:
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = \
            (flags + " --xla_force_host_platform_device_count=4").strip()


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr-level static analysis sweep over every ExecSpec "
                    "combo and subsystem entry point")
    parser.add_argument("--out", default="-",
                        help="write the JSON report here ('-' = stdout)")
    parser.add_argument("--pretty", action="store_true",
                        help="indent the JSON report")
    parser.add_argument("--root", default=None,
                        help="repo root for the project rules "
                             "(default: derived from the package location)")
    parser.add_argument("--obs-snapshot", default=None,
                        help="also write the repro.obs metrics/trace "
                             "snapshot accumulated during the sweep here")
    parser.add_argument("--sarif", default=None,
                        help="also write the report as SARIF 2.1.0 here "
                             "(for code-host/IDE problem panes)")
    parser.add_argument("--baseline", default=None,
                        help="suppression file (default: "
                             "analysis-baseline.json at the repo root); "
                             "entries carry a reason and an expiry date")
    args = parser.parse_args(argv)

    _pin_environment()
    from .report import run_sweep

    report = run_sweep(args.root, baseline_path=args.baseline)
    if args.sarif:
        from .sarif import to_sarif

        with open(args.sarif, "w", encoding="utf-8") as fh:
            json.dump(to_sarif(report), fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"analysis: wrote SARIF to {args.sarif}", file=sys.stderr)
    if args.obs_snapshot:
        from repro.obs import report as obs_report
        obs_report.export_snapshot(args.obs_snapshot)
        print(f"analysis: wrote obs snapshot to {args.obs_snapshot}",
              file=sys.stderr)
    text = json.dumps(report, indent=2 if args.pretty else None,
                      sort_keys=True)
    if args.out == "-":
        print(text)
    else:
        with open(args.out, "w", encoding="utf-8") as fh:
            fh.write(text + "\n")

    errors = [f for f in report["findings"] if f["severity"] == "error"]
    suppressed = [f for f in report["findings"]
                  if f["severity"] == "suppressed"]
    warns = [f for f in report["findings"]
             if f["severity"] not in ("error", "suppressed")]
    print(f"analysis: {len(report['targets'])} targets, "
          f"{len(report['skipped'])} skipped, {len(errors)} error(s), "
          f"{len(suppressed)} suppressed, {len(warns)} warning(s)",
          file=sys.stderr)
    for f in errors:
        print(f"  [{f['rule']}] {f['target']} @ {f['where']}: "
              f"{f['message']}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
