"""R3 precision-flow: bf16 matmul accumulations must reach an f32
direct-difference refinement before their winners are consumed.

The mixed-precision sweep (``kernels/sweep.py``) evaluates expanded-form
squared distances with a bf16 inner product — absolute error
~eps*(|x|^2+|y|^2), which is a *large relative* error for small distances
and flips near-tie argmins.  The contract (PR 3) is that every bf16 path
re-evaluates its kept candidates in direct-difference f32
(``refine_topk_d2`` / ``_fused_resolve``: ``sum((x - y_sel)**2)``) so the
winner and its value are exact whenever the true NN is within the kept k.

A refactor that drops the refinement epilogue changes no shapes and no
tests on well-separated data — exactly the silent-regression class a
static check catches.  R3 fires when a traced computation contains a
``dot_general`` with bf16 operands but no f32 direct-diff square-sum
chain (``sub`` -> ``integer_pow(2)``/``mul(x,x)`` -> ``reduce_sum``)
anywhere in the program (pallas kernel bodies included — the walker
descends into ``pallas_call`` jaxprs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from .rules import Finding, Rule, register_rule

RULE_NAME = "R3-precision-flow"


def _is_bf16(var: Any) -> bool:
    aval = getattr(var, "aval", None)
    return str(getattr(aval, "dtype", "")) == "bfloat16"


def _is_wide(var: Any) -> bool:
    """f32-or-wider: the refinement contract says *direct-diff in at least
    f32*; under x64 mode the same epilogue traces as f64."""
    aval = getattr(var, "aval", None)
    return str(getattr(aval, "dtype", "")) in ("float32", "float64")


def _jaxpr_has_refinement(jaxpr: Any) -> bool:
    """One jaxpr level: sub -> square -> reduce_sum in f32-or-wider?"""
    producer: dict[Any, Any] = {}
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            producer[v] = eqn
    for eqn in jaxpr.eqns:
        if eqn.primitive.name != "reduce_sum" \
                or not _is_wide(eqn.outvars[0]):
            continue
        src = producer.get(eqn.invars[0])
        if src is None:
            continue
        sq = src.primitive.name == "integer_pow" \
            and src.params.get("y") == 2
        sq = sq or (src.primitive.name == "mul"
                    and src.invars[0] is src.invars[1])
        if not sq:
            continue
        diff = producer.get(src.invars[0])
        if diff is not None and diff.primitive.name == "sub":
            return True
    return False


@dataclass(frozen=True)
class PrecisionFlowRule(Rule):
    name: str = RULE_NAME
    description: str = ("bf16 dot_general accumulations must be followed by "
                        "an f32 direct-diff refinement (sub -> square -> "
                        "reduce_sum) before winners are consumed")
    kind: str = "jaxpr"

    def check_jaxpr(self, target: str, closed_jaxpr: Any) -> list[Finding]:
        from .walker import iter_sites, sub_jaxprs, unwrap

        bf16_dot = None
        refined = False
        seen_jaxprs: list[Any] = []

        def collect(jaxpr: Any) -> None:
            seen_jaxprs.append(unwrap(jaxpr))
            for eqn in unwrap(jaxpr).eqns:
                for _k, sub in sub_jaxprs(eqn):
                    collect(sub)

        collect(closed_jaxpr)
        for jaxpr in seen_jaxprs:
            if not refined and _jaxpr_has_refinement(jaxpr):
                refined = True
        for site in iter_sites(closed_jaxpr):
            if site.eqn.primitive.name == "dot_general" \
                    and any(_is_bf16(v) for v in site.eqn.invars[:2]):
                bf16_dot = site
                break
        if bf16_dot is None or refined:
            return []
        return [Finding(
            rule=self.name, severity="error", target=target,
            message=("bf16 dot_general accumulation with no f32 direct-"
                     "diff refinement epilogue in the traced computation "
                     "— expanded-form d2 error flips near-tie NN winners "
                     "(the refine_topk_d2 / _fused_resolve contract, "
                     "kernels/sweep.py)"),
            where=bf16_dot.where)]


register_rule(PrecisionFlowRule())
