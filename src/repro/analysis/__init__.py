"""repro.analysis: jaxpr-level static analysis for the DPC engine.

Born from PR 4/5's silently-wrong distributed block-sparse results (the
pinned jax-0.4.37 XLA CPU SPMD pipeline miscompiles sort-derived gathers
inside multi-partition ``shard_map`` bodies): the class of bug that passes
every unit test on one device and corrupts results on four deserves a
static check, not a memory.  Five rules walk traced computations and the
source tree:

=====================  =====================================================
R1-spmd-gather         sort-tainted dynamic indices feeding gather /
                       dynamic_slice inside multi-partition shard_map — the
                       miscompile class itself; also the re-enablement gate
                       for distributed block-sparse (``spmd_gather_safe``)
R2-check-rep-audit     every ``check_rep=False`` shard_map body carries an
                       ``@audit_check_rep`` replication-safety annotation
R3-precision-flow      bf16 dot_general accumulations reach the f32
                       direct-diff refinement epilogue
R4-pallas-legality     pallas_call grid/block divisibility, SMEM scalar
                       prefetch placement, host-static grids
R5-spec-coverage       ExecSpec axes x validation x dispatch x tests stay
                       mutually exhaustive
=====================  =====================================================

Rules run (a) at plan time — ``repro.engine.planner.plan`` analyzes each
fresh plan's canonical traces (``REPRO_ANALYSIS=0`` bypasses) — and (b) in
the CLI sweep, ``python -m repro.analysis``, which CI gates on.

This top level stays jax-free (audit + rule vocabulary only); everything
that traces loads lazily via ``__getattr__``.
"""
from __future__ import annotations

from .audit import CheckRepAudit, all_audits, audit_check_rep, audit_of
from .rules import (AnalysisError, Finding, Rule, all_rules, analyze_jaxpr,
                    jaxpr_rules, project_rules, register_rule)

__all__ = [
    "AnalysisError", "CheckRepAudit", "Finding", "Rule",
    "all_audits", "all_rules", "analyze_jaxpr", "analyze_plan",
    "audit_check_rep", "audit_of", "jaxpr_rules", "project_rules",
    "register_rule", "run_sweep", "spmd_gather_safe",
]

_LAZY = {
    "spmd_gather_safe": ("r1_spmd_gather", "spmd_gather_safe"),
    "analyze_plan": ("targets", "analyze_plan"),
    "plan_targets": ("targets", "plan_targets"),
    "run_sweep": ("report", "run_sweep"),
}


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        mod_name, attr = _LAZY[name]
        mod = importlib.import_module(f".{mod_name}", __name__)
        return getattr(mod, attr)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
