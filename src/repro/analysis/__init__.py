"""repro.analysis: jaxpr-level static analysis for the DPC engine.

Born from PR 4/5's silently-wrong distributed block-sparse results (the
pinned jax-0.4.37 XLA CPU SPMD pipeline miscompiles sort-derived gathers
inside multi-partition ``shard_map`` bodies): the class of bug that passes
every unit test on one device and corrupts results on four deserves a
static check, not a memory.  Nine rules walk traced computations, resolved
plans and the source tree:

=====================  =====================================================
R1-spmd-gather         sort-tainted dynamic indices feeding gather /
                       dynamic_slice inside multi-partition shard_map — the
                       miscompile class itself; also the re-enablement gate
                       for distributed block-sparse (``spmd_gather_safe``)
R2-check-rep-audit     every ``check_rep=False`` shard_map body carries an
                       ``@audit_check_rep`` replication-safety annotation
R3-precision-flow      bf16 dot_general accumulations reach the f32
                       direct-diff refinement epilogue
R4-pallas-legality     pallas_call grid/block divisibility, SMEM scalar
                       prefetch placement (budget from ``limits``),
                       host-static grids
R5-spec-coverage       ExecSpec axes x validation x dispatch x tests stay
                       mutually exhaustive
R6-pallas-race         abstract interpretation of every pallas_call's
                       output index maps over the symbolic grid: blocks
                       are visited once, or every revisit-path write is an
                       associative accumulate / guarded init; aliased
                       inputs are never read (``absint``)
R7-transfer-retrace    no host callbacks inside hot traces; equivalent
                       ``d_cut`` spellings hit one jit trace (stable
                       weak-type/dtype avals at every pjit boundary)
R8-determinism         non-associative float reductions (multi-device
                       psum, duplicate-index scatter-add) carry an
                       ``@audit_determinism`` blessing; unannotated sites
                       feeding user-visible outputs fail
R9-memory-budget       per-pallas_call VMEM/SMEM estimates and dense
                       live-buffer peaks stay under the per-platform
                       budget table (``limits``; surfaced in
                       ``DPCPlan.telemetry()``)
=====================  =====================================================

Rules run (a) at plan time — ``repro.engine.planner.plan`` analyzes each
fresh plan's canonical traces and the plan itself; ``REPRO_ANALYSIS=0``
bypasses the raise but still records findings on the
``analysis_findings_total`` obs counter — and (b) in the CLI sweep,
``python -m repro.analysis``, which CI gates on (``--sarif`` emits SARIF
2.1.0; ``analysis-baseline.json`` holds expiring suppression leases).

This top level stays jax-free (audit + rule vocabulary only); everything
that traces loads lazily via ``__getattr__``.
"""
from __future__ import annotations

from .audit import (CheckRepAudit, DeterminismAudit, all_audits,
                    all_determinism_audits, audit_check_rep,
                    audit_determinism, audit_of, determinism_audit_of)
from .limits import KernelLimits, limits_for_platform
from .rules import (AnalysisError, Finding, Rule, all_rules, analyze_jaxpr,
                    jaxpr_rules, plan_rules, project_rules, register_rule)

__all__ = [
    "AnalysisError", "CheckRepAudit", "DeterminismAudit", "Finding",
    "KernelLimits", "Rule",
    "all_audits", "all_determinism_audits", "all_rules", "analyze_jaxpr",
    "analyze_plan", "audit_check_rep", "audit_determinism", "audit_of",
    "determinism_audit_of", "jaxpr_rules", "limits_for_platform",
    "plan_memory", "plan_rules", "project_rules", "register_rule",
    "run_sweep", "spmd_gather_safe", "to_sarif",
]

_LAZY = {
    "spmd_gather_safe": ("r1_spmd_gather", "spmd_gather_safe"),
    "analyze_plan": ("targets", "analyze_plan"),
    "plan_targets": ("targets", "plan_targets"),
    "plan_memory": ("r9_memory_budget", "plan_memory"),
    "run_sweep": ("report", "run_sweep"),
    "to_sarif": ("sarif", "to_sarif"),
}


def __getattr__(name: str) -> object:
    if name in _LAZY:
        import importlib

        mod_name, attr = _LAZY[name]
        mod = importlib.import_module(f".{mod_name}", __name__)
        return getattr(mod, attr)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
