"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.

Encoder-only (bidirectional), same backbone as wav2vec2 [arXiv:2106.07447].
The conv waveform frontend is a STUB: inputs are precomputed frame embeddings
(frontend_dim=512); the vocab is the HuBERT pseudo-label codebook (504 units),
which examples/hubert_units.py regenerates with DPC instead of k-means.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
    activation="geglu",
    is_causal=False,
    tie_embeddings=False,
    frontend_dim=512,
)
