"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000 — RG-LRU recurrent blocks + local attention, 1 attn : 2 rec
[arXiv:2402.19427].

38 layers = 12 x (rec, rec, attn) superblocks + 2 trailing rec layers.
Local attention window 2048, RG-LRU width 4096, temporal conv width 4.
Bounded decode state means the long_500k cell runs for this arch.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    activation="geglu",
    tie_embeddings=True,
    embed_scale=True,
    pattern=("rec", "rec", "attn"),
    local_window=2048,
    rnn_width=4096,
    ssm_conv=4,
)
