"""mamba2-130m [ssm]: 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128 — SSD / state-space duality [arXiv:2405.21060].

d_inner = 2 * d_model = 1536, 24 SSD heads of head_dim 64, shared B/C
(one group), conv width 4, SSD chunk 256.  State-size decode means the
long_500k cell runs at O(1) memory in sequence length.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=24,          # SSD heads (d_inner / ssm_head_dim)
    n_kv_heads=24,
    head_dim=64,
    d_ff=0,              # attention-free, no FFN sublayer
    vocab=50280,
    tie_embeddings=True,
    ssm_state=128,
    ssm_expand=2,
    ssm_conv=4,
    ssm_head_dim=64,
    ssm_chunk=256,
)
