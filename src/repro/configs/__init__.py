"""Architecture registry, assigned input shapes, and dry-run cell table.

``ARCHS`` maps the 10 assigned architecture ids to their exact ArchConfig;
``SHAPES`` are the 4 assigned input shapes; ``cells()`` enumerates the full
40-cell (arch x shape) table with per-cell skip reasons (encoder archs have
no decode step; long_500k needs sub-quadratic decode state).

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input — weak-type-correct, shardable, no device allocation — which is
what launch/dryrun.py lowers against.  ``reduce_config(cfg)`` produces the
small same-family config used by per-arch CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig

from .gemma_2b import CONFIG as GEMMA_2B
from .granite_8b import CONFIG as GRANITE_8B
from .granite_moe_3b_a800m import CONFIG as GRANITE_MOE
from .h2o_danube_1p8b import CONFIG as H2O_DANUBE
from .hubert_xlarge import CONFIG as HUBERT_XLARGE
from .mamba2_130m import CONFIG as MAMBA2_130M
from .paligemma_3b import CONFIG as PALIGEMMA_3B
from .phi3_mini_3p8b import CONFIG as PHI3_MINI
from .qwen3_moe_30b_a3b import CONFIG as QWEN3_MOE
from .recurrentgemma_9b import CONFIG as RECURRENTGEMMA_9B

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in (
        HUBERT_XLARGE, GEMMA_2B, GRANITE_8B, PHI3_MINI, H2O_DANUBE,
        PALIGEMMA_3B, GRANITE_MOE, QWEN3_MOE, MAMBA2_130M, RECURRENTGEMMA_9B,
    )
}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# archs whose decode state is bounded in sequence length (SSM state /
# RG-LRU state + local window / sliding window ring buffer)
SUB_QUADRATIC = {"mamba2-130m", "recurrentgemma-9b", "h2o-danube-1.8b"}


def skip_reason(arch: str, shape: str) -> str | None:
    """None if the (arch, shape) cell runs; otherwise why it is skipped."""
    cfg = ARCHS[arch]
    spec = SHAPES[shape]
    if cfg.family == "encoder" and spec.kind == "decode":
        return "encoder-only: no decode step"
    if shape == "long_500k" and arch not in SUB_QUADRATIC:
        return "full-attention decode: 500k KV cache needs sub-quadratic arch"
    return None


def cells():
    """All 40 (arch, shape, skip_reason) cells."""
    return [(a, s, skip_reason(a, s)) for a in ARCHS for s in SHAPES]


# -------------------------------------------------------------- input specs
def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for the step function's batch argument.

    train / prefill: the full batch dict.  decode: {'tokens': (B, 1)} — the
    KV cache comes from jax.eval_shape over Model.init_cache in the dry-run.
    """
    B, L = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.family == "encoder":
        return {
            "features": jax.ShapeDtypeStruct((B, L, cfg.frontend_dim),
                                             jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((B, L), i32),
        }
    if cfg.family == "vlm":
        # image patches + text fill the assigned seq_len exactly
        return {
            "patches": jax.ShapeDtypeStruct((B, cfg.num_patches,
                                             cfg.frontend_dim), jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((B, L - cfg.num_patches), i32),
        }
    return {"tokens": jax.ShapeDtypeStruct((B, L), i32)}


# ---------------------------------------------------------- reduced configs
def reduce_config(cfg: ArchConfig) -> ArchConfig:
    """Small same-family config for CPU smoke tests (one fwd/train step)."""
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=128,
    )
    if cfg.family == "moe":
        kw.update(n_experts=4, top_k=2, d_ff=32, n_experts_padded=0)
    if cfg.family == "ssm":
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
                  n_heads=8, n_kv_heads=8)   # d_inner 128 / 16
    if cfg.family == "hybrid":
        kw.update(n_layers=5, rnn_width=64, local_window=16)  # 1 super + 2 tail
    if cfg.sliding_window:
        kw.update(sliding_window=32)
    if cfg.frontend_dim:
        kw.update(frontend_dim=16)
    if cfg.num_patches:
        kw.update(num_patches=4)
    return dataclasses.replace(cfg, **kw)


def get(arch: str) -> ArchConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


__all__ = ["ARCHS", "SHAPES", "ShapeSpec", "SUB_QUADRATIC", "cells",
           "skip_reason", "input_specs", "reduce_config", "get"]
