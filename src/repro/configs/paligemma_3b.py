"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216.

SigLIP vision tower + gemma LM [arXiv:2407.07726].  The SigLIP frontend is a
STUB: inputs are precomputed patch embeddings (frontend_dim=1152, 256 patches
per image) projected into d_model; text attends with a bidirectional prefix
over image tokens (prefix-LM mask).
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=257216,
    activation="geglu",
    tie_embeddings=True,
    embed_scale=True,
    frontend_dim=1152,
    num_patches=256,
)
