"""The paper's own experiment configurations (§6).

Dataset cards (dims / cardinality / domain / default d_cut) from the paper,
plus the parameter defaults used across its tables.  At container scale the
benchmarks regenerate distribution-matched proxies via data/points.py and
re-derive d_cut with the same quantile rule (core/tuning.pick_dcut); these
cards document the paper-exact values for full-scale runs.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core import DPCConfig


@dataclass(frozen=True)
class DatasetCard:
    name: str
    d: int
    n: int
    domain: float
    d_cut: float          # the paper's default
    source: str


PAPER_DATASETS = {
    "syn": DatasetCard("syn", 2, 100_000, 1e5, 250.0,
                       "random-walk generator of [Gan & Tao '15]"),
    "s1": DatasetCard("s1", 2, 5_000, 1e5, 250.0, "Franti & Sieranoja"),
    "s2": DatasetCard("s2", 2, 5_000, 1e5, 250.0, "Franti & Sieranoja"),
    "s3": DatasetCard("s3", 2, 5_000, 1e5, 250.0, "Franti & Sieranoja"),
    "s4": DatasetCard("s4", 2, 5_000, 1e5, 250.0, "Franti & Sieranoja"),
    "airline": DatasetCard("airline", 3, 5_810_462, 1e6, 1000.0,
                           "stat-computing.org dataexpo 2009"),
    "household": DatasetCard("household", 4, 2_049_280, 1e5, 1000.0, "UCI"),
    "pamap2": DatasetCard("pamap2", 4, 3_850_505, 1e5, 1000.0, "UCI"),
    "sensor": DatasetCard("sensor", 8, 928_991, 1e5, 5000.0, "UCI"),
}

# Table 5: per-dataset eps chosen by the paper from the time/accuracy trade
PAPER_EPS = {"airline": 0.8, "household": 0.8, "pamap2": 0.8, "sensor": 0.6}

# rho_min "specified to remove points with (very) small local densities"
PAPER_RHO_MIN = 10.0


def paper_config(dataset: str, algorithm: str = "approxdpc") -> DPCConfig:
    card = PAPER_DATASETS[dataset]
    return DPCConfig(d_cut=card.d_cut, rho_min=PAPER_RHO_MIN,
                     algorithm=algorithm,
                     eps=PAPER_EPS.get(dataset, 0.8))
