"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8 [hf:ibm-granite granite-3.0 family].

d_ff is the per-expert FFN width; 8 of 40 experts are active per token.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    activation="swiglu",
    tie_embeddings=True,
    n_experts=40,
    top_k=8,
    # 40 % 16 != 0: expert weights/buffers are padded to 48 so 16-way
    # expert parallelism applies (~17% padded capacity, 16x sharding; §Perf)
    n_experts_padded=48,
)
