"""repro: Fast Density-Peaks Clustering on TPU pods (JAX).

x64 is enabled globally: the grid cell keys (DESIGN.md §2) are mixed-radix
encodings over up to 8 dims and overflow int32.  All numeric model code in
this package is dtype-explicit (bf16/f32), so the only x64 effect is on index
arithmetic.
"""
import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
