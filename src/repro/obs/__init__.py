"""repro.obs — unified tracing, metrics and kernel telemetry.

Three pieces, one switch:

* **Span tracer** (:mod:`.tracer`): ``with obs.span("rho") as sp: ...;
  sp.sync(out)`` records nested phase timings with host wall-time and
  fenced device-time, optionally appended to a JSON-lines trace file.
* **Metrics registry** (:mod:`.metrics`): named counters / gauges /
  histograms with labels; the engine's plan-cache, worklist, stream and
  serve counters all live here.
* **Report CLI** (``python -m repro.obs report``): phase-time table +
  machine-readable snapshot.

``obs.configure(level=...)`` selects ``"off"`` (default — ``span()``
returns a shared no-op singleton, zero overhead), ``"metrics"`` (host
wall-time spans) or ``"trace"`` (host + device-fenced timings, JSONL
emission).  The level is independent of ``ExecSpec``: it changes what is
*measured*, never what is *computed*.

This package is a leaf dependency: it imports only jax + stdlib, so every
layer of the engine (planner, kernels, stream, serve) can import it.
"""
from . import metrics, report, tracer
from .metrics import (Counter, Gauge, Histogram, counter, gauge, histogram,
                      get_metric)
from .metrics import reset as reset_metrics
from .metrics import snapshot as metrics_snapshot
from .tracer import (LEVELS, NULL_SPAN, configure, enabled, flush, level,
                     reset_spans, span, spans, tracing)

__all__ = [
    "LEVELS", "NULL_SPAN", "configure", "level", "enabled", "tracing",
    "span", "spans", "reset_spans", "flush",
    "Counter", "Gauge", "Histogram", "counter", "gauge", "histogram",
    "get_metric", "metrics_snapshot", "reset_metrics",
    "metrics", "tracer", "report",
]
