"""The metrics registry: named counters / gauges / histograms with labels.

One process-global registry onto which the repo's previously ad-hoc
instrumentation migrates (planner plan-cache hits/misses/evictions,
blocksparse worklist builds/cache-hits/fingerprint-misses, stream tick and
dirty-tracking counters, serve HIT/MISS_FALLBACK rates).  The old read
surfaces (``plan_cache_info()``, ``worklist_build_count()``,
``StreamDPC.stats()``) remain as thin shims over these metrics.

Metrics are plain host-side Python — they are incremented from driver
orchestration code, never from inside a jit trace, so they add no device
work and nothing to compiled programs.  All mutation happens under one
lock; values are numbers (counters/gauges) or ``{count, sum, min, max}``
stat dicts (histograms), keyed by a canonical rendering of the label set.
"""
from __future__ import annotations

import threading
from typing import Any, TypeVar, cast

__all__ = ["Counter", "Gauge", "Histogram", "counter", "gauge", "histogram",
           "get_metric", "snapshot", "reset"]

_LOCK = threading.RLock()
_REGISTRY: dict[str, "Metric"] = {}


def _label_key(labels: dict) -> str:
    """Canonical label rendering: ``''`` for no labels, else ``k=v,...``
    sorted by key — the snapshot/diff identity of a metric series."""
    if not labels:
        return ""
    return ",".join(f"{k}={v}" for k, v in sorted(labels.items()))


class Metric:
    """Base: a named family of label-keyed series."""

    kind = "metric"

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self._vals: dict[str, Any] = {}

    # -- suspension support (blocksparse.suspend_counters): the full series
    # -- state can be snapshotted and restored atomically
    def _state(self) -> dict:
        with _LOCK:
            return {k: (dict(v) if isinstance(v, dict) else v)
                    for k, v in self._vals.items()}

    def _restore(self, state: dict) -> None:
        with _LOCK:
            self._vals = {k: (dict(v) if isinstance(v, dict) else v)
                          for k, v in state.items()}

    def _reset(self) -> None:
        with _LOCK:
            self._vals.clear()

    def series(self) -> dict:
        """``{label_key: value}`` copy of every series in this family."""
        return self._state()


class Counter(Metric):
    """Monotonic counter (per label set)."""

    kind = "counter"

    def inc(self, v: float = 1, **labels: Any) -> None:
        k = _label_key(labels)
        with _LOCK:
            self._vals[k] = self._vals.get(k, 0) + v

    def value(self, **labels: Any) -> Any:
        return self._vals.get(_label_key(labels), 0)

    def total(self) -> Any:
        """Sum over every label set (the unlabeled view of the family)."""
        with _LOCK:
            return sum(self._vals.values())


class Gauge(Metric):
    """Last-write-wins value (per label set)."""

    kind = "gauge"

    def set(self, v: float, **labels: Any) -> None:
        with _LOCK:
            self._vals[_label_key(labels)] = v

    def value(self, default: Any = None, **labels: Any) -> Any:
        return self._vals.get(_label_key(labels), default)


class Histogram(Metric):
    """Streaming summary stats (count / sum / min / max) per label set."""

    kind = "histogram"

    def observe(self, v: float, **labels: Any) -> None:
        k = _label_key(labels)
        with _LOCK:
            s = self._vals.get(k)
            if s is None:
                self._vals[k] = {"count": 1, "sum": v, "min": v, "max": v}
            else:
                s["count"] += 1
                s["sum"] += v
                s["min"] = min(s["min"], v)
                s["max"] = max(s["max"], v)

    def stats(self, **labels: Any) -> dict | None:
        s = self._vals.get(_label_key(labels))
        return dict(s) if s is not None else None


_M = TypeVar("_M", bound=Metric)


def _register(cls: type[_M], name: str, help: str) -> _M:
    with _LOCK:
        m = _REGISTRY.get(name)
        if m is None:
            m = cls(name, help)
            _REGISTRY[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, not {cls.kind}")
        elif help and not m.help:
            m.help = help
        return cast(_M, m)


def counter(name: str, help: str = "") -> Counter:
    """Get-or-register the counter family ``name``."""
    return _register(Counter, name, help)


def gauge(name: str, help: str = "") -> Gauge:
    return _register(Gauge, name, help)


def histogram(name: str, help: str = "") -> Histogram:
    return _register(Histogram, name, help)


def get_metric(name: str) -> Metric | None:
    return _REGISTRY.get(name)


def snapshot() -> dict:
    """Machine-readable registry state: ``{name: {kind, help, values}}``.

    ``values`` maps canonical label keys (``''`` = unlabeled) to numbers
    (counter/gauge) or stat dicts (histogram).  This is what the report CLI
    renders and what CI uploads/diffs.
    """
    with _LOCK:
        return {name: {"kind": m.kind, "help": m.help, "values": m.series()}
                for name, m in sorted(_REGISTRY.items())}


def reset() -> None:
    """Zero every registered series (registrations survive)."""
    with _LOCK:
        for m in _REGISTRY.values():
            m._reset()
