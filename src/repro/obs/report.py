"""Render traces + metrics into a phase-time table and CI-diffable snapshot.

Aggregation is by span *path* (``engine.fit/approxdpc.rho_delta``): every
occurrence of the same phase under the same ancestry folds into one row
with count / total host / total device / self time.  ``self_s`` is host
time not covered by child spans — the orchestration overhead of a phase.
"""
from __future__ import annotations

import json

from . import metrics as _metrics
from . import tracer as _tracer

__all__ = ["load_trace", "aggregate", "render_table", "render_metrics",
           "export_snapshot", "build_snapshot"]


def load_trace(path: str) -> list[dict]:
    """Parse a JSON-lines trace file into span records."""
    out: list[dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def aggregate(spans: list[dict]) -> dict[str, dict]:
    """Fold span records into per-path phase rows.

    Returns ``{path: {count, host_s, device_s, self_s, depth}}`` with
    ``device_s`` ``None`` when no occurrence fenced device work.
    """
    phases: dict[str, dict] = {}
    child_host: dict[int, float] = {}  # parent span id -> sum of child host_s
    for rec in spans:
        p = rec.get("parent")
        if p is not None:
            child_host[p] = child_host.get(p, 0.0) + rec.get("host_s", 0.0)
    for rec in spans:
        path = rec.get("path", rec.get("name", "?"))
        row = phases.setdefault(path, {"count": 0, "host_s": 0.0,
                                       "device_s": None, "self_s": 0.0,
                                       "depth": rec.get("depth", 0)})
        host = rec.get("host_s", 0.0)
        row["count"] += 1
        row["host_s"] += host
        row["self_s"] += max(0.0, host - child_host.get(rec.get("id"), 0.0))
        dev = rec.get("device_s")
        if dev is not None:
            row["device_s"] = (row["device_s"] or 0.0) + dev
    return phases


def _fmt_s(v: float | None) -> str:
    if v is None:
        return "-"
    if v >= 1.0:
        return f"{v:.3f}s"
    if v >= 1e-3:
        return f"{v * 1e3:.2f}ms"
    return f"{v * 1e6:.1f}us"


def render_table(phases: dict[str, dict], top: int | None = None) -> str:
    """Phase-time table, tree-indented by path depth, roots first."""
    if not phases:
        return "(no spans recorded)"
    root_host = sum(r["host_s"] for r in phases.values() if r["depth"] == 0)
    rows = sorted(phases.items(), key=lambda kv: kv[0])
    if top is not None:
        keep = sorted(rows, key=lambda kv: -kv[1]["host_s"])[:top]
        kept = {k for k, _ in keep}
        rows = [kv for kv in rows if kv[0] in kept]
    name_w = max(24, max(len(_indent_name(p, r)) for p, r in rows) + 2)
    hdr = (f"{'phase':<{name_w}} {'count':>6} {'host':>10} {'device':>10} "
           f"{'self':>10} {'%run':>6}")
    lines = [hdr, "-" * len(hdr)]
    for path, row in rows:
        pct = 100.0 * row["host_s"] / root_host if root_host > 0 else 0.0
        lines.append(
            f"{_indent_name(path, row):<{name_w}} {row['count']:>6} "
            f"{_fmt_s(row['host_s']):>10} {_fmt_s(row['device_s']):>10} "
            f"{_fmt_s(row['self_s']):>10} {pct:>5.1f}%")
    return "\n".join(lines)


def _indent_name(path: str, row: dict) -> str:
    return "  " * row["depth"] + path.rsplit("/", 1)[-1]


def render_metrics(snap: dict) -> str:
    """Flat ``name{labels} = value`` listing of a metrics snapshot."""
    lines: list[str] = []
    for name, fam in sorted(snap.items()):
        for key, val in sorted(fam.get("values", {}).items()):
            label = f"{{{key}}}" if key else ""
            if isinstance(val, dict):  # histogram stats
                val = ("count=%d sum=%.6g min=%.6g max=%.6g"
                       % (val["count"], val["sum"], val["min"], val["max"]))
            lines.append(f"{name}{label} = {val}")
    return "\n".join(lines) if lines else "(no metrics recorded)"


def build_snapshot(spans: list[dict] | None = None,
                   metrics_snap: dict | None = None) -> dict:
    """Machine-readable run snapshot: aggregated phases + metric values."""
    if spans is None:
        spans = _tracer.spans()
    if metrics_snap is None:
        metrics_snap = _metrics.snapshot()
    return {"schema": "repro.obs/1",
            "level": _tracer.level(),
            "phases": aggregate(spans),
            "metrics": metrics_snap}


def export_snapshot(path: str, spans: list[dict] | None = None,
                    metrics_snap: dict | None = None) -> dict:
    """Write :func:`build_snapshot` as JSON to ``path`` and return it."""
    snap = build_snapshot(spans, metrics_snap)
    with open(path, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True, default=str)
        f.write("\n")
    return snap
