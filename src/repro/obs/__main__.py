"""``python -m repro.obs`` — render traces and metrics snapshots.

    python -m repro.obs report --trace run.jsonl [--metrics snap.json]
                               [--json out.json] [--top N]

Reads a JSON-lines trace (written by ``obs.configure(trace_path=...)``)
and/or a metrics snapshot, prints the phase-time table, and optionally
exports the machine-readable snapshot CI diffs.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import report


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)
    rp = sub.add_parser("report", help="render a trace/metrics snapshot")
    rp.add_argument("--trace", help="JSON-lines span trace file")
    rp.add_argument("--metrics",
                    help="metrics snapshot JSON (raw registry snapshot or a "
                         "repro.obs/1 run snapshot)")
    rp.add_argument("--json", dest="json_out",
                    help="write the aggregated run snapshot here")
    rp.add_argument("--top", type=int, default=None,
                    help="only show the N costliest phases")
    args = ap.parse_args(argv)

    if not args.trace and not args.metrics:
        ap.error("report needs --trace and/or --metrics")

    spans = report.load_trace(args.trace) if args.trace else []
    metrics_snap = None
    if args.metrics:
        with open(args.metrics) as f:
            metrics_snap = json.load(f)
        # accept a full run snapshot as well as a bare registry snapshot
        if metrics_snap.get("schema") == "repro.obs/1":
            metrics_snap = metrics_snap.get("metrics", {})

    if spans:
        phases = report.aggregate(spans)
        print(report.render_table(phases, top=args.top))
    if metrics_snap is not None:
        if spans:
            print()
        print(report.render_metrics(metrics_snap))

    if args.json_out:
        report.export_snapshot(args.json_out, spans=spans,
                               metrics_snap=metrics_snap or {})
        print(f"\nsnapshot written to {args.json_out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
