"""Phase-scoped span tracer with host wall-time and device-time fencing.

``obs.span("rho")`` opens a nested span.  Spans are pure host-side
bookkeeping: a perf-counter pair plus a thread-local stack to record
parentage.  Device time is measured by *fencing*: call ``sp.sync(value)``
on the arrays a phase produced and, at ``level="trace"``, the span blocks
via ``jax.block_until_ready`` and records the span-start-to-fence window
as ``device_s`` (the synced compute portion of the phase; post-fence host
orchestration is what's left in ``host_s - device_s``).  Because the
fence happens *inside* the span, per-phase host times sum to roughly the
end-to-end wall time of a run instead of measuring only async dispatch.

Levels (``configure(level=...)``):

* ``"off"``     — default.  ``span()`` returns a shared null singleton
  (no allocation, no locking, no recording) and ``sync`` is the identity,
  so instrumented code paths keep JAX's async dispatch untouched.
* ``"metrics"`` — spans record host wall-time only; no device fencing.
* ``"trace"``   — spans record host + fenced device time, and are
  optionally appended to a JSON-lines trace file as they close.

Optionally a ``jax.profiler`` trace can be captured alongside
(``configure(profile_dir=...)``) for TensorBoard-level detail.

This module must stay a leaf: it may import jax/numpy/stdlib only, never
``repro.engine``/``repro.kernels`` — those import *us*.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time
import warnings
from typing import Any

import jax

__all__ = ["LEVELS", "configure", "level", "enabled", "tracing", "span",
           "spans", "reset_spans", "flush"]

LEVELS = ("off", "metrics", "trace")

# Retention cap for the in-memory span list (streaming runs emit one span
# tree per tick; without a cap a long soak would grow unbounded).
_MAX_SPANS = 200_000

_LOCK = threading.RLock()
_TLS = threading.local()
_IDS = itertools.count(1)
_ORIGIN = time.perf_counter()


class _State:
    level: str = "off"
    trace_path: str | None = None
    file: Any = None  # lazily-opened JSONL handle
    profile_dir: str | None = None
    profiling: bool = False


_STATE = _State()
_DONE: list[dict] = []

_KEEP = object()  # configure() sentinel: leave this setting unchanged


def configure(level: Any = _KEEP, trace_path: Any = _KEEP,
              profile_dir: Any = _KEEP) -> None:
    """Set the global observability level and trace sinks.

    ``level`` is one of ``LEVELS``.  ``trace_path`` names a JSON-lines file
    that closed spans are appended to (``None`` disables file emission;
    spans stay available in memory via :func:`spans`).  ``profile_dir``
    starts a ``jax.profiler`` trace into that directory; it is stopped when
    the level returns to ``"off"`` or ``profile_dir=None`` is passed.
    Arguments left unspecified keep their current value.
    """
    with _LOCK:
        if level is not _KEEP:
            if level not in LEVELS:
                raise ValueError(f"level must be one of {LEVELS}, got {level!r}")
            _STATE.level = level
        if trace_path is not _KEEP and trace_path != _STATE.trace_path:
            if _STATE.file is not None:
                try:
                    _STATE.file.close()
                except OSError:
                    pass
                _STATE.file = None
            _STATE.trace_path = trace_path
        if profile_dir is not _KEEP and profile_dir != _STATE.profile_dir:
            _stop_profile()
            _STATE.profile_dir = profile_dir
            if profile_dir is not None:
                try:
                    jax.profiler.start_trace(profile_dir)
                    _STATE.profiling = True
                except Exception as e:  # pragma: no cover - env dependent
                    warnings.warn(f"obs: jax.profiler capture unavailable: {e}",
                                  stacklevel=2)
        if _STATE.level == "off":
            _stop_profile()


def _stop_profile() -> None:
    if _STATE.profiling:
        try:
            jax.profiler.stop_trace()
        except Exception:  # pragma: no cover - env dependent
            pass
        _STATE.profiling = False


def level() -> str:
    return _STATE.level


def enabled() -> bool:
    """True when any instrumentation level is active."""
    return _STATE.level != "off"


def tracing() -> bool:
    """True when spans fence device work (``level="trace"``)."""
    return _STATE.level == "trace"


class _NullSpan:
    """Shared no-op span for the off path: entering, closing, ``sync`` and
    ``set`` all do nothing, so disabled instrumentation costs one dict
    lookup per ``span()`` call and zero allocation."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def sync(self, value: Any = None) -> Any:
        return value

    def set(self, **attrs: Any) -> None:
        pass


NULL_SPAN = _NullSpan()


class Span:
    __slots__ = ("name", "attrs", "id", "parent", "depth", "path",
                 "_t0", "_mark", "_fence_s")

    def __init__(self, name: str, attrs: dict) -> None:
        self.name = name
        self.attrs = attrs
        self.id = next(_IDS)
        self.parent = None
        self.depth = 0
        self.path = name
        self._t0 = 0.0
        self._mark = 0.0
        self._fence_s = 0.0

    def set(self, **attrs: Any) -> None:
        """Attach attributes to an open span (e.g. sizes known mid-phase)."""
        self.attrs.update(attrs)

    def sync(self, value: Any = None) -> Any:
        """Fence device work attributed to this span.

        At trace level, blocks until ``value`` (any pytree of arrays) is
        ready and accumulates the *synced compute* duration — the time
        from the span's start (or its previous fence) until the fence
        completes — as ``device_s``.  On async backends the fence wait
        dominates this window; on CPU, where jnp executes synchronously
        inside the producing call, the window still covers the compute,
        which a fence-wait-only measurement would miss entirely.  Host
        orchestration after the last fence is excluded, so ``device_s <=
        host_s`` and per-phase device times sum to ~wall time for a
        compute-bound run.  Returns ``value`` so it can wrap an
        expression in place.  Tracer values (inside jit) cannot block and
        are passed through untouched.
        """
        if _STATE.level == "trace" and value is not None:
            try:
                jax.block_until_ready(value)
            except Exception:
                return value  # abstract values / non-arrays: nothing to fence
            now = time.perf_counter()
            self._fence_s += now - self._mark
            self._mark = now
        return value

    def __enter__(self) -> "Span":
        stack = getattr(_TLS, "stack", None)
        if stack is None:
            stack = _TLS.stack = []
        if stack:
            top = stack[-1]
            self.parent = top.id
            self.depth = top.depth + 1
            self.path = f"{top.path}/{self.name}"
        stack.append(self)
        self._t0 = self._mark = time.perf_counter()
        return self

    def __exit__(self, exc_type: Any, exc: Any, tb: Any) -> bool:
        t1 = time.perf_counter()
        stack = getattr(_TLS, "stack", None)
        if stack and stack[-1] is self:
            stack.pop()
        host_s = t1 - self._t0
        rec: dict[str, Any] = {
            "name": self.name,
            "path": self.path,
            "id": self.id,
            "parent": self.parent,
            "depth": self.depth,
            "t0": self._t0 - _ORIGIN,
            "host_s": host_s,
            # device_s is a *component* of host_s: the start-to-last-fence
            # window; host_s adds the post-fence orchestration tail
            "device_s": self._fence_s if _STATE.level == "trace" else None,
        }
        if self.attrs:
            rec["attrs"] = self.attrs
        if exc_type is not None:
            rec["error"] = exc_type.__name__
        with _LOCK:
            _DONE.append(rec)
            if len(_DONE) > _MAX_SPANS:
                del _DONE[: len(_DONE) - _MAX_SPANS]
            if _STATE.trace_path is not None:
                if _STATE.file is None:
                    _STATE.file = open(_STATE.trace_path, "a")
                json.dump(rec, _STATE.file, default=str)
                _STATE.file.write("\n")
        return False


def span(name: str, **attrs: Any) -> "Span | _NullSpan":
    """Open a named span context.  At ``level="off"`` returns the shared
    null singleton, keeping uninstrumented runs overhead-free."""
    if _STATE.level == "off":
        return NULL_SPAN
    return Span(name, attrs)


def spans() -> list[dict]:
    """Copy of all closed span records (insertion order = close order)."""
    with _LOCK:
        return [dict(r) for r in _DONE]


def reset_spans() -> None:
    with _LOCK:
        _DONE.clear()


def flush() -> None:
    """Flush the JSONL trace file (if one is open) to disk."""
    with _LOCK:
        if _STATE.file is not None:
            _STATE.file.flush()


# Environment activation, so benchmarks/CI can instrument without touching
# code: REPRO_OBS=metrics|trace [REPRO_OBS_TRACE=/path/to/trace.jsonl]
_env_level = os.environ.get("REPRO_OBS", "").strip().lower()
if _env_level:
    if _env_level in LEVELS:
        configure(level=_env_level,
                  trace_path=os.environ.get("REPRO_OBS_TRACE") or None)
    else:  # pragma: no cover - defensive
        warnings.warn(f"REPRO_OBS={_env_level!r} ignored (not in {LEVELS})",
                      stacklevel=1)
