"""Graceful backend degradation: pallas -> pallas-interpret -> jnp.

The planner resolves an `ExecSpec.backend` string to a kernel backend
once per plan.  On hardware where the requested backend cannot actually
*compile* (no TPU for Mosaic lowering, a pallas regression, a driver
mismatch), the old behavior was to hand back a backend whose first
kernel launch explodes deep inside a jit trace.  This module inserts a
plan-time **compile probe** and walks a documented degradation chain
instead::

    pallas  ->  pallas-interpret  ->  jnp

mirroring how ``shard_blocksparse_layout`` already degrades off its R1
probe: probe once, warn once per edge, count every transition on
``resilience_degrade_total{src,dst,reason}``, and serve the strongest
backend that demonstrably works.  ``jnp`` is the chain's floor and is
never probed (pure jax.numpy always lowers on the host platform).

Probe results are memoized per backend name for the life of the
process; :func:`reset` clears the memo (tests).  Set ``REPRO_DEGRADE=0``
to disable degradation entirely and surface raw compile errors.

bf16 precision requires MXU-dense support which ``jnp`` lacks, so a
bf16 plan never silently lands on ``jnp`` — if the chain bottoms out
for a bf16 spec the degradation itself raises.
"""
from __future__ import annotations

import os
import warnings

from repro import obs
from repro.kernels.backend import default_backend_name, get_backend
from repro.resilience import faultinject

__all__ = ["DEGRADE_CHAIN", "probe_backend", "reset", "resolve_backend"]

# src -> next-weaker backend; jnp is the floor.
DEGRADE_CHAIN = {"pallas": "pallas-interpret", "pallas-interpret": "jnp"}

_M_DEGRADE = obs.counter(
    "resilience_degrade_total",
    "plan-time backend degradations, labeled by src/dst/reason")

# backend name -> None (probe passed) | str (failure reason)
_PROBED: dict[str, str | None] = {}
_WARNED: set[tuple[str, str]] = set()


def _enabled() -> bool:
    return os.environ.get("REPRO_DEGRADE", "1").lower() not in (
        "0", "off", "no", "false")


def probe_backend(name: str) -> str | None:
    """Compile-probe ``name``; return None if healthy, else the failure
    reason.  Memoized per process — one tiny compile per backend name."""
    if name in _PROBED:
        return _PROBED[name]
    if name == "jnp":
        _PROBED[name] = None
        return None
    reason: str | None = None
    try:
        faultinject.fire("degrade.probe")
        import jax
        import jax.numpy as jnp

        be = get_backend(name)
        pts = jnp.zeros((8, 2), jnp.float32)
        jax.jit(lambda a: be.range_count(a, a, 1.0)).lower(pts).compile()
    except Exception as exc:  # noqa: BLE001 - any compile failure degrades
        reason = f"{type(exc).__name__}: {exc}"
    _PROBED[name] = reason
    return reason


def resolve_backend(requested: str | None, *, precision: str = "f32") -> str:
    """Resolve a spec's backend request to a name whose compile probe
    passes, walking :data:`DEGRADE_CHAIN` with one-shot warnings."""
    name = requested
    if name in (None, "auto"):
        name = default_backend_name()
    if name == "jnp" or not _enabled():
        return name
    while True:
        reason = probe_backend(name)
        if reason is None:
            return name
        nxt = DEGRADE_CHAIN.get(name)
        if nxt is None or (precision == "bf16" and nxt == "jnp"):
            raise RuntimeError(
                f"backend {name!r} failed its compile probe ({reason}) and "
                f"no admissible fallback remains"
                + (" for bf16 precision (jnp has no MXU-dense path)"
                   if precision == "bf16" else ""))
        _M_DEGRADE.inc(src=name, dst=nxt, reason=type_of(reason))
        if (name, nxt) not in _WARNED:
            _WARNED.add((name, nxt))
            warnings.warn(
                f"repro.resilience: backend {name!r} failed its compile "
                f"probe ({reason}); degrading to {nxt!r}. Set "
                f"REPRO_DEGRADE=0 to surface the raw error instead.",
                RuntimeWarning, stacklevel=3)
        name = nxt


def type_of(reason: str) -> str:
    """Label value for the degrade counter: the exception class name
    prefixing the probe's reason string."""
    return reason.split(":", 1)[0] if reason else "unknown"


def reset() -> None:
    """Forget probe results and warning history (test isolation)."""
    _PROBED.clear()
    _WARNED.clear()
