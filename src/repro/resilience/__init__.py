"""repro.resilience — failure containment for the streaming DPC engine.

Four pillars, each its own module:

* :mod:`.checkpoint` — versioned, atomic ``StreamDPC.save/restore`` with
  bit-identical post-restore ticks (device-count independent).
* :mod:`.sanitize` — admission control (NaN/Inf/dtype/out-of-range
  quarantine: ``reject`` | ``drop`` | ``clamp``) plus the shared
  :func:`finite_or` kernel-epilogue guard.
* :mod:`.degrade` — plan-time compile probing with the graceful backend
  chain pallas -> pallas-interpret -> jnp.
* :mod:`.faultinject` — deterministic named-site fault injection driving
  the chaos suite that proves the other three.
"""
from repro.resilience import (checkpoint, degrade,  # noqa: F401
                              faultinject, sanitize)
from repro.resilience.checkpoint import (CheckpointError,  # noqa: F401
                                         restore_stream, save_stream)
from repro.resilience.degrade import resolve_backend  # noqa: F401
from repro.resilience.faultinject import (FaultError,  # noqa: F401
                                          KILL_EXIT_CODE, KNOWN_SITES,
                                          activate, deactivate, fire)
from repro.resilience.sanitize import (AdmissionConfig,  # noqa: F401
                                       AdmissionResult, PoisonedInputError,
                                       admit, finite_or)

__all__ = [
    "AdmissionConfig", "AdmissionResult", "CheckpointError", "FaultError",
    "KILL_EXIT_CODE", "KNOWN_SITES", "PoisonedInputError", "activate",
    "admit", "checkpoint", "deactivate", "degrade", "faultinject",
    "finite_or", "fire", "resolve_backend", "restore_stream", "sanitize",
    "save_stream",
]
