"""Versioned, atomic checkpoints of the complete StreamDPC state.

A checkpoint is one ``.npz`` file: a JSON metadata blob (format tag,
version, ExecSpec fingerprint, config, scalar counters) plus every array
the incremental tick math reads — ring window in slot order, grid
bookkeeping with its measured capacities and free-list, repaired rho,
the cached maxima NN answers with their validity mask, the stable-center
registry, and the last published tick.  The restore contract is the
repo's parity contract extended across a crash: a restored stream's next
ticks are **bit-identical** to the uninterrupted run's — including onto
a *different device count*, because the sharded repair tail is already
bit-identical to the replicated path (the window arrays are device-count
agnostic; only the compiled repair functions differ, and those rebuild
from the target mesh at restore time).

Writes are atomic: serialize to ``<path>.tmp.<pid>``, fsync, then
``os.replace`` — a crash mid-write (the ``checkpoint.write`` fault site
sits exactly between the two) leaves the previous checkpoint intact and
readable.  Readers validate the format tag and version and raise
:class:`CheckpointError` on anything unreadable, truncated, or from a
future version — never a half-restored stream.

Version policy: ``VERSION`` bumps whenever the serialized state's
meaning changes (a new field with a safe default does not bump; a
re-interpretation of an existing field does).  Restore accepts exactly
the current version — checkpoints are crash-recovery artifacts, not an
archival format.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.resilience import faultinject

__all__ = ["CheckpointError", "FORMAT", "VERSION", "restore_stream",
           "save_stream"]

FORMAT = "repro.stream-ckpt"
VERSION = 1


class CheckpointError(RuntimeError):
    """The file is not a readable checkpoint of the current version."""


def _cfg_meta(cfg) -> dict:
    return {
        "d_cut": cfg.d_cut,
        "capacity": cfg.capacity,
        "batch_cap": cfg.batch_cap,
        "rho_min": cfg.rho_min,
        "delta_min": cfg.delta_min,
        "cell_slack": cfg.cell_slack,
        "extent_margin": cfg.extent_margin,
        "continuity_radius": cfg.continuity_radius,
        "dirty_tracking": cfg.dirty_tracking,
        "transactional": cfg.transactional,
    }


def save_stream(stream, path: str) -> None:
    """Serialize ``stream`` (a :class:`repro.stream.StreamDPC`) to ``path``
    atomically.  Raises ValueError on a stream that has never seen data."""
    faultinject.fire("checkpoint.serialize")
    w = stream.window
    if w is None:
        raise ValueError("cannot checkpoint a StreamDPC before its first "
                         "initialize()/ingest() — there is no window state")
    g = stream.grid
    spec = stream.cfg.resolved_exec()
    meta = {
        "format": FORMAT,
        "version": VERSION,
        "fingerprint": spec.describe(),
        "exec": {"backend": spec.backend, "layout": spec.layout,
                 "precision": spec.precision, "block": spec.block,
                 "data_axis": spec.data_axis},
        "cfg": _cfg_meta(stream.cfg),
        "dim": w.dim,
        "window": {"count": w.count, "cursor": w.cursor, "ticks": w.ticks},
        "counters": {"ticks": stream._ticks,
                     "full_recomputes": stream._full_recomputes,
                     "next_stable": stream._next_stable,
                     "nn_maxima_total": stream._nn_maxima_total,
                     "nn_queries": stream._nn_queries},
        "grid": {"built": g._built, "rebuilds": g.rebuilds},
        "has_rho": stream._rho is not None,
        "has_result": stream._result is not None,
        "has_last": stream._last is not None,
        "registry_ids": [s for s, _ in stream._registry],
    }
    arrays: dict[str, np.ndarray] = {"win_host": w.host}
    if stream._rho is not None:
        arrays["rho"] = np.asarray(stream._rho)
    arrays["nn_delta"] = stream._nn_delta_cache
    arrays["nn_parent"] = stream._nn_parent_cache
    arrays["nn_valid"] = stream._nn_valid
    if g._built:
        meta["grid"].update({
            "live_cells": g.live_cells, "next_id": g.next_id,
            "maxima_cap": g.maxima_cap, "free_ids": list(g.free_ids),
            "has_touched": g.last_touched is not None})
        arrays["grid_box_lo"] = np.asarray(g.box_lo)
        arrays["grid_box_extent"] = np.asarray(g.box_extent)
        arrays["grid_strides"] = np.asarray(g.strides)
        arrays["grid_cell_count"] = g.cell_count
        arrays["grid_seg"] = g.seg_np
        arrays["grid_keys"] = np.fromiter(g.key_to_id.keys(), np.int64,
                                          len(g.key_to_id))
        arrays["grid_ids"] = np.fromiter(g.key_to_id.values(), np.int32,
                                         len(g.key_to_id))
        if g.last_touched is not None:
            arrays["grid_touched"] = g.last_touched
    if stream._registry:
        arrays["reg_pos"] = np.stack([p for _, p in stream._registry])
    if stream._result is not None:
        r = stream._result
        arrays["res_rho"] = np.asarray(r.rho)
        arrays["res_rho_key"] = np.asarray(r.rho_key)
        arrays["res_delta"] = np.asarray(r.delta)
        arrays["res_parent"] = np.asarray(r.parent)
        cl = stream._clustering
        arrays["cl_labels"] = np.asarray(cl.labels)
        arrays["cl_centers"] = np.asarray(cl.centers)
        meta["num_clusters"] = int(cl.num_clusters)
    if stream._last is not None:
        t = stream._last
        meta["last"] = {"num_clusters": int(t.num_clusters),
                        "rebuilt": bool(t.rebuilt),
                        "full_recompute": bool(t.full_recompute),
                        "tick": int(t.tick)}
        arrays["last_labels"] = np.asarray(t.labels)
        arrays["last_centers"] = np.asarray(t.centers)
        arrays["last_stable"] = np.asarray(t.stable_ids)
    arrays["meta"] = np.frombuffer(json.dumps(meta).encode(), np.uint8)

    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as fh:
        np.savez(fh, **arrays)
        fh.flush()
        os.fsync(fh.fileno())
    faultinject.fire("checkpoint.write")    # kill/raise: old file survives
    if faultinject.should_corrupt("checkpoint.write"):
        with open(tmp, "r+b") as fh:
            fh.truncate(max(os.path.getsize(tmp) // 2, 8))
    os.replace(tmp, path)


def _meta_of(z) -> dict:
    try:
        meta = json.loads(bytes(z["meta"].tobytes()).decode())
    except Exception as exc:
        raise CheckpointError(f"checkpoint metadata unreadable: {exc}") \
            from exc
    if meta.get("format") != FORMAT:
        raise CheckpointError(
            f"not a {FORMAT} file (format={meta.get('format')!r})")
    if meta.get("version") != VERSION:
        raise CheckpointError(
            f"checkpoint version {meta.get('version')!r} != supported "
            f"{VERSION}; restore accepts exactly the current version")
    return meta


def restore_stream(path: str, mesh=None):
    """Rebuild a :class:`repro.stream.StreamDPC` from ``path``.

    ``mesh`` may differ from the saved run's (including None after a
    sharded run): the serialized arrays are device-count agnostic and the
    repair tail recompiles against the target mesh with bit-identical
    results.
    """
    import jax.numpy as jnp

    from repro.core.dpc_types import DPCResult
    from repro.core.labels import Clustering
    from repro.engine.spec import ExecSpec
    from repro.stream.stream_dpc import StreamDPC, StreamDPCConfig, StreamTick

    try:
        z = np.load(path, allow_pickle=False)
    except Exception as exc:
        raise CheckpointError(f"cannot read checkpoint {path!r}: {exc}") \
            from exc
    with z:
        try:
            meta = _meta_of(z)
            spec = ExecSpec(**meta["exec"])
            if spec.describe() != meta["fingerprint"]:
                raise CheckpointError(
                    f"ExecSpec fingerprint mismatch: file says "
                    f"{meta['fingerprint']!r}, rebuilt {spec.describe()!r}")
            cfg = StreamDPCConfig(exec_spec=spec, **meta["cfg"])
            s = StreamDPC(cfg, mesh=mesh)
            s._ensure_window(int(meta["dim"]))
            w = s.window
            w.host[:] = z["win_host"]
            w.device = jnp.asarray(w.host)
            wm = meta["window"]
            w.count, w.cursor, w.ticks = wm["count"], wm["cursor"], wm["ticks"]
            gm = meta["grid"]
            if gm["built"]:
                g = s.grid
                g.box_lo = z["grid_box_lo"]
                g.box_extent = z["grid_box_extent"]
                g.strides = z["grid_strides"]
                g.cell_count = z["grid_cell_count"].copy()
                g.seg_np = z["grid_seg"].copy()
                g.seg_dev = jnp.asarray(g.seg_np)
                g.key_to_id = {int(k): int(i) for k, i in
                               zip(z["grid_keys"], z["grid_ids"])}
                g.live_cells = gm["live_cells"]
                g.next_id = gm["next_id"]
                g.maxima_cap = gm["maxima_cap"]
                g.free_ids = list(gm["free_ids"])
                g.rebuilds = gm["rebuilds"]
                g._built = True
                g.last_touched = (z["grid_touched"].copy()
                                  if gm["has_touched"] else None)
            if meta["has_rho"]:
                s._rho = jnp.asarray(z["rho"])
            s._nn_delta_cache[:] = z["nn_delta"]
            s._nn_parent_cache[:] = z["nn_parent"]
            s._nn_valid[:] = z["nn_valid"]
            c = meta["counters"]
            s._ticks = c["ticks"]
            s._full_recomputes = c["full_recomputes"]
            s._next_stable = c["next_stable"]
            s._nn_maxima_total = c["nn_maxima_total"]
            s._nn_queries = c["nn_queries"]
            ids = meta["registry_ids"]
            if ids:
                pos = z["reg_pos"]
                s._registry = [(int(i), pos[j].copy())
                               for j, i in enumerate(ids)]
            if meta["has_result"]:
                s._result = DPCResult(
                    rho=jnp.asarray(z["res_rho"]),
                    rho_key=jnp.asarray(z["res_rho_key"]),
                    delta=jnp.asarray(z["res_delta"]),
                    parent=jnp.asarray(z["res_parent"]))
                s._clustering = Clustering(
                    labels=jnp.asarray(z["cl_labels"]),
                    centers=jnp.asarray(z["cl_centers"]),
                    num_clusters=jnp.asarray(meta["num_clusters"], jnp.int32))
            if meta["has_last"]:
                lm = meta["last"]
                s._last = StreamTick(
                    labels=z["last_labels"].copy(),
                    centers=z["last_centers"].copy(),
                    stable_ids=z["last_stable"].copy(),
                    num_clusters=lm["num_clusters"], rebuilt=lm["rebuilt"],
                    full_recompute=lm["full_recompute"], tick=lm["tick"])
        except CheckpointError:
            raise
        except KeyError as exc:
            raise CheckpointError(
                f"checkpoint {path!r} is missing field {exc}") from exc
    return s
