"""Poisoned-input admission control + shared non-finite epilogue guards.

A single NaN coordinate entering the streaming window silently poisons
everything downstream: the signed range counts (``NaN < d_cut`` is False,
but the *repair* of a NaN row never cancels), the grid packing (``floor``
of NaN), and every distance the serve path computes against the window.
The admission layer catches malformed points **at the boundary** —
``StreamService.submit`` and ``DPCEngine.fit/partial_fit/predict`` — and
applies one configurable quarantine policy:

* ``reject`` (default) — raise :class:`PoisonedInputError`; nothing enters.
* ``drop``   — quarantine the offending rows, admit the rest.
* ``clamp``  — repair in place: NaN -> 0, +-inf / out-of-range -> the
  largest admissible magnitude (strictly below ``max_abs``).

"Poisoned" means any of: non-numeric / complex / object dtype (never
repairable — always rejected regardless of policy), non-finite
coordinates after f32 cast, or coordinates with ``|x| >= max_abs``.  The
default bound is the kernels' padding sentinel ``PAD_COORD`` (1e9): a real
point at or beyond it is indistinguishable from an empty window slot, so
it must never be admitted — while anything below stays valid (the serve
tests probe with 9e8 coordinates on purpose).

Every quarantined point counts on the obs registry
(``resilience_quarantined_points{reason,policy,where}``).

:func:`finite_or` is the shared jnp-traceable epilogue guard (generalizing
the one-off non-finite cap that lived in ``serve/dpc_kv``): kernel
epilogues that must cap ``inf``/NaN results (e.g. the global density
peak's infinite delta before a gamma product) route through it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import numpy as np
import jax.numpy as jnp

from repro import obs
from repro.kernels.density import PAD_COORD

__all__ = ["POLICIES", "AdmissionConfig", "AdmissionResult",
           "PoisonedInputError", "admit", "finite_or"]

POLICIES = ("reject", "drop", "clamp")

_M_QUARANTINED = obs.counter(
    "resilience_quarantined_points",
    "points caught by admission control, labeled by reason/policy/boundary")


class PoisonedInputError(ValueError):
    """Malformed points hit a ``reject`` boundary (or are unrepairable)."""


@dataclass(frozen=True)
class AdmissionConfig:
    """Quarantine policy for one admission boundary.

    ``max_abs`` is the open coordinate bound: ``|x| >= max_abs`` is out of
    range.  It defaults to the kernels' ``PAD_COORD`` sentinel — the first
    magnitude a real point must never carry.
    """

    policy: str = "reject"
    max_abs: float = float(PAD_COORD)

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown quarantine policy {self.policy!r}; "
                             f"expected one of {POLICIES}")
        if not self.max_abs > 0.0:
            raise ValueError(f"max_abs must be positive, got {self.max_abs!r}")


class AdmissionResult(NamedTuple):
    points: np.ndarray      # admitted rows, f32, 2-D (clamped under 'clamp')
    keep: np.ndarray        # (m,) bool over the INPUT rows; False = dropped
    quarantined: int        # rows caught (dropped, clamped, or — reject — 0)


def admit(points, cfg: AdmissionConfig, *,
          where: str = "ingest") -> AdmissionResult:
    """Validate ``points`` against ``cfg`` at boundary ``where``.

    Returns the admitted (possibly repaired) rows plus the keep mask over
    the input — callers that must stay row-aligned (predict) re-expand
    with it.  ``reject`` raises on any poisoned row; non-numeric input
    raises under every policy.
    """
    arr = np.asarray(points)
    if (arr.dtype == object or arr.dtype.kind in "cSUVmM"):
        _M_QUARANTINED.inc(max(arr.shape[0], 1) if arr.ndim else 1,
                           reason="bad_dtype", policy=cfg.policy, where=where)
        raise PoisonedInputError(
            f"{where}: points have non-numeric dtype {arr.dtype!r}; no "
            f"quarantine policy can repair that — submit a real-valued "
            f"array")
    pts = np.atleast_2d(np.asarray(arr, np.float32))
    if pts.size == 0:
        return AdmissionResult(pts, np.zeros(len(pts), bool), 0)
    nonfinite = ~np.isfinite(pts)
    oob = np.abs(pts) >= np.float32(cfg.max_abs)
    bad = (nonfinite | oob).any(axis=1)
    nbad = int(bad.sum())
    if nbad == 0:
        return AdmissionResult(pts, np.ones(len(pts), bool), 0)

    n_nonfin = int(nonfinite.any(axis=1).sum())
    if n_nonfin:
        _M_QUARANTINED.inc(n_nonfin, reason="non_finite",
                           policy=cfg.policy, where=where)
    if nbad - n_nonfin:
        _M_QUARANTINED.inc(nbad - n_nonfin, reason="out_of_range",
                           policy=cfg.policy, where=where)

    if cfg.policy == "reject":
        first = int(np.nonzero(bad)[0][0])
        raise PoisonedInputError(
            f"{where}: {nbad}/{len(pts)} poisoned point(s) (non-finite or "
            f"|x| >= {cfg.max_abs:g}); first bad row {first}: "
            f"{pts[first].tolist()} — policy='reject' admits nothing "
            f"(use 'drop' or 'clamp' to degrade instead)")
    if cfg.policy == "drop":
        return AdmissionResult(pts[~bad], ~bad, nbad)
    # clamp: NaN -> 0, +-inf and out-of-range -> largest admissible value
    limit = np.nextafter(np.float32(cfg.max_abs), np.float32(0.0))
    fixed = np.nan_to_num(pts, nan=0.0, posinf=limit, neginf=-limit)
    fixed = np.clip(fixed, -limit, limit)
    return AdmissionResult(fixed, np.ones(len(pts), bool), nbad)


def finite_or(x, fill):
    """jnp-traceable non-finite guard: ``x`` where finite, ``fill``
    elsewhere — the shared kernel-epilogue cap (inf deltas at global
    density peaks, NaN distances from poisoned rows)."""
    return jnp.where(jnp.isfinite(x), x, fill)
