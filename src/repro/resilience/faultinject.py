"""Deterministic fault injection for the chaos test suite.

Production failures arrive mid-tick: a kernel raises halfway through a
streaming ingest, the process dies between a checkpoint's temp-file write
and its rename, a backend fails to lower at plan time.  This module plants
*named sites* at those exact points (``fire(site)`` — a no-op costing one
attribute read when nothing is armed) so tests can kill, raise or corrupt
at any of them deterministically and prove the resilience invariants:
transactional rollback (``repro.stream``), atomic checkpoints
(``resilience.checkpoint``), graceful degradation (``resilience.degrade``).

Activation is programmatic (:func:`activate`) or by environment — the
subprocess chaos tests and the CI ``chaos`` job set::

    REPRO_FAULT_SITE=tick.rho_repair  REPRO_FAULT_MODE=kill \
    REPRO_FAULT_TRIGGER=2  python ...

Triggers are **seed-driven deterministic**: a plan fires on the Nth hit of
its site (``trigger=N``; ``0`` = every hit), and when only a ``seed`` is
given the hit index derives from it by a fixed mixing function — the same
seed always kills at the same point, so every chaos run is replayable.

Modes: ``raise`` (a :class:`FaultError` the caller's transaction handling
must contain), ``kill`` (``os._exit(KILL_EXIT_CODE)`` — a mid-tick crash
with no unwinding, the checkpoint/restore tests' hammer), and ``corrupt``
(never raises at ``fire``; writers poll :func:`should_corrupt` and damage
their own output, e.g. the checkpoint temp file, to exercise reader-side
validation).
"""
from __future__ import annotations

import contextlib
import os

from repro import obs

__all__ = ["FaultError", "FaultPlan", "KILL_EXIT_CODE", "KNOWN_SITES",
           "MODES", "activate", "active", "deactivate", "fire",
           "should_corrupt", "suspended"]

# Every plantable site.  Adding a fire() call requires adding its name
# here — activate() validates against this tuple so a typo in a chaos
# test fails loudly instead of silently never firing.
KNOWN_SITES = (
    "service.submit",        # StreamService.submit entry
    "tick.grid_apply",       # steady tick: before grid bookkeeping update
    "tick.rho_repair",       # steady tick: before the signed rho repair
    "tick.nn_update",        # steady tick: before the dirty-maxima NN pass
    "tick.finish",           # before label/continuity finalization
    "checkpoint.serialize",  # StreamDPC.save entry (before the temp write)
    "checkpoint.write",      # after the temp write, before the atomic rename
    "kernel.dispatch",       # DPCPlan primitive wrappers
    "degrade.probe",         # backend compile probe (forces degradation)
)
MODES = ("raise", "kill", "corrupt")
KILL_EXIT_CODE = 42

_M_FAULTS = obs.counter(
    "resilience_faults_injected_total",
    "faults actually fired, labeled by site and mode")


class FaultError(RuntimeError):
    """The exception an armed ``mode='raise'`` site throws."""


class FaultPlan:
    """One armed fault: fire ``mode`` on the ``trigger``-th hit of ``site``
    (``trigger == 0``: every hit).  ``hits`` counts site matches so far."""

    def __init__(self, site: str, mode: str, trigger: int):
        self.site = site
        self.mode = mode
        self.trigger = trigger
        self.hits = 0

    def describe(self) -> str:
        return (f"FaultPlan[{self.site} mode={self.mode} "
                f"trigger={self.trigger} hits={self.hits}]")

    __repr__ = describe


_PLAN: FaultPlan | None = None


def _seed_trigger(seed: int) -> int:
    """Deterministic hit index from a seed (Knuth multiplicative mix):
    same seed -> same trigger, spread over the first few hits."""
    return 1 + ((int(seed) * 2654435761) % (2 ** 32)) % 4


def activate(site: str, *, mode: str = "raise", trigger: int | None = None,
             seed: int | None = None) -> FaultPlan:
    """Arm one fault plan (replacing any previous one)."""
    global _PLAN
    if site not in KNOWN_SITES:
        raise ValueError(f"unknown fault site {site!r}; known sites: "
                         f"{KNOWN_SITES}")
    if mode not in MODES:
        raise ValueError(f"unknown fault mode {mode!r}; expected one of "
                         f"{MODES}")
    if trigger is None:
        trigger = 1 if seed is None else _seed_trigger(seed)
    if trigger < 0:
        raise ValueError(f"trigger must be >= 0, got {trigger}")
    _PLAN = FaultPlan(site, mode, int(trigger))
    return _PLAN


def deactivate() -> None:
    global _PLAN
    _PLAN = None


def active() -> FaultPlan | None:
    return _PLAN


@contextlib.contextmanager
def suspended():
    """Temporarily disarm the active plan (restored on exit, hit count
    intact).  Observability/analysis code that *traces* production
    primitives on the host — the plan-time static analyzer, the telemetry
    memory estimator — wraps its tracing here so an armed chaos fault
    neither fires inside the analyzer nor has its hit budget consumed by
    probe traffic the production code never sees."""
    global _PLAN
    saved, _PLAN = _PLAN, None
    try:
        yield
    finally:
        _PLAN = saved


def fire(site: str) -> None:
    """A named injection site.  No-op unless a plan is armed for ``site``
    and its trigger is reached; then counts the fault and raises / kills
    (``corrupt`` plans never act here — see :func:`should_corrupt`)."""
    plan = _PLAN
    if plan is None or plan.site != site:
        return
    plan.hits += 1
    if plan.trigger != 0 and plan.hits != plan.trigger:
        return
    if plan.mode == "corrupt":
        return
    _M_FAULTS.inc(site=site, mode=plan.mode)
    if plan.mode == "kill":
        os._exit(KILL_EXIT_CODE)
    raise FaultError(f"injected fault at {site!r} (hit {plan.hits})")


def should_corrupt(site: str) -> bool:
    """True when an armed ``mode='corrupt'`` plan targets ``site`` and its
    trigger is reached — the writer owning the site damages its output."""
    plan = _PLAN
    if plan is None or plan.mode != "corrupt" or plan.site != site:
        return False
    hit = plan.trigger == 0 or plan.hits == plan.trigger
    if hit:
        _M_FAULTS.inc(site=site, mode=plan.mode)
    return hit


def _from_env() -> None:
    site = os.environ.get("REPRO_FAULT_SITE")
    if not site:
        return
    trigger = os.environ.get("REPRO_FAULT_TRIGGER")
    seed = os.environ.get("REPRO_FAULT_SEED")
    activate(site, mode=os.environ.get("REPRO_FAULT_MODE", "raise"),
             trigger=None if trigger is None else int(trigger),
             seed=None if seed is None else int(seed))


_from_env()
