"""jit'd public wrappers around the Pallas tile-sweep kernels.

On CPU (this container) the kernels execute in interpret mode for
correctness; on TPU they compile to Mosaic.  ``pad_points`` implements the
padding contract shared by all kernels (rows padded at PAD_COORD, far outside
any d_cut; padded output rows sliced off).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .density import (PAD_COORD, range_count, range_count_halo,
                      range_count_signed)
from .dependent import masked_min_dist, masked_min_dist_halo, prefix_min_dist
from .sweep import FUSED_TOPK, SweepSpec, gather_nn, tile_sweep


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def pad_points(x: jnp.ndarray, multiple: int) -> jnp.ndarray:
    n = x.shape[0]
    npad = -(-n // multiple) * multiple
    return jnp.pad(x, ((0, npad - n), (0, 0)), constant_values=PAD_COORD)


def pad_vec(x: jnp.ndarray, multiple: int, value) -> jnp.ndarray:
    n = x.shape[0]
    npad = -(-n // multiple) * multiple
    return jnp.pad(x, (0, npad - n), constant_values=value)


DENSITY_BLOCK_N = 256
DENSITY_BLOCK_M = 512


def local_density_xy(x: jnp.ndarray, y: jnp.ndarray, d_cut, *,
                     block_n: int = DENSITY_BLOCK_N,
                     block_m: int = DENSITY_BLOCK_M,
                     interpret: bool | None = None,
                     worklist=None) -> jnp.ndarray:
    """Kernel-backed rectangular range count: per x-row count of y within
    d_cut (the backend-layer form of Def. 1; query != candidate set)."""
    if interpret is None:
        interpret = _on_cpu()
    n = x.shape[0]
    xp = pad_points(x.astype(jnp.float32), block_n)
    yp = pad_points(y.astype(jnp.float32), block_m)
    cnt = range_count(xp, yp, d_cut, block_n=block_n, block_m=block_m,
                      interpret=interpret, worklist=worklist)
    return cnt[:n].astype(jnp.float32)


def local_density(points: jnp.ndarray, d_cut, *,
                  block_n: int = DENSITY_BLOCK_N,
                  block_m: int = DENSITY_BLOCK_M,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Kernel-backed all-pairs local density (Scan's rho on TPU)."""
    return local_density_xy(points, points, d_cut, block_n=block_n,
                            block_m=block_m, interpret=interpret)


def local_density_delta(x: jnp.ndarray, batch: jnp.ndarray,
                        signs: jnp.ndarray, d_cut, *,
                        block_n: int = DENSITY_BLOCK_N,
                        interpret: bool | None = None,
                        worklist=None) -> jnp.ndarray:
    """Kernel-backed signed range count over a delta batch (streaming rho
    repair): per x-row, (+1 per inserted / -1 per evicted) batch neighbor
    within d_cut, fused in a single tile sweep."""
    if interpret is None:
        interpret = _on_cpu()
    n = x.shape[0]
    xp = pad_points(x.astype(jnp.float32), block_n)
    bp = pad_points(batch.astype(jnp.float32), DENSITY_BLOCK_M)
    sp = pad_vec(signs.astype(jnp.float32), DENSITY_BLOCK_M, 0.0)
    cnt = range_count_signed(xp, bp, sp, d_cut, block_n=block_n,
                             block_m=DENSITY_BLOCK_M, interpret=interpret,
                             worklist=worklist)
    return cnt[:n]


def dependent_prefix(points_sorted_desc: jnp.ndarray, *, block: int = 256,
                     interpret: bool | None = None):
    """Kernel-backed triangular dependent-point pass (rows pre-sorted)."""
    if interpret is None:
        interpret = _on_cpu()
    n = points_sorted_desc.shape[0]
    x = pad_points(points_sorted_desc.astype(jnp.float32), block)
    delta, parent = prefix_min_dist(x, block=block, interpret=interpret)
    return delta[:n], parent[:n]


def dependent_masked(x, x_key, y, y_key, *, block_n: int = 128,
                     block_m: int = 256, interpret: bool | None = None,
                     worklist=None):
    """Kernel-backed masked NN fallback (strictly-denser candidates)."""
    if interpret is None:
        interpret = _on_cpu()
    n = x.shape[0]
    xp = pad_points(x.astype(jnp.float32), block_n)
    xk = pad_vec(x_key.astype(jnp.float32), block_n, jnp.inf)
    yp = pad_points(y.astype(jnp.float32), block_m)
    yk = pad_vec(y_key.astype(jnp.float32), block_m, -jnp.inf)
    delta, parent = masked_min_dist(xp, xk, yp, yk, block_n=block_n,
                                    block_m=block_m, interpret=interpret,
                                    worklist=worklist)
    return delta[:n], parent[:n]


# ------------------------------------------------------ fused rho + delta
def fused_sweep(x, y, d_cut, *, nn_sel=None, k: int = FUSED_TOPK,
                block_n: int = DENSITY_BLOCK_N, block_m: int = DENSITY_BLOCK_M,
                precision: str = "f32", interpret: bool | None = None,
                worklist=None):
    """One tile sweep: per x-row range count over y AND the k nearest
    candidates (expanded-form d2 + global index, unmasked by density — the
    denser-mask resolves in the caller's epilogue once the counts are
    complete).  ``nn_sel`` (len(y) bool/int) optionally gates which columns
    may enter the kept-k (S-Approx representatives); the count ignores it.

    Returns (count (n,) f32, topv (n, k) f32 expanded d2, topi (n, k) int32
    y-row index, -1 when fewer than k candidates).
    """
    if interpret is None:
        interpret = _on_cpu()
    n = x.shape[0]
    xp = pad_points(x.astype(jnp.float32), block_n)
    yp = pad_points(y.astype(jnp.float32), block_m)
    sel = None
    if nn_sel is not None:
        sel = pad_vec(nn_sel.astype(jnp.float32), block_m, 0.0)
    spec = SweepSpec(block_n=block_n, block_m=block_m, count=True, nn="topk",
                     nn_sel=sel is not None, k=k, precision=precision)
    wm, wb = (worklist.meta, worklist.lb) if worklist is not None else (None,
                                                                       None)
    cnt, topv, topi = tile_sweep(spec, xp, yp, d_cut, nn_sel=sel,
                                 wl_meta=wm, wl_lb=wb, interpret=interpret)
    return cnt[:n].astype(jnp.float32), topv[:n], topi[:n]


# --------------------------------------------------------- halo windows
def halo_density(x, window, starts, ends, d_cut, *,
                 block_n: int = DENSITY_BLOCK_N,
                 block_m: int = DENSITY_BLOCK_M,
                 interpret: bool | None = None, worklist=None):
    """Kernel-backed halo range count: per x-row count of window columns
    inside the row's [start, end) spans and within d_cut."""
    if interpret is None:
        interpret = _on_cpu()
    n = x.shape[0]
    xp = pad_points(x.astype(jnp.float32), block_n)
    wp = pad_points(window.astype(jnp.float32), block_m)
    st = _pad_spans(starts, block_n)
    en = _pad_spans(ends, block_n)
    cnt = range_count_halo(xp, wp, st, en, d_cut, block_n=block_n,
                           block_m=block_m, interpret=interpret,
                           worklist=worklist)
    return cnt[:n].astype(jnp.float32)


def halo_dependent(x, x_key, window, w_key, starts, ends, d_cut, *,
                   block_n: int = 128, block_m: int = DENSITY_BLOCK_M,
                   interpret: bool | None = None, worklist=None):
    """Kernel-backed halo strictly-denser NN within d_cut.  Returns
    (delta, parent_window_idx, found)."""
    if interpret is None:
        interpret = _on_cpu()
    n = x.shape[0]
    xp = pad_points(x.astype(jnp.float32), block_n)
    xk = pad_vec(x_key.astype(jnp.float32), block_n, jnp.inf)
    wp = pad_points(window.astype(jnp.float32), block_m)
    wk = pad_vec(w_key.astype(jnp.float32), block_m, -jnp.inf)
    st = _pad_spans(starts, block_n)
    en = _pad_spans(ends, block_n)
    delta, parent = masked_min_dist_halo(xp, xk, wp, wk, st, en, d_cut,
                                         block_n=block_n, block_m=block_m,
                                         interpret=interpret,
                                         worklist=worklist)
    found = jnp.isfinite(delta[:n])
    return delta[:n], parent[:n], found


def _pad_spans(s, multiple: int):
    n = s.shape[0]
    npad = -(-n // multiple) * multiple
    return jnp.pad(s.astype(jnp.int32), ((0, npad - n), (0, 0)),
                   constant_values=0)


# ----------------------------------------------------- fused-gather NN
def dependent_masked_gather(table, keys, q_slots, *, block_n: int = 128,
                            block_m: int = DENSITY_BLOCK_M,
                            interpret: bool | None = None):
    """Strictly-denser NN for the row subset ``table[q_slots]``, with the
    gather fused into the kernel (the streaming maxima repair: the gathered
    query subset never materialises in HBM).  ``q_slots`` >= len(table) are
    padding and return (inf, -1).  Returns (delta, parent)."""
    if interpret is None:
        interpret = _on_cpu()
    q = q_slots.shape[0]
    m = table.shape[0]
    tp = pad_points(table.astype(jnp.float32), block_m)
    kp = pad_vec(keys.astype(jnp.float32), block_m, -jnp.inf)
    # padded slots point past the valid table: the kernel marks them inert
    sp = pad_vec(q_slots.astype(jnp.int32), block_n, m)
    best, parent = gather_nn(tp, kp, sp, m_valid=m, block_n=block_n,
                             block_m=block_m, interpret=interpret)
    return jnp.sqrt(best[:q]), parent[:q]
