"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode for
correctness; on TPU they compile to Mosaic.  ``pad_points`` implements the
padding contract shared by all kernels (rows padded at PAD_COORD, far outside
any d_cut; padded output rows sliced off).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .density import PAD_COORD, range_count, range_count_signed
from .dependent import masked_min_dist, prefix_min_dist


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def pad_points(x: jnp.ndarray, multiple: int) -> jnp.ndarray:
    n = x.shape[0]
    npad = -(-n // multiple) * multiple
    return jnp.pad(x, ((0, npad - n), (0, 0)), constant_values=PAD_COORD)


def pad_vec(x: jnp.ndarray, multiple: int, value) -> jnp.ndarray:
    n = x.shape[0]
    npad = -(-n // multiple) * multiple
    return jnp.pad(x, (0, npad - n), constant_values=value)


DENSITY_BLOCK_N = 256
DENSITY_BLOCK_M = 512


def local_density_xy(x: jnp.ndarray, y: jnp.ndarray, d_cut, *,
                     block_n: int = DENSITY_BLOCK_N,
                     block_m: int = DENSITY_BLOCK_M,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Kernel-backed rectangular range count: per x-row count of y within
    d_cut (the backend-layer form of Def. 1; query != candidate set)."""
    if interpret is None:
        interpret = _on_cpu()
    n = x.shape[0]
    xp = pad_points(x.astype(jnp.float32), block_n)
    yp = pad_points(y.astype(jnp.float32), block_m)
    cnt = range_count(xp, yp, d_cut, block_n=block_n, block_m=block_m,
                      interpret=interpret)
    return cnt[:n].astype(jnp.float32)


def local_density(points: jnp.ndarray, d_cut, *,
                  block_n: int = DENSITY_BLOCK_N,
                  block_m: int = DENSITY_BLOCK_M,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Kernel-backed all-pairs local density (Scan's rho on TPU)."""
    return local_density_xy(points, points, d_cut, block_n=block_n,
                            block_m=block_m, interpret=interpret)


def local_density_delta(x: jnp.ndarray, batch: jnp.ndarray,
                        signs: jnp.ndarray, d_cut, *,
                        block_n: int = DENSITY_BLOCK_N,
                        interpret: bool | None = None) -> jnp.ndarray:
    """Kernel-backed signed range count over a delta batch (streaming rho
    repair): per x-row, (+1 per inserted / -1 per evicted) batch neighbor
    within d_cut, fused in a single tile sweep."""
    if interpret is None:
        interpret = _on_cpu()
    n = x.shape[0]
    xp = pad_points(x.astype(jnp.float32), block_n)
    bp = pad_points(batch.astype(jnp.float32), DENSITY_BLOCK_M)
    sp = pad_vec(signs.astype(jnp.float32), DENSITY_BLOCK_M, 0.0)
    cnt = range_count_signed(xp, bp, sp, d_cut, block_n=block_n,
                             block_m=DENSITY_BLOCK_M, interpret=interpret)
    return cnt[:n]


def dependent_prefix(points_sorted_desc: jnp.ndarray, *, block: int = 256,
                     interpret: bool | None = None):
    """Kernel-backed triangular dependent-point pass (rows pre-sorted)."""
    if interpret is None:
        interpret = _on_cpu()
    n = points_sorted_desc.shape[0]
    x = pad_points(points_sorted_desc.astype(jnp.float32), block)
    delta, parent = prefix_min_dist(x, block=block, interpret=interpret)
    return delta[:n], parent[:n]


def dependent_masked(x, x_key, y, y_key, *, block_n: int = 128,
                     block_m: int = 256, interpret: bool | None = None):
    """Kernel-backed masked NN fallback (strictly-denser candidates)."""
    if interpret is None:
        interpret = _on_cpu()
    n = x.shape[0]
    xp = pad_points(x.astype(jnp.float32), block_n)
    xk = pad_vec(x_key.astype(jnp.float32), block_n, jnp.inf)
    yp = pad_points(y.astype(jnp.float32), block_m)
    yk = pad_vec(y_key.astype(jnp.float32), block_m, -jnp.inf)
    delta, parent = masked_min_dist(xp, xk, yp, yk, block_n=block_n,
                                    block_m=block_m, interpret=interpret)
    return delta[:n], parent[:n]
