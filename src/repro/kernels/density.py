"""Pallas TPU kernel: blocked pairwise range count (local density, Def. 1).

The compute hot spot of DPC's rho phase.  Tiles the (n x m) pairwise-distance
problem into (BLOCK_N x BLOCK_M) VMEM tiles; the squared distance uses the
expanded form |x|^2 + |y|^2 - 2 x.y so the inner product feeds the MXU
(a (BLOCK_N, d) @ (d, BLOCK_M) matmul per tile).  Counts accumulate in the
output ref across the column grid dimension.

The threshold d_cut^2 rides in SMEM as a runtime scalar (not baked into the
kernel), so jit-traced callers — DPC-KV estimates d_cut per head *inside*
jit — hit one compiled kernel for every threshold.

Padding contract: callers pad x/y rows with coordinates >= PAD_COORD, which
puts padded pairs far outside any realistic d_cut without overflowing f32
(see ops.pad_points).  Padded *rows* produce garbage counts that callers
slice off; padded *columns* are never counted.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

PAD_COORD = 1e9  # >> any data domain; 3*PAD^2 ~ 3e18 << f32 max

DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_M = 512


def _density_kernel(d2_ref, x_ref, y_ref, o_ref):
    j = pl.program_id(1)
    d2cut = d2_ref[0]                                # SMEM scalar
    x = x_ref[...]                                   # (bn, d)
    y = y_ref[...]                                   # (bm, d)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)      # (bn, 1)
    y2 = jnp.sum(y * y, axis=-1, keepdims=True).T    # (1, bm)
    xy = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d2 = x2 + y2 - 2.0 * xy
    cnt = jnp.sum(d2 < d2cut, axis=1).astype(jnp.int32)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = cnt

    @pl.when(j != 0)
    def _acc():
        o_ref[...] += cnt


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_m", "interpret"))
def range_count(x: jnp.ndarray, y: jnp.ndarray, d_cut,
                block_n: int = DEFAULT_BLOCK_N, block_m: int = DEFAULT_BLOCK_M,
                interpret: bool = False) -> jnp.ndarray:
    """For each row of x (n, d): |{j : ||x_i - y_j|| < d_cut}| over y (m, d).

    x and y must already be padded to multiples of block_n/block_m with
    PAD_COORD rows (ops.pad_points does this).  ``d_cut`` may be a python
    float or a traced f32 scalar.
    """
    n, d = x.shape
    m, _ = y.shape
    assert n % block_n == 0 and m % block_m == 0
    grid = (n // block_n, m // block_m)
    d2cut = (jnp.asarray(d_cut, jnp.float32) ** 2).reshape((1,))
    return pl.pallas_call(
        _density_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(d2cut, x, y)


def _signed_density_kernel(d2_ref, x_ref, y_ref, s_ref, o_ref):
    """Signed range count: one tile sweep accumulates sum_j s_j * [d2 < d2cut].

    The streaming rho-repair kernel — every surviving point's density changes
    by +1 per inserted / -1 per evicted neighbor, so one fused pass over the
    (insert + evict) delta batch with a per-column sign replaces two
    range-count sweeps.
    """
    j = pl.program_id(1)
    d2cut = d2_ref[0]                                # SMEM scalar
    x = x_ref[...]                                   # (bn, d)
    y = y_ref[...]                                   # (bm, d)
    s = s_ref[...]                                   # (bm,) f32 in {-1, 0, +1}
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    y2 = jnp.sum(y * y, axis=-1, keepdims=True).T
    xy = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d2 = x2 + y2 - 2.0 * xy
    cnt = jnp.sum(jnp.where(d2 < d2cut, s[None, :], 0.0), axis=1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = cnt

    @pl.when(j != 0)
    def _acc():
        o_ref[...] += cnt


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_m", "interpret"))
def range_count_signed(x: jnp.ndarray, y: jnp.ndarray, signs: jnp.ndarray,
                       d_cut, block_n: int = DEFAULT_BLOCK_N,
                       block_m: int = DEFAULT_BLOCK_M,
                       interpret: bool = False) -> jnp.ndarray:
    """For each row of x: sum_j signs[j] * [||x_i - y_j|| < d_cut], f32.

    Same padding contract as ``range_count``; padded y rows must carry
    sign 0 (and PAD_COORD coordinates keep them outside any d_cut anyway).
    """
    n, d = x.shape
    m, _ = y.shape
    assert n % block_n == 0 and m % block_m == 0
    grid = (n // block_n, m // block_m)
    d2cut = (jnp.asarray(d_cut, jnp.float32) ** 2).reshape((1,))
    return pl.pallas_call(
        _signed_density_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(d2cut, x, y, signs.astype(jnp.float32))
