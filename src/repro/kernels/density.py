"""Range-count kernels (local density, Def. 1) — tile-sweep instantiations.

The compute hot spot of DPC's rho phase: an (n x m) pairwise-distance problem
tiled into (BLOCK_N x BLOCK_M) VMEM blocks, squared distances in the MXU
expanded form, counts accumulated across the column grid dimension.  Since
the unified engine landed, this module is the *instantiation* of
``kernels.sweep`` for the two count-only primitives; the kernel body itself
lives in ``sweep.tile_sweep`` (one ``SweepSpec`` per primitive).

The threshold d_cut^2 rides in SMEM as a runtime scalar (not baked into the
kernel), so jit-traced callers — DPC-KV estimates d_cut per head *inside*
jit — hit one compiled kernel for every threshold.

Padding contract: callers pad x/y rows with coordinates >= PAD_COORD, which
puts padded pairs far outside any realistic d_cut without overflowing f32
(see ops.pad_points).  Padded *rows* produce garbage counts that callers
slice off; padded *columns* are never counted.
"""
from __future__ import annotations

import jax.numpy as jnp

from .sweep import (DEFAULT_BLOCK_M, DEFAULT_BLOCK_N, PAD_COORD,  # noqa: F401
                    SweepSpec, tile_sweep)


def range_count(x: jnp.ndarray, y: jnp.ndarray, d_cut,
                block_n: int = DEFAULT_BLOCK_N, block_m: int = DEFAULT_BLOCK_M,
                interpret: bool = False,
                precision: str = "f32", worklist=None) -> jnp.ndarray:
    """For each row of x (n, d): |{j : ||x_i - y_j|| < d_cut}| over y (m, d).

    x and y must already be padded to multiples of block_n/block_m with
    PAD_COORD rows (ops.pad_points does this).  ``d_cut`` may be a python
    float or a traced f32 scalar.
    """
    spec = SweepSpec(block_n=block_n, block_m=block_m, count=True,
                     precision=precision)
    wm, wb = (worklist.meta, worklist.lb) if worklist is not None else (None,
                                                                        None)
    (cnt,) = tile_sweep(spec, x, y, d_cut, wl_meta=wm, wl_lb=wb,
                        interpret=interpret)
    return cnt


def range_count_signed(x: jnp.ndarray, y: jnp.ndarray, signs: jnp.ndarray,
                       d_cut, block_n: int = DEFAULT_BLOCK_N,
                       block_m: int = DEFAULT_BLOCK_M,
                       interpret: bool = False,
                       precision: str = "f32", worklist=None) -> jnp.ndarray:
    """For each row of x: sum_j signs[j] * [||x_i - y_j|| < d_cut], f32.

    The streaming rho-repair kernel — every surviving point's density changes
    by +1 per inserted / -1 per evicted neighbor, so one fused pass over the
    (insert + evict) delta batch with a per-column sign replaces two
    range-count sweeps.  Same padding contract as ``range_count``; padded y
    rows must carry sign 0 (and PAD_COORD keeps them outside any d_cut).
    """
    spec = SweepSpec(block_n=block_n, block_m=block_m, count=True,
                     signed=True, precision=precision)
    wm, wb = (worklist.meta, worklist.lb) if worklist is not None else (None,
                                                                        None)
    (cnt,) = tile_sweep(spec, x, y, d_cut, signs=signs, wl_meta=wm, wl_lb=wb,
                        interpret=interpret)
    return cnt


def range_count_halo(x: jnp.ndarray, window: jnp.ndarray,
                     starts: jnp.ndarray, ends: jnp.ndarray, d_cut,
                     block_n: int = DEFAULT_BLOCK_N,
                     block_m: int = DEFAULT_BLOCK_M,
                     interpret: bool = False,
                     precision: str = "f32", worklist=None) -> jnp.ndarray:
    """Range count against per-row ragged [start, end) windows (halo tiles).

    The distributed halo layout: each x-row counts only the window columns
    inside its candidate spans (``starts``/``ends``: (n, S) window-local
    bounds; empty or negative spans contribute nothing).  Same padding
    contract; padded x rows must carry empty spans.
    """
    spec = SweepSpec(block_n=block_n, block_m=block_m, count=True, span=True,
                     span_s=starts.shape[1], precision=precision)
    wm, wb = (worklist.meta, worklist.lb) if worklist is not None else (None,
                                                                        None)
    (cnt,) = tile_sweep(spec, x, window, d_cut, starts=starts, ends=ends,
                        wl_meta=wm, wl_lb=wb, interpret=interpret)
    return cnt
