"""The unified tile-sweep engine behind every DPC Pallas kernel.

Every hot primitive in this repo is the same computation wearing different
masks: sweep a grid of (row-tile x col-tile) squared-distance blocks and
reduce each tile into per-row accumulators.  This module owns that sweep
once — a :class:`SweepSpec` declares *which* accumulators and *which* masks a
primitive needs, and ``tile_sweep`` builds the corresponding Mosaic kernel:

==================  =======================================================
accumulators        ``count`` — |{j : d2 < d_cut^2}| per row (Def. 1), with
                    optional per-column ``signed`` weights (streaming rho
                    repair); ``nn`` — running masked nearest neighbor, either
                    ``'best1'`` (min + argmin, per-tile direct-diff re-rank
                    of the top-``refine_k`` candidates) or ``'topk'`` (the
                    ``k`` nearest candidates kept for a direct-diff epilogue
                    — the fused rho+delta path, where the denser-mask is not
                    known until the counts are complete).
masks               ``key`` — strictly-denser candidates only (Def. 2);
                    ``prefix`` — strict lower-triangular tiles (Ex-DPC's
                    density-sorted invariant; upper tiles never touch the
                    MXU); ``span`` — per-row ragged [start, end) windows into
                    the column table (the distributed halo layout); ``nn_dcut``
                    — NN candidates must also sit within d_cut (stencil
                    semantics); ``nn_sel`` — per-column candidate gate for
                    the NN accumulator only (S-Approx representatives).
precision           ``'f32'`` — expanded-form distances with an f32 MXU
                    matmul; ``'bf16'`` — bf16 inner product (MXU at twice the
                    f32 rate), f32 accumulation and norms.  Winners are
                    restored to direct-difference f32 by the re-rank
                    (``'best1'``) or the caller's epilogue (``'topk'``), so
                    mixed precision costs nothing on well-separated data.
==================  =======================================================

``kernels/density.py`` and ``kernels/dependent.py`` keep their public
signatures as thin instantiations, and ``kernels/ops.py`` adds the padding
wrappers for the new fused / halo / gathered entry points.

Since the block-sparse mode landed, the sweep grid is **worklist-driven**: a
1-D ``pallas_call`` grid iterates a scalar-prefetched (row-tile, col-tile,
first-visit, in-cut) table plus a per-pair lower-bound vector
(``kernels.blocksparse.FlatWorklist``), so a grid-pruned worklist visits
only the tile pairs that can matter — the count accumulators honour the
``in_cut`` flag (pairs within d_cut of the tile AABBs) and the NN
accumulators skip pairs in-kernel whenever the pair's lower bound exceeds
the accumulator's current prune radius (best-1: the worst current best;
kept-k: the worst current kth candidate).  ``worklist=None`` degenerates to
the dense all-pairs table (every flag live, all bounds zero), so every
existing ``SweepSpec`` instantiation routes through this one engine
unchanged.

Also here: ``gather_nn`` — the fused-gather variant of the masked NN for the
streaming repair path.  The query rows are gathered *inside* the kernel from
the (VMEM-resident) window table via one-hot matmuls over a doubled column
grid (first ``nbc`` steps assemble the queries into scratch, the next ``nbc``
steps run the masked-NN sweep), so the gathered row subset is never
materialised in HBM.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

PAD_COORD = 1e9  # >> any data domain; 3*PAD^2 ~ 3e18 << f32 max

DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_M = 512

# How many expanded-form candidates are re-ranked in direct-difference form
# per row tile ('best1') or kept for the epilogue ('topk').
REFINE_TOPK = 4
FUSED_TOPK = 8


@dataclass(frozen=True)
class SweepSpec:
    """Static description of one tile-sweep primitive (hashable: jit key)."""

    block_n: int = DEFAULT_BLOCK_N
    block_m: int = DEFAULT_BLOCK_M
    count: bool = False          # emit (n,) range count
    signed: bool = False         # count weighted by per-column signs (f32)
    nn: str | None = None        # None | 'best1' | 'topk'
    key: bool = False            # strictly-denser mask (xk / yk inputs)
    prefix: bool = False         # strict lower-triangular sweep
    span: bool = False           # per-row ragged [start, end) column windows
    span_s: int = 0              # spans per row (span mask)
    nn_dcut: bool = False        # NN candidates must satisfy d2 < d_cut^2
    nn_sel: bool = False         # per-column NN candidate gate (f32 mask)
    k: int = FUSED_TOPK          # kept candidates ('topk')
    refine_k: int = REFINE_TOPK  # re-rank rounds ('best1')
    precision: str = "f32"       # 'f32' | 'bf16' tile-distance inner product

    @property
    def needs_dcut(self) -> bool:
        return self.count or self.nn_dcut


def tile_d2(x, y, precision: str = "f32"):
    """Expanded-form squared distances |x|^2 + |y|^2 - 2 x.y for one tile.

    The inner product feeds the MXU; ``'bf16'`` casts the operands of the
    matmul only (norms and accumulation stay f32), trading ~8 mantissa bits
    on the cross term for twice the MXU rate.
    """
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    y2 = jnp.sum(y * y, axis=-1, keepdims=True).T
    if precision == "bf16":
        xm, ym = x.astype(jnp.bfloat16), y.astype(jnp.bfloat16)
    else:
        xm, ym = x, y
    xy = jax.lax.dot_general(xm, ym, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    return x2 + y2 - 2.0 * xy


def refine_topk_d2(x, y, d2, k: int):
    """Re-rank the k smallest expanded-form candidates in direct-diff form.

    The expanded form has absolute error ~eps*(|x|^2+|y|^2) — a large
    *relative* error for small distances, big enough to flip near-tie argmins
    when NN distances are far below the domain scale.  k rounds of extract-
    argmin / re-evaluate-direct-diff (one-hot matmul: MXU-friendly, no
    gather) / retire make both the winner *and* its value direct-diff exact
    whenever the true NN sits within the top-k expanded candidates.

    Masked candidates carry d2 = inf and stay inert.  Returns
    (best_d2_direct, local_argmin); (inf, -1) where no finite candidate.
    """
    bn, bm = d2.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (bn, bm), 1)
    best = jnp.full((bn,), jnp.inf, jnp.float32)
    arg = jnp.full((bn,), -1, jnp.int32)
    work = d2
    for _ in range(max(k, 1)):
        loc = jnp.argmin(work, axis=1).astype(jnp.int32)
        cand = jnp.min(work, axis=1)
        onehot = (loc[:, None] == cols).astype(jnp.float32)
        y_sel = jax.lax.dot_general(onehot, y, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        d2d = jnp.sum((x - y_sel) ** 2, axis=-1)
        d2d = jnp.where(jnp.isfinite(cand), d2d, jnp.inf)     # keep masked inert
        better = d2d < best
        best = jnp.where(better, d2d, best)
        arg = jnp.where(better, loc, arg)
        work = jnp.where(cols == loc[:, None], jnp.inf, work)  # retire winner
    return best, arg


def _extract_topk(d2, base_col: int, k: int):
    """k smallest (d2, global col) of a tile, ascending by (d2, idx)."""
    bn, bm = d2.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (bn, bm), 1)
    vals, idxs = [], []
    work = d2
    for _ in range(k):
        loc = jnp.argmin(work, axis=1).astype(jnp.int32)
        vals.append(jnp.min(work, axis=1))
        idxs.append(base_col + loc)
        work = jnp.where(cols == loc[:, None], jnp.inf, work)
    return jnp.stack(vals, axis=1), jnp.stack(idxs, axis=1)


def _merge_topk(av, ai, bv, bi, k: int):
    """Merge two (bn, k) candidate lists, keeping the k smallest by (d2, idx).

    The tie-break is *explicitly* lexicographic on the global index: each
    round extracts the minimum value and, among equal-valued entries, the
    lowest index.  For the dense sweep (tiles arriving in ascending column
    order) this reproduces the historical first-position behaviour exactly;
    for a block-sparse worklist (tiles arriving in ring order) it makes the
    kept set independent of the visit order — the bit-parity contract.
    """
    allv = jnp.concatenate([av, bv], axis=1)                  # (bn, 2k)
    alli = jnp.concatenate([ai, bi], axis=1)
    bn, w = allv.shape
    pos = jax.lax.broadcasted_iota(jnp.int32, (bn, w), 1)
    int_max = jnp.iinfo(jnp.int32).max
    vals, idxs = [], []
    work = allv
    for _ in range(k):
        m = jnp.min(work, axis=1)
        hit_v = work == m[:, None]
        sel_idx = jnp.min(jnp.where(hit_v, alli, int_max), axis=1)
        vals.append(m)
        idxs.append(sel_idx)
        # retire exactly one entry: the first position carrying (m, sel_idx)
        hit = hit_v & (alli == sel_idx[:, None])
        first = jnp.min(jnp.where(hit, pos, int_max), axis=1)
        work = jnp.where(pos == first[:, None], jnp.inf, work)
    return jnp.stack(vals, axis=1), jnp.stack(idxs, axis=1)


def _span_mask(st, en, base_col: int, bn: int, bm: int):
    """(bn, bm) bool: column j in any of the row's [start, end) windows."""
    col = base_col + jax.lax.broadcasted_iota(jnp.int32, (bn, bm), 1)
    mask = jnp.zeros((bn, bm), bool)
    for s in range(st.shape[1]):
        mask |= (col >= st[:, s][:, None]) & (col < en[:, s][:, None])
    return mask


def _make_sweep_kernel(spec: SweepSpec):
    bn, bm = spec.block_n, spec.block_m

    def kernel(*refs):
        it = iter(refs)
        meta_ref = next(it)               # (4, W) scalar-prefetched worklist
        lb_ref = next(it)                 # (W,) per-pair lower bounds
        d2s_ref = next(it) if spec.needs_dcut else None
        x_ref = next(it)
        xk_ref = next(it) if spec.key else None
        y_ref = next(it)
        yk_ref = next(it) if spec.key else None
        s_ref = next(it) if spec.signed else None
        sel_ref = next(it) if spec.nn_sel else None
        st_ref = next(it) if spec.span else None
        en_ref = next(it) if spec.span else None
        cnt_ref = next(it) if spec.count else None
        if spec.nn == "best1":
            best_ref, arg_ref = next(it), next(it)
        elif spec.nn == "topk":
            topv_ref, topi_ref = next(it), next(it)

        p = pl.program_id(0)
        i = meta_ref[0, p]
        j = meta_ref[1, p]

        @pl.when(meta_ref[2, p] == 1)
        def _init():
            if spec.count:
                cnt_ref[...] = jnp.zeros_like(cnt_ref[...])
            if spec.nn == "best1":
                best_ref[...] = jnp.full_like(best_ref[...], jnp.inf)
                arg_ref[...] = jnp.full_like(arg_ref[...], -1)
            elif spec.nn == "topk":
                topv_ref[...] = jnp.full_like(topv_ref[...], jnp.inf)
                topi_ref[...] = jnp.full_like(topi_ref[...], -1)

        # per-accumulator liveness: the count honours the worklist's in-cut
        # flag; the NN accumulators compare the pair's lower bound against
        # their current prune radius (dense worklists carry lb = 0 and
        # in_cut = 1 everywhere, so every pair stays live — the degenerate
        # case reproduces the historical dense sweep bit-for-bit).
        cnt_live = (meta_ref[3, p] == 1) if spec.count else False
        if spec.nn == "best1":
            nn_live = lb_ref[p] <= jnp.max(best_ref[...])
        elif spec.nn == "topk":
            nn_live = lb_ref[p] <= jnp.max(topv_ref[...])
        else:
            nn_live = False
        live = cnt_live | nn_live if spec.count and spec.nn else \
            (cnt_live if spec.count else nn_live)

        @pl.when(live)
        def _compute():
            x = x_ref[...]
            y = y_ref[...]
            d2 = tile_d2(x, y, spec.precision)
            d2cut = d2s_ref[0] if spec.needs_dcut else None
            smask = (_span_mask(st_ref[...], en_ref[...], j * bm, bn, bm)
                     if spec.span else None)

            if spec.count:
                cmask = d2 < d2cut
                if smask is not None:
                    cmask &= smask
                if spec.signed:
                    cnt = jnp.sum(jnp.where(cmask, s_ref[...][None, :], 0.0),
                                  axis=1)
                else:
                    cnt = jnp.sum(cmask, axis=1).astype(jnp.int32)
                live_cnt = jnp.where(cnt_live, cnt,
                                     jnp.zeros_like(cnt))
                cnt_ref[...] += live_cnt

            if spec.nn is None:
                return
            d2m = d2
            if spec.key:
                d2m = jnp.where(yk_ref[...][None, :] > xk_ref[...][:, None],
                                d2m, jnp.inf)
            if spec.prefix:
                row = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, bm), 0)
                col = j * bm + jax.lax.broadcasted_iota(jnp.int32, (bn, bm), 1)
                d2m = jnp.where(col < row, d2m, jnp.inf)
            if smask is not None:
                d2m = jnp.where(smask, d2m, jnp.inf)
            if spec.nn_dcut:
                d2m = jnp.where(d2 < d2cut, d2m, jnp.inf)
            if spec.nn_sel:
                d2m = jnp.where(sel_ref[...][None, :] > 0, d2m, jnp.inf)

            if spec.nn == "best1":
                cand, loc = refine_topk_d2(x, y, d2m, spec.refine_k)
                cand = jnp.where(nn_live, cand, jnp.inf)
                gidx = j * bm + loc
                # lexicographic (d2, col) update: ring-ordered worklists
                # visit tiles out of column order, and on exact distance
                # ties the dense sweep's winner is the lowest column
                better = cand < best_ref[...]
                tie = ((cand == best_ref[...]) & jnp.isfinite(cand)
                       & (gidx < arg_ref[...]))
                upd = better | tie
                best_ref[...] = jnp.where(upd, cand, best_ref[...])
                arg_ref[...] = jnp.where(upd, gidx, arg_ref[...])
            else:
                tv, ti = _extract_topk(d2m, j * bm, spec.k)
                tv = jnp.where(nn_live, tv, jnp.inf)
                ti = jnp.where(nn_live, ti, -1)
                mv, mi = _merge_topk(topv_ref[...], topi_ref[...], tv, ti,
                                     spec.k)
                topv_ref[...] = mv
                topi_ref[...] = mi

    return kernel


def _dense_worklist(nbr: int, nbc: int, prefix: bool, block_n: int,
                    block_m: int):
    """The worklist=None degenerate case: every pair, row-major, all flags
    live, zero lower bounds.  Triangular specs pre-prune the upper tiles the
    2-D grid used to skip with a ``pl.when`` guard (same pairs, same order).
    Static shapes -> plain numpy, folded into the trace as constants."""
    wi = np.repeat(np.arange(nbr), nbc)
    wj = np.tile(np.arange(nbc), nbr)
    if prefix:
        kept = wj * block_m < (wi + 1) * block_n
        wi, wj = wi[kept], wj[kept]
    first = np.zeros(len(wi), np.int64)
    first[np.unique(wi, return_index=True)[1]] = 1
    meta = np.stack([wi, wj, first, np.ones(len(wi), np.int64)])
    return (jnp.asarray(meta.astype(np.int32)),
            jnp.zeros((len(wi),), jnp.float32))


def tile_sweep(spec: SweepSpec, x, y, d_cut=None, x_key=None, y_key=None,
               signs=None, nn_sel=None, starts=None, ends=None,
               wl_meta=None, wl_lb=None, *, interpret: bool = False):
    """Run the sweep described by ``spec`` over padded inputs.

    Shape contract (as for every kernel here): ``x`` is (n, d) padded to a
    multiple of ``spec.block_n`` with PAD_COORD rows, ``y`` (m, d) padded to
    ``spec.block_m``; per-row/per-column vectors padded to match (keys +inf
    on padded queries / -inf on padded candidates; signs 0; spans empty).
    ``wl_meta``/``wl_lb`` (``blocksparse.FlatWorklist`` arrays) select the
    block-sparse tile-pair worklist; ``None`` runs the dense all-pairs
    sweep.  Returns the tuple of requested accumulators, in order:
    ``count`` (n,), then ``nn`` — (best_d2, arg) or (topv, topi).

    Host wrapper: ``d_cut`` is normalized to a strong ``f32`` *before* the
    jit boundary — a python float traces weak-typed and a numpy scalar
    strong, so an un-normalized scalar would land one trace-cache entry
    per spelling the caller uses (R7's retrace-churn finding).
    """
    if d_cut is not None:
        d_cut = jnp.asarray(d_cut, jnp.float32)
    return _tile_sweep_jit(spec, x, y, d_cut, x_key, y_key, signs, nn_sel,
                           starts, ends, wl_meta, wl_lb,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("spec", "interpret"))
def _tile_sweep_jit(spec: SweepSpec, x, y, d_cut=None, x_key=None,
                    y_key=None, signs=None, nn_sel=None, starts=None,
                    ends=None, wl_meta=None, wl_lb=None, *,
                    interpret: bool = False):
    n, d = x.shape
    m, _ = y.shape
    assert n % spec.block_n == 0 and m % spec.block_m == 0
    bn, bm = spec.block_n, spec.block_m
    if wl_meta is None:
        wl_meta, wl_lb = _dense_worklist(n // bn, m // bm, spec.prefix,
                                         bn, bm)
    W = wl_meta.shape[1]

    args, in_specs = [], []
    if spec.needs_dcut:
        d2cut = (jnp.asarray(d_cut, jnp.float32) ** 2).reshape((1,))
        args.append(d2cut)
        in_specs.append(pl.BlockSpec((1,), lambda p, mt, lb: (0,),
                                     memory_space=pltpu.SMEM))
    args.append(x)
    in_specs.append(pl.BlockSpec((bn, d), lambda p, mt, lb: (mt[0, p], 0)))
    if spec.key:
        args.append(x_key)
        in_specs.append(pl.BlockSpec((bn,), lambda p, mt, lb: (mt[0, p],)))
    args.append(y)
    in_specs.append(pl.BlockSpec((bm, d), lambda p, mt, lb: (mt[1, p], 0)))
    if spec.key:
        args.append(y_key)
        in_specs.append(pl.BlockSpec((bm,), lambda p, mt, lb: (mt[1, p],)))
    if spec.signed:
        args.append(signs.astype(jnp.float32))
        in_specs.append(pl.BlockSpec((bm,), lambda p, mt, lb: (mt[1, p],)))
    if spec.nn_sel:
        args.append(nn_sel.astype(jnp.float32))
        in_specs.append(pl.BlockSpec((bm,), lambda p, mt, lb: (mt[1, p],)))
    if spec.span:
        S = spec.span_s
        args += [starts.astype(jnp.int32), ends.astype(jnp.int32)]
        in_specs += [pl.BlockSpec((bn, S),
                                  lambda p, mt, lb: (mt[0, p], 0))] * 2

    out_specs, out_shape = [], []
    row_spec = pl.BlockSpec((bn,), lambda p, mt, lb: (mt[0, p],))
    if spec.count:
        out_specs.append(row_spec)
        out_shape.append(jax.ShapeDtypeStruct(
            (n,), jnp.float32 if spec.signed else jnp.int32))
    if spec.nn == "best1":
        out_specs += [row_spec] * 2
        out_shape += [jax.ShapeDtypeStruct((n,), jnp.float32),
                      jax.ShapeDtypeStruct((n,), jnp.int32)]
    elif spec.nn == "topk":
        out_specs += [pl.BlockSpec((bn, spec.k),
                                   lambda p, mt, lb: (mt[0, p], 0))] * 2
        out_shape += [jax.ShapeDtypeStruct((n, spec.k), jnp.float32),
                      jax.ShapeDtypeStruct((n, spec.k), jnp.int32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(W,),
        in_specs=in_specs,
        out_specs=out_specs if len(out_specs) > 1 else out_specs[0],
    )
    out = pl.pallas_call(
        _make_sweep_kernel(spec),
        grid_spec=grid_spec,
        out_shape=out_shape if len(out_shape) > 1 else out_shape[0],
        interpret=interpret,
    )(wl_meta, wl_lb, *args)
    return out if isinstance(out, (tuple, list)) else (out,)


# ------------------------------------------------------- fused-gather NN
def _gather_nn_kernel(slots_ref, y_ref, yk_ref, best_ref, arg_ref, acc_ref, *,
                      block_n: int, block_m: int, nbc: int, m_valid: int,
                      refine_k: int):
    """Masked NN whose query rows are gathered in-kernel from the table.

    Doubled column grid: steps j < nbc assemble the gathered queries
    [coords | key] into VMEM scratch via one-hot matmuls (MXU-friendly, no
    dynamic gather); steps j >= nbc run the standard strictly-denser NN
    sweep against column tile (j - nbc).  Slots >= ``m_valid`` are padding
    and produce (inf, -1).
    """
    j = pl.program_id(1)
    bn, bm = block_n, block_m
    d = y_ref.shape[1]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref[...])
        best_ref[...] = jnp.full_like(best_ref[...], jnp.inf)
        arg_ref[...] = jnp.full_like(arg_ref[...], -1)

    @pl.when(j < nbc)
    def _gather():
        slots = slots_ref[...]
        col = j * bm + jax.lax.broadcasted_iota(jnp.int32, (bn, bm), 1)
        onehot = (slots[:, None] == col).astype(jnp.float32)
        # padded table rows carry -inf keys; finitize them so the one-hot
        # matmul never forms 0 * inf = NaN (such slots are masked inert below)
        yk = jnp.maximum(yk_ref[...], jnp.float32(-3e38))
        both = jnp.concatenate([y_ref[...], yk[:, None]], axis=1)
        acc_ref[...] += jax.lax.dot_general(
            onehot, both, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j >= nbc)
    def _sweep():
        x = acc_ref[...][:, :d]
        xk = jnp.where(slots_ref[...] < m_valid, acc_ref[...][:, d], jnp.inf)
        d2 = tile_d2(x, y_ref[...])
        d2m = jnp.where(yk_ref[...][None, :] > xk[:, None], d2, jnp.inf)
        cand, loc = refine_topk_d2(x, y_ref[...], d2m, refine_k)
        better = cand < best_ref[...]
        best_ref[...] = jnp.where(better, cand, best_ref[...])
        arg_ref[...] = jnp.where(
            better, (j - nbc) * bm + loc, arg_ref[...])


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "m_valid",
                                             "refine_k", "interpret"))
def gather_nn(table, keys, q_slots, *, m_valid: int,
              block_n: int = 128, block_m: int = DEFAULT_BLOCK_M,
              refine_k: int = REFINE_TOPK, interpret: bool = False):
    """Strictly-denser NN for ``table[q_slots]`` rows, gather fused in-kernel.

    ``table`` (m, d) / ``keys`` (m,) padded to ``block_m`` multiples
    (PAD_COORD rows, -inf keys); ``q_slots`` (q,) int32 padded to ``block_n``
    with values >= ``m_valid`` (padding queries return (inf, -1)).  Returns
    (best_d2, parent) of shape (q,) — best_d2 is the squared distance.
    """
    q = q_slots.shape[0]
    m, d = table.shape
    assert q % block_n == 0 and m % block_m == 0
    nbc = m // block_m
    kernel = functools.partial(_gather_nn_kernel, block_n=block_n,
                               block_m=block_m, nbc=nbc, m_valid=m_valid,
                               refine_k=refine_k)
    col_map = lambda i, j: (jax.lax.rem(j, jnp.int32(nbc)),)

    best, arg = pl.pallas_call(
        kernel,
        grid=(q // block_n, 2 * nbc),
        in_specs=[
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_m, d), lambda i, j: (*col_map(i, j), 0)),
            pl.BlockSpec((block_m,), col_map),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q,), jnp.float32),
            jax.ShapeDtypeStruct((q,), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((block_n, d + 1), jnp.float32)],
        interpret=interpret,
    )(q_slots.astype(jnp.int32), table, keys)
    return best, arg
