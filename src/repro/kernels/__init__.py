"""Pallas TPU kernels for DPC's two compute hot spots (+ jnp oracles)."""
from .ops import dependent_masked, dependent_prefix, local_density

__all__ = ["local_density", "dependent_prefix", "dependent_masked"]
