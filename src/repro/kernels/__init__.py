"""The unified tile-sweep engine (Pallas TPU kernels + jnp oracles) for DPC's
two compute hot spots, and the pluggable backend registry that routes every
DPC hot path onto them."""
from .backend import (KernelBackend, available_backends,
                      default_backend_name, get_backend, register_backend,
                      rho_delta_sequential)
from .blocksparse import FlatWorklist, build_flat_worklist, worklist_stats
from .ops import (dependent_masked, dependent_masked_gather, dependent_prefix,
                  fused_sweep, halo_density, halo_dependent, local_density,
                  local_density_delta, local_density_xy)
from .sweep import SweepSpec, tile_sweep

__all__ = ["local_density", "local_density_xy", "local_density_delta",
           "dependent_prefix", "dependent_masked", "dependent_masked_gather",
           "fused_sweep", "halo_density", "halo_dependent", "KernelBackend",
           "get_backend", "register_backend", "available_backends",
           "default_backend_name", "rho_delta_sequential", "SweepSpec",
           "tile_sweep", "FlatWorklist", "build_flat_worklist",
           "worklist_stats"]
