"""Pallas TPU kernels for DPC's two compute hot spots (+ jnp oracles), and
the pluggable backend registry that routes every DPC hot path onto them."""
from .backend import (KernelBackend, available_backends,
                      default_backend_name, get_backend, register_backend)
from .ops import (dependent_masked, dependent_prefix, local_density,
                  local_density_delta, local_density_xy)

__all__ = ["local_density", "local_density_xy", "local_density_delta",
           "dependent_prefix", "dependent_masked", "KernelBackend",
           "get_backend", "register_backend", "available_backends",
           "default_backend_name"]
