"""Block-sparse tile worklists: grid-pruned sub-quadratic DPC sweeps.

The dense engine visits every (row-tile x col-tile) pair of the distance
grid — O(n^2) tile work regardless of d_cut.  Under the paper's d_cut
assumption (average rho in the tens) almost all of those pairs are provably
empty: when points are laid out in grid-sorted order (``core.grid``'s
(candidate-cell, grouping-cell) sort) each kernel tile covers a compact
region of space, so a per-tile axis-aligned bounding box gives a cheap lower
bound on every pairwise distance the tile pair could produce.  This module
owns that pruning logic once, in three forms:

* **pair bounds** — per-tile AABBs (pad rows masked) and the conservative
  min/max inter-tile squared distances.  Lower bounds are shrunk and upper
  bounds grown by a few ulps (``LB_SHRINK`` / ``UB_GROW``) so f32 rounding of
  the bound arithmetic can never out-round the kernels' own f32 distance
  evaluation — pruning decisions are exact, bit-parity with the dense sweep
  is preserved (tested on tie-heavy lattice data).

* **jit-built ring worklists** (the jnp backend) — the (nbr, nbc) bound
  matrix is *ranked* ascending per row tile (double argsort — pure sorts,
  no gather); count accumulators walk the prefix with ``lb <= d_cut^2``
  and NN accumulators walk the ring with a ``lax.while_loop`` that stops
  once the next lower bound exceeds the row tile's worst current candidate
  (the progressively-shrinking prune radius).  Each step selects its
  column tile by a one-hot ``(rank == p)`` matmul contraction — the same
  idiom ``sweep.gather_nn`` uses in-kernel — so **no sort-derived value
  ever feeds a gather/dynamic_slice index** and the walk is R1-clean
  (``analysis.spmd_gather_safe``): safe inside multi-partition shard_map
  bodies under the pinned jax-0.4.37 XLA CPU SPMD pipeline, which
  miscompiles sort-derived gather indices there.  Everything is traced —
  shapes depend only on tile counts — so the block-sparse jnp primitives
  stay jit/shard_map-safe (``rho_delta`` remains ``fused_traceable``) and
  the *work* is data-proportional because ``while_loop`` trip counts are
  runtime values.

* **host-built flat worklists** (the pallas backends) — the kept tile pairs
  flatten into a scalar-prefetched (wi, wj, first-visit, in-cut) table that
  drives a 1-D ``pallas_call`` grid (``sweep.tile_sweep``); the grid size IS
  the kept-pair count for count primitives, while NN primitives keep a
  ring-ordered list and skip tiles in-kernel against the live accumulator
  (``best1``: the current best; ``topk``: the worst kept kth — statically
  pre-pruned by the k-nearest upper-bound radius, which is exact because a
  tile whose lower bound clears k strictly-closer candidates can never
  contribute a kept entry).

Every builder force-keeps at least one pair per row tile so output blocks
are always initialized, and all-in-one-cell data degenerates to the dense
worklist (nothing prunes; the engine behaves exactly as ``worklist=None``).
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.obs import metrics as _obsm

# Conservative slack on the f32 bound arithmetic: shrink lower bounds / grow
# upper bounds by ~10 ulp-equivalents so a bound can never out-round the
# kernel's own f32 distance (pruning stays exact; costs a few extra tiles).
LB_SHRINK = 1.0 - 1e-5
UB_GROW = 1.0 + 1e-5

# Default block-sparse tile shape (jnp ring sweeps).  Smaller row tiles than
# the dense engine: the ring early-exit is gated by the worst row in the
# tile and the AABB tightens with fewer points, which buys more pruning than
# the larger-tile dispatch amortization buys throughput (measured on the
# 64k acceptance shape: (128, 256) beats (256, 256) and (512, 512)).
BS_BLOCK_N = 128
BS_BLOCK_M = 256


def _pad_inf(x: jnp.ndarray, block: int) -> jnp.ndarray:
    n = x.shape[0]
    npad = -(-n // block) * block
    return jnp.pad(x, ((0, npad - n), (0, 0)), constant_values=jnp.inf)


def tile_bounds(xp: jnp.ndarray, n_valid: int, block: int):
    """Per-tile AABB (lo, hi) of padded points, pad rows masked out.

    Empty (all-pad) tiles report (lo=+inf, hi=-inf), which makes every bound
    against them +inf — they prune away wherever pruning is legal and stay
    inert (infinite distances) wherever it is not.
    """
    N, d = xp.shape
    nb = N // block
    valid = (jnp.arange(N) < n_valid).reshape(nb, block)[..., None]
    xt = xp.reshape(nb, block, d)
    lo = jnp.min(jnp.where(valid, xt, jnp.inf), axis=1)
    hi = jnp.max(jnp.where(valid, xt, -jnp.inf), axis=1)
    return lo, hi


def pair_lower_bounds(rlo, rhi, clo, chi) -> jnp.ndarray:
    """(nbr, nbc) conservative min inter-AABB squared distance (shrunk)."""
    gap = jnp.maximum(jnp.maximum(clo[None, :, :] - rhi[:, None, :],
                                  rlo[:, None, :] - chi[None, :, :]), 0.0)
    return jnp.sum(gap * gap, axis=-1) * LB_SHRINK


def pair_upper_bounds(rlo, rhi, clo, chi) -> jnp.ndarray:
    """(nbr, nbc) conservative max inter-AABB squared distance (grown).

    +inf whenever either tile is empty (its degenerate box has lo > hi).
    """
    reach = jnp.maximum(jnp.maximum(chi[None, :, :] - rlo[:, None, :],
                                    rhi[:, None, :] - clo[None, :, :]), 0.0)
    ub = jnp.sum(reach * reach, axis=-1) * UB_GROW
    empty_r = jnp.any(rlo > rhi, axis=-1)
    empty_c = jnp.any(clo > chi, axis=-1)
    return jnp.where(empty_r[:, None] | empty_c[None, :], jnp.inf, ub)


def _ring(x_pad, nx, y_pad, ny, bn: int, bm: int):
    """Ascending-lb ring *ranks* per row tile: (rank, lb), both (nbr, nbc).

    ``rank[i, j]`` is column tile j's position in row tile i's ascending-lb
    visit order — a double argsort, so ties rank in tile-index order exactly
    like the stable ``argsort`` permutation the walk used to gather through.
    Pure traced math, and deliberately gather-free: both sorts return whole
    permutations that the walks consume only through ``rank == p`` one-hot
    comparisons, never as a gather/dynamic_slice index.  That keeps the jnp
    ring walk R1-clean (``spmd_gather_safe``) inside multi-partition
    shard_map bodies, where the pinned XLA CPU SPMD pipeline miscompiles
    sort-derived gather indices.
    """
    rlo, rhi = tile_bounds(x_pad, nx, bn)
    clo, chi = tile_bounds(y_pad, ny, bm)
    lb = pair_lower_bounds(rlo, rhi, clo, chi)
    rank = jnp.argsort(jnp.argsort(lb, axis=1), axis=1).astype(jnp.int32)
    return rank, lb


# One-hot contractions never see ±inf pad values: 0 * inf = NaN would leak
# into selected tiles.  Clamped pads keep the walks exact — a clamped coord
# still squares past the f32 max (distance stays +inf), and a clamped -inf
# column key is restored below the admissibility mask (_RESTORE_NEG).
_FINITE_CAP = jnp.float32(3e38)


def _finitize(a: jnp.ndarray) -> jnp.ndarray:
    return jnp.clip(a, -_FINITE_CAP, _FINITE_CAP)


def _onehot_pick(sel_f32: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Select one row of ``table`` (nbc, w) by a one-hot (nbc,) vector.

    A permutation-matrix contraction (MXU-friendly dot, no gather): the
    exact 0/1 weights make the picked row bitwise-equal to the stored row.
    """
    return jax.lax.dot_general(sel_f32[None, :], table,
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)[0]


# =====================================================================
# jnp block-sparse primitives (direct-difference; bit-parity with the
# dense jnp engine — same per-tile float expressions, order-independent
# count sums, explicit lexicographic (d2, col) NN tie-break)
# =====================================================================
@partial(jax.jit, static_argnames=("bn", "bm", "signed"))
def _count_bs_jnp(x, y, weights, d_cut, bn: int = BS_BLOCK_N,
                  bm: int = BS_BLOCK_M, signed: bool = False):
    """Block-sparse (optionally signed) range count, x rows over y columns.

    Walks only the ascending-lb prefix with lb <= d_cut^2 per row tile
    (``while_loop``: work is proportional to the kept pairs, not the grid).
    Integer/sign sums are order-independent, so the result is bit-identical
    to the dense jnp range count.
    """
    n, d = x.shape
    m = y.shape[0]
    xp = _pad_inf(x, bn)
    yp = _pad_inf(y, bm)
    nbr, nbc = xp.shape[0] // bn, yp.shape[0] // bm
    rank, lb = _ring(xp, n, yp, m, bn, bm)
    d2cut = jnp.asarray(d_cut, jnp.float32) ** 2
    kcut = jnp.sum(lb <= d2cut, axis=1).astype(jnp.int32)
    ypf = _finitize(yp).reshape(nbc, bm * d)
    if signed:
        wp = jnp.pad(weights.astype(jnp.float32), (0, nbc * bm - m),
                     constant_values=0.0).reshape(nbc, bm)

    def row_tile(i):
        rows = jax.lax.dynamic_slice_in_dim(xp, i * bn, bn, 0)
        rank_i, kc = rank[i], kcut[i]

        def body(c):
            p, acc = c
            sel = (rank_i == p).astype(jnp.float32)
            cols = _onehot_pick(sel, ypf).reshape(bm, d)
            d2 = jnp.sum((rows[:, None, :] - cols[None, :, :]) ** 2, -1)
            if signed:
                s = _onehot_pick(sel, wp)
                upd = jnp.sum(jnp.where(d2 < d2cut, s[None, :], 0.0), axis=1)
            else:
                upd = jnp.sum(d2 < d2cut, axis=1).astype(jnp.float32)
            return p + 1, acc + upd

        _, acc = jax.lax.while_loop(lambda c: c[0] < kc, body,
                                    (jnp.int32(0),
                                     jnp.zeros((bn,), jnp.float32)))
        return acc

    cnt = jax.lax.map(row_tile, jnp.arange(nbr)).reshape(-1)[:n]
    return cnt


def _nn_ring_rows(xp, rkp, yp, ckp, n, rank, lb, bn: int, bm: int):
    """One block-sparse masked-NN row-tile sweep (the shared Def.-2 core).

    Ring order with a runtime early-exit: stop once the next tile's lower
    bound strictly exceeds the worst current best among the tile's valid
    rows (a bound can only be *conservative*, so every skipped pair is
    strictly worse for every row — exact, ties included).  Each step picks
    its column tile by one-hot ``rank == p`` contraction (never a
    sort-derived gather index) and tracks the winner *in-loop* as a global
    column id with a lexicographic (d2, col) tie-break — because global
    col = tile * bm + local col, this is exactly the dense sweep's
    lowest-index winner, bit for bit (same float ops on the same operands).
    """
    nbc, d = yp.shape[0] // bm, yp.shape[1]
    int_max = jnp.iinfo(jnp.int32).max
    # [coords | key] contraction table, pads finitized (gather_nn's idiom);
    # clamped -inf keys are restored after the pick so the strictly-denser
    # admissibility mask is untouched.
    ytab = jnp.concatenate([_finitize(yp), _finitize(ckp)[:, None]],
                           axis=1).reshape(nbc, bm * (d + 1))
    tile_ids = jnp.arange(nbc, dtype=jnp.int32)

    def row_tile(i):
        rows = jax.lax.dynamic_slice_in_dim(xp, i * bn, bn, 0)
        rrk = jax.lax.dynamic_slice_in_dim(rkp, i * bn, bn, 0)
        rvalid = (i * bn + jnp.arange(bn)) < n
        rank_i, lb_i = rank[i], lb[i]

        def cond(c):
            p, best, _ = c
            worst = jnp.max(jnp.where(rvalid, best, -jnp.inf))
            lb_p = jnp.sum(jnp.where(rank_i == jnp.minimum(p, nbc - 1),
                                     lb_i, 0.0))
            return (p < nbc) & (lb_p <= worst)

        def body(c):
            p, best, barg = c
            onehot = (rank_i == p)
            j = jnp.sum(jnp.where(onehot, tile_ids, 0)).astype(jnp.int32)
            picked = _onehot_pick(onehot.astype(jnp.float32),
                                  ytab).reshape(bm, d + 1)
            cols = picked[:, :d]
            crk = jnp.where(picked[:, d] <= -_FINITE_CAP, -jnp.inf,
                            picked[:, d])
            d2 = jnp.sum((rows[:, None, :] - cols[None, :, :]) ** 2, -1)
            d2m = jnp.where(crk[None, :] > rrk[:, None], d2, jnp.inf)
            cand = jnp.min(d2m, axis=1)
            carg = (j * bm + jnp.argmin(d2m, axis=1)).astype(jnp.int32)
            better = cand < best
            tie = (cand == best) & jnp.isfinite(cand) & (carg < barg)
            return (p + 1, jnp.where(better, cand, best),
                    jnp.where(better | tie, carg, barg))

        _, best, barg = jax.lax.while_loop(
            cond, body, (jnp.int32(0), jnp.full((bn,), jnp.inf),
                         jnp.full((bn,), int_max, jnp.int32)))
        parent = jnp.where(jnp.isfinite(best), barg, -1)
        return jnp.sqrt(best), parent

    return row_tile


@partial(jax.jit, static_argnames=("bn", "bm"))
def _denser_nn_bs_jnp(x, x_key, y, y_key, bn: int = BS_BLOCK_N,
                      bm: int = BS_BLOCK_M):
    """Block-sparse strictly-denser NN (Def. 2), ring-pruned."""
    n, d = x.shape
    m = y.shape[0]
    xp = _pad_inf(x, bn)
    yp = _pad_inf(y, bm)
    nbr = xp.shape[0] // bn
    rank, lb = _ring(xp, n, yp, m, bn, bm)
    rkp = jnp.pad(x_key.astype(jnp.float32), (0, xp.shape[0] - n),
                  constant_values=jnp.inf)
    ckp = jnp.pad(y_key.astype(jnp.float32), (0, yp.shape[0] - m),
                  constant_values=-jnp.inf)
    row_tile = _nn_ring_rows(xp, rkp, yp, ckp, n, rank, lb, bn, bm)
    delta, parent = jax.lax.map(row_tile, jnp.arange(nbr))
    return (delta.reshape(-1)[:n],
            parent.reshape(-1)[:n].astype(jnp.int32))


@partial(jax.jit, static_argnames=("bn", "bm"))
def _rho_delta_bs_jnp(x, y, jitter, d_cut, y_sel_slots=None,
                      bn: int = BS_BLOCK_N, bm: int = BS_BLOCK_M):
    """Block-sparse fused rho + delta, one jit (jit-built worklist).

    The count pass walks each row tile's lb <= d_cut^2 ring prefix; the NN
    pass walks the same ring with the runtime prune radius.  Bit-identical
    to the dense ``_rho_delta_jnp`` (order-independent counts; lexicographic
    NN winner recovery).
    """
    n, d = x.shape
    m = y.shape[0]
    xp = _pad_inf(x, bn)
    yp = _pad_inf(y, bm)
    nbr = xp.shape[0] // bn
    nbc = yp.shape[0] // bm
    rank, lb = _ring(xp, n, yp, m, bn, bm)
    d2cut = jnp.asarray(d_cut, jnp.float32) ** 2
    kcut = jnp.sum(lb <= d2cut, axis=1).astype(jnp.int32)
    ypf = _finitize(yp).reshape(nbc, bm * d)

    def row_count(i):
        rows = jax.lax.dynamic_slice_in_dim(xp, i * bn, bn, 0)
        rank_i, kc = rank[i], kcut[i]

        def body(c):
            p, acc = c
            sel = (rank_i == p).astype(jnp.float32)
            cols = _onehot_pick(sel, ypf).reshape(bm, d)
            d2 = jnp.sum((rows[:, None, :] - cols[None, :, :]) ** 2, -1)
            return p + 1, acc + jnp.sum(d2 < d2cut, axis=1).astype(jnp.int32)

        _, acc = jax.lax.while_loop(lambda c: c[0] < kc, body,
                                    (jnp.int32(0),
                                     jnp.zeros((bn,), jnp.int32)))
        return acc

    cnt = jax.lax.map(row_count, jnp.arange(nbr)).reshape(-1)[:n]
    rho = cnt.astype(jnp.float32)
    rho_key = rho + jitter
    if y_sel_slots is None:
        col_key = rho_key
    else:
        col_key = jnp.full((m,), -jnp.inf,
                           jnp.float32).at[y_sel_slots].set(rho_key)
    rkp = jnp.pad(rho_key, (0, xp.shape[0] - n), constant_values=jnp.inf)
    ckp = jnp.pad(col_key, (0, yp.shape[0] - m), constant_values=-jnp.inf)
    row_nn = _nn_ring_rows(xp, rkp, yp, ckp, n, rank, lb, bn, bm)
    delta, parent = jax.lax.map(row_nn, jnp.arange(nbr))
    return (rho, rho_key, delta.reshape(-1)[:n],
            parent.reshape(-1)[:n].astype(jnp.int32))


# =====================================================================
# host-built flat worklists (the pallas scalar-prefetch grid)
# =====================================================================
@dataclass(frozen=True)
class FlatWorklist:
    """A kept tile-pair list driving one 1-D ``tile_sweep`` grid.

    ``meta`` rows: [row_tile, col_tile, first-visit flag, in-d_cut flag];
    entries sorted by (row_tile, lb) so output blocks are revisited
    consecutively (the Mosaic accumulation contract) in ring order.
    """

    meta: jnp.ndarray          # (4, W) int32
    lb: jnp.ndarray            # (W,) f32 — the in-kernel NN prune radius
    n_kept: int                # worklist entries (incl. forced keeps)
    n_total: int               # nbr * nbc dense pair count

    @property
    def pruned_frac(self) -> float:
        return 1.0 - self.n_kept / max(self.n_total, 1)


def _host_bounds(arr: np.ndarray, block: int):
    n, d = arr.shape
    nb = -(-n // block)
    pad = np.full((nb * block, d), np.inf, np.float32)
    pad[:n] = arr
    valid = (np.arange(nb * block) < n).reshape(nb, block)[..., None]
    xt = pad.reshape(nb, block, d)
    lo = np.where(valid, xt, np.inf).min(axis=1)
    hi = np.where(valid, xt, -np.inf).max(axis=1)
    return lo, hi


def host_pair_bounds(x: np.ndarray, y: np.ndarray, block_n: int,
                     block_m: int):
    """Host (numpy) mirror of the device bound math: (lb, ub) matrices."""
    rlo, rhi = _host_bounds(np.asarray(x, np.float32), block_n)
    clo, chi = _host_bounds(np.asarray(y, np.float32), block_m)
    gap = np.maximum(np.maximum(clo[None] - rhi[:, None],
                                rlo[:, None] - chi[None]), 0.0)
    lb = (gap * gap).sum(-1) * LB_SHRINK
    reach = np.maximum(np.maximum(chi[None] - rlo[:, None],
                                  rhi[:, None] - clo[None]), 0.0)
    ub = (reach * reach).sum(-1) * UB_GROW
    empty_r = (rlo > rhi).any(-1)
    empty_c = (clo > chi).any(-1)
    ub[empty_r[:, None] | empty_c[None, :]] = np.inf
    return lb.astype(np.float32), ub.astype(np.float32)


def _knn_radius(ub: np.ndarray, col_counts: np.ndarray, k: int) -> np.ndarray:
    """Per-row-tile static k-NN prune radius: the smallest upper bound v
    such that tiles with ub <= v hold at least k candidate points.  A pair
    with lb > v is provably outside every row's kept-k (k strictly closer
    candidates exist), so pruning by it preserves bit-parity."""
    nbr, nbc = ub.shape
    ord_ub = np.argsort(ub, axis=1)
    ub_sorted = np.take_along_axis(ub, ord_ub, axis=1)
    cnt_sorted = col_counts[ord_ub]
    cum = np.cumsum(cnt_sorted, axis=1)
    reach = np.argmax(cum >= k, axis=1)           # first prefix with >= k
    enough = cum[:, -1] >= k
    radius = ub_sorted[np.arange(nbr), reach]
    return np.where(enough, radius, np.inf).astype(np.float32)


# --------------------------------------------------------------------------
# Host-worklist caching.  Building a flat worklist is host work proportional
# to the tile-pair grid; a DPCPlan (repro.engine.planner) activates a small
# LRU here so repeated fits on the same data skip the rebuild.  Keys are
# content fingerprints (blake2b over the input bytes + every build knob), so
# same-shape-different-data inputs can never collide.  With no active cache
# (direct backend calls) every build runs, exactly as before.
_WL_CACHE_STACK: list[tuple[dict, int]] = []

# Instrumentation lives on the repro.obs metrics registry (the old
# ``_WL_BUILDS``/``_WL_CACHE_HITS`` module globals are gone); the functions
# below are the stable read surface tests and callers use.
_M_BUILDS = _obsm.counter(
    "worklist_builds", "host flat-worklist builds (cache misses included)")
_M_CACHE_HITS = _obsm.counter(
    "worklist_cache_hits", "fingerprint hits inside a worklist_cache scope")
_M_FP_MISSES = _obsm.counter(
    "worklist_fingerprint_misses",
    "cache was active but the content fingerprint was absent (true rebuild)")
_G_WL_LEN = _obsm.gauge(
    "worklist_len", "kept tile-pair count of the most recent build")
_G_WL_PRUNED = _obsm.gauge(
    "worklist_pruned_frac", "pruned tile fraction of the most recent build")


@contextmanager
def worklist_cache(cache, max_entries: int = 8,
                   max_bytes: int = 64 << 20):
    """Activate ``cache`` (a MutableMapping, LRU-trimmed to ``max_entries``
    AND to ``max_bytes`` of worklist table data — dense-degenerate
    worklists can reach tens of MB, so the cap is size-aware) for
    build_flat_worklist calls inside the context."""
    _WL_CACHE_STACK.append((cache, max_entries, max_bytes))
    try:
        yield cache
    finally:
        _WL_CACHE_STACK.pop()


@contextmanager
def suspend_counters():
    """Scope inside which worklist instrumentation is discarded.

    Plan-time static analysis (``engine.planner._plan_check``) builds
    throwaway worklists to probe kernel structure; those must not count as
    real builds or cache traffic.  On exit every worklist metric family is
    restored to its value at entry, atomically per family.
    """
    saved = [(m, m._state()) for m in
             (_M_BUILDS, _M_CACHE_HITS, _M_FP_MISSES, _G_WL_LEN,
              _G_WL_PRUNED)]
    try:
        yield
    finally:
        for m, state in saved:
            m._restore(state)


def _wl_nbytes(wl: "FlatWorklist") -> int:
    return int(wl.meta.nbytes) + int(wl.lb.nbytes)


def worklist_build_count() -> int:
    return int(_M_BUILDS.value())


def worklist_cache_hits() -> int:
    return int(_M_CACHE_HITS.value())


def worklist_fingerprint_misses() -> int:
    return int(_M_FP_MISSES.value())


def _src_dtype_tag(arr) -> str:
    """The dtype the CALLER handed us, before any canonicalizing cast.

    Worklists are fingerprinted on the f32-converted coordinates, but two
    callers passing the same coordinates at different source precisions are
    different cache identities: the sweep kernels consume the *original*
    arrays, so a worklist built for one must not be served to the other
    (a f64 pad row that rounds onto a kept f32 point, say, has different
    pruning slack).  The tag rides the fingerprint alongside the bytes.
    """
    if arr is None:
        return "none"
    dt = getattr(arr, "dtype", None)
    return str(dt) if dt is not None else np.asarray(arr).dtype.name


def _wl_fingerprint(x, y, d_cut, block_n, block_m, count, nn, k, nn_dcut,
                    nn_col_counts, starts, ends,
                    src_dtypes: tuple = ()) -> bytes:
    import hashlib

    h = hashlib.blake2b(digest_size=16)
    for arr in (x, y, nn_col_counts, starts, ends):
        if arr is None:
            h.update(b"\x00none")
        else:
            a = np.ascontiguousarray(np.asarray(arr))
            h.update(str(a.shape).encode())
            h.update(str(a.dtype).encode())
            h.update(a.tobytes())
    h.update(repr((None if d_cut is None else float(d_cut), block_n,
                   block_m, bool(count), nn, int(k),
                   bool(nn_dcut), src_dtypes)).encode())
    return h.digest()


def build_flat_worklist(x, y, d_cut=None, *, block_n: int, block_m: int,
                        count: bool = True, nn: str | None = None,
                        k: int = 0, nn_dcut: bool = False,
                        nn_col_counts=None,
                        starts=None, ends=None) -> FlatWorklist:
    """Host-built kept-pair worklist for one pallas sweep.

    Kept pairs are the union of what each requested accumulator can touch:
    ``count`` keeps lb <= d_cut^2; ``nn='topk'`` adds the static k-NN ring
    (see :func:`_knn_radius`; ``nn_col_counts`` overrides the per-col-tile
    admissible-candidate counts when a selection gate restricts the kept-k,
    e.g. S-Approx representatives); ``nn='best1'`` keeps every pair unless
    ``nn_dcut`` bounds the search (halo semantics) — the in-kernel runtime
    radius does the remaining pruning.  ``starts``/``ends`` (halo spans)
    additionally drop col tiles no row span reaches.  At least one pair per
    row tile is force-kept so output blocks always initialize.

    Inside a :func:`worklist_cache` context (a DPCPlan primitive wrapper)
    results are memoized by content fingerprint — same data, same knobs,
    no rebuild.
    """
    src_dtypes = (_src_dtype_tag(x), _src_dtype_tag(y))
    x = np.asarray(x, np.float32)
    y = np.asarray(y, np.float32)
    key = None
    if _WL_CACHE_STACK:
        cache, max_entries, max_bytes = _WL_CACHE_STACK[-1]
        key = _wl_fingerprint(x, y, d_cut, block_n, block_m, count, nn, k,
                              nn_dcut, nn_col_counts, starts, ends,
                              src_dtypes)
        hit = cache.get(key)
        if hit is not None:
            _M_CACHE_HITS.inc()
            if hasattr(cache, "move_to_end"):
                cache.move_to_end(key)
            return hit
        _M_FP_MISSES.inc()
    _M_BUILDS.inc()
    n, _ = x.shape
    m = y.shape[0]
    nbr, nbc = -(-n // block_n), -(-m // block_m)
    lb, ub = host_pair_bounds(x, y, block_n, block_m)
    d2cut = None if d_cut is None else float(d_cut) ** 2

    in_cut = np.zeros((nbr, nbc), bool)
    keep = np.zeros((nbr, nbc), bool)
    if count:
        assert d2cut is not None
        in_cut = lb <= d2cut
        keep |= in_cut
    if nn == "best1":
        if nn_dcut:
            assert d2cut is not None
            keep |= lb <= d2cut
        else:
            keep[:] = True
    elif nn == "topk":
        if nn_col_counts is None:
            col_counts = np.minimum(block_m, np.maximum(
                0, m - np.arange(nbc) * block_m))
        else:
            col_counts = np.asarray(nn_col_counts)
        radius = _knn_radius(ub, col_counts, max(k, 1))
        keep |= lb <= radius[:, None]

    if starts is not None:
        st = np.asarray(starts)
        en = np.asarray(ends)
        pad_rows = nbr * block_n - n
        if pad_rows:
            st = np.pad(st, ((0, pad_rows), (0, 0)))
            en = np.pad(en, ((0, pad_rows), (0, 0)))
        live = en > st
        smin = np.where(live, st, np.iinfo(np.int64).max) \
            .reshape(nbr, block_n, -1).min(axis=(1, 2))
        emax = np.where(live, en, np.iinfo(np.int64).min) \
            .reshape(nbr, block_n, -1).max(axis=(1, 2))
        jlo = np.arange(nbc) * block_m
        overlap = (smin[:, None] < jlo[None, :] + block_m) & \
                  (emax[:, None] > jlo[None, :])
        keep &= overlap
        in_cut &= overlap

    # force-keep the min-lb pair of every row tile (output block init)
    jmin = np.argmin(lb, axis=1)
    keep[np.arange(nbr), jmin] = True

    wi, wj = np.nonzero(keep)
    wl = lb[wi, wj]
    sort = np.lexsort((wl, wi))
    wi, wj, wl = wi[sort], wj[sort], wl[sort]
    first = np.zeros(len(wi), np.int32)
    first[np.unique(wi, return_index=True)[1]] = 1
    meta = np.stack([wi, wj, first,
                     in_cut[wi, wj].astype(np.int64)]).astype(np.int32)
    out = FlatWorklist(meta=jnp.asarray(meta),
                       lb=jnp.asarray(wl.astype(np.float32)),
                       n_kept=len(wi), n_total=nbr * nbc)
    _G_WL_LEN.set(out.n_kept)
    _G_WL_PRUNED.set(round(out.pruned_frac, 6))
    if key is not None:
        cache[key] = out
        while len(cache) > 1 and (
                len(cache) > max_entries
                or sum(map(_wl_nbytes, cache.values())) > max_bytes):
            cache.pop(next(iter(cache)))    # oldest entry (insertion order)
    return out


def worklist_stats(x, y, d_cut, *, block_n: int = BS_BLOCK_N,
                   block_m: int = BS_BLOCK_M) -> dict:
    """Pruning statistics for benchmarks: how much of the dense tile grid
    the d_cut-bounded count sweep keeps (``benchmarks/scaling_dcut.py``
    records this next to runtime so the sensitivity plot shows *why*)."""
    wl = build_flat_worklist(x, y, d_cut, block_n=block_n, block_m=block_m,
                             count=True)
    return {"tiles_total": wl.n_total, "tiles_kept": wl.n_kept,
            "pruned_tile_frac": wl.pruned_frac}
