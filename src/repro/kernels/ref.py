"""Pure-jnp oracles for the Pallas kernels (the ground truth in kernel tests)."""
from __future__ import annotations

import jax.numpy as jnp


def range_count_ref(x: jnp.ndarray, y: jnp.ndarray, d_cut: float) -> jnp.ndarray:
    """For each row of x: |{j : ||x_i - y_j|| < d_cut}| (direct-diff form)."""
    d2 = jnp.sum((x[:, None, :] - y[None, :, :]) ** 2, axis=-1)
    return jnp.sum(d2 < jnp.float32(d_cut) ** 2, axis=1).astype(jnp.int32)


def prefix_min_dist_ref(pts: jnp.ndarray):
    """Prefix NN: for each i, min_j<i ||p_i - p_j|| and its argmin.

    Rows must be sorted by descending density key, so j < i == "j is denser"
    (Ex-DPC's incremental-tree invariant as a static iteration space).
    """
    n = pts.shape[0]
    d2 = jnp.sum((pts[:, None, :] - pts[None, :, :]) ** 2, axis=-1)
    mask = jnp.arange(n)[None, :] < jnp.arange(n)[:, None]
    d2 = jnp.where(mask, d2, jnp.inf)
    arg = jnp.argmin(d2, axis=1)
    best = d2[jnp.arange(n), arg]
    return jnp.sqrt(best), jnp.where(jnp.isfinite(best), arg, -1).astype(jnp.int32)


def masked_min_dist_ref(x, x_key, y, y_key):
    """For each row of x: nearest y with y_key strictly greater (+argmin)."""
    d2 = jnp.sum((x[:, None, :] - y[None, :, :]) ** 2, axis=-1)
    d2 = jnp.where(y_key[None, :] > x_key[:, None], d2, jnp.inf)
    arg = jnp.argmin(d2, axis=1)
    best = d2[jnp.arange(x.shape[0]), arg]
    return jnp.sqrt(best), jnp.where(jnp.isfinite(best), arg, -1).astype(jnp.int32)
