"""Pluggable kernel backend for DPC's two primitives.

The paper's entire contribution is making two primitives fast on parallel
hardware: the range count behind local density (Def. 1) and the
nearest-strictly-denser-neighbor search behind the dependent point (Def. 2).
This module is the seam that lets every algorithm (core, distributed, serve)
pick where those primitives run:

* ``jnp``              — blocked pure-jnp direct-difference forms: the
                         reference implementation and the CPU default.  Bit-
                         identical to the historical ``core.scan`` oracle.
* ``pallas``           — the Mosaic TPU tile-sweep kernels in
                         ``kernels/sweep.py`` (MXU expanded-form tiles).
* ``pallas-interpret`` — the same kernels under the Pallas interpreter, so CI
                         containers without a TPU exercise the kernel code
                         paths (slow; correctness only).

Beyond the two static primitives (+ the triangular prefix variant), every
backend carries:

* the **fused** ``rho_delta`` primitive — Def. 1 and Def. 2 answered by one
  engine invocation instead of two back-to-back sweeps.  The jnp form shares
  one jit (a count pass plus a lean min-only NN pass whose argmin is
  recovered per winning tile); the pallas form is a genuinely single tile
  sweep (count + unmasked kept-k accumulator, the denser-mask resolved in a
  direct-diff epilogue, unresolved rows — the local-maxima tail — re-queried
  with one small masked-NN pass).  ``fused_traceable`` marks backends whose
  ``rho_delta`` is jit-safe end to end (the pallas epilogue's fallback is
  host-orchestrated).
* the **halo** primitives ``range_count_halo`` / ``denser_nn_halo`` — the
  same two definitions restricted to per-row ragged [start, end) windows of
  a halo-exchanged column table (the distributed optimized path).
* the two *streaming* batched primitives used by ``repro.stream``:
  ``range_count_delta`` (signed range count over an insert/evict delta batch
  — the sliding-window rho repair) and ``denser_nn_update`` (Def. 2
  re-queried for a row subset; the pallas backends fuse the row gather into
  the kernel).

Every dense primitive additionally accepts ``layout="block-sparse"``: the
grid-pruned execution mode (``kernels.blocksparse``).  Callers lay the
points out in grid-sorted order (``core.grid``'s sort — the drivers do
this), per-tile AABBs bound every tile pair's distances, and only pairs
that can matter are visited: count accumulators keep pairs with min
inter-AABB distance <= d_cut, NN accumulators walk an ascending-bound ring
with a progressively-shrinking prune radius.  The jnp worklists are
jit-built (``worklist_traceable``: block-sparse stays legal inside
jit/shard_map and ``rho_delta`` stays ``fused_traceable``); the pallas
worklists are host-built, like the grid itself, and drive a scalar-
prefetched 1-D kernel grid.  f32 results are bit-identical to the dense
layout of the same backend — pruning bounds carry conservative slack
covering f32 rounding of the bound arithmetic, NN tie-breaks are
explicitly lexicographic — which is property-tested on tie-heavy lattice
data (tests/test_blocksparse.py).  Under ``precision="bf16"`` the bounds
remain *true-distance* conservative (never prune a truly-relevant pair),
but the dense bf16 sweep evaluates tile distances with ~2^-8 relative
error, so on data where that error is material the two layouts can keep
different candidates — block-sparse == dense-bf16 exactly on
exactly-representable data (tested), and up to bf16 rounding elsewhere
(the same caveat bf16 itself carries).  Correctness never depends on the
input order; only the pruning rate does.

``get_backend(None)`` auto-detects: ``pallas`` on TPU, ``jnp`` elsewhere.
Numerical contract: the pallas backends compute squared distances in the MXU
expanded form |x|^2+|y|^2-2xy (then re-rank the top-k candidates direct-diff,
see sweep.refine_topk_d2), so pairs within f32 rounding of a threshold
can be classified differently from ``jnp``.  Equality tests draw data away
from thresholds; production consumers treat the backends as interchangeable.
The pallas backends additionally accept ``precision="bf16"`` on ``rho_delta``
(bf16 inner product at twice the MXU rate, winners refined back to f32
direct-diff); the jnp backend is the f32 reference and rejects it.
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from . import blocksparse, ops

__all__ = ["KernelBackend", "available_backends", "default_backend_name",
           "get_backend", "register_backend", "rho_delta_sequential"]


def _default_jitter(n: int):
    from repro.core.dpc_types import density_jitter  # lazy: avoids a cycle
    return density_jitter(n)


def _pow2_pad(m: int) -> int:
    p = 1
    while p < m:
        p *= 2
    return p


def _sparse(layout: str | None) -> bool:
    """Resolve a layout name: None/'dense' -> False, 'block-sparse' -> True."""
    if layout in (None, "dense"):
        return False
    if layout == "block-sparse":
        return True
    raise ValueError(f"unknown layout {layout!r}; "
                     "expected 'dense' or 'block-sparse'")


def _require_host(name: str, *arrays) -> None:
    """Pallas worklists are host-built (like the grid index itself)."""
    if any(isinstance(a, jax.core.Tracer) for a in arrays):
        raise ValueError(
            f"{name}(layout='block-sparse') on a pallas backend builds its "
            "tile worklist on the host; call it outside jit/shard_map, or "
            "use the jnp backend (worklist_traceable) for traced callers")


def rho_delta_sequential(be: "KernelBackend", x, y, d_cut, *, jitter=None,
                         y_sel_slots=None, block: int | None = None,
                         layout: str | None = None):
    """The two-pass reference formulation of the fused primitive.

    Def. 1 then Def. 2 as separate backend calls — the parity oracle the
    fused ``rho_delta`` implementations are tested against, and the default
    for backends that do not override it.  ``y_sel_slots`` (len(x) int,
    y-row of query i) restricts the NN candidate set to the query rows
    themselves mapped into y space (S-Approx representatives); ``None``
    means y *is* the query set (identity correspondence).
    """
    rho = be.range_count(x, y, d_cut, block=block, layout=layout)
    if jitter is None:
        jitter = _default_jitter(x.shape[0])
    rho_key = rho + jitter
    if y_sel_slots is None:
        assert x.shape[0] == y.shape[0], \
            "identity rho_delta needs y rows == query rows"
        col_key = rho_key
    else:
        col_key = jnp.full((y.shape[0],), -jnp.inf,
                           jnp.float32).at[y_sel_slots].set(rho_key)
    delta, parent = be.denser_nn(x, rho_key, y, col_key, block=block,
                                 layout=layout)
    return rho, rho_key, delta, parent


# --------------------------------------------------------------- interface
class KernelBackend:
    """The DPC primitives (Def. 1 / Def. 2 + fused, halo and streaming forms).

    ``mxu_dense`` tells algorithm drivers this backend wants the dense tiled
    formulation (all-pairs MXU tiles) rather than the grid-stencil gathers;
    the stencil IS the jnp reference, so only the pallas backends set it.
    ``fused_traceable`` marks a ``rho_delta`` that is safe to call inside
    jit/vmap (no host-orchestrated fallback step).  ``worklist_traceable``
    marks a backend whose block-sparse worklists are jit-built — its
    ``layout="block-sparse"`` primitives stay legal inside jit/shard_map
    (the pallas worklists are host-built, like the grid index).
    """

    name: str = "abstract"
    mxu_dense: bool = False
    fused_traceable: bool = False
    worklist_traceable: bool = False

    def range_count(self, x, y, d_cut, *, block: int | None = None,
                    layout: str | None = None):
        """(n,) f32: |{j : ||x_i - y_j|| < d_cut}| per row of x (Def. 1)."""
        raise NotImplementedError

    def denser_nn(self, x, x_key, y, y_key, *, block: int | None = None,
                  layout: str | None = None):
        """(delta, parent): NN among y rows with y_key strictly greater
        (Def. 2).  delta = +inf, parent = -1 where no such row exists."""
        raise NotImplementedError

    def prefix_nn(self, pts_sorted_desc, *, block: int | None = None):
        """(delta, parent): NN among strict-prefix rows, input pre-sorted by
        descending density key — Def. 2 as a triangular sweep (Ex-DPC)."""
        raise NotImplementedError

    # ---- fused rho + delta (the unified-engine primitive) ----

    def rho_delta(self, x, y, d_cut, *, jitter=None, y_sel_slots=None,
                  block: int | None = None, precision: str | None = None,
                  fallback_interest=None, layout: str | None = None):
        """Fused Def. 1 + Def. 2: per x-row range count over y AND the
        nearest strictly-denser neighbor, one engine invocation.

        Returns (rho, rho_key, delta, parent); rho_key = rho + jitter
        (all-distinct comparison key), parent in y-row index space.
        ``y_sel_slots``: see :func:`rho_delta_sequential`.  ``precision``:
        pallas backends accept ``"bf16"`` for the tile inner product (winners
        refined back to f32 direct-diff); default f32.  ``layout``:
        ``"block-sparse"`` selects the grid-pruned worklist mode (callers
        should pass grid-sorted points — pruning quality, not correctness,
        depends on the layout).

        ``fallback_interest``: optional ``rho_key -> (nx,) bool`` callable
        naming the rows whose Def.-2 answer the caller will actually consume
        (e.g. Approx-DPC reads it only for the cell maxima).  Backends whose
        fused path re-queries unresolved rows may restrict that pass to the
        interest set — rows outside it can come back as (inf, -1) when the
        kept-k did not resolve them.  Exact backends ignore it.
        """
        if precision not in (None, "f32"):
            raise ValueError(f"{self.name} backend computes f32 only")
        del fallback_interest  # every row exact: nothing to restrict
        return rho_delta_sequential(self, x, y, d_cut, jitter=jitter,
                                    y_sel_slots=y_sel_slots, block=block,
                                    layout=layout)

    # ---- halo-window primitives (distributed optimized path) ----

    def range_count_halo(self, x, window, starts, ends, d_cut, *,
                         span_cap: int, block: int | None = None,
                         layout: str | None = None):
        """Def. 1 restricted to per-row ragged [start, end) windows into a
        halo-exchanged column table.  ``starts``/``ends``: (n, S)
        window-local span bounds (empty or negative spans count nothing;
        a row's spans must be pairwise disjoint, as the grid's candidate-cell
        spans are); ``span_cap``: static max span length (gather-form
        backends)."""
        raise NotImplementedError

    def denser_nn_halo(self, x, x_key, window, w_key, starts, ends, d_cut, *,
                       span_cap: int, block: int | None = None,
                       layout: str | None = None):
        """Def. 2 restricted to the row's halo spans AND to d_cut (stencil
        semantics).  Returns (delta, parent_window_idx, found); rows with no
        strictly-denser candidate within d_cut inside their spans report
        found = False (the caller's global fallback handles them)."""
        raise NotImplementedError

    # ---- streaming (repro.stream) batched primitives ----

    def range_count_delta(self, x, batch, signs, d_cut, *,
                          block: int | None = None,
                          layout: str | None = None):
        """(n,) f32 signed count: sum_b signs[b] * [||x_i - batch_b|| < d_cut].

        The sliding-window rho repair (each surviving point's density changes
        by +1 per inserted / -1 per evicted neighbor): signs are +1 for
        inserted rows, -1 for evicted rows, 0 for padding.  With
        ``layout="block-sparse"`` the window's row tiles outside d_cut of
        the batch AABB are pruned — pays when batches are spatially
        localized (drifting streams)."""
        raise NotImplementedError

    def denser_nn_update(self, points, rho_key, q_slots, *,
                         block: int | None = None,
                         layout: str | None = None):
        """Def. 2 recomputed for the row subset ``q_slots`` of ``points``.

        The streaming delta repair: only rows whose dependent point may have
        changed (cell maxima / dirty rows) are re-queried against the full
        window.  ``q_slots`` entries >= len(points) are padding and return
        (inf, -1).  Rides each backend's denser-NN kernel; the pallas
        backends override with the fused-gather kernel (the gathered subset
        never materialises)."""
        n = points.shape[0]
        slot_c = jnp.clip(q_slots, 0, n - 1)
        valid = q_slots < n
        q = points[slot_c]
        qk = jnp.where(valid, rho_key[slot_c], jnp.inf)  # +inf key: inert row
        return self.denser_nn(q, qk, points, rho_key, block=block,
                              layout=layout)


# ------------------------------------------------------------ jnp reference
@partial(jax.jit, static_argnames=("block",))
def _range_count_jnp(x, y, d_cut, block: int = 512):
    """Blocked direct-difference range count (row blocks x column loop)."""
    n, d = x.shape
    m = y.shape[0]
    nbr, nbc = -(-n // block), -(-m // block)
    xp = jnp.pad(x, ((0, nbr * block - n), (0, 0)), constant_values=jnp.inf)
    yp = jnp.pad(y, ((0, nbc * block - m), (0, 0)), constant_values=jnp.inf)
    d2cut = jnp.asarray(d_cut, jnp.float32) ** 2

    def row_block(i0):
        rows = jax.lax.dynamic_slice_in_dim(xp, i0, block, 0)

        def col_block(j, acc):
            cols = jax.lax.dynamic_slice_in_dim(yp, j * block, block, 0)
            d2 = jnp.sum((rows[:, None, :] - cols[None, :, :]) ** 2, -1)
            return acc + jnp.sum(d2 < d2cut, axis=1).astype(jnp.int32)

        return jax.lax.fori_loop(0, nbc, col_block,
                                 jnp.zeros((block,), jnp.int32))

    cnt = jax.lax.map(row_block, jnp.arange(nbr) * block).reshape(-1)[:n]
    return cnt.astype(jnp.float32)


@partial(jax.jit, static_argnames=("block",))
def _denser_nn_jnp(x, x_key, y, y_key, block: int = 512):
    """Blocked direct-difference masked NN with a running (min, argmin)."""
    n, d = x.shape
    m = y.shape[0]
    nbr, nbc = -(-n // block), -(-m // block)
    xp = jnp.pad(x, ((0, nbr * block - n), (0, 0)), constant_values=jnp.inf)
    xk = jnp.pad(x_key, (0, nbr * block - n), constant_values=jnp.inf)
    yp = jnp.pad(y, ((0, nbc * block - m), (0, 0)), constant_values=jnp.inf)
    yk = jnp.pad(y_key, (0, nbc * block - m), constant_values=-jnp.inf)

    def row_block(i0):
        rows = jax.lax.dynamic_slice_in_dim(xp, i0, block, 0)
        rrk = jax.lax.dynamic_slice_in_dim(xk, i0, block, 0)

        def col_block(j, carry):
            best, arg = carry
            cols = jax.lax.dynamic_slice_in_dim(yp, j * block, block, 0)
            crk = jax.lax.dynamic_slice_in_dim(yk, j * block, block, 0)
            d2 = jnp.sum((rows[:, None, :] - cols[None, :, :]) ** 2, -1)
            d2 = jnp.where(crk[None, :] > rrk[:, None], d2, jnp.inf)
            jj = jnp.argmin(d2, axis=1)
            cand = d2[jnp.arange(block), jj]
            better = cand < best
            return (jnp.where(better, cand, best),
                    jnp.where(better, j * block + jj, arg))

        best, arg = jax.lax.fori_loop(
            0, nbc, col_block,
            (jnp.full((block,), jnp.inf), jnp.full((block,), -1, jnp.int64)))
        return jnp.sqrt(best), jnp.where(jnp.isfinite(best), arg, -1)

    delta, parent = jax.lax.map(row_block, jnp.arange(nbr) * block)
    return delta.reshape(-1)[:n], parent.reshape(-1)[:n].astype(jnp.int32)


@partial(jax.jit, static_argnames=("block",))
def _range_count_delta_jnp(x, batch, signs, d_cut, block: int = 512):
    """Blocked direct-difference *signed* range count (streaming rho repair).

    One fused pass over the delta batch: each batch column contributes its
    sign (+1 inserted / -1 evicted / 0 pad) to every x-row within d_cut."""
    n, d = x.shape
    m = batch.shape[0]
    nbr, nbc = -(-n // block), -(-m // block)
    xp = jnp.pad(x, ((0, nbr * block - n), (0, 0)), constant_values=jnp.inf)
    bp = jnp.pad(batch, ((0, nbc * block - m), (0, 0)),
                 constant_values=jnp.inf)
    sp = jnp.pad(signs.astype(jnp.float32), (0, nbc * block - m),
                 constant_values=0.0)
    d2cut = jnp.asarray(d_cut, jnp.float32) ** 2

    def row_block(i0):
        rows = jax.lax.dynamic_slice_in_dim(xp, i0, block, 0)

        def col_block(j, acc):
            cols = jax.lax.dynamic_slice_in_dim(bp, j * block, block, 0)
            s = jax.lax.dynamic_slice_in_dim(sp, j * block, block, 0)
            d2 = jnp.sum((rows[:, None, :] - cols[None, :, :]) ** 2, -1)
            return acc + jnp.sum(jnp.where(d2 < d2cut, s[None, :], 0.0),
                                 axis=1)

        return jax.lax.fori_loop(0, nbc, col_block,
                                 jnp.zeros((block,), jnp.float32))

    cnt = jax.lax.map(row_block, jnp.arange(nbr) * block).reshape(-1)[:n]
    return cnt


@partial(jax.jit, static_argnames=("block",))
def _rho_delta_jnp(x, y, jitter, d_cut, y_sel_slots=None, block: int = 512):
    """Fused rho + delta, direct-difference, one jit.

    Pass 1 is the blocked range count; pass 2 is a *lean* masked NN that
    keeps only (min d2, winning column tile) per row — no per-tile argmin or
    gathers on the hot loop; the argmin is recovered afterwards by
    recomputing the single winning tile per row block (bit-identical floats,
    so the recovered winner equals the sequential formulation's exactly).
    """
    n, d = x.shape
    m = y.shape[0]
    nbr, nbc = -(-n // block), -(-m // block)
    xp = jnp.pad(x, ((0, nbr * block - n), (0, 0)), constant_values=jnp.inf)
    yp = jnp.pad(y, ((0, nbc * block - m), (0, 0)), constant_values=jnp.inf)
    d2cut = jnp.asarray(d_cut, jnp.float32) ** 2

    # ---- pass 1: range count (Def. 1) ----
    def row_count(i0):
        rows = jax.lax.dynamic_slice_in_dim(xp, i0, block, 0)

        def col(j, acc):
            cols = jax.lax.dynamic_slice_in_dim(yp, j * block, block, 0)
            d2 = jnp.sum((rows[:, None, :] - cols[None, :, :]) ** 2, -1)
            return acc + jnp.sum(d2 < d2cut, axis=1).astype(jnp.int32)

        return jax.lax.fori_loop(0, nbc, col, jnp.zeros((block,), jnp.int32))

    cnt = jax.lax.map(row_count, jnp.arange(nbr) * block).reshape(-1)[:n]
    rho = cnt.astype(jnp.float32)
    rho_key = rho + jitter
    if y_sel_slots is None:
        col_key = rho_key
    else:
        col_key = jnp.full((m,), -jnp.inf,
                           jnp.float32).at[y_sel_slots].set(rho_key)
    rkp = jnp.pad(rho_key, (0, nbr * block - n), constant_values=jnp.inf)
    ckp = jnp.pad(col_key, (0, nbc * block - m), constant_values=-jnp.inf)

    # ---- pass 2 + epilogue: lean masked NN (Def. 2) ----
    def row_nn(i0):
        rows = jax.lax.dynamic_slice_in_dim(xp, i0, block, 0)
        rrk = jax.lax.dynamic_slice_in_dim(rkp, i0, block, 0)

        def col(j, carry):
            best, jwin = carry
            cols = jax.lax.dynamic_slice_in_dim(yp, j * block, block, 0)
            crk = jax.lax.dynamic_slice_in_dim(ckp, j * block, block, 0)
            d2 = jnp.sum((rows[:, None, :] - cols[None, :, :]) ** 2, -1)
            cand = jnp.min(jnp.where(crk[None, :] > rrk[:, None], d2,
                                     jnp.inf), axis=1)
            better = cand < best
            return (jnp.where(better, cand, best), jnp.where(better, j, jwin))

        best, jwin = jax.lax.fori_loop(
            0, nbc, col, (jnp.full((block,), jnp.inf),
                          jnp.zeros((block,), jnp.int32)))
        # recover the argmin inside each row's winning tile (same float ops
        # on the same operands -> bitwise-equal d2 -> the sequential winner)
        cidx = jwin[:, None] * block + jnp.arange(block)[None, :]
        cols = yp[cidx]                              # (block, block, d)
        crk = ckp[cidx]
        d2r = jnp.sum((rows[:, None, :] - cols) ** 2, -1)
        d2m = jnp.where(crk > rrk[:, None], d2r, jnp.inf)
        jloc = jnp.argmin(d2m, axis=1)
        parent = jnp.where(jnp.isfinite(best),
                           cidx[jnp.arange(block), jloc], -1)
        return jnp.sqrt(best), parent

    delta, parent = jax.lax.map(row_nn, jnp.arange(nbr) * block)
    return (rho, rho_key, delta.reshape(-1)[:n],
            parent.reshape(-1)[:n].astype(jnp.int32))


@partial(jax.jit, static_argnames=("span_w", "block"))
def _range_count_halo_jnp(x, window, starts, ends, d_cut, span_w: int,
                          block: int = 256):
    """Gather-form halo range count: per-row candidate spans into a window."""
    W = window.shape[0]
    m, d = x.shape
    nb = -(-m // block)
    mp = nb * block
    xp = jnp.pad(x, ((0, mp - m), (0, 0)), constant_values=jnp.inf)
    st_p = jnp.pad(starts, ((0, mp - m), (0, 0)), constant_values=0)
    en_p = jnp.pad(ends, ((0, mp - m), (0, 0)), constant_values=0)
    d2cut = jnp.asarray(d_cut, jnp.float32) ** 2

    def chunk(i0):
        rows = jax.lax.dynamic_slice_in_dim(xp, i0, block, 0)
        st = jax.lax.dynamic_slice_in_dim(st_p, i0, block, 0)
        en = jax.lax.dynamic_slice_in_dim(en_p, i0, block, 0)
        idx = st[..., None] + jnp.arange(span_w, dtype=st.dtype)
        valid = (idx < en[..., None]) & (idx >= 0)
        cand = window[jnp.clip(idx, 0, W - 1)]
        d2 = jnp.sum((rows[:, None, None, :] - cand) ** 2, axis=-1)
        return jnp.sum((d2 < d2cut) & valid, axis=(1, 2))

    cnt = jax.lax.map(chunk, jnp.arange(nb) * block).reshape(-1)[:m]
    return cnt.astype(jnp.float32)


@partial(jax.jit, static_argnames=("span_w", "block"))
def _denser_nn_halo_jnp(x, x_key, window, w_key, starts, ends, d_cut,
                        span_w: int, block: int = 256):
    """Gather-form halo strictly-denser NN within d_cut (window-local
    parents; found = a qualifying candidate exists inside the spans)."""
    W = window.shape[0]
    m, d = x.shape
    nb = -(-m // block)
    mp = nb * block
    xp = jnp.pad(x, ((0, mp - m), (0, 0)), constant_values=jnp.inf)
    rk_p = jnp.pad(x_key, (0, mp - m), constant_values=jnp.inf)
    st_p = jnp.pad(starts, ((0, mp - m), (0, 0)), constant_values=0)
    en_p = jnp.pad(ends, ((0, mp - m), (0, 0)), constant_values=0)
    d2cut = jnp.asarray(d_cut, jnp.float32) ** 2

    def chunk(i0):
        rows = jax.lax.dynamic_slice_in_dim(xp, i0, block, 0)
        rk = jax.lax.dynamic_slice_in_dim(rk_p, i0, block, 0)
        st = jax.lax.dynamic_slice_in_dim(st_p, i0, block, 0)
        en = jax.lax.dynamic_slice_in_dim(en_p, i0, block, 0)
        idx = st[..., None] + jnp.arange(span_w, dtype=st.dtype)
        valid = (idx < en[..., None]) & (idx >= 0)
        idx_c = jnp.clip(idx, 0, W - 1)
        cand = window[idx_c]
        cand_rk = w_key[idx_c]
        d2 = jnp.sum((rows[:, None, None, :] - cand) ** 2, axis=-1)
        mask = valid & (cand_rk > rk[:, None, None]) & (d2 < d2cut)
        d2m = jnp.where(mask, d2, jnp.inf).reshape(block, -1)
        j = jnp.argmin(d2m, axis=1)
        best = d2m[jnp.arange(block), j]
        pidx = idx_c.reshape(block, -1)[jnp.arange(block), j].astype(jnp.int32)
        ok = jnp.isfinite(best)
        return (jnp.sqrt(best), jnp.where(ok, pidx, -1).astype(jnp.int32), ok)

    dd, pp, ff = jax.lax.map(chunk, jnp.arange(nb) * block)
    return (dd.reshape(-1)[:m], pp.reshape(-1)[:m], ff.reshape(-1)[:m])


class JnpBackend(KernelBackend):
    """Reference backend: the direct-difference math of the Scan oracle.

    Block-sparse routes (``layout="block-sparse"``) run the jit-built ring
    worklists of ``kernels.blocksparse`` — bit-identical outputs (same
    per-tile float expressions, order-independent count sums, lexicographic
    NN winner), sub-quadratic work under the paper's d_cut assumption.
    The halo primitives are gather-form — the candidate spans already ARE
    the grid pruning — so they accept and ignore ``layout``.
    """

    name = "jnp"
    mxu_dense = False
    fused_traceable = True
    worklist_traceable = True

    def range_count(self, x, y, d_cut, *, block=None, layout=None):
        if _sparse(layout):
            return blocksparse._count_bs_jnp(x, y, None, d_cut)
        return _range_count_jnp(x, y, d_cut, block=block or 512)

    def range_count_delta(self, x, batch, signs, d_cut, *, block=None,
                          layout=None):
        if _sparse(layout):
            return blocksparse._count_bs_jnp(x, batch, signs, d_cut,
                                             signed=True)
        return _range_count_delta_jnp(x, batch, signs, d_cut,
                                      block=block or 512)

    def denser_nn(self, x, x_key, y, y_key, *, block=None, layout=None):
        if _sparse(layout):
            return blocksparse._denser_nn_bs_jnp(x, x_key, y, y_key)
        return _denser_nn_jnp(x, x_key, y, y_key, block=block or 512)

    def prefix_nn(self, pts_sorted_desc, *, block=None):
        # strict prefix == strictly greater key when keyed by -row_index
        n = pts_sorted_desc.shape[0]
        kdt = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
        key = -jnp.arange(n, dtype=kdt)
        return _denser_nn_jnp(pts_sorted_desc, key, pts_sorted_desc, key,
                              block=block or 512)

    def rho_delta(self, x, y, d_cut, *, jitter=None, y_sel_slots=None,
                  block=None, precision=None, fallback_interest=None,
                  layout=None):
        if precision not in (None, "f32"):
            raise ValueError("the jnp backend is the f32 direct-difference "
                             "reference; use a pallas backend for bf16")
        del fallback_interest  # every row answered exactly on both layouts
        if jitter is None:
            jitter = _default_jitter(x.shape[0])
        if _sparse(layout):
            return blocksparse._rho_delta_bs_jnp(x, y, jitter, d_cut,
                                                 y_sel_slots)
        return _rho_delta_jnp(x, y, jitter, d_cut, y_sel_slots,
                              block=block or 512)

    def range_count_halo(self, x, window, starts, ends, d_cut, *,
                         span_cap, block=None, layout=None):
        del layout  # gather form: the spans already prune the candidates
        return _range_count_halo_jnp(x, window, starts, ends, d_cut,
                                     span_cap, block=block or 256)

    def denser_nn_halo(self, x, x_key, window, w_key, starts, ends, d_cut, *,
                       span_cap, block=None, layout=None):
        del layout  # gather form: the spans already prune the candidates
        return _denser_nn_halo_jnp(x, x_key, window, w_key, starts, ends,
                                   d_cut, span_cap, block=block or 256)


# --------------------------------------------------------------- pallas
@jax.jit
def _fused_resolve(x, y, rho_key, col_key, topv, topi):
    """Direct-diff refine + denser-mask resolution of the kept-k candidates.

    Re-evaluates every kept candidate in direct-difference f32 (extending the
    refine_topk_d2 contract to the fused path: both the winner and its value
    are direct-diff exact whenever the true denser-NN sits within the kept
    k), then picks the nearest strictly-denser one — lexicographic
    (d2, y-index), matching the sequential sweep's tie-break.  Rows with no
    denser kept candidate report resolved = False.
    """
    n, k = topi.shape
    ti = jnp.maximum(topi, 0)
    y_sel = y[ti]                                      # (n, k, d)
    d2d = jnp.sum((x[:, None, :] - y_sel) ** 2, -1)
    ok = (topi >= 0) & (col_key[ti] > rho_key[:, None])
    cand = jnp.where(ok, d2d, jnp.inf)
    best = jnp.min(cand, axis=1)
    tied = jnp.where(cand == best[:, None], topi, jnp.iinfo(jnp.int32).max)
    parent = jnp.min(tied, axis=1)
    resolved = jnp.isfinite(best)
    parent = jnp.where(resolved, parent, -1).astype(jnp.int32)
    return jnp.sqrt(best), parent, resolved


class PallasBackend(KernelBackend):
    """MXU tiled kernels; ``interpret=True`` is the CPU-CI variant.

    Block-sparse routes host-build a :class:`blocksparse.FlatWorklist` and
    hand it to the scalar-prefetched 1-D sweep grid: count primitives get a
    genuinely pruned grid (kept pairs only), NN primitives a ring-ordered
    list whose pairs the kernel skips against its live prune radius, and
    the fused ``rho_delta`` the union of the d_cut prefix and the static
    k-NN ring.  Host-built means not jit-callable (``worklist_traceable``
    stays False) — the same contract as the grid build itself.
    """

    mxu_dense = True

    def __init__(self, interpret: bool):
        self.interpret = interpret
        self.name = "pallas-interpret" if interpret else "pallas"

    def range_count(self, x, y, d_cut, *, block=None, layout=None):
        bn = block or ops.DENSITY_BLOCK_N
        wl = None
        if _sparse(layout):
            _require_host("range_count", x, y)
            wl = blocksparse.build_flat_worklist(
                x, y, d_cut, block_n=bn, block_m=ops.DENSITY_BLOCK_M,
                count=True)
        return ops.local_density_xy(x, y, d_cut, block_n=bn,
                                    interpret=self.interpret, worklist=wl)

    def range_count_delta(self, x, batch, signs, d_cut, *, block=None,
                          layout=None):
        bn = block or ops.DENSITY_BLOCK_N
        wl = None
        if _sparse(layout):
            _require_host("range_count_delta", x, batch)
            wl = blocksparse.build_flat_worklist(
                x, batch, d_cut, block_n=bn, block_m=ops.DENSITY_BLOCK_M,
                count=True)
        return ops.local_density_delta(x, batch, signs, d_cut, block_n=bn,
                                       interpret=self.interpret, worklist=wl)

    def denser_nn(self, x, x_key, y, y_key, *, block=None, layout=None):
        bn = min(block or 128, 1024)
        wl = None
        if _sparse(layout):
            _require_host("denser_nn", x, y)
            wl = blocksparse.build_flat_worklist(
                x, y, None, block_n=bn, block_m=256, count=False, nn="best1")
        return ops.dependent_masked(x, x_key, y, y_key, block_n=bn,
                                    interpret=self.interpret, worklist=wl)

    def prefix_nn(self, pts_sorted_desc, *, block=None):
        return ops.dependent_prefix(pts_sorted_desc, block=block or 256,
                                    interpret=self.interpret)

    def rho_delta(self, x, y, d_cut, *, jitter=None, y_sel_slots=None,
                  block=None, precision=None, fallback_interest=None,
                  layout=None):
        """One tile sweep (count + unmasked kept-k), direct-diff epilogue,
        then one small masked-NN pass for the unresolved tail.

        The kept-k resolution is exact: if any kept candidate is strictly
        denser, every candidate nearer than it would also have been kept, so
        the nearest denser kept candidate IS the dependent point.  Rows
        whose k nearest neighbors are all less dense (the local-maxima /
        jitter-tail fraction) fall through to the fallback —
        ``fallback_interest`` restricts that pass to the rows the caller
        will read (Approx-DPC: the |G| << n cell maxima).  The fallback is
        host-orchestrated, so this path is not jit-safe (fused_traceable is
        False); jitted consumers use the two-pass formulation instead.
        """
        if precision is None:
            precision = "f32"
        if jitter is None:
            jitter = _default_jitter(x.shape[0])
        nn_sel = None
        if y_sel_slots is not None:
            nn_sel = jnp.zeros((y.shape[0],),
                               jnp.float32).at[y_sel_slots].set(1.0)
        bn = block or ops.DENSITY_BLOCK_N
        wl = None
        if _sparse(layout):
            _require_host("rho_delta", x, y)
            # the d_cut prefix (count) union the static kept-k ring (NN):
            # a pair whose lower bound clears k strictly-closer candidates
            # can never contribute a kept entry, so pruning it preserves
            # the kept set bit-for-bit; rows whose true denser-NN lies
            # beyond the kept-k fall to the existing unresolved fallback
            sel_counts = None
            if y_sel_slots is not None:
                # selection-gated kept-k: the static ring must count only
                # the admissible (representative) columns per tile
                nbc = -(-y.shape[0] // ops.DENSITY_BLOCK_M)
                sel_counts = np.bincount(
                    np.asarray(y_sel_slots) // ops.DENSITY_BLOCK_M,
                    minlength=nbc)
            wl = blocksparse.build_flat_worklist(
                x, y, d_cut, block_n=bn, block_m=ops.DENSITY_BLOCK_M,
                count=True, nn="topk", k=ops.FUSED_TOPK,
                nn_col_counts=sel_counts)
        cnt, topv, topi = ops.fused_sweep(x, y, d_cut, nn_sel=nn_sel,
                                          precision=precision,
                                          block_n=bn,
                                          interpret=self.interpret,
                                          worklist=wl)
        rho = cnt
        rho_key = rho + jitter
        if y_sel_slots is None:
            col_key = rho_key
        else:
            col_key = jnp.full((y.shape[0],), -jnp.inf,
                               jnp.float32).at[y_sel_slots].set(rho_key)
        delta, parent, resolved = _fused_resolve(
            jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32),
            rho_key, col_key, topv, topi)
        unres_mask = ~np.asarray(resolved)
        if fallback_interest is not None:
            unres_mask &= np.asarray(fallback_interest(rho_key), bool)
        unresolved = np.nonzero(unres_mask)[0]
        if unresolved.size:
            cap = _pow2_pad(unresolved.size)
            rows = np.pad(unresolved, (0, cap - unresolved.size))
            fd, fp = self.denser_nn(jnp.asarray(x)[rows], rho_key[rows],
                                    y, col_key, block=block)
            dd = np.asarray(delta).copy()
            pp = np.asarray(parent).copy()
            dd[unresolved] = np.asarray(fd)[: unresolved.size]
            pp[unresolved] = np.asarray(fp)[: unresolved.size]
            delta, parent = jnp.asarray(dd), jnp.asarray(pp)
        return rho, rho_key, delta, parent

    def range_count_halo(self, x, window, starts, ends, d_cut, *,
                         span_cap, block=None, layout=None):
        del span_cap  # dense span-masked tiles: no gather width needed
        bn = block or ops.DENSITY_BLOCK_N
        wl = None
        if _sparse(layout):
            _require_host("range_count_halo", x, window)
            wl = blocksparse.build_flat_worklist(
                x, window, d_cut, block_n=bn, block_m=ops.DENSITY_BLOCK_M,
                count=True, starts=starts, ends=ends)
        return ops.halo_density(x, window, starts, ends, d_cut, block_n=bn,
                                interpret=self.interpret, worklist=wl)

    def denser_nn_halo(self, x, x_key, window, w_key, starts, ends, d_cut, *,
                       span_cap, block=None, layout=None):
        del span_cap
        bn = min(block or 128, 1024)
        wl = None
        if _sparse(layout):
            _require_host("denser_nn_halo", x, window)
            # halo NN is d_cut-bounded (stencil semantics), so the best-1
            # ring prunes statically by lb <= d_cut^2 AND span reach
            wl = blocksparse.build_flat_worklist(
                x, window, d_cut, block_n=bn, block_m=ops.DENSITY_BLOCK_M,
                count=False, nn="best1", nn_dcut=True,
                starts=starts, ends=ends)
        return ops.halo_dependent(x, x_key, window, w_key, starts, ends,
                                  d_cut, block_n=bn,
                                  interpret=self.interpret, worklist=wl)

    def denser_nn_update(self, points, rho_key, q_slots, *, block=None,
                         layout=None):
        del layout  # the fused-gather kernel is already subset-shaped
        return ops.dependent_masked_gather(points, rho_key, q_slots,
                                           block_n=min(block or 128, 1024),
                                           interpret=self.interpret)


# --------------------------------------------------------------- registry
_REGISTRY: dict = {}
_INSTANCES: dict = {}


def register_backend(name: str, factory) -> None:
    """Register a backend factory under ``name`` (instantiated lazily)."""
    _REGISTRY[name] = factory
    _INSTANCES.pop(name, None)


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


def default_backend_name() -> str:
    """Platform auto-detection: kernels on TPU, reference elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def get_backend(backend: str | KernelBackend | None = None) -> KernelBackend:
    """Resolve a backend name (or None/'auto' for platform default)."""
    if isinstance(backend, KernelBackend):
        return backend
    name = backend if backend not in (None, "auto") else default_backend_name()
    if name not in _REGISTRY:
        raise ValueError(f"unknown kernel backend {name!r}; "
                         f"available: {available_backends()}")
    if name not in _INSTANCES:
        _INSTANCES[name] = _REGISTRY[name]()
    return _INSTANCES[name]


register_backend("jnp", JnpBackend)
register_backend("pallas", lambda: PallasBackend(interpret=False))
register_backend("pallas-interpret", lambda: PallasBackend(interpret=True))
