"""Pluggable kernel backend for DPC's two primitives.

The paper's entire contribution is making two primitives fast on parallel
hardware: the range count behind local density (Def. 1) and the
nearest-strictly-denser-neighbor search behind the dependent point (Def. 2).
This module is the seam that lets every algorithm (core, distributed, serve)
pick where those primitives run:

* ``jnp``              — blocked pure-jnp direct-difference forms: the
                         reference implementation and the CPU default.  Bit-
                         identical to the historical ``core.scan`` oracle.
* ``pallas``           — the Mosaic TPU kernels in ``kernels/density.py`` /
                         ``kernels/dependent.py`` (MXU expanded-form tiles).
* ``pallas-interpret`` — the same kernels under the Pallas interpreter, so CI
                         containers without a TPU exercise the kernel code
                         paths (slow; correctness only).

Beyond the two static primitives (+ the triangular prefix variant), every
backend carries the two *streaming* batched primitives used by
``repro.stream``: ``range_count_delta`` (signed range count over an
insert/evict delta batch — the sliding-window rho repair) and
``denser_nn_update`` (Def. 2 re-queried for a row subset — the delta repair
for points whose dependent may have changed).

``get_backend(None)`` auto-detects: ``pallas`` on TPU, ``jnp`` elsewhere.
Numerical contract: the pallas backends compute squared distances in the MXU
expanded form |x|^2+|y|^2-2xy (then re-rank the top-k candidates direct-diff,
see dependent._refine_topk_d2), so pairs within f32 rounding of a threshold
can be classified differently from ``jnp``.  Equality tests draw data away
from thresholds; production consumers treat the backends as interchangeable.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ops

__all__ = ["KernelBackend", "available_backends", "default_backend_name",
           "get_backend", "register_backend"]


# --------------------------------------------------------------- interface
class KernelBackend:
    """The two DPC primitives (+ the triangular prefix variant of Def. 2).

    ``mxu_dense`` tells algorithm drivers this backend wants the dense tiled
    formulation (all-pairs MXU tiles) rather than the grid-stencil gathers;
    the stencil IS the jnp reference, so only the pallas backends set it.
    """

    name: str = "abstract"
    mxu_dense: bool = False

    def range_count(self, x, y, d_cut, *, block: int | None = None):
        """(n,) f32: |{j : ||x_i - y_j|| < d_cut}| per row of x (Def. 1)."""
        raise NotImplementedError

    def denser_nn(self, x, x_key, y, y_key, *, block: int | None = None):
        """(delta, parent): NN among y rows with y_key strictly greater
        (Def. 2).  delta = +inf, parent = -1 where no such row exists."""
        raise NotImplementedError

    def prefix_nn(self, pts_sorted_desc, *, block: int | None = None):
        """(delta, parent): NN among strict-prefix rows, input pre-sorted by
        descending density key — Def. 2 as a triangular sweep (Ex-DPC)."""
        raise NotImplementedError

    # ---- streaming (repro.stream) batched primitives ----

    def range_count_delta(self, x, batch, signs, d_cut, *,
                          block: int | None = None):
        """(n,) f32 signed count: sum_b signs[b] * [||x_i - batch_b|| < d_cut].

        The sliding-window rho repair (each surviving point's density changes
        by +1 per inserted / -1 per evicted neighbor): signs are +1 for
        inserted rows, -1 for evicted rows, 0 for padding."""
        raise NotImplementedError

    def denser_nn_update(self, points, rho_key, q_slots, *,
                         block: int | None = None):
        """Def. 2 recomputed for the row subset ``q_slots`` of ``points``.

        The streaming delta repair: only rows whose dependent point may have
        changed (cell maxima / dirty rows) are re-queried against the full
        window.  ``q_slots`` entries >= len(points) are padding and return
        (inf, -1).  Rides each backend's denser-NN kernel; backends may
        override with a fused gather kernel."""
        n = points.shape[0]
        slot_c = jnp.clip(q_slots, 0, n - 1)
        valid = q_slots < n
        q = points[slot_c]
        qk = jnp.where(valid, rho_key[slot_c], jnp.inf)  # +inf key: inert row
        return self.denser_nn(q, qk, points, rho_key, block=block)


# ------------------------------------------------------------ jnp reference
@partial(jax.jit, static_argnames=("block",))
def _range_count_jnp(x, y, d_cut, block: int = 512):
    """Blocked direct-difference range count (row blocks x column loop)."""
    n, d = x.shape
    m = y.shape[0]
    nbr, nbc = -(-n // block), -(-m // block)
    xp = jnp.pad(x, ((0, nbr * block - n), (0, 0)), constant_values=jnp.inf)
    yp = jnp.pad(y, ((0, nbc * block - m), (0, 0)), constant_values=jnp.inf)
    d2cut = jnp.asarray(d_cut, jnp.float32) ** 2

    def row_block(i0):
        rows = jax.lax.dynamic_slice_in_dim(xp, i0, block, 0)

        def col_block(j, acc):
            cols = jax.lax.dynamic_slice_in_dim(yp, j * block, block, 0)
            d2 = jnp.sum((rows[:, None, :] - cols[None, :, :]) ** 2, -1)
            return acc + jnp.sum(d2 < d2cut, axis=1).astype(jnp.int32)

        return jax.lax.fori_loop(0, nbc, col_block,
                                 jnp.zeros((block,), jnp.int32))

    cnt = jax.lax.map(row_block, jnp.arange(nbr) * block).reshape(-1)[:n]
    return cnt.astype(jnp.float32)


@partial(jax.jit, static_argnames=("block",))
def _denser_nn_jnp(x, x_key, y, y_key, block: int = 512):
    """Blocked direct-difference masked NN with a running (min, argmin)."""
    n, d = x.shape
    m = y.shape[0]
    nbr, nbc = -(-n // block), -(-m // block)
    xp = jnp.pad(x, ((0, nbr * block - n), (0, 0)), constant_values=jnp.inf)
    xk = jnp.pad(x_key, (0, nbr * block - n), constant_values=jnp.inf)
    yp = jnp.pad(y, ((0, nbc * block - m), (0, 0)), constant_values=jnp.inf)
    yk = jnp.pad(y_key, (0, nbc * block - m), constant_values=-jnp.inf)

    def row_block(i0):
        rows = jax.lax.dynamic_slice_in_dim(xp, i0, block, 0)
        rrk = jax.lax.dynamic_slice_in_dim(xk, i0, block, 0)

        def col_block(j, carry):
            best, arg = carry
            cols = jax.lax.dynamic_slice_in_dim(yp, j * block, block, 0)
            crk = jax.lax.dynamic_slice_in_dim(yk, j * block, block, 0)
            d2 = jnp.sum((rows[:, None, :] - cols[None, :, :]) ** 2, -1)
            d2 = jnp.where(crk[None, :] > rrk[:, None], d2, jnp.inf)
            jj = jnp.argmin(d2, axis=1)
            cand = d2[jnp.arange(block), jj]
            better = cand < best
            return (jnp.where(better, cand, best),
                    jnp.where(better, j * block + jj, arg))

        best, arg = jax.lax.fori_loop(
            0, nbc, col_block,
            (jnp.full((block,), jnp.inf), jnp.full((block,), -1, jnp.int64)))
        return jnp.sqrt(best), jnp.where(jnp.isfinite(best), arg, -1)

    delta, parent = jax.lax.map(row_block, jnp.arange(nbr) * block)
    return delta.reshape(-1)[:n], parent.reshape(-1)[:n].astype(jnp.int32)


@partial(jax.jit, static_argnames=("block",))
def _range_count_delta_jnp(x, batch, signs, d_cut, block: int = 512):
    """Blocked direct-difference *signed* range count (streaming rho repair).

    One fused pass over the delta batch: each batch column contributes its
    sign (+1 inserted / -1 evicted / 0 pad) to every x-row within d_cut."""
    n, d = x.shape
    m = batch.shape[0]
    nbr, nbc = -(-n // block), -(-m // block)
    xp = jnp.pad(x, ((0, nbr * block - n), (0, 0)), constant_values=jnp.inf)
    bp = jnp.pad(batch, ((0, nbc * block - m), (0, 0)),
                 constant_values=jnp.inf)
    sp = jnp.pad(signs.astype(jnp.float32), (0, nbc * block - m),
                 constant_values=0.0)
    d2cut = jnp.asarray(d_cut, jnp.float32) ** 2

    def row_block(i0):
        rows = jax.lax.dynamic_slice_in_dim(xp, i0, block, 0)

        def col_block(j, acc):
            cols = jax.lax.dynamic_slice_in_dim(bp, j * block, block, 0)
            s = jax.lax.dynamic_slice_in_dim(sp, j * block, block, 0)
            d2 = jnp.sum((rows[:, None, :] - cols[None, :, :]) ** 2, -1)
            return acc + jnp.sum(jnp.where(d2 < d2cut, s[None, :], 0.0),
                                 axis=1)

        return jax.lax.fori_loop(0, nbc, col_block,
                                 jnp.zeros((block,), jnp.float32))

    cnt = jax.lax.map(row_block, jnp.arange(nbr) * block).reshape(-1)[:n]
    return cnt


class JnpBackend(KernelBackend):
    """Reference backend: the direct-difference math of the Scan oracle."""

    name = "jnp"
    mxu_dense = False

    def range_count(self, x, y, d_cut, *, block=None):
        return _range_count_jnp(x, y, d_cut, block=block or 512)

    def range_count_delta(self, x, batch, signs, d_cut, *, block=None):
        return _range_count_delta_jnp(x, batch, signs, d_cut,
                                      block=block or 512)

    def denser_nn(self, x, x_key, y, y_key, *, block=None):
        return _denser_nn_jnp(x, x_key, y, y_key, block=block or 512)

    def prefix_nn(self, pts_sorted_desc, *, block=None):
        # strict prefix == strictly greater key when keyed by -row_index
        n = pts_sorted_desc.shape[0]
        kdt = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
        key = -jnp.arange(n, dtype=kdt)
        return _denser_nn_jnp(pts_sorted_desc, key, pts_sorted_desc, key,
                              block=block or 512)


# --------------------------------------------------------------- pallas
class PallasBackend(KernelBackend):
    """MXU tiled kernels; ``interpret=True`` is the CPU-CI variant."""

    mxu_dense = True

    def __init__(self, interpret: bool):
        self.interpret = interpret
        self.name = "pallas-interpret" if interpret else "pallas"

    def range_count(self, x, y, d_cut, *, block=None):
        return ops.local_density_xy(x, y, d_cut,
                                    block_n=block or ops.DENSITY_BLOCK_N,
                                    interpret=self.interpret)

    def range_count_delta(self, x, batch, signs, d_cut, *, block=None):
        return ops.local_density_delta(x, batch, signs, d_cut,
                                       block_n=block or ops.DENSITY_BLOCK_N,
                                       interpret=self.interpret)

    def denser_nn(self, x, x_key, y, y_key, *, block=None):
        return ops.dependent_masked(x, x_key, y, y_key,
                                    block_n=min(block or 128, 1024),
                                    interpret=self.interpret)

    def prefix_nn(self, pts_sorted_desc, *, block=None):
        return ops.dependent_prefix(pts_sorted_desc, block=block or 256,
                                    interpret=self.interpret)


# --------------------------------------------------------------- registry
_REGISTRY: dict = {}
_INSTANCES: dict = {}


def register_backend(name: str, factory) -> None:
    """Register a backend factory under ``name`` (instantiated lazily)."""
    _REGISTRY[name] = factory
    _INSTANCES.pop(name, None)


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


def default_backend_name() -> str:
    """Platform auto-detection: kernels on TPU, reference elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def get_backend(backend: str | KernelBackend | None = None) -> KernelBackend:
    """Resolve a backend name (or None/'auto' for platform default)."""
    if isinstance(backend, KernelBackend):
        return backend
    name = backend if backend not in (None, "auto") else default_backend_name()
    if name not in _REGISTRY:
        raise ValueError(f"unknown kernel backend {name!r}; "
                         f"available: {available_backends()}")
    if name not in _INSTANCES:
        _INSTANCES[name] = _REGISTRY[name]()
    return _INSTANCES[name]


register_backend("jnp", JnpBackend)
register_backend("pallas", lambda: PallasBackend(interpret=False))
register_backend("pallas-interpret", lambda: PallasBackend(interpret=True))
