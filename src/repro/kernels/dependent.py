"""Dependent-point (Def. 2) kernels — tile-sweep instantiations.

Ex-DPC's delta phase: with points sorted by *descending* density key, the
dependent point of row i is its nearest neighbor among rows j < i.  The
paper's incrementally-rebuilt kd-tree (provably sequential) becomes a static
lower-triangular tile sweep (``prefix_min_dist``); ``masked_min_dist`` is the
rectangular strictly-denser variant (global fallback, S-Approx phase 2); and
``masked_min_dist_halo`` is the same NN restricted to per-row ragged halo
windows (the distributed optimized path).  All three are instantiations of
``kernels.sweep`` — one ``SweepSpec`` each over the shared engine.

Every variant computes tile distances in the MXU expanded form and re-ranks
the top-k candidates per row in direct-difference form
(``sweep.refine_topk_d2``), so near-tie argmins survive ill-conditioned data
(NN distances << domain scale) and the consumed delta value is direct-diff
exact.
"""
from __future__ import annotations

import jax.numpy as jnp

from .sweep import (REFINE_TOPK, SweepSpec, tile_sweep,  # noqa: F401
                    refine_topk_d2 as _refine_topk_d2)

DEFAULT_BLOCK = 256


def prefix_min_dist(pts: jnp.ndarray, block: int = DEFAULT_BLOCK,
                    interpret: bool = False, refine_k: int = REFINE_TOPK,
                    precision: str = "f32"):
    """min_{j<i} ||p_i - p_j|| and argmin, rows sorted by descending key.

    pts must be padded to a multiple of block with PAD_COORD rows.
    Returns (delta (n,), parent (n,) int32, -1 where no prefix).
    """
    spec = SweepSpec(block_n=block, block_m=block, nn="best1", prefix=True,
                     refine_k=refine_k, precision=precision)
    best, arg = tile_sweep(spec, pts, pts, interpret=interpret)
    return jnp.sqrt(best), arg


def masked_min_dist(x, x_key, y, y_key, block_n: int = 128,
                    block_m: int = DEFAULT_BLOCK, interpret: bool = False,
                    refine_k: int = REFINE_TOPK, precision: str = "f32",
                    worklist=None):
    """NN among y-rows with y_key > x_key, per x-row (global fallback)."""
    spec = SweepSpec(block_n=block_n, block_m=block_m, nn="best1", key=True,
                     refine_k=refine_k, precision=precision)
    wm, wb = (worklist.meta, worklist.lb) if worklist is not None else (None,
                                                                       None)
    best, arg = tile_sweep(spec, x, y, x_key=x_key, y_key=y_key,
                           wl_meta=wm, wl_lb=wb, interpret=interpret)
    return jnp.sqrt(best), arg


def masked_min_dist_halo(x, x_key, window, w_key, starts, ends, d_cut,
                         block_n: int = 128, block_m: int = DEFAULT_BLOCK,
                         interpret: bool = False,
                         refine_k: int = REFINE_TOPK,
                         precision: str = "f32", worklist=None):
    """Strictly-denser NN within d_cut over per-row ragged halo windows.

    The distributed delta phase: candidates are the window columns inside the
    row's [start, end) spans that are strictly denser AND within d_cut
    (stencil semantics — beyond-d_cut rows fall to the global fallback).
    Returns (delta, parent_window_idx); parent -1 / delta inf when no
    candidate qualifies.
    """
    spec = SweepSpec(block_n=block_n, block_m=block_m, nn="best1", key=True,
                     span=True, span_s=starts.shape[1], nn_dcut=True,
                     refine_k=refine_k, precision=precision)
    wm, wb = (worklist.meta, worklist.lb) if worklist is not None else (None,
                                                                       None)
    best, arg = tile_sweep(spec, x, window, d_cut, x_key=x_key, y_key=w_key,
                           starts=starts, ends=ends, wl_meta=wm, wl_lb=wb,
                           interpret=interpret)
    return jnp.sqrt(best), arg
