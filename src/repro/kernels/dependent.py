"""Pallas TPU kernel: triangular prefix nearest-neighbor (dependent points).

Ex-DPC's delta phase: with points sorted by *descending* density key, the
dependent point of row i is its nearest neighbor among rows j < i.  The
paper's incrementally-rebuilt kd-tree (provably sequential) becomes a static
lower-triangular tile sweep: tile (i, j) is computed only when j <= i, giving
the 2x triangular saving; within the diagonal tile an iota mask enforces the
strict prefix.  Running (min, argmin) accumulate in the output refs across
the column grid dimension.

Also provides ``masked_min_dist``: NN among rows with strictly greater key —
the global fallback used for stencil-unresolved points and the S-Approx
phase-2 representative search.

Both kernels compute tile distances in the MXU expanded form and re-rank the
top-k candidates per row in direct-difference form (``_refine_topk_d2``), so
near-tie argmins survive ill-conditioned data (NN distances << domain scale)
and the consumed delta value is direct-diff exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 256

# How many expanded-form candidates are re-ranked in direct-difference form
# per row tile.  1 restores the historical refine-the-winner-only behavior
# (value exact, winner potentially flipped by expanded-form rounding).
REFINE_TOPK = 4


def _mxu_d2(x, y):
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    y2 = jnp.sum(y * y, axis=-1, keepdims=True).T
    xy = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    return x2 + y2 - 2.0 * xy


def _refine_topk_d2(x, y, d2, k: int):
    """Re-rank the k smallest expanded-form candidates in direct-diff form.

    The expanded form has absolute error ~eps*(|x|^2+|y|^2) — a large
    *relative* error for small distances, big enough to flip near-tie argmins
    when NN distances are far below the domain scale.  k rounds of extract-
    argmin / re-evaluate-direct-diff (one-hot matmul: MXU-friendly, no
    gather) / retire make both the winner *and* its value direct-diff exact
    whenever the true NN sits within the top-k expanded candidates.

    Masked candidates carry d2 = inf and stay inert.  Returns
    (best_d2_direct, local_argmin); (inf, -1) where no finite candidate.
    """
    bn, bm = d2.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (bn, bm), 1)
    best = jnp.full((bn,), jnp.inf, jnp.float32)
    arg = jnp.full((bn,), -1, jnp.int32)
    work = d2
    for _ in range(max(k, 1)):
        loc = jnp.argmin(work, axis=1).astype(jnp.int32)
        cand = jnp.min(work, axis=1)
        onehot = (loc[:, None] == cols).astype(jnp.float32)
        y_sel = jax.lax.dot_general(onehot, y, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        d2d = jnp.sum((x - y_sel) ** 2, axis=-1)
        d2d = jnp.where(jnp.isfinite(cand), d2d, jnp.inf)     # keep masked inert
        better = d2d < best
        best = jnp.where(better, d2d, best)
        arg = jnp.where(better, loc, arg)
        work = jnp.where(cols == loc[:, None], jnp.inf, work)  # retire winner
    return best, arg


def _prefix_kernel(x_ref, y_ref, best_ref, arg_ref, *, block: int,
                   refine_k: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_ref[...] = jnp.full((block,), jnp.inf, jnp.float32)
        arg_ref[...] = jnp.full((block,), -1, jnp.int32)

    @pl.when(j <= i)  # triangular: upper tiles never touch the MXU
    def _compute():
        d2 = _mxu_d2(x_ref[...], y_ref[...])                  # (block, block)
        row = i * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
        col = j * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
        d2 = jnp.where(col < row, d2, jnp.inf)                # strict prefix
        cand, loc = _refine_topk_d2(x_ref[...], y_ref[...], d2, refine_k)
        better = cand < best_ref[...]
        best_ref[...] = jnp.where(better, cand, best_ref[...])
        arg_ref[...] = jnp.where(better, j * block + loc, arg_ref[...])


@functools.partial(jax.jit, static_argnames=("block", "interpret", "refine_k"))
def prefix_min_dist(pts: jnp.ndarray, block: int = DEFAULT_BLOCK,
                    interpret: bool = False, refine_k: int = REFINE_TOPK):
    """min_{j<i} ||p_i - p_j|| and argmin, rows sorted by descending key.

    pts must be padded to a multiple of block with PAD_COORD rows.
    Returns (delta (n,), parent (n,) int32, -1 where no prefix).
    """
    n, d = pts.shape
    assert n % block == 0
    nb = n // block
    best, arg = pl.pallas_call(
        functools.partial(_prefix_kernel, block=block, refine_k=refine_k),
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((block, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i, j: (i,)),
            pl.BlockSpec((block,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(pts, pts)
    return jnp.sqrt(best), arg


def _masked_kernel(x_ref, xk_ref, y_ref, yk_ref, best_ref, arg_ref, *,
                   block_m: int, refine_k: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_ref[...] = jnp.full_like(best_ref[...], jnp.inf)
        arg_ref[...] = jnp.full_like(arg_ref[...], -1)

    d2 = _mxu_d2(x_ref[...], y_ref[...])
    d2 = jnp.where(yk_ref[...][None, :] > xk_ref[...][:, None], d2, jnp.inf)
    cand, loc = _refine_topk_d2(x_ref[...], y_ref[...], d2, refine_k)
    better = cand < best_ref[...]
    best_ref[...] = jnp.where(better, cand, best_ref[...])
    arg_ref[...] = jnp.where(better, j * block_m + loc, arg_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_m", "interpret",
                                    "refine_k"))
def masked_min_dist(x, x_key, y, y_key, block_n: int = 128,
                    block_m: int = DEFAULT_BLOCK, interpret: bool = False,
                    refine_k: int = REFINE_TOPK):
    """NN among y-rows with y_key > x_key, per x-row (global fallback)."""
    n, d = x.shape
    m, _ = y.shape
    assert n % block_n == 0 and m % block_m == 0
    best, arg = pl.pallas_call(
        functools.partial(_masked_kernel, block_m=block_m, refine_k=refine_k),
        grid=(n // block_n, m // block_m),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_m, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(x, x_key, y, y_key)
    return jnp.sqrt(best), arg
