"""Sharded streaming repair tail: maxima NN, label propagation, centers.

``incremental.make_sharded_repair`` shards the rho repair, but until PR 8
every stage *after* it ran replicated: the dirty-maxima NN re-query, label
propagation and the center-continuity distance matrix all touched the whole
window on every member.  At the north-star scale (64M-point windows) those
replicated stages dominate the tick, so this module gives each one the same
shard_map treatment, over the same flattened data axis:

* **maxima NN re-query** (:func:`make_sharded_nn_update`) — drop-in for
  ``backend.denser_nn_update``: the window rows and their density keys
  shard ``P(axis)``, the (replicated) query rows run the backend's own
  masked-NN primitive against each member's local slice, and the global
  winner is recovered with two explicit lexicographic ``pmin`` reductions
  (value, then lowest global column among the value's holders) — exactly
  the replicated kernel's lowest-index tie-break, bit for bit.  The
  per-shard primitive honors the plan's layout through the same
  ``shard_blocksparse_layout`` probe the batch path uses (no new guards):
  with the PR 8 one-hot ring walk the jnp block-sparse sweep is R1-clean
  inside the multi-partition body.
* **label propagation** (:func:`make_sharded_labels`) — pointer jumping in
  the one-hot-matmul formulation (Xu et al., Faithful-DPC-on-MPI): each
  round, every member jumps its own ``P(axis)`` chunk of the pointer table
  by contracting a ``(chunk, n)`` one-hot of its parents against the
  replicated table (exact 0/1 weights; parent ids < 2^24 are exact in
  f32), then re-replicates with an ``all_gather``.  ceil(log2 n) rounds,
  identical integer trajectories to ``core.labels._propagate``.
* **center matching** (:func:`make_sharded_center_dists`) — the f64
  center-continuity distance matrix, new centers sharded over the data
  axis, previous centers replicated; the greedy host matching consumes the
  gathered matrix unchanged.  No collectives and only ``P(axis)``-local
  outputs, so this body keeps ``check_rep=True``.

Every stage is bit-identical to its replicated predecessor (parity-tested
in ``tests/test_stream.py``) and traced by the R1/R2 analysis rules via
``analysis.targets.stream_targets``.
"""
from __future__ import annotations

import math

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.analysis.audit import audit_check_rep
from repro.launch.mesh import flatten_mesh

_INT32_MAX = np.iinfo(np.int32).max


def make_sharded_nn_update(mesh, axis: str, backend, layout: str | None = None):
    """Sharded Def.-2 re-query: ``backend.denser_nn_update``'s signature,
    window rows sharded ``P(axis)``.

    Build once per (mesh, backend, layout) and reuse across ticks; callers
    resolve ``layout`` through ``distributed.dpc.shard_blocksparse_layout``
    so the shard-phase layout decision (and its R1 probe) is shared with
    the batch path.
    """
    flat = flatten_mesh(mesh, axis)
    S = int(flat.devices.size)

    @audit_check_rep(
        "window rows and keys are P(axis)-local; both outputs are made "
        "identical on every member by explicit lexicographic pmin "
        "reductions (best value, then lowest global winner column among "
        "the holders of that value)",
        collectives=("pmin", "axis_index"))
    def f(w_my, k_my, q, qk):
        rows_per = w_my.shape[0]
        off = (jax.lax.axis_index(axis) * rows_per).astype(jnp.int32)
        # the backend's own masked-NN primitive on my slice: per-pair d2 is
        # the same direct-difference expression as the replicated pass, so
        # min over shards == the replicated min, bitwise
        dd, pp = backend.denser_nn(q, qk, w_my, k_my, layout=layout)
        best = jax.lax.pmin(dd, axis)
        hit = (dd == best) & jnp.isfinite(dd)
        argc = jnp.where(hit, off + pp, _INT32_MAX)
        arg = jax.lax.pmin(argc, axis)
        parent = jnp.where(jnp.isfinite(best), arg, -1).astype(jnp.int32)
        return best, parent

    sm = shard_map(f, mesh=flat,
                   in_specs=(P(axis), P(axis), P(None), P(None)),
                   out_specs=(P(None), P(None)),
                   check_rep=False)   # pallas_call lacks a rep rule
    sm_jit = jax.jit(sm)

    def nn_update(window_dev, rho_key, q_slots):
        n = window_dev.shape[0]
        assert n % S == 0, "device count must divide the window capacity"
        # the replicated prelude of KernelBackend.denser_nn_update: gather
        # the query rows by (clean, slot-derived) index; pad slots >= n are
        # inert +inf-key rows and come back (inf, -1)
        slot_c = jnp.clip(q_slots, 0, n - 1)
        valid = q_slots < n
        q = window_dev[slot_c]
        qk = jnp.where(valid, rho_key[slot_c], jnp.inf)
        return sm_jit(window_dev, rho_key, q, qk)

    nn_update.inner = sm        # the shard_map body, for the R1/R2 sweep
    return nn_update


def make_sharded_labels(mesh, axis: str, capacity: int):
    """Sharded ``assign_labels``: pointer jumping as one-hot matmuls.

    Returns ``assign(res, rho_min, delta_min) -> Clustering``, bit-identical
    to ``core.labels.assign_labels`` (same integer pointer trajectories,
    same center selection and densification).
    """
    from repro.core.labels import Clustering, select_centers

    flat = flatten_mesh(mesh, axis)
    S = int(flat.devices.size)
    n = int(capacity)
    assert n % S == 0, "device count must divide the window capacity"
    chunk = n // S
    steps = max(int(math.ceil(math.log2(max(n, 2)))), 1)

    @audit_check_rep(
        "each pointer-jump round contracts my P(axis) chunk's one-hot "
        "against the replicated table and re-replicates with an explicit "
        "all_gather(tiled), identical on every member by construction",
        collectives=("all_gather", "axis_index"))
    def propagate(p0):
        off = jax.lax.axis_index(axis) * chunk
        iota = jnp.arange(n, dtype=jnp.int32)

        def jump(p, _):
            p_my = jax.lax.dynamic_slice_in_dim(p, off, chunk, 0)
            # Xu et al.'s matrix formulation: parent ids select rows of the
            # replicated table by exact 0/1 contraction weights (ids < 2^24
            # are exact in f32), never by a gather index
            onehot = (p_my[:, None] == iota[None, :]).astype(jnp.float32)
            jumped = jax.lax.dot_general(
                onehot, p.astype(jnp.float32)[:, None],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)[:, 0].astype(jnp.int32)
            return jax.lax.all_gather(jumped, axis, axis=0, tiled=True), None

        p, _ = jax.lax.scan(jump, p0, None, length=steps)
        return p

    sm = shard_map(propagate, mesh=flat, in_specs=(P(None),),
                   out_specs=P(None), check_rep=False)
    sm_jit = jax.jit(sm)

    def assign(res, rho_min: float, delta_min: float) -> Clustering:
        from repro import obs

        with obs.span("labels.assign", shards=S) as sp:
            centers, noise = select_centers(res, rho_min, delta_min)
            iota = jnp.arange(n, dtype=res.parent.dtype)
            p0 = jnp.where(centers, iota, res.parent)
            p0 = jnp.where(p0 < 0, iota, p0)          # global peak self-loop
            root = sm_jit(p0.astype(jnp.int32))
            cid = jnp.cumsum(centers.astype(jnp.int32)) - 1
            labels = cid[root]
            reached = centers[root]
            labels = jnp.where(noise | ~reached, -1, labels).astype(jnp.int32)
            sp.sync(labels)
        return Clustering(labels=labels, centers=centers,
                          num_clusters=jnp.sum(centers.astype(jnp.int32)))

    assign.inner = sm           # the shard_map body, for the R1/R2 sweep
    return assign


def make_sharded_center_dists(mesh, axis: str):
    """Sharded center-continuity distances: (m_new, m_old) f64 matrix with
    the new centers sharded over the data axis.  The host greedy matching
    (``StreamDPC._match_centers``) consumes the gathered matrix unchanged;
    per-entry math mirrors the numpy expression exactly."""
    flat = flatten_mesh(mesh, axis)
    S = int(flat.devices.size)

    def dists(new_my, prev):
        diff = (new_my[:, None, :].astype(jnp.float64)
                - prev[None, :, :].astype(jnp.float64))
        return jnp.sqrt(jnp.sum(diff * diff, axis=-1))

    # no collectives, P(axis)-local outputs only: rep checking stays on
    sm = shard_map(dists, mesh=flat, in_specs=(P(axis), P(None)),
                   out_specs=P(axis))
    sm_jit = jax.jit(sm)

    def center_dists(new_pos: np.ndarray, prev_pos: np.ndarray) -> np.ndarray:
        m = int(new_pos.shape[0])
        mp = -(-m // S) * S
        pad = np.zeros((mp, new_pos.shape[1]), np.float32)
        pad[:m] = new_pos
        out = sm_jit(jnp.asarray(pad), jnp.asarray(prev_pos, jnp.float32))
        return np.asarray(out)[:m]

    center_dists.inner = sm     # the shard_map body, for the R1/R2 sweep
    return center_dists
