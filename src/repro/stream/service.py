"""Streaming clustering endpoint with the serve layer's shape discipline.

Mirrors ``serve.engine.ServeEngine``'s production rules for the online-
clustering workload: fixed micro-batch shapes (requests accumulate into a
static ``micro_batch`` and pad, never reshape/recompile), deterministic
behavior, and read-only queries answered from maintained state.

* ``submit`` buffers arriving points and fires a ``StreamDPC.ingest`` tick
  for every full micro-batch (zero or more ticks per call).
* ``flush`` drains the partial remainder as one padded tick.
* ``query`` labels arbitrary points *without mutating the window*, returning
  a :class:`QueryResult` of (labels, status) per point.  A query point whose
  nearest window point lies within d_cut adopts that point's stable cluster
  id (``HIT``; the id is -1 when the window point is noise).  Out-of-coverage
  points no longer get a bare -1: they fall back to the *nearest current
  cluster center* with an explicit ``MISS_FALLBACK`` status, so consumers can
  distinguish "confidently clustered" from "best-effort nearest center" —
  the decide-and-drop policy the roadmap called for.  ``MISS`` (label -1)
  only remains for the no-centers-at-all window.  The window NN runs through
  the backend's ``denser_nn`` with a -inf query key — every window row is
  "denser", so the masked NN degenerates to a plain NN on the same kernels
  the write path uses.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import NamedTuple

import numpy as np
import jax.numpy as jnp

from repro import obs
from repro.kernels.density import PAD_COORD
from repro.resilience import faultinject
from repro.resilience.sanitize import AdmissionConfig, admit

from .stream_dpc import StreamDPC, StreamDPCConfig, StreamTick

# Serve read-path metrics: every nearest_label_query (StreamService.query
# and DPCEngine.predict both route here) counts its per-point outcomes, so
# HIT / MISS_FALLBACK / MISS rates are first-class registry series.
_M_QUERY_POINTS = obs.counter(
    "serve_query_points", "nearest-label query points, labeled by status")
_M_QUERY_CALLS = obs.counter(
    "serve_query_calls", "nearest_label_query invocations")


class QueryStatus(enum.IntEnum):
    """Per-point provenance of a ``StreamService.query`` answer."""

    HIT = 0            # nearest window point within d_cut; its stable label
    MISS_FALLBACK = 1  # out of coverage; nearest current center's stable id
    MISS = 2           # out of coverage and no centers exist; label is -1
    QUARANTINED = 3    # point failed admission (NaN/Inf/dropped); label -1


class QueryResult(NamedTuple):
    labels: np.ndarray   # (m,) int64 stable cluster ids (-1 = noise / MISS)
    status: np.ndarray   # (m,) int8 QueryStatus values


def nearest_label_query(backend, points, d_cut: float, ref_table,
                        ref_labels, center_ids, center_pos,
                        pad_multiple: int) -> QueryResult:
    """The serve layer's read-only label query, shared by
    ``StreamService.query`` and ``repro.engine.DPCEngine.predict``.

    ``ref_table``: (N, d) labeled reference points (device array; padded
    rows hold ``PAD_COORD`` and can never be a finite NN).  ``ref_labels``:
    (N,) labels aligned to the table (-1 = noise).  ``center_ids`` /
    ``center_pos``: the current cluster centers for the miss fallback.
    Queries pad to a multiple of ``pad_multiple`` (fixed request shapes).
    A query within ``d_cut`` of its nearest reference point adopts that
    point's label (``HIT``); otherwise it falls back to the nearest
    center's id (``MISS_FALLBACK``), or -1/``MISS`` when no centers exist.
    The NN runs through the backend's ``denser_nn`` with a -inf query key —
    every reference row is "denser", so the masked NN degenerates to a
    plain NN on the same kernels the write path uses.
    """
    points = np.atleast_2d(np.asarray(points, np.float32))
    m = len(points)
    if m == 0 or points.shape[1] == 0:
        return QueryResult(labels=np.zeros(0, np.int64),
                           status=np.zeros(0, np.int8))
    with obs.span("serve.query", m=m) as sp:
        # non-finite query rows would poison the kernel distances AND the
        # fallback argmin — quarantine them (label -1) instead of guessing
        finite = np.isfinite(points).all(axis=1)
        B = max(int(pad_multiple), 1)
        mp = -(-m // B) * B                   # fixed-shape request pad
        q = np.full((mp, points.shape[1]), PAD_COORD, np.float32)
        q[:m] = np.where(finite[:, None], points, PAD_COORD)
        qk = np.full(mp, np.inf, np.float32)  # +inf key: padding inert
        qk[:m] = -np.inf                      # -inf key: plain NN
        wkey = jnp.zeros((ref_table.shape[0],), jnp.float32)
        dist, parent = sp.sync(backend.denser_nn(
            jnp.asarray(q), jnp.asarray(qk), ref_table, wkey))
        dist = np.asarray(dist)[:m]
        parent = np.asarray(parent)[:m]
        ref_labels = np.asarray(ref_labels)
        labels = np.full(m, -1, np.int64)
        status = np.full(m, int(QueryStatus.MISS), np.int8)
        ok = (np.isfinite(dist) & (dist < d_cut)
              & (parent >= 0) & (parent < len(ref_labels)) & finite)
        labels[ok] = ref_labels[parent[ok]]
        status[ok] = int(QueryStatus.HIT)
        miss = ~ok & finite
        if miss.any() and len(center_ids):
            d2 = ((points[miss][:, None, :].astype(np.float64)
                   - np.asarray(center_pos)[None]) ** 2).sum(-1)
            labels[miss] = np.asarray(center_ids)[np.argmin(d2, axis=1)]
            status[miss] = int(QueryStatus.MISS_FALLBACK)
        status[~finite] = int(QueryStatus.QUARANTINED)
        _M_QUERY_CALLS.inc()
        for st in QueryStatus:
            cnt = int((status == int(st)).sum())
            if cnt:
                _M_QUERY_POINTS.inc(cnt, status=st.name)
    return QueryResult(labels=labels, status=status)


@dataclass(frozen=True)
class StreamServeConfig:
    """Endpoint config: ``stream`` is the clustering config; ``micro_batch``
    (= the stream's ``batch_cap``) is the fixed request-accumulation shape."""

    stream: StreamDPCConfig
    micro_batch: int = field(default=0)  # 0 -> stream.batch_cap
    # write-path admission control (resilience.sanitize); None disables
    admission: AdmissionConfig | None = AdmissionConfig()

    def resolved_micro_batch(self) -> int:
        return self.micro_batch or self.stream.batch_cap


class StreamService:
    def __init__(self, cfg: StreamServeConfig, mesh=None):
        self.cfg = cfg
        self.engine = StreamDPC(cfg.stream, mesh=mesh)
        self._buffer: list[np.ndarray] = []
        self._buffered = 0
        self._submitted = 0

    # ------------------------------------------------------------- writes
    def submit(self, points: np.ndarray) -> list[StreamTick]:
        """Buffer points; run one ingest tick per full micro-batch.

        Points pass admission control first (``cfg.admission``): poisoned
        rows are rejected/dropped/clamped per policy before they can touch
        the buffer.  An empty or fully-quarantined submit is a no-op —
        it never contributes padded ghost ticks."""
        faultinject.fire("service.submit")
        if self.cfg.admission is not None:
            points = admit(points, self.cfg.admission,
                           where="service.submit").points
        else:
            points = np.atleast_2d(np.asarray(points, np.float32))
        if points.size == 0:
            return []
        self._buffer.append(points)
        self._buffered += len(points)
        self._submitted += len(points)
        B = self.cfg.resolved_micro_batch()
        if self._buffered < B:
            return []
        # one concatenation per submit, then slice out full micro-batches
        with obs.span("serve.submit", buffered=self._buffered):
            flat = np.concatenate(self._buffer)
            ticks = [self.engine.ingest(flat[i: i + B])
                     for i in range(0, len(flat) - B + 1, B)]
            rest = flat[len(ticks) * B:]
            self._buffer = [rest] if len(rest) else []
            self._buffered = len(rest)
        return ticks

    def flush(self) -> StreamTick | None:
        """Ingest the partial remainder (padded to the fixed shape inside)."""
        if self._buffered == 0:
            return None
        with obs.span("serve.flush", buffered=self._buffered):
            flat = np.concatenate(self._buffer)
            self._buffer, self._buffered = [], 0
            return self.engine.ingest(flat)

    # ------------------------------------------------------------ queries
    def query(self, points: np.ndarray) -> QueryResult:
        """(labels, status) per query point (read-only).

        Within-coverage points take their nearest window point's stable id
        (``HIT``); out-of-coverage points fall back to the nearest current
        cluster center (``MISS_FALLBACK``) instead of a bare -1; ``MISS``
        (label -1) only when the window currently has no centers at all.
        """
        last = self.engine._last
        assert last is not None, "query before any ingest tick"
        ids, pos = self.engine.center_positions()
        return nearest_label_query(
            self.engine.be, points, self.cfg.stream.d_cut,
            self.engine.window.device, last.labels, ids, pos,
            pad_multiple=self.cfg.resolved_micro_batch())

    def stats(self) -> dict:
        return {**self.engine.stats(), "buffered": self._buffered,
                "submitted": self._submitted}
