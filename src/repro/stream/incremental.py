"""Incremental maintenance of the grouping cell list + sliding-window rho.

The static path rebuilds ``core.grid.build_grid`` (a global sort plus host
capacity measurement) for every point set.  Streaming keeps the only grid
state the Approx-DPC rules actually consume — the *grouping-cell partition*
(rule 1's segments) — incrementally:

* Cell coordinates are **canonical** (``core.grid.canonical_group_coords``:
  absolute-origin ``floor(p / side)``), so the maintained partition is
  bit-identical to what a from-scratch ``build_grid`` of the current window
  would produce — the parity contract of ``repro.stream``.
* A batched insert/evict (``apply``) updates cell membership with O(batch)
  host bookkeeping: a key->cell-id dict, per-cell member counts, and a
  free-list that recycles the ids of emptied cells, keeping every id below
  the window capacity.  The per-slot segment-id table mirrors to device with
  one fixed-shape scatter.
* Capacities are *measured at rebuild time* (the standard cell-list
  pattern): the live-cell budget ``maxima_cap`` (bounds the rule-2/3 query
  pad) and the coordinate box (bounds key packing).  When a batch overflows
  either — density collapse spawning cells, or drift walking out of the
  indexed box — ``apply`` raises :class:`CellOverflow` and the caller falls
  back to a full ``rebuild``.  A rebuild re-derives bookkeeping only; rho is
  partition-independent and survives untouched.
* **Per-cell dirty tracking**: ``apply`` records the grouping-cell coords the
  batch touched (inserted + evicted points), and ``dirty_near`` answers
  which query points sit within a Chebyshev cell radius of any of them.  A
  cell maximum whose answer could have changed must be within 2*d_cut of a
  touched point (its own key, a candidate's key, or a candidate's existence
  can only change there — see ``stream_dpc``), so ``StreamDPC`` skips the
  maxima NN re-query for everything farther away.  A rebuild clears the
  record (``None`` = treat everything dirty — apply may have part-mutated).
* ``repair_rho`` is the density repair: one signed range count over the
  insert/evict delta batch (each surviving neighbor's rho changes by +-1 per
  batch point) plus fresh counts for the inserted rows — O(n * batch)
  instead of the O(n * stencil) full pass.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.analysis.audit import audit_check_rep, audit_determinism
from repro.core.grid import canonical_group_coords
from repro.launch.mesh import flatten_mesh


class CellOverflow(Exception):
    """A batch exceeded a measured capacity; the grid must be rebuilt."""


def _round_up(x: int, m: int) -> int:
    return -(-int(x) // m) * m


class IncrementalGrid:
    """Slot-indexed grouping-cell bookkeeping over a sliding window."""

    def __init__(self, d_cut: float, capacity: int, dim: int,
                 cell_slack: float = 2.0, extent_margin: int = 4):
        assert cell_slack >= 1.0, "cell_slack must be >= 1"
        self.d_cut = float(d_cut)
        self.capacity = int(capacity)
        self.dim = int(dim)
        self.cell_slack = float(cell_slack)
        self.extent_margin = int(extent_margin)
        self.rebuilds = 0
        self._built = False
        # grouping-cell coords touched by the last successful apply();
        # None = unknown (fresh build / rebuild) -> treat everything dirty
        self.last_touched: np.ndarray | None = None

    # ------------------------------------------------------------- helpers
    def _coords(self, pts: np.ndarray) -> np.ndarray:
        """Canonical grouping coords via the shared device helper (the same
        float math as build_grid -> bit-identical partitions)."""
        return np.asarray(canonical_group_coords(jnp.asarray(pts, jnp.float32),
                                                 self.d_cut))

    def _pack(self, coords: np.ndarray) -> np.ndarray:
        """Pack coords into int64 keys against the measured box.

        Raises CellOverflow when any coordinate falls outside the box the
        strides were measured for (drift out of the indexed region)."""
        rel = coords - self.box_lo
        if (rel < 0).any() or (rel >= self.box_extent).any():
            raise CellOverflow("coordinate outside the indexed box")
        return rel @ self.strides

    # ------------------------------------------------------------- rebuild
    def rebuild(self, pts: np.ndarray, count: int) -> None:
        """Re-derive all bookkeeping from the current window (host, O(n))."""
        pts = np.asarray(pts[:count], np.float32)
        coords = self._coords(pts)
        margin = self.extent_margin
        self.box_lo = coords.min(axis=0) - margin
        self.box_extent = (coords.max(axis=0) + margin + 1) - self.box_lo
        ext = self.box_extent.astype(np.int64)
        self.strides = np.concatenate(
            [np.cumprod(ext[::-1])[::-1][1:], np.ones(1, np.int64)])
        keys = self._pack(coords)
        uniq, inv = np.unique(keys, return_inverse=True)
        live = len(uniq)
        self.key_to_id = {int(k): i for i, k in enumerate(uniq)}
        self.cell_count = np.zeros(self.capacity, np.int32)
        self.cell_count[:live] = np.bincount(inv, minlength=live)
        self.live_cells = live
        self.free_ids: list[int] = []
        self.next_id = live
        self.maxima_cap = min(
            self.capacity,
            _round_up(max(64, int(live * self.cell_slack)), 64))
        self.seg_np = np.zeros(self.capacity, np.int32)
        self.seg_np[:count] = inv
        self.seg_dev = jnp.asarray(self.seg_np)
        self.rebuilds += 1 if self._built else 0
        self._built = True
        self.last_touched = None        # apply may have part-mutated

    # --------------------------------------------------------------- apply
    def apply(self, slots: np.ndarray, new_pts: np.ndarray,
              old_pts: np.ndarray, r: int) -> None:
        """Batched insert/evict: slot ``slots[i]``'s point changes from
        ``old_pts[i]`` to ``new_pts[i]`` for i < r.

        Raises CellOverflow when the live-cell count would exceed the
        measured ``maxima_cap`` or a new point leaves the indexed box; the
        caller must ``rebuild`` (bookkeeping may be part-updated — rebuild
        resets everything from the window)."""
        assert self._built
        if r == 0:
            self.last_touched = np.zeros((0, self.dim), np.int64)
            return
        old_coords = self._coords(old_pts[:r])
        new_coords = self._coords(new_pts[:r])
        old_keys = self._pack(old_coords)
        new_keys = self._pack(new_coords)                    # may raise
        # evictions first: emptied ids return to the free list before the
        # insert loop allocates, so ids never exceed the live-cell bound
        for k in old_keys:
            cid = self.key_to_id[int(k)]
            self.cell_count[cid] -= 1
            if self.cell_count[cid] == 0:
                del self.key_to_id[int(k)]
                self.free_ids.append(cid)
                self.live_cells -= 1
        ids = np.empty(r, np.int32)
        for i, k in enumerate(new_keys):
            cid = self.key_to_id.get(int(k))
            if cid is None:
                if self.live_cells + 1 > self.maxima_cap:
                    raise CellOverflow("live cells exceed measured capacity")
                cid = self.free_ids.pop() if self.free_ids else self.next_id
                if cid == self.next_id:
                    self.next_id += 1
                self.key_to_id[int(k)] = cid
                self.live_cells += 1
            self.cell_count[cid] += 1
            ids[i] = cid
        self.seg_np[slots[:r]] = ids
        # one fixed-shape scatter keeps the device mirror in sync
        B = slots.shape[0]
        ids_p = np.zeros(B, np.int32)
        ids_p[:r] = ids
        self.seg_dev = self.seg_dev.at[jnp.asarray(slots)].set(
            jnp.asarray(ids_p), mode="drop")
        self.last_touched = np.concatenate([old_coords, new_coords])

    # ----------------------------------------------------------- snapshot
    def snapshot(self) -> dict:
        """Cheap pre-tick state capture for transactional rollback.

        Host arrays that ``apply`` mutates in place (``cell_count``,
        ``seg_np``) are copied; everything ``apply``/``rebuild`` only
        *reassigns* (``seg_dev``, the box arrays, ``last_touched``) is
        captured by reference — the old object stays valid."""
        if not self._built:
            return {"built": False}
        return {
            "built": True,
            "box_lo": self.box_lo, "box_extent": self.box_extent,
            "strides": self.strides,
            "key_to_id": dict(self.key_to_id),
            "cell_count": self.cell_count.copy(),
            "live_cells": self.live_cells,
            "free_ids": list(self.free_ids),
            "next_id": self.next_id,
            "maxima_cap": self.maxima_cap,
            "seg_np": self.seg_np.copy(),
            "seg_dev": self.seg_dev,
            "rebuilds": self.rebuilds,
            "last_touched": self.last_touched,
        }

    def restore(self, snap: dict) -> None:
        """Roll back to a :meth:`snapshot` (a failed tick's grid state may
        be part-mutated — see ``apply``)."""
        self._built = snap["built"]
        if not self._built:
            self.last_touched = None
            return
        self.box_lo = snap["box_lo"]
        self.box_extent = snap["box_extent"]
        self.strides = snap["strides"]
        self.key_to_id = dict(snap["key_to_id"])
        self.cell_count = snap["cell_count"].copy()
        self.live_cells = snap["live_cells"]
        self.free_ids = list(snap["free_ids"])
        self.next_id = snap["next_id"]
        self.maxima_cap = snap["maxima_cap"]
        self.seg_np = snap["seg_np"].copy()
        self.seg_dev = snap["seg_dev"]
        self.rebuilds = snap["rebuilds"]
        self.last_touched = snap["last_touched"]

    # --------------------------------------------------------------- dirty
    def dirty_near(self, coords: np.ndarray, radius_cells: int) -> np.ndarray:
        """(len(coords),) bool: within ``radius_cells`` (Chebyshev, grouping
        cells) of any cell the last batch touched.  ``None`` record (fresh
        build / rebuild / overflow) conservatively reports all-dirty."""
        if self.last_touched is None:
            return np.ones(len(coords), bool)
        if len(self.last_touched) == 0:
            return np.zeros(len(coords), bool)
        cheb = np.max(np.abs(coords[:, None, :].astype(np.int64)
                             - self.last_touched[None, :, :]), axis=-1)
        return (cheb <= radius_cells).any(axis=1)


# ------------------------------------------------------------- rho repair
def repair_rho(backend, d_cut: float, window_dev, rho, delta_batch, signs,
               ins_batch, slots):
    """Exact sliding-window density repair (slot-indexed, fixed shapes).

    * survivors:  rho += signed range count over the (insert +1 / evict -1)
      delta batch — ``range_count_delta``, the streaming kernel primitive;
    * inserted rows: fresh ``range_count`` against the post-insert window,
      scattered into their slots (padding rows scatter-drop).

    Counts are exact integers in f32, so repairs never drift from a
    from-scratch recount (parity-tested per backend).
    """
    delta = backend.range_count_delta(window_dev, delta_batch, signs, d_cut)
    fresh = backend.range_count(ins_batch, window_dev, d_cut)
    return (rho + delta).at[slots].set(fresh, mode="drop")


def make_sharded_repair(mesh, axis: str, backend, d_cut: float):
    """Sharded ingest: the rho repair as one SPMD pass over the window.

    The window rows shard over every device (``launch.mesh.flatten_mesh`` —
    the same flattening ``DistDPCConfig`` uses for the batch path); the
    delta batch replicates.  Each shard repairs its rows locally and the
    inserted rows' fresh counts reduce with a psum (integer-exact in f32,
    so the sharded repair is bit-identical to the replicated one).
    Returns a jitted callable with ``repair_rho``'s signature (minus
    backend/d_cut); build once per (mesh, backend) and reuse across ticks.
    """
    flat = flatten_mesh(mesh, axis)

    @audit_check_rep(
        "per-row repairs are P(axis)-local; the one replicated output "
        "(inserted rows' fresh counts) is produced by an explicit psum, "
        "identical on every member by construction",
        collectives=("psum",))
    @audit_determinism(
        "the psum reduces per-shard neighbor *counts* — exact integers in "
        "f32 far below 2^24, so addition is associative over them and "
        "every reduction order (ring, tree, any device count) yields "
        "identical bits; parity-tested against the replicated recount",
        ops=("psum",))
    def f(w_my, rho_my, batch, sgn, ins):
        d = backend.range_count_delta(w_my, batch, sgn, d_cut)
        part = backend.range_count(ins, w_my, d_cut)
        return rho_my + d, jax.lax.psum(part, axis)

    sm = shard_map(f, mesh=flat,
                   in_specs=(P(axis), P(axis), P(None), P(None), P(None)),
                   out_specs=(P(axis), P(None)),
                   check_rep=False)   # pallas_call lacks a rep rule
    sm_jit = jax.jit(sm)

    def repair(window_dev, rho, delta_batch, signs, ins_batch, slots):
        n_dev = flat.devices.size
        assert window_dev.shape[0] % n_dev == 0, \
            "device count must divide the window capacity"
        rho2, fresh = sm_jit(window_dev, rho, delta_batch, signs, ins_batch)
        return rho2.at[slots].set(fresh, mode="drop")

    return repair
