"""Streaming DPC: incremental sliding-window clustering.

``StreamDPC`` maintains Approx-DPC state over a fixed-capacity sliding
window with micro-batch ``ingest`` (incremental rho repair + maxima-only
dependent updates, full-rebuild fallback on capacity overflow, stable
cluster ids across ticks).  ``StreamService`` wraps it with the serve
layer's fixed-shape padding discipline.
"""
from .incremental import CellOverflow, IncrementalGrid, repair_rho
from .service import (QueryResult, QueryStatus, StreamServeConfig,
                      StreamService)
from .stream_dpc import StreamDPC, StreamDPCConfig, StreamTick
from .window import SlidingWindow

__all__ = ["StreamDPC", "StreamDPCConfig", "StreamTick", "SlidingWindow",
           "IncrementalGrid", "CellOverflow", "repair_rho",
           "StreamService", "StreamServeConfig", "QueryResult",
           "QueryStatus"]
