"""StreamDPC: incremental sliding-window density-peaks clustering.

The static pipeline answers "cluster this point set"; production traffic asks
"keep the clustering current while points arrive and expire".  StreamDPC
maintains Approx-DPC state over a fixed-capacity sliding window with
micro-batch ``ingest``:

* **rho** repairs incrementally (``incremental.repair_rho``): one signed
  range count over the insert/evict delta batch instead of a full density
  pass — the window's grid index is the asset, not the per-tick output.
* **delta / dependent points** re-derive from the repaired densities using
  the maintained grouping partition: rule 1 is O(n) segment ops (no distance
  search — every non-maximum depends on its cell maximum), and only the cell
  maxima — the points whose dependent can actually have changed (their
  current NN evicted, or the rho ordering around them flipped) — are
  re-queried with one ``denser_nn_update`` pass.  Found within d_cut ->
  rule 2; otherwise the query IS the rule-3 exact root answer, exactly as in
  the dense Approx-DPC branch.
* **per-cell dirty tracking** (``cfg.dirty_tracking``, default on): a cell
  maximum's answer can only change when something within 2*d_cut of it
  changed — its own key changes within d_cut of a batch point; a candidate
  appears/disappears within its current nn_delta < d_cut; or its current
  parent (within d_cut) has *its* key changed by a batch point within
  another d_cut.  Maxima of cells outside that halo of the batch
  (``incremental.dirty_near``: Chebyshev ceil(2*sqrt(d))+1 grouping cells)
  reuse the previous tick's cached raw NN answer verbatim — except rule-3
  roots (cached answer not < d_cut), whose parent can live arbitrarily far
  and which are always re-queried.  The dirty query set pads to a power of
  two instead of ``maxima_cap``, so small batches into many-cell windows
  re-query a handful of rows, not every maximum (bit-parity preserved —
  the cached answer is provably unchanged, and the parity suite ingests
  both localized and scattered streams to prove it).
* **full-rebuild fallback**: when a batch overflows the measured cell
  capacities (density collapse or drift out of the indexed box) the grid
  bookkeeping rebuilds from the window; rho is partition-independent and
  survives, so a rebuild costs O(n) host work, not a recluster.
* **label continuity**: cluster centers carry *stable ids* across ticks,
  matched by nearest-center between consecutive windows, so downstream
  consumers see "cluster 7 drifted" rather than arbitrary relabels.

Parity contract (tested per backend, incl. ``pallas-interpret``): after any
sequence of ingest/evict batches, rho/delta/parent and the derived
centers/labels are identical to a from-scratch ``run_approxdpc`` +
``assign_labels`` on the current window contents.  The deterministic density
jitter is slot-indexed and the window extracts in slot order, so the
tie-break key stream matches the static path bit-for-bit.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.core.approxdpc import run_approxdpc
from repro.core.dpc_types import DPCResult, density_jitter
from repro.core.labels import Clustering, assign_labels
from repro.engine.planner import plan
from repro.engine.spec import ExecSpec, merge_legacy
from repro.kernels.density import PAD_COORD
from repro.resilience import faultinject

from .incremental import CellOverflow, IncrementalGrid, make_sharded_repair, \
    repair_rho
from .sharded import make_sharded_center_dists, make_sharded_labels, \
    make_sharded_nn_update
from .window import SlidingWindow

# Process-wide stream counters on the obs registry.  ``StreamDPC.stats()``
# keeps its per-instance dict (the legacy read surface); these aggregate
# across every stream in the process for the metrics snapshot.
_M_TICKS = obs.counter("stream_ticks", "StreamDPC ticks across all streams")
_M_FULL = obs.counter("stream_full_recomputes",
                      "full window recomputes (warm-up / bulk loads)")
_M_NN_MAXIMA = obs.counter(
    "stream_nn_maxima_total", "cell maxima seen by the incremental NN stage")
_M_NN_QUERIES = obs.counter(
    "stream_nn_queries",
    "maxima actually re-queried (dirty); maxima_total - queries = the "
    "dirty-tracking saving")


@dataclass(frozen=True)
class StreamDPCConfig:
    """Streaming DPC configuration (mirrors ``DPCConfig`` where shared).

    ``capacity`` is the sliding-window size (fixed shapes; steady state
    keeps it full), ``batch_cap`` the static micro-batch pad.  Execution
    (kernel backend, full-tick engine layout, sweep block, sharded-ingest
    mesh axis) is one :class:`repro.engine.ExecSpec` on ``exec_spec``;
    streaming rides the same registry/auto-detection via the two batched
    primitives (``range_count_delta`` / ``denser_nn_update``).  The
    ``backend`` / ``layout`` / ``data_axis`` fields are the legacy
    spellings and fold into the spec with a ``DeprecationWarning``
    (see ``repro.engine``; ``DPCEngine.partial_fit`` is the facade).
    """

    d_cut: float
    capacity: int = 4096
    batch_cap: int = 256
    rho_min: float = 10.0
    delta_min: float | None = None      # default 2 * d_cut (must be > d_cut)
    cell_slack: float = 2.0             # live-cell budget over measured count
    extent_margin: int = 4              # indexed-box margin, in cells
    continuity_radius: float | None = None  # center matching (default 2*d_cut)
    dirty_tracking: bool = True         # skip clean-cell maxima NN re-query
    transactional: bool = True          # roll a failed tick back pre-tick
    exec_spec: ExecSpec | None = None   # the unified execution axes
    backend: str | None = None          # deprecated -> ExecSpec.backend
    data_axis: str = "data"             # deprecated -> ExecSpec.data_axis
    layout: str | None = None           # deprecated -> ExecSpec.layout

    def __post_init__(self):
        if not self.d_cut > 0.0:
            raise ValueError(f"d_cut must be positive, got {self.d_cut!r}")
        if self.batch_cap > self.capacity:
            raise ValueError("batch_cap cannot exceed the window capacity")
        ex = merge_legacy(self.exec_spec, owner="StreamDPCConfig",
                          backend=self.backend, layout=self.layout,
                          data_axis=self.data_axis)
        object.__setattr__(self, "exec_spec", ex)

    def resolved_exec(self) -> ExecSpec:
        return self.exec_spec

    def resolved_delta_min(self) -> float:
        dm = 2.0 * self.d_cut if self.delta_min is None else self.delta_min
        if dm <= self.d_cut:
            raise ValueError("delta_min must exceed d_cut (Def. 5)")
        return dm

    def resolved_radius(self) -> float:
        return (2.0 * self.d_cut if self.continuity_radius is None
                else self.continuity_radius)


class StreamTick(NamedTuple):
    labels: np.ndarray        # (count,) stable cluster ids, -1 noise
    centers: np.ndarray       # (count,) bool center mask
    stable_ids: np.ndarray    # (k,) stable id of tick-local cluster 0..k-1
    num_clusters: int
    rebuilt: bool             # grid bookkeeping was rebuilt this tick
    full_recompute: bool      # warm-up path (window below capacity)
    tick: int


@partial(jax.jit, static_argnames=("num_segments",))
def _rule1(rho_key, seg_ids, num_segments: int):
    """Approx-DPC rule 1 over maintained segments: per-cell argmax of the
    all-distinct density key; every point's provisional parent is its cell
    maximum (the maximum points at itself until rules 2/3 overwrite it)."""
    slot = jnp.arange(rho_key.shape[0], dtype=jnp.int32)
    seg_max = jax.ops.segment_max(rho_key, seg_ids, num_segments=num_segments)
    is_max = rho_key == seg_max[seg_ids]
    max_slot = jax.ops.segment_max(jnp.where(is_max, slot, -1), seg_ids,
                                   num_segments=num_segments)
    return is_max, max_slot[seg_ids]


@jax.jit
def _assemble(parent1, q_slots, nn_delta, nn_parent, d_cut):
    """Merge rule 1 with the maxima NN pass — the dense Approx-DPC stamping:
    NN within d_cut -> rule 2 (delta stamped d_cut); NN beyond -> rule 3
    exact root delta (inf at the global peak)."""
    n = parent1.shape[0]
    d_cut = jnp.asarray(d_cut, jnp.float32)
    found2 = jnp.isfinite(nn_delta) & (nn_delta < d_cut)
    q_delta = jnp.where(found2, d_cut,
                        jnp.where(jnp.isfinite(nn_delta), nn_delta, jnp.inf))
    delta = jnp.full((n,), d_cut, jnp.float32)
    delta = delta.at[q_slots].set(q_delta, mode="drop")
    parent = parent1.at[q_slots].set(nn_parent, mode="drop").astype(jnp.int32)
    return delta, parent


class StreamDPC:
    """Micro-batch streaming driver over a sliding window.

    ``mesh``: optional jax Mesh — the window shards over every device for
    the whole repair tail, mirroring how ``DistDPCConfig`` shards the
    batch path: rho repair (``incremental.make_sharded_repair``), dirty
    maxima NN re-query, label propagation and the center-continuity
    distances (``stream.sharded``).  The NN stage resolves its layout
    through the same ``shard_blocksparse_layout`` R1 probe as the batch
    driver, so block-sparse shard phases ride along automatically.
    Requires ``capacity % device_count == 0``.
    """

    def __init__(self, cfg: StreamDPCConfig, mesh=None):
        self.cfg = cfg
        # shape-independent plan: resolves the backend + layout once; the
        # full-tick driver re-plans per window shape through the plan cache
        self.plan = plan(None, cfg.resolved_exec())
        self.be = self.plan.backend
        self.mesh = mesh
        self.window: SlidingWindow | None = None
        self.grid: IncrementalGrid | None = None
        self._rho = None
        self._jitter = density_jitter(cfg.capacity)
        self._sharded = None
        self._sharded_nn = None
        self._sharded_labels = None
        self._sharded_cdist = None
        self._result: DPCResult | None = None
        self._clustering: Clustering | None = None
        self._registry: list[tuple[int, np.ndarray]] = []  # (stable_id, pos)
        self._next_stable = 0
        self._ticks = 0
        self._full_recomputes = 0
        self._last: StreamTick | None = None
        # raw (nn_delta, nn_parent) cache by slot for clean-cell maxima
        self._nn_delta_cache: np.ndarray | None = None
        self._nn_parent_cache: np.ndarray | None = None
        self._nn_valid: np.ndarray | None = None
        self._nn_maxima_total = 0
        self._nn_queries = 0

    # ------------------------------------------------------------- public
    def initialize(self, points: np.ndarray) -> StreamTick:
        """Bulk-load up to ``capacity`` points (one full recompute)."""
        points = np.atleast_2d(np.asarray(points, np.float32))
        if len(points) > self.cfg.capacity:
            raise ValueError(
                f"initialize got {len(points)} points for a capacity-"
                f"{self.cfg.capacity} window; bulk-load at most capacity "
                f"and stream the rest through ingest()")
        self._ensure_window(points.shape[1])
        w = self.window
        w.host[: len(points)] = points
        w.device = w.device.at[: len(points)].set(jnp.asarray(points))
        w.count = len(points)
        w.cursor = w.count % self.cfg.capacity
        return self._full_tick()

    def ingest(self, batch: np.ndarray) -> StreamTick:
        """Micro-batch ingest; batches larger than ``batch_cap`` chunk.

        Transactional (``cfg.transactional``, default on): an exception
        inside a tick — kernel failure, grid corruption, injected fault —
        rolls window/grid/rho back to the pre-tick snapshot before
        re-raising, so a failed tick never leaves half-applied state and
        the stream stays serviceable.  An empty batch is a no-op (returns
        the last tick), never a padded ghost tick."""
        batch = np.asarray(batch, np.float32)
        if batch.size == 0:
            return self._last
        batch = np.atleast_2d(batch)
        self._ensure_window(batch.shape[1])
        tick = self._last
        while len(batch):
            chunk, batch = batch[: self.cfg.batch_cap], \
                batch[self.cfg.batch_cap:]
            snap = self._snapshot() if self.cfg.transactional else None
            try:
                if not self.window.full:
                    tick = self._warmup(chunk)
                else:
                    tick = self._steady(chunk)
            except Exception:
                if snap is not None:
                    self._rollback(snap)
                raise
        return tick

    def save(self, path: str) -> None:
        """Atomic, versioned checkpoint of the complete incremental state
        (see :mod:`repro.resilience.checkpoint`)."""
        from repro.resilience.checkpoint import save_stream
        save_stream(self, path)

    @classmethod
    def restore(cls, path: str, mesh=None) -> "StreamDPC":
        """Rebuild a stream from a checkpoint; post-restore ticks are
        bit-identical to the uninterrupted run, on any device count."""
        from repro.resilience.checkpoint import restore_stream
        return restore_stream(path, mesh=mesh)

    def window_points(self) -> np.ndarray:
        """Window contents in slot order — run_approxdpc on this array is
        the from-scratch reference the stream is parity-tested against."""
        return self.window.contents()

    def center_positions(self) -> tuple[np.ndarray, np.ndarray]:
        """(stable_ids, positions) of the current tick's cluster centers —
        the read-side view ``StreamService.query`` uses for its
        nearest-center miss fallback."""
        if not self._registry:
            dim = 0 if self.window is None else self.window.dim
            return np.zeros(0, np.int64), np.zeros((0, dim), np.float32)
        ids = np.array([s for s, _ in self._registry], np.int64)
        pos = np.stack([p for _, p in self._registry]).astype(np.float32)
        return ids, pos

    @property
    def result(self) -> DPCResult:
        return self._result

    @property
    def clustering(self) -> Clustering:
        return self._clustering

    def stats(self) -> dict:
        return {
            "ticks": self._ticks,
            "count": 0 if self.window is None else self.window.count,
            "capacity": self.cfg.capacity,
            "full_recomputes": self._full_recomputes,
            "rebuilds": 0 if self.grid is None else self.grid.rebuilds,
            "live_cells": 0 if self.grid is None else self.grid.live_cells,
            "maxima_cap": 0 if self.grid is None else self.grid.maxima_cap,
            "clusters": 0 if self._last is None else self._last.num_clusters,
            "nn_maxima_total": self._nn_maxima_total,
            "nn_queries": self._nn_queries,
        }

    # ------------------------------------------------------------ phases
    def _ensure_window(self, dim: int):
        if self.window is not None and dim != self.window.dim:
            raise ValueError(
                f"batch dimensionality {dim} != window dimensionality "
                f"{self.window.dim}; a stream's dimension is fixed at "
                f"first ingest")
        if self.window is None:
            self.window = SlidingWindow(self.cfg.capacity, dim)
            self.grid = IncrementalGrid(
                self.cfg.d_cut, self.cfg.capacity, dim,
                cell_slack=self.cfg.cell_slack,
                extent_margin=self.cfg.extent_margin)
            if self.mesh is not None:
                axis = self.plan.data_axis
                self._sharded = make_sharded_repair(
                    self.mesh, axis, self.be, self.cfg.d_cut)
                # the batch driver's probe-gated layout decision, shared:
                # R1-clean block-sparse shard phases switch on here too
                from repro.distributed.dpc import shard_blocksparse_layout
                lay = shard_blocksparse_layout(self.plan, self.mesh)
                self._sharded_nn = make_sharded_nn_update(
                    self.mesh, axis, self.be, layout=lay)
                self._sharded_labels = make_sharded_labels(
                    self.mesh, axis, self.cfg.capacity)
                self._sharded_cdist = make_sharded_center_dists(
                    self.mesh, axis)
            cap = self.cfg.capacity
            self._nn_delta_cache = np.full(cap, np.inf, np.float32)
            self._nn_parent_cache = np.full(cap, -1, np.int32)
            self._nn_valid = np.zeros(cap, bool)

    # ------------------------------------------------------- transactions
    def _snapshot(self) -> dict:
        """Pre-tick state capture.  Host arrays mutated in place (window
        host mirror, NN caches) are copied; device arrays are immutable
        jnp values captured by reference — a snapshot costs O(capacity)
        host memcpy, nothing on device."""
        w = self.window
        return {
            "host": w.host.copy(), "device": w.device,
            "count": w.count, "cursor": w.cursor, "wticks": w.ticks,
            "grid": self.grid.snapshot(),
            "rho": self._rho,
            "nn_delta": self._nn_delta_cache.copy(),
            "nn_parent": self._nn_parent_cache.copy(),
            "nn_valid": self._nn_valid.copy(),
            "registry": list(self._registry),
            "next_stable": self._next_stable,
            "ticks": self._ticks,
            "full_recomputes": self._full_recomputes,
            "nn_maxima_total": self._nn_maxima_total,
            "nn_queries": self._nn_queries,
            "result": self._result,
            "clustering": self._clustering,
            "last": self._last,
        }

    def _rollback(self, snap: dict) -> None:
        w = self.window
        w.host[:] = snap["host"]
        w.device = snap["device"]
        w.count, w.cursor, w.ticks = snap["count"], snap["cursor"], \
            snap["wticks"]
        self.grid.restore(snap["grid"])
        self._rho = snap["rho"]
        self._nn_delta_cache[:] = snap["nn_delta"]
        self._nn_parent_cache[:] = snap["nn_parent"]
        self._nn_valid[:] = snap["nn_valid"]
        self._registry = list(snap["registry"])
        self._next_stable = snap["next_stable"]
        self._ticks = snap["ticks"]
        self._full_recomputes = snap["full_recomputes"]
        self._nn_maxima_total = snap["nn_maxima_total"]
        self._nn_queries = snap["nn_queries"]
        self._result = snap["result"]
        self._clustering = snap["clustering"]
        self._last = snap["last"]

    def _warmup(self, chunk: np.ndarray) -> StreamTick:
        """Below capacity: append and recompute from scratch (the density
        jitter is n-indexed, so every fill step reshuffles tie-breaks —
        incremental repair only pays once shapes freeze at capacity)."""
        w = self.window
        room = self.cfg.capacity - w.count
        take = chunk[:room]
        B = self.cfg.batch_cap
        padded = np.full((B, w.dim), PAD_COORD, np.float32)
        padded[: len(take)] = take
        w.push(padded, len(take))
        tick = self._full_tick()
        rest = chunk[room:]
        return self._steady(rest) if len(rest) else tick

    def _full_tick(self) -> StreamTick:
        """Full recompute of the current window (warm-up / bulk load)."""
        w = self.window
        with obs.span("stream.full_tick", count=w.count) as sp:
            res = run_approxdpc(jnp.asarray(w.contents()), self.cfg.d_cut,
                                exec_spec=self.plan.spec)
            sp.sync((res.rho, res.delta))
        self._full_recomputes += 1
        _M_FULL.inc()
        # the full tick stamps rule-2 deltas (not raw NN answers), so the
        # raw cache restarts empty — the next steady tick re-queries all
        self._nn_valid[:] = False
        if w.full:
            # steady state starts: freeze rho at full window shape and
            # derive the incremental bookkeeping
            self._rho = res.rho
            self.grid.rebuild(w.host, w.count)
        return self._finish(res, rebuilt=False, full=True)

    def _steady(self, chunk: np.ndarray) -> StreamTick:
        cfg = self.cfg
        w = self.window
        r = len(chunk)
        if r == 0:
            return self._last
        B = cfg.batch_cap
        with obs.span("stream.tick", batch=r) as tick_sp:
            padded = np.full((B, w.dim), PAD_COORD, np.float32)
            padded[:r] = chunk
            slots, evicted, ev_valid = w.push(padded, r)
            rebuilt = False
            faultinject.fire("tick.grid_apply")
            with obs.span("stream.grid_apply") as sp:
                try:
                    self.grid.apply(slots, padded, evicted, r)
                except CellOverflow:
                    self.grid.rebuild(w.host, w.count)
                    rebuilt = True
                sp.set(rebuilt=rebuilt)
            # rho repair: +1 per inserted, -1 per evicted neighbor (fused)
            delta_batch = jnp.asarray(np.concatenate([padded, np.where(
                ev_valid[:, None], evicted, PAD_COORD)]))
            signs = np.zeros(2 * B, np.float32)
            signs[:r] = 1.0
            signs[B:][ev_valid] = -1.0
            repair = self._sharded if self._sharded is not None else partial(
                repair_rho, self.be, cfg.d_cut)
            faultinject.fire("tick.rho_repair")
            with obs.span("stream.rho_repair") as sp:
                self._rho = sp.sync(repair(
                    w.device, self._rho, delta_batch, jnp.asarray(signs),
                    jnp.asarray(padded), jnp.asarray(slots)))
            out = self._finish(self._incremental_result(), rebuilt=rebuilt,
                               full=False)
            tick_sp.set(rebuilt=rebuilt)
        return out

    def _incremental_result(self) -> DPCResult:
        """Rules 1-3 from maintained state: segment ops for every point, one
        denser-NN pass for the *dirty* cell maxima only (clean-cell maxima
        reuse their cached raw answer — see the module docstring)."""
        cfg = self.cfg
        cap = cfg.capacity
        rho_key = self._rho + self._jitter
        is_max, parent1 = _rule1(rho_key, self.grid.seg_dev, cap)
        q = np.nonzero(np.asarray(is_max))[0]
        assert len(q) <= self.grid.maxima_cap   # apply() enforces the budget

        if cfg.dirty_tracking:
            cached = self._nn_valid[q]
            # rule-3 roots (no denser point within d_cut): their parent can
            # be arbitrarily far, so any batch anywhere may flip it
            roots = ~(self._nn_delta_cache[q] < cfg.d_cut)
            rc = int(np.ceil(2.0 * np.sqrt(self.window.dim))) + 1
            near = self.grid.dirty_near(
                self.grid._coords(self.window.host[q]), rc)
            dirty = (~cached) | roots | near
        else:
            dirty = np.ones(len(q), bool)
        dq = q[dirty]
        self._nn_maxima_total += len(q)
        self._nn_queries += len(dq)
        _M_NN_MAXIMA.inc(len(q))
        _M_NN_QUERIES.inc(len(dq))
        faultinject.fire("tick.nn_update")

        if len(dq):
            # pad the dirty set to a power of two (few shape buckets), not
            # to maxima_cap — the whole point is a smaller NN pass
            pad = 1
            while pad < len(dq):
                pad *= 2
            dq_slots = np.full(pad, cap, np.int64)
            dq_slots[: len(dq)] = dq
            nn_fn = (self._sharded_nn if self._sharded_nn is not None
                     else self.be.denser_nn_update)
            with obs.span("stream.nn_update", queries=len(dq)) as sp:
                nn_d, nn_p = sp.sync(nn_fn(
                    self.window.device, rho_key, jnp.asarray(dq_slots)))
            self._nn_delta_cache[dq] = np.asarray(nn_d)[: len(dq)]
            self._nn_parent_cache[dq] = np.asarray(nn_p)[: len(dq)]
            self._nn_valid[dq] = True

        q_slots = np.full(self.grid.maxima_cap, cap, np.int64)
        q_slots[: len(q)] = q
        nn_delta = np.full(self.grid.maxima_cap, np.inf, np.float32)
        nn_parent = np.full(self.grid.maxima_cap, -1, np.int32)
        nn_delta[: len(q)] = self._nn_delta_cache[q]
        nn_parent[: len(q)] = self._nn_parent_cache[q]
        delta, parent = _assemble(parent1, jnp.asarray(q_slots),
                                  jnp.asarray(nn_delta),
                                  jnp.asarray(nn_parent), cfg.d_cut)
        return DPCResult(rho=self._rho, rho_key=rho_key, delta=delta,
                         parent=parent)

    # ------------------------------------------------- labels + continuity
    def _finish(self, res: DPCResult, *, rebuilt: bool,
                full: bool) -> StreamTick:
        faultinject.fire("tick.finish")
        cfg = self.cfg
        # warm-up ticks run below capacity; the sharded propagation is
        # shape-frozen at capacity, so they fall back to the replicated pass
        if (self._sharded_labels is not None
                and res.parent.shape[0] == cfg.capacity):
            cl = self._sharded_labels(res, cfg.rho_min,
                                      cfg.resolved_delta_min())
        else:
            cl = assign_labels(res, cfg.rho_min, cfg.resolved_delta_min())
        self._result, self._clustering = res, cl
        with obs.span("stream.continuity") as sp:
            labels = np.asarray(cl.labels)
            centers = np.asarray(cl.centers)
            c_slots = np.nonzero(centers)[0]
            stable = self._match_centers(self.window.host[c_slots])
            k = int(cl.num_clusters)
            by_label = np.full(max(k, 1), -1, np.int64)
            by_label[labels[c_slots]] = stable
            out = np.where(labels >= 0, by_label[np.maximum(labels, 0)], -1)
            self._registry = [(int(s), self.window.host[c].copy())
                              for s, c in zip(stable, c_slots)]
            sp.set(clusters=k)
        self._ticks += 1
        _M_TICKS.inc()
        self._last = StreamTick(labels=out, centers=centers,
                                stable_ids=stable, num_clusters=k,
                                rebuilt=rebuilt, full_recompute=full,
                                tick=self._ticks)
        return self._last

    def _match_centers(self, positions: np.ndarray) -> np.ndarray:
        """Greedy nearest matching of new centers to the previous tick's,
        within ``continuity_radius``; unmatched centers get fresh ids."""
        m = len(positions)
        stable = np.full(m, -1, np.int64)
        if self._registry and m:
            prev_pos = np.stack([p for _, p in self._registry])
            prev_ids = np.array([s for s, _ in self._registry])
            if self._sharded_cdist is not None:
                dist = self._sharded_cdist(positions, prev_pos)
            else:
                dist = np.sqrt(((positions[:, None, :].astype(np.float64)
                                 - prev_pos[None]) ** 2).sum(-1))
            radius = self.cfg.resolved_radius()
            used_new = np.zeros(m, bool)
            used_old = np.zeros(len(prev_ids), bool)
            for flat in np.argsort(dist, axis=None):
                i, j = divmod(int(flat), len(prev_ids))
                if dist[i, j] > radius:
                    break
                if used_new[i] or used_old[j]:
                    continue
                stable[i] = prev_ids[j]
                used_new[i] = used_old[j] = True
        for i in range(m):
            if stable[i] < 0:
                stable[i] = self._next_stable
                self._next_stable += 1
        return stable
