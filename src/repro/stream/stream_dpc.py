"""StreamDPC: incremental sliding-window density-peaks clustering.

The static pipeline answers "cluster this point set"; production traffic asks
"keep the clustering current while points arrive and expire".  StreamDPC
maintains Approx-DPC state over a fixed-capacity sliding window with
micro-batch ``ingest``:

* **rho** repairs incrementally (``incremental.repair_rho``): one signed
  range count over the insert/evict delta batch instead of a full density
  pass — the window's grid index is the asset, not the per-tick output.
* **delta / dependent points** re-derive from the repaired densities using
  the maintained grouping partition: rule 1 is O(n) segment ops (no distance
  search — every non-maximum depends on its cell maximum), and only the cell
  maxima — the points whose dependent can actually have changed (their
  current NN evicted, or the rho ordering around them flipped) — are
  re-queried with one ``denser_nn_update`` pass.  Found within d_cut ->
  rule 2; otherwise the query IS the rule-3 exact root answer, exactly as in
  the dense Approx-DPC branch.
* **full-rebuild fallback**: when a batch overflows the measured cell
  capacities (density collapse or drift out of the indexed box) the grid
  bookkeeping rebuilds from the window; rho is partition-independent and
  survives, so a rebuild costs O(n) host work, not a recluster.
* **label continuity**: cluster centers carry *stable ids* across ticks,
  matched by nearest-center between consecutive windows, so downstream
  consumers see "cluster 7 drifted" rather than arbitrary relabels.

Parity contract (tested per backend, incl. ``pallas-interpret``): after any
sequence of ingest/evict batches, rho/delta/parent and the derived
centers/labels are identical to a from-scratch ``run_approxdpc`` +
``assign_labels`` on the current window contents.  The deterministic density
jitter is slot-indexed and the window extracts in slot order, so the
tie-break key stream matches the static path bit-for-bit.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.approxdpc import run_approxdpc
from repro.core.dpc_types import DPCResult, density_jitter
from repro.core.labels import Clustering, assign_labels
from repro.kernels.backend import get_backend
from repro.kernels.density import PAD_COORD

from .incremental import CellOverflow, IncrementalGrid, make_sharded_repair, \
    repair_rho
from .window import SlidingWindow


@dataclass(frozen=True)
class StreamDPCConfig:
    """Streaming DPC configuration (mirrors ``DPCConfig`` where shared).

    ``capacity`` is the sliding-window size (fixed shapes; steady state
    keeps it full), ``batch_cap`` the static micro-batch pad.  ``backend``
    selects the kernel backend exactly as in ``DPCConfig``; streaming rides
    the same registry/auto-detection via the two batched primitives
    (``range_count_delta`` / ``denser_nn_update``).
    """

    d_cut: float
    capacity: int = 4096
    batch_cap: int = 256
    rho_min: float = 10.0
    delta_min: float | None = None      # default 2 * d_cut (must be > d_cut)
    backend: str | None = None
    cell_slack: float = 2.0             # live-cell budget over measured count
    extent_margin: int = 4              # indexed-box margin, in cells
    continuity_radius: float | None = None  # center matching (default 2*d_cut)
    data_axis: str = "data"             # sharded-ingest mesh axis name

    def __post_init__(self):
        if self.batch_cap > self.capacity:
            raise ValueError("batch_cap cannot exceed the window capacity")

    def resolved_delta_min(self) -> float:
        dm = 2.0 * self.d_cut if self.delta_min is None else self.delta_min
        if dm <= self.d_cut:
            raise ValueError("delta_min must exceed d_cut (Def. 5)")
        return dm

    def resolved_radius(self) -> float:
        return (2.0 * self.d_cut if self.continuity_radius is None
                else self.continuity_radius)


class StreamTick(NamedTuple):
    labels: np.ndarray        # (count,) stable cluster ids, -1 noise
    centers: np.ndarray       # (count,) bool center mask
    stable_ids: np.ndarray    # (k,) stable id of tick-local cluster 0..k-1
    num_clusters: int
    rebuilt: bool             # grid bookkeeping was rebuilt this tick
    full_recompute: bool      # warm-up path (window below capacity)
    tick: int


@partial(jax.jit, static_argnames=("num_segments",))
def _rule1(rho_key, seg_ids, num_segments: int):
    """Approx-DPC rule 1 over maintained segments: per-cell argmax of the
    all-distinct density key; every point's provisional parent is its cell
    maximum (the maximum points at itself until rules 2/3 overwrite it)."""
    slot = jnp.arange(rho_key.shape[0], dtype=jnp.int32)
    seg_max = jax.ops.segment_max(rho_key, seg_ids, num_segments=num_segments)
    is_max = rho_key == seg_max[seg_ids]
    max_slot = jax.ops.segment_max(jnp.where(is_max, slot, -1), seg_ids,
                                   num_segments=num_segments)
    return is_max, max_slot[seg_ids]


@jax.jit
def _assemble(parent1, q_slots, nn_delta, nn_parent, d_cut):
    """Merge rule 1 with the maxima NN pass — the dense Approx-DPC stamping:
    NN within d_cut -> rule 2 (delta stamped d_cut); NN beyond -> rule 3
    exact root delta (inf at the global peak)."""
    n = parent1.shape[0]
    d_cut = jnp.asarray(d_cut, jnp.float32)
    found2 = jnp.isfinite(nn_delta) & (nn_delta < d_cut)
    q_delta = jnp.where(found2, d_cut,
                        jnp.where(jnp.isfinite(nn_delta), nn_delta, jnp.inf))
    delta = jnp.full((n,), d_cut, jnp.float32)
    delta = delta.at[q_slots].set(q_delta, mode="drop")
    parent = parent1.at[q_slots].set(nn_parent, mode="drop").astype(jnp.int32)
    return delta, parent


class StreamDPC:
    """Micro-batch streaming driver over a sliding window.

    ``mesh``: optional jax Mesh — the window shards over every device for
    the rho repair (``incremental.make_sharded_repair``), mirroring how
    ``DistDPCConfig`` shards the batch path; requires
    ``capacity % device_count == 0``.
    """

    def __init__(self, cfg: StreamDPCConfig, mesh=None):
        self.cfg = cfg
        self.be = get_backend(cfg.backend)
        self.mesh = mesh
        self.window: SlidingWindow | None = None
        self.grid: IncrementalGrid | None = None
        self._rho = None
        self._jitter = density_jitter(cfg.capacity)
        self._sharded = None
        self._result: DPCResult | None = None
        self._clustering: Clustering | None = None
        self._registry: list[tuple[int, np.ndarray]] = []  # (stable_id, pos)
        self._next_stable = 0
        self._ticks = 0
        self._full_recomputes = 0
        self._last: StreamTick | None = None

    # ------------------------------------------------------------- public
    def initialize(self, points: np.ndarray) -> StreamTick:
        """Bulk-load up to ``capacity`` points (one full recompute)."""
        points = np.asarray(points, np.float32)
        assert len(points) <= self.cfg.capacity, "initialize overfills window"
        self._ensure_window(points.shape[1])
        w = self.window
        w.host[: len(points)] = points
        w.device = w.device.at[: len(points)].set(jnp.asarray(points))
        w.count = len(points)
        w.cursor = w.count % self.cfg.capacity
        return self._full_tick()

    def ingest(self, batch: np.ndarray) -> StreamTick:
        """Micro-batch ingest; batches larger than ``batch_cap`` chunk."""
        batch = np.atleast_2d(np.asarray(batch, np.float32))
        self._ensure_window(batch.shape[1])
        tick = self._last
        while len(batch):
            chunk, batch = batch[: self.cfg.batch_cap], \
                batch[self.cfg.batch_cap:]
            if not self.window.full:
                tick = self._warmup(chunk)
            else:
                tick = self._steady(chunk)
        return tick

    def window_points(self) -> np.ndarray:
        """Window contents in slot order — run_approxdpc on this array is
        the from-scratch reference the stream is parity-tested against."""
        return self.window.contents()

    def center_positions(self) -> tuple[np.ndarray, np.ndarray]:
        """(stable_ids, positions) of the current tick's cluster centers —
        the read-side view ``StreamService.query`` uses for its
        nearest-center miss fallback."""
        if not self._registry:
            dim = 0 if self.window is None else self.window.dim
            return np.zeros(0, np.int64), np.zeros((0, dim), np.float32)
        ids = np.array([s for s, _ in self._registry], np.int64)
        pos = np.stack([p for _, p in self._registry]).astype(np.float32)
        return ids, pos

    @property
    def result(self) -> DPCResult:
        return self._result

    @property
    def clustering(self) -> Clustering:
        return self._clustering

    def stats(self) -> dict:
        return {
            "ticks": self._ticks,
            "count": 0 if self.window is None else self.window.count,
            "capacity": self.cfg.capacity,
            "full_recomputes": self._full_recomputes,
            "rebuilds": 0 if self.grid is None else self.grid.rebuilds,
            "live_cells": 0 if self.grid is None else self.grid.live_cells,
            "maxima_cap": 0 if self.grid is None else self.grid.maxima_cap,
            "clusters": 0 if self._last is None else self._last.num_clusters,
        }

    # ------------------------------------------------------------ phases
    def _ensure_window(self, dim: int):
        if self.window is None:
            self.window = SlidingWindow(self.cfg.capacity, dim)
            self.grid = IncrementalGrid(
                self.cfg.d_cut, self.cfg.capacity, dim,
                cell_slack=self.cfg.cell_slack,
                extent_margin=self.cfg.extent_margin)
            if self.mesh is not None:
                self._sharded = make_sharded_repair(
                    self.mesh, self.cfg.data_axis, self.be, self.cfg.d_cut)

    def _warmup(self, chunk: np.ndarray) -> StreamTick:
        """Below capacity: append and recompute from scratch (the density
        jitter is n-indexed, so every fill step reshuffles tie-breaks —
        incremental repair only pays once shapes freeze at capacity)."""
        w = self.window
        room = self.cfg.capacity - w.count
        take = chunk[:room]
        B = self.cfg.batch_cap
        padded = np.full((B, w.dim), PAD_COORD, np.float32)
        padded[: len(take)] = take
        w.push(padded, len(take))
        tick = self._full_tick()
        rest = chunk[room:]
        return self._steady(rest) if len(rest) else tick

    def _full_tick(self) -> StreamTick:
        """Full recompute of the current window (warm-up / bulk load)."""
        w = self.window
        res = run_approxdpc(jnp.asarray(w.contents()), self.cfg.d_cut,
                            backend=self.be)
        self._full_recomputes += 1
        if w.full:
            # steady state starts: freeze rho at full window shape and
            # derive the incremental bookkeeping
            self._rho = res.rho
            self.grid.rebuild(w.host, w.count)
        return self._finish(res, rebuilt=False, full=True)

    def _steady(self, chunk: np.ndarray) -> StreamTick:
        cfg = self.cfg
        w = self.window
        r = len(chunk)
        if r == 0:
            return self._last
        B = cfg.batch_cap
        padded = np.full((B, w.dim), PAD_COORD, np.float32)
        padded[:r] = chunk
        slots, evicted, ev_valid = w.push(padded, r)
        rebuilt = False
        try:
            self.grid.apply(slots, padded, evicted, r)
        except CellOverflow:
            self.grid.rebuild(w.host, w.count)
            rebuilt = True
        # rho repair: +1 per inserted, -1 per evicted neighbor (fused)
        delta_batch = jnp.asarray(np.concatenate([padded, np.where(
            ev_valid[:, None], evicted, PAD_COORD)]))
        signs = np.zeros(2 * B, np.float32)
        signs[:r] = 1.0
        signs[B:][ev_valid] = -1.0
        repair = self._sharded if self._sharded is not None else partial(
            repair_rho, self.be, cfg.d_cut)
        self._rho = repair(w.device, self._rho, delta_batch,
                           jnp.asarray(signs), jnp.asarray(padded),
                           jnp.asarray(slots))
        return self._finish(self._incremental_result(), rebuilt=rebuilt,
                            full=False)

    def _incremental_result(self) -> DPCResult:
        """Rules 1-3 from maintained state: segment ops for every point, one
        denser-NN pass for the cell maxima only."""
        cfg = self.cfg
        cap = cfg.capacity
        rho_key = self._rho + self._jitter
        is_max, parent1 = _rule1(rho_key, self.grid.seg_dev, cap)
        q = np.nonzero(np.asarray(is_max))[0]
        assert len(q) <= self.grid.maxima_cap   # apply() enforces the budget
        q_slots = np.full(self.grid.maxima_cap, cap, np.int64)
        q_slots[: len(q)] = q
        q_slots = jnp.asarray(q_slots)
        nn_delta, nn_parent = self.be.denser_nn_update(
            self.window.device, rho_key, q_slots)
        delta, parent = _assemble(parent1, q_slots, nn_delta, nn_parent,
                                  cfg.d_cut)
        return DPCResult(rho=self._rho, rho_key=rho_key, delta=delta,
                         parent=parent)

    # ------------------------------------------------- labels + continuity
    def _finish(self, res: DPCResult, *, rebuilt: bool,
                full: bool) -> StreamTick:
        cfg = self.cfg
        cl = assign_labels(res, cfg.rho_min, cfg.resolved_delta_min())
        self._result, self._clustering = res, cl
        labels = np.asarray(cl.labels)
        centers = np.asarray(cl.centers)
        c_slots = np.nonzero(centers)[0]
        stable = self._match_centers(self.window.host[c_slots])
        k = int(cl.num_clusters)
        by_label = np.full(max(k, 1), -1, np.int64)
        by_label[labels[c_slots]] = stable
        out = np.where(labels >= 0, by_label[np.maximum(labels, 0)], -1)
        self._registry = [(int(s), self.window.host[c].copy())
                          for s, c in zip(stable, c_slots)]
        self._ticks += 1
        self._last = StreamTick(labels=out, centers=centers,
                                stable_ids=stable, num_clusters=k,
                                rebuilt=rebuilt, full_recompute=full,
                                tick=self._ticks)
        return self._last

    def _match_centers(self, positions: np.ndarray) -> np.ndarray:
        """Greedy nearest matching of new centers to the previous tick's,
        within ``continuity_radius``; unmatched centers get fresh ids."""
        m = len(positions)
        stable = np.full(m, -1, np.int64)
        if self._registry and m:
            prev_pos = np.stack([p for _, p in self._registry])
            prev_ids = np.array([s for s, _ in self._registry])
            dist = np.sqrt(((positions[:, None, :].astype(np.float64)
                             - prev_pos[None]) ** 2).sum(-1))
            radius = self.cfg.resolved_radius()
            used_new = np.zeros(m, bool)
            used_old = np.zeros(len(prev_ids), bool)
            for flat in np.argsort(dist, axis=None):
                i, j = divmod(int(flat), len(prev_ids))
                if dist[i, j] > radius:
                    break
                if used_new[i] or used_old[j]:
                    continue
                stable[i] = prev_ids[j]
                used_new[i] = used_old[j] = True
        for i in range(m):
            if stable[i] < 0:
                stable[i] = self._next_stable
                self._next_stable += 1
        return stable
