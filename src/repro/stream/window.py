"""Fixed-capacity sliding-window point store (ring buffer, slot-stable).

The streaming analogue of the static point table: ``capacity`` slots whose
*identity is stable* — a point keeps its slot for its whole lifetime, so every
per-point quantity (rho, cell id, the deterministic density jitter) is slot-
indexed and survives ticks without reindexing.  Arrival order is the ring
order: the oldest point always sits at the cursor, so eviction is simply
overwriting the next ``r`` slots.

Shapes are donate-friendly fixed: ``push`` takes a batch padded to a static
``batch_cap`` plus a valid count, and the device table is updated with one
fixed-shape scatter (invalid rows scatter to slot ``capacity`` and drop).
During warm-up the occupied slots are exactly the prefix ``[0, count)`` —
the property the full-recompute path relies on to extract window contents in
slot order.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels.density import PAD_COORD


class SlidingWindow:
    """Ring buffer of points with a host mirror and a device table."""

    def __init__(self, capacity: int, dim: int):
        self.capacity = int(capacity)
        self.dim = int(dim)
        # empty slots sit at the kernels' PAD coordinate: far outside any
        # d_cut, so warm-up reads (e.g. service.query NN) never match them
        self.host = np.full((capacity, dim), PAD_COORD, np.float32)
        self.device = jnp.full((capacity, dim), PAD_COORD, jnp.float32)
        self.count = 0          # occupied slots (== capacity at steady state)
        self.cursor = 0         # next slot to fill / evict (ring order)
        self.ticks = 0

    @property
    def full(self) -> bool:
        return self.count == self.capacity

    def contents(self) -> np.ndarray:
        """Current window contents in slot order (host copy, (count, d))."""
        return self.host[: self.count].copy()

    def push(self, batch: np.ndarray, r: int):
        """Overwrite the next ``r`` ring slots with ``batch[:r]``.

        ``batch`` is the fixed-shape (batch_cap, d) micro-batch; rows past
        ``r`` are padding.  Returns ``(slots, evicted, evicted_valid)``:

        * ``slots``          (batch_cap,) int32 — target slot per batch row,
                             ``capacity`` (out of range -> scatter-drop) for
                             padding rows;
        * ``evicted``        (batch_cap, d) f32 — the *old* contents of those
                             slots (garbage where not ``evicted_valid``);
        * ``evicted_valid``  (batch_cap,) bool — True where the slot held a
                             live point that this push evicts.
        """
        cap, B = self.capacity, batch.shape[0]
        assert 0 <= r <= min(B, cap)
        slots = np.full((B,), cap, np.int32)
        ring = (self.cursor + np.arange(r)) % cap
        slots[:r] = ring
        evicted = self.host[np.minimum(slots, cap - 1)].copy()
        evicted_valid = np.zeros((B,), bool)
        evicted_valid[:r] = ring < self.count
        # host mirror + one fixed-shape device scatter (drop on padding)
        self.host[ring] = batch[:r]
        self.device = self.device.at[jnp.asarray(slots)].set(
            jnp.asarray(batch), mode="drop")
        self.cursor = int((self.cursor + r) % cap)
        self.count = min(self.count + r, cap)
        self.ticks += 1
        return slots, evicted, evicted_valid
